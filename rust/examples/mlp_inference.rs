//! End-to-end driver (DESIGN.md deliverable): serve batched MLP inference
//! requests through the full three-layer stack and prove the layers
//! compose:
//!
//! * **L3 (rust)** — the coordinator partitions the 3×1024×1024 model
//!   across a simulated 16-DPU PIM set and orchestrates the per-layer
//!   gather/redistribute, exactly like the PrIM MLP benchmark;
//! * **L2/L1 (JAX+Pallas via PJRT)** — the AOT `mlp.hlo.txt` artifact
//!   (row-panel Pallas GEMV kernels lowered through JAX) runs the same
//!   requests on the host as the numeric oracle / CPU counterpart;
//! * outputs are compared request by request; per-request simulated PIM
//!   latency, host XLA latency, and native-Rust CPU latency are reported.
//!
//! ```bash
//! make artifacts && cargo run --release --example mlp_inference
//! ```

use prim_pim::arch::SystemConfig;
use prim_pim::coordinator::PimSet;
use prim_pim::dpu::Ctx;
use prim_pim::prim::gemv::gemv_kernel;
use prim_pim::runtime::{self, MlpOracle, PjrtRuntime, MLP_DIM};
use prim_pim::util::Rng;

const N_DPUS: usize = 16;
const LAYERS: usize = 3;
const REQUESTS: usize = 8;

fn main() -> anyhow::Result<()> {
    let dim = MLP_DIM; // 1024, fixed by the AOT artifact
    let mut rng = Rng::new(7);

    // small integer weights: exact in both u32 and f32 paths
    let weights: Vec<Vec<u32>> =
        (0..LAYERS).map(|_| (0..dim * dim).map(|_| rng.below(3) as u32).collect()).collect();
    let requests: Vec<Vec<u32>> =
        (0..REQUESTS).map(|_| (0..dim).map(|_| rng.below(4) as u32).collect()).collect();

    // ---- PIM side: distribute the model across 16 simulated DPUs
    // (typed MRAM symbols: W1 | W2 | W3 | x | y)
    let mut set = PimSet::allocate(SystemConfig::p21_rank(), N_DPUS as u32);
    let rows_per = dim / N_DPUS;
    let w_syms: Vec<_> = (0..LAYERS).map(|_| set.symbol::<u32>(rows_per * dim)).collect();
    let x_sym = set.symbol::<u32>(dim);
    let y_sym = set.symbol::<u32>(rows_per * 2);
    for (l, w) in weights.iter().enumerate() {
        let bufs: Vec<Vec<u32>> = (0..N_DPUS)
            .map(|d| w[d * rows_per * dim..(d + 1) * rows_per * dim].to_vec())
            .collect();
        set.xfer(w_syms[l]).to().equal(&bufs);
    }
    println!(
        "model loaded: {} layers x {} DPUs ({:.1} MB/DPU)",
        LAYERS,
        N_DPUS,
        (LAYERS * rows_per * dim * 4) as f64 / 1e6
    );

    // ---- host side: the AOT JAX/Pallas oracle through PJRT
    let oracle = if runtime::artifacts_available() {
        let rt = PjrtRuntime::cpu()?;
        let wf: Vec<Vec<f32>> =
            weights.iter().map(|w| w.iter().map(|&v| v as f32).collect()).collect();
        let b0 = vec![0f32; dim];
        Some(MlpOracle::load(
            &rt,
            [wf[0].clone(), wf[1].clone(), wf[2].clone()],
            [b0.clone(), b0.clone(), b0],
        )?)
    } else {
        eprintln!("artifacts missing (run `make artifacts`): skipping PJRT oracle");
        None
    };

    let mut pim_lat = Vec::new();
    let mut xla_lat = Vec::new();
    let mut all_match = true;

    for (i, x) in requests.iter().enumerate() {
        // serve on PIM: 3 layers with host gather/redistribute between
        let before = set.metrics;
        set.xfer(x_sym).to().broadcast(x);
        for l in 0..LAYERS {
            let w_sym = w_syms[l];
            set.launch(16, |_d, ctx: &mut Ctx| {
                gemv_kernel(ctx, rows_per, dim, w_sym.off(), x_sym.off(), y_sym.off(), true);
            });
            if l + 1 < LAYERS {
                let parts = set.xfer(y_sym).inter().from().all();
                let next: Vec<u32> =
                    parts.iter().flat_map(|p| p.iter().step_by(2).copied()).collect();
                set.host_merge((dim * 4) as u64, dim as u64);
                set.xfer(x_sym).inter().to().broadcast(&next);
            }
        }
        let parts = set.xfer(y_sym).from().all();
        let y_pim: Vec<u32> = parts.iter().flat_map(|p| p.iter().step_by(2).copied()).collect();
        let lat = set.metrics.total() - before.total();
        pim_lat.push(lat);

        // oracle on the host through XLA
        if let Some(oracle) = &oracle {
            let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
            let t0 = std::time::Instant::now();
            let y_xla = oracle.forward(&xf)?;
            xla_lat.push(t0.elapsed().as_secs_f64());
            let matches = y_pim.iter().zip(&y_xla).all(|(p, h)| {
                let rel = (*p as f64 - *h as f64).abs() / (1.0 + *h as f64);
                rel < 1e-5
            });
            if !matches {
                all_match = false;
            }
            println!(
                "request {i}: PIM {:.3} ms (simulated) | XLA oracle {:.3} ms | match: {}",
                lat * 1e3,
                xla_lat.last().unwrap() * 1e3,
                matches
            );
        } else {
            println!("request {i}: PIM {:.3} ms (simulated)", lat * 1e3);
        }
    }

    // native CPU baseline for one request
    let m = prim_pim::baselines::native::gemv(&weights[0], &requests[0], dim, dim);
    println!("\nnative rust single-layer GEMV: {:.3} ms", m.secs * 1e3);

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!(
        "served {REQUESTS} requests | mean PIM latency {:.3} ms | throughput {:.1} req/s (simulated)",
        mean(&pim_lat) * 1e3,
        1.0 / mean(&pim_lat)
    );
    println!("breakdown: {}", set.metrics.fmt_ms());
    if oracle.is_some() {
        println!("oracle agreement: {}", if all_match { "ALL MATCH" } else { "MISMATCH" });
        assert!(all_match, "PIM output must match the JAX/Pallas oracle");
    }
    Ok(())
}
