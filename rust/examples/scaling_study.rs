//! Scaling study: strong + weak scaling of a chosen benchmark, with the
//! AOT fleet estimator (PJRT `dpu_timing` artifact) cross-checking the
//! simulated kernel times at fleet scale.
//!
//! ```bash
//! cargo run --release --example scaling_study [BENCH]
//! ```

use prim_pim::prim::common::{bench_by_name, RunConfig};
use prim_pim::runtime::{self, DpuDesc};

fn main() -> anyhow::Result<()> {
    let name = std::env::args().nth(1).unwrap_or_else(|| "RED".to_string());
    let bench = bench_by_name(&name).unwrap_or_else(|| panic!("unknown benchmark {name}"));
    println!("== strong scaling: {name} (fixed total problem) ==");
    println!("{:>5} {:>12} {:>12} {:>10}", "DPUs", "DPU ms", "total ms", "speedup");
    let mut t1 = 0.0;
    for nd in [1u32, 4, 16, 64] {
        let rc = RunConfig {
            n_dpus: nd,
            n_tasklets: bench.best_tasklets(),
            scale: 0.05,
            ..RunConfig::rank_default()
        };
        let r = bench.run(&rc);
        assert!(r.verified);
        if nd == 1 {
            t1 = r.breakdown.dpu;
        }
        println!(
            "{:>5} {:>12.3} {:>12.3} {:>9.1}x",
            nd,
            r.breakdown.dpu * 1e3,
            r.breakdown.total() * 1e3,
            t1 / r.breakdown.dpu.max(1e-12)
        );
    }

    println!("\n== weak scaling: {name} (fixed per-DPU load) ==");
    println!("{:>5} {:>12} {:>14}", "DPUs", "DPU ms", "Inter-DPU ms");
    let mut last: Option<(f64, u64, u64)> = None;
    for nd in [1u32, 4, 16, 64] {
        let rc = RunConfig {
            n_dpus: nd,
            n_tasklets: bench.best_tasklets(),
            scale: 0.05 * nd as f64 / 64.0,
            ..RunConfig::rank_default()
        };
        let r = bench.run(&rc);
        assert!(r.verified);
        println!(
            "{:>5} {:>12.3} {:>14.3}",
            nd,
            r.breakdown.dpu * 1e3,
            r.breakdown.inter_dpu * 1e3
        );
        last = Some((r.breakdown.dpu, r.dpu_instrs / nd as u64, nd as u64));
    }

    // fleet estimate: project the per-DPU descriptor to 2,556 DPUs
    if let Some((dpu_secs, instrs_per_dpu, nd)) = last {
        let _ = nd;
        let desc = DpuDesc {
            instrs_per_tasklet: instrs_per_dpu as f64 / bench.best_tasklets() as f64,
            tasklets: bench.best_tasklets() as f64,
            n_reads: 0.0,
            read_bytes: 0.0,
            n_writes: 0.0,
            write_bytes: 0.0,
        };
        let cycles = if runtime::artifacts_available() {
            let rt = runtime::PjrtRuntime::cpu()?;
            runtime::FleetEstimator::load(&rt)?.estimate(&vec![desc; 2048])?
        } else {
            runtime::fleet_cycles_native(&vec![desc; 2048])
        };
        let est = cycles[0] / 350e6;
        println!(
            "\nfleet estimator (pipeline-bound lower bound, 2,048-DPU projection): \
             {:.3} ms/DPU vs simulated {:.3} ms/DPU",
            est * 1e3,
            dpu_secs * 1e3
        );
    }
    Ok(())
}
