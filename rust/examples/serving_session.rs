//! Persistent-session serving demo: load a workload's dataset into MRAM
//! once, then serve a stream of requests against the warm state —
//! serialized and pipelined.
//!
//! ```text
//! cargo run --release --example serving_session
//! ```
//!
//! Equivalent CLI: `repro serve --bench BS --requests 8 [--pipeline]`.

use prim_pim::arch::SystemConfig;
use prim_pim::prim::common::{ExecChoice, RunConfig};
use prim_pim::prim::workload::{serve, workload_by_name};

fn main() {
    let w = workload_by_name("BS").expect("BS is registered");
    let rc = RunConfig {
        sys: SystemConfig::p21_rank(),
        n_dpus: 16,
        n_tasklets: w.best_tasklets(),
        scale: 0.01,
        seed: 42,
        exec: ExecChoice::Auto,
        trace: None,
        metrics: None,
    };
    let requests = 8;

    for pipeline in [false, true] {
        let rep = serve(w.as_ref(), &rc, requests, pipeline);
        println!(
            "\n== {} · {} requests · {} ==",
            rep.name,
            requests,
            if pipeline { "pipelined" } else { "serialized" }
        );
        println!("cold load : {}", rep.cold.fmt_ms());
        println!("steady    : {}", rep.steady_state().fmt_ms());
        println!(
            "warm total: {:.3} ms (overlap hidden {:.3} ms) [{}]",
            rep.warm.total() * 1e3,
            rep.warm.overlapped * 1e3,
            if rep.verified { "ok" } else { "VERIFY-FAIL" }
        );
        let oneshot = (rep.cold.total() + rep.steady_state().total()) * requests as f64;
        let amortized = rep.cold.total() + rep.warm.total();
        println!(
            "{requests} one-shot runs would model {:.3} ms — warm serving is {:.2}x cheaper",
            oneshot * 1e3,
            oneshot / amortized
        );
    }
}
