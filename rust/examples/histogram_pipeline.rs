//! Image-processing scenario: a camera pipeline histogramming a stream of
//! frames on the PIM fleet (HST-S), with per-frame latency, a native CPU
//! baseline, and the energy-model comparison — the Fig. 16/17 story on one
//! concrete workload.
//!
//! ```bash
//! cargo run --release --example histogram_pipeline
//! ```

use prim_pim::arch::SystemConfig;
use prim_pim::baselines::native;
use prim_pim::energy::EnergyModel;
use prim_pim::prim::common::RunConfig;
use prim_pim::prim::hst::{run_hst, HstKind};
use prim_pim::util::data::natural_image;

fn main() {
    const FRAMES: usize = 4;
    let sys = SystemConfig::p21_rank();
    let em = EnergyModel::default();
    let mut pim_total = 0.0;
    let mut cpu_total = 0.0;

    println!("histogramming {FRAMES} frames (1536x1024-scale natural images) on 32 DPUs\n");
    for f in 0..FRAMES {
        let rc = RunConfig {
            n_dpus: 32,
            n_tasklets: 16,
            scale: 0.05,
            seed: 100 + f as u64,
            sys: sys.clone(),
            exec: Default::default(),
            trace: None,
            metrics: None,
        };
        let r = run_hst(HstKind::Short, "HST-S", &rc, 256);
        assert!(r.verified, "frame {f} failed verification");
        let pim = r.breakdown.total();
        pim_total += pim;

        // native CPU baseline on the same frame
        let px = natural_image(rc.scaled(1536 * 1024), 12, rc.seed);
        let px8: Vec<u32> = px.iter().map(|p| p >> 4).collect();
        let m = native::hst(&px8);
        cpu_total += m.secs;

        println!(
            "frame {f}: PIM {:.3} ms (DPU {:.3} + xfer {:.3}) | native CPU {:.3} ms",
            pim * 1e3,
            r.breakdown.dpu * 1e3,
            (r.breakdown.cpu_dpu + r.breakdown.dpu_cpu) * 1e3,
            m.secs * 1e3
        );

        let e_pim = em.pim_joules(&sys, 32, &r.breakdown);
        let e_cpu = em.cpu_joules(m.secs);
        println!(
            "         energy: PIM {:.4} J | CPU {:.4} J ({}x)",
            e_pim,
            e_cpu,
            (e_cpu / e_pim) as u64
        );
    }
    println!(
        "\npipeline: PIM {:.2} ms total, CPU {:.2} ms total ({} frames)",
        pim_total * 1e3,
        cpu_total * 1e3,
        FRAMES
    );
}
