//! Quickstart: allocate a few simulated DPUs, run a vector-addition kernel
//! written against the UPMEM-style API, verify the result, and print the
//! paper-style time breakdown.
//!
//! Data movement uses the typed-symbol API: carve MRAM regions from the
//! fleet layout (`set.symbol`), then transfer through the builder
//! (`set.xfer(sym).to().ragged(..)` etc.) — no hand-computed offsets.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use prim_pim::arch::{DType, Op, SystemConfig};
use prim_pim::coordinator::PimSet;
use prim_pim::dpu::Ctx;
use prim_pim::util::Rng;

fn main() {
    // 1. allocate 8 DPUs of the 2,556-DPU (P21) system
    let mut set = PimSet::allocate(SystemConfig::p21_rank(), 8);

    // 2. build a dataset; 65,000 elements do NOT divide evenly by 8 DPUs,
    //    so the chunks are pushed with a ragged parallel transfer
    let n = 65_000usize;
    let mut rng = Rng::new(1);
    let a = rng.vec_i32(n, 1 << 20);
    let b = rng.vec_i32(n, 1 << 20);
    let per = n.div_ceil(8).div_ceil(256) * 256; // whole 1,024-B blocks
    let chunk = |src: &[i32], d: usize| src[(d * per).min(n)..((d + 1) * per).min(n)].to_vec();
    let abufs: Vec<Vec<i32>> = (0..8).map(|d| chunk(&a, d)).collect();
    let bbufs: Vec<Vec<i32>> = (0..8).map(|d| chunk(&b, d)).collect();
    let counts: Vec<usize> = abufs.iter().map(Vec::len).collect();
    let a_sym = set.symbol::<i32>(per);
    let b_sym = set.symbol::<i32>(per);
    let c_sym = set.symbol::<i32>(per);
    set.xfer(a_sym).to().ragged(&abufs);
    set.xfer(b_sym).to().ragged(&bbufs);

    // 3. launch 16 tasklets per DPU: stream 1,024-B blocks, add, write back
    let counts_ref = &counts;
    set.launch(16, |d, ctx: &mut Ctx| {
        let my_bytes = counts_ref[d] * 4;
        let blocks = my_bytes.div_ceil(1024);
        let wa = ctx.mem_alloc(1024);
        let wb = ctx.mem_alloc(1024);
        let mut blk = ctx.tasklet_id as usize;
        while blk < blocks {
            let off = blk * 1024;
            let take = (my_bytes - off).min(1024);
            ctx.mram_read(a_sym.off() + off, wa, take);
            ctx.mram_read(b_sym.off() + off, wb, take);
            let av: Vec<i32> = ctx.wram_get(wa, take / 4);
            let bv: Vec<i32> = ctx.wram_get(wb, take / 4);
            let cv: Vec<i32> = av.iter().zip(&bv).map(|(x, y)| x.wrapping_add(*y)).collect();
            ctx.wram_set(wa, &cv);
            ctx.charge_stream(DType::I32, Op::Add, (take / 4) as u64);
            ctx.mram_write(wa, c_sym.off() + off, take);
            blk += ctx.n_tasklets as usize;
        }
    });

    // 4. retrieve (ragged — each DPU returns exactly its share) and verify
    let out = set.xfer(c_sym).from().ragged(&counts);
    let mut c: Vec<i32> = Vec::with_capacity(n);
    for part in &out {
        c.extend_from_slice(part);
    }
    let ok = c
        .iter()
        .enumerate()
        .all(|(g, v)| *v == a[g].wrapping_add(b[g]));

    println!("vector-add on 8 simulated DPUs: {}", if ok { "VERIFIED" } else { "FAILED" });
    println!("  {}", set.metrics.fmt_ms());
    println!(
        "  {} launches, {:.1} KB to DPUs, {:.1} KB back",
        set.metrics.launches,
        set.metrics.bytes_to_dpu as f64 / 1024.0,
        set.metrics.bytes_from_dpu as f64 / 1024.0
    );
    assert!(ok);
}
