//! Quickstart: allocate a few simulated DPUs, run a vector-addition kernel
//! written against the UPMEM-style API, verify the result, and print the
//! paper-style time breakdown.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use prim_pim::arch::{DType, Op, SystemConfig};
use prim_pim::coordinator::PimSet;
use prim_pim::dpu::Ctx;
use prim_pim::util::Rng;

fn main() {
    // 1. allocate 8 DPUs of the 2,556-DPU (P21) system
    let mut set = PimSet::allocate(SystemConfig::p21_rank(), 8);

    // 2. build a dataset and push equal chunks to the DPUs (parallel xfer)
    let n = 64 * 1024usize;
    let mut rng = Rng::new(1);
    let a = rng.vec_i32(n, 1 << 20);
    let b = rng.vec_i32(n, 1 << 20);
    let per = n / 8;
    let abufs: Vec<Vec<i32>> = (0..8).map(|d| a[d * per..(d + 1) * per].to_vec()).collect();
    let bbufs: Vec<Vec<i32>> = (0..8).map(|d| b[d * per..(d + 1) * per].to_vec()).collect();
    set.push_to(0, &abufs);
    set.push_to(per * 4, &bbufs);

    // 3. launch 16 tasklets per DPU: stream 1,024-B blocks, add, write back
    let blocks = per * 4 / 1024;
    set.launch(16, |_dpu, ctx: &mut Ctx| {
        let wa = ctx.mem_alloc(1024);
        let wb = ctx.mem_alloc(1024);
        let mut blk = ctx.tasklet_id as usize;
        while blk < blocks {
            let off = blk * 1024;
            ctx.mram_read(off, wa, 1024);
            ctx.mram_read(per * 4 + off, wb, 1024);
            let av: Vec<i32> = ctx.wram_get(wa, 256);
            let bv: Vec<i32> = ctx.wram_get(wb, 256);
            let cv: Vec<i32> = av.iter().zip(&bv).map(|(x, y)| x.wrapping_add(*y)).collect();
            ctx.wram_set(wa, &cv);
            ctx.charge_stream(DType::I32, Op::Add, 256);
            ctx.mram_write(wa, 2 * per * 4 + off, 1024);
            blk += ctx.n_tasklets as usize;
        }
    });

    // 4. retrieve and verify
    let out = set.push_from::<i32>(2 * per * 4, per);
    let ok = out.iter().enumerate().all(|(d, chunk)| {
        chunk.iter().enumerate().all(|(i, v)| {
            let g = d * per + i;
            *v == a[g].wrapping_add(b[g])
        })
    });

    println!("vector-add on 8 simulated DPUs: {}", if ok { "VERIFIED" } else { "FAILED" });
    println!("  {}", set.metrics.fmt_ms());
    println!(
        "  {} launches, {:.1} KB to DPUs, {:.1} KB back",
        set.metrics.launches,
        set.metrics.bytes_to_dpu as f64 / 1024.0,
        set.metrics.bytes_from_dpu as f64 / 1024.0
    );
    assert!(ok);
}
