//! Graph-processing scenario: BFS over an rMat power-law graph on the PIM
//! fleet, showing the paper's central negative result — frontier unioning
//! through the host makes inter-DPU synchronization the bottleneck.
//!
//! ```bash
//! cargo run --release --example graph_bfs
//! ```

use prim_pim::prim::bfs::Bfs;
use prim_pim::prim::common::{PrimBench, RunConfig};

fn main() {
    println!("BFS on rMat graphs (loc-gowalla statistics), scaling the DPU count:\n");
    println!(
        "{:>5} {:>12} {:>14} {:>12} {:>12}",
        "DPUs", "DPU ms", "Inter-DPU ms", "xfer ms", "inter/DPU"
    );
    for nd in [1u32, 4, 16, 64] {
        let rc = RunConfig {
            n_dpus: nd,
            n_tasklets: 16,
            scale: 0.05,
            ..RunConfig::rank_default()
        };
        let r = Bfs.run(&rc);
        assert!(r.verified);
        println!(
            "{:>5} {:>12.3} {:>14.3} {:>12.3} {:>11.1}x",
            nd,
            r.breakdown.dpu * 1e3,
            r.breakdown.inter_dpu * 1e3,
            (r.breakdown.cpu_dpu + r.breakdown.dpu_cpu) * 1e3,
            r.breakdown.inter_dpu / r.breakdown.dpu.max(1e-12)
        );
    }
    println!(
        "\nKey Takeaway 3: the frontier union runs through the host, so adding DPUs\n\
         shrinks kernel time but grows synchronization — BFS prefers few DPUs."
    );
}
