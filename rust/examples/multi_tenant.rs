//! Multi-tenant fleet scheduling demo: three workloads resident at once
//! on disjoint rank slices of one machine, open-loop traffic, and the
//! three bus-arbitration policies compared on the same request streams.
//!
//! ```text
//! cargo run --release --example multi_tenant
//! ```
//!
//! Equivalent CLI: `repro sched --tenants "gemv:2,bs:1,va:1" --requests 6
//! --policy wrr` (add `--json` for `results/BENCH_SCHED.json`).

use prim_pim::coordinator::{run_sched, PolicyKind, SchedConfig, TenantSpec};
use prim_pim::harness::harness_scale;
use prim_pim::prim::common::ExecChoice;
use prim_pim::prim::workload::workload_by_name;

fn main() {
    // gemv gets 2 ranks (128 DPUs); bs gets 1 rank with WRR weight 2;
    // va gets 1 rank. Rates are open-loop requests/second of modeled
    // time, per tenant.
    let mut tenants =
        TenantSpec::parse_list("gemv:2,bs:1:2:2000,va:1").expect("mix parses");
    for t in &mut tenants {
        let w = workload_by_name(&t.bench).expect("known workload");
        t.scale = harness_scale(w.name()) * 0.05;
    }

    for policy in PolicyKind::ALL {
        let cfg = SchedConfig {
            requests: 6,
            policy,
            rate: 1000.0, // default for tenants without an explicit rate
            max_batch: 4,
            pipeline: false,
            seed: 42,
            exec: ExecChoice::Auto,
            tenants: tenants.clone(),
            trace: None,
            metrics: None,
            elastic: None,
            shift: None,
        };
        let rep = run_sched(&cfg).expect("scheduler runs");
        println!(
            "\n== policy {} · {} tenants on {} ranks · makespan {:.3} ms · occupancy {:.1}% ==",
            rep.policy,
            rep.tenants.len(),
            rep.total_ranks,
            rep.makespan * 1e3,
            rep.occupancy() * 100.0,
        );
        for t in &rep.tenants {
            let l = t.latency_summary();
            println!(
                "{:<6} {:>1} ranks @ {:>6.0} req/s | thr {:>8.1} req/s | p50 {:>7.3} ms  \
                 p99 {:>7.3} ms  max {:>7.3} ms | queue p99 {:>7.3} ms | util {:>5.1}% [{}]",
                t.bench,
                t.slice.n_ranks,
                t.rate,
                t.throughput(),
                l.p50 * 1e3,
                l.p99 * 1e3,
                l.max * 1e3,
                prim_pim::util::stats::percentile(
                    &t.records.iter().map(|r| r.queueing()).collect::<Vec<_>>(),
                    99.0,
                ) * 1e3,
                t.utilization(rep.makespan) * 100.0,
                if t.verified { "ok" } else { "VERIFY-FAIL" },
            );
        }
    }
}
