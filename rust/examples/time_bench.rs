use prim_pim::prim::common::{bench_by_name, RunConfig};
use prim_pim::arch::SystemConfig;
fn main() {
    let name = std::env::args().nth(1).unwrap();
    let scale: f64 = std::env::args().nth(2).unwrap().parse().unwrap();
    let nd: u32 = std::env::args().nth(3).map(|s| s.parse().unwrap()).unwrap_or(64);
    let b = bench_by_name(&name).unwrap();
    let rc = RunConfig {
        n_dpus: nd,
        n_tasklets: b.best_tasklets(),
        scale,
        seed: 42,
        sys: SystemConfig::p21_rank(),
        exec: Default::default(),
        trace: None,
        metrics: None,
    };
    let t0 = std::time::Instant::now();
    let r = b.run(&rc);
    println!(
        "{name} scale {scale} nd {nd}: wall {:.2}s verified={} dpu={:.4}s",
        t0.elapsed().as_secs_f64(),
        r.verified,
        r.breakdown.dpu
    );
}
