//! Async command-queue demo: the modeled `dpu_launch(DPU_ASYNCHRONOUS)`
//! + `dpu_sync` pattern. Two "requests" double-buffer their inputs, so
//! request 1's push has no data dependency on request 0's launch and
//! hides under it on the modeled timeline — the §6 overlap
//! recommendation, derived from the command DAG instead of hand-credited.
//!
//! ```text
//! cargo run --release --example async_queue
//! ```
//!
//! Equivalent CLI study: `repro prim --overlap` / `repro figure overlap`.

use prim_pim::arch::SystemConfig;
use prim_pim::coordinator::{Access, PimSet, Symbol};

fn main() {
    let mut set = PimSet::allocate(SystemConfig::p21_rank(), 16);
    let n = 4096usize;
    // double-buffered request inputs + one output region
    let inputs: [Symbol<i64>; 2] = [set.symbol::<i64>(n), set.symbol::<i64>(n)];
    let out = set.symbol::<i64>(2);

    let bufs: Vec<Vec<i64>> = (0..16).map(|d| vec![d as i64 + 1; n]).collect();

    let mut q = set.queue();
    for req in 0..2usize {
        let input = inputs[req % 2];
        // push this request's input (request 1's push slides under
        // request 0's launch: disjoint symbol, no dependency)
        q.xfer(input).to().equal(&bufs);
        // launch with a declared footprint: reads its buffer, writes out
        q.launch_seq_acc(
            Access::new().read(input.region()).write(out.region()),
            16,
            move |_d, ctx| {
                let w = ctx.mem_alloc(2048);
                let mut acc = 0i64;
                let mut off = 0;
                while off < n * 8 {
                    let take = (n * 8 - off).min(2048);
                    ctx.mram_read(input.off() + off, w, take);
                    let v: Vec<i64> = ctx.wram_get(w, take / 8);
                    acc += v.iter().sum::<i64>();
                    ctx.compute((take / 8) as u64 * 3);
                    off += take;
                }
                ctx.wram_set(w, &[acc, 0]);
                ctx.mram_write(w, out.off(), 16);
            },
        );
    }
    let hidden = q.sync();

    let m = &set.metrics;
    println!("== async command queue · 16 DPUs · 2 requests ==");
    println!(
        "buckets   : DPU {:.3} ms | CPU-DPU {:.3} ms",
        m.dpu * 1e3,
        m.cpu_dpu * 1e3
    );
    println!(
        "derived   : hidden {:.3} ms ({}% of the pushes) — total {:.3} ms vs {:.3} ms serialized",
        hidden * 1e3,
        (100.0 * hidden / m.cpu_dpu).round(),
        m.total() * 1e3,
        (m.total() + hidden) * 1e3
    );
    assert!(hidden > 0.0, "the second push must hide under the first launch");
}
