//! CPU↔DPU transfer bandwidth model + functional data movement.
//!
//! Calibrated to the paper's Fig. 10 measurements on the 2,556-DPU system:
//!
//! * single-DPU transfers ramp linearly with size up to ~2 KB, then
//!   saturate (Key Obs. 7) at 0.33 GB/s CPU→DPU / 0.12 GB/s DPU→CPU for
//!   32 MB — the asymmetry comes from the SDK's asynchronous AVX *writes*
//!   vs synchronous AVX *reads* (Key Obs. 9);
//! * parallel transfers inside a rank scale sublinearly with DPU count
//!   (Key Obs. 8): 6.68 GB/s CPU→DPU and 4.74 GB/s DPU→CPU at 64 DPUs
//!   (20.13× / 38.76× over one DPU);
//! * broadcast reaches 16.88 GB/s thanks to CPU cache locality;
//! * everything stays below the 19.2 GB/s DDR4-2400 channel peak — the gap
//!   is the SDK transposition library that scatters 64-bit words across
//!   the 8 chips of a rank;
//! * transfers to different **ranks are serialized** (§5.1.1: "these
//!   transfers are not simultaneous across ranks").
//!
//! The model is a saturating-hyperbola family: single-transfer time
//! `t(s) = t0 + s/BW∞`; parallel aggregate bandwidth
//! `BW(N) = A·N/(N+B)` at the 32 MB calibration point, scaled by the
//! single-DPU size curve for other sizes.
//!
//! The seconds computed here are what a transfer command occupies the
//! **serialized host bus** for on the modeled resource timelines of
//! `coordinator::queue` — the async command queues that decide which
//! transfers can hide under concurrently-running kernels — and what the
//! multi-tenant scheduler's bus arbitration reserves per grant.

use crate::coordinator::executor::{FleetExecutor, FleetSlot};
use crate::dpu::Dpu;
use crate::util::pod::Pod;
use std::sync::OnceLock;

/// Direction of a host↔MRAM transfer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dir {
    /// Host main memory → MRAM (`dpu_copy_to` / push_xfer TO_DPU).
    CpuToDpu,
    /// MRAM → host main memory (`dpu_copy_from` / push_xfer FROM_DPU).
    DpuToCpu,
}

/// Bandwidth-model parameters (defaults = 2,556-DPU system calibration).
#[derive(Clone, Debug)]
pub struct XferModel {
    /// Fixed software+bus latency of one serial transfer, seconds.
    pub t0: f64,
    /// Asymptotic single-DPU CPU→DPU bandwidth, B/s.
    pub bw_c2d: f64,
    /// Asymptotic single-DPU DPU→CPU bandwidth, B/s.
    pub bw_d2c: f64,
    /// Parallel CPU→DPU hyperbola (A in B/s, B dimensionless):
    /// aggregate BW at N DPUs (32 MB each) = A·N/(N+B).
    pub par_c2d: (f64, f64),
    /// Parallel DPU→CPU hyperbola.
    pub par_d2c: (f64, f64),
    /// Broadcast hyperbola.
    pub par_bcast: (f64, f64),
    /// DPUs per rank (parallelism domain).
    pub rank_size: u32,
}

/// Reference size at which the parallel hyperbolas are calibrated.
const CAL_SIZE: f64 = 32.0 * 1024.0 * 1024.0;

impl Default for XferModel {
    fn default() -> Self {
        // Fits to Fig. 10 (see module docs): bw(1 dpu, 32MB) = 0.33 / 0.12
        // GB/s; bw(64) = 6.68 / 4.74; broadcast(64) = 16.88.
        XferModel {
            t0: 2.5e-6,
            bw_c2d: 0.342e9,
            bw_d2c: 0.125e9,
            par_c2d: (9.62e9, 28.1),
            par_d2c: (11.87e9, 96.3),
            par_bcast: (24.3e9, 28.1),
            rank_size: 64,
        }
    }
}

impl XferModel {
    /// Seconds for one serial transfer of `bytes` to/from one MRAM bank.
    pub fn serial_secs(&self, dir: Dir, bytes: usize) -> f64 {
        let bw = match dir {
            Dir::CpuToDpu => self.bw_c2d,
            Dir::DpuToCpu => self.bw_d2c,
        };
        self.t0 + bytes as f64 / bw
    }

    /// Effective single-DPU bandwidth at `bytes` (B/s).
    pub fn serial_bw(&self, dir: Dir, bytes: usize) -> f64 {
        bytes as f64 / self.serial_secs(dir, bytes)
    }

    /// Aggregate bandwidth of a parallel transfer of `bytes` per DPU to
    /// `n` DPUs **within one rank** (B/s).
    pub fn parallel_bw(&self, dir: Dir, bytes: usize, n: u32) -> f64 {
        let n = n.min(self.rank_size);
        let (a, b) = match dir {
            Dir::CpuToDpu => self.par_c2d,
            Dir::DpuToCpu => self.par_d2c,
        };
        let bw32 = a * n as f64 / (n as f64 + b);
        // scale by the size curve so small parallel transfers keep the
        // fixed-cost penalty of Fig. 10a
        let scale = self.serial_bw(dir, bytes)
            / self.serial_bw(dir, CAL_SIZE as usize);
        bw32 * scale.min(1.0)
    }

    /// Seconds for a parallel transfer of `bytes` per DPU to `n` DPUs,
    /// serialized across ranks.
    pub fn parallel_secs(&self, dir: Dir, bytes: usize, n: u32) -> f64 {
        if n == 0 || bytes == 0 {
            return 0.0;
        }
        let mut total = 0.0;
        let mut left = n;
        while left > 0 {
            let in_rank = left.min(self.rank_size);
            let bw = self.parallel_bw(dir, bytes, in_rank);
            total += in_rank as f64 * bytes as f64 / bw;
            left -= in_rank;
        }
        total
    }

    /// Seconds for a parallel transfer with **per-DPU sizes** (the
    /// `dpu_push_xfer` generalization newer SDKs expose). Each DPU's
    /// shard is charged at the size-scaled aggregate bandwidth of its
    /// rank — the per-shard single-DPU size curve of Fig. 10a applied to
    /// the rank hyperbola — and ranks stay serialized (§5.1.1). For
    /// uniform sizes this reduces to [`XferModel::parallel_secs`]'s
    /// per-rank terms; zero-length shards cost nothing and do not count
    /// toward the rank's parallelism.
    pub fn ragged_secs(&self, dir: Dir, sizes: &[usize]) -> f64 {
        let rank = (self.rank_size.max(1)) as usize;
        let mut total = 0.0;
        for shard in sizes.chunks(rank) {
            let in_rank = shard.iter().filter(|&&b| b > 0).count() as u32;
            for &bytes in shard {
                if bytes > 0 {
                    total += bytes as f64 / self.parallel_bw(dir, bytes, in_rank);
                }
            }
        }
        total
    }

    /// Seconds to broadcast `bytes` to each of `n` DPUs.
    pub fn broadcast_secs(&self, bytes: usize, n: u32) -> f64 {
        if n == 0 || bytes == 0 {
            return 0.0;
        }
        let mut total = 0.0;
        let mut left = n;
        while left > 0 {
            let in_rank = left.min(self.rank_size);
            let (a, b) = self.par_bcast;
            let bw32 = a * in_rank as f64 / (in_rank as f64 + b);
            let scale = (self.serial_bw(Dir::CpuToDpu, bytes)
                / self.serial_bw(Dir::CpuToDpu, CAL_SIZE as usize))
            .min(1.0);
            total += in_rank as f64 * bytes as f64 / (bw32 * scale);
            left -= in_rank;
        }
        total
    }
}

// ------------------------------------------------------------------ engine

/// Functional + timed transfer engine over a set of DPUs.
///
/// All functions move real bytes and return modeled seconds; the
/// coordinator accumulates the seconds into the `CPU-DPU` / `DPU-CPU`
/// breakdown of the paper's figures.
pub struct TransferEngine {
    pub model: XferModel,
}

impl TransferEngine {
    pub fn new(model: XferModel) -> Self {
        TransferEngine { model }
    }

    /// `dpu_copy_to`: serial transfer of `data` to one DPU's MRAM.
    pub fn copy_to<T: Pod>(&self, dpu: &mut Dpu, mram_off: usize, data: &[T]) -> f64 {
        dpu.mram_store(mram_off, data);
        self.model.serial_secs(Dir::CpuToDpu, std::mem::size_of_val(data))
    }

    /// `dpu_copy_from`: serial transfer from one DPU's MRAM.
    pub fn copy_from<T: Pod>(&self, dpu: &Dpu, mram_off: usize, n: usize) -> (Vec<T>, f64) {
        let v = dpu.mram_load(mram_off, n);
        let secs = self
            .model
            .serial_secs(Dir::DpuToCpu, n * std::mem::size_of::<T>());
        (v, secs)
    }

    /// `dpu_prepare_xfer` + `dpu_push_xfer(TO_DPU)`: parallel transfer of
    /// per-DPU buffers (all the **same size**, as the SDK requires). The
    /// functional byte movement fans out across the fleet executor's
    /// workers; the modeled seconds depend only on sizes and DPU count.
    pub fn push_to<T: Pod>(
        &self,
        exec: &dyn FleetExecutor,
        dpus: &mut [Dpu],
        mram_off: usize,
        bufs: &[Vec<T>],
    ) -> f64 {
        assert_eq!(dpus.len(), bufs.len(), "one buffer per DPU");
        let size = bufs.first().map_or(0, |b| b.len());
        assert!(
            bufs.iter().all(|b| b.len() == size),
            "parallel transfers require equal sizes (UPMEM SDK 2021.1.1)"
        );
        let n_dpus = dpus.len() as u32;
        let mut slots: Vec<FleetSlot<'_>> = dpus.iter_mut().enumerate().collect();
        exec.for_each(&mut slots, &|i, dpu| dpu.mram_store(mram_off, &bufs[i]));
        self.model
            .parallel_secs(Dir::CpuToDpu, size * std::mem::size_of::<T>(), n_dpus)
    }

    /// `dpu_push_xfer(FROM_DPU)`: parallel retrieval of equal-size buffers.
    /// Per-DPU output vectors are filled by the executor's workers into
    /// index-addressed cells, so the returned order is DPU order whatever
    /// the schedule.
    pub fn push_from<T: Pod>(
        &self,
        exec: &dyn FleetExecutor,
        dpus: &mut [Dpu],
        mram_off: usize,
        n: usize,
    ) -> (Vec<Vec<T>>, f64) {
        let n_dpus = dpus.len() as u32;
        let cells: Vec<OnceLock<Vec<T>>> = (0..dpus.len()).map(|_| OnceLock::new()).collect();
        let mut slots: Vec<FleetSlot<'_>> = dpus.iter_mut().enumerate().collect();
        exec.for_each(&mut slots, &|i, dpu| {
            let _ = cells[i].set(dpu.mram_load(mram_off, n));
        });
        let out: Vec<Vec<T>> = cells
            .into_iter()
            .map(|c| c.into_inner().expect("executor must visit every DPU"))
            .collect();
        let secs = self
            .model
            .parallel_secs(Dir::DpuToCpu, n * std::mem::size_of::<T>(), n_dpus);
        (out, secs)
    }

    /// Ragged `dpu_push_xfer(TO_DPU)`: parallel transfer of per-DPU
    /// buffers of **independent sizes** (what the equal-size SDK
    /// restriction forced workloads to fake with sentinel padding).
    /// Functional fan-out across the executor; seconds from
    /// [`XferModel::ragged_secs`].
    pub fn push_to_ragged<T: Pod>(
        &self,
        exec: &dyn FleetExecutor,
        dpus: &mut [Dpu],
        mram_off: usize,
        bufs: &[Vec<T>],
    ) -> f64 {
        assert_eq!(dpus.len(), bufs.len(), "one buffer per DPU");
        let mut slots: Vec<FleetSlot<'_>> = dpus.iter_mut().enumerate().collect();
        exec.for_each(&mut slots, &|i, dpu| {
            if !bufs[i].is_empty() {
                dpu.mram_store(mram_off, &bufs[i]);
            }
        });
        let sizes: Vec<usize> =
            bufs.iter().map(|b| std::mem::size_of_val(b.as_slice())).collect();
        self.model.ragged_secs(Dir::CpuToDpu, &sizes)
    }

    /// Ragged `dpu_push_xfer(FROM_DPU)`: parallel retrieval of `lens[i]`
    /// elements from DPU `i` (a zero length skips that DPU).
    pub fn push_from_ragged<T: Pod>(
        &self,
        exec: &dyn FleetExecutor,
        dpus: &mut [Dpu],
        mram_off: usize,
        lens: &[usize],
    ) -> (Vec<Vec<T>>, f64) {
        assert_eq!(dpus.len(), lens.len(), "one length per DPU");
        let cells: Vec<OnceLock<Vec<T>>> = (0..dpus.len()).map(|_| OnceLock::new()).collect();
        let mut slots: Vec<FleetSlot<'_>> = dpus.iter_mut().enumerate().collect();
        exec.for_each(&mut slots, &|i, dpu| {
            let v = if lens[i] == 0 { Vec::new() } else { dpu.mram_load(mram_off, lens[i]) };
            let _ = cells[i].set(v);
        });
        let out: Vec<Vec<T>> = cells
            .into_iter()
            .map(|c| c.into_inner().expect("executor must visit every DPU"))
            .collect();
        let sizes: Vec<usize> = lens.iter().map(|n| n * std::mem::size_of::<T>()).collect();
        let secs = self.model.ragged_secs(Dir::DpuToCpu, &sizes);
        (out, secs)
    }

    /// `dpu_broadcast_to`: same buffer to every DPU.
    pub fn broadcast_to<T: Pod>(
        &self,
        exec: &dyn FleetExecutor,
        dpus: &mut [Dpu],
        mram_off: usize,
        data: &[T],
    ) -> f64 {
        let n_dpus = dpus.len() as u32;
        let mut slots: Vec<FleetSlot<'_>> = dpus.iter_mut().enumerate().collect();
        exec.for_each(&mut slots, &|_i, dpu| dpu.mram_store(mram_off, data));
        self.model.broadcast_secs(std::mem::size_of_val(data), n_dpus)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::DpuArch;

    fn model() -> XferModel {
        XferModel::default()
    }

    #[test]
    fn fig10_calibration_points() {
        let m = model();
        let mb32 = 32 * 1024 * 1024;
        // single-DPU 32 MB: 0.33 / 0.12 GB/s
        assert!((m.serial_bw(Dir::CpuToDpu, mb32) / 1e9 - 0.33).abs() < 0.02);
        assert!((m.serial_bw(Dir::DpuToCpu, mb32) / 1e9 - 0.12).abs() < 0.01);
        // 64-DPU parallel: 6.68 / 4.74 GB/s
        assert!((m.parallel_bw(Dir::CpuToDpu, mb32, 64) / 1e9 - 6.68).abs() < 0.15);
        assert!((m.parallel_bw(Dir::DpuToCpu, mb32, 64) / 1e9 - 4.74).abs() < 0.15);
        // broadcast 64: 16.88 GB/s
        let t = m.broadcast_secs(mb32, 64);
        let bw = 64.0 * mb32 as f64 / t / 1e9;
        assert!((bw - 16.88).abs() < 0.4, "bcast {bw}");
    }

    #[test]
    fn cpu_to_dpu_faster_than_back() {
        let m = model();
        for n in [1u32, 8, 64] {
            assert!(
                m.parallel_bw(Dir::CpuToDpu, 1 << 20, n) > m.parallel_bw(Dir::DpuToCpu, 1 << 20, n)
            );
        }
    }

    #[test]
    fn parallel_scales_sublinearly() {
        let m = model();
        let b1 = m.parallel_bw(Dir::CpuToDpu, 32 << 20, 1);
        let b64 = m.parallel_bw(Dir::CpuToDpu, 32 << 20, 64);
        let gain = b64 / b1;
        assert!(gain > 15.0 && gain < 25.0, "gain {gain} (paper: 20.13x)");
    }

    #[test]
    fn serial_flat_across_dpus() {
        // serial transfers: total time grows linearly with DPU count, so
        // aggregate bandwidth is flat (Fig. 10b "serial" lines).
        let m = model();
        let per = m.serial_secs(Dir::CpuToDpu, 32 << 20);
        let agg_bw_8 = 8.0 * (32 << 20) as f64 / (8.0 * per);
        let agg_bw_64 = 64.0 * (32 << 20) as f64 / (64.0 * per);
        assert!((agg_bw_8 - agg_bw_64).abs() < 1.0);
    }

    #[test]
    fn ranks_serialize() {
        let m = model();
        let one_rank = m.parallel_secs(Dir::CpuToDpu, 1 << 20, 64);
        let two_ranks = m.parallel_secs(Dir::CpuToDpu, 1 << 20, 128);
        assert!((two_ranks - 2.0 * one_rank).abs() / one_rank < 1e-9);
    }

    #[test]
    fn below_ddr4_peak() {
        let m = model();
        for n in [1u32, 16, 64] {
            assert!(m.parallel_bw(Dir::CpuToDpu, 32 << 20, n) < 19.2e9);
        }
        assert!(64.0 * (32u64 << 20) as f64 / m.broadcast_secs(32 << 20, 64) < 19.2e9);
    }

    #[test]
    fn engine_moves_data() {
        use crate::coordinator::executor::{ParallelExecutor, SerialExecutor};
        for exec in [
            &SerialExecutor as &dyn FleetExecutor,
            &ParallelExecutor::new(2) as &dyn FleetExecutor,
        ] {
            let eng = TransferEngine::new(model());
            let mut dpus: Vec<Dpu> = (0..4).map(|_| Dpu::new(DpuArch::p21())).collect();
            let bufs: Vec<Vec<i64>> = (0..4).map(|i| vec![i as i64; 8]).collect();
            let secs = eng.push_to(exec, &mut dpus, 0, &bufs);
            assert!(secs > 0.0);
            let (back, secs2) = eng.push_from::<i64>(exec, &mut dpus, 0, 8);
            assert!(secs2 > secs, "read-back slower (Key Obs. 9)");
            assert_eq!(back, bufs);
            // broadcast
            let secs3 = eng.broadcast_to(exec, &mut dpus, 1024, &[7i64; 4]);
            assert!(secs3 > 0.0);
            for d in &dpus {
                assert_eq!(d.mram_load::<i64>(1024, 4), vec![7i64; 4]);
            }
        }
    }

    /// The equal-size path (`push_to`, the 2021.1.1 SDK restriction) still
    /// rejects ragged buffers — `push_to_ragged` is the sanctioned route.
    #[test]
    #[should_panic(expected = "equal sizes")]
    fn unequal_parallel_rejected() {
        use crate::coordinator::executor::SerialExecutor;
        let eng = TransferEngine::new(model());
        let mut dpus: Vec<Dpu> = (0..2).map(|_| Dpu::new(DpuArch::p21())).collect();
        let bufs = vec![vec![1i64; 4], vec![1i64; 8]];
        eng.push_to(&SerialExecutor, &mut dpus, 0, &bufs);
    }

    #[test]
    fn ragged_engine_moves_exact_bytes() {
        use crate::coordinator::executor::{ParallelExecutor, SerialExecutor};
        for exec in [
            &SerialExecutor as &dyn FleetExecutor,
            &ParallelExecutor::new(3) as &dyn FleetExecutor,
        ] {
            let eng = TransferEngine::new(model());
            let mut dpus: Vec<Dpu> = (0..5).map(|_| Dpu::new(DpuArch::p21())).collect();
            let bufs: Vec<Vec<i64>> = vec![
                vec![1; 16],
                vec![2; 4],
                Vec::new(),
                vec![4; 64],
                vec![5; 8],
            ];
            let secs = eng.push_to_ragged(exec, &mut dpus, 0, &bufs);
            assert!(secs > 0.0);
            let lens: Vec<usize> = bufs.iter().map(Vec::len).collect();
            let (back, secs2) = eng.push_from_ragged::<i64>(exec, &mut dpus, 0, &lens);
            assert_eq!(back, bufs);
            assert!(secs2 > secs, "read-back slower (Key Obs. 9)");
        }
    }

    #[test]
    fn ragged_secs_matches_parallel_secs_for_uniform_sizes() {
        let m = model();
        for n in [1usize, 7, 64, 100] {
            for bytes in [64usize, 1 << 20] {
                let sizes = vec![bytes; n];
                let ragged = m.ragged_secs(Dir::CpuToDpu, &sizes);
                let equal = m.parallel_secs(Dir::CpuToDpu, bytes, n as u32);
                assert!(
                    (ragged - equal).abs() / equal < 1e-9,
                    "n={n} bytes={bytes}: {ragged} vs {equal}"
                );
            }
        }
    }

    #[test]
    fn ragged_secs_serializes_ranks_and_skips_empty_shards() {
        let m = model();
        let one_rank = m.ragged_secs(Dir::CpuToDpu, &vec![1 << 20; 64]);
        let two_ranks = m.ragged_secs(Dir::CpuToDpu, &vec![1 << 20; 128]);
        assert!((two_ranks - 2.0 * one_rank).abs() / one_rank < 1e-9);
        // zero-length shards neither cost time nor dilute the rank BW
        let mut sizes = vec![1 << 20; 8];
        sizes.resize(64, 0);
        let with_zeros = m.ragged_secs(Dir::CpuToDpu, &sizes);
        let without = m.ragged_secs(Dir::CpuToDpu, &vec![1 << 20; 8]);
        assert!((with_zeros - without).abs() / without < 1e-9);
        assert_eq!(m.ragged_secs(Dir::DpuToCpu, &[]), 0.0);
    }
}
