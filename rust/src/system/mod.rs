//! System-level organization: the CPU↔DPU transfer engine (the UPMEM SDK's
//! `dpu_copy_to/from`, `dpu_prepare_xfer`/`dpu_push_xfer`,
//! `dpu_broadcast_to`) and the host-CPU cost model used for inter-DPU
//! synchronization phases.

pub mod host;
pub mod transfer;

pub use host::HostModel;
pub use transfer::{Dir, TransferEngine, XferModel};
