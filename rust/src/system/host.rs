//! Host-CPU cost model for the inter-DPU synchronization phases.
//!
//! All inter-DPU communication goes through the host (there is no direct
//! DPU↔DPU channel), so benchmarks with global phases — frontier union in
//! BFS, partial-result merging in SEL/UNI/RED/HST, the intermediate scan of
//! SCAN-SSA/SCAN-RSS, diagonal exchange in NW — pay host compute in
//! addition to the transfer time. The paper's "Inter-DPU" bars contain
//! both; we model host compute with simple sustained-rate parameters of the
//! Intel Xeon Silver 4215 host and *measure* the functional merge work we
//! actually perform.

/// Sustained-rate model of the host CPU (single socket, single thread —
/// the SDK's merge loops are sequential, §5.1.1's BFS analysis).
#[derive(Clone, Debug)]
pub struct HostModel {
    /// Sustained scalar integer op rate, ops/s.
    pub int_ops_per_sec: f64,
    /// Sustained float op rate, ops/s.
    pub float_ops_per_sec: f64,
    /// Sustained main-memory streaming bandwidth, B/s.
    pub mem_bw: f64,
    /// Penalty factor for a second-socket (remote NUMA) access — the paper
    /// observes the Inter-DPU jump from 1,024 to 2,048 DPUs on the
    /// dual-socket system.
    pub numa_penalty: f64,
}

impl Default for HostModel {
    fn default() -> Self {
        HostModel {
            // Xeon Silver 4215 @2.5 GHz, ~1 scalar op/cycle sustained in
            // pointer-ful merge loops.
            int_ops_per_sec: 2.5e9,
            float_ops_per_sec: 2.0e9,
            // single-thread streaming (~1/3 of the 37.5 GB/s socket peak)
            mem_bw: 12.0e9,
            numa_penalty: 1.6,
        }
    }
}

impl HostModel {
    /// Seconds to run `ops` scalar integer operations on the host.
    pub fn int_ops(&self, ops: u64) -> f64 {
        ops as f64 / self.int_ops_per_sec
    }

    /// Seconds to run `ops` scalar float operations on the host.
    pub fn float_ops(&self, ops: u64) -> f64 {
        ops as f64 / self.float_ops_per_sec
    }

    /// Seconds to stream `bytes` through host memory (merge copies).
    pub fn stream(&self, bytes: u64) -> f64 {
        bytes as f64 / self.mem_bw
    }

    /// Seconds for a host-side merge touching `bytes` and executing `ops`
    /// (max of the two roofs — the host overlaps loads with ALU work).
    pub fn merge(&self, bytes: u64, ops: u64) -> f64 {
        self.stream(bytes).max(self.int_ops(ops))
    }

    /// NUMA-degraded merge (used when the DPU set spans >16 ranks, i.e.
    /// DIMMs on both sockets of the 2,556-DPU machine).
    pub fn merge_numa(&self, bytes: u64, ops: u64, spans_sockets: bool) -> f64 {
        let t = self.merge(bytes, ops);
        if spans_sockets {
            t * self.numa_penalty
        } else {
            t
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rates_sane() {
        let h = HostModel::default();
        assert!(h.int_ops(2_500_000_000) > 0.99);
        assert!(h.stream(12_000_000_000) > 0.99);
    }

    #[test]
    fn merge_is_max_of_roofs() {
        let h = HostModel::default();
        // compute-heavy merge bound by ops
        assert_eq!(h.merge(8, 1_000_000), h.int_ops(1_000_000));
        // memory-heavy merge bound by bytes
        assert_eq!(h.merge(1 << 30, 8), h.stream(1 << 30));
    }

    #[test]
    fn numa_penalty_applies() {
        let h = HostModel::default();
        assert!(h.merge_numa(1 << 20, 0, true) > h.merge_numa(1 << 20, 0, false));
    }
}
