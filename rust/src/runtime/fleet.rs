//! Fleet timing estimator: the AOT `dpu_timing` artifact (L1 Pallas kernel
//! lowered through L2) evaluated from rust, plus a native fallback.
//!
//! The coordinator uses this to predict full-scale (2,556-DPU) scaling
//! shapes from per-DPU workload descriptors without functionally
//! simulating every DPU — the descriptor model is the same first-order
//! analytical model (pipeline vs DMA roofline) as the fluid engine.

use anyhow::Result;

/// Fleet width the artifact was lowered at (python/compile/model.py).
pub const FLEET_N: usize = 2048;

/// Workload descriptor of one DPU for the analytical model.
#[derive(Clone, Copy, Debug, Default)]
pub struct DpuDesc {
    /// Pipeline instructions per tasklet.
    pub instrs_per_tasklet: f64,
    /// Tasklets launched.
    pub tasklets: f64,
    /// MRAM→WRAM transfers and their (uniform) size.
    pub n_reads: f64,
    pub read_bytes: f64,
    /// WRAM→MRAM transfers and their size.
    pub n_writes: f64,
    pub write_bytes: f64,
}

/// Native evaluation of the analytical model (used when artifacts are not
/// built, and as the cross-check oracle for the PJRT path).
pub fn fleet_cycles_native(descs: &[DpuDesc]) -> Vec<f64> {
    const DISPATCH: f64 = 11.0;
    const ALPHA_R: f64 = 77.0;
    const ALPHA_W: f64 = 61.0;
    const BETA: f64 = 0.5;
    descs
        .iter()
        .map(|d| {
            let pipeline = d.instrs_per_tasklet * DISPATCH.max(d.tasklets);
            let dma = d.n_reads * (ALPHA_R + BETA * d.read_bytes)
                + d.n_writes * (ALPHA_W + BETA * d.write_bytes);
            pipeline.max(dma)
        })
        .collect()
}

/// PJRT-backed fleet estimator.
pub struct FleetEstimator {
    exe: xla::PjRtLoadedExecutable,
}

impl FleetEstimator {
    /// Load `artifacts/dpu_timing.hlo.txt` and compile it.
    pub fn load(rt: &super::PjrtRuntime) -> Result<Self> {
        Ok(FleetEstimator {
            exe: rt.load("dpu_timing.hlo.txt")?,
        })
    }

    /// Estimate cycles for each descriptor (chunks of `FLEET_N`, padded).
    pub fn estimate(&self, descs: &[DpuDesc]) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(descs.len());
        for chunk in descs.chunks(FLEET_N) {
            let mut cols = [(); 6].map(|_| vec![0f32; FLEET_N]);
            for (i, d) in chunk.iter().enumerate() {
                cols[0][i] = d.instrs_per_tasklet as f32;
                cols[1][i] = d.tasklets.max(1.0) as f32;
                cols[2][i] = d.n_reads as f32;
                cols[3][i] = d.read_bytes as f32;
                cols[4][i] = d.n_writes as f32;
                cols[5][i] = d.write_bytes as f32;
            }
            let dims: &[i64] = &[FLEET_N as i64];
            let inputs: Vec<(&[f32], &[i64])> =
                cols.iter().map(|c| (c.as_slice(), dims)).collect();
            let res = super::run_f32(&self.exe, &inputs)?;
            out.extend(res[..chunk.len()].iter().map(|&x| x as f64));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_model_pipeline_vs_dma() {
        let compute_bound = DpuDesc {
            instrs_per_tasklet: 1_000_000.0,
            tasklets: 16.0,
            n_reads: 10.0,
            read_bytes: 1024.0,
            ..Default::default()
        };
        let memory_bound = DpuDesc {
            instrs_per_tasklet: 100.0,
            tasklets: 16.0,
            n_reads: 100_000.0,
            read_bytes: 1024.0,
            ..Default::default()
        };
        let c = fleet_cycles_native(&[compute_bound, memory_bound]);
        assert_eq!(c[0], 16_000_000.0);
        assert_eq!(c[1], 100_000.0 * (77.0 + 512.0));
    }

    #[test]
    fn pjrt_matches_native() {
        if !super::super::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let rt = super::super::PjrtRuntime::cpu().unwrap();
        let est = FleetEstimator::load(&rt).unwrap();
        let descs: Vec<DpuDesc> = (0..100)
            .map(|i| DpuDesc {
                instrs_per_tasklet: 1000.0 * (i + 1) as f64,
                tasklets: (1 + i % 24) as f64,
                n_reads: (i * 10) as f64,
                read_bytes: 1024.0,
                n_writes: (i * 5) as f64,
                write_bytes: 512.0,
            })
            .collect();
        let pjrt = est.estimate(&descs).unwrap();
        let native = fleet_cycles_native(&descs);
        for (a, b) in pjrt.iter().zip(&native) {
            assert!((a - b).abs() / b.max(1.0) < 1e-5, "{a} vs {b}");
        }
    }
}
