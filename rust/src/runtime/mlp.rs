//! MLP oracle: the AOT 3-layer MLP (Pallas GEMV+ReLU layers) executed via
//! PJRT — the host-side "CPU counterpart" of the PrIM MLP workload and the
//! numeric oracle for the DPU-simulated MLP/GEMV results.

use anyhow::Result;

/// Layer width the artifact was lowered at (python/compile/model.py).
pub const MLP_DIM: usize = 1024;

/// PJRT-backed 3-layer MLP.
pub struct MlpOracle {
    exe: xla::PjRtLoadedExecutable,
    pub w: [Vec<f32>; 3],
    pub b: [Vec<f32>; 3],
}

impl MlpOracle {
    /// Load `artifacts/mlp.hlo.txt` and attach weights (row-major
    /// `MLP_DIM × MLP_DIM`).
    pub fn load(rt: &super::PjrtRuntime, w: [Vec<f32>; 3], b: [Vec<f32>; 3]) -> Result<Self> {
        for wi in &w {
            assert_eq!(wi.len(), MLP_DIM * MLP_DIM);
        }
        for bi in &b {
            assert_eq!(bi.len(), MLP_DIM);
        }
        Ok(MlpOracle {
            exe: rt.load("mlp.hlo.txt")?,
            w,
            b,
        })
    }

    /// Forward pass: y = relu(W3·relu(W2·relu(W1·x+b1)+b2)+b3).
    pub fn forward(&self, x: &[f32]) -> Result<Vec<f32>> {
        assert_eq!(x.len(), MLP_DIM);
        let d = MLP_DIM as i64;
        let vdims: &[i64] = &[d];
        let mdims: &[i64] = &[d, d];
        super::run_f32(
            &self.exe,
            &[
                (x, vdims),
                (&self.w[0], mdims),
                (&self.b[0], vdims),
                (&self.w[1], mdims),
                (&self.b[1], vdims),
                (&self.w[2], mdims),
                (&self.b[2], vdims),
            ],
        )
    }

    /// Native reference forward pass (for cross-checking the PJRT path and
    /// for use when artifacts are absent).
    pub fn forward_native(w: &[Vec<f32>; 3], b: &[Vec<f32>; 3], x: &[f32]) -> Vec<f32> {
        let mut h = x.to_vec();
        for l in 0..3 {
            let mut next = vec![0f32; MLP_DIM];
            for (r, out) in next.iter_mut().enumerate() {
                let row = &w[l][r * MLP_DIM..(r + 1) * MLP_DIM];
                let mut acc = 0f32;
                for (a, c) in row.iter().zip(&h) {
                    acc += a * c;
                }
                *out = (acc + b[l][r]).max(0.0);
            }
            h = next;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn params(seed: u64) -> ([Vec<f32>; 3], [Vec<f32>; 3], Vec<f32>) {
        let mut rng = Rng::new(seed);
        let mut mat = || -> Vec<f32> {
            (0..MLP_DIM * MLP_DIM).map(|_| (rng.f32() - 0.5) * 0.06).collect()
        };
        let w = [mat(), mat(), mat()];
        let mut rng2 = Rng::new(seed + 1);
        let mut vec = || -> Vec<f32> { (0..MLP_DIM).map(|_| rng2.f32() - 0.5).collect() };
        let b = [vec(), vec(), vec()];
        let x = vec();
        (w, b, x)
    }

    #[test]
    fn native_relu_nonnegative() {
        let (w, b, x) = params(3);
        let y = MlpOracle::forward_native(&w, &b, &x);
        assert_eq!(y.len(), MLP_DIM);
        assert!(y.iter().all(|&v| v >= 0.0));
        assert!(y.iter().any(|&v| v > 0.0));
    }

    #[test]
    fn pjrt_matches_native() {
        if !super::super::artifacts_available() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let (w, b, x) = params(7);
        let rt = super::super::PjrtRuntime::cpu().unwrap();
        let oracle = MlpOracle::load(&rt, w.clone(), b.clone()).unwrap();
        let got = oracle.forward(&x).unwrap();
        let want = MlpOracle::forward_native(&w, &b, &x);
        for (g, wnt) in got.iter().zip(&want) {
            assert!(
                (g - wnt).abs() <= 1e-3 * (1.0 + wnt.abs()),
                "{g} vs {wnt}"
            );
        }
    }
}
