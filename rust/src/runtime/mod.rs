//! PJRT runtime: load and execute the AOT JAX/Pallas artifacts from rust.
//!
//! Python runs once at build time (`make artifacts`) and lowers the L2
//! model to **HLO text** (`artifacts/*.hlo.txt`); this module compiles the
//! text on the PJRT CPU client (`xla` crate 0.1.6 / xla_extension 0.5.1)
//! and executes it on the request path. Text is the interchange format
//! because jax ≥ 0.5 serialized protos use 64-bit instruction ids that
//! xla_extension 0.5.1 rejects.

pub mod fleet;
pub mod mlp;

use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

pub use fleet::{fleet_cycles_native, DpuDesc, FleetEstimator, FLEET_N};
pub use mlp::{MlpOracle, MLP_DIM};

/// Locate the artifacts directory: `$PRIM_ARTIFACTS`, else
/// `<manifest>/artifacts`.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(p) = std::env::var("PRIM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// Do the AOT artifacts exist? (Tests skip PJRT paths when absent.)
pub fn artifacts_available() -> bool {
    artifacts_dir().join("mlp.hlo.txt").exists()
        && artifacts_dir().join("dpu_timing.hlo.txt").exists()
}

/// A PJRT CPU client; compiled executables are created via [`Self::load`].
pub struct PjrtRuntime {
    pub client: xla::PjRtClient,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client.
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client })
    }

    /// Load an HLO-text artifact and compile it.
    pub fn load(&self, file: &str) -> Result<xla::PjRtLoadedExecutable> {
        let path = artifacts_dir().join(file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path not utf-8")?,
        )
        .with_context(|| format!("parsing HLO text {path:?} (run `make artifacts`)"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .with_context(|| format!("compiling {file}"))
    }
}

/// Execute a compiled computation on f32 literals and return the f32
/// contents of the (single, tupled) output.
pub fn run_f32(
    exe: &xla::PjRtLoadedExecutable,
    inputs: &[(&[f32], &[i64])],
) -> Result<Vec<f32>> {
    let mut lits = Vec::with_capacity(inputs.len());
    for (data, dims) in inputs {
        let lit = xla::Literal::vec1(data);
        let lit = if dims.len() == 1 {
            lit
        } else {
            lit.reshape(dims).context("reshaping input literal")?
        };
        lits.push(lit);
    }
    let result = exe.execute::<xla::Literal>(&lits)?[0][0]
        .to_literal_sync()
        .context("fetching result")?;
    // jax lowering uses return_tuple=True → unwrap the 1-tuple
    let out = result.to_tuple1().context("unwrapping result tuple")?;
    out.to_vec::<f32>().context("reading f32 result")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn artifacts_dir_resolves() {
        let d = artifacts_dir();
        assert!(d.ends_with("artifacts"));
    }

    #[test]
    fn load_and_run_fleet_artifact() {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
        let rt = PjrtRuntime::cpu().unwrap();
        let exe = rt.load("dpu_timing.hlo.txt").unwrap();
        let n = FLEET_N;
        let instrs = vec![1000.0f32; n];
        let tasklets = vec![16.0f32; n];
        let zeros = vec![0.0f32; n];
        let dims: &[i64] = &[n as i64];
        let out = run_f32(
            &exe,
            &[
                (&instrs, dims),
                (&tasklets, dims),
                (&zeros, dims),
                (&zeros, dims),
                (&zeros, dims),
                (&zeros, dims),
            ],
        )
        .unwrap();
        assert_eq!(out.len(), n);
        // pipeline = 1000 * max(11,16) = 16000
        assert!((out[0] - 16_000.0).abs() < 1e-3, "{}", out[0]);
    }
}
