//! Trace diffing: compare two `trace/v1` captures of the **same run
//! configuration** and report what moved — per-lane busy-time deltas,
//! the makespan delta, and the top-k events whose placement changed the
//! most (closing the ROADMAP item-3 "diff mode" leftover).
//!
//! Same-config traces record the same command sequence with the same
//! dense event ids (capture walks the queue in enqueue order), so
//! events are matched **by id**. Diffing traces of different configs is
//! not an error — the report simply flags the unmatched tail — but the
//! per-event deltas are only meaningful when the programs agree.

use super::export::{kind_str, lane_str};
use super::Trace;
use crate::util::table::Table;
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// Busy-seconds of one lane label in each trace.
#[derive(Clone, Debug, PartialEq)]
pub struct LaneDelta {
    /// Lane label (`bus`, `host`, `ranks:l-h`, `bus:m`, `link:m`, …).
    pub lane: String,
    /// Summed event seconds on the lane in trace A.
    pub busy_a: f64,
    /// Summed event seconds on the lane in trace B.
    pub busy_b: f64,
}

impl LaneDelta {
    /// Signed busy-time change, B − A.
    pub fn delta(&self) -> f64 {
        self.busy_b - self.busy_a
    }
}

/// One id-matched event whose placement or duration changed.
#[derive(Clone, Debug, PartialEq)]
pub struct EventDelta {
    pub id: u64,
    /// Kind in trace B (same as A for same-config traces).
    pub kind: String,
    /// Lane labels in A and B.
    pub lane_a: String,
    pub lane_b: String,
    /// Start-instant change, B − A.
    pub d_start: f64,
    /// Duration change, B − A.
    pub d_secs: f64,
}

impl EventDelta {
    /// Ranking score: total placement movement.
    fn score(&self) -> f64 {
        self.d_start.abs() + self.d_secs.abs()
    }
}

/// The full comparison of two traces.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceDiff {
    pub makespan_a: f64,
    pub makespan_b: f64,
    pub events_a: usize,
    pub events_b: usize,
    /// Every lane either trace occupies, largest |busy delta| first.
    pub lanes: Vec<LaneDelta>,
    /// The k id-matched events with the largest placement change
    /// (zero-change events are omitted).
    pub top: Vec<EventDelta>,
}

impl TraceDiff {
    /// Signed makespan change, B − A.
    pub fn d_makespan(&self) -> f64 {
        self.makespan_b - self.makespan_a
    }

    /// Render as aligned text tables (the `repro trace --diff` output).
    pub fn render(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "makespan: {:e} -> {:e} (delta {:e})",
            self.makespan_a,
            self.makespan_b,
            self.d_makespan()
        );
        let _ = writeln!(out, "events: {} vs {}", self.events_a, self.events_b);
        let mut lanes = Table::new("lane busy-time", &["lane", "busy A", "busy B", "delta"]);
        for l in &self.lanes {
            lanes.row(vec![
                l.lane.clone(),
                format!("{:e}", l.busy_a),
                format!("{:e}", l.busy_b),
                format!("{:e}", l.delta()),
            ]);
        }
        out.push_str(&lanes.render());
        if !self.top.is_empty() {
            let mut top = Table::new(
                "top changed events",
                &["id", "kind", "lane A", "lane B", "d_start", "d_secs"],
            );
            for e in &self.top {
                top.row(vec![
                    e.id.to_string(),
                    e.kind.clone(),
                    e.lane_a.clone(),
                    e.lane_b.clone(),
                    format!("{:e}", e.d_start),
                    format!("{:e}", e.d_secs),
                ]);
            }
            out.push_str(&top.render());
        }
        out
    }

    /// Machine-readable form (`trace_diff/v1`).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"schema\": \"trace_diff/v1\",\n");
        let _ = writeln!(s, "  \"makespan_a\": {:e},", self.makespan_a);
        let _ = writeln!(s, "  \"makespan_b\": {:e},", self.makespan_b);
        let _ = writeln!(s, "  \"d_makespan\": {:e},", self.d_makespan());
        let _ = writeln!(s, "  \"events_a\": {},", self.events_a);
        let _ = writeln!(s, "  \"events_b\": {},", self.events_b);
        s.push_str("  \"lanes\": [\n");
        for (i, l) in self.lanes.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"lane\": \"{}\", \"busy_a\": {:e}, \"busy_b\": {:e}, \"delta\": {:e}}}",
                l.lane,
                l.busy_a,
                l.busy_b,
                l.delta()
            );
            s.push_str(if i + 1 < self.lanes.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ],\n  \"top_events\": [\n");
        for (i, e) in self.top.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"id\": {}, \"kind\": \"{}\", \"lane_a\": \"{}\", \"lane_b\": \"{}\", \
                 \"d_start\": {:e}, \"d_secs\": {:e}}}",
                e.id, e.kind, e.lane_a, e.lane_b, e.d_start, e.d_secs
            );
            s.push_str(if i + 1 < self.top.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Compare trace `b` against baseline `a`, keeping the `top_k` events
/// whose placement changed the most. Deterministic: lanes rank by
/// |busy delta| (ties by label), events by movement score (ties by id).
pub fn diff_traces(a: &Trace, b: &Trace, top_k: usize) -> TraceDiff {
    let mut busy: BTreeMap<String, (f64, f64)> = BTreeMap::new();
    for e in &a.events {
        busy.entry(lane_str(&e.lane)).or_insert((0.0, 0.0)).0 += e.secs;
    }
    for e in &b.events {
        busy.entry(lane_str(&e.lane)).or_insert((0.0, 0.0)).1 += e.secs;
    }
    let mut lanes: Vec<LaneDelta> = busy
        .into_iter()
        .map(|(lane, (busy_a, busy_b))| LaneDelta { lane, busy_a, busy_b })
        .collect();
    lanes.sort_by(|x, y| {
        y.delta()
            .abs()
            .total_cmp(&x.delta().abs())
            .then_with(|| x.lane.cmp(&y.lane))
    });

    let by_id: BTreeMap<u64, &super::TraceEvent> =
        a.events.iter().map(|e| (e.id, e)).collect();
    let mut top: Vec<EventDelta> = b
        .events
        .iter()
        .filter_map(|eb| {
            let ea = by_id.get(&eb.id)?;
            let d = EventDelta {
                id: eb.id,
                kind: kind_str(eb.kind).to_string(),
                lane_a: lane_str(&ea.lane),
                lane_b: lane_str(&eb.lane),
                d_start: eb.start - ea.start,
                d_secs: eb.secs - ea.secs,
            };
            (d.score() > 0.0 || d.lane_a != d.lane_b).then_some(d)
        })
        .collect();
    top.sort_by(|x, y| y.score().total_cmp(&x.score()).then_with(|| x.id.cmp(&y.id)));
    top.truncate(top_k);

    TraceDiff {
        makespan_a: a.span(),
        makespan_b: b.span(),
        events_a: a.events.len(),
        events_b: b.events.len(),
        lanes,
        top,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::CmdKind;
    use crate::coordinator::trace::{LaneTag, TraceEvent};

    fn ev(id: u64, lane: LaneTag, start: f64, secs: f64) -> TraceEvent {
        TraceEvent {
            id,
            kind: CmdKind::Push,
            lane,
            start,
            secs,
            bytes: 0,
            tenant: None,
            req: None,
            deps: Vec::new(),
        }
    }

    #[test]
    fn identical_traces_diff_to_zero() {
        let t = Trace {
            source: "queue".into(),
            n_ranks: 2,
            events: vec![
                ev(0, LaneTag::Bus, 0.0, 0.5),
                ev(1, LaneTag::Ranks { lo: 0, hi: 2 }, 0.5, 1.0),
            ],
        };
        let d = diff_traces(&t, &t.clone(), 10);
        assert_eq!(d.d_makespan(), 0.0);
        assert!(d.top.is_empty(), "no event moved");
        assert!(d.lanes.iter().all(|l| l.delta() == 0.0));
        assert_eq!(d.lanes.len(), 2);
    }

    #[test]
    fn moved_and_grown_events_rank_by_movement() {
        let a = Trace {
            source: "queue".into(),
            n_ranks: 1,
            events: vec![
                ev(0, LaneTag::Bus, 0.0, 0.5),
                ev(1, LaneTag::Bus, 0.5, 0.2),
                ev(2, LaneTag::Link { m: 0 }, 0.7, 0.1),
            ],
        };
        let mut b = a.clone();
        b.events[1].start = 0.9; // moved by 0.4
        b.events[2].secs = 0.2; // grew by 0.1
        let d = diff_traces(&a, &b, 2);
        assert_eq!(d.top.len(), 2);
        assert_eq!(d.top[0].id, 1, "largest movement first");
        assert!((d.top[0].d_start - 0.4).abs() < 1e-12);
        assert_eq!(d.top[1].id, 2);
        assert!((d.top[1].d_secs - 0.1).abs() < 1e-12);
        // link lane busy grew by 0.1 and ranks first in |delta| order
        assert_eq!(d.lanes[0].lane, "link:0");
        assert!((d.lanes[0].delta() - 0.1).abs() < 1e-12);
        // exports are well-formed
        assert!(crate::util::json::parse_json(&d.to_json()).is_ok());
        assert!(d.render().contains("top changed events"));
    }

    #[test]
    fn unmatched_tail_is_counted_not_crashed() {
        let a = Trace {
            source: "queue".into(),
            n_ranks: 1,
            events: vec![ev(0, LaneTag::Bus, 0.0, 0.5)],
        };
        let mut b = a.clone();
        b.events.push(ev(1, LaneTag::Host, 0.5, 0.3));
        let d = diff_traces(&a, &b, 10);
        assert_eq!((d.events_a, d.events_b), (1, 2));
        assert!(d.top.is_empty(), "the unmatched event has no pair to diff");
        assert_eq!(d.lanes.len(), 2);
    }
}
