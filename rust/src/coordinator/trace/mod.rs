//! Trace capture, export, deterministic replay, and hotspot triage —
//! the observability layer over the modeled machine (ROADMAP item 3).
//!
//! The queue scheduler and the multi-tenant scheduler already compute a
//! complete event schedule — per-command start/finish on the serialized
//! host bus, the host CPU, and the per-rank kernel lanes — and, before
//! this module, threw it away after deriving one number (`overlapped`).
//! A [`TraceSink`] records those schedules as typed [`TraceEvent`]s:
//!
//! * **queue traces** (`source: "queue"`) — every `PimSet` operation.
//!   Synchronous calls are the degenerate one-command queue, so they
//!   land back-to-back on a session-local clock; a pipelined batch's
//!   commands land at their *scheduled* offsets (the same single
//!   `CmdQueue::schedule` pass that credits `overlapped`), so the trace
//!   shows exactly which pushes hid under which launches.
//! * **scheduler traces** (`source: "sched"`) — per-batch push /
//!   kernel / pull reservations on the fleet-global timeline, tagged
//!   with tenant and request ids.
//!
//! Capture is **zero-cost when off**: the sink is an `Option` checked
//! before any event is built, and the scheduling pass it reads from is
//! the one `queue_sync` already runs for overlap accounting.
//!
//! Export ([`Trace::to_chrome_json`] / [`Trace::to_json`]), cursor-wise
//! replay ([`ReplayEngine`]), and hotspot ranking ([`TriageReport`])
//! live in the submodules; everything is deterministic — identical
//! traces produce bit-identical reports, across runs and executors.

mod diff;
mod export;
mod replay;
mod triage;

pub use diff::{diff_traces, LaneDelta, TraceDiff};
pub use export::parse_trace;
pub use replay::ReplayEngine;
pub use triage::{analyze, analyze_with, BusWindow, RankLoad, StallEdge, TriageReport};

use super::queue::{CmdKind, Lane};
use std::fmt;
use std::sync::{Arc, Mutex};

/// Which modeled resource an event occupied — the trace-side mirror of
/// [`Lane`], with rank spans flattened to plain bounds so events
/// serialize without `Range`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum LaneTag {
    /// The one serialized host memory bus.
    Bus,
    /// The host CPU (merge compute).
    Host,
    /// Kernel lanes of ranks `[lo, hi)`.
    Ranks { lo: u32, hi: u32 },
    /// No resource (fences / barriers).
    Barrier,
    /// Machine `m`'s host bus (cluster traces; machine 0 stays `Bus`).
    MachineBus { m: u32 },
    /// Machine `m`'s host CPU (cluster traces; machine 0 stays `Host`).
    MachineHost { m: u32 },
    /// Machine `m`'s egress network link (collective traffic).
    Link { m: u32 },
}

impl From<Option<Lane>> for LaneTag {
    fn from(l: Option<Lane>) -> Self {
        match l {
            None => LaneTag::Barrier,
            Some(Lane::Bus) => LaneTag::Bus,
            Some(Lane::Host) => LaneTag::Host,
            Some(Lane::Ranks(r)) => LaneTag::Ranks { lo: r.start, hi: r.end },
            Some(Lane::MachineBus(m)) => LaneTag::MachineBus { m },
            Some(Lane::MachineHost(m)) => LaneTag::MachineHost { m },
            Some(Lane::Link(m)) => LaneTag::Link { m },
        }
    }
}

/// One captured span of modeled work.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    /// Trace-wide event id (assigned by the sink, dense from 0).
    pub id: u64,
    /// What kind of command occupied the lane.
    pub kind: CmdKind,
    pub lane: LaneTag,
    /// Modeled start instant (seconds on the trace's timeline).
    pub start: f64,
    /// Modeled duration; `start + secs` is the finish instant, exactly
    /// (the schedulers reserve lanes as `finish = start + secs`).
    pub secs: f64,
    /// Payload bytes moved (0 for launches / fences).
    pub bytes: u64,
    /// Tenant index, on scheduler-level events.
    pub tenant: Option<u32>,
    /// Request id the recording side stamped, if any.
    pub req: Option<u64>,
    /// Ids of earlier events this one waited on (the reduced dependency
    /// edge set the scheduler actually issued against).
    pub deps: Vec<u64>,
}

impl TraceEvent {
    /// Finish instant.
    pub fn end(&self) -> f64 {
        self.start + self.secs
    }
}

/// A recorded trace: capture context plus the event list in id order.
#[derive(Clone, Debug, PartialEq)]
pub struct Trace {
    /// Capture source: `"queue"` (PimSet/session level) or `"sched"`
    /// (multi-tenant scheduler level).
    pub source: String,
    /// Rank count of the traced fleet (sizes the rank tracks).
    pub n_ranks: u32,
    pub events: Vec<TraceEvent>,
}

impl Trace {
    /// An empty trace shell (tests and fallbacks).
    pub fn empty(source: &str, n_ranks: u32) -> Self {
        Trace { source: source.to_string(), n_ranks, events: Vec::new() }
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Last finish instant over all events (0 for an empty trace).
    pub fn span(&self) -> f64 {
        self.events.iter().map(TraceEvent::end).fold(0.0, f64::max)
    }
}

#[derive(Default)]
struct SinkBuf {
    source: String,
    n_ranks: u32,
    events: Vec<TraceEvent>,
}

/// Shared handle the capture points append [`TraceEvent`]s through.
/// Cloning is cheap (one `Arc`); `RunConfig` carries an
/// `Option<TraceSink>` so the flag threads through every existing
/// config without cost when absent.
#[derive(Clone, Default)]
pub struct TraceSink {
    inner: Arc<Mutex<SinkBuf>>,
}

impl TraceSink {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record the capture context (source label + fleet rank count).
    /// Called by the allocation/build paths that install the sink; the
    /// last writer wins, which is what re-allocation wants.
    pub fn set_geometry(&self, source: &str, n_ranks: u32) {
        let mut b = self.inner.lock().unwrap();
        b.source = source.to_string();
        b.n_ranks = n_ranks;
    }

    /// Id the next pushed event will receive.
    pub fn next_id(&self) -> u64 {
        self.inner.lock().unwrap().events.len() as u64
    }

    /// Append an event; its `id` field is overwritten with the assigned
    /// dense id, which is returned.
    pub fn push(&self, mut ev: TraceEvent) -> u64 {
        let mut b = self.inner.lock().unwrap();
        let id = b.events.len() as u64;
        ev.id = id;
        b.events.push(ev);
        id
    }

    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Clone out the recorded trace (the sink keeps recording).
    pub fn snapshot(&self) -> Trace {
        let b = self.inner.lock().unwrap();
        Trace {
            source: b.source.clone(),
            n_ranks: b.n_ranks,
            events: b.events.clone(),
        }
    }
}

impl fmt::Debug for TraceSink {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let b = self.inner.lock().unwrap();
        write!(
            f,
            "TraceSink {{ source: {:?}, n_ranks: {}, events: {} }}",
            b.source,
            b.n_ranks,
            b.events.len()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sink_assigns_dense_ids_and_snapshots() {
        let sink = TraceSink::new();
        sink.set_geometry("queue", 2);
        assert!(sink.is_empty());
        let ev = |start: f64| TraceEvent {
            id: 999, // overwritten by the sink
            kind: CmdKind::Push,
            lane: LaneTag::Bus,
            start,
            secs: 0.5,
            bytes: 64,
            tenant: None,
            req: None,
            deps: Vec::new(),
        };
        assert_eq!(sink.push(ev(0.0)), 0);
        assert_eq!(sink.next_id(), 1);
        assert_eq!(sink.push(ev(0.5)), 1);
        let t = sink.snapshot();
        assert_eq!(t.source, "queue");
        assert_eq!(t.n_ranks, 2);
        assert_eq!(t.events.len(), 2);
        assert_eq!(t.events[1].id, 1);
        assert_eq!(t.span(), 1.0);
        // shared handle: a clone records into the same buffer
        let clone = sink.clone();
        clone.push(ev(1.0));
        assert_eq!(sink.len(), 3);
    }

    #[test]
    fn lane_tag_mirrors_lanes() {
        assert_eq!(LaneTag::from(Some(Lane::Bus)), LaneTag::Bus);
        assert_eq!(LaneTag::from(Some(Lane::Host)), LaneTag::Host);
        assert_eq!(
            LaneTag::from(Some(Lane::Ranks(2..5))),
            LaneTag::Ranks { lo: 2, hi: 5 }
        );
        assert_eq!(LaneTag::from(None), LaneTag::Barrier);
        assert_eq!(
            LaneTag::from(Some(Lane::MachineBus(3))),
            LaneTag::MachineBus { m: 3 }
        );
        assert_eq!(
            LaneTag::from(Some(Lane::MachineHost(1))),
            LaneTag::MachineHost { m: 1 }
        );
        assert_eq!(LaneTag::from(Some(Lane::Link(0))), LaneTag::Link { m: 0 });
    }
}
