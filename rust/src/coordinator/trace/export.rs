//! Trace serialization: Chrome-trace JSON (loadable in Perfetto /
//! `chrome://tracing`) for humans, and a compact native `trace/v1` JSON
//! for programmatic use ([`parse_trace`] reads it back bit-identically
//! — floats are written with Rust's shortest-roundtrip `{:e}` and
//! parsed with `str::parse::<f64>`).

use super::{LaneTag, Trace, TraceEvent};
use crate::coordinator::queue::CmdKind;
use crate::util::json::{parse_json, Value};
use std::fmt::Write as _;

pub(crate) fn kind_str(k: CmdKind) -> &'static str {
    match k {
        CmdKind::Push => "push",
        CmdKind::Pull => "pull",
        CmdKind::Launch => "launch",
        CmdKind::HostMerge => "host_merge",
        CmdKind::Fence => "fence",
        CmdKind::Net => "net",
        CmdKind::MigrateDrain => "migrate_drain",
        CmdKind::MigrateCopy => "migrate_copy",
        CmdKind::MigrateResume => "migrate_resume",
    }
}

fn kind_from(s: &str) -> Result<CmdKind, String> {
    Ok(match s {
        "push" => CmdKind::Push,
        "pull" => CmdKind::Pull,
        "launch" => CmdKind::Launch,
        "host_merge" => CmdKind::HostMerge,
        "fence" => CmdKind::Fence,
        "net" => CmdKind::Net,
        "migrate_drain" => CmdKind::MigrateDrain,
        "migrate_copy" => CmdKind::MigrateCopy,
        "migrate_resume" => CmdKind::MigrateResume,
        other => return Err(format!("unknown event kind '{other}'")),
    })
}

pub(crate) fn lane_str(l: &LaneTag) -> String {
    match l {
        LaneTag::Bus => "bus".into(),
        LaneTag::Host => "host".into(),
        LaneTag::Barrier => "barrier".into(),
        LaneTag::Ranks { lo, hi } => format!("ranks:{lo}-{hi}"),
        LaneTag::MachineBus { m } => format!("bus:{m}"),
        LaneTag::MachineHost { m } => format!("host:{m}"),
        LaneTag::Link { m } => format!("link:{m}"),
    }
}

fn lane_from(s: &str) -> Result<LaneTag, String> {
    Ok(match s {
        "bus" => LaneTag::Bus,
        "host" => LaneTag::Host,
        "barrier" => LaneTag::Barrier,
        other => {
            let machine = |prefix: &str, raw: &str| -> Result<u32, String> {
                raw.parse()
                    .map_err(|_| format!("bad {prefix} machine '{raw}'"))
            };
            if let Some(m) = other.strip_prefix("bus:") {
                return Ok(LaneTag::MachineBus { m: machine("bus", m)? });
            }
            if let Some(m) = other.strip_prefix("host:") {
                return Ok(LaneTag::MachineHost { m: machine("host", m)? });
            }
            if let Some(m) = other.strip_prefix("link:") {
                return Ok(LaneTag::Link { m: machine("link", m)? });
            }
            let span = other
                .strip_prefix("ranks:")
                .ok_or_else(|| format!("unknown lane '{other}'"))?;
            let (lo, hi) = span
                .split_once('-')
                .ok_or_else(|| format!("bad rank span '{span}'"))?;
            LaneTag::Ranks {
                lo: lo.parse().map_err(|_| format!("bad rank lo '{lo}'"))?,
                hi: hi.parse().map_err(|_| format!("bad rank hi '{hi}'"))?,
            }
        }
    })
}

impl Trace {
    /// Compact native form (`trace/v1`): one object per event, floats
    /// shortest-roundtrip, deps as id arrays. This is the form
    /// [`parse_trace`], the replay engine, and the triage loaders eat.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"schema\": \"trace/v1\",\n");
        let _ = writeln!(s, "  \"source\": \"{}\",", self.source);
        let _ = writeln!(s, "  \"n_ranks\": {},", self.n_ranks);
        s.push_str("  \"events\": [\n");
        for (i, e) in self.events.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"id\": {}, \"kind\": \"{}\", \"lane\": \"{}\", \"start\": {:e}, \
                 \"secs\": {:e}, \"bytes\": {}",
                e.id,
                kind_str(e.kind),
                lane_str(&e.lane),
                e.start,
                e.secs,
                e.bytes
            );
            match e.tenant {
                Some(t) => {
                    let _ = write!(s, ", \"tenant\": {t}");
                }
                None => s.push_str(", \"tenant\": null"),
            }
            match e.req {
                Some(r) => {
                    let _ = write!(s, ", \"req\": {r}");
                }
                None => s.push_str(", \"req\": null"),
            }
            s.push_str(", \"deps\": [");
            for (k, d) in e.deps.iter().enumerate() {
                if k > 0 {
                    s.push_str(", ");
                }
                let _ = write!(s, "{d}");
            }
            s.push_str("]}");
            s.push_str(if i + 1 < self.events.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Chrome-trace JSON: lanes become tracks (`tid` 0 = bus, 1 = host,
    /// `2 + r` = rank `r`; cluster traces add three tracks per machine
    /// `m` at `2 + n_ranks + 3m` — its bus, host CPU, and egress link —
    /// only for machines that actually appear in the events), durations
    /// become `ph: "X"` complete events with `ts`/`dur` in microseconds,
    /// fences become instant events. A launch spanning ranks `[lo, hi)`
    /// draws one slice per rank so the span is visible on every lane it
    /// occupies.
    pub fn to_chrome_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\"displayTimeUnit\": \"ms\", \"traceEvents\": [\n");
        let _ = writeln!(
            s,
            "  {{\"ph\": \"M\", \"name\": \"process_name\", \"pid\": 0, \
             \"args\": {{\"name\": \"pim ({})\"}}}},",
            self.source
        );
        let thread = |s: &mut String, tid: u32, name: &str| {
            let _ = writeln!(
                s,
                "  {{\"ph\": \"M\", \"name\": \"thread_name\", \"pid\": 0, \"tid\": {tid}, \
                 \"args\": {{\"name\": \"{name}\"}}}},"
            );
        };
        thread(&mut s, 0, "bus");
        thread(&mut s, 1, "host");
        for r in 0..self.n_ranks {
            thread(&mut s, 2 + r, &format!("rank {r}"));
        }
        // Machine / link tracks exist only when cluster events occupy
        // them, so single-machine traces keep their exact metadata set.
        let base = 2 + self.n_ranks;
        let mut machine_tracks: Vec<(u32, String)> = Vec::new();
        for e in &self.events {
            let t = match &e.lane {
                LaneTag::MachineBus { m } => (base + 3 * m, format!("machine {m} bus")),
                LaneTag::MachineHost { m } => (base + 3 * m + 1, format!("machine {m} host")),
                LaneTag::Link { m } => (base + 3 * m + 2, format!("link {m}")),
                _ => continue,
            };
            if !machine_tracks.contains(&t) {
                machine_tracks.push(t);
            }
        }
        machine_tracks.sort_by_key(|(tid, _)| *tid);
        for (tid, name) in &machine_tracks {
            thread(&mut s, *tid, name);
        }
        let mut lines: Vec<String> = Vec::with_capacity(self.events.len());
        for e in &self.events {
            let ts = e.start * 1e6;
            let dur = e.secs * 1e6;
            let mut args = format!("\"id\": {}, \"bytes\": {}", e.id, e.bytes);
            if let Some(t) = e.tenant {
                let _ = write!(args, ", \"tenant\": {t}");
            }
            if let Some(r) = e.req {
                let _ = write!(args, ", \"req\": {r}");
            }
            if !e.deps.is_empty() {
                let _ = write!(args, ", \"deps\": {}", e.deps.len());
            }
            let name = kind_str(e.kind);
            let mut slice = |tid: u32| {
                lines.push(format!(
                    "  {{\"ph\": \"X\", \"name\": \"{name}\", \"cat\": \"{name}\", \
                     \"pid\": 0, \"tid\": {tid}, \"ts\": {ts}, \"dur\": {dur}, \
                     \"args\": {{{args}}}}}"
                ));
            };
            match &e.lane {
                LaneTag::Bus => slice(0),
                LaneTag::Host => slice(1),
                LaneTag::Ranks { lo, hi } => {
                    for r in *lo..(*hi).min(self.n_ranks) {
                        slice(2 + r);
                    }
                }
                LaneTag::MachineBus { m } => slice(base + 3 * m),
                LaneTag::MachineHost { m } => slice(base + 3 * m + 1),
                LaneTag::Link { m } => slice(base + 3 * m + 2),
                LaneTag::Barrier => lines.push(format!(
                    "  {{\"ph\": \"i\", \"name\": \"{name}\", \"s\": \"p\", \
                     \"pid\": 0, \"tid\": 1, \"ts\": {ts}, \"args\": {{{args}}}}}"
                )),
            }
        }
        s.push_str(&lines.join(",\n"));
        if !lines.is_empty() {
            s.push('\n');
        }
        s.push_str("]}\n");
        s
    }
}

fn field<'v>(obj: &'v Value, key: &str) -> Result<&'v Value, String> {
    obj.get(key).ok_or_else(|| format!("missing field '{key}'"))
}

fn num(obj: &Value, key: &str) -> Result<f64, String> {
    field(obj, key)?
        .as_f64()
        .ok_or_else(|| format!("field '{key}' is not a number"))
}

fn opt_num(obj: &Value, key: &str) -> Result<Option<f64>, String> {
    match field(obj, key)? {
        Value::Null => Ok(None),
        Value::Num(x) => Ok(Some(*x)),
        _ => Err(format!("field '{key}' is neither number nor null")),
    }
}

/// Parse a native `trace/v1` document back into a [`Trace`]. Rejects
/// other schemas loudly; floats come back bit-identical to what
/// [`Trace::to_json`] wrote.
pub fn parse_trace(src: &str) -> Result<Trace, String> {
    let v = parse_json(src)?;
    let schema = field(&v, "schema")?
        .as_str()
        .ok_or("schema is not a string")?;
    if schema != "trace/v1" {
        return Err(format!("unsupported trace schema '{schema}'"));
    }
    let source = field(&v, "source")?
        .as_str()
        .ok_or("source is not a string")?
        .to_string();
    let n_ranks = num(&v, "n_ranks")? as u32;
    let raw = field(&v, "events")?
        .as_arr()
        .ok_or("events is not an array")?;
    let mut events = Vec::with_capacity(raw.len());
    for ev in raw {
        let deps = field(ev, "deps")?
            .as_arr()
            .ok_or("deps is not an array")?
            .iter()
            .map(|d| d.as_f64().map(|x| x as u64).ok_or("non-numeric dep id"))
            .collect::<Result<Vec<u64>, _>>()?;
        events.push(TraceEvent {
            id: num(ev, "id")? as u64,
            kind: kind_from(field(ev, "kind")?.as_str().ok_or("kind is not a string")?)?,
            lane: lane_from(field(ev, "lane")?.as_str().ok_or("lane is not a string")?)?,
            start: num(ev, "start")?,
            secs: num(ev, "secs")?,
            bytes: num(ev, "bytes")? as u64,
            tenant: opt_num(ev, "tenant")?.map(|x| x as u32),
            req: opt_num(ev, "req")?.map(|x| x as u64),
            deps,
        });
    }
    Ok(Trace { source, n_ranks, events })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Trace {
        Trace {
            source: "queue".into(),
            n_ranks: 2,
            events: vec![
                TraceEvent {
                    id: 0,
                    kind: CmdKind::Push,
                    lane: LaneTag::Bus,
                    start: 0.0,
                    secs: 0.2,
                    bytes: 4096,
                    tenant: None,
                    req: Some(0),
                    deps: vec![],
                },
                TraceEvent {
                    id: 1,
                    kind: CmdKind::Launch,
                    lane: LaneTag::Ranks { lo: 0, hi: 2 },
                    start: 0.2,
                    secs: 1.0 / 3.0,
                    bytes: 0,
                    tenant: Some(1),
                    req: Some(0),
                    deps: vec![0],
                },
                TraceEvent {
                    id: 2,
                    kind: CmdKind::Fence,
                    lane: LaneTag::Barrier,
                    start: 0.2 + 1.0 / 3.0,
                    secs: 0.0,
                    bytes: 0,
                    tenant: None,
                    req: None,
                    deps: vec![0, 1],
                },
            ],
        }
    }

    /// Native round trip is lossless and bit-identical, including the
    /// non-representable 1/3 duration.
    #[test]
    fn native_roundtrip_is_bit_identical() {
        let t = sample();
        let back = parse_trace(&t.to_json()).unwrap();
        assert_eq!(t, back);
        assert_eq!(t.events[1].secs.to_bits(), back.events[1].secs.to_bits());
        // and the re-serialization is byte-identical
        assert_eq!(t.to_json(), back.to_json());
    }

    /// The Chrome export is well-formed JSON with the lane→track
    /// metadata and one slice per occupied rank lane.
    #[test]
    fn chrome_export_parses_and_maps_lanes_to_tracks() {
        let t = sample();
        let v = parse_json(&t.to_chrome_json()).unwrap();
        let evs = v.get("traceEvents").and_then(Value::as_arr).unwrap();
        // 1 process + 2 fixed threads + 2 rank threads = 5 metadata
        let metas = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Value::as_str) == Some("M"))
            .count();
        assert_eq!(metas, 5);
        // push on bus (1 slice) + launch across 2 ranks (2 slices)
        let slices: Vec<f64> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Value::as_str) == Some("X"))
            .map(|e| e.get("tid").and_then(Value::as_f64).unwrap())
            .collect();
        assert_eq!(slices, vec![0.0, 2.0, 3.0]);
        // fence is an instant event
        assert_eq!(
            evs.iter()
                .filter(|e| e.get("ph").and_then(Value::as_str) == Some("i"))
                .count(),
            1
        );
    }

    /// Cluster lanes (`bus:m` / `host:m` / `link:m`) round-trip through
    /// the native form and map onto their own Chrome tracks — which are
    /// emitted only for machines actually present in the events.
    #[test]
    fn machine_lanes_roundtrip_and_get_own_tracks() {
        let mut t = sample();
        t.events.push(TraceEvent {
            id: 3,
            kind: CmdKind::Push,
            lane: LaneTag::MachineBus { m: 1 },
            start: 0.6,
            secs: 0.1,
            bytes: 128,
            tenant: None,
            req: None,
            deps: vec![],
        });
        t.events.push(TraceEvent {
            id: 4,
            kind: CmdKind::Net,
            lane: LaneTag::Link { m: 1 },
            start: 0.7,
            secs: 0.05,
            bytes: 256,
            tenant: None,
            req: None,
            deps: vec![3],
        });
        let back = parse_trace(&t.to_json()).unwrap();
        assert_eq!(t, back);
        assert_eq!(back.events[4].kind, CmdKind::Net);
        assert_eq!(back.events[4].lane, LaneTag::Link { m: 1 });
        let v = parse_json(&t.to_chrome_json()).unwrap();
        let evs = v.get("traceEvents").and_then(Value::as_arr).unwrap();
        // the 5 single-machine metas plus machine 1's bus and link
        // tracks (no host:1 meta — no event occupies it)
        let metas = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Value::as_str) == Some("M"))
            .count();
        assert_eq!(metas, 7);
        // base = 2 + n_ranks = 4: bus:1 → 4+3 = 7, link:1 → 4+5 = 9
        let tids: Vec<f64> = evs
            .iter()
            .filter(|e| e.get("ph").and_then(Value::as_str) == Some("X"))
            .map(|e| e.get("tid").and_then(Value::as_f64).unwrap())
            .collect();
        assert!(tids.contains(&7.0) && tids.contains(&9.0), "tids {tids:?}");
    }

    #[test]
    fn empty_trace_exports_parse() {
        let t = Trace::empty("queue", 1);
        let back = parse_trace(&t.to_json()).unwrap();
        assert!(back.is_empty());
        assert!(parse_json(&t.to_chrome_json()).is_ok());
    }

    #[test]
    fn foreign_schema_rejected() {
        assert!(parse_trace(r#"{"schema": "bench/v1", "source": "x", "n_ranks": 1, "events": []}"#)
            .is_err());
        assert!(parse_trace("not json").is_err());
    }
}
