//! Cursor-wise deterministic replay of a recorded [`Trace`].
//!
//! The engine orders events by `(start, id)` once at construction and
//! then steps a cursor over them — forwards, backwards, by simulated
//! time, or by seek ratio — the incident-replay idiom: load a recorded
//! timeline, scrub to the interesting window, single-step through it.
//! Everything is pure function of the trace, so two engines built from
//! bit-identical traces visit bit-identical event sequences.

use super::{Trace, TraceEvent};

/// Replays a recorded trace with seek / step / advance time controls.
#[derive(Clone, Debug)]
pub struct ReplayEngine {
    /// Events ordered by `(start, id)` (total order: `total_cmp` then
    /// id, so NaN-free schedules and duplicates both behave).
    events: Vec<TraceEvent>,
    /// How many duplicate-id events were dropped at load (first wins).
    pub dropped_duplicates: usize,
    /// Index of the next event the cursor will fire.
    cursor: usize,
    /// Current replay instant on the trace timeline.
    now: f64,
    /// Trace time bounds `[t0, t1]`.
    t0: f64,
    t1: f64,
    playing: bool,
    speed: f64,
}

impl ReplayEngine {
    pub fn new(trace: &Trace) -> Self {
        let mut events = trace.events.clone();
        events.sort_by(|a, b| a.start.total_cmp(&b.start).then(a.id.cmp(&b.id)));
        // Drop duplicate ids (first occurrence in time order wins) so a
        // concatenated or hand-edited trace still replays sanely.
        let mut seen = std::collections::BTreeSet::new();
        let before = events.len();
        events.retain(|e| seen.insert(e.id));
        let dropped_duplicates = before - events.len();
        let t0 = events.first().map(|e| e.start).unwrap_or(0.0);
        let t1 = events
            .iter()
            .map(TraceEvent::end)
            .fold(t0, f64::max);
        ReplayEngine {
            events,
            dropped_duplicates,
            cursor: 0,
            now: t0,
            t0,
            t1,
            playing: false,
            speed: 1.0,
        }
    }

    /// All events in replay order.
    pub fn events(&self) -> &[TraceEvent] {
        &self.events
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Current replay instant.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Index of the next event to fire (== `len()` when exhausted).
    pub fn cursor(&self) -> usize {
        self.cursor
    }

    /// Trace time bounds.
    pub fn bounds(&self) -> (f64, f64) {
        (self.t0, self.t1)
    }

    pub fn is_playing(&self) -> bool {
        self.playing
    }

    pub fn play(&mut self) {
        self.playing = true;
    }

    pub fn pause(&mut self) {
        self.playing = false;
    }

    /// Replay speed multiplier for [`advance`](Self::advance); clamped
    /// positive.
    pub fn set_speed(&mut self, speed: f64) {
        self.speed = if speed > 0.0 { speed } else { 1.0 };
    }

    /// Jump to `t0 + ratio * (t1 - t0)`; ratio clamps to `[0, 1]`.
    pub fn seek_ratio(&mut self, ratio: f64) {
        let r = ratio.clamp(0.0, 1.0);
        self.seek_time(self.t0 + r * (self.t1 - self.t0));
    }

    /// Jump the cursor so every event with `start < t` has fired and
    /// everything at or after `t` is still pending.
    pub fn seek_time(&mut self, t: f64) {
        let t = t.clamp(self.t0, self.t1);
        self.now = t;
        self.cursor = self.events.partition_point(|e| e.start < t);
    }

    /// Fire the next pending event, advancing `now` to its start.
    /// Returns `None` when exhausted (and pauses).
    pub fn step_next(&mut self) -> Option<&TraceEvent> {
        if self.cursor >= self.events.len() {
            self.playing = false;
            return None;
        }
        let ev = &self.events[self.cursor];
        self.cursor += 1;
        self.now = ev.start;
        Some(ev)
    }

    /// Un-fire the most recently fired event, moving `now` back to its
    /// start. Returns `None` at the beginning.
    pub fn step_prev(&mut self) -> Option<&TraceEvent> {
        if self.cursor == 0 {
            return None;
        }
        self.cursor -= 1;
        let ev = &self.events[self.cursor];
        self.now = ev.start;
        Some(ev)
    }

    /// Advance replay time by `dt * speed` (only while playing) and
    /// return the events whose start instants were crossed, in order.
    /// Auto-pauses when the end of the trace is reached.
    pub fn advance(&mut self, dt: f64) -> Vec<TraceEvent> {
        if !self.playing || dt <= 0.0 {
            return Vec::new();
        }
        let target = (self.now + dt * self.speed).min(self.t1);
        let end = self.events.partition_point(|e| e.start <= target);
        let fired = self.events[self.cursor..end].to_vec();
        self.cursor = end;
        self.now = target;
        if self.now >= self.t1 && self.cursor >= self.events.len() {
            self.playing = false;
        }
        fired
    }

    /// Events whose span covers instant `t` (the "what was running"
    /// query a scrubber UI asks).
    pub fn active_at(&self, t: f64) -> Vec<&TraceEvent> {
        self.events
            .iter()
            .filter(|e| e.start <= t && t < e.end().max(e.start + f64::EPSILON))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::CmdKind;
    use crate::coordinator::trace::LaneTag;

    fn ev(id: u64, start: f64, secs: f64) -> TraceEvent {
        TraceEvent {
            id,
            kind: CmdKind::Push,
            lane: LaneTag::Bus,
            start,
            secs,
            bytes: 0,
            tenant: None,
            req: None,
            deps: Vec::new(),
        }
    }

    fn trace(events: Vec<TraceEvent>) -> Trace {
        Trace { source: "queue".into(), n_ranks: 1, events }
    }

    #[test]
    fn steps_fire_in_start_then_id_order() {
        // deliberately shuffled input, with a same-start pair (2, 1)
        let t = trace(vec![ev(3, 2.0, 0.5), ev(1, 1.0, 0.5), ev(2, 1.0, 0.2), ev(0, 0.0, 1.0)]);
        let mut r = ReplayEngine::new(&t);
        let order: Vec<u64> = std::iter::from_fn(|| r.step_next().map(|e| e.id)).collect();
        assert_eq!(order, vec![0, 1, 2, 3]);
        assert_eq!(r.now(), 2.0);
        assert!(r.step_next().is_none());
        // and back
        assert_eq!(r.step_prev().unwrap().id, 3);
        assert_eq!(r.step_prev().unwrap().id, 2);
        assert_eq!(r.cursor(), 2);
    }

    #[test]
    fn seek_and_advance_cross_the_right_events() {
        let t = trace(vec![ev(0, 0.0, 1.0), ev(1, 1.0, 1.0), ev(2, 2.0, 1.0)]);
        let mut r = ReplayEngine::new(&t);
        r.seek_ratio(0.5); // now = 1.5: events starting before 1.5 fired
        assert_eq!(r.cursor(), 2);
        assert_eq!(r.now(), 1.5);
        r.play();
        let fired = r.advance(10.0); // overshoots: clamps to t1, fires the rest
        assert_eq!(fired.iter().map(|e| e.id).collect::<Vec<_>>(), vec![2]);
        assert!(!r.is_playing(), "auto-paused at end");
        assert_eq!(r.now(), 3.0);
        // paused engines don't move
        assert!(r.advance(1.0).is_empty());
        // speed scales the crossed window
        r.seek_time(0.0);
        r.play();
        r.set_speed(2.0);
        let fired = r.advance(0.6); // covers [0, 1.2]: ids 0 and 1
        assert_eq!(fired.iter().map(|e| e.id).collect::<Vec<_>>(), vec![0, 1]);
    }

    #[test]
    fn active_at_reports_overlapping_spans() {
        let t = trace(vec![ev(0, 0.0, 2.0), ev(1, 1.0, 2.0), ev(2, 4.0, 1.0)]);
        let r = ReplayEngine::new(&t);
        let ids: Vec<u64> = r.active_at(1.5).iter().map(|e| e.id).collect();
        assert_eq!(ids, vec![0, 1]);
        assert!(r.active_at(3.5).is_empty());
    }

    #[test]
    fn empty_trace_is_a_safe_no_op() {
        let mut r = ReplayEngine::new(&Trace::empty("queue", 4));
        assert!(r.is_empty());
        assert_eq!(r.bounds(), (0.0, 0.0));
        assert!(r.step_next().is_none());
        assert!(r.step_prev().is_none());
        r.play();
        assert!(r.advance(1.0).is_empty());
        r.seek_ratio(1.0);
        assert_eq!(r.now(), 0.0);
    }

    #[test]
    fn duplicate_ids_dropped_first_wins() {
        let mut dup = ev(1, 5.0, 1.0);
        dup.bytes = 999;
        let t = trace(vec![ev(1, 1.0, 1.0), dup, ev(0, 0.0, 1.0)]);
        let r = ReplayEngine::new(&t);
        assert_eq!(r.dropped_duplicates, 1);
        assert_eq!(r.len(), 2);
        // the earlier (start = 1.0) copy of id 1 survived
        assert_eq!(r.events()[1].bytes, 0);
    }
}
