//! Hotspot triage over a recorded trace: bus-saturation windows,
//! straggler ranks, and dependency-stall chains (critical path).
//!
//! Everything here is a deterministic pure function of the trace —
//! ranking ties break on `total_cmp` then window/event index, so two
//! bit-identical traces always produce bit-identical reports (the
//! replay determinism tests serialize reports and compare bytes).

use super::{LaneTag, Trace};
use std::fmt::Write as _;

/// One fixed-width window of bus occupancy.
#[derive(Clone, Debug, PartialEq)]
pub struct BusWindow {
    pub start: f64,
    pub end: f64,
    /// Bus-busy seconds inside the window (clipped to the window).
    pub busy: f64,
    /// `busy / (end - start)`, in `[0, 1]` up to float error.
    pub frac: f64,
}

/// Kernel-lane busy seconds attributed to one rank.
#[derive(Clone, Debug, PartialEq)]
pub struct RankLoad {
    pub rank: u32,
    pub busy: f64,
}

/// An event that sat waiting on its dependencies before starting.
#[derive(Clone, Debug, PartialEq)]
pub struct StallEdge {
    /// The stalled event's id.
    pub event: u64,
    /// Seconds between its latest dependency finishing and it starting.
    pub wait: f64,
}

/// The triage summary: saturation windows ranked hottest-first,
/// straggler ranks busiest-first, the critical path, and the worst
/// dependency stalls.
#[derive(Clone, Debug, PartialEq)]
pub struct TriageReport {
    pub source: String,
    pub events: usize,
    /// Last finish instant in the trace.
    pub span: f64,
    /// Total bus-busy seconds.
    pub bus_busy: f64,
    /// `bus_busy / span` (0 for an empty trace).
    pub bus_frac: f64,
    /// Occupancy windows, ranked by `frac` descending.
    pub windows: Vec<BusWindow>,
    /// Per-rank kernel busy seconds, busiest first.
    pub stragglers: Vec<RankLoad>,
    /// `max(busy) / mean(busy)` over ranks that did any work (1.0 when
    /// perfectly balanced or fewer than 2 active ranks).
    pub imbalance: f64,
    /// Event ids of the longest dependency chain, in execution order.
    pub critical_path: Vec<u64>,
    /// Sum of `secs` along the critical path.
    pub critical_secs: f64,
    /// Worst dependency stalls, longest wait first.
    pub stalls: Vec<StallEdge>,
}

/// [`analyze_with`] at the default window count (16).
pub fn analyze(trace: &Trace) -> TriageReport {
    analyze_with(trace, 16)
}

/// Rank the trace's hotspots. `n_windows` buckets the timeline for
/// bus-occupancy ranking; stalls and windows are truncated to the top 8
/// after ranking so reports stay table-sized.
pub fn analyze_with(trace: &Trace, n_windows: usize) -> TriageReport {
    let span = trace.span();
    let mut report = TriageReport {
        source: trace.source.clone(),
        events: trace.events.len(),
        span,
        bus_busy: 0.0,
        bus_frac: 0.0,
        windows: Vec::new(),
        stragglers: Vec::new(),
        imbalance: 1.0,
        critical_path: Vec::new(),
        critical_secs: 0.0,
        stalls: Vec::new(),
    };
    if trace.is_empty() || span <= 0.0 || n_windows == 0 {
        return report;
    }

    // --- bus occupancy, total and windowed -------------------------------
    let width = span / n_windows as f64;
    let mut windows: Vec<BusWindow> = (0..n_windows)
        .map(|w| BusWindow {
            start: w as f64 * width,
            end: (w + 1) as f64 * width,
            busy: 0.0,
            frac: 0.0,
        })
        .collect();
    for e in &trace.events {
        if e.lane != LaneTag::Bus || e.secs <= 0.0 {
            continue;
        }
        report.bus_busy += e.secs;
        let lo = ((e.start / width) as usize).min(n_windows - 1);
        let hi = ((e.end() / width) as usize).min(n_windows - 1);
        for (w, win) in windows.iter_mut().enumerate().take(hi + 1).skip(lo) {
            let clip = e.end().min((w + 1) as f64 * width) - e.start.max(w as f64 * width);
            if clip > 0.0 {
                win.busy += clip;
            }
        }
    }
    for w in &mut windows {
        w.frac = w.busy / width;
    }
    report.bus_frac = report.bus_busy / span;
    // hottest first; stable on (frac, then original window order)
    windows.sort_by(|a, b| b.frac.total_cmp(&a.frac).then(a.start.total_cmp(&b.start)));
    windows.truncate(8);
    report.windows = windows;

    // --- straggler ranks -------------------------------------------------
    let mut busy = vec![0.0f64; trace.n_ranks as usize];
    for e in &trace.events {
        if let LaneTag::Ranks { lo, hi } = e.lane {
            for r in lo..hi.min(trace.n_ranks) {
                busy[r as usize] += e.secs;
            }
        }
    }
    let mut loads: Vec<RankLoad> = busy
        .iter()
        .enumerate()
        .filter(|(_, b)| **b > 0.0)
        .map(|(r, b)| RankLoad { rank: r as u32, busy: *b })
        .collect();
    if loads.len() >= 2 {
        let mean = loads.iter().map(|l| l.busy).sum::<f64>() / loads.len() as f64;
        let max = loads.iter().map(|l| l.busy).fold(0.0, f64::max);
        report.imbalance = max / mean;
    }
    loads.sort_by(|a, b| b.busy.total_cmp(&a.busy).then(a.rank.cmp(&b.rank)));
    loads.truncate(8);
    report.stragglers = loads;

    // --- critical path & stalls ------------------------------------------
    // Events arrive in id order from the sinks, and deps always point at
    // earlier ids, so one forward pass computes the longest-chain cost.
    // Index events by id (ids may be sparse in hand-edited traces).
    let idx: std::collections::BTreeMap<u64, usize> =
        trace.events.iter().enumerate().map(|(i, e)| (e.id, i)).collect();
    let n = trace.events.len();
    let mut cp = vec![0.0f64; n]; // cost of the longest chain ending here
    let mut pred = vec![None::<usize>; n];
    for (i, e) in trace.events.iter().enumerate() {
        let mut best = 0.0f64;
        let mut best_pred = None;
        let mut latest_dep_end = f64::NEG_INFINITY;
        for d in &e.deps {
            if let Some(&j) = idx.get(d) {
                if j >= i {
                    continue; // ignore forward/self edges defensively
                }
                latest_dep_end = latest_dep_end.max(trace.events[j].end());
                if cp[j] > best || (cp[j] == best && best_pred.is_none()) {
                    best = cp[j];
                    best_pred = Some(j);
                }
            }
        }
        cp[i] = best + e.secs;
        pred[i] = best_pred;
        if latest_dep_end > f64::NEG_INFINITY {
            let wait = e.start - latest_dep_end;
            if wait > 0.0 {
                report.stalls.push(StallEdge { event: e.id, wait });
            }
        }
    }
    if let Some((end, _)) = cp
        .iter()
        .enumerate()
        .max_by(|(i, a), (j, b)| a.total_cmp(b).then(j.cmp(i)))
    {
        report.critical_secs = cp[end];
        let mut path = Vec::new();
        let mut cur = Some(end);
        while let Some(i) = cur {
            path.push(trace.events[i].id);
            cur = pred[i];
        }
        path.reverse();
        report.critical_path = path;
    }
    report
        .stalls
        .sort_by(|a, b| b.wait.total_cmp(&a.wait).then(a.event.cmp(&b.event)));
    report.stalls.truncate(8);
    report
}

impl TriageReport {
    /// Human-readable summary table.
    pub fn table(&self) -> String {
        let mut s = String::new();
        let _ = writeln!(
            s,
            "triage: {} trace, {} events, span {:.6} s",
            self.source, self.events, self.span
        );
        let _ = writeln!(
            s,
            "bus: {:.6} s busy ({:.1}% of span)",
            self.bus_busy,
            self.bus_frac * 100.0
        );
        if !self.windows.is_empty() {
            s.push_str("hottest bus windows:\n");
            for w in &self.windows {
                let _ = writeln!(
                    s,
                    "  [{:>9.6}, {:>9.6}) s  {:>5.1}% busy",
                    w.start,
                    w.end,
                    w.frac * 100.0
                );
            }
        }
        if !self.stragglers.is_empty() {
            let _ = writeln!(s, "straggler ranks (imbalance {:.3}):", self.imbalance);
            for l in &self.stragglers {
                let _ = writeln!(s, "  rank {:>3}  {:>9.6} s busy", l.rank, l.busy);
            }
        }
        if !self.critical_path.is_empty() {
            let _ = writeln!(
                s,
                "critical path: {:.6} s over {} events: {:?}",
                self.critical_secs,
                self.critical_path.len(),
                self.critical_path
            );
        }
        if !self.stalls.is_empty() {
            s.push_str("worst dependency stalls:\n");
            for st in &self.stalls {
                let _ = writeln!(s, "  event {:>4}  waited {:>9.6} s", st.event, st.wait);
            }
        }
        s
    }

    /// Machine form (floats shortest-roundtrip via `{:e}`, so two
    /// bit-identical reports serialize to identical bytes).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"schema\": \"triage/v1\",\n");
        let _ = writeln!(s, "  \"source\": \"{}\",", self.source);
        let _ = writeln!(s, "  \"events\": {},", self.events);
        let _ = writeln!(s, "  \"span\": {:e},", self.span);
        let _ = writeln!(s, "  \"bus_busy\": {:e},", self.bus_busy);
        let _ = writeln!(s, "  \"bus_frac\": {:e},", self.bus_frac);
        s.push_str("  \"windows\": [");
        for (i, w) in self.windows.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            let _ = write!(
                s,
                "{{\"start\": {:e}, \"end\": {:e}, \"busy\": {:e}, \"frac\": {:e}}}",
                w.start, w.end, w.busy, w.frac
            );
        }
        s.push_str("],\n  \"stragglers\": [");
        for (i, l) in self.stragglers.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            let _ = write!(s, "{{\"rank\": {}, \"busy\": {:e}}}", l.rank, l.busy);
        }
        let _ = writeln!(s, "],\n  \"imbalance\": {:e},", self.imbalance);
        s.push_str("  \"critical_path\": [");
        for (i, id) in self.critical_path.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            let _ = write!(s, "{id}");
        }
        let _ = writeln!(s, "],\n  \"critical_secs\": {:e},", self.critical_secs);
        s.push_str("  \"stalls\": [");
        for (i, st) in self.stalls.iter().enumerate() {
            if i > 0 {
                s.push_str(", ");
            }
            let _ = write!(s, "{{\"event\": {}, \"wait\": {:e}}}", st.event, st.wait);
        }
        s.push_str("]\n}\n");
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::queue::CmdKind;
    use crate::coordinator::trace::TraceEvent;

    fn ev(id: u64, lane: LaneTag, start: f64, secs: f64, deps: Vec<u64>) -> TraceEvent {
        TraceEvent {
            id,
            kind: match lane {
                LaneTag::Bus => CmdKind::Push,
                LaneTag::Host => CmdKind::HostMerge,
                LaneTag::Ranks { .. } => CmdKind::Launch,
                LaneTag::Barrier => CmdKind::Fence,
                LaneTag::Link { .. } => CmdKind::Net,
                LaneTag::MachineBus { .. } | LaneTag::MachineHost { .. } => CmdKind::Push,
            },
            lane,
            start,
            secs,
            bytes: 0,
            tenant: None,
            req: None,
            deps,
        }
    }

    /// An injected saturation burst must rank as the top window.
    #[test]
    fn injected_bus_saturation_window_ranks_top() {
        let mut events = Vec::new();
        // sparse background: a short push every 1 s over [0, 8)
        for i in 0..8u64 {
            events.push(ev(i, LaneTag::Bus, i as f64, 0.05, vec![]));
        }
        // saturation burst: the bus is 100% busy over [4.0, 5.0)
        for j in 0..10u64 {
            events.push(ev(8 + j, LaneTag::Bus, 4.0 + j as f64 * 0.1, 0.1, vec![]));
        }
        let t = Trace { source: "queue".into(), n_ranks: 1, events };
        let r = analyze_with(&t, 8); // 8 windows of ~1 s over span ≈ 8.05
        let top = &r.windows[0];
        assert!(
            top.start <= 4.0 && 4.0 < top.end,
            "top window {:?} should cover the injected burst at 4.0",
            top
        );
        assert!(top.frac > 0.9, "burst window ~saturated, got {}", top.frac);
        assert!(r.windows[1].frac < top.frac);
    }

    #[test]
    fn stragglers_and_imbalance_rank_busiest_rank_first() {
        let events = vec![
            ev(0, LaneTag::Ranks { lo: 0, hi: 4 }, 0.0, 1.0, vec![]),
            ev(1, LaneTag::Ranks { lo: 2, hi: 3 }, 1.0, 3.0, vec![]),
        ];
        let t = Trace { source: "queue".into(), n_ranks: 4, events };
        let r = analyze(&t);
        assert_eq!(r.stragglers[0].rank, 2);
        assert_eq!(r.stragglers[0].busy, 4.0);
        // mean = (1+1+4+1)/4 = 1.75, max = 4
        assert!((r.imbalance - 4.0 / 1.75).abs() < 1e-12);
    }

    #[test]
    fn critical_path_follows_longest_chain_and_finds_stalls() {
        // 0 -> 1 -> 3 (chain 0.5+2.0+1.0 = 3.5) beats 0 -> 2 -> 3 via cp;
        // 3 starts at 4.0 but its latest dep (1) ends at 2.5: stall 1.5.
        let events = vec![
            ev(0, LaneTag::Bus, 0.0, 0.5, vec![]),
            ev(1, LaneTag::Ranks { lo: 0, hi: 1 }, 0.5, 2.0, vec![0]),
            ev(2, LaneTag::Bus, 0.5, 0.1, vec![0]),
            ev(3, LaneTag::Host, 4.0, 1.0, vec![1, 2]),
        ];
        let t = Trace { source: "queue".into(), n_ranks: 1, events };
        let r = analyze(&t);
        assert_eq!(r.critical_path, vec![0, 1, 3]);
        assert!((r.critical_secs - 3.5).abs() < 1e-12);
        assert_eq!(r.stalls[0].event, 3);
        assert!((r.stalls[0].wait - 1.5).abs() < 1e-12);
    }

    #[test]
    fn empty_trace_yields_inert_report() {
        let r = analyze(&Trace::empty("queue", 4));
        assert_eq!(r.events, 0);
        assert_eq!(r.span, 0.0);
        assert!(r.windows.is_empty() && r.stalls.is_empty() && r.critical_path.is_empty());
        assert_eq!(r.imbalance, 1.0);
        // serializers don't choke on the empty shell
        assert!(r.to_json().contains("\"triage/v1\""));
        assert!(r.table().contains("0 events"));
    }

    #[test]
    fn report_json_is_deterministic() {
        let events = vec![
            ev(0, LaneTag::Bus, 0.0, 1.0 / 3.0, vec![]),
            ev(1, LaneTag::Ranks { lo: 0, hi: 2 }, 1.0 / 3.0, 0.7, vec![0]),
        ];
        let t = Trace { source: "queue".into(), n_ranks: 2, events };
        let a = analyze(&t).to_json();
        let b = analyze(&t.clone()).to_json();
        assert_eq!(a, b);
        assert!(crate::util::json::parse_json(&a).is_ok());
    }
}
