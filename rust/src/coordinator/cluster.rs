//! Multi-machine sharded fleets with modeled network collectives.
//!
//! A [`Cluster`] owns N independent [`PimSet`]s — one per machine, each
//! with its own MRAM layout, transfer engine, and host model — behind a
//! single façade, and records every operation into **one** cluster-wide
//! [`CmdQueue`]. Machine `m`'s DPUs get global command indices offset by
//! a rank-aligned stride (`ranks_per_machine × dpus_per_rank`), so the
//! queue's existing DPU-overlap dependency gate isolates machines
//! automatically, and `lane_for`'s rank math lands each machine's
//! launches on disjoint `Lane::Ranks` spans. Transfers and host merges
//! route to the per-machine [`Lane::MachineBus`] / [`Lane::MachineHost`]
//! lanes (machine 0 keeps the legacy `Bus` / `Host` lanes, which is what
//! makes a 1-machine cluster bit-identical to the single-machine path).
//!
//! Cross-machine traffic is modeled, not functional: the cluster driver
//! plays every machine's host, so data moves host-side for free and a
//! [`CmdKind::Net`] command charges the wire. The [`NetModel`] is a
//! flat, non-blocking, full-duplex switch ([`Topology::FlatSwitch`]):
//! only the **egress** link of the sending machine is occupied, for
//! `bytes / link_bw + latency` seconds, so an all-gather's modeled
//! makespan is exactly the analytic bound
//! `max_i((N−1)·s_i / B + L)` (see `tests/properties.rs`).
//!
//! Collectives are first-class queue commands built from `Net`:
//!
//! * [`Cluster::all_gather`] — machine `i` streams its `s_i`-byte shard
//!   to the other N−1 machines: one `Net` of `(N−1)·s_i` bytes per link.
//! * [`Cluster::reduce_scatter`] — machine `i` sends everything it does
//!   *not* own: one `Net` of `S − s_i` bytes per link.
//! * [`Cluster::all_reduce`] — reduce-scatter, a per-machine host-side
//!   combine, then all-gather of the reduced shards.
//! * [`Cluster::exchange`] — explicit point-to-point sends (BFS frontier
//!   exchange), serialized per egress link in issue order.
//!
//! Everything funnels through the same `CmdQueue::schedule` pass the
//! single-machine path uses, so cross-machine overlap (machine 1's
//! launch hiding under machine 0's push, a frontier exchange hiding
//! under the next level's zeroing traffic) falls out of the existing
//! dependency inference — and serial vs parallel executors stay
//! bit-identical, because nothing here touches the executor contract.

use super::executor::FleetExecutor;
use super::layout::Symbol;
use super::accounting::{Bucket, TimeBreakdown};
use super::queue::{Access, CmdId, CmdMeta, CmdQueue, Lane};
use super::telemetry::{Labels, Telemetry};
use super::trace::{TraceEvent, TraceSink};
use super::{LaunchStats, PimSet};
use crate::arch::SystemConfig;
use crate::dpu::Ctx;
use crate::util::pod::Pod;
use std::sync::Arc;

/// Per-link network calibration of the modeled interconnect.
#[derive(Clone, Debug, PartialEq)]
pub struct NetModel {
    /// Link bandwidth in bytes/second (default 12.5 GB/s ≈ 100 Gb/s
    /// Ethernet, the commodity datacenter fabric).
    pub link_bw: f64,
    /// Per-message latency in seconds (default 2 µs: NIC + one switch
    /// hop).
    pub latency: f64,
}

impl Default for NetModel {
    fn default() -> Self {
        NetModel { link_bw: 12.5e9, latency: 2e-6 }
    }
}

impl NetModel {
    /// Modeled seconds one egress transfer of `bytes` occupies its link:
    /// `bytes / link_bw + latency`. The analytic collective bounds are
    /// built from this exact expression, so tests can compare bitwise.
    pub fn xfer_secs(&self, bytes: u64) -> f64 {
        bytes as f64 / self.link_bw + self.latency
    }
}

/// Interconnect topology. Only the flat switch is modeled today: every
/// machine hangs off one non-blocking, full-duplex switch, so transfers
/// contend solely on the sender's egress link.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Topology {
    #[default]
    FlatSwitch,
}

/// Configuration of a modeled multi-machine fleet.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// Per-machine PIM system (every machine is identical).
    pub sys: SystemConfig,
    pub machines: u32,
    pub dpus_per_machine: u32,
    pub net: NetModel,
    pub topology: Topology,
}

impl ClusterConfig {
    /// Default-network config for `machines` × `dpus_per_machine`.
    pub fn new(sys: SystemConfig, machines: u32, dpus_per_machine: u32) -> Self {
        assert!(machines >= 1, "a cluster needs at least one machine");
        ClusterConfig {
            sys,
            machines,
            dpus_per_machine,
            net: NetModel::default(),
            topology: Topology::FlatSwitch,
        }
    }
}

/// Scalar summary a [`Cluster::report`] returns alongside the summed
/// per-machine breakdown.
#[derive(Clone, Debug, Default)]
pub struct ClusterReport {
    pub machines: u32,
    /// Per-machine bucket sums (`TimeBreakdown::add` over the fleets),
    /// with `overlapped` replaced by the cluster-schedule credit.
    pub breakdown: TimeBreakdown,
    /// Modeled wall time: sum of every `sync`'s schedule makespan.
    pub makespan: f64,
    /// Seconds the modeled links were busy (sum over `Net` commands;
    /// concurrent links accumulate independently).
    pub net_secs: f64,
    /// Bytes that crossed the modeled network.
    pub net_bytes: u64,
}

/// N machines of DPUs behind one façade — see the module docs.
pub struct Cluster {
    pub cfg: ClusterConfig,
    /// One fleet per machine, in machine order. Direct access is fine
    /// for reads; mutate through the cluster so commands get recorded.
    pub sets: Vec<PimSet>,
    queue: CmdQueue,
    /// DPUs per rank of the per-machine system (lane math).
    per: usize,
    /// Whole ranks each machine spans — the global DPU-index stride is
    /// `ranks_per_machine × per`, so machines never share a rank lane.
    ranks_per_machine: usize,
    /// Cluster-schedule overlap credit accumulated across syncs.
    overlapped: f64,
    /// Modeled wall clock: advances by each sync's makespan (also the
    /// base instant trace events are stamped against).
    clock: f64,
    net_secs: f64,
    net_bytes: u64,
    trace: Option<TraceSink>,
    /// Telemetry registry (`--metrics`): per-link egress bytes and busy
    /// seconds, collective counters, and per-sync queue digests. Pure
    /// reads of modeled values — an instrumented cluster run is
    /// bit-identical to a bare one.
    telemetry: Option<Telemetry>,
}

impl Cluster {
    /// Allocate `machines` identical fleets sharing one executor (one
    /// worker pool serves the whole cluster, like `split_ranks`).
    pub fn new(cfg: ClusterConfig, exec: Arc<dyn FleetExecutor>) -> Self {
        let sets: Vec<PimSet> = (0..cfg.machines)
            .map(|_| {
                PimSet::allocate_with(cfg.sys.clone(), cfg.dpus_per_machine, Arc::clone(&exec))
            })
            .collect();
        let per = cfg.sys.dpus_per_rank().max(1) as usize;
        let ranks_per_machine = (cfg.dpus_per_machine as usize).div_ceil(per);
        Cluster {
            sets,
            queue: CmdQueue::new(),
            per,
            ranks_per_machine,
            overlapped: 0.0,
            clock: 0.0,
            net_secs: 0.0,
            net_bytes: 0,
            trace: None,
            telemetry: None,
            cfg,
        }
    }

    /// Install a trace sink (builder style): every sync emits the
    /// scheduled commands as `source: "cluster"` events, with machine
    /// bus / host / link lanes tagged per machine.
    pub fn with_trace(mut self, sink: TraceSink) -> Self {
        sink.set_geometry("cluster", (self.machines() as usize * self.ranks_per_machine) as u32);
        self.trace = Some(sink);
        self
    }

    /// Install a telemetry registry (builder style) — see
    /// `coordinator::telemetry`.
    pub fn with_telemetry(mut self, tel: Telemetry) -> Self {
        self.telemetry = Some(tel);
        self
    }

    pub fn machines(&self) -> u32 {
        self.cfg.machines
    }

    /// DPUs on each machine.
    pub fn dpus_per_machine(&self) -> usize {
        self.cfg.dpus_per_machine as usize
    }

    /// Commands recorded since the last sync.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Id of the most recently recorded command.
    pub fn last_cmd(&self) -> Option<CmdId> {
        self.queue.last_id()
    }

    /// First global DPU index of machine `m` (rank-aligned stride).
    fn dpu_offset(&self, m: u32) -> usize {
        m as usize * self.ranks_per_machine * self.per
    }

    /// Allocate the same typed MRAM region on **every** machine. The
    /// layouts evolve in lockstep (identical allocation sequences), so
    /// one `Symbol` handle serves the whole cluster — the multi-machine
    /// generalization of fleet-wide linker-placed symbols.
    pub fn symbol<T: Pod>(&mut self, elems: usize) -> Symbol<T> {
        let first = self.sets[0].symbol::<T>(elems);
        for set in &mut self.sets[1..] {
            let sym = set.symbol::<T>(elems);
            debug_assert_eq!(
                sym.off(),
                first.off(),
                "cluster layouts must evolve in lockstep"
            );
        }
        first
    }

    /// Coalesce subsequent transfers on one machine into a single
    /// recorded bus command (a transfer group may not span machines).
    pub fn group_begin(&mut self) {
        self.queue.group_begin();
    }

    pub fn group_end(&mut self) {
        self.queue.group_end();
    }

    // ------------------------------------------------------- transfers
    //
    // Each method performs the functional movement and exact accounting
    // of the corresponding `PimSet::xfer` terminal on machine `m`'s
    // fleet, then records the identical `CmdMeta` — machine-tagged and
    // with globally-offset DPU indices — into the cluster queue. The
    // engine's seconds are recorded directly (no bucket-delta round
    // trip), so a 1-machine cluster records bit-identical commands to a
    // plain `PimSet` queue session.

    /// Equal-size per-DPU buffers to machine `m` (`dpu_push_xfer`).
    pub fn push_equal<T: Pod>(
        &mut self,
        m: u32,
        bucket: Bucket,
        sym: Symbol<T>,
        bufs: &[Vec<T>],
        after: &[CmdId],
    ) -> CmdId {
        let off = sym.off();
        let (secs, bytes, per_dpu, n) = {
            let set = &mut self.sets[m as usize];
            assert_eq!(bufs.len(), set.dpus.len(), "one buffer per DPU");
            let secs = set.engine.push_to(&*set.exec, &mut set.dpus, off, bufs);
            let bytes: u64 =
                bufs.iter().map(|b| std::mem::size_of_val(b.as_slice()) as u64).sum();
            set.metrics.account(bucket, secs, bytes);
            let per_dpu = bufs.first().map_or(0, |b| std::mem::size_of_val(b.as_slice()));
            (secs, bytes, per_dpu, set.dpus.len())
        };
        let g0 = self.dpu_offset(m);
        self.queue.push(
            CmdMeta::push(g0..g0 + n, off..off + per_dpu, secs, after.to_vec())
                .with_bytes(bytes)
                .on_machine(m),
        )
    }

    /// Serial transfer to one DPU of machine `m` (`dpu_copy_to`).
    pub fn push_one<T: Pod>(
        &mut self,
        m: u32,
        bucket: Bucket,
        sym: Symbol<T>,
        dpu: usize,
        data: &[T],
        after: &[CmdId],
    ) -> CmdId {
        let off = sym.off();
        let bytes = std::mem::size_of_val(data);
        let secs = {
            let set = &mut self.sets[m as usize];
            let secs = set.engine.copy_to(&mut set.dpus[dpu], off, data);
            set.metrics.account(bucket, secs, bytes as u64);
            secs
        };
        let g0 = self.dpu_offset(m);
        self.queue.push(
            CmdMeta::push(g0 + dpu..g0 + dpu + 1, off..off + bytes, secs, after.to_vec())
                .with_bytes(bytes as u64)
                .on_machine(m),
        )
    }

    /// Same buffer to every DPU of machine `m` (`dpu_broadcast_to`).
    pub fn broadcast<T: Pod>(
        &mut self,
        m: u32,
        bucket: Bucket,
        sym: Symbol<T>,
        data: &[T],
        after: &[CmdId],
    ) -> CmdId {
        let off = sym.off();
        let per_dpu = std::mem::size_of_val(data);
        let (secs, n) = {
            let set = &mut self.sets[m as usize];
            let secs = set.engine.broadcast_to(&*set.exec, &mut set.dpus, off, data);
            let n = set.dpus.len();
            set.metrics.account(bucket, secs, (n * per_dpu) as u64);
            (secs, n)
        };
        let g0 = self.dpu_offset(m);
        self.queue.push(
            CmdMeta::push(g0..g0 + n, off..off + per_dpu, secs, after.to_vec())
                .with_bytes((n * per_dpu) as u64)
                .on_machine(m),
        )
    }

    /// Retrieve `n` elements from every DPU of machine `m`.
    pub fn pull_equal<T: Pod>(
        &mut self,
        m: u32,
        bucket: Bucket,
        sym: Symbol<T>,
        n: usize,
        after: &[CmdId],
    ) -> (Vec<Vec<T>>, CmdId) {
        let off = sym.off();
        let per_dpu = n * std::mem::size_of::<T>();
        let (data, secs, n_dpus) = {
            let set = &mut self.sets[m as usize];
            let (data, secs) = set.engine.push_from(&*set.exec, &mut set.dpus, off, n);
            let n_dpus = set.dpus.len();
            set.metrics.account(bucket, secs, (n_dpus * per_dpu) as u64);
            (data, secs, n_dpus)
        };
        let g0 = self.dpu_offset(m);
        let id = self.queue.push(
            CmdMeta::pull(g0..g0 + n_dpus, off..off + per_dpu, secs, after.to_vec())
                .with_bytes((n_dpus * per_dpu) as u64)
                .on_machine(m),
        );
        (data, id)
    }

    /// Retrieve `n` elements from one DPU of machine `m`.
    pub fn pull_one<T: Pod>(
        &mut self,
        m: u32,
        bucket: Bucket,
        sym: Symbol<T>,
        dpu: usize,
        n: usize,
        after: &[CmdId],
    ) -> (Vec<T>, CmdId) {
        let off = sym.off();
        let bytes = n * std::mem::size_of::<T>();
        let (data, secs) = {
            let set = &mut self.sets[m as usize];
            let (data, secs) = set.engine.copy_from(&set.dpus[dpu], off, n);
            set.metrics.account(bucket, secs, bytes as u64);
            (data, secs)
        };
        let g0 = self.dpu_offset(m);
        let id = self.queue.push(
            CmdMeta::pull(g0 + dpu..g0 + dpu + 1, off..off + bytes, secs, after.to_vec())
                .with_bytes(bytes as u64)
                .on_machine(m),
        );
        (data, id)
    }

    // -------------------------------------------------------- launches

    /// Launch `f(dpu_idx, ctx)` on every DPU of machine `m` with the
    /// declared MRAM footprint (threaded tasklets: barriers / mutexes
    /// allowed). `dpu_idx` is machine-local.
    pub fn launch_acc<F>(
        &mut self,
        m: u32,
        acc: Access,
        n_tasklets: u32,
        f: F,
    ) -> (LaunchStats, CmdId)
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        // With no open queue and no sink on the machine set, the launch
        // records nothing there — the cluster queue is the only record.
        let stats = self.sets[m as usize].launch_acc(acc.clone(), n_tasklets, f);
        let n = self.sets[m as usize].dpus.len();
        let g0 = self.dpu_offset(m);
        let id = self
            .queue
            .push(CmdMeta::launch(g0..g0 + n, acc, stats.secs).on_machine(m));
        (stats, id)
    }

    /// Sequential-tasklet fast-path launch on machine `m` (kernels
    /// without barriers or handshakes; see `PimSet::launch_seq_acc`).
    pub fn launch_seq_acc<F>(
        &mut self,
        m: u32,
        acc: Access,
        n_tasklets: u32,
        f: F,
    ) -> (LaunchStats, CmdId)
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        let stats = self.sets[m as usize].launch_seq_acc(acc.clone(), n_tasklets, f);
        let n = self.sets[m as usize].dpus.len();
        let g0 = self.dpu_offset(m);
        let id = self
            .queue
            .push(CmdMeta::launch(g0..g0 + n, acc, stats.secs).on_machine(m));
        (stats, id)
    }

    /// Charge merge work on machine `m`'s host (its `MachineHost` lane),
    /// depending only on the listed commands.
    pub fn host_merge(&mut self, m: u32, bytes: u64, ops: u64, after: &[CmdId]) -> CmdId {
        let secs = {
            let set = &mut self.sets[m as usize];
            let spans = set.spans_sockets();
            let secs = set.host.merge_numa(bytes, ops, spans);
            set.metrics.inter_dpu += secs;
            secs
        };
        self.queue.push(
            CmdMeta::host_merge_after(secs, after.to_vec())
                .with_bytes(bytes)
                .on_machine(m),
        )
    }

    // ----------------------------------------------------- collectives

    /// One modeled egress transfer of `bytes` from machine `src`. The
    /// building block of every collective; deps flow only through
    /// `after` (a `Net` touches no MRAM region).
    pub fn net_send(&mut self, src: u32, bytes: u64, after: &[CmdId]) -> CmdId {
        assert!(src < self.machines(), "machine {src} out of range");
        let secs = self.cfg.net.xfer_secs(bytes);
        self.net_secs += secs;
        self.net_bytes += bytes;
        if let Some(tel) = &self.telemetry {
            let lbl = Labels::lane(&Lane::Link(src)).with_machine(src);
            tel.counter_add("cluster_link_bytes", lbl.clone(), bytes);
            tel.gauge_add("cluster_link_busy_secs", lbl, secs);
        }
        self.queue
            .push(CmdMeta::net(src, secs, after.to_vec()).with_bytes(bytes))
    }

    /// All-gather: machine `i` streams its `shard_bytes[i]` shard to the
    /// other N−1 machines — one `Net` of `(N−1)·s_i` bytes per egress
    /// link, gated on `after[i]`. Returns the per-machine command ids; a
    /// consumer of the gathered buffer on any machine should wait on
    /// **all** of them. A 1-machine cluster gathers nothing.
    pub fn all_gather(&mut self, shard_bytes: &[u64], after: &[Vec<CmdId>]) -> Vec<CmdId> {
        let n = self.machines() as usize;
        assert_eq!(shard_bytes.len(), n, "one shard size per machine");
        assert_eq!(after.len(), n, "one dependency list per machine");
        if n == 1 {
            return Vec::new();
        }
        self.count_collective("cluster_all_gather_total");
        (0..n)
            .map(|i| self.net_send(i as u32, (n as u64 - 1) * shard_bytes[i], &after[i]))
            .collect()
    }

    /// Bump a collective-invocation counter (no-op without telemetry).
    fn count_collective(&self, name: &str) {
        if let Some(tel) = &self.telemetry {
            tel.counter_add(name, Labels::none(), 1);
        }
    }

    /// Reduce-scatter: machine `i` sends every contribution it does not
    /// own — one `Net` of `S − s_i` bytes per egress link (`S` = total).
    pub fn reduce_scatter(&mut self, shard_bytes: &[u64], after: &[Vec<CmdId>]) -> Vec<CmdId> {
        let n = self.machines() as usize;
        assert_eq!(shard_bytes.len(), n, "one shard size per machine");
        assert_eq!(after.len(), n, "one dependency list per machine");
        if n == 1 {
            return Vec::new();
        }
        self.count_collective("cluster_reduce_scatter_total");
        let total: u64 = shard_bytes.iter().sum();
        (0..n)
            .map(|i| self.net_send(i as u32, total - shard_bytes[i], &after[i]))
            .collect()
    }

    /// All-reduce: reduce-scatter, a per-machine host combine of the
    /// N−1 received contributions to its shard (`merge_ops[i]` scalar
    /// ops), then all-gather of the reduced shards. Returns the final
    /// all-gather ids (empty on one machine — nothing to reduce).
    pub fn all_reduce(
        &mut self,
        shard_bytes: &[u64],
        merge_ops: &[u64],
        after: &[Vec<CmdId>],
    ) -> Vec<CmdId> {
        let n = self.machines() as usize;
        assert_eq!(merge_ops.len(), n, "one merge-op count per machine");
        let rs = self.reduce_scatter(shard_bytes, after);
        if rs.is_empty() {
            return Vec::new();
        }
        // composes reduce-scatter + all-gather, so those counters tick too
        self.count_collective("cluster_all_reduce_total");
        let merges: Vec<Vec<CmdId>> = (0..n)
            .map(|i| {
                let recv = (n as u64 - 1) * shard_bytes[i];
                vec![self.host_merge(i as u32, recv, merge_ops[i], &rs)]
            })
            .collect();
        self.all_gather(shard_bytes, &merges)
    }

    /// Point-to-point sends `(src, dst, bytes)` (BFS frontier exchange).
    /// Each occupies its source's egress link in issue order; `dst` only
    /// validates — a flat switch's ingress is non-blocking. Returns one
    /// id per message, aligned with `msgs`.
    pub fn exchange(&mut self, msgs: &[(u32, u32, u64)], after: &[Vec<CmdId>]) -> Vec<CmdId> {
        assert_eq!(after.len(), self.machines() as usize, "one dependency list per machine");
        if !msgs.is_empty() {
            self.count_collective("cluster_exchange_total");
        }
        msgs.iter()
            .map(|&(src, dst, bytes)| {
                assert!(dst < self.machines(), "machine {dst} out of range");
                assert_ne!(src, dst, "a machine does not message itself");
                let deps = after[src as usize].clone();
                self.net_send(src, bytes, &deps)
            })
            .collect()
    }

    // ------------------------------------------------------------ sync

    /// Schedule the recorded program over every machine's bus / host /
    /// rank lanes plus the per-machine egress links, credit the derived
    /// overlap, advance the modeled clock by the makespan, and emit
    /// trace events (if a sink is installed). Returns the hidden
    /// seconds, like `PimSet::queue_sync`.
    pub fn sync(&mut self) -> f64 {
        assert!(!self.queue.group_open(), "sync with an open transfer group");
        if self.queue.is_empty() {
            return 0.0;
        }
        let n_ranks = self.machines() as usize * self.ranks_per_machine;
        let sched = self.queue.schedule(n_ranks, self.per);
        if let Some(sink) = self.trace.as_ref() {
            let base = self.clock;
            let id0 = sink.next_id();
            let lanes = self.queue.lanes(n_ranks, self.per);
            let deps = self.queue.dep_edges();
            for (i, cmd) in self.queue.cmds().iter().enumerate() {
                sink.push(TraceEvent {
                    id: 0, // assigned by the sink
                    kind: cmd.kind,
                    lane: lanes[i].clone().into(),
                    start: base + sched.start[i],
                    secs: cmd.secs,
                    bytes: cmd.bytes,
                    tenant: None,
                    req: cmd.req,
                    deps: deps[i].iter().map(|&j| id0 + j as u64).collect(),
                });
            }
        }
        if let Some(tel) = self.telemetry.as_ref() {
            let stats = self.queue.schedule_stats(&sched, n_ranks, self.per);
            tel.record_schedule(&stats, self.clock);
        }
        let hidden = sched.hidden();
        self.overlapped += hidden;
        self.clock += sched.makespan;
        self.queue.reset();
        hidden
    }

    /// Aggregate the per-machine breakdowns and the cluster-level
    /// schedule/network totals. (Call after `sync` — pending commands
    /// are not yet scheduled into the makespan.)
    pub fn report(&self) -> ClusterReport {
        let mut breakdown = TimeBreakdown::default();
        for set in &self.sets {
            breakdown.add(&set.metrics);
        }
        breakdown.overlapped = self.overlapped;
        ClusterReport {
            machines: self.machines(),
            breakdown,
            makespan: self.clock,
            net_secs: self.net_secs,
            net_bytes: self.net_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::executor::SerialExecutor;
    use crate::coordinator::trace::LaneTag;

    fn cluster(machines: u32, dpus: u32) -> Cluster {
        Cluster::new(
            ClusterConfig::new(SystemConfig::p21_rank(), machines, dpus),
            Arc::new(SerialExecutor),
        )
    }

    /// Two machines' pushes and launches occupy independent lanes, so
    /// the cluster schedule overlaps them — and each machine's fleet
    /// functionally executed its own data.
    #[test]
    fn machines_overlap_and_stay_functionally_isolated() {
        let mut c = cluster(2, 4);
        let sym = c.symbol::<i64>(64);
        let out = c.symbol::<i64>(1);
        for m in 0..2u32 {
            let bufs: Vec<Vec<i64>> =
                (0..4).map(|d| vec![(m as i64 + 1) * 100 + d as i64; 64]).collect();
            c.push_equal(m, Bucket::CpuDpu, sym, &bufs, &[]);
            let acc = Access::new().read(sym.region()).write(out.region());
            let (off, oout) = (sym.off(), out.off());
            c.launch_seq_acc(m, acc, 4, move |_d, ctx| {
                let w = ctx.mem_alloc(512);
                ctx.mram_read(off, w, 512);
                let v: Vec<i64> = ctx.wram_get(w, 64);
                let s: i64 = v.iter().sum();
                ctx.wram_set(w, &[s]);
                ctx.compute(10_000);
                ctx.mram_write(w, oout, 8);
            });
        }
        let hidden = c.sync();
        assert!(hidden > 0.0, "machine 1's work must hide under machine 0's");
        for m in 0..2u32 {
            let (vals, _) = c.pull_one(m, Bucket::DpuCpu, out, 1, 1, &[]);
            assert_eq!(vals[0], 64 * ((m as i64 + 1) * 100 + 1));
        }
        c.sync();
        let rep = c.report();
        assert_eq!(rep.machines, 2);
        assert!(rep.breakdown.dpu > 0.0 && rep.breakdown.cpu_dpu > 0.0);
        assert_eq!(rep.breakdown.overlapped.to_bits(), hidden.to_bits());
        assert!(rep.makespan > 0.0);
        assert_eq!(rep.net_bytes, 0, "no collective ran");
    }

    /// The modeled all-gather makespan is exactly the flat-switch bound
    /// `max_i((N−1)·s_i/B + L)` — bitwise, same float expression.
    #[test]
    fn all_gather_matches_flat_switch_bound_bitwise() {
        let mut c = cluster(4, 2);
        let shards = [1_000u64, 64_000, 7_000, 640];
        let after = vec![Vec::new(); 4];
        let ids = c.all_gather(&shards, &after);
        assert_eq!(ids.len(), 4);
        let net = c.cfg.net.clone();
        let bound = shards
            .iter()
            .map(|&s| net.xfer_secs(3 * s))
            .fold(0.0f64, f64::max);
        c.sync();
        let rep = c.report();
        assert_eq!(rep.makespan.to_bits(), bound.to_bits());
        assert_eq!(rep.net_bytes, shards.iter().map(|s| 3 * s).sum::<u64>());
    }

    /// All-reduce composes reduce-scatter → per-machine combine →
    /// all-gather, with the dependency chain serializing the stages.
    #[test]
    fn all_reduce_chains_scatter_merge_gather() {
        let mut c = cluster(3, 2);
        let shards = [4_096u64; 3];
        let ids = c.all_reduce(&shards, &[512; 3], &vec![Vec::new(); 3]);
        assert_eq!(ids.len(), 3);
        assert_eq!(c.pending(), 9, "3 scatters + 3 merges + 3 gathers");
        let net = c.cfg.net.clone();
        c.sync();
        let rep = c.report();
        // two serialized network stages: strictly longer than either alone
        assert!(rep.makespan > 2.0 * net.xfer_secs(2 * 4_096));
        assert!(rep.breakdown.inter_dpu > 0.0, "the combine runs on machine hosts");
        assert_eq!(rep.net_bytes, 2 * 3 * 2 * 4_096);
    }

    /// One machine is the degenerate cluster: collectives vanish and
    /// every recorded command stays on the legacy single-machine lanes.
    #[test]
    fn single_machine_cluster_uses_legacy_lanes_only() {
        let sink = TraceSink::new();
        let mut c = cluster(1, 2).with_trace(sink.clone());
        assert!(c.all_gather(&[1024], &[Vec::new()]).is_empty());
        assert!(c.all_reduce(&[1024], &[16], &[Vec::new()]).is_empty());
        let sym = c.symbol::<u32>(8);
        c.broadcast(0, Bucket::CpuDpu, sym, &[7u32; 8], &[]);
        let (_, pid) = c.pull_equal(0, Bucket::DpuCpu, sym, 8, &[]);
        c.host_merge(0, 64, 8, &[pid]);
        c.sync();
        let t = sink.snapshot();
        assert_eq!(t.source, "cluster");
        assert!(!t.events.is_empty());
        for e in &t.events {
            assert!(
                matches!(e.lane, LaneTag::Bus | LaneTag::Host | LaneTag::Ranks { .. }),
                "machine 0 must stay on legacy lanes, got {:?}",
                e.lane
            );
        }
        assert_eq!(c.report().net_bytes, 0);
    }

    /// Frontier-style exchange: sends serialize per egress link but
    /// overlap across links, and invalid targets are rejected.
    #[test]
    fn exchange_serializes_per_link_and_overlaps_across() {
        let mut c = cluster(3, 2);
        let b = 1 << 20;
        // machine 0 sends twice (serial); machines 1 and 2 once each
        let msgs = [(0u32, 1u32, b), (0, 2, b), (1, 0, b), (2, 1, b)];
        let ids = c.exchange(&msgs, &vec![Vec::new(); 3]);
        assert_eq!(ids.len(), 4);
        let net = c.cfg.net.clone();
        c.sync();
        let two = 2.0 * net.xfer_secs(b);
        assert_eq!(c.report().makespan.to_bits(), two.to_bits());
    }

    #[test]
    #[should_panic(expected = "does not message itself")]
    fn self_exchange_rejected() {
        let mut c = cluster(2, 2);
        c.exchange(&[(1, 1, 8)], &vec![Vec::new(); 2]);
    }
}
