//! Renamed to [`accounting`](super::accounting) — this module held the
//! `TimeBreakdown`/`Bucket` *time accounting* types, not telemetry. The
//! live telemetry subsystem (counters, gauges, histograms, SLO health)
//! lives in [`telemetry`](super::telemetry). This shim keeps old import
//! paths compiling.

pub use super::accounting::{Bucket, TimeBreakdown};
