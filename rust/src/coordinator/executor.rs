//! Fleet execution engine: how a launch (or transfer fan-out) walks the
//! allocated DPU set.
//!
//! On real UPMEM hardware the 2,048+ DPUs of the paper's headline
//! experiments (Figs. 11–16, Table 3) execute *concurrently*; the modeled
//! seconds already account for that (`max` over per-DPU cycles). This
//! module makes the **simulator wallclock** concurrent too: a
//! [`FleetExecutor`] abstracts the per-DPU iteration so the hottest loop
//! in the codebase runs either serially ([`SerialExecutor`], the
//! determinism/debugging baseline) or sharded across host cores
//! ([`ParallelExecutor`]).
//!
//! # Determinism contract
//!
//! Both executors are **bit-identical** by construction:
//!
//! * every DPU owns its private MRAM/WRAM, and kernels may only capture
//!   host data by shared reference (`Fn(usize, &mut Ctx) + Sync`), so the
//!   functional result of a DPU does not depend on when its neighbours
//!   run;
//! * per-DPU timings are produced by the trace replay, a pure function of
//!   that DPU's traces;
//! * the parallel path shards the slot vector into *contiguous* chunks
//!   and re-assembles the per-shard timing vectors in shard order, so the
//!   merged `Vec<DpuTiming>` is in DPU-index order — exactly the serial
//!   ordering — and every downstream fold (`max` for `LaunchStats::secs`,
//!   sums for instruction counts) sees operands in the same order.
//!
//! `rust/tests/executor_equivalence.rs` pins this contract for the
//! no-sync (VA), intra-DPU-sync (RED) and inter-DPU-sync (BFS) workload
//! classes.

use crate::dpu::{Ctx, Dpu, DpuTiming};
use std::sync::Arc;

/// One unit of fleet work: a global DPU index plus exclusive access to
/// that DPU.
pub type FleetSlot<'a> = (usize, &'a mut Dpu);

// Compile-time pin of the Send audit: fleet slots carry `&mut Dpu` across
// worker threads, so `Dpu` (arch params + MRAM bank) and the timing
// results must stay `Send`. Per-DPU RNG state does not exist (the host
// `Rng` runs before launches) and trace buffers live inside `Ctx`, which
// never crosses the executor boundary.
fn _assert_send<T: Send>() {}
fn _executor_send_audit() {
    _assert_send::<Dpu>();
    _assert_send::<DpuTiming>();
    _assert_send::<FleetSlot<'_>>();
}

/// A kernel launch request, shared (read-only) by all executor workers.
pub struct LaunchJob<'k> {
    /// The SPMD kernel: `f(dpu_idx, ctx)`.
    pub kernel: &'k (dyn Fn(usize, &mut Ctx) + Sync),
    /// Tasklets per DPU.
    pub n_tasklets: u32,
    /// Use the sequential tasklet fast path ([`Dpu::launch_seq`])
    /// instead of one OS thread per tasklet ([`Dpu::launch`]).
    pub seq_tasklets: bool,
}

impl LaunchJob<'_> {
    /// Run the job on one DPU and return its replayed timing.
    fn run_one(&self, idx: usize, dpu: &mut Dpu) -> DpuTiming {
        let g = |ctx: &mut Ctx| (self.kernel)(idx, ctx);
        let run = if self.seq_tasklets {
            dpu.launch_seq(&g, self.n_tasklets)
        } else {
            dpu.launch(&g, self.n_tasklets)
        };
        run.timing
    }
}

/// Strategy for walking a set of fleet slots.
///
/// Implementations must return timings **in slot order** and must call
/// `op`/the kernel exactly once per slot; beyond that they are free to
/// schedule the slots on any number of host threads (each slot holds
/// exclusive access to its DPU, so slots never alias).
pub trait FleetExecutor: Send + Sync {
    /// Short name for logs/benches ("serial" / "parallel").
    fn name(&self) -> &'static str;

    /// Launch `job` on every slot; per-DPU timings in slot order.
    fn launch(&self, slots: &mut [FleetSlot<'_>], job: &LaunchJob<'_>) -> Vec<DpuTiming>;

    /// Apply `op` to every slot (the transfer fan-out primitive).
    fn for_each(&self, slots: &mut [FleetSlot<'_>], op: &(dyn Fn(usize, &mut Dpu) + Sync));
}

/// The original single-threaded walk: slots in order, on the calling
/// thread. Kept as the determinism baseline and for debugging (panics
/// surface with an undisturbed stack, no shard boundaries).
#[derive(Clone, Copy, Debug, Default)]
pub struct SerialExecutor;

impl FleetExecutor for SerialExecutor {
    fn name(&self) -> &'static str {
        "serial"
    }

    fn launch(&self, slots: &mut [FleetSlot<'_>], job: &LaunchJob<'_>) -> Vec<DpuTiming> {
        slots.iter_mut().map(|(i, dpu)| job.run_one(*i, dpu)).collect()
    }

    fn for_each(&self, slots: &mut [FleetSlot<'_>], op: &(dyn Fn(usize, &mut Dpu) + Sync)) {
        for (i, dpu) in slots.iter_mut() {
            op(*i, dpu);
        }
    }
}

/// Shards the slot vector into contiguous chunks, one scoped thread per
/// chunk, and merges per-shard results deterministically by slot order.
///
/// Fleet wallclock drops from O(n_dpus) to O(n_dpus / cores); the modeled
/// seconds are unchanged (see the module-level determinism contract).
///
/// Worker sizing is one shard per host core even for the threaded
/// [`Dpu::launch`] path (where each DPU additionally spawns `n_tasklets`
/// OS threads): those tasklet threads serialize on their *own* DPU's
/// WRAM/MRAM mutexes, so per-DPU contention is independent of the shard
/// count and the extra threads are mostly parked — one shard keeps
/// roughly one core busy. Cap the pool explicitly with
/// `ParallelExecutor::new(n)` / `PRIM_THREADS=n` if the host is shared.
#[derive(Clone, Copy, Debug)]
pub struct ParallelExecutor {
    /// Worker-thread cap; 0 = one worker per available host core.
    pub threads: usize,
}

impl ParallelExecutor {
    pub fn new(threads: usize) -> Self {
        ParallelExecutor { threads }
    }

    /// Workers to actually spawn for `n_items` slots.
    fn workers(&self, n_items: usize) -> usize {
        let cap = if self.threads > 0 {
            self.threads
        } else {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        };
        cap.min(n_items).max(1)
    }
}

impl Default for ParallelExecutor {
    fn default() -> Self {
        ParallelExecutor::new(0)
    }
}

impl FleetExecutor for ParallelExecutor {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn launch(&self, slots: &mut [FleetSlot<'_>], job: &LaunchJob<'_>) -> Vec<DpuTiming> {
        let n = slots.len();
        let workers = self.workers(n);
        if workers <= 1 {
            return SerialExecutor.launch(slots, job);
        }
        let chunk = n.div_ceil(workers);
        // Deterministic merge without a merge: each shard writes its
        // timings straight into its contiguous slice of one preallocated
        // output vector, so the result is in slot order by construction
        // and the per-shard `Vec` allocations + post-join copy are gone.
        let mut timings = vec![DpuTiming::default(); n];
        std::thread::scope(|scope| {
            let mut out_rest: &mut [DpuTiming] = &mut timings;
            let mut handles = Vec::with_capacity(workers);
            for shard in slots.chunks_mut(chunk) {
                let (out_shard, rest) = std::mem::take(&mut out_rest).split_at_mut(shard.len());
                out_rest = rest;
                handles.push(scope.spawn(move || {
                    for ((i, dpu), out) in shard.iter_mut().zip(out_shard) {
                        *out = job.run_one(*i, dpu);
                    }
                }));
            }
            for h in handles {
                h.join().unwrap_or_else(|e| std::panic::resume_unwind(e));
            }
        });
        timings
    }

    fn for_each(&self, slots: &mut [FleetSlot<'_>], op: &(dyn Fn(usize, &mut Dpu) + Sync)) {
        let workers = self.workers(slots.len());
        if workers <= 1 {
            SerialExecutor.for_each(slots, op);
            return;
        }
        let chunk = slots.len().div_ceil(workers);
        std::thread::scope(|scope| {
            for shard in slots.chunks_mut(chunk) {
                scope.spawn(move || {
                    for (i, dpu) in shard.iter_mut() {
                        op(*i, dpu);
                    }
                });
            }
        });
    }
}

/// Executor selection carried by `prim::common::RunConfig` (and anything
/// else that allocates a `PimSet`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecChoice {
    /// Resolve from the environment: `PRIM_EXECUTOR=serial|parallel`,
    /// `PRIM_THREADS=N` (unset → parallel over all cores).
    #[default]
    Auto,
    Serial,
    /// Parallel with a worker cap; 0 = all available cores.
    Parallel(usize),
}

impl ExecChoice {
    /// Parse an executor-name / thread-count pair (the `PRIM_EXECUTOR` /
    /// `PRIM_THREADS` environment contract and the CLI's `--executor` /
    /// `--threads` flags). **Strict**: an unknown executor name or an
    /// unparsable thread count is an error — values used to fall through
    /// silently to the parallel default, hiding typos. Unset fields keep
    /// their defaults (parallel, all cores).
    pub fn parse(executor: Option<&str>, threads: Option<&str>) -> Result<Self, String> {
        let threads = match threads.map(str::trim) {
            None => 0,
            Some(v) => v.parse::<usize>().map_err(|_| {
                format!("invalid value '{v}' for the thread count (expected a usize)")
            })?,
        };
        match executor.map(str::trim) {
            None => Ok(ExecChoice::Parallel(threads)),
            Some(s) if s.eq_ignore_ascii_case("serial") => Ok(ExecChoice::Serial),
            Some(s) if s.eq_ignore_ascii_case("parallel") => Ok(ExecChoice::Parallel(threads)),
            Some(s) => Err(format!(
                "unknown executor '{s}' (expected serial|parallel)"
            )),
        }
    }

    /// Resolve from the process environment (never returns `Auto`).
    /// Malformed `PRIM_EXECUTOR` / `PRIM_THREADS` values exit with
    /// status 2, matching the CLI's strict numeric-flag parsing.
    pub fn from_env() -> Self {
        let executor = std::env::var("PRIM_EXECUTOR").ok();
        let threads = std::env::var("PRIM_THREADS").ok();
        Self::parse(executor.as_deref(), threads.as_deref()).unwrap_or_else(|e| {
            eprintln!("PRIM_EXECUTOR/PRIM_THREADS: {e}");
            std::process::exit(2);
        })
    }

    /// Build the chosen executor.
    pub fn build(self) -> Arc<dyn FleetExecutor> {
        match self {
            ExecChoice::Auto => Self::from_env().build(),
            ExecChoice::Serial => Arc::new(SerialExecutor),
            ExecChoice::Parallel(threads) => Arc::new(ParallelExecutor::new(threads)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::DpuArch;

    fn fleet(n: usize) -> Vec<Dpu> {
        (0..n).map(|_| Dpu::new(DpuArch::p21())).collect()
    }

    fn timings_with(exec: &dyn FleetExecutor, dpus: &mut [Dpu]) -> Vec<DpuTiming> {
        let kernel = |i: usize, ctx: &mut Ctx| {
            ctx.compute(100 * (i as u64 + 1));
        };
        let job = LaunchJob {
            kernel: &kernel,
            n_tasklets: 2,
            seq_tasklets: true,
        };
        let mut slots: Vec<FleetSlot<'_>> = dpus.iter_mut().enumerate().collect();
        exec.launch(&mut slots, &job)
    }

    #[test]
    fn parallel_matches_serial_bitwise() {
        let mut a = fleet(13);
        let mut b = fleet(13);
        let ts = timings_with(&SerialExecutor, &mut a);
        let tp = timings_with(&ParallelExecutor::new(4), &mut b);
        assert_eq!(ts.len(), tp.len());
        for (s, p) in ts.iter().zip(&tp) {
            assert_eq!(s.cycles.to_bits(), p.cycles.to_bits());
            assert_eq!(s.instrs, p.instrs);
            assert_eq!(s.dma_bytes, p.dma_bytes);
        }
    }

    #[test]
    fn parallel_for_each_touches_every_slot_once() {
        let mut dpus = fleet(9);
        let exec = ParallelExecutor::new(3);
        let mut slots: Vec<FleetSlot<'_>> = dpus.iter_mut().enumerate().collect();
        exec.for_each(&mut slots, &|i, dpu| {
            dpu.mram_store(0, &[i as i64 + 1]);
        });
        for (i, d) in dpus.iter().enumerate() {
            assert_eq!(d.mram_load::<i64>(0, 1), vec![i as i64 + 1]);
        }
    }

    #[test]
    fn worker_count_clamps() {
        let e = ParallelExecutor::new(8);
        assert_eq!(e.workers(3), 3);
        assert_eq!(e.workers(100), 8);
        assert!(ParallelExecutor::new(0).workers(100) >= 1);
    }

    #[test]
    fn choice_parsing_is_strict() {
        assert_eq!(ExecChoice::parse(Some("serial"), None), Ok(ExecChoice::Serial));
        assert_eq!(ExecChoice::parse(Some("SERIAL"), Some("4")), Ok(ExecChoice::Serial));
        assert_eq!(
            ExecChoice::parse(Some("parallel"), Some("4")),
            Ok(ExecChoice::Parallel(4))
        );
        assert_eq!(ExecChoice::parse(None, None), Ok(ExecChoice::Parallel(0)));
        assert_eq!(ExecChoice::parse(None, Some(" 7 ")), Ok(ExecChoice::Parallel(7)));
        // typos no longer fall through to the parallel default
        let bad_name = ExecChoice::parse(Some("bogus"), None);
        assert!(bad_name.is_err());
        assert!(bad_name.unwrap_err().contains("serial|parallel"));
        let bad_threads = ExecChoice::parse(Some("parallel"), Some("x"));
        assert!(bad_threads.is_err());
        assert!(bad_threads.unwrap_err().contains("thread count"));
        assert!(ExecChoice::parse(None, Some("-3")).is_err());
    }

    #[test]
    fn names() {
        assert_eq!(SerialExecutor.name(), "serial");
        assert_eq!(ParallelExecutor::default().name(), "parallel");
    }
}
