//! Live telemetry: a typed, labeled metrics registry plus SLO health —
//! the sensor substrate the serving stack exposes while it runs.
//!
//! The paper's whole method is measurement (§5 decomposes every run into
//! bandwidth, kernel, and transfer components); this module gives the
//! *serving* layers the same treatment continuously instead of post-hoc.
//! Three metric types — [`Counter`](MetricValue::Counter) (monotonic
//! events/bytes), [`Gauge`](MetricValue::Gauge) (levels and accumulated
//! seconds), and [`Histogram`] (log-bucketed, mergeable distributions) —
//! plus time [`Series`](MetricValue::Series) are registered in one
//! registry under a fixed label set ([`Labels`]: `bench`, `lane`,
//! `machine`, `tenant`).
//!
//! **Determinism.** Series points are sampled at *simulated-time* ticks
//! of the shared `Timeline` (scheduler loop instants, queue schedule
//! event times) — never wall clock — and the registry is keyed by a
//! `BTreeMap` over `(name, labels)`, so every executor and every seed
//! produces byte-identical snapshots. All instrumentation sites run on
//! the coordinator thread; the parallel executor's workers never touch
//! the registry.
//!
//! **Zero cost when off.** The handle is threaded as `Option<Telemetry>`
//! (exactly like `TraceSink`); every call site is gated on `Some`, and
//! instrumentation only *reads* modeled values, so a run with telemetry
//! disabled is bit-identical to one that never had the subsystem
//! (regression-pinned in `tests/telemetry.rs`).
//!
//! Snapshots export two ways: Prometheus text exposition
//! ([`MetricsSnapshot::to_prometheus`]) and a native `metrics/v1` JSON
//! ([`MetricsSnapshot::to_json`]) whose serialize→parse→serialize is the
//! byte identity (same `{:e}` float discipline as `trace/v1`). The
//! [`SloMonitor`] evaluates per-tenant targets over sliding windows of
//! the sampled series into a [`HealthReport`].

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::{Arc, Mutex};

use crate::util::json::{parse_json, Value};
use crate::util::stats::{nearest_rank, percentile};

use super::queue::{Lane, ScheduleStats};

// ------------------------------------------------------------------ labels

/// The fixed label set of every metric. Cardinality discipline: labels
/// only take values from small, bounded domains (tenant names, lane
/// names, machine indices, bench names) — never request ids or
/// timestamps — so the registry stays O(tenants × lanes) however long
/// the run. `Ord` on the struct (field order = alphabetical key order)
/// is the registry's deterministic sort.
#[derive(Clone, Debug, Default, PartialEq, Eq, PartialOrd, Ord)]
pub struct Labels {
    /// Workload name (`gemv`, `bfs`, …).
    pub bench: Option<String>,
    /// Modeled resource lane (`bus`, `host`, `ranks:0-4`, `link:2`, …).
    pub lane: Option<String>,
    /// Machine index in a cluster.
    pub machine: Option<u32>,
    /// Tenant name in the multi-tenant scheduler.
    pub tenant: Option<String>,
}

impl Labels {
    /// The empty label set (fleet-global metrics).
    pub fn none() -> Labels {
        Labels::default()
    }

    /// Label by tenant name.
    pub fn tenant(name: &str) -> Labels {
        Labels {
            tenant: Some(name.to_string()),
            ..Labels::default()
        }
    }

    /// Label by workload name.
    pub fn bench(name: &str) -> Labels {
        Labels {
            bench: Some(name.to_string()),
            ..Labels::default()
        }
    }

    /// Label by modeled resource lane.
    pub fn lane(lane: &Lane) -> Labels {
        Labels {
            lane: Some(lane_label(lane)),
            ..Labels::default()
        }
    }

    /// Add a bench label to an existing set.
    pub fn with_bench(mut self, name: &str) -> Labels {
        self.bench = Some(name.to_string());
        self
    }

    /// Add a machine label to an existing set.
    pub fn with_machine(mut self, m: u32) -> Labels {
        self.machine = Some(m);
        self
    }

    /// `{key="value",…}` in alphabetical key order, or `""` when empty —
    /// the Prometheus exposition form.
    fn prom(&self) -> String {
        let mut parts: Vec<String> = Vec::new();
        if let Some(b) = &self.bench {
            parts.push(format!("bench=\"{b}\""));
        }
        if let Some(l) = &self.lane {
            parts.push(format!("lane=\"{l}\""));
        }
        if let Some(m) = self.machine {
            parts.push(format!("machine=\"{m}\""));
        }
        if let Some(t) = &self.tenant {
            parts.push(format!("tenant=\"{t}\""));
        }
        if parts.is_empty() {
            String::new()
        } else {
            format!("{{{}}}", parts.join(","))
        }
    }
}

/// Stable string name of a [`Lane`] for the `lane` label (mirrors the
/// track naming of the Chrome-trace export).
pub fn lane_label(lane: &Lane) -> String {
    match lane {
        Lane::Bus => "bus".to_string(),
        Lane::Host => "host".to_string(),
        Lane::Ranks(r) => format!("ranks:{}-{}", r.start, r.end),
        Lane::MachineBus(m) => format!("bus:{m}"),
        Lane::MachineHost(m) => format!("host:{m}"),
        Lane::Link(m) => format!("link:{m}"),
    }
}

// --------------------------------------------------------------- histogram

/// Buckets are quarter-powers-of-two: a value lands in the bucket whose
/// upper bound is the smallest `2^(i/4) ≥ v`. Clamped so degenerate
/// values can't mint unbounded bucket indices.
const BUCKET_CLAMP: i32 = 4096;

/// A log-bucketed, mergeable distribution. Bucket boundaries are
/// quarter-powers-of-two (resolution ≤ 19% everywhere), so merging two
/// histograms is exact bucket-count addition and quantiles are accurate
/// to one bucket. Values that are exact powers of two sit exactly on a
/// bucket bound, which is what lets `quantile` agree bit-for-bit with
/// `util::stats::latency_summary` on such inputs (regression-pinned).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Histogram {
    /// Bucket index → observation count (sorted by construction).
    buckets: BTreeMap<i32, u64>,
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
}

impl Histogram {
    /// Bucket index of a value: smallest `i` with `2^(i/4) ≥ v`.
    /// Non-positive values share the lowest bucket; NaN is the caller's
    /// problem ([`observe`](Histogram::observe) guards it).
    pub fn bucket_index(v: f64) -> i32 {
        if v <= 0.0 {
            return -BUCKET_CLAMP;
        }
        let i = (4.0 * v.log2()).ceil();
        (i as i32).clamp(-BUCKET_CLAMP, BUCKET_CLAMP)
    }

    /// Upper bound of bucket `i`: `2^(i/4)`.
    pub fn bucket_upper(i: i32) -> f64 {
        (i as f64 / 4.0).exp2()
    }

    /// Record one observation. NaN observations are dropped (the
    /// NaN-guard path shared with `util::stats`, where `total_cmp` sorts
    /// NaN last so it never lands in p50/p95/p99 either).
    pub fn observe(&mut self, v: f64) {
        if v.is_nan() {
            return;
        }
        *self.buckets.entry(Self::bucket_index(v)).or_insert(0) += 1;
        if self.count == 0 {
            self.min = v;
            self.max = v;
        } else {
            self.min = self.min.min(v);
            self.max = self.max.max(v);
        }
        self.count += 1;
        self.sum += v;
    }

    /// Exact bucket-count merge of another histogram (the property that
    /// makes per-shard histograms aggregatable).
    pub fn merge(&mut self, other: &Histogram) {
        for (&i, &n) in &other.buckets {
            *self.buckets.entry(i).or_insert(0) += n;
        }
        if other.count > 0 {
            if self.count == 0 {
                self.min = other.min;
                self.max = other.max;
            } else {
                self.min = self.min.min(other.min);
                self.max = self.max.max(other.max);
            }
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// Quantile `p` in [0,100] by the **same nearest-rank formula** as
    /// `util::stats::percentile` ([`nearest_rank`]): walk buckets in
    /// order to the one holding the rank-th smallest observation and
    /// report its upper bound (clamped to the observed max, so the top
    /// bucket doesn't overshoot).
    pub fn quantile(&self, p: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let rank = nearest_rank(self.count as usize, p) as u64;
        let mut cum = 0u64;
        for (&i, &n) in &self.buckets {
            cum += n;
            if cum > rank {
                return Self::bucket_upper(i).min(self.max);
            }
        }
        self.max
    }

    /// Number of observations.
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of observations.
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Smallest observation (0 when empty).
    pub fn min(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.min
        }
    }

    /// Largest observation (0 when empty).
    pub fn max(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.max
        }
    }

    /// `(upper_bound, count)` per occupied bucket, ascending.
    pub fn buckets(&self) -> impl Iterator<Item = (f64, u64)> + '_ {
        self.buckets.iter().map(|(&i, &n)| (Self::bucket_upper(i), n))
    }

    fn from_parts(count: u64, sum: f64, min: f64, max: f64, buckets: BTreeMap<i32, u64>) -> Self {
        Histogram {
            buckets,
            count,
            sum,
            min,
            max,
        }
    }
}

// ---------------------------------------------------------------- registry

/// One metric's current value.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    /// Monotonic event/byte count.
    Counter(u64),
    /// A level or an accumulated quantity (seconds, joules).
    Gauge(f64),
    /// A log-bucketed distribution.
    Histogram(Histogram),
    /// `(simulated_time, value)` samples, appended in simulation order.
    Series(Vec<(f64, f64)>),
}

impl MetricValue {
    fn type_name(&self) -> &'static str {
        match self {
            MetricValue::Counter(_) => "counter",
            MetricValue::Gauge(_) => "gauge",
            MetricValue::Histogram(_) => "histogram",
            MetricValue::Series(_) => "series",
        }
    }
}

#[derive(Debug, Default)]
struct Registry {
    metrics: BTreeMap<(String, Labels), MetricValue>,
}

/// The cloneable telemetry handle threaded through the stack as
/// `Option<Telemetry>` (the `TraceSink` pattern). All mutation goes
/// through a `Mutex`, but every instrumentation site runs on the
/// coordinator thread, so lock order — and therefore registry content —
/// is deterministic.
#[derive(Clone, Debug, Default)]
pub struct Telemetry {
    inner: Arc<Mutex<Registry>>,
}

impl Telemetry {
    /// A fresh, empty registry.
    pub fn new() -> Telemetry {
        Telemetry::default()
    }

    fn with<R>(&self, f: impl FnOnce(&mut Registry) -> R) -> R {
        f(&mut self.inner.lock().unwrap())
    }

    /// Add `delta` to a counter (created at 0).
    pub fn counter_add(&self, name: &str, labels: Labels, delta: u64) {
        self.with(|r| {
            match r
                .metrics
                .entry((name.to_string(), labels))
                .or_insert(MetricValue::Counter(0))
            {
                MetricValue::Counter(c) => *c += delta,
                other => panic!("metric '{name}' is a {}, not a counter", other.type_name()),
            }
        });
    }

    /// Set a gauge to `v`.
    pub fn gauge_set(&self, name: &str, labels: Labels, v: f64) {
        self.with(|r| {
            match r
                .metrics
                .entry((name.to_string(), labels))
                .or_insert(MetricValue::Gauge(0.0))
            {
                MetricValue::Gauge(g) => *g = v,
                other => panic!("metric '{name}' is a {}, not a gauge", other.type_name()),
            }
        });
    }

    /// Accumulate `v` into a gauge (for modeled-seconds totals).
    pub fn gauge_add(&self, name: &str, labels: Labels, v: f64) {
        self.with(|r| {
            match r
                .metrics
                .entry((name.to_string(), labels))
                .or_insert(MetricValue::Gauge(0.0))
            {
                MetricValue::Gauge(g) => *g += v,
                other => panic!("metric '{name}' is a {}, not a gauge", other.type_name()),
            }
        });
    }

    /// Raise a gauge to `v` if larger (peak tracking).
    pub fn gauge_max(&self, name: &str, labels: Labels, v: f64) {
        self.with(|r| {
            match r
                .metrics
                .entry((name.to_string(), labels))
                .or_insert(MetricValue::Gauge(v))
            {
                MetricValue::Gauge(g) => *g = g.max(v),
                other => panic!("metric '{name}' is a {}, not a gauge", other.type_name()),
            }
        });
    }

    /// Record an observation into a histogram.
    pub fn observe(&self, name: &str, labels: Labels, v: f64) {
        self.with(|r| {
            match r
                .metrics
                .entry((name.to_string(), labels))
                .or_insert_with(|| MetricValue::Histogram(Histogram::default()))
            {
                MetricValue::Histogram(h) => h.observe(v),
                other => panic!("metric '{name}' is a {}, not a histogram", other.type_name()),
            }
        });
    }

    /// Append a `(simulated_time, value)` point to a series. `t` must be
    /// a simulated-time instant off the shared `Timeline` — never wall
    /// clock — so snapshots are executor- and host-independent.
    pub fn sample(&self, name: &str, labels: Labels, t: f64, v: f64) {
        self.with(|r| {
            match r
                .metrics
                .entry((name.to_string(), labels))
                .or_insert_with(|| MetricValue::Series(Vec::new()))
            {
                MetricValue::Series(s) => s.push((t, v)),
                other => panic!("metric '{name}' is a {}, not a series", other.type_name()),
            }
        });
    }

    /// Fold one command-queue schedule into the registry: per-lane busy
    /// seconds and command counts, dep-stall counts, hidden (overlapped)
    /// seconds, and the in-flight command series at `base`-offset
    /// simulated times. Called once per `queue_sync` — post-hoc from the
    /// finished [`ScheduleStats`], never from inside the scheduling loop.
    pub fn record_schedule(&self, stats: &ScheduleStats, base: f64) {
        for (lane, u) in &stats.lanes {
            let l = Labels::lane(lane);
            self.gauge_add("queue_lane_busy_secs", l.clone(), u.busy);
            self.counter_add("queue_lane_cmds", l, u.cmds);
        }
        self.counter_add("queue_syncs", Labels::none(), 1);
        self.counter_add("queue_dep_stalls", Labels::none(), stats.dep_stalls);
        self.gauge_add("queue_span_secs", Labels::none(), stats.makespan);
        self.gauge_add("queue_hidden_secs", Labels::none(), stats.hidden);
        self.gauge_max(
            "queue_peak_inflight",
            Labels::none(),
            stats.peak_inflight as f64,
        );
        for &(t, n) in &stats.inflight {
            self.sample("queue_inflight", Labels::none(), base + t, n as f64);
        }
    }

    /// Last `k` points of a series, oldest first (empty when the series
    /// doesn't exist or holds another metric type). This is the read
    /// side the elastic policy consumes: it windows the tail of the
    /// queue-depth / latency series the scheduler already samples
    /// rather than inventing private counters.
    pub fn series_tail(&self, name: &str, labels: &Labels, k: usize) -> Vec<(f64, f64)> {
        self.with(|r| {
            match r.metrics.get(&(name.to_string(), labels.clone())) {
                Some(MetricValue::Series(s)) => s[s.len().saturating_sub(k)..].to_vec(),
                _ => Vec::new(),
            }
        })
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.with(|r| r.metrics.is_empty())
    }

    /// A deterministic snapshot: entries sorted by `(name, labels)`.
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.with(|r| MetricsSnapshot {
            entries: r
                .metrics
                .iter()
                .map(|((name, labels), value)| MetricEntry {
                    name: name.clone(),
                    labels: labels.clone(),
                    value: value.clone(),
                })
                .collect(),
        })
    }
}

// ---------------------------------------------------------------- snapshot

/// One named, labeled metric in a snapshot.
#[derive(Clone, Debug, PartialEq)]
pub struct MetricEntry {
    pub name: String,
    pub labels: Labels,
    pub value: MetricValue,
}

/// An immutable, sorted view of the registry — the unit of export.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct MetricsSnapshot {
    /// Sorted by `(name, labels)`.
    pub entries: Vec<MetricEntry>,
}

/// `{:e}` — the shortest-roundtrip float form shared with `trace/v1`;
/// `parse_json` reads it back bit-identically, which is what makes
/// serialize→parse→serialize the byte identity.
fn fnum(x: f64) -> String {
    format!("{x:e}")
}

impl MetricsSnapshot {
    /// Native `metrics/v1` JSON. One metric per line; floats in `{:e}`;
    /// serialize→parse→serialize is the byte identity (pinned in tests).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"schema\": \"metrics/v1\",\n  \"metrics\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            s.push_str("    {");
            let _ = write!(s, "\"name\": \"{}\", \"labels\": {{", e.name);
            let mut lab: Vec<String> = Vec::new();
            if let Some(b) = &e.labels.bench {
                lab.push(format!("\"bench\": \"{b}\""));
            }
            if let Some(l) = &e.labels.lane {
                lab.push(format!("\"lane\": \"{l}\""));
            }
            if let Some(m) = e.labels.machine {
                lab.push(format!("\"machine\": {m}"));
            }
            if let Some(t) = &e.labels.tenant {
                lab.push(format!("\"tenant\": \"{t}\""));
            }
            s.push_str(&lab.join(", "));
            let _ = write!(s, "}}, \"type\": \"{}\", ", e.value.type_name());
            match &e.value {
                MetricValue::Counter(c) => {
                    let _ = write!(s, "\"value\": {c}");
                }
                MetricValue::Gauge(g) => {
                    let _ = write!(s, "\"value\": {}", fnum(*g));
                }
                MetricValue::Histogram(h) => {
                    let _ = write!(
                        s,
                        "\"count\": {}, \"sum\": {}, \"min\": {}, \"max\": {}, \"buckets\": [",
                        h.count(),
                        fnum(h.sum()),
                        fnum(h.min()),
                        fnum(h.max())
                    );
                    let n_buckets = h.buckets.len();
                    for (j, (&bi, &bn)) in h.buckets.iter().enumerate() {
                        let _ = write!(s, "{{\"i\": {bi}, \"n\": {bn}}}");
                        if j + 1 < n_buckets {
                            s.push_str(", ");
                        }
                    }
                    s.push(']');
                }
                MetricValue::Series(pts) => {
                    s.push_str("\"points\": [");
                    for (j, (t, v)) in pts.iter().enumerate() {
                        let _ = write!(s, "[{}, {}]", fnum(*t), fnum(*v));
                        if j + 1 < pts.len() {
                            s.push_str(", ");
                        }
                    }
                    s.push(']');
                }
            }
            s.push('}');
            s.push_str(if i + 1 < self.entries.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Prometheus text exposition. Histograms become cumulative
    /// `_bucket{le=…}` / `_sum` / `_count` families; series expose their
    /// latest value as a gauge (the full series lives in `metrics/v1`).
    pub fn to_prometheus(&self) -> String {
        let mut s = String::new();
        let mut last_name = "";
        for e in &self.entries {
            if e.name != last_name {
                let t = match &e.value {
                    MetricValue::Counter(_) => "counter",
                    MetricValue::Histogram(_) => "histogram",
                    _ => "gauge",
                };
                let _ = writeln!(s, "# TYPE {} {}", e.name, t);
                last_name = &e.name;
            }
            let lab = e.labels.prom();
            match &e.value {
                MetricValue::Counter(c) => {
                    let _ = writeln!(s, "{}{} {}", e.name, lab, c);
                }
                MetricValue::Gauge(g) => {
                    let _ = writeln!(s, "{}{} {}", e.name, lab, fnum(*g));
                }
                MetricValue::Histogram(h) => {
                    let mut cum = 0u64;
                    for (upper, n) in h.buckets() {
                        cum += n;
                        let mut le_labels = e.labels.prom();
                        let le = format!("le=\"{}\"", fnum(upper));
                        if le_labels.is_empty() {
                            le_labels = format!("{{{le}}}");
                        } else {
                            le_labels.insert_str(le_labels.len() - 1, &format!(",{le}"));
                        }
                        let _ = writeln!(s, "{}_bucket{} {}", e.name, le_labels, cum);
                    }
                    let mut inf_labels = e.labels.prom();
                    if inf_labels.is_empty() {
                        inf_labels = "{le=\"+Inf\"}".to_string();
                    } else {
                        inf_labels.insert_str(inf_labels.len() - 1, ",le=\"+Inf\"");
                    }
                    let _ = writeln!(s, "{}_bucket{} {}", e.name, inf_labels, h.count());
                    let _ = writeln!(s, "{}_sum{} {}", e.name, lab, fnum(h.sum()));
                    let _ = writeln!(s, "{}_count{} {}", e.name, lab, h.count());
                }
                MetricValue::Series(pts) => {
                    let v = pts.last().map(|&(_, v)| v).unwrap_or(0.0);
                    let _ = writeln!(s, "{}{} {}", e.name, lab, fnum(v));
                }
            }
        }
        s
    }

    /// All `(time, value)` points of the series `name` for `tenant`.
    fn series(&self, name: &str, tenant: &str) -> Option<&[(f64, f64)]> {
        self.entries
            .iter()
            .find(|e| e.name == name && e.labels.tenant.as_deref() == Some(tenant))
            .and_then(|e| match &e.value {
                MetricValue::Series(p) => Some(p.as_slice()),
                _ => None,
            })
    }

    /// A gauge's value for `tenant` (None when absent).
    fn tenant_gauge(&self, name: &str, tenant: &str) -> Option<f64> {
        self.entries
            .iter()
            .find(|e| e.name == name && e.labels.tenant.as_deref() == Some(tenant))
            .and_then(|e| match &e.value {
                MetricValue::Gauge(g) => Some(*g),
                _ => None,
            })
    }

    /// Tenant names that appear in any label, sorted (snapshot order).
    pub fn tenants(&self) -> Vec<String> {
        let mut out: Vec<String> = Vec::new();
        for e in &self.entries {
            if let Some(t) = &e.labels.tenant {
                if !out.contains(t) {
                    out.push(t.clone());
                }
            }
        }
        out.sort();
        out
    }
}

fn field<'v>(obj: &'v Value, key: &str) -> Result<&'v Value, String> {
    obj.get(key).ok_or_else(|| format!("missing field '{key}'"))
}

fn num(obj: &Value, key: &str) -> Result<f64, String> {
    field(obj, key)?
        .as_f64()
        .ok_or_else(|| format!("field '{key}' is not a number"))
}

fn opt_str(obj: &Value, key: &str) -> Option<String> {
    obj.get(key).and_then(|v| v.as_str()).map(str::to_string)
}

/// Parse a native `metrics/v1` document back into a snapshot. Rejects
/// other schemas loudly; floats come back bit-identical to what
/// [`MetricsSnapshot::to_json`] wrote.
pub fn parse_metrics(src: &str) -> Result<MetricsSnapshot, String> {
    let v = parse_json(src)?;
    let schema = field(&v, "schema")?
        .as_str()
        .ok_or("schema is not a string")?;
    if schema != "metrics/v1" {
        return Err(format!("unsupported metrics schema '{schema}'"));
    }
    let raw = field(&v, "metrics")?
        .as_arr()
        .ok_or("metrics is not an array")?;
    let mut entries = Vec::with_capacity(raw.len());
    for m in raw {
        let name = field(m, "name")?
            .as_str()
            .ok_or("name is not a string")?
            .to_string();
        let lv = field(m, "labels")?;
        let labels = Labels {
            bench: opt_str(lv, "bench"),
            lane: opt_str(lv, "lane"),
            machine: lv.get("machine").and_then(|x| x.as_f64()).map(|x| x as u32),
            tenant: opt_str(lv, "tenant"),
        };
        let ty = field(m, "type")?.as_str().ok_or("type is not a string")?;
        let value = match ty {
            "counter" => MetricValue::Counter(num(m, "value")? as u64),
            "gauge" => MetricValue::Gauge(num(m, "value")?),
            "histogram" => {
                let mut buckets = BTreeMap::new();
                for b in field(m, "buckets")?.as_arr().ok_or("buckets not array")? {
                    buckets.insert(num(b, "i")? as i32, num(b, "n")? as u64);
                }
                MetricValue::Histogram(Histogram::from_parts(
                    num(m, "count")? as u64,
                    num(m, "sum")?,
                    num(m, "min")?,
                    num(m, "max")?,
                    buckets,
                ))
            }
            "series" => {
                let mut pts = Vec::new();
                for p in field(m, "points")?.as_arr().ok_or("points not array")? {
                    let pair = p.as_arr().ok_or("point is not a pair")?;
                    if pair.len() != 2 {
                        return Err("point is not a pair".to_string());
                    }
                    let t = pair[0].as_f64().ok_or("point time not a number")?;
                    let val = pair[1].as_f64().ok_or("point value not a number")?;
                    pts.push((t, val));
                }
                MetricValue::Series(pts)
            }
            other => return Err(format!("unknown metric type '{other}'")),
        };
        entries.push(MetricEntry { name, labels, value });
    }
    Ok(MetricsSnapshot { entries })
}

// --------------------------------------------------------------------- slo

/// Per-tenant service-level targets.
#[derive(Clone, Copy, Debug, Default)]
pub struct SloTarget {
    /// p99 end-to-end latency ceiling, seconds. `<= 0` derives a default
    /// from the data: 2× the all-tenant p99 (so a tenant breaches when
    /// it is twice as slow as the machine-wide tail).
    pub p99_secs: f64,
    /// Minimum served throughput, requests/s. `<= 0` derives 0.5× the
    /// tenant's offered rate (`sched_offered_rps`), i.e. a tenant must
    /// keep up with at least half its arrival stream.
    pub min_throughput_rps: f64,
}

/// Health verdict of one tenant against its targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SloStatus {
    Ok,
    Warn,
    Breach,
}

impl SloStatus {
    /// Fixed-width display name.
    pub fn name(&self) -> &'static str {
        match self {
            SloStatus::Ok => "OK",
            SloStatus::Warn => "WARN",
            SloStatus::Breach => "BREACH",
        }
    }
}

/// One tenant's evaluated health.
#[derive(Clone, Debug)]
pub struct TenantHealth {
    pub tenant: String,
    pub status: SloStatus,
    /// Worst-window burn rate: how fast the tenant consumes its error
    /// budget (1.0 = exactly at target; ≥ 1.0 breaches).
    pub burn_rate: f64,
    /// p99 latency over the whole run, seconds.
    pub p99_secs: f64,
    /// Effective p99 target used, seconds.
    pub p99_target_secs: f64,
    /// Served throughput over the whole run, requests/s.
    pub throughput_rps: f64,
    /// Effective minimum-throughput target used, requests/s.
    pub min_throughput_rps: f64,
    /// Modeled energy attributed to the tenant's slice, joules.
    pub joules: f64,
    /// Number of sliding windows evaluated.
    pub windows: usize,
}

/// The SLO evaluation of a whole snapshot.
#[derive(Clone, Debug, Default)]
pub struct HealthReport {
    pub tenants: Vec<TenantHealth>,
}

impl HealthReport {
    /// True when no tenant breaches.
    pub fn healthy(&self) -> bool {
        self.tenants.iter().all(|t| t.status != SloStatus::Breach)
    }

    /// Machine-readable `health/v1` JSON (same float discipline as
    /// `metrics/v1`).
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n  \"schema\": \"health/v1\",\n  \"tenants\": [\n");
        for (i, t) in self.tenants.iter().enumerate() {
            let _ = write!(
                s,
                "    {{\"tenant\": \"{}\", \"status\": \"{}\", \"burn_rate\": {}, \
                 \"p99_secs\": {}, \"p99_target_secs\": {}, \"throughput_rps\": {}, \
                 \"min_throughput_rps\": {}, \"joules\": {}, \"windows\": {}}}",
                t.tenant,
                t.status.name(),
                fnum(t.burn_rate),
                fnum(t.p99_secs),
                fnum(t.p99_target_secs),
                fnum(t.throughput_rps),
                fnum(t.min_throughput_rps),
                fnum(t.joules),
                t.windows
            );
            s.push_str(if i + 1 < self.tenants.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }
}

/// Number of sliding windows the monitor splits a run into (half-window
/// stride, so 2W−1 evaluations cover the run).
const SLO_WINDOWS: usize = 4;

/// Evaluates per-tenant SLO targets over sliding windows of the sampled
/// `sched_done_latency` series (points at request-completion simulated
/// times). Stateless: feed it any snapshot, live or loaded from disk.
#[derive(Clone, Copy, Debug, Default)]
pub struct SloMonitor {
    pub target: SloTarget,
}

impl SloMonitor {
    /// Monitor with explicit targets (non-positive fields derive
    /// defaults from the snapshot; see [`SloTarget`]).
    pub fn new(target: SloTarget) -> SloMonitor {
        SloMonitor { target }
    }

    /// Evaluate every tenant present in the snapshot.
    pub fn evaluate(&self, snap: &MetricsSnapshot) -> HealthReport {
        let tenants = snap.tenants();
        // Effective p99 target: explicit, else 2× the all-tenant p99.
        let p99_target = if self.target.p99_secs > 0.0 {
            self.target.p99_secs
        } else {
            let mut all: Vec<f64> = Vec::new();
            for t in &tenants {
                if let Some(pts) = snap.series("sched_done_latency", t) {
                    all.extend(pts.iter().map(|&(_, v)| v));
                }
            }
            2.0 * percentile(&all, 99.0)
        };
        let mut out = Vec::new();
        for tenant in tenants {
            let pts = snap
                .series("sched_done_latency", &tenant)
                .unwrap_or(&[])
                .to_vec();
            if pts.is_empty() {
                continue;
            }
            let t_end = pts.iter().map(|&(t, _)| t).fold(0.0, f64::max);
            let offered = snap.tenant_gauge("sched_offered_rps", &tenant).unwrap_or(0.0);
            let min_tput = if self.target.min_throughput_rps > 0.0 {
                self.target.min_throughput_rps
            } else {
                0.5 * offered
            };
            let lats: Vec<f64> = pts.iter().map(|&(_, v)| v).collect();
            let p99 = percentile(&lats, 99.0);
            let throughput = if t_end > 0.0 {
                pts.len() as f64 / t_end
            } else {
                0.0
            };
            // Sliding windows: SLO_WINDOWS spans at half-window stride.
            let w = t_end / SLO_WINDOWS as f64;
            let mut burn = 0.0f64;
            let mut windows = 0usize;
            if w > 0.0 {
                let mut lo = 0.0;
                while lo + w <= t_end * (1.0 + 1e-12) {
                    let hi = lo + w;
                    let in_w: Vec<f64> = pts
                        .iter()
                        .filter(|&&(t, _)| t >= lo && t < hi)
                        .map(|&(_, v)| v)
                        .collect();
                    if !in_w.is_empty() {
                        let wp99 = percentile(&in_w, 99.0);
                        let wtput = in_w.len() as f64 / w;
                        let mut b: f64 = wp99 / p99_target;
                        if min_tput > 0.0 && wtput > 0.0 {
                            b = b.max(min_tput / wtput);
                        }
                        burn = burn.max(b);
                    }
                    windows += 1;
                    lo += 0.5 * w;
                }
            }
            let status = if burn >= 1.0 {
                SloStatus::Breach
            } else if burn >= 0.8 {
                SloStatus::Warn
            } else {
                SloStatus::Ok
            };
            out.push(TenantHealth {
                tenant: tenant.clone(),
                status,
                burn_rate: burn,
                p99_secs: p99,
                p99_target_secs: p99_target,
                throughput_rps: throughput,
                min_throughput_rps: min_tput,
                joules: snap.tenant_gauge("tenant_joules", &tenant).unwrap_or(0.0),
                windows,
            });
        }
        HealthReport { tenants: out }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::latency_summary;

    #[test]
    fn counter_gauge_roundtrip() {
        let t = Telemetry::new();
        assert!(t.is_empty());
        t.counter_add("c", Labels::tenant("a"), 2);
        t.counter_add("c", Labels::tenant("a"), 3);
        t.gauge_set("g", Labels::none(), 1.5);
        t.gauge_add("g", Labels::none(), 0.5);
        t.gauge_max("p", Labels::none(), 3.0);
        t.gauge_max("p", Labels::none(), 2.0);
        let s = t.snapshot();
        assert_eq!(s.entries.len(), 3);
        assert_eq!(s.entries[0].value, MetricValue::Counter(5));
        assert_eq!(s.entries[1].value, MetricValue::Gauge(2.0));
        assert_eq!(s.entries[2].value, MetricValue::Gauge(3.0));
    }

    #[test]
    fn snapshot_order_is_insertion_independent() {
        let a = Telemetry::new();
        a.counter_add("x", Labels::tenant("t1"), 1);
        a.counter_add("x", Labels::tenant("t0"), 1);
        a.gauge_set("a", Labels::none(), 0.0);
        let b = Telemetry::new();
        b.gauge_set("a", Labels::none(), 0.0);
        b.counter_add("x", Labels::tenant("t0"), 1);
        b.counter_add("x", Labels::tenant("t1"), 1);
        assert_eq!(a.snapshot(), b.snapshot());
        assert_eq!(a.snapshot().to_json(), b.snapshot().to_json());
    }

    #[test]
    fn histogram_quantile_agrees_with_latency_summary() {
        // Exact powers of two sit on bucket bounds, so the bucketed
        // quantile and the exact nearest-rank percentile are the same
        // number — the regression the shared `nearest_rank` formula pins.
        let xs: Vec<f64> = (0..64).map(|i| (i % 16) as f64).map(f64::exp2).collect();
        let mut h = Histogram::default();
        for &x in &xs {
            h.observe(x);
        }
        let s = latency_summary(&xs);
        assert_eq!(h.quantile(50.0).to_bits(), s.p50.to_bits());
        assert_eq!(h.quantile(95.0).to_bits(), s.p95.to_bits());
        assert_eq!(h.quantile(99.0).to_bits(), s.p99.to_bits());
        assert_eq!(h.max().to_bits(), s.max.to_bits());
    }

    #[test]
    fn histogram_nan_guard_matches_stats_path() {
        // NaN is dropped by the histogram and sorted last by
        // `total_cmp`, so both paths report the same p50 on real data.
        let xs = [1.0, 2.0, 4.0, f64::NAN];
        let mut h = Histogram::default();
        for &x in &xs {
            h.observe(x);
        }
        assert_eq!(h.count(), 3);
        let clean: Vec<f64> = xs.iter().copied().filter(|x| !x.is_nan()).collect();
        assert_eq!(
            h.quantile(50.0).to_bits(),
            latency_summary(&clean).p50.to_bits()
        );
    }

    #[test]
    fn histogram_merge_is_exact() {
        let mut a = Histogram::default();
        let mut b = Histogram::default();
        let mut whole = Histogram::default();
        for i in 0..32 {
            let v = 1.0 + i as f64;
            if i % 2 == 0 {
                a.observe(v);
            } else {
                b.observe(v);
            }
            whole.observe(v);
        }
        a.merge(&b);
        assert_eq!(a, whole);
    }

    #[test]
    fn histogram_bucket_bounds() {
        assert_eq!(Histogram::bucket_upper(Histogram::bucket_index(1.0)), 1.0);
        assert_eq!(Histogram::bucket_upper(Histogram::bucket_index(8.0)), 8.0);
        let v = 3.0;
        let up = Histogram::bucket_upper(Histogram::bucket_index(v));
        assert!(up >= v && up <= v * 2f64.powf(0.25) * (1.0 + 1e-12));
        // Non-positive values share the lowest bucket.
        assert_eq!(Histogram::bucket_index(0.0), Histogram::bucket_index(-5.0));
    }

    #[test]
    fn metrics_v1_roundtrip_is_bit_identical() {
        let t = Telemetry::new();
        t.counter_add("arrivals", Labels::tenant("a").with_bench("gemv"), 7);
        t.gauge_set("util", Labels::lane(&Lane::Bus), 0.375);
        t.gauge_set("joules", Labels::tenant("a"), 1.234e-3);
        t.observe("lat", Labels::tenant("a"), 0.5);
        t.observe("lat", Labels::tenant("a"), 2.0);
        t.sample("depth", Labels::tenant("a"), 0.1, 3.0);
        t.sample("depth", Labels::tenant("a"), 0.2, 1.0);
        t.counter_add("link_bytes", Labels::none().with_machine(2), 4096);
        let json = t.snapshot().to_json();
        let parsed = parse_metrics(&json).expect("parse back");
        assert_eq!(parsed, t.snapshot());
        assert_eq!(parsed.to_json(), json, "serialize→parse→serialize identity");
    }

    #[test]
    fn parse_rejects_foreign_schema() {
        assert!(parse_metrics("{\"schema\": \"trace/v1\", \"metrics\": []}").is_err());
        assert!(parse_metrics("{}").is_err());
    }

    #[test]
    fn prometheus_exposition_shape() {
        let t = Telemetry::new();
        t.counter_add("reqs", Labels::tenant("a"), 3);
        t.observe("lat", Labels::none(), 1.0);
        t.observe("lat", Labels::none(), 2.0);
        t.sample("depth", Labels::none(), 0.5, 4.0);
        let p = t.snapshot().to_prometheus();
        assert!(p.contains("# TYPE reqs counter"), "{p}");
        assert!(p.contains("reqs{tenant=\"a\"} 3"), "{p}");
        assert!(p.contains("# TYPE lat histogram"), "{p}");
        assert!(p.contains("lat_bucket{le=\"+Inf\"} 2"), "{p}");
        assert!(p.contains("lat_count 2"), "{p}");
        assert!(p.contains("depth 4e0"), "{p}");
    }

    #[test]
    fn slo_monitor_flags_breach() {
        let t = Telemetry::new();
        // Tenant "fast": 20 completions at latency 0.1 over 2s.
        // Tenant "slow": 20 completions at latency 1.0 over 2s.
        for i in 0..20 {
            let at = 0.1 * (i + 1) as f64;
            t.sample("sched_done_latency", Labels::tenant("fast"), at, 0.1);
            t.sample("sched_done_latency", Labels::tenant("slow"), at, 1.0);
        }
        t.gauge_set("sched_offered_rps", Labels::tenant("fast"), 10.0);
        t.gauge_set("sched_offered_rps", Labels::tenant("slow"), 10.0);
        t.gauge_set("tenant_joules", Labels::tenant("slow"), 42.0);
        let snap = t.snapshot();
        let rep = SloMonitor::new(SloTarget {
            p99_secs: 0.5,
            min_throughput_rps: 0.0,
        })
        .evaluate(&snap);
        assert_eq!(rep.tenants.len(), 2);
        let fast = rep.tenants.iter().find(|t| t.tenant == "fast").unwrap();
        let slow = rep.tenants.iter().find(|t| t.tenant == "slow").unwrap();
        assert_eq!(fast.status, SloStatus::Ok);
        assert_eq!(slow.status, SloStatus::Breach);
        assert!(slow.burn_rate >= 2.0 - 1e-9);
        assert_eq!(slow.joules, 42.0);
        assert!(!rep.healthy());
        let json = rep.to_json();
        assert!(json.contains("\"schema\": \"health/v1\""));
        assert!(json.contains("\"status\": \"BREACH\""));
    }

    #[test]
    fn slo_default_targets_derive_from_snapshot() {
        let t = Telemetry::new();
        for i in 0..10 {
            t.sample(
                "sched_done_latency",
                Labels::tenant("only"),
                0.5 * (i + 1) as f64,
                0.2,
            );
        }
        t.gauge_set("sched_offered_rps", Labels::tenant("only"), 2.0);
        let rep = SloMonitor::default().evaluate(&t.snapshot());
        let h = &rep.tenants[0];
        // Derived p99 target = 2× observed p99 → burn ≈ 0.5 → OK.
        assert_eq!(h.p99_target_secs, 0.4);
        assert_eq!(h.min_throughput_rps, 1.0);
        assert_eq!(h.status, SloStatus::Ok);
    }
}
