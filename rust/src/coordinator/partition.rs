//! Workload partitioning helpers (the "split the workload into independent
//! data blocks" programming recommendation).

use std::ops::Range;

/// Split `n_items` into `n_parts` contiguous balanced ranges (sizes differ
/// by at most 1; earlier parts get the extra element). Empty ranges are
/// produced when `n_parts > n_items`.
pub fn chunk_ranges(n_items: usize, n_parts: usize) -> Vec<Range<usize>> {
    assert!(n_parts > 0);
    let base = n_items / n_parts;
    let extra = n_items % n_parts;
    let mut out = Vec::with_capacity(n_parts);
    let mut start = 0;
    for i in 0..n_parts {
        let len = base + usize::from(i < extra);
        out.push(start..start + len);
        start += len;
    }
    out
}

/// Split into contiguous ranges whose starts are aligned to `align`
/// elements (so per-DPU MRAM buffers keep 8-byte DMA alignment). The last
/// range absorbs the remainder.
pub fn chunk_ranges_aligned(n_items: usize, n_parts: usize, align: usize) -> Vec<Range<usize>> {
    assert!(n_parts > 0 && align > 0);
    let per = n_items.div_ceil(n_parts);
    let per = per.div_ceil(align) * align;
    let mut out = Vec::with_capacity(n_parts);
    let mut start = 0usize;
    for _ in 0..n_parts {
        let end = (start + per).min(n_items);
        out.push(start..end);
        start = end;
    }
    out
}

/// Per-DPU element counts of a contiguous ragged split: DPU `d` owns the
/// slice `[d*per, d*per + count_d)` with `count_d = per.min(n_items -
/// d*per)` (zero once the items run out) — the share vector the transfer
/// builder's `ragged` terminals take. Counts always sum to `n_items`
/// when `per * n_parts >= n_items`.
pub fn ragged_counts(n_items: usize, per: usize, n_parts: usize) -> Vec<usize> {
    assert!(per > 0 || n_items == 0, "zero stride cannot cover {n_items} items");
    (0..n_parts).map(|d| per.min(n_items.saturating_sub(d * per))).collect()
}

/// Block-cyclic assignment of `n_blocks` blocks to `n_workers` workers
/// (block j → worker j % n_workers) — the intra-DPU tasklet assignment used
/// by VA and friends. Returns the block indices of each worker.
pub fn cyclic_blocks(n_blocks: usize, n_workers: usize) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); n_workers];
    for b in 0..n_blocks {
        out[b % n_workers].push(b);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ragged_counts_sum_to_items() {
        for (n, per, p) in [(7504, 1280, 7), (100, 8, 13), (0, 16, 4), (64, 64, 1)] {
            let counts = ragged_counts(n, per, p);
            assert_eq!(counts.len(), p);
            assert_eq!(counts.iter().sum::<usize>(), n, "n={n} per={per} p={p}");
            assert!(counts.iter().all(|&c| c <= per));
            // monotone: full shares first, then the tail, then zeros
            for w in counts.windows(2) {
                assert!(w[0] >= w[1]);
            }
        }
    }

    #[test]
    fn chunks_cover_exactly() {
        for (n, p) in [(100, 7), (5, 8), (64, 64), (0, 3), (1000, 1)] {
            let rs = chunk_ranges(n, p);
            assert_eq!(rs.len(), p);
            let mut cursor = 0;
            for r in &rs {
                assert_eq!(r.start, cursor);
                cursor = r.end;
            }
            assert_eq!(cursor, n);
            let sizes: Vec<usize> = rs.iter().map(|r| r.len()).collect();
            let (min, max) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
            assert!(max - min <= 1);
        }
    }

    #[test]
    fn aligned_chunks_cover() {
        let rs = chunk_ranges_aligned(1000, 7, 16);
        let mut cursor = 0;
        for r in &rs {
            assert_eq!(r.start, cursor);
            assert_eq!(r.start % 16, 0);
            cursor = r.end;
        }
        assert_eq!(cursor, 1000);
    }

    #[test]
    fn cyclic_covers_all_blocks() {
        let asg = cyclic_blocks(10, 3);
        let mut all: Vec<usize> = asg.iter().flatten().copied().collect();
        all.sort_unstable();
        assert_eq!(all, (0..10).collect::<Vec<_>>());
        assert_eq!(asg[0], vec![0, 3, 6, 9]);
    }
}
