//! Async command queues: one resource-timeline model for launches,
//! transfers, and their overlap.
//!
//! The real UPMEM SDK exposes exactly one abstraction for the paper's §6
//! "overlap CPU-DPU transfers with kernel execution" recommendation:
//! asynchronous operation queues (`dpu_launch(DPU_ASYNCHRONOUS)` +
//! `dpu_sync`), emphasized again in the follow-on "Benchmarking
//! Memory-Centric Computing Systems" (arXiv:2110.01709). This module is
//! the modeled analogue: a [`CmdQueue`] of typed commands
//! ([`CmdKind`]: `Push` / `Pull` / `Launch` / `HostMerge` / `Fence`)
//! scheduled onto three kinds of modeled resource lanes ([`Lane`]):
//!
//! * **one serialized host bus** — every CPU↔DPU transfer occupies it,
//!   whatever rank it targets (§5.1.1: "these transfers are not
//!   simultaneous across ranks");
//! * **per-rank kernel lanes** — launches occupy the lanes of the ranks
//!   they run on, so kernels on disjoint rank sets overlap (the
//!   concurrency the multi-tenant scheduler's rank slicing buys);
//! * **the host CPU** — `HostMerge` commands (frontier unions, partial
//!   result merges) occupy it and may overlap bus and kernel activity;
//! * **per-machine bus / host / network-link lanes** — a multi-machine
//!   [`super::cluster::Cluster`] records commands tagged with their
//!   [`CmdMeta::machine`]: machine `m`'s transfers serialize on its own
//!   bus lane ([`Lane::MachineBus`]), its merges on its own host CPU,
//!   and modeled collectives ([`CmdKind::Net`]) serialize on the
//!   issuing machine's egress link ([`Lane::Link`]) exactly the way
//!   host transfers serialize on the bus. Machine 0 uses the legacy
//!   single-machine lanes, so a one-machine cluster schedules
//!   bit-identically to a plain queue.
//!
//! Ordering between commands is **inferred from the `Symbol` byte
//! regions each command reads and writes** (RAW / WAR / WAW overlap on
//! intersecting DPU ranges), plus explicit `after` edges for host-side
//! data flow the region model cannot see (a merge consumes the host
//! image of a just-pulled region). [`CmdQueue::schedule`] then runs a
//! greedy list schedule: at every step the dependency-ready command that
//! can start earliest issues next — so an independent push (e.g. the
//! *next* request's double-buffered input) slides under a running
//! kernel, exactly the software pipelining an async UPMEM program
//! expresses by issuing work before `dpu_sync`.
//!
//! # Scheduling invariants
//!
//! Two properties are load-bearing and guarded by tests:
//!
//! * **Tie-breaking**: among dependency-ready commands with equal
//!   feasible start times, the **lowest [`CmdId`] (enqueue order) issues
//!   first**. Every executor derives the same modeled seconds, so this
//!   makes the whole schedule — finish times, makespan, `total_secs` —
//!   bit-identical across executors and across the optimized/reference
//!   scheduler pair below.
//! * **Reference equivalence**: [`CmdQueue::schedule`] is an indexed,
//!   event-driven rewrite (segment index over byte regions for
//!   dependency inference, min-heap ready selection, span-compressed
//!   rank timeline). [`CmdQueue::schedule_reference`] retains the naive
//!   O(n²) pairwise scheduler as the executable spec; property tests
//!   assert the two produce **bitwise-equal** `Schedule`s on randomized
//!   command soups. The optimization is a pure speedup with zero
//!   modeled-time drift.
//!
//! The derived quantity is the **makespan** of the scheduled timeline;
//! `PimSet::queue_sync` folds `sum(command secs) − makespan` into
//! [`super::TimeBreakdown::overlapped`]. A queue with a single command —
//! what every synchronous `PimSet` call degenerates to — has
//! `makespan == secs`, so the credit is exactly zero and synchronous
//! accounting is bit-identical to the pre-queue model. A fully dependent
//! chain likewise folds to `makespan == sum` (the same left-to-right
//! float accumulation), so `overlapped` is zero whenever nothing can
//! actually overlap.
//!
//! Functionally nothing is reordered: commands *execute* immediately, in
//! program order, through the same `FleetExecutor`/`TransferEngine`
//! paths as synchronous calls — the queue records modeled metadata only.
//! On today's shipping hardware a rank's MRAM cannot be touched while
//! its DPUs run, so (as with the retired batch-credit model) the
//! launch-concurrent transfer portion of the credit is the §6 **what-if**
//! the paper argues for, not a property of the 2021 SDK.

use std::cmp::{Ordering, Reverse};
use std::collections::BinaryHeap;
use std::ops::Range;

/// Index of a command within its [`CmdQueue`] (returned by enqueue,
/// consumed by explicit `after` dependencies).
pub type CmdId = usize;

/// The command vocabulary — one variant per kind of modeled work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmdKind {
    /// Host → MRAM transfer (any distribution; occupies the bus).
    Push,
    /// MRAM → host transfer (occupies the bus).
    Pull,
    /// Kernel launch (occupies the lanes of the ranks it runs on).
    Launch,
    /// Host-side merge compute (occupies the host CPU lane).
    HostMerge,
    /// Synchronization barrier: waits for everything enqueued before it
    /// and blocks everything after. Zero modeled seconds.
    Fence,
    /// Inter-machine network transfer (a collective shard or frontier
    /// exchange): occupies the issuing machine's egress link lane.
    /// Ordered only by explicit `after` edges — its data flow is
    /// host-side and invisible to the MRAM region model.
    Net,
    /// Elastic migration, drain phase: the window between the resize
    /// decision and the moment the affected slices fall idle. Emitted
    /// by the scheduler's `Migrator` (never enqueued in a `CmdQueue`);
    /// occupies no lane of its own.
    MigrateDrain,
    /// Elastic migration, copy phase: re-pushing a resized tenant's
    /// resident symbols over the shared bus. Bus-lane traffic like
    /// [`CmdKind::Push`].
    MigrateCopy,
    /// Elastic migration, resume phase: the instant a resized slice
    /// re-enters service on its new rank span. Zero modeled seconds.
    MigrateResume,
}

/// Declared MRAM footprint of a launch: the byte regions its kernel
/// reads and writes (built from [`super::Symbol::region`]). Launches
/// enqueued without a declaration conservatively touch the whole bank,
/// which serializes them against every transfer — safe, and exactly the
/// degenerate timeline the synchronous shim wants.
#[derive(Clone, Debug, Default)]
pub struct Access {
    pub reads: Vec<Range<usize>>,
    pub writes: Vec<Range<usize>>,
}

impl Access {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare a byte region the kernel reads (builder style).
    pub fn read(mut self, r: Range<usize>) -> Self {
        self.reads.push(r);
        self
    }

    /// Declare a byte region the kernel writes.
    pub fn write(mut self, r: Range<usize>) -> Self {
        self.writes.push(r);
        self
    }
}

/// A command's byte-region footprint, allocation-free in the common
/// cases: most commands declare **zero or one** region (every push/pull
/// is one range; merges and fences have none), so the one-range case is
/// stored inline instead of heap-allocating a `Vec` per command — the
/// per-command allocator churn the old `Vec<Range>` representation paid
/// on every recorded transfer.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub enum RegionSet {
    /// No regions (merges, fences, undeclared sides).
    #[default]
    Empty,
    /// Exactly one region, stored inline (pushes, pulls, grouped
    /// transfers, single-symbol launches).
    One(Range<usize>),
    /// Two or more regions (multi-symbol launch footprints).
    Many(Vec<Range<usize>>),
}

impl RegionSet {
    /// View as a slice of ranges (empty slice for `Empty`).
    pub fn as_slice(&self) -> &[Range<usize>] {
        match self {
            RegionSet::Empty => &[],
            RegionSet::One(r) => std::slice::from_ref(r),
            RegionSet::Many(v) => v,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.as_slice().is_empty()
    }
}

impl From<Range<usize>> for RegionSet {
    fn from(r: Range<usize>) -> Self {
        RegionSet::One(r)
    }
}

impl From<Vec<Range<usize>>> for RegionSet {
    fn from(mut v: Vec<Range<usize>>) -> Self {
        match v.len() {
            0 => RegionSet::Empty,
            1 => RegionSet::One(v.pop().expect("len checked")),
            _ => RegionSet::Many(v),
        }
    }
}

/// One recorded command: kind, modeled seconds, and the footprint the
/// dependency inference works from.
#[derive(Clone, Debug)]
pub struct CmdMeta {
    pub kind: CmdKind,
    /// Modeled seconds this command occupies its lane. Must be
    /// non-negative: finish times are then monotone along dependency
    /// edges, which the indexed dependency inference relies on.
    pub secs: f64,
    /// DPU index range the command touches (commands on disjoint DPU
    /// ranges never conflict through memory).
    pub dpus: Range<usize>,
    /// MRAM byte regions read / written (fleet-shared address space).
    pub reads: RegionSet,
    pub writes: RegionSet,
    /// Explicit extra dependencies (host-side data flow).
    pub after: Vec<CmdId>,
    /// Fence semantics: conflicts with every other command.
    pub fence: bool,
    /// Payload bytes the command moves (trace annotation only — the
    /// scheduling model works from `secs` and the byte *regions*).
    pub bytes: u64,
    /// Request tag stamped by the recording `PimSet` (trace annotation;
    /// `None` outside a tagged batch).
    pub req: Option<u64>,
    /// Machine that issues the command (0 for the single-machine
    /// default). Routes bus/host commands to that machine's lanes and
    /// [`CmdKind::Net`] commands to its egress link. Dependency
    /// inference is unaffected: cluster recording keys deps on
    /// machine-disjoint global DPU indices instead.
    pub machine: u32,
}

impl CmdMeta {
    /// A host→MRAM transfer writing `bytes` on `dpus`.
    pub fn push(dpus: Range<usize>, bytes: Range<usize>, secs: f64, after: Vec<CmdId>) -> Self {
        CmdMeta {
            kind: CmdKind::Push,
            secs,
            dpus,
            reads: RegionSet::Empty,
            writes: bytes.into(),
            after,
            fence: false,
            bytes: 0,
            req: None,
            machine: 0,
        }
    }

    /// An MRAM→host transfer reading `bytes` on `dpus`.
    pub fn pull(dpus: Range<usize>, bytes: Range<usize>, secs: f64, after: Vec<CmdId>) -> Self {
        CmdMeta {
            kind: CmdKind::Pull,
            secs,
            dpus,
            reads: bytes.into(),
            writes: RegionSet::Empty,
            after,
            fence: false,
            bytes: 0,
            req: None,
            machine: 0,
        }
    }

    /// A launch with a declared footprint.
    pub fn launch(dpus: Range<usize>, acc: Access, secs: f64) -> Self {
        CmdMeta {
            kind: CmdKind::Launch,
            secs,
            dpus,
            reads: acc.reads.into(),
            writes: acc.writes.into(),
            after: Vec::new(),
            fence: false,
            bytes: 0,
            req: None,
            machine: 0,
        }
    }

    /// A launch with no declaration: conservatively reads and writes the
    /// whole `mram_bytes` bank, serializing against every transfer on
    /// its DPUs.
    pub fn launch_full(dpus: Range<usize>, mram_bytes: usize, secs: f64) -> Self {
        Self::launch(
            dpus,
            Access::new().read(0..mram_bytes).write(0..mram_bytes),
            secs,
        )
    }

    /// A host merge with fence semantics (no declared data flow — the
    /// conservative default of `PimSet::host_merge`).
    pub fn host_merge(secs: f64) -> Self {
        CmdMeta {
            kind: CmdKind::HostMerge,
            secs,
            dpus: 0..0,
            reads: RegionSet::Empty,
            writes: RegionSet::Empty,
            after: Vec::new(),
            fence: true,
            bytes: 0,
            req: None,
            machine: 0,
        }
    }

    /// A host merge depending only on the listed commands (the pulls
    /// whose host-side images it consumes) — the precise form that lets
    /// merge compute overlap later bus traffic.
    pub fn host_merge_after(secs: f64, after: Vec<CmdId>) -> Self {
        CmdMeta {
            kind: CmdKind::HostMerge,
            secs,
            dpus: 0..0,
            reads: RegionSet::Empty,
            writes: RegionSet::Empty,
            after,
            fence: false,
            bytes: 0,
            req: None,
            machine: 0,
        }
    }

    /// An inter-machine network transfer issued by `machine`: occupies
    /// that machine's egress link lane for `secs`, ordered only by the
    /// explicit `after` edges (like a dep'd host merge, its payload
    /// lives host-side where the region model cannot see it).
    pub fn net(machine: u32, secs: f64, after: Vec<CmdId>) -> Self {
        CmdMeta {
            kind: CmdKind::Net,
            secs,
            dpus: 0..0,
            reads: RegionSet::Empty,
            writes: RegionSet::Empty,
            after,
            fence: false,
            bytes: 0,
            req: None,
            machine,
        }
    }

    /// Route the command to a machine's lane set (builder style;
    /// machine 0 is the legacy single-machine lane set).
    pub fn on_machine(mut self, machine: u32) -> Self {
        self.machine = machine;
        self
    }

    /// Annotate the command with the payload bytes it moves (builder
    /// style; trace metadata only — scheduling is unaffected).
    pub fn with_bytes(mut self, bytes: u64) -> Self {
        self.bytes = bytes;
        self
    }

    /// A zero-second synchronization barrier.
    pub fn fence() -> Self {
        CmdMeta {
            kind: CmdKind::Fence,
            secs: 0.0,
            dpus: 0..0,
            reads: RegionSet::Empty,
            writes: RegionSet::Empty,
            after: Vec::new(),
            fence: true,
            bytes: 0,
            req: None,
            machine: 0,
        }
    }
}

/// Do two byte/DPU ranges intersect? Empty ranges touch nothing and
/// never overlap anything — a zero-byte region or zero-DPU command
/// cannot conflict (this is load-bearing for the indexed inference,
/// which skips empty footprints entirely).
fn ranges_overlap(a: &Range<usize>, b: &Range<usize>) -> bool {
    a.start < a.end && b.start < b.end && a.start < b.end && b.start < a.end
}

fn any_overlap(a: &[Range<usize>], b: &[Range<usize>]) -> bool {
    a.iter().any(|ra| b.iter().any(|rb| ranges_overlap(ra, rb)))
}

/// Must `b` wait for `a` (enqueued earlier)? True on fences and on any
/// RAW / WAR / WAW byte overlap over intersecting DPU ranges. This is
/// the *definition* of a dependency; the indexed inference in
/// [`infer_deps`] derives a reduced edge set that provably schedules
/// identically (see the proof sketch there).
fn depends(a: &CmdMeta, b: &CmdMeta) -> bool {
    if a.fence || b.fence {
        return true;
    }
    if !ranges_overlap(&a.dpus, &b.dpus) {
        return false;
    }
    any_overlap(a.writes.as_slice(), b.writes.as_slice())
        || any_overlap(a.writes.as_slice(), b.reads.as_slice())
        || any_overlap(a.reads.as_slice(), b.writes.as_slice())
}

// ------------------------------------------------------- dependency index

/// One open access recorded in the region index: which command, on which
/// DPU range.
#[derive(Clone, Debug)]
struct Entry {
    id: CmdId,
    dpus: Range<usize>,
}

/// A maximal byte interval whose frontier (last writers + readers since)
/// is uniform. Segments are disjoint, sorted, and may leave gaps for
/// never-touched bytes.
#[derive(Debug)]
struct Seg {
    start: usize,
    end: usize,
    /// Frontier writers: the most recent writes not fully shadowed by a
    /// later covering write. Usually length 1.
    writers: Vec<Entry>,
    /// Readers since the frontier writers (cleared when a covering write
    /// shadows them).
    readers: Vec<Entry>,
}

/// Interval index over the fleet-shared MRAM byte space: for every byte
/// point, the frontier of open accesses. Dependency inference queries
/// and updates it per command region instead of sweeping all pairs.
///
/// Frontier `Vec<Entry>`s are **arena-recycled**: every segment created
/// by a split or a gap fill draws its writer/reader vectors from
/// [`RegionIndex::pool`], and [`RegionIndex::clear`] (the per-fence
/// epoch reset) drains them back. A fence-heavy queue — the 10k-command
/// soup the `simulator_hotpath` bench schedules — rebuilds its segment
/// frontier every epoch; recycling keeps that churn off the allocator
/// after the first epoch warms the pool.
#[derive(Debug)]
struct RegionIndex {
    segs: Vec<Seg>,
    /// Recycled frontier vectors (cleared, capacity retained).
    pool: Vec<Vec<Entry>>,
    /// Recycling switch: `false` allocates fresh vectors on every
    /// split/clear — the before/after baseline `dep_edges_unpooled`
    /// exposes for the hot-path bench.
    pooled: bool,
}

impl RegionIndex {
    fn new(pooled: bool) -> Self {
        RegionIndex {
            segs: Vec::new(),
            pool: Vec::new(),
            pooled,
        }
    }

    /// A frontier vector, recycled from the pool when possible.
    fn take_vec(&mut self) -> Vec<Entry> {
        if self.pooled {
            self.pool.pop().unwrap_or_default()
        } else {
            Vec::new()
        }
    }

    fn new_seg(&mut self, start: usize, end: usize) -> Seg {
        Seg {
            start,
            end,
            writers: self.take_vec(),
            readers: self.take_vec(),
        }
    }

    /// Split segment `k` at `x` (strictly inside); it keeps `[start, x)`
    /// and the returned segment carries `[x, end)` with a copied
    /// frontier.
    fn split_seg(&mut self, k: usize, x: usize) -> Seg {
        debug_assert!(self.segs[k].start < x && x < self.segs[k].end);
        let mut right = self.new_seg(x, self.segs[k].end);
        right.writers.extend_from_slice(&self.segs[k].writers);
        right.readers.extend_from_slice(&self.segs[k].readers);
        self.segs[k].end = x;
        right
    }

    /// Make segment boundaries line up with `[lo, hi)` exactly (splitting
    /// straddlers, materializing gaps) and return the index range of the
    /// segments that tile it.
    fn carve(&mut self, lo: usize, hi: usize) -> Range<usize> {
        debug_assert!(lo < hi);
        let mut k = self.segs.partition_point(|s| s.end <= lo);
        if k < self.segs.len() && self.segs[k].start < lo {
            let right = self.split_seg(k, lo);
            self.segs.insert(k + 1, right);
            k += 1;
        }
        let begin = k;
        let mut cursor = lo;
        while cursor < hi {
            if k == self.segs.len() || self.segs[k].start >= hi {
                let s = self.new_seg(cursor, hi);
                self.segs.insert(k, s);
                k += 1;
                break;
            }
            let s_start = self.segs[k].start;
            if s_start > cursor {
                let s = self.new_seg(cursor, s_start);
                self.segs.insert(k, s);
                k += 1;
                cursor = s_start;
                continue;
            }
            if self.segs[k].end > hi {
                let right = self.split_seg(k, hi);
                self.segs.insert(k + 1, right);
            }
            cursor = self.segs[k].end;
            k += 1;
        }
        begin..k
    }

    fn clear(&mut self) {
        if self.pooled {
            let mut segs = std::mem::take(&mut self.segs);
            for s in segs.drain(..) {
                let Seg { mut writers, mut readers, .. } = s;
                writers.clear();
                readers.clear();
                self.pool.push(writers);
                self.pool.push(readers);
            }
            self.segs = segs;
        } else {
            self.segs.clear();
        }
    }
}

/// Inferred dependency DAG in adjacency form: `out[j]` lists the later
/// commands that wait on `j`; `indeg[i]` counts how many earlier
/// commands `i` waits on.
struct DepGraph {
    out: Vec<Vec<CmdId>>,
    indeg: Vec<u32>,
}

/// Record edge `j → i` (i waits on j), deduplicating repeats via the
/// per-dependent stamp in `mark`.
fn edge(j: CmdId, i: CmdId, mark: &mut [CmdId], out: &mut [Vec<CmdId>], indeg: &mut [u32]) {
    if j == i || mark[j] == i {
        return;
    }
    mark[j] = i;
    out[j].push(i);
    indeg[i] += 1;
}

/// Is `inner` fully contained in `outer`?
fn covers(outer: &Range<usize>, inner: &Range<usize>) -> bool {
    inner.start >= outer.start && inner.end <= outer.end
}

/// Indexed dependency inference: one pass over the commands, querying a
/// segment index of frontier accesses instead of testing all pairs.
///
/// The naive spec ([`depends`]) conflicts every pair with overlapping
/// DPU ranges and overlapping read/write byte regions; fences conflict
/// with everything. This pass emits a **reduced** edge set: per byte
/// point only the frontier (last writers not shadowed by a covering
/// later write, plus readers since) generates edges, and fences become
/// epoch barriers (edges from the commands since — and including — the
/// previous fence) instead of all-pairs edges.
///
/// Why the reduction schedules identically (bitwise): every dropped
/// conflict `j → i` is *dominated* — there is a retained edge path
/// `j → … → i`. Since command seconds are non-negative, finish times are
/// monotone along edges, so `max` over the retained predecessors' finish
/// times equals the max over the full conflict set; and "all
/// dependencies done" propagates transitively along the same paths, so
/// commands become ready in the same scheduling rounds. Both the ready
/// *values* and the ready *sets* coincide with the naive scheduler's at
/// every step, hence identical picks and identical float accumulation.
fn infer_deps(cmds: &[CmdMeta]) -> DepGraph {
    infer_deps_with(cmds, true)
}

/// [`infer_deps`] with the [`RegionIndex`] frontier-vector recycling
/// switchable — `pooled: false` is the allocation-per-split baseline
/// kept for the hot-path bench's before/after comparison.
fn infer_deps_with(cmds: &[CmdMeta], pooled: bool) -> DepGraph {
    let n = cmds.len();
    let mut out: Vec<Vec<CmdId>> = vec![Vec::new(); n];
    let mut indeg = vec![0u32; n];
    let mut mark = vec![usize::MAX; n];
    let mut index = RegionIndex::new(pooled);
    // Commands since (and including) the previous fence — the epoch a
    // fence must wait for.
    let mut epoch: Vec<CmdId> = Vec::new();
    let mut last_fence: Option<CmdId> = None;
    for (i, c) in cmds.iter().enumerate() {
        for &j in &c.after {
            if j < i {
                edge(j, i, &mut mark, &mut out, &mut indeg);
            }
        }
        if c.fence {
            for &j in &epoch {
                edge(j, i, &mut mark, &mut out, &mut indeg);
            }
            index.clear();
            epoch.clear();
            epoch.push(i);
            last_fence = Some(i);
            continue;
        }
        if let Some(fj) = last_fence {
            edge(fj, i, &mut mark, &mut out, &mut indeg);
        }
        epoch.push(i);
        if c.dpus.start >= c.dpus.end {
            // No DPU footprint ⇒ no region conflicts possible (the
            // naive spec's DPU-overlap gate always fails).
            continue;
        }
        for r in c.reads.as_slice() {
            if r.start >= r.end {
                continue;
            }
            let span = index.carve(r.start, r.end);
            for k in span.clone() {
                for e in &index.segs[k].writers {
                    if e.id != i && ranges_overlap(&e.dpus, &c.dpus) {
                        edge(e.id, i, &mut mark, &mut out, &mut indeg);
                    }
                }
            }
            for k in span {
                index.segs[k].readers.push(Entry {
                    id: i,
                    dpus: c.dpus.clone(),
                });
            }
        }
        for w in c.writes.as_slice() {
            if w.start >= w.end {
                continue;
            }
            let span = index.carve(w.start, w.end);
            for k in span.clone() {
                let seg = &index.segs[k];
                for e in seg.writers.iter().chain(seg.readers.iter()) {
                    if e.id != i && ranges_overlap(&e.dpus, &c.dpus) {
                        edge(e.id, i, &mut mark, &mut out, &mut indeg);
                    }
                }
            }
            for k in span {
                let seg = &mut index.segs[k];
                // Entries fully covered on the DPU axis are shadowed:
                // any later conflict with them also conflicts with this
                // write, so their edges route through it (dominance).
                seg.writers.retain(|e| !covers(&c.dpus, &e.dpus));
                seg.readers.retain(|e| !covers(&c.dpus, &e.dpus));
                seg.writers.push(Entry {
                    id: i,
                    dpus: c.dpus.clone(),
                });
            }
        }
    }
    DepGraph { out, indeg }
}

// ---------------------------------------------------------------- timeline

/// A modeled resource lane (see the module docs). Rank lanes are indexed
/// relative to the owning fleet/machine.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Lane {
    /// The one serialized host memory bus (all CPU↔DPU transfers).
    /// In a cluster this is machine 0's bus, so single-machine queues
    /// keep their lane assignment unchanged.
    Bus,
    /// The host CPU (merge compute); machine 0's in a cluster.
    Host,
    /// The kernel lanes of a contiguous rank span (cluster launches use
    /// machine-disjoint global rank indices, so no machine variant is
    /// needed here).
    Ranks(Range<u32>),
    /// Machine `m`'s serialized host bus (`m ≥ 1`; machine 0 is
    /// [`Lane::Bus`]).
    MachineBus(u32),
    /// Machine `m`'s host CPU (`m ≥ 1`; machine 0 is [`Lane::Host`]).
    MachineHost(u32),
    /// Machine `m`'s egress network link (flat-switch topology: every
    /// machine owns one full-duplex link into a non-blocking switch, so
    /// egress serialization is the only contention point).
    Link(u32),
}

/// Free-time bookkeeping of every lane: one bus, one host CPU, `n`
/// ranks. Shared by [`CmdQueue::schedule`] and the multi-tenant
/// [`super::Scheduler`], so both model the machine identically.
///
/// Rank free times are stored as **coalesced spans** `(first_rank,
/// free_time)` covering `[0, n_ranks)` — a fleet-wide launch is one span
/// however many ranks it spans, and tenant slices split only at their
/// boundaries. `free_at` / `reserve` / `hold` on a rank lane are
/// O(log S + K) in the S spans present and the K spans the lane touches,
/// instead of O(ranks in lane) per-element scans. Values are identical
/// to the per-element representation: `free_at` is the same
/// `fold(0.0, f64::max)` over the same value multiset, and
/// reserve/hold assign the same per-rank values.
#[derive(Clone, Debug)]
pub struct Timeline {
    bus: f64,
    host: f64,
    n_ranks: u32,
    /// `spans[k]` covers ranks `[spans[k].0, spans[k+1].0)` (last span
    /// runs to `n_ranks`) at free time `spans[k].1`. Invariants:
    /// `spans[0].0 == 0`, starts strictly increase, adjacent span values
    /// differ (coalesced).
    spans: Vec<(u32, f64)>,
    /// Splice scratch buffer, reused across updates so steady-state
    /// reserve/hold allocate nothing.
    scratch: Vec<(u32, f64)>,
    /// Per-machine bus lanes for machines ≥ 1, indexed by machine id and
    /// grown on demand (an absent lane is free at 0.0). Empty for every
    /// single-machine queue, so `Timeline::new` and legacy schedules are
    /// untouched.
    mbus: Vec<f64>,
    /// Per-machine host-CPU lanes for machines ≥ 1 (see `mbus`).
    mhost: Vec<f64>,
    /// Per-machine egress network links, indexed by machine id (machine
    /// 0 included — the network is new, there is no legacy lane to
    /// alias).
    links: Vec<f64>,
}

/// Grow-on-write store into a machine-lane vector (absent lanes are
/// free at 0.0 until first reserved).
fn set_lane(lanes: &mut Vec<f64>, m: u32, t: f64) {
    let m = m as usize;
    if lanes.len() <= m {
        lanes.resize(m + 1, 0.0);
    }
    lanes[m] = t;
}

impl Timeline {
    pub fn new(n_ranks: usize) -> Self {
        Timeline {
            bus: 0.0,
            host: 0.0,
            n_ranks: n_ranks.max(1) as u32,
            spans: vec![(0, 0.0)],
            scratch: Vec::new(),
            mbus: Vec::new(),
            mhost: Vec::new(),
            links: Vec::new(),
        }
    }

    /// Clamp a lane's rank range to the machine.
    fn clamp(&self, r: &Range<u32>) -> (u32, u32) {
        (r.start.min(self.n_ranks), r.end.min(self.n_ranks))
    }

    /// Earliest instant the lane is free.
    pub fn free_at(&self, lane: &Lane) -> f64 {
        match lane {
            Lane::Bus => self.bus,
            Lane::Host => self.host,
            Lane::Ranks(r) => {
                let (lo, hi) = self.clamp(r);
                let mut acc = 0.0f64;
                if lo < hi {
                    let mut k = self.spans.partition_point(|s| s.0 <= lo) - 1;
                    while k < self.spans.len() && self.spans[k].0 < hi {
                        acc = acc.max(self.spans[k].1);
                        k += 1;
                    }
                }
                acc
            }
            Lane::MachineBus(m) => self.mbus.get(*m as usize).copied().unwrap_or(0.0),
            Lane::MachineHost(m) => self.mhost.get(*m as usize).copied().unwrap_or(0.0),
            Lane::Link(m) => self.links.get(*m as usize).copied().unwrap_or(0.0),
        }
    }

    /// Rewrite rank free times on `[lo, hi)` through `f`, preserving the
    /// span invariants (split at the boundaries, coalesce equal
    /// neighbors). Runs through the scratch buffer — no steady-state
    /// allocation.
    fn splice_ranks(&mut self, lo: u32, hi: u32, f: impl Fn(f64) -> f64) {
        if lo >= hi {
            return;
        }
        let mut out = std::mem::take(&mut self.scratch);
        out.clear();
        let push = |out: &mut Vec<(u32, f64)>, start: u32, v: f64| {
            if let Some(&(_, lv)) = out.last() {
                if lv == v {
                    return;
                }
            }
            out.push((start, v));
        };
        let n = self.spans.len();
        for k in 0..n {
            let (s_start, v) = self.spans[k];
            let s_end = if k + 1 < n {
                self.spans[k + 1].0
            } else {
                self.n_ranks
            };
            if s_start < lo.min(s_end) {
                push(&mut out, s_start, v);
            }
            let i_lo = s_start.max(lo);
            let i_hi = s_end.min(hi);
            if i_lo < i_hi {
                push(&mut out, i_lo, f(v));
            }
            if s_end > hi && s_start.max(hi) < s_end {
                push(&mut out, s_start.max(hi), v);
            }
        }
        self.scratch = std::mem::replace(&mut self.spans, out);
    }

    /// Occupy the lane for `secs`, starting no earlier than `ready`.
    /// Returns `(start, finish)`.
    pub fn reserve(&mut self, lane: &Lane, ready: f64, secs: f64) -> (f64, f64) {
        let start = ready.max(self.free_at(lane));
        let finish = start + secs;
        match lane {
            Lane::Bus => self.bus = finish,
            Lane::Host => self.host = finish,
            Lane::Ranks(r) => {
                let (lo, hi) = self.clamp(r);
                self.splice_ranks(lo, hi, |_| finish);
            }
            Lane::MachineBus(m) => set_lane(&mut self.mbus, *m, finish),
            Lane::MachineHost(m) => set_lane(&mut self.mhost, *m, finish),
            Lane::Link(m) => set_lane(&mut self.links, *m, finish),
        }
        (start, finish)
    }

    /// Raise the lane's free time to at least `until` (never lowers it).
    /// The scheduler uses this to keep a tenant's rank slice occupied
    /// through its response pull.
    pub fn hold(&mut self, lane: &Lane, until: f64) {
        match lane {
            Lane::Bus => self.bus = self.bus.max(until),
            Lane::Host => self.host = self.host.max(until),
            Lane::Ranks(r) => {
                let (lo, hi) = self.clamp(r);
                self.splice_ranks(lo, hi, |v| v.max(until));
            }
            Lane::MachineBus(m) => {
                let cur = self.free_at(lane);
                set_lane(&mut self.mbus, *m, cur.max(until));
            }
            Lane::MachineHost(m) => {
                let cur = self.free_at(lane);
                set_lane(&mut self.mhost, *m, cur.max(until));
            }
            Lane::Link(m) => {
                let cur = self.free_at(lane);
                set_lane(&mut self.links, *m, cur.max(until));
            }
        }
    }
}

// --------------------------------------------------------------- schedule

/// Outcome of scheduling a command queue onto the resource timelines.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Per-command start times, indexed by [`CmdId`] (the instant the
    /// command's lane reservation begins; `finish[i] - start[i]` is
    /// exactly the command's seconds). Trace capture reads these.
    pub start: Vec<f64>,
    /// Per-command finish times, indexed by [`CmdId`].
    pub finish: Vec<f64>,
    /// Last finish over all commands — the modeled wall time of the
    /// queue ("critical path" through dependencies *and* resources).
    pub makespan: f64,
    /// Sum of all command seconds (what fully serialized execution,
    /// i.e. the four accounting buckets, charges).
    pub total_secs: f64,
}

impl Schedule {
    /// Seconds the schedule hides relative to fully serialized
    /// execution — the derived `overlapped` credit. `queue_sync`
    /// computes **one** schedule and derives both this credit and the
    /// trace events from it (no second scheduling pass).
    pub fn hidden(&self) -> f64 {
        (self.total_secs - self.makespan).max(0.0)
    }
}

/// Busy seconds and command count of one lane inside a schedule.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct LaneUse {
    /// Seconds the lane held reservations (commands on one lane never
    /// overlap, so this is a plain sum).
    pub busy: f64,
    /// Commands issued on the lane.
    pub cmds: u64,
}

/// Post-hoc observability digest of one [`Schedule`] — everything the
/// telemetry layer records per `queue_sync`. Computed from the finished
/// schedule plus [`CmdQueue::lanes`]/[`CmdQueue::dep_edges`], **never**
/// from inside the scheduling loop, so the hot path and the modeled
/// times are untouched whether or not anyone asks for stats.
#[derive(Clone, Debug, Default)]
pub struct ScheduleStats {
    /// Per-lane usage, ordered by each lane's first command (stable and
    /// executor-independent because command order is).
    pub lanes: Vec<(Lane, LaneUse)>,
    /// Commands whose start time was pinned by a dependency's finish
    /// (rather than lane availability alone) — the queue-level stall
    /// signal the triage report counts per-window.
    pub dep_stalls: u64,
    /// Maximum number of simultaneously in-flight commands.
    pub peak_inflight: u64,
    /// `(time, in-flight count)` after every change event, ascending by
    /// time (schedule-relative; callers offset by their base clock).
    pub inflight: Vec<(f64, u64)>,
    /// Copy of [`Schedule::makespan`].
    pub makespan: f64,
    /// Copy of [`Schedule::hidden`].
    pub hidden: f64,
}

/// Heap key of a dependency-ready command: ordered by feasible start,
/// then by [`CmdId`] — the documented tie-break (lowest id wins on equal
/// start, matching the reference scheduler's first-scan-wins).
#[derive(Debug)]
struct ReadyKey {
    start: f64,
    id: CmdId,
}

impl PartialEq for ReadyKey {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}

impl Eq for ReadyKey {}

impl PartialOrd for ReadyKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for ReadyKey {
    fn cmp(&self, other: &Self) -> Ordering {
        self.start
            .total_cmp(&other.start)
            .then(self.id.cmp(&other.id))
    }
}

/// Incremental accumulator of an open transfer group: members fold into
/// running bounds instead of being buffered, so a group of millions of
/// tiny pushes (full-scale TRNS step 1) costs O(1) memory.
#[derive(Debug)]
struct GroupAcc {
    kind: CmdKind,
    secs: f64,
    dpu_lo: usize,
    dpu_hi: usize,
    read_lo: usize,
    read_hi: usize,
    write_lo: usize,
    write_hi: usize,
    after: Vec<CmdId>,
    bytes: u64,
    req: Option<u64>,
    machine: u32,
    any: bool,
}

impl GroupAcc {
    fn new() -> Self {
        GroupAcc {
            kind: CmdKind::Pull,
            secs: 0.0,
            dpu_lo: usize::MAX,
            dpu_hi: 0,
            read_lo: usize::MAX,
            read_hi: 0,
            write_lo: usize::MAX,
            write_hi: 0,
            after: Vec::new(),
            bytes: 0,
            req: None,
            machine: 0,
            any: false,
        }
    }

    fn fold(&mut self, cmd: CmdMeta) {
        if !self.any {
            self.machine = cmd.machine;
        } else {
            debug_assert_eq!(
                self.machine, cmd.machine,
                "a transfer group cannot span machines"
            );
        }
        self.any = true;
        self.secs += cmd.secs;
        self.dpu_lo = self.dpu_lo.min(cmd.dpus.start);
        self.dpu_hi = self.dpu_hi.max(cmd.dpus.end);
        for r in cmd.reads.as_slice() {
            self.read_lo = self.read_lo.min(r.start);
            self.read_hi = self.read_hi.max(r.end);
        }
        for w in cmd.writes.as_slice() {
            self.write_lo = self.write_lo.min(w.start);
            self.write_hi = self.write_hi.max(w.end);
        }
        for &j in &cmd.after {
            if !self.after.contains(&j) {
                self.after.push(j);
            }
        }
        self.bytes += cmd.bytes;
        if self.req.is_none() {
            self.req = cmd.req;
        }
        if cmd.kind == CmdKind::Push {
            self.kind = CmdKind::Push;
        }
    }

    /// The merged command, or `None` for a group that folded nothing —
    /// an empty `group_begin`/`group_end` pair is a no-op by
    /// construction (it cannot emit a degenerate `usize::MAX`-bounded
    /// command).
    fn into_cmd(self) -> Option<CmdMeta> {
        if !self.any {
            return None;
        }
        let bound = |lo: usize, hi: usize| -> RegionSet {
            if lo < hi {
                RegionSet::One(lo..hi)
            } else {
                RegionSet::Empty
            }
        };
        Some(CmdMeta {
            kind: self.kind,
            secs: self.secs,
            dpus: self.dpu_lo..self.dpu_hi.max(self.dpu_lo),
            reads: bound(self.read_lo, self.read_hi),
            writes: bound(self.write_lo, self.write_hi),
            after: self.after,
            fence: false,
            bytes: self.bytes,
            req: self.req,
            machine: self.machine,
        })
    }
}

/// A recorded program of typed commands plus the scheduling that derives
/// its overlap. Commands execute functionally at enqueue time (outside
/// this module); the queue holds modeled metadata only.
#[derive(Debug, Default)]
pub struct CmdQueue {
    cmds: Vec<CmdMeta>,
    group: Option<GroupAcc>,
}

impl CmdQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.cmds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cmds.is_empty()
    }

    /// Clear recorded commands, keeping the command buffer's capacity —
    /// `PimSet` pools the queue shell across `queue_begin`/`queue_sync`
    /// sessions so steady-state recording stops churning the allocator.
    pub fn reset(&mut self) {
        assert!(self.group.is_none(), "reset with an open transfer group");
        self.cmds.clear();
    }

    /// Append a command; returns its id. Inside an open transfer group
    /// the command folds into the group accumulator and the returned id
    /// is the one the merged command will receive at
    /// [`CmdQueue::group_end`]. Only bus transfers may join a group —
    /// folding a launch or merge would silently drop its lane and fence
    /// semantics, so that is a hard error.
    pub fn push(&mut self, cmd: CmdMeta) -> CmdId {
        debug_assert!(
            cmd.secs >= 0.0,
            "modeled seconds must be non-negative (got {})",
            cmd.secs
        );
        if let Some(g) = self.group.as_mut() {
            assert!(
                matches!(cmd.kind, CmdKind::Push | CmdKind::Pull),
                "only bus transfers can join a transfer group (got {:?})",
                cmd.kind
            );
            g.fold(cmd);
            return self.cmds.len();
        }
        self.cmds.push(cmd);
        self.cmds.len() - 1
    }

    /// Is a transfer group currently open?
    pub fn group_open(&self) -> bool {
        self.group.is_some()
    }

    /// Id of the most recently enqueued command (the prospective merged
    /// id while a non-empty group is open).
    pub fn last_id(&self) -> Option<CmdId> {
        if let Some(g) = &self.group {
            if g.any {
                return Some(self.cmds.len());
            }
        }
        self.cmds.len().checked_sub(1)
    }

    /// Start coalescing subsequently enqueued transfers into one bus
    /// command (see [`CmdQueue::group_end`]). Groups keep scheduling
    /// tractable for workloads that issue thousands of tiny transfers
    /// per request (TRNS step 1) without changing bucket accounting —
    /// the grouped command's seconds are the exact sum of its members'.
    pub fn group_begin(&mut self) {
        assert!(self.group.is_none(), "transfer group already open");
        self.group = Some(GroupAcc::new());
    }

    /// Close the open group: the folded members land as a single bus
    /// command — seconds summed in enqueue order, footprints collapsed
    /// to their bounding regions (conservative: only adds dependencies),
    /// external `after` edges kept. An empty group records nothing.
    pub fn group_end(&mut self) {
        let g = self.group.take().expect("group_end without group_begin");
        if let Some(cmd) = g.into_cmd() {
            self.cmds.push(cmd);
        }
    }

    fn lane_of(&self, i: CmdId, dpus_per_rank: usize, n_ranks: usize) -> Option<Lane> {
        lane_for(&self.cmds[i], dpus_per_rank, n_ranks)
    }

    /// The recorded commands, in enqueue order (trace capture walks
    /// them alongside the [`Schedule`]'s start/finish arrays).
    pub fn cmds(&self) -> &[CmdMeta] {
        &self.cmds
    }

    /// Lane assignment of every recorded command under the given fleet
    /// geometry — `None` for fences (they occupy no resource).
    pub fn lanes(&self, n_ranks: usize, dpus_per_rank: usize) -> Vec<Option<Lane>> {
        (0..self.cmds.len())
            .map(|i| self.lane_of(i, dpus_per_rank, n_ranks))
            .collect()
    }

    /// Per-command dependency lists from the indexed inference:
    /// `deps[i]` holds the earlier commands `i` waits on, ascending.
    /// Trace capture records these as the event dep edges; it is the
    /// same reduced edge set the scheduler issues against.
    pub fn dep_edges(&self) -> Vec<Vec<CmdId>> {
        self.dep_edges_impl(true)
    }

    /// [`CmdQueue::dep_edges`] with [`RegionIndex`] frontier-vector
    /// recycling disabled — the allocation-per-split baseline the
    /// `simulator_hotpath` bench compares the arena against. Produces
    /// the identical edge set.
    pub fn dep_edges_unpooled(&self) -> Vec<Vec<CmdId>> {
        self.dep_edges_impl(false)
    }

    fn dep_edges_impl(&self, pooled: bool) -> Vec<Vec<CmdId>> {
        let DepGraph { out, .. } = infer_deps_with(&self.cmds, pooled);
        let mut deps: Vec<Vec<CmdId>> = vec![Vec::new(); self.cmds.len()];
        for (j, outs) in out.iter().enumerate() {
            for &i in outs {
                deps[i].push(j);
            }
        }
        for d in &mut deps {
            d.sort_unstable();
        }
        deps
    }

    /// Observability digest of a finished schedule (see
    /// [`ScheduleStats`]): per-lane busy/command tallies, dependency
    /// stalls, and the in-flight command profile. Pure read over the
    /// schedule's start/finish arrays — calling it (or not) cannot
    /// perturb any modeled time.
    pub fn schedule_stats(
        &self,
        sched: &Schedule,
        n_ranks: usize,
        dpus_per_rank: usize,
    ) -> ScheduleStats {
        let lanes = self.lanes(n_ranks, dpus_per_rank);
        let deps = self.dep_edges();
        let mut per_lane: Vec<(Lane, LaneUse)> = Vec::new();
        let mut dep_stalls = 0u64;
        // Event sweep for the in-flight profile: +1 at starts, −1 at
        // finishes; finishes sort before starts at equal times so an
        // abutting pair doesn't read as concurrent.
        let mut events: Vec<(f64, i8)> = Vec::with_capacity(2 * self.cmds.len());
        for i in 0..self.cmds.len() {
            let secs = sched.finish[i] - sched.start[i];
            if let Some(lane) = &lanes[i] {
                match per_lane.iter_mut().find(|(l, _)| l == lane) {
                    Some((_, u)) => {
                        u.busy += secs;
                        u.cmds += 1;
                    }
                    None => per_lane.push((lane.clone(), LaneUse { busy: secs, cmds: 1 })),
                }
            }
            let bound = deps[i]
                .iter()
                .map(|&d| sched.finish[d])
                .fold(0.0, f64::max);
            if !deps[i].is_empty() && bound > 0.0 && sched.start[i] == bound {
                dep_stalls += 1;
            }
            events.push((sched.start[i], 1));
            events.push((sched.finish[i], -1));
        }
        events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let mut inflight: Vec<(f64, u64)> = Vec::new();
        let mut cur = 0i64;
        let mut peak = 0u64;
        for (t, d) in events {
            cur += d as i64;
            peak = peak.max(cur as u64);
            match inflight.last_mut() {
                Some(last) if last.0 == t => last.1 = cur as u64,
                _ => inflight.push((t, cur as u64)),
            }
        }
        ScheduleStats {
            lanes: per_lane,
            dep_stalls,
            peak_inflight: peak,
            inflight,
            makespan: sched.makespan,
            hidden: sched.hidden(),
        }
    }

    /// Greedy list schedule over the dependency DAG and the resource
    /// lanes: repeatedly issue the dependency-ready command that can
    /// start earliest (ties: lowest id — see the module invariants).
    /// Deterministic — everything derives from modeled seconds, which
    /// are executor-independent.
    ///
    /// This is the indexed, event-driven fast path: dependency edges
    /// come from [`infer_deps`] (a segment index over byte regions —
    /// near-linear for bounded region palettes, instead of the O(n²)
    /// all-pairs sweep), and the ready set lives in a min-heap keyed by
    /// `(feasible start, id)` with lazy re-keying — a popped entry whose
    /// lane moved while it waited is re-pushed at its recomputed start,
    /// which is sound because lane free times only increase. Overall
    /// O((n + E) log n) scheduling over E inferred edges. Output is
    /// **bit-identical** to [`CmdQueue::schedule_reference`]; property
    /// tests enforce it.
    pub fn schedule(&self, n_ranks: usize, dpus_per_rank: usize) -> Schedule {
        let n = self.cmds.len();
        let DepGraph { out, mut indeg } = infer_deps(&self.cmds);
        let lanes: Vec<Option<Lane>> = (0..n)
            .map(|i| self.lane_of(i, dpus_per_rank, n_ranks))
            .collect();
        let mut tl = Timeline::new(n_ranks);
        let mut start_at = vec![0.0f64; n];
        let mut finish = vec![0.0f64; n];
        // Max finish over each command's dependencies; final once its
        // indegree hits zero (only then does it enter the heap).
        let mut dep_ready = vec![0.0f64; n];
        let mut heap: BinaryHeap<Reverse<ReadyKey>> = BinaryHeap::with_capacity(n.min(1 << 16));
        for (i, lane) in lanes.iter().enumerate() {
            if indeg[i] == 0 {
                let start = match lane {
                    Some(l) => tl.free_at(l),
                    None => 0.0,
                };
                heap.push(Reverse(ReadyKey { start, id: i }));
            }
        }
        let mut total = 0.0f64;
        let mut makespan = 0.0f64;
        let mut done = 0usize;
        while let Some(Reverse(ReadyKey { start, id: i })) = heap.pop() {
            let ready = dep_ready[i];
            // Lazy re-key: lane free times never decrease, so a heap key
            // never overestimates — if the recomputed start grew past the
            // stored key, this entry is stale; re-queue it at its true
            // start. When the key is accurate it is the minimum true
            // (start, id) over all ready commands, exactly the reference
            // scheduler's pick.
            let cur = match &lanes[i] {
                Some(l) => ready.max(tl.free_at(l)),
                None => ready,
            };
            if cur > start {
                heap.push(Reverse(ReadyKey { start: cur, id: i }));
                continue;
            }
            let (s, f) = match &lanes[i] {
                Some(lane) => tl.reserve(lane, ready, self.cmds[i].secs),
                None => (ready, ready + self.cmds[i].secs),
            };
            start_at[i] = s;
            finish[i] = f;
            total += self.cmds[i].secs;
            makespan = makespan.max(f);
            done += 1;
            for &k in &out[i] {
                dep_ready[k] = dep_ready[k].max(f);
                indeg[k] -= 1;
                if indeg[k] == 0 {
                    let start = match &lanes[k] {
                        Some(l) => dep_ready[k].max(tl.free_at(l)),
                        None => dep_ready[k],
                    };
                    heap.push(Reverse(ReadyKey { start, id: k }));
                }
            }
        }
        debug_assert_eq!(done, n, "dependency edges all point backwards");
        Schedule {
            start: start_at,
            finish,
            makespan,
            total_secs: total,
        }
    }

    /// The retained naive scheduler — the executable spec the optimized
    /// [`CmdQueue::schedule`] must match bitwise. O(n²) pairwise
    /// dependency sweep plus an O(n²) greedy ready-scan; kept `pub` so
    /// property tests and the hot-path benches can compare against it.
    pub fn schedule_reference(&self, n_ranks: usize, dpus_per_rank: usize) -> Schedule {
        let n = self.cmds.len();
        let mut deps: Vec<Vec<CmdId>> = vec![Vec::new(); n];
        for i in 0..n {
            for j in 0..i {
                if depends(&self.cmds[j], &self.cmds[i]) {
                    deps[i].push(j);
                }
            }
            for &j in &self.cmds[i].after {
                if j < i {
                    deps[i].push(j);
                }
            }
        }
        let mut tl = Timeline::new(n_ranks);
        let mut start_at = vec![0.0f64; n];
        let mut finish = vec![0.0f64; n];
        let mut done = vec![false; n];
        let mut total = 0.0f64;
        let mut makespan = 0.0f64;
        for _ in 0..n {
            // pick the ready command with the earliest feasible start
            let mut best: Option<(f64, CmdId)> = None;
            for i in 0..n {
                if done[i] {
                    continue;
                }
                let mut ready = 0.0f64;
                let mut blocked = false;
                for &j in &deps[i] {
                    if !done[j] {
                        blocked = true;
                        break;
                    }
                    ready = ready.max(finish[j]);
                }
                if blocked {
                    continue;
                }
                let start = match self.lane_of(i, dpus_per_rank, n_ranks) {
                    Some(lane) => ready.max(tl.free_at(&lane)),
                    None => ready,
                };
                let better = match best {
                    None => true,
                    // strict `<`: on equal starts the first-scanned
                    // (lowest) id wins — the documented tie-break.
                    Some((s, _)) => start < s,
                };
                if better {
                    best = Some((start, i));
                }
            }
            let (_, i) = best.expect("deps point backwards, so some command is always ready");
            let mut ready = 0.0f64;
            for &j in &deps[i] {
                ready = ready.max(finish[j]);
            }
            let (s, f) = match self.lane_of(i, dpus_per_rank, n_ranks) {
                Some(lane) => tl.reserve(&lane, ready, self.cmds[i].secs),
                None => (ready, ready + self.cmds[i].secs),
            };
            start_at[i] = s;
            finish[i] = f;
            done[i] = true;
            total += self.cmds[i].secs;
            makespan = makespan.max(f);
        }
        Schedule {
            start: start_at,
            finish,
            makespan,
            total_secs: total,
        }
    }

    /// Seconds the schedule hides relative to fully serialized
    /// execution — the derived `overlapped` credit. One scheduling
    /// pass; equals [`Schedule::hidden`] of [`CmdQueue::schedule`]
    /// bitwise (regression-tested), so callers that need the schedule
    /// itself (trace capture) call `schedule` once and use both.
    pub fn hidden_secs(&self, n_ranks: usize, dpus_per_rank: usize) -> f64 {
        if self.cmds.is_empty() {
            return 0.0;
        }
        self.schedule(n_ranks, dpus_per_rank).hidden()
    }
}

/// Resource lane a command occupies under the given fleet geometry
/// (`None` for fences). Shared by the queue schedulers and the
/// synchronous trace capture path in `PimSet`, so a traced synchronous
/// op lands on exactly the lane its queued form would.
pub(crate) fn lane_for(c: &CmdMeta, dpus_per_rank: usize, n_ranks: usize) -> Option<Lane> {
    match c.kind {
        CmdKind::Push | CmdKind::Pull => Some(if c.machine == 0 {
            Lane::Bus
        } else {
            Lane::MachineBus(c.machine)
        }),
        CmdKind::HostMerge => Some(if c.machine == 0 {
            Lane::Host
        } else {
            Lane::MachineHost(c.machine)
        }),
        CmdKind::Net => Some(Lane::Link(c.machine)),
        CmdKind::MigrateCopy => Some(if c.machine == 0 {
            Lane::Bus
        } else {
            Lane::MachineBus(c.machine)
        }),
        CmdKind::Fence | CmdKind::MigrateDrain | CmdKind::MigrateResume => None,
        CmdKind::Launch => {
            let per = dpus_per_rank.max(1);
            let lo = (c.dpus.start / per) as u32;
            let hi = if c.dpus.end == 0 {
                lo
            } else {
                ((c.dpus.end - 1) / per + 1) as u32
            };
            Some(Lane::Ranks(lo..hi.min(n_ranks as u32).max(lo)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PER: usize = 4; // DPUs per rank in these tests
    const RANKS: usize = 2;

    fn sched(q: &CmdQueue) -> Schedule {
        q.schedule(RANKS, PER)
    }

    /// Optimized and reference schedulers must agree bitwise on every
    /// output field.
    fn assert_schedules_match(q: &CmdQueue, n_ranks: usize, per: usize) {
        let a = q.schedule(n_ranks, per);
        let b = q.schedule_reference(n_ranks, per);
        assert_eq!(a.finish.len(), b.finish.len());
        for (i, (x, y)) in a.finish.iter().zip(&b.finish).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "finish[{i}]: {x} vs {y}");
        }
        for (i, (x, y)) in a.start.iter().zip(&b.start).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "start[{i}]: {x} vs {y}");
        }
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        assert_eq!(a.total_secs.to_bits(), b.total_secs.to_bits());
    }

    /// Satellite regression: the overlap credit must be exactly what
    /// the **single** `schedule` pass `queue_sync` shares with trace
    /// capture derives — `hidden_secs` and `Schedule::hidden` agree
    /// bitwise, and the recorded start/finish pairs are internally
    /// consistent (`finish − start == secs` for every laned command).
    #[test]
    fn hidden_secs_matches_single_schedule_pass_bitwise() {
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..1024, 0.2, vec![]));
        q.push(CmdMeta::launch(0..8, Access::new().read(0..1024), 1.0));
        q.push(CmdMeta::push(0..8, 1024..2048, 0.3, vec![]));
        q.push(CmdMeta::host_merge(0.05));
        q.push(CmdMeta::pull(0..8, 0..1024, 0.11, vec![]));
        let s = q.schedule(RANKS, PER);
        assert!(s.hidden() > 0.0, "the independent push must hide");
        assert_eq!(q.hidden_secs(RANKS, PER).to_bits(), s.hidden().to_bits());
        for (i, c) in q.cmds().iter().enumerate() {
            assert_eq!(
                (s.start[i] + c.secs).to_bits(),
                s.finish[i].to_bits(),
                "cmd {i}: start+secs must equal finish exactly"
            );
        }
        assert_schedules_match(&q, RANKS, PER);
    }

    #[test]
    fn single_command_is_the_degenerate_timeline() {
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..1024, 0.5, vec![]));
        let s = sched(&q);
        assert_eq!(s.makespan.to_bits(), 0.5f64.to_bits());
        assert_eq!(s.total_secs.to_bits(), s.makespan.to_bits());
        assert_eq!(q.hidden_secs(RANKS, PER), 0.0);
        assert_schedules_match(&q, RANKS, PER);
    }

    #[test]
    fn dependent_chain_equals_sum_bitwise() {
        // push → launch (reads the pushed region) → pull (reads the
        // launch's output): fully dependent, makespan == Σ secs exactly.
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..1024, 0.3, vec![]));
        q.push(CmdMeta::launch(
            0..8,
            Access::new().read(0..1024).write(1024..2048),
            0.7,
        ));
        q.push(CmdMeta::pull(0..8, 1024..2048, 0.11, vec![]));
        let s = sched(&q);
        assert_eq!(s.makespan.to_bits(), s.total_secs.to_bits());
        assert_eq!(q.hidden_secs(RANKS, PER), 0.0);
        assert_schedules_match(&q, RANKS, PER);
    }

    #[test]
    fn independent_push_hides_under_a_launch() {
        // request 0: push A, launch reading A; request 1's double-
        // buffered push B is independent and slides under the launch.
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..1024, 0.2, vec![]));
        q.push(CmdMeta::launch(0..8, Access::new().read(0..1024), 1.0));
        q.push(CmdMeta::push(0..8, 1024..2048, 0.3, vec![]));
        let s = sched(&q);
        // bus: [0,0.2] then [0.2,0.5]; launch on ranks [0.2,1.2]
        assert!((s.makespan - 1.2).abs() < 1e-12, "makespan {}", s.makespan);
        let hidden = q.hidden_secs(RANKS, PER);
        assert!((hidden - 0.3).abs() < 1e-12, "hidden {hidden}");
        assert_schedules_match(&q, RANKS, PER);
    }

    #[test]
    fn war_conflict_serializes_a_push_behind_the_reader() {
        // the second push overwrites the region the launch still reads
        // (no double buffering): it must wait for the launch.
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..1024, 0.2, vec![]));
        q.push(CmdMeta::launch(0..8, Access::new().read(0..1024), 1.0));
        q.push(CmdMeta::push(0..8, 0..1024, 0.3, vec![]));
        let s = sched(&q);
        assert_eq!(s.makespan.to_bits(), s.total_secs.to_bits());
        assert_schedules_match(&q, RANKS, PER);
    }

    #[test]
    fn disjoint_dpu_ranges_never_conflict() {
        let a = CmdMeta::push(0..4, 0..1024, 0.1, vec![]);
        let b = CmdMeta::pull(4..8, 0..1024, 0.1, vec![]);
        assert!(!depends(&a, &b), "same bytes on disjoint DPUs");
        let c = CmdMeta::pull(3..8, 0..1024, 0.1, vec![]);
        assert!(depends(&a, &c), "overlapping DPUs + bytes conflict");
        // the indexed inference agrees with the pairwise spec
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..4, 0..1024, 0.1, vec![]));
        q.push(CmdMeta::pull(4..8, 0..1024, 0.1, vec![]));
        q.push(CmdMeta::pull(3..8, 0..1024, 0.1, vec![]));
        assert_schedules_match(&q, RANKS, PER);
    }

    #[test]
    fn launches_on_disjoint_rank_spans_overlap() {
        let mut q = CmdQueue::new();
        q.push(CmdMeta::launch(0..PER, Access::new().write(0..8), 1.0));
        q.push(CmdMeta::launch(
            PER..2 * PER,
            Access::new().write(8..16),
            1.0,
        ));
        let s = sched(&q);
        assert!(
            (s.makespan - 1.0).abs() < 1e-12,
            "disjoint ranks run concurrently"
        );
        // same span: serialized on the rank lane even without data deps
        let mut q2 = CmdQueue::new();
        q2.push(CmdMeta::launch(0..PER, Access::new().write(0..8), 1.0));
        q2.push(CmdMeta::launch(0..PER, Access::new().write(8..16), 1.0));
        assert!((sched(&q2).makespan - 2.0).abs() < 1e-12);
        assert_schedules_match(&q, RANKS, PER);
        assert_schedules_match(&q2, RANKS, PER);
    }

    #[test]
    fn fence_orders_everything() {
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..8, 0.25, vec![]));
        q.push(CmdMeta::fence());
        q.push(CmdMeta::push(0..8, 1024..1032, 0.25, vec![]));
        q.push(CmdMeta::launch(0..8, Access::new().read(2048..4096), 1.0));
        let s = sched(&q);
        // without the fence the launch (no data deps) would start at 0
        // and the makespan would be 1.0; the fence delays it to 0.25.
        assert!((s.makespan - 1.25).abs() < 1e-12, "makespan {}", s.makespan);
        assert_schedules_match(&q, RANKS, PER);
    }

    #[test]
    fn dep_merge_overlaps_later_bus_traffic_but_fence_merge_does_not() {
        let build = |fenced: bool| {
            let mut q = CmdQueue::new();
            let pull = q.push(CmdMeta::pull(0..8, 0..1024, 0.4, vec![]));
            if fenced {
                q.push(CmdMeta::host_merge(0.5));
            } else {
                q.push(CmdMeta::host_merge_after(0.5, vec![pull]));
            }
            q.push(CmdMeta::push(0..8, 0..1024, 0.4, vec![]));
            q
        };
        // dep'd merge: pull [0,0.4]; merge on host [0.4,0.9]; the push
        // (WAR on the pull's region) rides the bus [0.4,0.8] under it.
        let free = sched(&build(false));
        assert!(
            (free.makespan - 0.9).abs() < 1e-12,
            "makespan {}",
            free.makespan
        );
        // fence merge: strictly serial.
        let fenced = sched(&build(true));
        assert_eq!(fenced.makespan.to_bits(), fenced.total_secs.to_bits());
        assert_schedules_match(&build(false), RANKS, PER);
        assert_schedules_match(&build(true), RANKS, PER);
    }

    #[test]
    fn explicit_after_gates_host_data_flow() {
        let mut q = CmdQueue::new();
        let pull = q.push(CmdMeta::pull(0..8, 0..1024, 0.4, vec![]));
        let merge = q.push(CmdMeta::host_merge_after(0.5, vec![pull]));
        // the next push carries data derived from the merge: without the
        // explicit edge its region (disjoint) would let it start at 0.
        q.push(CmdMeta::push(0..8, 4096..5120, 0.1, vec![merge]));
        let s = sched(&q);
        assert!(
            (s.finish[2] - 1.0).abs() < 1e-12,
            "push waits for the merge"
        );
        assert_schedules_match(&q, RANKS, PER);
    }

    #[test]
    fn grouped_transfers_sum_seconds_and_keep_external_deps() {
        let mut q = CmdQueue::new();
        let anchor = q.push(CmdMeta::pull(0..8, 8192..8200, 0.05, vec![]));
        q.group_begin();
        for i in 0..10usize {
            q.push(CmdMeta::push(
                i % 8..i % 8 + 1,
                i * 64..(i + 1) * 64,
                0.01,
                vec![anchor],
            ));
        }
        q.group_end();
        assert_eq!(q.len(), 2, "ten member transfers merged into one");
        let g = &q.cmds[1];
        assert_eq!(g.kind, CmdKind::Push);
        assert!((g.secs - 0.1).abs() < 1e-12);
        assert_eq!(g.writes, RegionSet::One(0..640));
        assert_eq!(g.after, vec![anchor]);
        // a single-member group stays as-is
        let mut q2 = CmdQueue::new();
        q2.group_begin();
        q2.push(CmdMeta::push(0..1, 0..64, 0.01, vec![]));
        q2.group_end();
        assert_eq!(q2.len(), 1);
    }

    /// Satellite: an empty `group_begin`/`group_end` pair is a no-op —
    /// it records no command at all (not even a degenerate one).
    #[test]
    fn empty_group_is_a_noop() {
        let mut q = CmdQueue::new();
        let anchor = q.push(CmdMeta::push(0..1, 0..64, 0.01, vec![]));
        q.group_begin();
        assert_eq!(
            q.last_id(),
            Some(anchor),
            "an empty open group exposes the previous id"
        );
        q.group_end();
        assert_eq!(q.len(), 1, "empty group records nothing");
        assert_eq!(sched(&q).finish.len(), 1);
        // fully empty queue + empty group
        let mut q2 = CmdQueue::new();
        q2.group_begin();
        q2.group_end();
        assert!(q2.is_empty());
        assert_eq!(q2.last_id(), None);
        assert_eq!(q2.hidden_secs(RANKS, PER), 0.0);
    }

    /// Folding a launch into a bus group would drop its rank-lane and
    /// serialization semantics — a hard error, release builds included.
    #[test]
    #[should_panic(expected = "only bus transfers")]
    fn grouping_a_launch_panics() {
        let mut q = CmdQueue::new();
        q.group_begin();
        q.push(CmdMeta::launch(0..4, Access::new(), 0.1));
    }

    #[test]
    fn reset_clears_commands_and_reuses_the_shell() {
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..1024, 0.5, vec![]));
        q.push(CmdMeta::fence());
        q.reset();
        assert!(q.is_empty());
        assert_eq!(q.last_id(), None);
        // the shell is fully usable again
        q.push(CmdMeta::push(0..8, 0..1024, 0.25, vec![]));
        let s = sched(&q);
        assert_eq!(s.makespan.to_bits(), 0.25f64.to_bits());
    }

    #[test]
    fn timeline_hold_extends_rank_occupancy() {
        let mut tl = Timeline::new(4);
        let lane = Lane::Ranks(1..3);
        let (s, f) = tl.reserve(&lane, 0.5, 1.0);
        assert_eq!((s, f), (0.5, 1.5));
        tl.hold(&lane, 2.0);
        assert_eq!(tl.free_at(&lane), 2.0);
        tl.hold(&lane, 1.0);
        assert_eq!(tl.free_at(&lane), 2.0, "hold never lowers");
        assert_eq!(tl.free_at(&Lane::Ranks(0..1)), 0.0, "other ranks untouched");
        assert_eq!(tl.free_at(&Lane::Bus), 0.0);
    }

    /// The span representation splits at lane boundaries and coalesces
    /// equal neighbors back into single spans.
    #[test]
    fn timeline_spans_split_and_coalesce() {
        let mut tl = Timeline::new(8);
        assert_eq!(tl.spans.len(), 1);
        tl.reserve(&Lane::Ranks(2..5), 0.0, 1.0);
        assert_eq!(tl.spans.len(), 3, "split into [0,2) [2,5) [5,8)");
        assert_eq!(tl.free_at(&Lane::Ranks(0..2)), 0.0);
        assert_eq!(tl.free_at(&Lane::Ranks(2..5)), 1.0);
        assert_eq!(tl.free_at(&Lane::Ranks(4..6)), 1.0, "max over mixed spans");
        assert_eq!(tl.free_at(&Lane::Ranks(5..8)), 0.0);
        // partial-overlap hold splits again and maxes only the overlap
        tl.hold(&Lane::Ranks(4..7), 2.0);
        assert_eq!(tl.free_at(&Lane::Ranks(2..4)), 1.0);
        assert_eq!(tl.free_at(&Lane::Ranks(4..5)), 2.0);
        assert_eq!(tl.free_at(&Lane::Ranks(6..7)), 2.0);
        assert_eq!(tl.free_at(&Lane::Ranks(7..8)), 0.0);
        // a fleet-wide reserve levels everything back to one span
        tl.reserve(&Lane::Ranks(0..8), 0.0, 0.0);
        assert_eq!(tl.spans.len(), 1, "uniform free time coalesces");
        assert_eq!(tl.free_at(&Lane::Ranks(0..8)), 2.0);
        // out-of-machine lane ranges clamp instead of panicking
        assert_eq!(tl.free_at(&Lane::Ranks(6..32)), 2.0);
        tl.hold(&Lane::Ranks(0..32), 3.0);
        assert_eq!(tl.free_at(&Lane::Ranks(0..8)), 3.0);
    }

    /// Satellite: the documented tie-break — equal feasible starts issue
    /// in enqueue order (lowest id first) — on both schedulers, bitwise.
    #[test]
    fn equal_start_ties_issue_in_enqueue_order() {
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..4, 0..64, 0.25, vec![]));
        q.push(CmdMeta::push(4..8, 1024..1088, 0.75, vec![]));
        let s = sched(&q);
        // both are bus commands ready at t=0: id 0 must take the bus
        // first, so finish[0] = 0.25 and finish[1] = 1.0 exactly.
        assert_eq!(s.finish[0].to_bits(), 0.25f64.to_bits());
        assert_eq!(s.finish[1].to_bits(), 1.0f64.to_bits());
        assert_schedules_match(&q, RANKS, PER);
    }

    /// The complement of the tie-break: a strictly earlier feasible
    /// start beats enqueue order (greedy start-time order).
    #[test]
    fn earliest_start_beats_enqueue_order() {
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..1024, 0.2, vec![])); // id 0
        q.push(CmdMeta::launch(0..8, Access::new().read(0..1024), 1.0)); // id 1
        q.push(CmdMeta::push(0..8, 0..1024, 0.3, vec![])); // id 2: WAR-blocked
        q.push(CmdMeta::push(0..8, 4096..4160, 0.1, vec![])); // id 3: independent
        let s = sched(&q);
        // id 3 rides the bus right after push 0 ([0.2, 0.3]) while the
        // WAR-blocked id 2 waits out the launch (finishes at 1.5).
        assert!((s.finish[3] - 0.3).abs() < 1e-12, "finish[3] {}", s.finish[3]);
        assert!(s.finish[3] < s.finish[2]);
        assert!((s.makespan - 1.5).abs() < 1e-12, "makespan {}", s.makespan);
        assert_schedules_match(&q, RANKS, PER);
    }

    #[test]
    fn schedule_is_deterministic() {
        let build = || {
            let mut q = CmdQueue::new();
            for i in 0..20usize {
                match i % 4 {
                    0 => q.push(CmdMeta::push(
                        0..8,
                        (i * 512)..(i * 512 + 256),
                        0.01,
                        vec![],
                    )),
                    1 => q.push(CmdMeta::launch(
                        0..8,
                        Access::new()
                            .read((i - 1) * 512..(i - 1) * 512 + 256)
                            .write(65536..65544),
                        0.05,
                    )),
                    2 => q.push(CmdMeta::pull(0..8, 65536..65544, 0.02, vec![])),
                    _ => q.push(CmdMeta::host_merge_after(0.03, vec![i - 1])),
                };
            }
            q
        };
        let a = sched(&build());
        let b = sched(&build());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        for (x, y) in a.finish.iter().zip(&b.finish) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert!(a.makespan <= a.total_secs + 1e-12);
        assert_schedules_match(&build(), RANKS, PER);
    }

    /// A deliberately messy mixed queue — partial overlaps, fences,
    /// groups, empty footprints, `after` edges — schedules bitwise
    /// identically on the optimized and reference paths.
    #[test]
    fn optimized_matches_reference_on_a_messy_queue() {
        let mut q = CmdQueue::new();
        for i in 0..60usize {
            match i % 6 {
                0 => {
                    q.push(CmdMeta::push(
                        i % 16..i % 16 + 4,
                        (i % 5) * 512..(i % 5) * 512 + 256,
                        0.01 + i as f64 * 1e-3,
                        vec![],
                    ));
                }
                1 => {
                    q.push(CmdMeta::launch(
                        0..8,
                        Access::new().read(0..1024).write(4096..4200),
                        0.05,
                    ));
                }
                2 => {
                    q.push(CmdMeta::pull(4..12, 4096..4200, 0.02, vec![]));
                }
                3 => {
                    let j = q.last_id().expect("commands enqueued");
                    q.push(CmdMeta::host_merge_after(0.03, vec![j]));
                }
                4 if i % 12 == 4 => {
                    q.push(CmdMeta::fence());
                }
                4 => {
                    q.push(CmdMeta::launch(8..16, Access::new(), 0.04));
                }
                _ => {
                    q.group_begin();
                    for k in 0..5usize {
                        q.push(CmdMeta::push(k..k + 1, k * 64..k * 64 + 64, 0.001, vec![]));
                    }
                    q.group_end();
                }
            }
        }
        assert_schedules_match(&q, 4, 4);
        assert_schedules_match(&q, 2, 8);
        assert_schedules_match(&q, 32, 64);
    }

    /// Machine buses are independent resource lanes: same-machine
    /// transfers serialize, cross-machine transfers (disjoint global
    /// DPU indices, so no data deps either) ride in parallel.
    #[test]
    fn machine_buses_are_independent_lanes() {
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..1024, 0.4, vec![]));
        q.push(CmdMeta::push(16..24, 0..1024, 0.4, vec![]).on_machine(1));
        q.push(CmdMeta::push(16..24, 2048..3072, 0.4, vec![]).on_machine(1));
        let s = q.schedule(4, PER);
        assert_eq!(s.finish[0].to_bits(), 0.4f64.to_bits());
        assert_eq!(
            s.finish[1].to_bits(),
            0.4f64.to_bits(),
            "machine 1's bus is free while machine 0 pushes"
        );
        assert_eq!(
            s.finish[2].to_bits(),
            0.8f64.to_bits(),
            "machine 1's second push waits for its own bus"
        );
        assert_schedules_match(&q, 4, PER);
    }

    /// Net commands serialize on the issuing machine's egress link and
    /// overlap across machines — the flat-switch model.
    #[test]
    fn net_serializes_per_link_and_overlaps_across_links() {
        let mut q = CmdQueue::new();
        q.push(CmdMeta::net(0, 0.3, vec![]));
        q.push(CmdMeta::net(0, 0.3, vec![]));
        q.push(CmdMeta::net(1, 0.3, vec![]));
        let s = q.schedule(RANKS, PER);
        assert_eq!(s.finish[0].to_bits(), 0.3f64.to_bits());
        assert_eq!(s.finish[1].to_bits(), 0.6f64.to_bits(), "same link serializes");
        assert_eq!(s.finish[2].to_bits(), 0.3f64.to_bits(), "other link overlaps");
        assert!((s.makespan - 0.6).abs() < 1e-12);
        // a Net gated behind a pull via an explicit edge waits for it
        let mut q2 = CmdQueue::new();
        let pull = q2.push(CmdMeta::pull(0..8, 0..1024, 0.2, vec![]));
        q2.push(CmdMeta::net(0, 0.5, vec![pull]));
        let s2 = q2.schedule(RANKS, PER);
        assert_eq!(s2.makespan.to_bits(), 0.7f64.to_bits());
        assert_schedules_match(&q, RANKS, PER);
        assert_schedules_match(&q2, RANKS, PER);
    }

    /// Machine lanes grow on demand and an absent lane reads free-at-0,
    /// so `Timeline::new` stays geometry-compatible with every existing
    /// single-machine caller.
    #[test]
    fn timeline_machine_lanes_grow_on_demand() {
        let mut tl = Timeline::new(2);
        assert_eq!(tl.free_at(&Lane::MachineBus(3)), 0.0);
        assert_eq!(tl.free_at(&Lane::Link(7)), 0.0);
        let (s, f) = tl.reserve(&Lane::MachineBus(3), 0.0, 1.0);
        assert_eq!((s, f), (0.0, 1.0));
        assert_eq!(tl.free_at(&Lane::MachineBus(3)), 1.0);
        assert_eq!(tl.free_at(&Lane::MachineBus(2)), 0.0, "other machines untouched");
        assert_eq!(tl.free_at(&Lane::Bus), 0.0, "machine 0's bus untouched");
        tl.hold(&Lane::Link(1), 2.0);
        assert_eq!(tl.free_at(&Lane::Link(1)), 2.0);
        tl.hold(&Lane::Link(1), 0.5);
        assert_eq!(tl.free_at(&Lane::Link(1)), 2.0, "hold never lowers");
        tl.hold(&Lane::MachineHost(2), 1.5);
        assert_eq!(tl.free_at(&Lane::MachineHost(2)), 1.5);
    }

    /// A transfer group records the machine of its members, so grouped
    /// cluster scatters land on the right per-machine bus lane.
    #[test]
    fn grouped_transfers_carry_their_machine() {
        let mut q = CmdQueue::new();
        q.group_begin();
        q.push(CmdMeta::push(16..17, 0..64, 0.01, vec![]).on_machine(2));
        q.push(CmdMeta::push(17..18, 64..128, 0.01, vec![]).on_machine(2));
        q.group_end();
        assert_eq!(q.len(), 1);
        assert_eq!(q.cmds()[0].machine, 2);
        assert_eq!(
            q.lanes(RANKS, PER)[0],
            Some(Lane::MachineBus(2)),
            "the merged command rides machine 2's bus"
        );
    }

    /// A messy multi-machine queue — per-machine transfers, launches on
    /// machine-disjoint global DPU spans, Net collectives, merges, and
    /// fences — schedules bitwise identically on both schedulers.
    #[test]
    fn optimized_matches_reference_with_machines_and_net() {
        let mut q = CmdQueue::new();
        for m in 0..4u32 {
            let base = m as usize * 16;
            let push = q.push(
                CmdMeta::push(base..base + 16, 0..1024, 0.02 + m as f64 * 1e-3, vec![])
                    .on_machine(m),
            );
            q.push(
                CmdMeta::launch(
                    base..base + 16,
                    Access::new().read(0..1024).write(4096..4160),
                    0.05,
                )
                .on_machine(m),
            );
            let pull =
                q.push(CmdMeta::pull(base..base + 16, 4096..4160, 0.01, vec![]).on_machine(m));
            q.push(CmdMeta::net(m, 0.015, vec![pull]));
            q.push(CmdMeta::host_merge_after(0.01, vec![push]).on_machine(m));
        }
        q.push(CmdMeta::fence());
        for m in 0..4u32 {
            q.push(CmdMeta::net(m, 0.02, vec![]));
        }
        // 4 machines × 4 ranks of 4 DPUs = global geometry (16, 4)
        assert_schedules_match(&q, 16, 4);
        let s = q.schedule(16, 4);
        assert!(s.hidden() > 0.0, "cross-machine work must overlap");
    }

    /// The pooled (arena) and unpooled dependency inference emit the
    /// same edge set on a fence-heavy queue — the recycling is a pure
    /// allocation optimization.
    #[test]
    fn pooled_and_unpooled_dep_edges_agree() {
        let mut q = CmdQueue::new();
        for i in 0..200usize {
            match i % 5 {
                0 => {
                    q.push(CmdMeta::push(
                        i % 8..i % 8 + 2,
                        (i % 7) * 256..(i % 7) * 256 + 300,
                        0.01,
                        vec![],
                    ));
                }
                1 => {
                    q.push(CmdMeta::launch(
                        0..8,
                        Access::new().read(0..2048).write(8192..8300),
                        0.05,
                    ));
                }
                2 => {
                    q.push(CmdMeta::pull(2..10, 8192..8300, 0.02, vec![]));
                }
                3 if i % 20 == 3 => {
                    q.push(CmdMeta::fence());
                }
                3 => {
                    q.push(CmdMeta::host_merge(0.01));
                }
                _ => {
                    q.push(CmdMeta::push((i / 3) % 4..(i / 3) % 4 + 1, 0..128, 0.001, vec![]));
                }
            }
        }
        assert_eq!(q.dep_edges(), q.dep_edges_unpooled());
    }
}
