//! Async command queues: one resource-timeline model for launches,
//! transfers, and their overlap.
//!
//! The real UPMEM SDK exposes exactly one abstraction for the paper's §6
//! "overlap CPU-DPU transfers with kernel execution" recommendation:
//! asynchronous operation queues (`dpu_launch(DPU_ASYNCHRONOUS)` +
//! `dpu_sync`), emphasized again in the follow-on "Benchmarking
//! Memory-Centric Computing Systems" (arXiv:2110.01709). This module is
//! the modeled analogue: a [`CmdQueue`] of typed commands
//! ([`CmdKind`]: `Push` / `Pull` / `Launch` / `HostMerge` / `Fence`)
//! scheduled onto three kinds of modeled resource lanes ([`Lane`]):
//!
//! * **one serialized host bus** — every CPU↔DPU transfer occupies it,
//!   whatever rank it targets (§5.1.1: "these transfers are not
//!   simultaneous across ranks");
//! * **per-rank kernel lanes** — launches occupy the lanes of the ranks
//!   they run on, so kernels on disjoint rank sets overlap (the
//!   concurrency the multi-tenant scheduler's rank slicing buys);
//! * **the host CPU** — `HostMerge` commands (frontier unions, partial
//!   result merges) occupy it and may overlap bus and kernel activity.
//!
//! Ordering between commands is **inferred from the `Symbol` byte
//! regions each command reads and writes** (RAW / WAR / WAW overlap on
//! intersecting DPU ranges), plus explicit `after` edges for host-side
//! data flow the region model cannot see (a merge consumes the host
//! image of a just-pulled region). [`CmdQueue::schedule`] then runs a
//! greedy list schedule: at every step the dependency-ready command that
//! can start earliest issues next — so an independent push (e.g. the
//! *next* request's double-buffered input) slides under a running
//! kernel, exactly the software pipelining an async UPMEM program
//! expresses by issuing work before `dpu_sync`.
//!
//! The derived quantity is the **makespan** of the scheduled timeline;
//! `PimSet::queue_sync` folds `sum(command secs) − makespan` into
//! [`super::TimeBreakdown::overlapped`]. A queue with a single command —
//! what every synchronous `PimSet` call degenerates to — has
//! `makespan == secs`, so the credit is exactly zero and synchronous
//! accounting is bit-identical to the pre-queue model. A fully dependent
//! chain likewise folds to `makespan == sum` (the same left-to-right
//! float accumulation), so `overlapped` is zero whenever nothing can
//! actually overlap.
//!
//! Functionally nothing is reordered: commands *execute* immediately, in
//! program order, through the same `FleetExecutor`/`TransferEngine`
//! paths as synchronous calls — the queue records modeled metadata only.
//! On today's shipping hardware a rank's MRAM cannot be touched while
//! its DPUs run, so (as with the retired batch-credit model) the
//! launch-concurrent transfer portion of the credit is the §6 **what-if**
//! the paper argues for, not a property of the 2021 SDK.

use std::ops::Range;

/// Index of a command within its [`CmdQueue`] (returned by enqueue,
/// consumed by explicit `after` dependencies).
pub type CmdId = usize;

/// The command vocabulary — one variant per kind of modeled work.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CmdKind {
    /// Host → MRAM transfer (any distribution; occupies the bus).
    Push,
    /// MRAM → host transfer (occupies the bus).
    Pull,
    /// Kernel launch (occupies the lanes of the ranks it runs on).
    Launch,
    /// Host-side merge compute (occupies the host CPU lane).
    HostMerge,
    /// Synchronization barrier: waits for everything enqueued before it
    /// and blocks everything after. Zero modeled seconds.
    Fence,
}

/// Declared MRAM footprint of a launch: the byte regions its kernel
/// reads and writes (built from [`super::Symbol::region`]). Launches
/// enqueued without a declaration conservatively touch the whole bank,
/// which serializes them against every transfer — safe, and exactly the
/// degenerate timeline the synchronous shim wants.
#[derive(Clone, Debug, Default)]
pub struct Access {
    pub reads: Vec<Range<usize>>,
    pub writes: Vec<Range<usize>>,
}

impl Access {
    pub fn new() -> Self {
        Self::default()
    }

    /// Declare a byte region the kernel reads (builder style).
    pub fn read(mut self, r: Range<usize>) -> Self {
        self.reads.push(r);
        self
    }

    /// Declare a byte region the kernel writes.
    pub fn write(mut self, r: Range<usize>) -> Self {
        self.writes.push(r);
        self
    }
}

/// One recorded command: kind, modeled seconds, and the footprint the
/// dependency inference works from.
#[derive(Clone, Debug)]
pub struct CmdMeta {
    pub kind: CmdKind,
    /// Modeled seconds this command occupies its lane.
    pub secs: f64,
    /// DPU index range the command touches (commands on disjoint DPU
    /// ranges never conflict through memory).
    pub dpus: Range<usize>,
    /// MRAM byte regions read / written (fleet-shared address space).
    pub reads: Vec<Range<usize>>,
    pub writes: Vec<Range<usize>>,
    /// Explicit extra dependencies (host-side data flow).
    pub after: Vec<CmdId>,
    /// Fence semantics: conflicts with every other command.
    pub fence: bool,
}

impl CmdMeta {
    /// A host→MRAM transfer writing `bytes` on `dpus`.
    pub fn push(dpus: Range<usize>, bytes: Range<usize>, secs: f64, after: Vec<CmdId>) -> Self {
        CmdMeta {
            kind: CmdKind::Push,
            secs,
            dpus,
            reads: Vec::new(),
            writes: vec![bytes],
            after,
            fence: false,
        }
    }

    /// An MRAM→host transfer reading `bytes` on `dpus`.
    pub fn pull(dpus: Range<usize>, bytes: Range<usize>, secs: f64, after: Vec<CmdId>) -> Self {
        CmdMeta {
            kind: CmdKind::Pull,
            secs,
            dpus,
            reads: vec![bytes],
            writes: Vec::new(),
            after,
            fence: false,
        }
    }

    /// A launch with a declared footprint.
    pub fn launch(dpus: Range<usize>, acc: Access, secs: f64) -> Self {
        CmdMeta {
            kind: CmdKind::Launch,
            secs,
            dpus,
            reads: acc.reads,
            writes: acc.writes,
            after: Vec::new(),
            fence: false,
        }
    }

    /// A launch with no declaration: conservatively reads and writes the
    /// whole `mram_bytes` bank, serializing against every transfer on
    /// its DPUs.
    pub fn launch_full(dpus: Range<usize>, mram_bytes: usize, secs: f64) -> Self {
        Self::launch(
            dpus,
            Access::new().read(0..mram_bytes).write(0..mram_bytes),
            secs,
        )
    }

    /// A host merge with fence semantics (no declared data flow — the
    /// conservative default of `PimSet::host_merge`).
    pub fn host_merge(secs: f64) -> Self {
        CmdMeta {
            kind: CmdKind::HostMerge,
            secs,
            dpus: 0..0,
            reads: Vec::new(),
            writes: Vec::new(),
            after: Vec::new(),
            fence: true,
        }
    }

    /// A host merge depending only on the listed commands (the pulls
    /// whose host-side images it consumes) — the precise form that lets
    /// merge compute overlap later bus traffic.
    pub fn host_merge_after(secs: f64, after: Vec<CmdId>) -> Self {
        CmdMeta {
            kind: CmdKind::HostMerge,
            secs,
            dpus: 0..0,
            reads: Vec::new(),
            writes: Vec::new(),
            after,
            fence: false,
        }
    }

    /// A zero-second synchronization barrier.
    pub fn fence() -> Self {
        CmdMeta {
            kind: CmdKind::Fence,
            secs: 0.0,
            dpus: 0..0,
            reads: Vec::new(),
            writes: Vec::new(),
            after: Vec::new(),
            fence: true,
        }
    }
}

fn ranges_overlap(a: &Range<usize>, b: &Range<usize>) -> bool {
    a.start < b.end && b.start < a.end
}

fn any_overlap(a: &[Range<usize>], b: &[Range<usize>]) -> bool {
    a.iter().any(|ra| b.iter().any(|rb| ranges_overlap(ra, rb)))
}

/// Must `b` wait for `a` (enqueued earlier)? True on fences and on any
/// RAW / WAR / WAW byte overlap over intersecting DPU ranges.
fn depends(a: &CmdMeta, b: &CmdMeta) -> bool {
    if a.fence || b.fence {
        return true;
    }
    if !ranges_overlap(&a.dpus, &b.dpus) {
        return false;
    }
    any_overlap(&a.writes, &b.writes)
        || any_overlap(&a.writes, &b.reads)
        || any_overlap(&a.reads, &b.writes)
}

// ---------------------------------------------------------------- timeline

/// A modeled resource lane (see the module docs). Rank lanes are indexed
/// relative to the owning fleet/machine.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Lane {
    /// The one serialized host memory bus (all CPU↔DPU transfers).
    Bus,
    /// The host CPU (merge compute).
    Host,
    /// The kernel lanes of a contiguous rank span.
    Ranks(Range<u32>),
}

/// Free-time bookkeeping of every lane: one bus, one host CPU, `n`
/// ranks. Shared by [`CmdQueue::schedule`] and the multi-tenant
/// [`super::Scheduler`], so both model the machine identically.
#[derive(Clone, Debug)]
pub struct Timeline {
    bus: f64,
    host: f64,
    ranks: Vec<f64>,
}

impl Timeline {
    pub fn new(n_ranks: usize) -> Self {
        Timeline {
            bus: 0.0,
            host: 0.0,
            ranks: vec![0.0; n_ranks.max(1)],
        }
    }

    /// Earliest instant the lane is free.
    pub fn free_at(&self, lane: &Lane) -> f64 {
        match lane {
            Lane::Bus => self.bus,
            Lane::Host => self.host,
            Lane::Ranks(r) => r
                .clone()
                .map(|i| self.ranks[i as usize])
                .fold(0.0, f64::max),
        }
    }

    /// Occupy the lane for `secs`, starting no earlier than `ready`.
    /// Returns `(start, finish)`.
    pub fn reserve(&mut self, lane: &Lane, ready: f64, secs: f64) -> (f64, f64) {
        let start = ready.max(self.free_at(lane));
        let finish = start + secs;
        match lane {
            Lane::Bus => self.bus = finish,
            Lane::Host => self.host = finish,
            Lane::Ranks(r) => {
                for i in r.clone() {
                    self.ranks[i as usize] = finish;
                }
            }
        }
        (start, finish)
    }

    /// Raise the lane's free time to at least `until` (never lowers it).
    /// The scheduler uses this to keep a tenant's rank slice occupied
    /// through its response pull.
    pub fn hold(&mut self, lane: &Lane, until: f64) {
        match lane {
            Lane::Bus => self.bus = self.bus.max(until),
            Lane::Host => self.host = self.host.max(until),
            Lane::Ranks(r) => {
                for i in r.clone() {
                    let f = &mut self.ranks[i as usize];
                    *f = f.max(until);
                }
            }
        }
    }
}

// --------------------------------------------------------------- schedule

/// Outcome of scheduling a command queue onto the resource timelines.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// Per-command finish times, indexed by [`CmdId`].
    pub finish: Vec<f64>,
    /// Last finish over all commands — the modeled wall time of the
    /// queue ("critical path" through dependencies *and* resources).
    pub makespan: f64,
    /// Sum of all command seconds (what fully serialized execution,
    /// i.e. the four accounting buckets, charges).
    pub total_secs: f64,
}

/// Incremental accumulator of an open transfer group: members fold into
/// running bounds instead of being buffered, so a group of millions of
/// tiny pushes (full-scale TRNS step 1) costs O(1) memory.
#[derive(Debug)]
struct GroupAcc {
    kind: CmdKind,
    secs: f64,
    dpu_lo: usize,
    dpu_hi: usize,
    read_lo: usize,
    read_hi: usize,
    write_lo: usize,
    write_hi: usize,
    after: Vec<CmdId>,
    any: bool,
}

impl GroupAcc {
    fn new() -> Self {
        GroupAcc {
            kind: CmdKind::Pull,
            secs: 0.0,
            dpu_lo: usize::MAX,
            dpu_hi: 0,
            read_lo: usize::MAX,
            read_hi: 0,
            write_lo: usize::MAX,
            write_hi: 0,
            after: Vec::new(),
            any: false,
        }
    }

    fn fold(&mut self, cmd: CmdMeta) {
        self.any = true;
        self.secs += cmd.secs;
        self.dpu_lo = self.dpu_lo.min(cmd.dpus.start);
        self.dpu_hi = self.dpu_hi.max(cmd.dpus.end);
        for r in &cmd.reads {
            self.read_lo = self.read_lo.min(r.start);
            self.read_hi = self.read_hi.max(r.end);
        }
        for w in &cmd.writes {
            self.write_lo = self.write_lo.min(w.start);
            self.write_hi = self.write_hi.max(w.end);
        }
        for &j in &cmd.after {
            if !self.after.contains(&j) {
                self.after.push(j);
            }
        }
        if cmd.kind == CmdKind::Push {
            self.kind = CmdKind::Push;
        }
    }

    fn into_cmd(self) -> CmdMeta {
        let bound = |lo: usize, hi: usize| -> Vec<Range<usize>> {
            if lo < hi {
                vec![lo..hi]
            } else {
                Vec::new()
            }
        };
        CmdMeta {
            kind: self.kind,
            secs: self.secs,
            dpus: self.dpu_lo..self.dpu_hi.max(self.dpu_lo),
            reads: bound(self.read_lo, self.read_hi),
            writes: bound(self.write_lo, self.write_hi),
            after: self.after,
            fence: false,
        }
    }
}

/// A recorded program of typed commands plus the scheduling that derives
/// its overlap. Commands execute functionally at enqueue time (outside
/// this module); the queue holds modeled metadata only.
#[derive(Debug, Default)]
pub struct CmdQueue {
    cmds: Vec<CmdMeta>,
    group: Option<GroupAcc>,
}

impl CmdQueue {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn len(&self) -> usize {
        self.cmds.len()
    }

    pub fn is_empty(&self) -> bool {
        self.cmds.is_empty()
    }

    /// Append a command; returns its id. Inside an open transfer group
    /// the command folds into the group accumulator and the returned id
    /// is the one the merged command will receive at
    /// [`CmdQueue::group_end`]. Only bus transfers may join a group —
    /// folding a launch or merge would silently drop its lane and fence
    /// semantics, so that is a hard error.
    pub fn push(&mut self, cmd: CmdMeta) -> CmdId {
        if let Some(g) = self.group.as_mut() {
            assert!(
                matches!(cmd.kind, CmdKind::Push | CmdKind::Pull),
                "only bus transfers can join a transfer group (got {:?})",
                cmd.kind
            );
            g.fold(cmd);
            return self.cmds.len();
        }
        self.cmds.push(cmd);
        self.cmds.len() - 1
    }

    /// Is a transfer group currently open?
    pub fn group_open(&self) -> bool {
        self.group.is_some()
    }

    /// Id of the most recently enqueued command (the prospective merged
    /// id while a non-empty group is open).
    pub fn last_id(&self) -> Option<CmdId> {
        if let Some(g) = &self.group {
            if g.any {
                return Some(self.cmds.len());
            }
        }
        self.cmds.len().checked_sub(1)
    }

    /// Start coalescing subsequently enqueued transfers into one bus
    /// command (see [`CmdQueue::group_end`]). Groups keep scheduling
    /// tractable for workloads that issue thousands of tiny transfers
    /// per request (TRNS step 1) without changing bucket accounting —
    /// the grouped command's seconds are the exact sum of its members'.
    pub fn group_begin(&mut self) {
        assert!(self.group.is_none(), "transfer group already open");
        self.group = Some(GroupAcc::new());
    }

    /// Close the open group: the folded members land as a single bus
    /// command — seconds summed in enqueue order, footprints collapsed
    /// to their bounding regions (conservative: only adds dependencies),
    /// external `after` edges kept. An empty group records nothing.
    pub fn group_end(&mut self) {
        let g = self.group.take().expect("group_end without group_begin");
        if g.any {
            self.cmds.push(g.into_cmd());
        }
    }

    fn lane_of(&self, i: CmdId, dpus_per_rank: usize, n_ranks: usize) -> Option<Lane> {
        let c = &self.cmds[i];
        match c.kind {
            CmdKind::Push | CmdKind::Pull => Some(Lane::Bus),
            CmdKind::HostMerge => Some(Lane::Host),
            CmdKind::Fence => None,
            CmdKind::Launch => {
                let per = dpus_per_rank.max(1);
                let lo = (c.dpus.start / per) as u32;
                let hi = if c.dpus.end == 0 {
                    lo
                } else {
                    ((c.dpus.end - 1) / per + 1) as u32
                };
                Some(Lane::Ranks(lo..hi.min(n_ranks as u32).max(lo)))
            }
        }
    }

    /// Greedy list schedule over the dependency DAG and the resource
    /// lanes: repeatedly issue the dependency-ready command that can
    /// start earliest (ties: enqueue order). Deterministic — everything
    /// derives from modeled seconds, which are executor-independent.
    ///
    /// Complexity is O(n²) in recorded commands (pairwise dependency
    /// inference plus the greedy pick loop). All shipped surfaces stay
    /// in the low thousands per batch — transfer storms coalesce via
    /// [`CmdQueue::group_begin`] — but a hand-rolled pipelined run that
    /// records tens of thousands of ungrouped commands (e.g. BFS on
    /// thousands of DPUs, whose per-level pulls need individual ids)
    /// will pay a noticeably slow `sync`.
    pub fn schedule(&self, n_ranks: usize, dpus_per_rank: usize) -> Schedule {
        let n = self.cmds.len();
        let mut deps: Vec<Vec<CmdId>> = vec![Vec::new(); n];
        for i in 0..n {
            for j in 0..i {
                if depends(&self.cmds[j], &self.cmds[i]) {
                    deps[i].push(j);
                }
            }
            for &j in &self.cmds[i].after {
                if j < i {
                    deps[i].push(j);
                }
            }
        }
        let mut tl = Timeline::new(n_ranks);
        let mut finish = vec![0.0f64; n];
        let mut done = vec![false; n];
        let mut total = 0.0f64;
        let mut makespan = 0.0f64;
        for _ in 0..n {
            // pick the ready command with the earliest feasible start
            let mut best: Option<(f64, CmdId)> = None;
            for i in 0..n {
                if done[i] {
                    continue;
                }
                let mut ready = 0.0f64;
                let mut blocked = false;
                for &j in &deps[i] {
                    if !done[j] {
                        blocked = true;
                        break;
                    }
                    ready = ready.max(finish[j]);
                }
                if blocked {
                    continue;
                }
                let start = match self.lane_of(i, dpus_per_rank, n_ranks) {
                    Some(lane) => ready.max(tl.free_at(&lane)),
                    None => ready,
                };
                let better = match best {
                    None => true,
                    Some((s, _)) => start < s,
                };
                if better {
                    best = Some((start, i));
                }
            }
            let (_, i) = best.expect("deps point backwards, so some command is always ready");
            let mut ready = 0.0f64;
            for &j in &deps[i] {
                ready = ready.max(finish[j]);
            }
            let f = match self.lane_of(i, dpus_per_rank, n_ranks) {
                Some(lane) => tl.reserve(&lane, ready, self.cmds[i].secs).1,
                None => ready + self.cmds[i].secs,
            };
            finish[i] = f;
            done[i] = true;
            total += self.cmds[i].secs;
            makespan = makespan.max(f);
        }
        Schedule { finish, makespan, total_secs: total }
    }

    /// Seconds the schedule hides relative to fully serialized
    /// execution — the derived `overlapped` credit.
    pub fn hidden_secs(&self, n_ranks: usize, dpus_per_rank: usize) -> f64 {
        if self.cmds.is_empty() {
            return 0.0;
        }
        let s = self.schedule(n_ranks, dpus_per_rank);
        (s.total_secs - s.makespan).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const PER: usize = 4; // DPUs per rank in these tests
    const RANKS: usize = 2;

    fn sched(q: &CmdQueue) -> Schedule {
        q.schedule(RANKS, PER)
    }

    #[test]
    fn single_command_is_the_degenerate_timeline() {
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..1024, 0.5, vec![]));
        let s = sched(&q);
        assert_eq!(s.makespan.to_bits(), 0.5f64.to_bits());
        assert_eq!(s.total_secs.to_bits(), s.makespan.to_bits());
        assert_eq!(q.hidden_secs(RANKS, PER), 0.0);
    }

    #[test]
    fn dependent_chain_equals_sum_bitwise() {
        // push → launch (reads the pushed region) → pull (reads the
        // launch's output): fully dependent, makespan == Σ secs exactly.
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..1024, 0.3, vec![]));
        q.push(CmdMeta::launch(
            0..8,
            Access::new().read(0..1024).write(1024..2048),
            0.7,
        ));
        q.push(CmdMeta::pull(0..8, 1024..2048, 0.11, vec![]));
        let s = sched(&q);
        assert_eq!(s.makespan.to_bits(), s.total_secs.to_bits());
        assert_eq!(q.hidden_secs(RANKS, PER), 0.0);
    }

    #[test]
    fn independent_push_hides_under_a_launch() {
        // request 0: push A, launch reading A; request 1's double-
        // buffered push B is independent and slides under the launch.
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..1024, 0.2, vec![]));
        q.push(CmdMeta::launch(0..8, Access::new().read(0..1024), 1.0));
        q.push(CmdMeta::push(0..8, 1024..2048, 0.3, vec![]));
        let s = sched(&q);
        // bus: [0,0.2] then [0.2,0.5]; launch on ranks [0.2,1.2]
        assert!((s.makespan - 1.2).abs() < 1e-12, "makespan {}", s.makespan);
        let hidden = q.hidden_secs(RANKS, PER);
        assert!((hidden - 0.3).abs() < 1e-12, "hidden {hidden}");
    }

    #[test]
    fn war_conflict_serializes_a_push_behind_the_reader() {
        // the second push overwrites the region the launch still reads
        // (no double buffering): it must wait for the launch.
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..1024, 0.2, vec![]));
        q.push(CmdMeta::launch(0..8, Access::new().read(0..1024), 1.0));
        q.push(CmdMeta::push(0..8, 0..1024, 0.3, vec![]));
        let s = sched(&q);
        assert_eq!(s.makespan.to_bits(), s.total_secs.to_bits());
    }

    #[test]
    fn disjoint_dpu_ranges_never_conflict() {
        let a = CmdMeta::push(0..4, 0..1024, 0.1, vec![]);
        let b = CmdMeta::pull(4..8, 0..1024, 0.1, vec![]);
        assert!(!depends(&a, &b), "same bytes on disjoint DPUs");
        let c = CmdMeta::pull(3..8, 0..1024, 0.1, vec![]);
        assert!(depends(&a, &c), "overlapping DPUs + bytes conflict");
    }

    #[test]
    fn launches_on_disjoint_rank_spans_overlap() {
        let mut q = CmdQueue::new();
        q.push(CmdMeta::launch(0..PER, Access::new().write(0..8), 1.0));
        q.push(CmdMeta::launch(
            PER..2 * PER,
            Access::new().write(8..16),
            1.0,
        ));
        let s = sched(&q);
        assert!((s.makespan - 1.0).abs() < 1e-12, "disjoint ranks run concurrently");
        // same span: serialized on the rank lane even without data deps
        let mut q2 = CmdQueue::new();
        q2.push(CmdMeta::launch(0..PER, Access::new().write(0..8), 1.0));
        q2.push(CmdMeta::launch(0..PER, Access::new().write(8..16), 1.0));
        assert!((sched(&q2).makespan - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fence_orders_everything() {
        let mut q = CmdQueue::new();
        q.push(CmdMeta::push(0..8, 0..8, 0.25, vec![]));
        q.push(CmdMeta::fence());
        q.push(CmdMeta::push(0..8, 1024..1032, 0.25, vec![]));
        q.push(CmdMeta::launch(0..8, Access::new().read(2048..4096), 1.0));
        let s = sched(&q);
        // without the fence the launch (no data deps) would start at 0
        // and the makespan would be 1.0; the fence delays it to 0.25.
        assert!((s.makespan - 1.25).abs() < 1e-12, "makespan {}", s.makespan);
    }

    #[test]
    fn dep_merge_overlaps_later_bus_traffic_but_fence_merge_does_not() {
        let build = |fenced: bool| {
            let mut q = CmdQueue::new();
            let pull = q.push(CmdMeta::pull(0..8, 0..1024, 0.4, vec![]));
            if fenced {
                q.push(CmdMeta::host_merge(0.5));
            } else {
                q.push(CmdMeta::host_merge_after(0.5, vec![pull]));
            }
            q.push(CmdMeta::push(0..8, 0..1024, 0.4, vec![]));
            q
        };
        // dep'd merge: pull [0,0.4]; merge on host [0.4,0.9]; the push
        // (WAR on the pull's region) rides the bus [0.4,0.8] under it.
        let free = sched(&build(false));
        assert!((free.makespan - 0.9).abs() < 1e-12, "makespan {}", free.makespan);
        // fence merge: strictly serial.
        let fenced = sched(&build(true));
        assert_eq!(fenced.makespan.to_bits(), fenced.total_secs.to_bits());
    }

    #[test]
    fn explicit_after_gates_host_data_flow() {
        let mut q = CmdQueue::new();
        let pull = q.push(CmdMeta::pull(0..8, 0..1024, 0.4, vec![]));
        let merge = q.push(CmdMeta::host_merge_after(0.5, vec![pull]));
        // the next push carries data derived from the merge: without the
        // explicit edge its region (disjoint) would let it start at 0.
        q.push(CmdMeta::push(0..8, 4096..5120, 0.1, vec![merge]));
        let s = sched(&q);
        assert!((s.finish[2] - 1.0).abs() < 1e-12, "push waits for the merge");
    }

    #[test]
    fn grouped_transfers_sum_seconds_and_keep_external_deps() {
        let mut q = CmdQueue::new();
        let anchor = q.push(CmdMeta::pull(0..8, 8192..8200, 0.05, vec![]));
        q.group_begin();
        for i in 0..10usize {
            q.push(CmdMeta::push(
                i % 8..i % 8 + 1,
                i * 64..(i + 1) * 64,
                0.01,
                vec![anchor],
            ));
        }
        q.group_end();
        assert_eq!(q.len(), 2, "ten member transfers merged into one");
        let g = &q.cmds[1];
        assert_eq!(g.kind, CmdKind::Push);
        assert!((g.secs - 0.1).abs() < 1e-12);
        assert_eq!(g.writes, vec![0..640]);
        assert_eq!(g.after, vec![anchor]);
        // a single-member group stays as-is
        let mut q2 = CmdQueue::new();
        q2.group_begin();
        q2.push(CmdMeta::push(0..1, 0..64, 0.01, vec![]));
        q2.group_end();
        assert_eq!(q2.len(), 1);
    }

    /// Folding a launch into a bus group would drop its rank-lane and
    /// serialization semantics — a hard error, release builds included.
    #[test]
    #[should_panic(expected = "only bus transfers")]
    fn grouping_a_launch_panics() {
        let mut q = CmdQueue::new();
        q.group_begin();
        q.push(CmdMeta::launch(0..4, Access::new(), 0.1));
    }

    #[test]
    fn timeline_hold_extends_rank_occupancy() {
        let mut tl = Timeline::new(4);
        let lane = Lane::Ranks(1..3);
        let (s, f) = tl.reserve(&lane, 0.5, 1.0);
        assert_eq!((s, f), (0.5, 1.5));
        tl.hold(&lane, 2.0);
        assert_eq!(tl.free_at(&lane), 2.0);
        tl.hold(&lane, 1.0);
        assert_eq!(tl.free_at(&lane), 2.0, "hold never lowers");
        assert_eq!(tl.free_at(&Lane::Ranks(0..1)), 0.0, "other ranks untouched");
        assert_eq!(tl.free_at(&Lane::Bus), 0.0);
    }

    #[test]
    fn schedule_is_deterministic() {
        let build = || {
            let mut q = CmdQueue::new();
            for i in 0..20usize {
                match i % 4 {
                    0 => q.push(CmdMeta::push(0..8, (i * 512)..(i * 512 + 256), 0.01, vec![])),
                    1 => q.push(CmdMeta::launch(
                        0..8,
                        Access::new().read((i - 1) * 512..(i - 1) * 512 + 256).write(65536..65544),
                        0.05,
                    )),
                    2 => q.push(CmdMeta::pull(0..8, 65536..65544, 0.02, vec![])),
                    _ => q.push(CmdMeta::host_merge_after(0.03, vec![i - 1])),
                };
            }
            q
        };
        let a = sched(&build());
        let b = sched(&build());
        assert_eq!(a.makespan.to_bits(), b.makespan.to_bits());
        for (x, y) in a.finish.iter().zip(&b.finish) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert!(a.makespan <= a.total_secs + 1e-12);
    }
}
