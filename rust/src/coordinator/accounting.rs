//! Execution-time breakdown — the exact four buckets of the paper's
//! Figures 12–15 plus data-volume counters.

/// The accounting bucket a transfer is charged to. The paper splits every
/// host↔DPU byte into input time (`CPU-DPU`), result-retrieval time
/// (`DPU-CPU`), or host-orchestrated mid-run synchronization
/// (`Inter-DPU`); the transfer builder makes the choice explicit instead
/// of duplicating `_inter` method variants.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Bucket {
    /// Input distribution — the "CPU-DPU" bar.
    CpuDpu,
    /// Result retrieval — the "DPU-CPU" bar.
    DpuCpu,
    /// Mid-run exchange between launches — the "Inter-DPU" bar.
    InterDpu,
}

/// Accumulated time breakdown of a benchmark run.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct TimeBreakdown {
    /// Kernel time on the DPUs (max over concurrent DPUs, summed over
    /// launches) — the "DPU" bar.
    pub dpu: f64,
    /// Host-orchestrated synchronization between launches (host compute +
    /// mid-run transfers) — the "Inter-DPU" bar.
    pub inter_dpu: f64,
    /// Input transfer time — the "CPU-DPU" bar.
    pub cpu_dpu: f64,
    /// Result retrieval time — the "DPU-CPU" bar.
    pub dpu_cpu: f64,
    /// Bytes moved host→MRAM (input phase).
    pub bytes_to_dpu: u64,
    /// Bytes moved MRAM→host (retrieval phase).
    pub bytes_from_dpu: u64,
    /// Bytes exchanged during inter-DPU synchronization phases (both
    /// directions) — the volume a direct DPU↔DPU channel would carry.
    pub bytes_inter: u64,
    /// Number of kernel launches.
    pub launches: u64,
    /// Seconds hidden by the async command-queue schedule (§6's overlap
    /// recommendation; see `coordinator::queue`): **derived** as
    /// `sum(bucket secs) − makespan` of the recorded command DAG on the
    /// modeled resource timelines — a double-buffered push under a
    /// launch, a host merge under bus traffic. The component buckets
    /// above keep their full values — `total()` subtracts this credit, so
    /// a serialized schedule (`overlapped == 0`) is unchanged.
    pub overlapped: f64,
}

impl TimeBreakdown {
    /// Charge `secs` of transfer time and `bytes` of volume to `bucket` —
    /// the single accounting path behind every transfer in the builder
    /// (previously copy-pasted across ten `PimSet` methods).
    pub fn account(&mut self, bucket: Bucket, secs: f64, bytes: u64) {
        match bucket {
            Bucket::CpuDpu => {
                self.cpu_dpu += secs;
                self.bytes_to_dpu += bytes;
            }
            Bucket::DpuCpu => {
                self.dpu_cpu += secs;
                self.bytes_from_dpu += bytes;
            }
            Bucket::InterDpu => {
                self.inter_dpu += secs;
                self.bytes_inter += bytes;
            }
        }
    }

    /// Total wall time of the run: the four buckets minus whatever the
    /// async command-queue schedule hid (`overlapped`).
    pub fn total(&self) -> f64 {
        self.dpu + self.inter_dpu + self.cpu_dpu + self.dpu_cpu - self.overlapped
    }

    /// DPU + Inter-DPU: the quantity the paper uses for the CPU/GPU
    /// comparison of §5.2 ("we include the time spent in the DPU and the
    /// time spent for inter-DPU synchronization").
    pub fn kernel_plus_sync(&self) -> f64 {
        self.dpu + self.inter_dpu
    }

    /// Element-wise sum (accumulate repetitions).
    pub fn add(&mut self, o: &TimeBreakdown) {
        self.dpu += o.dpu;
        self.inter_dpu += o.inter_dpu;
        self.cpu_dpu += o.cpu_dpu;
        self.dpu_cpu += o.dpu_cpu;
        self.bytes_to_dpu += o.bytes_to_dpu;
        self.bytes_from_dpu += o.bytes_from_dpu;
        self.bytes_inter += o.bytes_inter;
        self.launches += o.launches;
        self.overlapped += o.overlapped;
    }

    /// Element-wise difference since an earlier snapshot of the same
    /// accumulator (metrics are monotonic within a run, so plain
    /// subtraction is exact).
    pub fn delta(&self, since: &TimeBreakdown) -> TimeBreakdown {
        TimeBreakdown {
            dpu: self.dpu - since.dpu,
            inter_dpu: self.inter_dpu - since.inter_dpu,
            cpu_dpu: self.cpu_dpu - since.cpu_dpu,
            dpu_cpu: self.dpu_cpu - since.dpu_cpu,
            bytes_to_dpu: self.bytes_to_dpu - since.bytes_to_dpu,
            bytes_from_dpu: self.bytes_from_dpu - since.bytes_from_dpu,
            bytes_inter: self.bytes_inter - since.bytes_inter,
            launches: self.launches - since.launches,
            overlapped: self.overlapped - since.overlapped,
        }
    }

    /// Format as milliseconds for tables.
    pub fn fmt_ms(&self) -> String {
        format!(
            "DPU {:.3} ms | Inter-DPU {:.3} ms | CPU-DPU {:.3} ms | DPU-CPU {:.3} ms",
            self.dpu * 1e3,
            self.inter_dpu * 1e3,
            self.cpu_dpu * 1e3,
            self.dpu_cpu * 1e3
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals() {
        let b = TimeBreakdown {
            dpu: 1.0,
            inter_dpu: 0.5,
            cpu_dpu: 0.25,
            dpu_cpu: 0.25,
            ..Default::default()
        };
        assert_eq!(b.total(), 2.0);
        assert_eq!(b.kernel_plus_sync(), 1.5);
    }

    #[test]
    fn account_routes_to_buckets() {
        let mut b = TimeBreakdown::default();
        b.account(Bucket::CpuDpu, 1.0, 10);
        b.account(Bucket::DpuCpu, 2.0, 20);
        b.account(Bucket::InterDpu, 4.0, 40);
        assert_eq!((b.cpu_dpu, b.bytes_to_dpu), (1.0, 10));
        assert_eq!((b.dpu_cpu, b.bytes_from_dpu), (2.0, 20));
        assert_eq!((b.inter_dpu, b.bytes_inter), (4.0, 40));
        assert_eq!(b.dpu, 0.0);
    }

    #[test]
    fn overlapped_credits_total_only() {
        let mut b = TimeBreakdown {
            dpu: 1.0,
            cpu_dpu: 0.5,
            ..Default::default()
        };
        b.overlapped = 0.3;
        assert_eq!(b.total(), 1.2);
        assert_eq!(b.kernel_plus_sync(), 1.0, "overlap never touches kernel+sync");
        assert_eq!(b.cpu_dpu, 0.5, "component buckets keep full values");
    }

    #[test]
    fn delta_is_elementwise() {
        let a = TimeBreakdown {
            dpu: 1.0,
            cpu_dpu: 2.0,
            bytes_to_dpu: 100,
            launches: 3,
            ..Default::default()
        };
        let mut b = a;
        b.dpu += 0.5;
        b.bytes_to_dpu += 10;
        b.launches += 1;
        let d = b.delta(&a);
        assert_eq!(d.dpu, 0.5);
        assert_eq!(d.cpu_dpu, 0.0);
        assert_eq!(d.bytes_to_dpu, 10);
        assert_eq!(d.launches, 1);
    }

    #[test]
    fn add_accumulates() {
        let mut a = TimeBreakdown::default();
        let b = TimeBreakdown {
            dpu: 1.0,
            launches: 2,
            bytes_to_dpu: 100,
            ..Default::default()
        };
        a.add(&b);
        a.add(&b);
        assert_eq!(a.dpu, 2.0);
        assert_eq!(a.launches, 4);
        assert_eq!(a.bytes_to_dpu, 200);
    }
}
