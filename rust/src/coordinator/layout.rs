//! Typed MRAM layout: a per-fleet bump allocator and `Symbol<T>` handles.
//!
//! The UPMEM SDK addresses DPU memory through *named program symbols*
//! (`DPU_MRAM_HEAP_POINTER_NAME` plus whatever the kernel declares); the
//! host never does pointer arithmetic against raw MRAM offsets. Our first
//! API generation did exactly that — every workload hand-computed
//! `mram_off: usize` values and kept them consistent across host and
//! kernel by discipline alone. `MramLayout` replaces the discipline with a
//! bump allocator: each fleet owns one layout, every region is carved out
//! exactly once, all offsets respect the 8-byte DMA alignment rule
//! (`DpuArch::dma_align`), and the resulting [`Symbol`] is the only
//! currency the transfer builder (`PimSet::xfer`) accepts.
//!
//! Offsets are deterministic: the same allocation sequence always yields
//! the same layout, so modeled timing and functional results stay
//! reproducible across runs and executors.

use crate::util::pod::Pod;
use std::fmt;
use std::marker::PhantomData;

/// The MRAM DMA alignment rule every region start must satisfy.
pub const DMA_ALIGN: usize = 8;

/// Per-fleet bump allocator over one DPU's 64-MB MRAM bank.
///
/// Every DPU in a set shares the same layout (SPMD symbols live at the
/// same offset in every bank, exactly like linker-placed symbols in the
/// real SDK). Allocation never reuses space; [`MramLayout::reset`]
/// starts a fresh program layout **generation**: the cursor rewinds and
/// every `Symbol` carved from an earlier generation becomes stale —
/// using one in a transfer panics, so a warm session can re-plan its
/// layout without reallocating the fleet and without the silent-aliasing
/// bug class.
#[derive(Clone, Debug)]
pub struct MramLayout {
    capacity: usize,
    cursor: usize,
    gen: u64,
}

impl MramLayout {
    /// A fresh layout over a bank of `capacity` bytes (generation 0).
    pub fn new(capacity: usize) -> Self {
        MramLayout { capacity, cursor: 0, gen: 0 }
    }

    /// Carve out a region of `elems` elements of `T`, 8-byte aligned and
    /// disjoint from every previously allocated region. Panics when the
    /// bank is exhausted.
    pub fn alloc<T: Pod>(&mut self, elems: usize) -> Symbol<T> {
        let bytes = elems
            .checked_mul(std::mem::size_of::<T>())
            .expect("MRAM symbol size overflows usize");
        let off = self.cursor;
        let end = off.checked_add(bytes).expect("MRAM layout cursor overflows usize");
        assert!(
            end <= self.capacity,
            "MRAM layout overflow: {bytes} B requested at offset {off} in a {} B bank",
            self.capacity
        );
        self.cursor = (end + DMA_ALIGN - 1) & !(DMA_ALIGN - 1);
        Symbol { off, elems, gen: self.gen, _elem: PhantomData }
    }

    /// Bytes consumed so far (next allocation offset).
    pub fn used(&self) -> usize {
        self.cursor
    }

    /// Bytes still available.
    pub fn remaining(&self) -> usize {
        self.capacity.saturating_sub(self.cursor)
    }

    /// Bank size this layout manages.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Forget all allocations and start a new layout generation. Every
    /// previously allocated `Symbol` becomes stale: the generation check
    /// in `PimSet::xfer` panics on its next use, asserting there is no
    /// live use of the retired layout.
    pub fn reset(&mut self) {
        self.cursor = 0;
        self.gen += 1;
    }

    /// Current layout generation (bumped by every [`MramLayout::reset`]).
    pub fn generation(&self) -> u64 {
        self.gen
    }
}

/// A typed handle to an MRAM region: element type, byte offset, and
/// capacity in elements. The analogue of a named program symbol in the
/// UPMEM SDK — transfers address symbols, never raw offsets.
///
/// `Symbol` is `Copy` (two words), so kernels capture it by value and use
/// [`Symbol::off`] / [`Symbol::byte_at`] for their DMA addressing.
pub struct Symbol<T: Pod> {
    off: usize,
    elems: usize,
    /// Layout generation this symbol was carved from (stale-use check).
    gen: u64,
    // fn() -> T keeps Symbol Send + Sync + Copy independent of T's autotraits.
    _elem: PhantomData<fn() -> T>,
}

impl<T: Pod> Clone for Symbol<T> {
    fn clone(&self) -> Self {
        *self
    }
}

impl<T: Pod> Copy for Symbol<T> {}

impl<T: Pod> fmt::Debug for Symbol<T> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "Symbol<{}>[off={}, elems={}]",
            std::any::type_name::<T>(),
            self.off,
            self.elems
        )
    }
}

impl<T: Pod> Symbol<T> {
    /// Wrap a hand-placed region (legacy interop; prefer
    /// [`MramLayout::alloc`]). The offset must satisfy the 8-byte DMA
    /// alignment rule. Raw symbols belong to layout generation 0, so
    /// they go stale on the first [`MramLayout::reset`] like everything
    /// else.
    pub fn raw(off: usize, elems: usize) -> Self {
        assert!(off % DMA_ALIGN == 0, "symbol offset {off} violates the 8-B DMA alignment");
        Symbol { off, elems, gen: 0, _elem: PhantomData }
    }

    /// Layout generation this symbol belongs to.
    pub fn generation(&self) -> u64 {
        self.gen
    }

    /// The byte region `[off, off + size_bytes)` this symbol occupies in
    /// every DPU's bank — the footprint currency of the async command
    /// queue's dependency inference (`coordinator::queue::Access`).
    pub fn region(&self) -> std::ops::Range<usize> {
        self.off..self.off + self.size_bytes()
    }

    /// Byte offset of the region start in every DPU's MRAM bank.
    pub fn off(&self) -> usize {
        self.off
    }

    /// Capacity in elements of `T`.
    pub fn len(&self) -> usize {
        self.elems
    }

    pub fn is_empty(&self) -> bool {
        self.elems == 0
    }

    /// Capacity in bytes.
    pub fn size_bytes(&self) -> usize {
        self.elems * std::mem::size_of::<T>()
    }

    /// Byte offset of element `elem` (may equal the one-past-the-end
    /// position; useful for kernel-side DMA addressing).
    pub fn byte_at(&self, elem: usize) -> usize {
        assert!(
            elem <= self.elems,
            "element {elem} out of bounds for {self:?}"
        );
        self.off + elem * std::mem::size_of::<T>()
    }

    /// Sub-symbol of `elems` elements starting at element `start`. The
    /// slice start must itself land on an 8-byte boundary (it becomes a
    /// transfer target). Slices inherit the parent's layout generation.
    pub fn slice(&self, start: usize, elems: usize) -> Symbol<T> {
        assert!(
            start + elems <= self.elems,
            "slice {start}..{} out of bounds for {self:?}",
            start + elems
        );
        let mut s = Symbol::raw(self.byte_at(start), elems);
        s.gen = self.gen;
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bump_is_aligned_and_disjoint() {
        let mut l = MramLayout::new(1 << 20);
        let a = l.alloc::<u8>(13);
        let b = l.alloc::<i32>(7);
        let c = l.alloc::<i64>(0);
        let d = l.alloc::<i64>(4);
        for off in [a.off(), b.off(), c.off(), d.off()] {
            assert_eq!(off % DMA_ALIGN, 0);
        }
        assert!(a.off() + a.size_bytes() <= b.off());
        assert!(b.off() + b.size_bytes() <= c.off());
        assert!(c.off() + c.size_bytes() <= d.off());
        assert_eq!(l.used(), d.off() + d.size_bytes());
    }

    #[test]
    fn deterministic_offsets() {
        let run = || {
            let mut l = MramLayout::new(1 << 16);
            (l.alloc::<i32>(100).off(), l.alloc::<u64>(9).off(), l.alloc::<u8>(3).off())
        };
        assert_eq!(run(), run());
    }

    #[test]
    #[should_panic(expected = "MRAM layout overflow")]
    fn overflow_rejected() {
        let mut l = MramLayout::new(64);
        l.alloc::<i64>(9);
    }

    #[test]
    fn reset_reuses_bank() {
        let mut l = MramLayout::new(128);
        l.alloc::<i64>(16);
        assert_eq!(l.remaining(), 0);
        l.reset();
        assert_eq!(l.alloc::<i64>(16).off(), 0);
    }

    /// A second allocation after exhaustion must still panic (the bank
    /// does not silently wrap), and a reset re-opens it.
    #[test]
    fn double_alloc_past_capacity_panics_until_reset() {
        let mut l = MramLayout::new(128);
        let _ = l.alloc::<i64>(16);
        let second = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            l.alloc::<i64>(1)
        }));
        assert!(second.is_err(), "double-alloc past capacity must panic");
        l.reset();
        assert_eq!(l.alloc::<i64>(16).off(), 0, "reset re-opens the bank");
    }

    #[test]
    fn reset_bumps_generation_and_marks_symbols_stale() {
        let mut l = MramLayout::new(1 << 10);
        assert_eq!(l.generation(), 0);
        let old = l.alloc::<i64>(8);
        let old_slice = old.slice(0, 4);
        assert_eq!(old.generation(), 0);
        assert_eq!(old_slice.generation(), 0, "slices inherit the generation");
        l.reset();
        assert_eq!(l.generation(), 1);
        let fresh = l.alloc::<i64>(8);
        assert_eq!(fresh.generation(), 1);
        assert_ne!(old.generation(), l.generation(), "old symbols are stale");
    }

    #[test]
    fn region_spans_exactly_the_symbol_bytes() {
        let mut l = MramLayout::new(1 << 10);
        let a = l.alloc::<i32>(10);
        assert_eq!(a.region(), a.off()..a.off() + 40);
        assert_eq!(a.slice(2, 4).region(), a.off() + 8..a.off() + 24);
    }

    #[test]
    fn slice_and_byte_at() {
        let mut l = MramLayout::new(1 << 10);
        let s = l.alloc::<i64>(32);
        let sub = s.slice(4, 8);
        assert_eq!(sub.off(), s.off() + 32);
        assert_eq!(sub.len(), 8);
        assert_eq!(s.byte_at(32), s.off() + 256);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn slice_bounds_checked() {
        let mut l = MramLayout::new(1 << 10);
        let s = l.alloc::<i32>(8);
        let _ = s.slice(4, 8);
    }

    #[test]
    #[should_panic(expected = "DMA alignment")]
    fn misaligned_raw_rejected() {
        let _ = Symbol::<i32>::raw(4, 8);
    }
}
