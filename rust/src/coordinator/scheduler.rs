//! Multi-tenant fleet scheduler: rank-sliced tenants, deterministic
//! traffic generation, and QoS accounting.
//!
//! The paper's §6 recommendations (amortize input loads, overlap
//! transfers, split work into independent blocks) stop at one workload
//! owning the whole fleet. A production deployment shares the 2,556-DPU
//! machine across many **resident** workloads at once, and the natural
//! allocation unit is the **rank**: kernels on disjoint ranks execute
//! concurrently, while CPU↔DPU transfers serialize across ranks on the
//! host memory bus (§5.1.1 — "these transfers are not simultaneous across
//! ranks"). The scheduler models exactly that split:
//!
//! * **Fleet slicing** — [`PimSet::split_ranks`] carves one allocated
//!   fleet into rank-granular, non-overlapping sub-fleets ([`FleetSlice`]
//!   records the geometry); each slice backs an independent tenant
//!   [`Session`] with its own `MramLayout`, resident dataset, and metrics.
//! * **Traffic generation** — open-loop Poisson arrivals per tenant,
//!   seeded via `util::rng` (exponential inter-arrival times at the
//!   tenant's configured rate; `rate <= 0` degenerates to a burst at
//!   t = 0). Request payloads come from [`Request::stream`], so every
//!   arrival is a genuinely fresh query/vector/root for the query-style
//!   workloads.
//! * **Scheduling** — the host bus is the contended resource, so the
//!   [`Policy`] is a **bus arbiter**: whenever the bus frees up it picks
//!   which tenant's queued requests are granted the next push. Kernel
//!   time runs on the tenant's private rank slice and overlaps freely
//!   with other tenants' kernels *and* with other tenants' bus traffic.
//! * **QoS accounting** — per-request latency = modeled queueing delay
//!   (bus + slice wait) plus service time (push, kernels + inter-DPU
//!   sync, response pull); reports quote per-tenant throughput,
//!   p50/p95/p99/max latency, slice utilization, and aggregate machine
//!   occupancy.
//!
//! # Timing model
//!
//! The machine's contended resources live in one shared
//! [`Timeline`](super::queue::Timeline) — the same bus / rank-lane /
//! host model the async command queues (`coordinator::queue`) schedule
//! onto. A dispatched batch of `k` requests from one tenant reserves, in
//! order: the bus for its aggregated input push (`Σ cpu_dpu −
//! overlapped`: with pipelining on, `Session::execute_batch` wraps the
//! batch in an async command queue whose derived credit — double-
//! buffered pushes hidden under launches, merges hidden under bus
//! traffic — shortens the bus occupancy here), the tenant's rank lanes
//! for its kernels and host-orchestrated sync (`Σ dpu + inter_dpu`;
//! mid-run inter-DPU exchanges are charged to the slice window for
//! simplicity), and the bus again for the response pull (`Σ dpu_cpu`).
//! While a slice computes, the bus serves other tenants — that is the
//! §5.1.1 concurrency the rank split buys. Ready responses take bus
//! priority over new pushes (finish in-flight work first).
//!
//! # Determinism
//!
//! Every scheduling decision derives from modeled seconds (which are
//! executor-independent, see `coordinator::executor`) and from the seeded
//! RNG, so serial and parallel executors produce bit-identical outputs,
//! bucket breakdowns, and latency distributions for the same seed,
//! policy, and tenant mix. Within a tenant, requests dispatch in arrival
//! (id) order under every policy — policies only reorder *across*
//! tenants — so a single-tenant stream is policy-invariant
//! (`tests/executor_equivalence.rs`).

use super::elastic::{ElasticConfig, ElasticPolicy, ElasticView, Migrator, MoveRanks};
use super::queue::{CmdKind, Lane, Timeline};
use super::telemetry::{Labels, Telemetry};
use super::trace::{LaneTag, TraceEvent, TraceSink};
use super::{ExecChoice, PimSet, Session, TimeBreakdown};
use crate::arch::SystemConfig;
use crate::energy::EnergyModel;
use crate::prim::common::RunConfig;
use crate::prim::workload::{workload_by_name, Dataset, Output, Request, Workload};
use crate::util::stats::{latency_summary, LatencySummary};
use crate::util::Rng;
use std::collections::VecDeque;

/// Golden-ratio multiplier for decorrelating per-tenant seeds.
const GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// Stable tenant label used in telemetry and SLO reports (`t0`, `t1`, …)
/// — matches the integer tenant ids of `SchedReport::to_json`.
fn tenant_name(idx: usize) -> String {
    format!("t{idx}")
}

// ----------------------------------------------------------------- tenants

/// One tenant of the shared machine: a workload name, a rank budget, and
/// traffic-shaping knobs. Parsed from the CLI mix syntax
/// `name:ranks[:weight[:rate]]` (e.g. `gemv:8,bs:4:2,va:4:1:1500`).
#[derive(Clone, Debug, PartialEq)]
pub struct TenantSpec {
    /// PrIM workload short name (`workload_by_name`).
    pub bench: String,
    /// Whole ranks this tenant owns (64 DPUs each).
    pub ranks: u32,
    /// Weighted-round-robin weight (batch quantum); default 1.
    pub weight: u32,
    /// Open-loop arrival rate, requests per second of modeled time;
    /// `<= 0` falls back to [`SchedConfig::rate`].
    pub rate: f64,
    /// Dataset scale factor for this tenant's `prepare` (the caller sets
    /// this from its scale policy, e.g. `harness::harness_scale`).
    pub scale: f64,
}

impl TenantSpec {
    pub fn new(bench: &str, ranks: u32) -> Self {
        TenantSpec {
            bench: bench.to_string(),
            ranks,
            weight: 1,
            rate: 0.0,
            scale: 1.0,
        }
    }

    /// Parse a comma-separated tenant mix: `name:ranks[:weight[:rate]]`.
    pub fn parse_list(s: &str) -> anyhow::Result<Vec<TenantSpec>> {
        let mut out = Vec::new();
        for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let fields: Vec<&str> = part.split(':').collect();
            if fields.len() < 2 || fields.len() > 4 {
                anyhow::bail!(
                    "tenant '{part}' is not name:ranks[:weight[:rate]] (e.g. gemv:8)"
                );
            }
            let mut spec = TenantSpec::new(fields[0], 0);
            spec.ranks = fields[1]
                .parse()
                .map_err(|_| anyhow::anyhow!("tenant '{part}': bad rank count '{}'", fields[1]))?;
            if spec.ranks == 0 {
                anyhow::bail!("tenant '{part}': needs at least one rank");
            }
            if let Some(w) = fields.get(2) {
                spec.weight = w
                    .parse()
                    .map_err(|_| anyhow::anyhow!("tenant '{part}': bad weight '{w}'"))?;
                if spec.weight == 0 {
                    anyhow::bail!("tenant '{part}': weight must be >= 1");
                }
            }
            if let Some(r) = fields.get(3) {
                spec.rate = r
                    .parse()
                    .map_err(|_| anyhow::anyhow!("tenant '{part}': bad rate '{r}'"))?;
            }
            out.push(spec);
        }
        if out.is_empty() {
            anyhow::bail!("empty tenant mix (expected e.g. \"gemv:8,bs:4,va:4\")");
        }
        Ok(out)
    }
}

/// The geometry of one tenant's rank slice inside the shared fleet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FleetSlice {
    pub tenant: usize,
    /// First rank (0-based) and rank count — whole ranks only.
    pub rank0: u32,
    pub n_ranks: u32,
    /// First global DPU index and DPU count (derived: ranks × 64).
    pub dpu0: u32,
    pub n_dpus: u32,
}

/// Lay out non-overlapping rank slices in tenant order — a pure preview
/// of the geometry [`PimSet::split_ranks`] produces (the scheduler itself
/// derives each [`FleetSlice`] from the carved set, so the two cannot
/// drift). The slices tile the fleet exactly: slice `i` starts where
/// slice `i−1` ended.
pub fn carve_slices(dpus_per_rank: u32, ranks: &[u32]) -> Vec<FleetSlice> {
    let mut rank0 = 0u32;
    ranks
        .iter()
        .enumerate()
        .map(|(tenant, &n_ranks)| {
            let s = FleetSlice {
                tenant,
                rank0,
                n_ranks,
                dpu0: rank0 * dpus_per_rank,
                n_dpus: n_ranks * dpus_per_rank,
            };
            rank0 += n_ranks;
            s
        })
        .collect()
}

// ----------------------------------------------------------------- traffic

/// One generated request: which tenant it belongs to and when it arrives
/// (seconds of modeled time after all tenants finished loading).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Arrival {
    pub tenant: usize,
    pub req: Request,
    pub at: f64,
}

/// A mid-run load shift for the elastic scenarios: one tenant's arrival
/// rate is multiplied by `factor` from modeled time `at` onward. The
/// shifted stream shares its RNG draws with the unshifted one, so the
/// pre-shift arrival prefix is bit-identical — the shift changes only
/// how fast the exponential gaps play out after `at`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LoadShift {
    /// Tenant index the shift applies to.
    pub tenant: usize,
    /// Modeled time the new rate takes effect.
    pub at: f64,
    /// Rate multiplier from `at` onward (e.g. `8.0` = hot, `0.25` =
    /// cooled off).
    pub factor: f64,
}

/// Deterministic open-loop arrival stream for one tenant: exponential
/// inter-arrival times at `rate` req/s (Poisson process), request
/// payload seeds from [`Request::stream`]. `rate <= 0` produces a burst
/// (everything arrives at t = 0).
pub fn gen_arrivals(tenant: usize, seed: u64, n: usize, rate: f64) -> VecDeque<Arrival> {
    gen_arrivals_shifted(tenant, seed, n, rate, None)
}

/// [`gen_arrivals`] with an optional piecewise rate: gaps drawn while
/// the clock is past `shift.0` use `rate * shift.1`. With `shift =
/// None` the computation is identical to the unshifted generator,
/// bitwise.
pub fn gen_arrivals_shifted(
    tenant: usize,
    seed: u64,
    n: usize,
    rate: f64,
    shift: Option<(f64, f64)>,
) -> VecDeque<Arrival> {
    let mut rng = Rng::new(seed ^ 0x5BD1_E995_9D1B_54D5);
    let mut at = 0.0f64;
    Request::stream(seed, n)
        .into_iter()
        .map(|req| {
            if rate > 0.0 {
                let r = match shift {
                    Some((t0, factor)) if at >= t0 => rate * factor,
                    _ => rate,
                };
                // inverse-CDF exponential; f64() < 1 so ln is finite
                at += -(1.0 - rng.f64()).ln() / r;
            }
            Arrival { tenant, req, at }
        })
        .collect()
}

// ---------------------------------------------------------------- policies

/// A tenant eligible for the next bus grant (head request arrived and its
/// slice is idle).
#[derive(Clone, Copy, Debug)]
pub struct Candidate {
    pub tenant: usize,
    /// Arrival time of the tenant's head request.
    pub arrival: f64,
    /// Current service-time estimate (EWMA of observed per-request
    /// modeled service; 0 until the tenant has completed a batch).
    pub estimate: f64,
    pub weight: u32,
}

/// A bus-arbitration policy: given the eligible tenants (in tenant
/// order), pick who is granted the bus next and how many of their queued
/// requests may ride as one batch (capped by arrivals and
/// [`SchedConfig::max_batch`]).
pub trait Policy: Send {
    fn name(&self) -> &'static str;
    fn pick(&mut self, feasible: &[Candidate]) -> (usize, usize);
}

/// First-in-first-out across all tenants: earliest head arrival wins
/// (ties: lowest tenant index); one request per grant.
pub struct Fifo;

impl Policy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn pick(&mut self, feasible: &[Candidate]) -> (usize, usize) {
        let c = feasible
            .iter()
            .min_by(|a, b| a.arrival.total_cmp(&b.arrival).then(a.tenant.cmp(&b.tenant)))
            .expect("non-empty feasible set");
        (c.tenant, 1)
    }
}

/// Weighted round-robin: cycle a pointer over the tenants, serving up to
/// `weight` queued requests per visit.
#[derive(Default)]
pub struct WeightedRoundRobin {
    pos: usize,
}

impl WeightedRoundRobin {
    pub fn new() -> Self {
        Self::default()
    }
}

impl Policy for WeightedRoundRobin {
    fn name(&self) -> &'static str {
        "wrr"
    }

    fn pick(&mut self, feasible: &[Candidate]) -> (usize, usize) {
        // feasible is in tenant order: next eligible tenant at/after the
        // pointer, wrapping to the front
        let c = feasible
            .iter()
            .find(|c| c.tenant >= self.pos)
            .unwrap_or(&feasible[0]);
        self.pos = c.tenant + 1;
        (c.tenant, c.weight as usize)
    }
}

/// Modeled-shortest-job-first: smallest EWMA service-time estimate wins
/// (ties: earliest arrival, then tenant index). Tenants with no completed
/// batch yet have estimate 0 and are probed first.
pub struct ShortestJob;

impl Policy for ShortestJob {
    fn name(&self) -> &'static str {
        "sjf"
    }

    fn pick(&mut self, feasible: &[Candidate]) -> (usize, usize) {
        let c = feasible
            .iter()
            .min_by(|a, b| {
                a.estimate
                    .total_cmp(&b.estimate)
                    .then(a.arrival.total_cmp(&b.arrival))
                    .then(a.tenant.cmp(&b.tenant))
            })
            .expect("non-empty feasible set");
        (c.tenant, 1)
    }
}

/// Named policy selection (CLI `--policy`, harness sweeps).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PolicyKind {
    Fifo,
    Wrr,
    Sjf,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "fifo" => Some(PolicyKind::Fifo),
            "wrr" => Some(PolicyKind::Wrr),
            "sjf" => Some(PolicyKind::Sjf),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PolicyKind::Fifo => "fifo",
            PolicyKind::Wrr => "wrr",
            PolicyKind::Sjf => "sjf",
        }
    }

    pub fn build(self) -> Box<dyn Policy> {
        match self {
            PolicyKind::Fifo => Box::new(Fifo),
            PolicyKind::Wrr => Box::new(WeightedRoundRobin::new()),
            PolicyKind::Sjf => Box::new(ShortestJob),
        }
    }

    pub const ALL: [PolicyKind; 3] = [PolicyKind::Fifo, PolicyKind::Wrr, PolicyKind::Sjf];
}

// ------------------------------------------------------------------ config

/// Configuration of one multi-tenant scheduling run.
#[derive(Clone, Debug)]
pub struct SchedConfig {
    pub tenants: Vec<TenantSpec>,
    /// Requests generated per tenant.
    pub requests: usize,
    pub policy: PolicyKind,
    /// Default arrival rate (req/s of modeled time) for tenants whose
    /// spec leaves `rate <= 0`.
    pub rate: f64,
    /// Cap on how many queued requests one bus grant may batch.
    pub max_batch: usize,
    /// Pipelined staging + rank-granular overlap credit inside batches
    /// (see `coordinator::session`).
    pub pipeline: bool,
    pub seed: u64,
    pub exec: ExecChoice,
    /// Trace capture sink (`--trace`): records every bus grant, kernel
    /// window, and response pull on the fleet-global timeline, tagged
    /// with tenant and request ids (`source: "sched"`). `None` = off.
    pub trace: Option<TraceSink>,
    /// Live telemetry registry (`--metrics`): per-tenant arrival /
    /// dispatch / completion counters, queue-depth / EWMA-latency /
    /// cumulative-joule series sampled at simulated-time instants of the
    /// shared timeline, and latency histograms (see
    /// `coordinator::telemetry`). `None` = off, zero cost.
    pub metrics: Option<Telemetry>,
    /// Elastic autoscaling (`--elastic [policy]`): live rank
    /// reallocation between tenants with modeled migration cost (see
    /// `coordinator::elastic`). `None` = static slices. An elastic run
    /// always carries a telemetry registry (the policy's sensor input);
    /// when `metrics` is `None` an internal one is created.
    pub elastic: Option<ElasticConfig>,
    /// Mid-run load shift (`--shift t:at:factor`): multiply tenant
    /// `t`'s arrival rate by `factor` from modeled time `at` onward —
    /// the scenario elastic policies exist for.
    pub shift: Option<LoadShift>,
}

impl SchedConfig {
    pub fn new(tenants: Vec<TenantSpec>) -> Self {
        SchedConfig {
            tenants,
            requests: 8,
            policy: PolicyKind::Fifo,
            rate: 500.0,
            max_batch: 4,
            pipeline: false,
            seed: 42,
            exec: ExecChoice::Auto,
            trace: None,
            metrics: None,
            elastic: None,
            shift: None,
        }
    }
}

// ------------------------------------------------------------------ report

/// Timeline of one request through the shared machine.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RequestRecord {
    pub id: u64,
    /// Open-loop arrival time.
    pub arrival: f64,
    /// When the request's batch was granted the bus (queueing ends).
    pub dispatched: f64,
    /// When the response pull completed (batched requests complete
    /// together).
    pub done: f64,
}

impl RequestRecord {
    /// End-to-end latency: arrival → response pulled.
    pub fn latency(&self) -> f64 {
        self.done - self.arrival
    }

    /// Modeled queueing delay (bus + slice wait before service).
    pub fn queueing(&self) -> f64 {
        self.dispatched - self.arrival
    }
}

/// Per-tenant QoS outcome.
pub struct TenantReport {
    pub bench: String,
    pub slice: FleetSlice,
    pub weight: u32,
    /// Effective arrival rate used (spec rate or the config default).
    pub rate: f64,
    /// Load cost (allocation + resident input push) paid once, before
    /// the measured serving window.
    pub cold: TimeBreakdown,
    /// Accumulated breakdown over all served requests.
    pub warm: TimeBreakdown,
    /// Per-request timelines in dispatch order.
    pub records: Vec<RequestRecord>,
    /// Seconds the slice was occupied (granted → response done).
    pub busy: f64,
    /// Modeled energy (J) the tenant's slice drew over the serving
    /// window: chips active during its kernel seconds, idling for the
    /// rest of the machine makespan, plus bus energy for its bytes
    /// ([`EnergyModel::slice_joules`]). Cold load is excluded — clock 0
    /// is "all tenants resident"; migration re-loads are excluded too
    /// (they are billed separately below).
    pub joules: f64,
    /// Elastic migrations this tenant underwent (slice geometry
    /// changes — grows, shrinks, and re-homes alike).
    pub migrations: u32,
    /// Accumulated migration bill: the re-load breakdown of every
    /// resize, measured through the ordinary transfer path and kept out
    /// of `warm` (the bus copy lives in `mig.cpu_dpu`; `mig.bytes_to_dpu`
    /// is the re-pushed volume).
    pub mig: TimeBreakdown,
    /// Cross-machine link seconds its migrations paid (0 unless the
    /// elastic config models a network leg).
    pub mig_net_secs: f64,
    /// Modeled energy (J) of its migration copies
    /// ([`EnergyModel::pim_joules`] over `mig`).
    pub mig_joules: f64,
    /// Last retrieved output checked against the native reference.
    pub verified: bool,
}

impl TenantReport {
    pub fn latencies(&self) -> Vec<f64> {
        self.records.iter().map(RequestRecord::latency).collect()
    }

    pub fn latency_summary(&self) -> LatencySummary {
        latency_summary(&self.latencies())
    }

    /// Completed requests per second of modeled time, over this tenant's
    /// own first-arrival → last-completion span.
    pub fn throughput(&self) -> f64 {
        let first = self.records.iter().map(|r| r.arrival).fold(f64::INFINITY, f64::min);
        let last = self.records.iter().map(|r| r.done).fold(0.0f64, f64::max);
        self.records.len() as f64 / (last - first).max(1e-12)
    }

    /// Fraction of the machine-wide makespan this tenant's slice was busy.
    pub fn utilization(&self, makespan: f64) -> f64 {
        if makespan <= 0.0 {
            0.0
        } else {
            self.busy / makespan
        }
    }

    /// Total modeled seconds this tenant's migrations occupied shared
    /// resources (bus copy + optional link leg).
    pub fn mig_secs(&self) -> f64 {
        self.mig.total() + self.mig_net_secs
    }
}

/// Outcome of a multi-tenant scheduling run.
pub struct SchedReport {
    pub policy: &'static str,
    pub seed: u64,
    pub pipelined: bool,
    pub tenants: Vec<TenantReport>,
    /// Last response completion across all tenants (clock 0 = all
    /// tenants resident).
    pub makespan: f64,
    pub total_ranks: u32,
    /// Elastic policy name when autoscaling was on (`None` = static
    /// slices; JSON spells it `"static"`).
    pub elastic: Option<&'static str>,
}

impl SchedReport {
    /// Rank-weighted average slice utilization — the fraction of the
    /// machine's rank-seconds spent serving requests.
    pub fn occupancy(&self) -> f64 {
        if self.makespan <= 0.0 || self.total_ranks == 0 {
            return 0.0;
        }
        let busy_rank_secs: f64 =
            self.tenants.iter().map(|t| t.busy * t.slice.n_ranks as f64).sum();
        busy_rank_secs / (self.makespan * self.total_ranks as f64)
    }

    /// Machine-wide migration count.
    pub fn migrations(&self) -> u64 {
        self.tenants.iter().map(|t| t.migrations as u64).sum()
    }

    /// Machine-wide bytes re-pushed by migrations.
    pub fn mig_bytes(&self) -> u64 {
        self.tenants.iter().map(|t| t.mig.bytes_to_dpu).sum()
    }

    /// Machine-wide modeled seconds migrations occupied shared resources.
    pub fn mig_secs(&self) -> f64 {
        self.tenants.iter().map(TenantReport::mig_secs).sum()
    }

    /// Machine-wide modeled energy (J) of migration copies.
    pub fn mig_joules(&self) -> f64 {
        self.tenants.iter().map(|t| t.mig_joules).sum()
    }

    /// Machine-readable record (`results/BENCH_SCHED.json`). Rust float
    /// formatting is shortest-roundtrip, so equal JSON ⇔ bit-equal
    /// modeled times — the determinism tests compare these strings.
    pub fn to_json(&self) -> String {
        let mut out = format!(
            "{{\"policy\": \"{}\", \"seed\": {}, \"pipelined\": {}, \
             \"makespan_secs\": {:e}, \"occupancy\": {:e}, \"total_ranks\": {},\n \
             \"elastic\": \"{}\", \"migrations\": {}, \"mig_secs\": {:e}, \
             \"mig_bytes\": {}, \"mig_joules\": {:e},\n \"tenants\": [\n",
            self.policy,
            self.seed,
            self.pipelined,
            self.makespan,
            self.occupancy(),
            self.total_ranks,
            self.elastic.unwrap_or("static"),
            self.migrations(),
            self.mig_secs(),
            self.mig_bytes(),
            self.mig_joules(),
        );
        for (i, t) in self.tenants.iter().enumerate() {
            let l = t.latency_summary();
            out.push_str(&format!(
                "  {{\"tenant\": {}, \"bench\": \"{}\", \"ranks\": {}, \"dpus\": {}, \
                 \"weight\": {}, \"rate_rps\": {:e}, \"requests\": {},\n   \
                 \"throughput_rps\": {:e}, \"p50_secs\": {:e}, \"p95_secs\": {:e}, \
                 \"p99_secs\": {:e}, \"max_secs\": {:e},\n   \
                 \"utilization\": {:e}, \"cold_secs\": {:e}, \"warm_secs\": {:e}, \
                 \"joules\": {:e},\n   \
                 \"migrations\": {}, \"mig_secs\": {:e}, \"mig_bytes\": {}, \
                 \"mig_joules\": {:e}, \"verified\": {}}}{}\n",
                t.slice.tenant,
                t.bench,
                t.slice.n_ranks,
                t.slice.n_dpus,
                t.weight,
                t.rate,
                t.records.len(),
                t.throughput(),
                l.p50,
                l.p95,
                l.p99,
                l.max,
                t.utilization(self.makespan),
                t.cold.total(),
                t.warm.total(),
                t.joules,
                t.migrations,
                t.mig_secs(),
                t.mig.bytes_to_dpu,
                t.mig_joules,
                t.verified,
                if i + 1 < self.tenants.len() { "," } else { "" },
            ));
        }
        out.push_str(" ]}\n");
        out
    }
}

// --------------------------------------------------------------- scheduler

/// A resident tenant: its slice-backed session, queued traffic, and
/// accumulated QoS records.
struct Tenant {
    spec: TenantSpec,
    slice: FleetSlice,
    rate: f64,
    workload: Box<dyn Workload>,
    dataset: Dataset,
    session: Session,
    cold: TimeBreakdown,
    queue: VecDeque<Arrival>,
    records: Vec<RequestRecord>,
    busy: f64,
    /// Accumulated active-phase energy (J) of dispatched batches —
    /// feeds the cumulative-joules telemetry series; the report's
    /// slice-level figure is recomputed in `finish` from the warm
    /// breakdown and the machine makespan.
    joules: f64,
    /// A dispatched batch whose response pull has not completed yet.
    in_flight: bool,
    /// EWMA of observed per-request modeled service time (SJF input).
    estimate: f64,
    served: u64,
    last_out: Option<Output>,
    /// Migration bill (see [`TenantReport::mig`]); all zero when static.
    mig: TimeBreakdown,
    migrations: u32,
    mig_net_secs: f64,
    mig_joules: f64,
    /// Verification verdict of the last output retrieved *before* a
    /// migration, checked against the dataset it was actually served
    /// from (a migration repartitions the dataset, so the check must
    /// not be deferred across one).
    pre_mig_verified: Option<bool>,
}

impl Tenant {
    /// The shared-timeline lane of this tenant's rank slice.
    fn lane(&self) -> Lane {
        Lane::Ranks(self.slice.rank0..self.slice.rank0 + self.slice.n_ranks)
    }
}

/// A dispatched batch waiting for its response pull: ready once the
/// slice's kernels finish, then competes for the bus.
struct PendingPull {
    ready: f64,
    /// Dispatch sequence number (deterministic tiebreak).
    seq: u64,
    tenant: usize,
    pull_secs: f64,
    /// Indices into the tenant's `records`.
    recs: Vec<usize>,
    /// Response bytes the pull carries (trace annotation).
    pull_bytes: u64,
    /// First request id of the batch (trace annotation).
    req0: Option<u64>,
    /// Trace id of the batch's kernel event — the pull's dependency.
    kernel_ev: Option<u64>,
}

/// A decided rank move waiting for its affected tenants to drain.
/// "Affected" = every tenant whose slice geometry changes under the
/// re-tiled layout (slices stay contiguous in tenant order, so a move
/// can re-home bystanders between donor and receiver — they pay too,
/// honestly).
struct PendingMove {
    mv: MoveRanks,
    /// Decision instant (modeled seconds) — the drain phase starts here.
    decided_at: f64,
    /// Tenants whose geometry changes, in tenant order.
    affected: Vec<usize>,
    /// Post-move rank allocation for every tenant.
    new_ranks: Vec<u32>,
}

/// Elastic autoscaling state threaded through the serving loop: the
/// policy (sensor reader), the migrator (state mechanics), and the
/// freeze → drain → migrate → resume bookkeeping.
struct ElasticRun {
    cfg: ElasticConfig,
    policy: Box<dyn ElasticPolicy>,
    migrator: Migrator,
    pending: Option<PendingMove>,
    /// Modeled end of the last migration's copy phase (cooldown anchor).
    last_end: f64,
    /// Last evaluated decision instant (one policy evaluation per
    /// distinct modeled time).
    last_eval: f64,
}

/// The multi-tenant serving loop: rank-sliced sessions, one shared
/// resource timeline (bus + rank lanes, from `coordinator::queue`), a
/// pluggable arbitration policy. Build with [`Scheduler::build`], run to
/// completion with [`Scheduler::run`].
pub struct Scheduler {
    tenants: Vec<Tenant>,
    policy: Box<dyn Policy>,
    policy_kind: PolicyKind,
    max_batch: usize,
    pipelined: bool,
    seed: u64,
    total_ranks: u32,
    /// The machine's modeled resources: one serialized bus and one
    /// kernel lane per rank — the same model the async command queues
    /// schedule onto.
    timeline: Timeline,
    pulls: Vec<PendingPull>,
    seq: u64,
    /// Trace capture sink (`source: "sched"`), if tracing is on.
    trace: Option<TraceSink>,
    /// Telemetry registry (`--metrics`), if live metrics are on. Every
    /// record below reads modeled values the run computes anyway, so an
    /// instrumented run is bit-identical to a bare one. Elastic runs
    /// always carry one — it is the policy's sensor input.
    telemetry: Option<Telemetry>,
    /// Machine config the fleet was allocated on (energy accounting).
    sys: SystemConfig,
    /// Executor choice, kept for migration-time dataset re-preparation.
    exec: ExecChoice,
    /// Elastic autoscaling state (`None` = static slices).
    elastic: Option<ElasticRun>,
}

impl Scheduler {
    /// Allocate the shared fleet, carve the rank slices, and make every
    /// tenant resident (prepare + load); the serving clock starts at 0
    /// with all datasets warm.
    pub fn build(cfg: &SchedConfig) -> anyhow::Result<Scheduler> {
        if cfg.tenants.is_empty() {
            anyhow::bail!("scheduler needs at least one tenant");
        }
        if cfg.requests == 0 {
            anyhow::bail!("scheduler needs at least one request per tenant");
        }
        let ranks: Vec<u32> = cfg.tenants.iter().map(|t| t.ranks).collect();
        let total_ranks: u32 = ranks.iter().sum();
        let sys = if total_ranks <= 1 {
            SystemConfig::p21_rank()
        } else {
            SystemConfig::p21_2556()
        };
        let per = sys.dpus_per_rank();
        let total_dpus = total_ranks * per;
        if total_dpus > sys.n_dpus() {
            anyhow::bail!(
                "tenant mix asks for {total_ranks} ranks ({total_dpus} DPUs) but the \
                 machine has {} usable DPUs",
                sys.n_dpus()
            );
        }
        if let Some(s) = &cfg.shift {
            if s.tenant >= cfg.tenants.len() {
                anyhow::bail!(
                    "--shift targets tenant {} but the mix has {}",
                    s.tenant,
                    cfg.tenants.len()
                );
            }
            if s.factor <= 0.0 {
                anyhow::bail!("--shift factor must be > 0 (got {})", s.factor);
            }
        }
        // an elastic policy needs the telemetry series as sensor input,
        // so elastic runs get an internal registry when --metrics is off
        let telemetry = match (&cfg.metrics, &cfg.elastic) {
            (Some(tel), _) => Some(tel.clone()),
            (None, Some(_)) => Some(Telemetry::default()),
            (None, None) => None,
        };
        let mut parent = PimSet::allocate_with(sys.clone(), total_dpus, cfg.exec.build());
        if let Some(tel) = &telemetry {
            parent = parent.with_telemetry(tel.clone());
        }
        let sets = parent.split_ranks(&ranks);

        let mut tenants = Vec::with_capacity(cfg.tenants.len());
        for (tenant_idx, (spec, set)) in cfg.tenants.iter().zip(sets).enumerate() {
            // geometry comes from the carved set itself, so it cannot
            // drift from what the session actually runs on
            let slice = FleetSlice {
                tenant: tenant_idx,
                rank0: set.rank0,
                n_ranks: set.n_dpus() / per,
                dpu0: set.rank0 * per,
                n_dpus: set.n_dpus(),
            };
            let workload = workload_by_name(&spec.bench)
                .ok_or_else(|| anyhow::anyhow!("unknown benchmark '{}'", spec.bench))?;
            let tseed = cfg.seed ^ (tenant_idx as u64 + 1).wrapping_mul(GOLDEN);
            let rc = RunConfig {
                sys: sys.clone(),
                n_dpus: slice.n_dpus,
                n_tasklets: workload.best_tasklets(),
                scale: spec.scale,
                seed: tseed,
                exec: cfg.exec,
                trace: None,
                metrics: None,
            };
            let dataset = workload.prepare(&rc);
            let mut session =
                Session::new(set, rc.n_tasklets).with_pipeline(cfg.pipeline);
            workload.load(&mut session, &dataset);
            let cold = session.set.metrics;
            session.set.reset_metrics();
            let rate = if spec.rate > 0.0 { spec.rate } else { cfg.rate };
            let shift = match &cfg.shift {
                Some(s) if s.tenant == tenant_idx => Some((s.at, s.factor)),
                _ => None,
            };
            let queue =
                gen_arrivals_shifted(slice.tenant, tseed, cfg.requests, rate, shift);
            if let Some(tel) = &telemetry {
                let name = tenant_name(tenant_idx);
                let lbl = Labels::tenant(&name).with_bench(&spec.bench);
                tel.counter_add("sched_arrivals", lbl, cfg.requests as u64);
                tel.gauge_set("sched_offered_rps", Labels::tenant(&name), rate);
            }
            tenants.push(Tenant {
                spec: spec.clone(),
                slice,
                rate,
                workload,
                dataset,
                session,
                cold,
                queue,
                records: Vec::with_capacity(cfg.requests),
                busy: 0.0,
                joules: 0.0,
                in_flight: false,
                estimate: 0.0,
                served: 0,
                last_out: None,
                mig: TimeBreakdown::default(),
                migrations: 0,
                mig_net_secs: 0.0,
                mig_joules: 0.0,
                pre_mig_verified: None,
            });
        }
        if let Some(sink) = &cfg.trace {
            sink.set_geometry("sched", total_ranks);
        }
        Ok(Scheduler {
            tenants,
            policy: cfg.policy.build(),
            policy_kind: cfg.policy,
            max_batch: cfg.max_batch.max(1),
            pipelined: cfg.pipeline,
            seed: cfg.seed,
            total_ranks,
            timeline: Timeline::new(total_ranks as usize),
            pulls: Vec::new(),
            seq: 0,
            trace: cfg.trace.clone(),
            telemetry,
            sys,
            exec: cfg.exec,
            elastic: cfg.elastic.as_ref().map(|ec| ElasticRun {
                cfg: ec.clone(),
                policy: ec.build(),
                migrator: Migrator { net: ec.net.clone() },
                pending: None,
                last_end: f64::NEG_INFINITY,
                last_eval: f64::NEG_INFINITY,
            }),
        })
    }

    /// Drive every queued request to completion and report QoS.
    pub fn run(mut self) -> SchedReport {
        loop {
            // an armed resize executes the moment its slices drain
            self.try_migrate();
            // earliest time any tenant's head request could take the bus;
            // tenants frozen by a pending resize don't dispatch
            let mut t_push = f64::INFINITY;
            for (i, tn) in self.tenants.iter().enumerate() {
                if tn.in_flight || tn.queue.is_empty() || self.frozen(i) {
                    continue;
                }
                let slice_free = self.timeline.free_at(&tn.lane());
                t_push = t_push.min(tn.queue[0].at.max(slice_free));
            }
            // earliest ready response pull
            let t_pull =
                self.pulls.iter().map(|p| p.ready).fold(f64::INFINITY, f64::min);
            if t_push.is_infinite() && t_pull.is_infinite() {
                break;
            }
            let now = self.timeline.free_at(&Lane::Bus).max(t_push.min(t_pull));
            if let Some(tel) = &self.telemetry {
                // queue depth per tenant at this bus-arbitration instant:
                // arrived but not yet dispatched
                for (i, tn) in self.tenants.iter().enumerate() {
                    let depth = tn.queue.iter().take_while(|a| a.at <= now).count();
                    tel.sample(
                        "sched_queue_depth",
                        Labels::tenant(&tenant_name(i)),
                        now,
                        depth as f64,
                    );
                }
            }
            // in-flight responses take bus priority over new pushes
            if let Some(pi) = self
                .pulls
                .iter()
                .enumerate()
                .filter(|(_, p)| p.ready <= now)
                .min_by(|(_, a), (_, b)| {
                    a.ready.total_cmp(&b.ready).then(a.seq.cmp(&b.seq))
                })
                .map(|(i, _)| i)
            {
                self.serve_pull(pi);
                continue;
            }
            // between batches: give the elastic policy one look at this
            // decision instant before committing the bus to a new push
            if self.maybe_decide(now) {
                continue;
            }
            let timeline = &self.timeline;
            let pending = self.elastic.as_ref().and_then(|e| e.pending.as_ref());
            let feasible: Vec<Candidate> = self
                .tenants
                .iter()
                .enumerate()
                .filter(|(i, tn)| {
                    let froze = match pending {
                        Some(p) => p.affected.contains(i),
                        None => false,
                    };
                    !froze
                        && !tn.in_flight
                        && !tn.queue.is_empty()
                        && tn.queue[0].at.max(timeline.free_at(&tn.lane())) <= now
                })
                .map(|(i, tn)| Candidate {
                    tenant: i,
                    arrival: tn.queue[0].at,
                    estimate: tn.estimate,
                    weight: tn.spec.weight,
                })
                .collect();
            debug_assert!(!feasible.is_empty(), "dispatch epoch with no candidate");
            let (t, want) = self.policy.pick(&feasible);
            assert!(
                feasible.iter().any(|c| c.tenant == t),
                "policy {} picked infeasible tenant {t}",
                self.policy.name()
            );
            self.dispatch(t, want, now);
        }
        self.finish()
    }

    /// Whether tenant `t` is frozen by a pending resize: affected
    /// tenants take no new dispatches until the move executes.
    fn frozen(&self, t: usize) -> bool {
        if let Some(e) = &self.elastic {
            if let Some(p) = &e.pending {
                return p.affected.contains(&t);
            }
        }
        false
    }

    /// Give the elastic policy one look at decision instant `now`
    /// (between batches, never mid-flight). Returns `true` when a move
    /// was armed, so the caller re-enters the loop and the freeze takes
    /// effect before the next dispatch.
    fn maybe_decide(&mut self, now: f64) -> bool {
        let Some(e) = &mut self.elastic else { return false };
        if e.pending.is_some()
            || now <= e.last_eval
            || now < e.last_end + e.cfg.cooldown
        {
            return false;
        }
        e.last_eval = now;
        let ranks: Vec<u32> =
            self.tenants.iter().map(|t| t.slice.n_ranks).collect();
        let tel = self
            .telemetry
            .as_ref()
            .expect("elastic runs always carry a telemetry registry");
        let view = ElasticView::new(now, &ranks, tel, e.cfg.window);
        let Some(mv) = e.policy.decide(&view) else { return false };
        // a policy proposing an impossible move is a bug — fail loud
        assert!(
            mv.from != mv.to
                && mv.ranks >= 1
                && mv.from < ranks.len()
                && mv.to < ranks.len()
                && ranks[mv.from] > mv.ranks,
            "elastic policy {} proposed an invalid move {mv:?} over ranks {ranks:?}",
            e.policy.name(),
        );
        let mut new_ranks = ranks.clone();
        new_ranks[mv.from] -= mv.ranks;
        new_ranks[mv.to] += mv.ranks;
        // slices stay contiguous in tenant order, so re-tiling can
        // re-home bystanders between donor and receiver — every tenant
        // whose geometry changes is affected and must drain
        let per = self.sys.dpus_per_rank();
        let old_slices = carve_slices(per, &ranks);
        let new_slices = carve_slices(per, &new_ranks);
        let affected: Vec<usize> = (0..ranks.len())
            .filter(|&i| {
                old_slices[i].rank0 != new_slices[i].rank0
                    || old_slices[i].n_ranks != new_slices[i].n_ranks
            })
            .collect();
        e.pending = Some(PendingMove { mv, decided_at: now, affected, new_ranks });
        true
    }

    /// Execute an armed resize once every affected tenant has drained
    /// (no batch in flight): freeze already happened at decision time,
    /// the drain window ends when the affected slices' lanes free up,
    /// then each affected tenant's resident state is re-pushed over the
    /// shared bus (and the modeled network link, on multi-machine
    /// fleets) into its new slice, and serving resumes. The re-push is
    /// priced by the same transfer model as any other push — migration
    /// is real modeled traffic, not a fudge factor.
    fn try_migrate(&mut self) {
        let ready = match &self.elastic {
            Some(e) => match &e.pending {
                Some(p) => p.affected.iter().all(|&i| !self.tenants[i].in_flight),
                None => return,
            },
            None => return,
        };
        if !ready {
            return;
        }
        let e = self.elastic.as_mut().unwrap();
        let p = e.pending.take().unwrap();
        let migrator = e.migrator.clone();
        let per = self.sys.dpus_per_rank();
        let new_slices = carve_slices(per, &p.new_ranks);
        // the drain window closes when every affected slice's lane is
        // free (their pulls have left the machine)
        let mut drain_end = p.decided_at;
        for &i in &p.affected {
            drain_end = drain_end.max(self.timeline.free_at(&self.tenants[i].lane()));
        }
        let mut clock = drain_end;
        for &i in &p.affected {
            let tseed = self.seed ^ (i as u64 + 1).wrapping_mul(GOLDEN);
            let ns = new_slices[i];
            let old = self.tenants[i].slice;
            let rc = RunConfig {
                sys: self.sys.clone(),
                n_dpus: ns.n_dpus,
                n_tasklets: self.tenants[i].session.n_tasklets,
                scale: self.tenants[i].spec.scale,
                seed: tseed,
                exec: self.exec,
                trace: None,
                metrics: None,
            };
            let tn = &mut self.tenants[i];
            // a migration repartitions the dataset, so the deferred
            // verification of the last served output must happen now,
            // against the dataset it was actually served from
            if let Some(out) = tn.last_out.take() {
                tn.pre_mig_verified = Some(tn.workload.verify(&tn.dataset, &out));
            }
            let (dataset, cost) = {
                let Tenant { workload, session, .. } = tn;
                migrator.migrate(session, workload.as_ref(), &rc, ns.rank0, ns.n_ranks)
            };
            let tn = &mut self.tenants[i];
            tn.dataset = dataset;
            tn.slice = ns;
            tn.mig.add(&cost.bd);
            tn.migrations += 1;
            tn.mig_net_secs += cost.net_secs;
            tn.mig_joules +=
                EnergyModel::default().pim_joules(&self.sys, ns.n_dpus, &cost.bd);
            // model the copy: optional inter-machine link leg, then the
            // shared bus carries the re-push bytes; both the old and the
            // new rank spans sit out the copy
            let (net_start, net_end) = if cost.net_secs > 0.0 {
                self.timeline.reserve(&Lane::Link(0), clock, cost.net_secs)
            } else {
                (clock, clock)
            };
            let (copy_start, copy_end) =
                self.timeline.reserve(&Lane::Bus, net_end, cost.bus_secs());
            self.timeline
                .hold(&Lane::Ranks(old.rank0..old.rank0 + old.n_ranks), copy_end);
            self.timeline
                .hold(&Lane::Ranks(ns.rank0..ns.rank0 + ns.n_ranks), copy_end);
            if let Some(sink) = &self.trace {
                let drain_ev = sink.push(TraceEvent {
                    id: 0, // assigned by the sink
                    kind: CmdKind::MigrateDrain,
                    lane: LaneTag::Ranks { lo: old.rank0, hi: old.rank0 + old.n_ranks },
                    start: p.decided_at,
                    secs: drain_end - p.decided_at,
                    bytes: 0,
                    tenant: Some(i as u32),
                    req: None,
                    deps: Vec::new(),
                });
                let before_copy = if cost.net_secs > 0.0 {
                    sink.push(TraceEvent {
                        id: 0,
                        kind: CmdKind::Net,
                        lane: LaneTag::Link { m: 0 },
                        start: net_start,
                        secs: cost.net_secs,
                        bytes: cost.bytes,
                        tenant: Some(i as u32),
                        req: None,
                        deps: vec![drain_ev],
                    })
                } else {
                    drain_ev
                };
                let copy_ev = sink.push(TraceEvent {
                    id: 0,
                    kind: CmdKind::MigrateCopy,
                    lane: LaneTag::Bus,
                    start: copy_start,
                    secs: cost.bus_secs(),
                    bytes: cost.bytes,
                    tenant: Some(i as u32),
                    req: None,
                    deps: vec![before_copy],
                });
                sink.push(TraceEvent {
                    id: 0,
                    kind: CmdKind::MigrateResume,
                    lane: LaneTag::Ranks { lo: ns.rank0, hi: ns.rank0 + ns.n_ranks },
                    start: copy_end,
                    secs: 0.0,
                    bytes: 0,
                    tenant: Some(i as u32),
                    req: None,
                    deps: vec![copy_ev],
                });
            }
            if let Some(tel) = &self.telemetry {
                let name = tenant_name(i);
                tel.counter_add("elastic_migrations", Labels::tenant(&name), 1);
                tel.counter_add(
                    "elastic_migration_bytes",
                    Labels::tenant(&name),
                    cost.bytes,
                );
                tel.sample(
                    "elastic_ranks",
                    Labels::tenant(&name),
                    copy_end,
                    ns.n_ranks as f64,
                );
            }
            clock = copy_end;
        }
        self.elastic.as_mut().unwrap().last_end = clock;
    }

    /// Grant tenant `t` the bus at `now`: pop up to `want` arrived
    /// requests, execute them functionally (stage → execute → retrieve
    /// through the session), and advance the modeled bus/slice timelines
    /// by the batch's aggregated push / kernel / pull seconds.
    fn dispatch(&mut self, t: usize, want: usize, now: f64) {
        let max_batch = self.max_batch;
        let tn = &mut self.tenants[t];
        let arrived = tn.queue.iter().take_while(|a| a.at <= now).count();
        let k = want.max(1).min(arrived).min(max_batch);
        let batch: Vec<Arrival> = tn.queue.drain(..k).collect();
        let reqs: Vec<Request> = batch.iter().map(|a| a.req).collect();

        let mut deltas: Vec<TimeBreakdown> = Vec::with_capacity(k);
        let overlap_before = tn.session.set.metrics.overlapped;
        {
            let Tenant { workload, dataset, session, last_out, .. } = tn;
            let w: &dyn Workload = workload.as_ref();
            let ds: &Dataset = &*dataset;
            let deltas = &mut deltas;
            session.execute_batch(
                &reqs,
                |r| w.stage(ds, r),
                |s: &mut Session, r: &Request, staged| {
                    let before = s.set.metrics;
                    let stats = w.execute(s, ds, r, staged);
                    // a request is only answered once its response is
                    // pulled — charge the per-request DPU-CPU traffic
                    *last_out = Some(w.retrieve(s, ds));
                    deltas.push(s.set.metrics.delta(&before));
                    stats
                },
            );
        }

        let tn = &mut self.tenants[t];

        // aggregate the batch's modeled service components; the
        // pipelined overlap credit is batch-level (execute_batch wraps
        // the batch in one async command queue and credits the derived
        // overlap at sync), so subtract it from the batch's bus
        // occupancy once rather than per delta
        let mut push = 0.0f64;
        let mut kernels = 0.0f64;
        let mut pull = 0.0f64;
        for d in &deltas {
            push += d.cpu_dpu;
            kernels += d.dpu + d.inter_dpu;
            pull += d.dpu_cpu;
        }
        let batch_overlap = tn.session.set.metrics.overlapped - overlap_before;
        let push = (push - batch_overlap).max(0.0);

        let mut recs = Vec::with_capacity(k);
        for a in &batch {
            recs.push(tn.records.len());
            tn.records.push(RequestRecord {
                id: a.req.id,
                arrival: a.at,
                dispatched: now,
                done: f64::NAN,
            });
        }

        // observed service feeds the SJF estimate (EWMA, α = 0.3)
        let obs = (push + kernels + pull) / k as f64;
        tn.estimate =
            if tn.served == 0 { obs } else { 0.7 * tn.estimate + 0.3 * obs };
        tn.served += k as u64;
        tn.in_flight = true;
        // active-phase energy of the batch (telemetry series; the
        // report's slice-level figure is recomputed in `finish`)
        let mut batch_bd = TimeBreakdown::default();
        for d in &deltas {
            batch_bd.add(d);
        }
        tn.joules += EnergyModel::default().pim_joules(&self.sys, tn.slice.n_dpus, &batch_bd);
        let est = tn.estimate;
        let joules_cum = tn.joules;
        let lane = tn.lane();

        // reserve the shared resources: the bus carries the push from
        // `now`, the tenant's rank lanes run the kernels after it; the
        // response pull re-arbitrates for the bus once the kernels
        // finish (dispatch only happens with the bus and slice idle, so
        // both reservations start exactly at their ready times)
        let (push_start, push_end) = self.timeline.reserve(&Lane::Bus, now, push);
        let (kern_start, kern_end) = self.timeline.reserve(&lane, push_end, kernels);
        let (req0, kernel_ev) = match &self.trace {
            None => (None, None),
            Some(sink) => {
                let req0 = batch.first().map(|a| a.req.id);
                let bytes_to: u64 = deltas.iter().map(|d| d.bytes_to_dpu).sum();
                let push_ev = sink.push(TraceEvent {
                    id: 0, // assigned by the sink
                    kind: CmdKind::Push,
                    lane: LaneTag::Bus,
                    start: push_start,
                    secs: push,
                    bytes: bytes_to,
                    tenant: Some(t as u32),
                    req: req0,
                    deps: Vec::new(),
                });
                let kernel_ev = sink.push(TraceEvent {
                    id: 0,
                    kind: CmdKind::Launch,
                    lane: LaneTag::from(Some(lane.clone())),
                    start: kern_start,
                    secs: kernels,
                    bytes: 0,
                    tenant: Some(t as u32),
                    req: req0,
                    deps: vec![push_ev],
                });
                (req0, Some(kernel_ev))
            }
        };
        if let Some(tel) = &self.telemetry {
            let name = tenant_name(t);
            tel.counter_add("sched_dispatches", Labels::tenant(&name), 1);
            for a in &batch {
                tel.observe("sched_queueing_secs", Labels::tenant(&name), now - a.at);
            }
            tel.sample("sched_ewma_secs", Labels::tenant(&name), now, est);
            tel.sample("sched_joules_cum", Labels::tenant(&name), kern_end, joules_cum);
        }
        let pull_bytes: u64 = deltas.iter().map(|d| d.bytes_from_dpu).sum();
        self.seq += 1;
        self.pulls.push(PendingPull {
            ready: kern_end,
            seq: self.seq,
            tenant: t,
            pull_secs: pull,
            recs,
            pull_bytes,
            req0,
            kernel_ev,
        });
    }

    /// Serve a ready response pull: the bus carries the batch's DPU-CPU
    /// bytes, the batch's requests complete together, and the slice
    /// frees up. The tenant's rank lanes are held occupied through the
    /// pull — a slice is busy until its response has left the machine.
    fn serve_pull(&mut self, idx: usize) {
        let p = self.pulls.remove(idx);
        let (pull_start, done) = self.timeline.reserve(&Lane::Bus, p.ready, p.pull_secs);
        if let Some(sink) = &self.trace {
            sink.push(TraceEvent {
                id: 0, // assigned by the sink
                kind: CmdKind::Pull,
                lane: LaneTag::Bus,
                start: pull_start,
                secs: p.pull_secs,
                bytes: p.pull_bytes,
                tenant: Some(p.tenant as u32),
                req: p.req0,
                deps: p.kernel_ev.into_iter().collect(),
            });
        }
        let lane = self.tenants[p.tenant].lane();
        self.timeline.hold(&lane, done);
        let tn = &mut self.tenants[p.tenant];
        tn.in_flight = false;
        tn.busy += done - tn.records[p.recs[0]].dispatched;
        for &ri in &p.recs {
            tn.records[ri].done = done;
        }
        if let Some(tel) = &self.telemetry {
            let name = tenant_name(p.tenant);
            tel.counter_add(
                "sched_requests_done",
                Labels::tenant(&name),
                p.recs.len() as u64,
            );
            for &ri in &p.recs {
                let lat = tn.records[ri].latency();
                tel.observe("sched_latency_secs", Labels::tenant(&name), lat);
                tel.sample("sched_done_latency", Labels::tenant(&name), done, lat);
            }
        }
    }

    fn finish(self) -> SchedReport {
        let Scheduler {
            tenants,
            policy_kind,
            seed,
            pipelined,
            total_ranks,
            telemetry,
            sys,
            elastic,
            ..
        } = self;
        let elastic_name = elastic.as_ref().map(|e| e.policy.name());
        let mut makespan = 0.0f64;
        for tn in &tenants {
            makespan = tn.records.iter().map(|r| r.done).fold(makespan, f64::max);
        }
        let em = EnergyModel::default();
        let mut reports = Vec::with_capacity(tenants.len());
        for tn in tenants {
            // a tenant whose final batch preceded a migration had its
            // output checked at migration time (the dataset it was
            // served from no longer exists)
            let verified = match &tn.last_out {
                Some(o) => tn.workload.verify(&tn.dataset, o),
                None => tn.pre_mig_verified.unwrap_or(false),
            };
            // serving traffic only: the migration re-pushes are billed
            // separately under `mig`
            let warm = tn.session.set.metrics.delta(&tn.mig);
            // serving-window energy: active during the slice's kernel
            // seconds, idling for the rest of the shared makespan (cold
            // load is excluded — clock 0 is "all tenants resident")
            let joules = em.slice_joules(&sys, tn.slice.n_dpus, &warm, makespan);
            if let Some(tel) = &telemetry {
                let name = tenant_name(tn.slice.tenant);
                tel.gauge_set(
                    "tenant_joules",
                    Labels::tenant(&name).with_bench(&tn.spec.bench),
                    joules,
                );
                let util = if makespan > 0.0 { tn.busy / makespan } else { 0.0 };
                tel.gauge_set("sched_slice_utilization", Labels::tenant(&name), util);
            }
            reports.push(TenantReport {
                bench: tn.spec.bench.clone(),
                slice: tn.slice,
                weight: tn.spec.weight,
                rate: tn.rate,
                cold: tn.cold,
                warm,
                records: tn.records,
                busy: tn.busy,
                joules,
                verified,
                migrations: tn.migrations,
                mig: tn.mig,
                mig_net_secs: tn.mig_net_secs,
                mig_joules: tn.mig_joules,
            });
        }
        let report = SchedReport {
            policy: policy_kind.name(),
            seed,
            pipelined,
            tenants: reports,
            makespan,
            total_ranks,
            elastic: elastic_name,
        };
        if let Some(tel) = &telemetry {
            tel.gauge_set("sched_occupancy", Labels::none(), report.occupancy());
            tel.gauge_set("sched_makespan_secs", Labels::none(), report.makespan);
        }
        report
    }
}

/// Build-and-run convenience for the CLI, harness, and examples.
pub fn run_sched(cfg: &SchedConfig) -> anyhow::Result<SchedReport> {
    Ok(Scheduler::build(cfg)?.run())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::layout::DMA_ALIGN;

    #[test]
    fn tenant_mix_parses_with_defaults_and_options() {
        let v = TenantSpec::parse_list("gemv:8,bs:4:2,va:4:1:1500").unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!((v[0].bench.as_str(), v[0].ranks, v[0].weight), ("gemv", 8, 1));
        assert_eq!(v[0].rate, 0.0, "unset rate defers to the config default");
        assert_eq!((v[1].ranks, v[1].weight), (4, 2));
        assert_eq!((v[2].weight, v[2].rate), (1, 1500.0));
    }

    #[test]
    fn tenant_mix_rejects_malformed_entries() {
        assert!(TenantSpec::parse_list("").is_err());
        assert!(TenantSpec::parse_list("gemv").is_err());
        assert!(TenantSpec::parse_list("gemv:0").is_err());
        assert!(TenantSpec::parse_list("gemv:x").is_err());
        assert!(TenantSpec::parse_list("gemv:2:0").is_err());
        assert!(TenantSpec::parse_list("gemv:2:1:zap").is_err());
        assert!(TenantSpec::parse_list("gemv:2:1:5:9").is_err());
    }

    #[test]
    fn slices_tile_the_fleet_without_overlap() {
        let ranks = [3u32, 1, 2];
        let slices = carve_slices(64, &ranks);
        assert_eq!(slices.len(), 3);
        // full coverage, rank granularity, no overlap
        let mut next_dpu = 0u32;
        let mut next_rank = 0u32;
        for (i, s) in slices.iter().enumerate() {
            assert_eq!(s.tenant, i);
            assert_eq!(s.rank0, next_rank);
            assert_eq!(s.dpu0, next_dpu);
            assert_eq!(s.n_dpus, s.n_ranks * 64);
            assert_eq!(s.dpu0 % 64, 0, "slices start on rank boundaries");
            next_rank += s.n_ranks;
            next_dpu += s.n_dpus;
        }
        assert_eq!(next_dpu, 6 * 64);
    }

    #[test]
    fn split_ranks_isolates_slices_and_preserves_alignment() {
        let parent = PimSet::allocate(SystemConfig::p21_2556(), 3 * 64);
        let mut sets = parent.split_ranks(&[1, 2]);
        assert_eq!(sets.len(), 2);
        assert_eq!(sets[0].n_dpus(), 64);
        assert_eq!(sets[1].n_dpus(), 128);
        // fresh per-slice layouts: both start at offset 0, 8-B aligned
        let a = sets[0].symbol::<i64>(5);
        let b = sets[1].symbol::<i32>(3);
        assert_eq!(a.off(), 0);
        assert_eq!(b.off(), 0);
        let a2 = sets[0].symbol::<u8>(1);
        assert_eq!(a2.off() % DMA_ALIGN, 0);
        // functional isolation: both slices write their own offset-0
        // region; neither clobbers the other
        sets[0].xfer(a).to().broadcast(&[7i64; 5]);
        let b_probe = sets[1].symbol::<i64>(5);
        sets[1].xfer(b_probe).to().broadcast(&[9i64; 5]);
        assert_eq!(sets[0].xfer(a).from().one(3, 5), vec![7i64; 5]);
        assert_eq!(sets[1].xfer(b_probe).from().one(100, 5), vec![9i64; 5]);
        // metrics are per-slice
        assert!(sets[0].metrics.cpu_dpu > 0.0);
        let before = sets[0].metrics;
        let _ = sets[1].xfer(b_probe).from().one(0, 5);
        assert_eq!(sets[0].metrics, before, "tenant 1 traffic never bills tenant 0");
    }

    #[test]
    #[should_panic(expected = "cover the fleet exactly")]
    fn split_ranks_rejects_partial_coverage() {
        let parent = PimSet::allocate(SystemConfig::p21_2556(), 3 * 64);
        let _ = parent.split_ranks(&[1, 1]);
    }

    /// The pure geometry preview and the actual carve must agree — this
    /// is what lets callers trust `carve_slices` for planning without
    /// allocating a fleet.
    #[test]
    fn carve_slices_matches_split_ranks_geometry() {
        let ranks = [2u32, 1, 3];
        let parent = PimSet::allocate(SystemConfig::p21_2556(), 6 * 64);
        let per = parent.cfg.dpus_per_rank();
        let sets = parent.split_ranks(&ranks);
        let slices = carve_slices(per, &ranks);
        assert_eq!(slices.len(), sets.len());
        for (s, set) in slices.iter().zip(&sets) {
            assert_eq!(s.rank0, set.rank0);
            assert_eq!(s.n_dpus, set.n_dpus());
            assert_eq!(s.dpu0, set.rank0 * per);
            assert_eq!(s.n_ranks, set.n_dpus() / per);
        }
    }

    #[test]
    fn sliced_fleets_keep_their_socket_position() {
        // 20 ranks split 10/10: the second slice reaches past the
        // 16-rank NUMA boundary even though it only owns 10 ranks
        let parent = PimSet::allocate(SystemConfig::p21_2556(), 20 * 64);
        assert!(parent.spans_sockets(), "20 ranks cross the boundary");
        let sets = parent.split_ranks(&[10, 10]);
        assert_eq!(sets[0].rank0, 0);
        assert_eq!(sets[1].rank0, 10);
        assert!(!sets[0].spans_sockets(), "ranks 0-9 stay on the near socket");
        assert!(sets[1].spans_sockets(), "ranks 10-19 reach past rank 16");
    }

    #[test]
    fn arrivals_are_deterministic_and_monotone() {
        let a = gen_arrivals(0, 42, 16, 1000.0);
        let b = gen_arrivals(0, 42, 16, 1000.0);
        assert_eq!(a, b);
        let times: Vec<f64> = a.iter().map(|x| x.at).collect();
        assert!(times.windows(2).all(|w| w[0] <= w[1]), "open-loop times sorted");
        assert!(times[0] > 0.0);
        // a different seed decorrelates
        let c = gen_arrivals(0, 43, 16, 1000.0);
        assert_ne!(a, c);
        // non-positive rate = burst at t=0
        let burst = gen_arrivals(1, 42, 4, 0.0);
        assert!(burst.iter().all(|x| x.at == 0.0));
        assert_eq!(burst[2].req, Request::stream(42, 4)[2]);
    }

    fn cand(tenant: usize, arrival: f64, estimate: f64, weight: u32) -> Candidate {
        Candidate { tenant, arrival, estimate, weight }
    }

    #[test]
    fn fifo_picks_earliest_arrival() {
        let f = &[cand(0, 2.0, 0.0, 1), cand(1, 1.0, 0.0, 1), cand(2, 1.0, 0.0, 1)];
        assert_eq!(Fifo.pick(f), (1, 1), "earliest arrival, lowest tenant on ties");
    }

    #[test]
    fn wrr_cycles_with_weights() {
        let mut p = WeightedRoundRobin::new();
        let f = &[cand(0, 0.0, 0.0, 2), cand(1, 0.0, 0.0, 1), cand(2, 0.0, 0.0, 3)];
        assert_eq!(p.pick(f), (0, 2));
        assert_eq!(p.pick(f), (1, 1));
        assert_eq!(p.pick(f), (2, 3));
        assert_eq!(p.pick(f), (0, 2), "pointer wraps");
        // skips tenants that are not feasible
        let partial = &[cand(2, 0.0, 0.0, 3)];
        assert_eq!(p.pick(partial), (2, 3));
    }

    #[test]
    fn sjf_picks_smallest_estimate() {
        let f = &[cand(0, 0.0, 3e-3, 1), cand(1, 5.0, 1e-3, 1), cand(2, 0.0, 2e-3, 1)];
        assert_eq!(ShortestJob.pick(f), (1, 1));
        // unprobed tenants (estimate 0) go first
        let g = &[cand(0, 0.0, 3e-3, 1), cand(1, 9.0, 0.0, 1)];
        assert_eq!(ShortestJob.pick(g), (1, 1));
    }

    /// Tiny end-to-end run: two resident tenants on disjoint rank slices,
    /// every request served, verified outputs, sane QoS accounting.
    #[test]
    fn end_to_end_two_tenants() {
        let mut specs = TenantSpec::parse_list("va:1,bs:1").unwrap();
        for s in &mut specs {
            s.scale = 0.002;
        }
        let mut cfg = SchedConfig::new(specs);
        cfg.requests = 3;
        cfg.rate = 0.0; // burst: maximum cross-tenant contention
        cfg.exec = ExecChoice::Serial;
        let rep = run_sched(&cfg).unwrap();
        assert_eq!(rep.tenants.len(), 2);
        assert_eq!(rep.total_ranks, 2);
        assert!(rep.makespan > 0.0);
        let occ = rep.occupancy();
        assert!(occ > 0.0 && occ <= 1.0, "occupancy {occ}");
        for t in &rep.tenants {
            assert!(t.verified, "{} must verify", t.bench);
            assert_eq!(t.records.len(), 3);
            for r in &t.records {
                assert!(r.done.is_finite(), "every request completes");
                assert!(r.latency() > 0.0);
                assert!(r.queueing() >= 0.0);
                assert!(r.dispatched >= r.arrival);
            }
            assert!(t.throughput() > 0.0);
            assert!(t.utilization(rep.makespan) <= 1.0 + 1e-12);
            assert!(t.warm.dpu > 0.0);
            assert!(t.cold.cpu_dpu > 0.0, "resident load paid in the cold window");
        }
        // within a tenant, dispatch respects arrival (id) order
        for t in &rep.tenants {
            let ids: Vec<u64> = t.records.iter().map(|r| r.id).collect();
            assert_eq!(ids, vec![0, 1, 2]);
        }
        // the report is reproducible bit-for-bit
        let rep2 = run_sched(&cfg).unwrap();
        assert_eq!(rep.to_json(), rep2.to_json());
    }

    /// Pipelining changes only the modeled bus occupancy of
    /// multi-request batches (the batch-level overlap credit):
    /// component buckets and functional outputs stay identical, and the
    /// timeline can only shrink.
    #[test]
    fn pipelined_batches_only_shrink_the_timeline() {
        let run = |pipeline: bool| {
            let mut specs = TenantSpec::parse_list("bs:1:4").unwrap();
            specs[0].scale = 0.002;
            let mut cfg = SchedConfig::new(specs);
            cfg.requests = 4;
            cfg.policy = PolicyKind::Wrr; // weight-4 grants batch the burst
            cfg.rate = 0.0;
            cfg.pipeline = pipeline;
            cfg.exec = ExecChoice::Serial;
            run_sched(&cfg).unwrap()
        };
        let ser = run(false);
        let pip = run(true);
        let (s, p) = (&ser.tenants[0], &pip.tenants[0]);
        assert!(s.verified && p.verified);
        // component buckets and bytes are schedule-independent
        assert_eq!(s.warm.cpu_dpu.to_bits(), p.warm.cpu_dpu.to_bits());
        assert_eq!(s.warm.dpu.to_bits(), p.warm.dpu.to_bits());
        assert_eq!(s.warm.bytes_to_dpu, p.warm.bytes_to_dpu);
        assert_eq!(s.warm.overlapped, 0.0);
        assert!(pip.makespan <= ser.makespan);
        if p.warm.overlapped > 0.0 {
            assert!(pip.makespan < ser.makespan, "credited pushes must shorten the bus");
        }
    }

    /// The shared-bus model must serialize cross-tenant transfers: with
    /// two tenants bursting at t=0, someone's bus grant waits for the
    /// other's push.
    #[test]
    fn bus_serializes_cross_tenant_pushes() {
        let mut specs = TenantSpec::parse_list("bs:1,bs:1").unwrap();
        for s in &mut specs {
            s.scale = 0.002;
        }
        let mut cfg = SchedConfig::new(specs);
        cfg.requests = 2;
        cfg.rate = 0.0;
        cfg.exec = ExecChoice::Serial;
        let rep = run_sched(&cfg).unwrap();
        let queued: f64 = rep
            .tenants
            .iter()
            .flat_map(|t| t.records.iter())
            .map(RequestRecord::queueing)
            .sum();
        assert!(queued > 0.0, "identical burst tenants must contend on the bus");
    }

    #[test]
    fn unshifted_generator_is_bitwise_the_shifted_one_with_no_shift() {
        let plain = gen_arrivals(2, 99, 32, 1200.0);
        let shifted = gen_arrivals_shifted(2, 99, 32, 1200.0, None);
        assert_eq!(plain, shifted);
    }

    #[test]
    fn load_shift_keeps_the_prefix_and_accelerates_the_tail() {
        let base = gen_arrivals(0, 7, 64, 800.0);
        let t0 = base[31].at;
        let hot = gen_arrivals_shifted(0, 7, 64, 800.0, Some((t0, 8.0)));
        // identical RNG draws: every arrival at or before the shift
        // instant lands at exactly the same time
        for (b, h) in base.iter().zip(&hot) {
            if b.at <= t0 {
                assert_eq!(b.at.to_bits(), h.at.to_bits());
            }
        }
        // ×8 rate compresses the tail
        assert!(
            hot[63].at < base[63].at,
            "shifted tail {} must beat unshifted {}",
            hot[63].at,
            base[63].at
        );
        assert!(hot.iter().zip(hot.iter().skip(1)).all(|(a, b)| a.at <= b.at));
    }

    /// End-to-end elastic run on a planned move: the donor shrinks, the
    /// receiver grows, both pay a nonzero migration bill measured
    /// through the ordinary transfer path, every request still completes
    /// verified, and the whole thing is reproducible bit-for-bit.
    #[test]
    fn planned_migration_resizes_slices_and_bills_the_copy() {
        use crate::coordinator::elastic::{ElasticPolicyKind, PlannedMove};
        let mut specs = TenantSpec::parse_list("va:2,bs:1").unwrap();
        for s in &mut specs {
            s.scale = 0.002;
        }
        let mut cfg = SchedConfig::new(specs);
        cfg.requests = 3;
        cfg.rate = 0.0;
        cfg.exec = ExecChoice::Serial;
        cfg.elastic = Some(ElasticConfig::new(ElasticPolicyKind::Planned(vec![
            PlannedMove { at: 0.0, mv: MoveRanks { from: 0, to: 1, ranks: 1 } },
        ])));
        let rep = run_sched(&cfg).unwrap();
        assert_eq!(rep.elastic, Some("planned"));
        // the move executed: geometry re-tiled in tenant order
        assert_eq!(rep.tenants[0].slice.n_ranks, 1);
        assert_eq!(rep.tenants[1].slice.n_ranks, 2);
        assert_eq!(rep.tenants[1].slice.rank0, 1);
        // both tenants' geometry changed, so both migrated and both paid
        assert_eq!(rep.migrations(), 2);
        assert!(rep.mig_bytes() > 0, "a resident dataset moved");
        assert!(rep.mig_secs() > 0.0, "the copy occupied the bus");
        assert!(rep.mig_joules() > 0.0, "the copy drew energy");
        for t in &rep.tenants {
            assert_eq!(t.migrations, 1);
            assert!(t.mig.bytes_to_dpu > 0);
            assert!(t.verified, "{} must verify across the migration", t.bench);
            assert_eq!(t.records.len(), 3);
            assert!(t.records.iter().all(|r| r.done.is_finite()));
        }
        // migration traffic is billed under mig, not warm: the warm
        // push bytes cover served requests only
        let rep2 = run_sched(&cfg).unwrap();
        assert_eq!(rep.to_json(), rep2.to_json(), "elastic runs are deterministic");
    }
}
