//! L3 coordinator — the rust analogue of the UPMEM host runtime.
//!
//! Owns the DPU fleet, the transfer engine, the MRAM layout, and the host
//! cost model, and accounts every second into the same four buckets the
//! paper's figures use: `DPU` (kernel time, max over concurrently-running
//! DPUs), `Inter-DPU` (host-orchestrated synchronization between
//! launches), `CPU-DPU` and `DPU-CPU` (input/result transfers).
//!
//! Data movement goes through **typed MRAM symbols** and a single builder
//! entry point ([`PimSet::xfer`]): allocate regions from the per-fleet
//! [`MramLayout`], then pick a direction (`to`/`from`), a distribution
//! (`one`, `equal`, `ragged`, `broadcast`), and — when the transfer is a
//! mid-run exchange — an accounting [`Bucket`]. (The pre-Symbol
//! raw-offset `copy_to`/`push_to`/`broadcast` family lived one release as
//! deprecated wrappers and is now gone.)
//!
//! Long-lived serving state is a [`Session`]: a `PimSet` kept warm across
//! many requests, with batched, pipelined execution (see [`session`]).
//!
//! Time-domain concurrency is modeled by **async command queues**
//! ([`queue`]): open one with [`PimSet::queue`] (or implicitly via a
//! pipelined `Session` batch), issue the same `xfer`/`launch` vocabulary,
//! and `sync()` schedules the recorded commands onto one serialized host
//! bus, per-rank kernel lanes, and the host CPU — deriving
//! [`TimeBreakdown::overlapped`] as `sum(command secs) − makespan`.
//! Every synchronous call is the degenerate one-command queue, so plain
//! accounting is bit-identical to the pre-queue model.
//!
//! Multi-tenant sharing carves one fleet into rank-granular slices
//! ([`PimSet::split_ranks`]), each backing its own resident session; the
//! [`scheduler`] arbitrates the same modeled resources ([`Timeline`])
//! between the tenants' request streams and accounts per-tenant QoS.

pub mod accounting;
pub mod cluster;
pub mod elastic;
pub mod executor;
pub mod layout;
pub mod partition;
pub mod queue;
pub mod scheduler;
pub mod session;
pub mod telemetry;
pub mod trace;

use crate::arch::SystemConfig;
use crate::dpu::{Ctx, Dpu, DpuTiming};
use crate::system::{HostModel, TransferEngine, XferModel};
use crate::util::pod::Pod;
use std::sync::Arc;

pub use cluster::{Cluster, ClusterConfig, ClusterReport, NetModel, Topology};
pub use elastic::{
    DepthPolicy, ElasticConfig, ElasticPolicy, ElasticPolicyKind, ElasticView, LatencyPolicy,
    MigrationCost, Migrator, MoveRanks, PlannedMove,
};
pub use executor::{
    ExecChoice, FleetExecutor, FleetSlot, LaunchJob, ParallelExecutor, SerialExecutor,
};
pub use layout::{MramLayout, Symbol};
pub use accounting::{Bucket, TimeBreakdown};
pub use partition::{chunk_ranges, chunk_ranges_aligned, cyclic_blocks, ragged_counts};
pub use queue::{
    Access, CmdId, CmdKind, CmdMeta, CmdQueue, Lane, RegionSet, Schedule, ScheduleStats, Timeline,
};
pub use scheduler::{
    run_sched, FleetSlice, LoadShift, PolicyKind, SchedConfig, SchedReport, Scheduler,
    TenantReport, TenantSpec,
};
pub use session::Session;
pub use telemetry::{
    parse_metrics, HealthReport, Histogram, Labels, MetricEntry, MetricValue, MetricsSnapshot,
    SloMonitor, SloStatus, SloTarget, Telemetry, TenantHealth,
};
pub use trace::{
    parse_trace, LaneTag, ReplayEngine, Trace, TraceEvent, TraceSink, TriageReport,
};

/// Statistics of one kernel launch across the allocated DPU set.
#[derive(Clone, Debug, Default)]
pub struct LaunchStats {
    /// Per-DPU timing (cycles etc.).
    pub timings: Vec<DpuTiming>,
    /// Seconds of the launch = slowest DPU (they run concurrently).
    pub secs: f64,
}

impl LaunchStats {
    /// Load imbalance: max/mean DPU cycles. Empty, all-zero-cycle, or
    /// otherwise degenerate timing sets report 1.0 (perfectly balanced)
    /// instead of walking the NaN-prone `max/mean` path.
    pub fn imbalance(&self) -> f64 {
        if self.timings.is_empty() {
            return 1.0;
        }
        let max = self.timings.iter().map(|t| t.cycles).fold(0.0, f64::max);
        let mean =
            self.timings.iter().map(|t| t.cycles).sum::<f64>() / self.timings.len() as f64;
        if mean.is_nan() || mean <= 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    pub fn total_instrs(&self) -> u64 {
        self.timings.iter().map(|t| t.instrs).sum()
    }

    pub fn total_dma_bytes(&self) -> u64 {
        self.timings.iter().map(|t| t.dma_bytes).sum()
    }
}

/// An allocated set of DPUs plus the host-side machinery — the object PrIM
/// benchmarks are written against (the `dpu_set_t` of the UPMEM SDK).
pub struct PimSet {
    pub cfg: SystemConfig,
    pub dpus: Vec<Dpu>,
    /// CPU↔DPU transfer engine (bandwidth model + functional movement).
    pub engine: TransferEngine,
    pub host: HostModel,
    pub metrics: TimeBreakdown,
    /// Per-fleet MRAM layout: every transferred region is carved out of
    /// this bump allocator as a typed [`Symbol`] (same offset in every
    /// DPU's bank, like linker-placed SDK symbols).
    pub layout: MramLayout,
    /// Fleet execution engine: walks the DPU set on launches and parallel
    /// transfers (serial baseline or multi-core sharding; see
    /// [`executor`]). Both engines are bit-identical in modeled time.
    pub exec: Arc<dyn FleetExecutor>,
    /// First global rank this set occupies (0 for a freshly allocated
    /// fleet; rank slices carved by [`PimSet::split_ranks`] record their
    /// physical position so NUMA placement stays visible).
    pub rank0: u32,
    /// Open async command queue, if any ([`PimSet::queue_begin`]). While
    /// open, every launch / transfer / host merge records a [`CmdMeta`]
    /// alongside its normal (unchanged) bucket accounting; `queue_sync`
    /// schedules the recorded program and credits the derived overlap.
    cmd_queue: Option<CmdQueue>,
    /// Drained queue shell kept for reuse: `queue_begin` takes it back
    /// instead of allocating, so steady-state pipelined serving records
    /// commands into a buffer that has already grown to session size.
    queue_pool: Option<CmdQueue>,
    /// Trace capture sink, if tracing is on ([`PimSet::with_trace`] /
    /// `RunConfig::trace`). Synchronous operations emit events directly
    /// on the set's [`trace_clock`](Self::trace_clock); queued batches
    /// emit at their scheduled offsets during `queue_sync` — from the
    /// same single scheduling pass that credits the overlap.
    pub trace: Option<TraceSink>,
    /// Session-local modeled clock the queue trace accumulates on.
    trace_clock: f64,
    /// Request tag stamped onto every recorded command / emitted event
    /// (set by `Session::execute_batch` around each request).
    pub trace_req: Option<u64>,
    /// Live telemetry registry, if metrics are on ([`PimSet::with_telemetry`]
    /// / `RunConfig::metrics`). `queue_sync` folds a post-hoc
    /// [`ScheduleStats`] digest of each schedule into it; like the trace
    /// sink, it is a pure observer — no modeled value ever depends on it.
    pub telemetry: Option<Telemetry>,
    /// Session-local modeled clock telemetry series accumulate on
    /// (advances by each sync's makespan, independent of `trace_clock`).
    tel_clock: f64,
}

impl PimSet {
    /// Allocate `n_dpus` DPUs of the configured system
    /// (`dpu_alloc(n_dpus, ...)`), with the executor resolved from the
    /// environment (`PRIM_EXECUTOR` / `PRIM_THREADS`; default parallel).
    pub fn allocate(cfg: SystemConfig, n_dpus: u32) -> Self {
        Self::allocate_with(cfg, n_dpus, ExecChoice::Auto.build())
    }

    /// Allocate with an explicit fleet executor.
    pub fn allocate_with(cfg: SystemConfig, n_dpus: u32, exec: Arc<dyn FleetExecutor>) -> Self {
        assert!(n_dpus >= 1, "need at least one DPU");
        assert!(
            n_dpus <= cfg.n_dpus(),
            "requested {n_dpus} DPUs but the {:?} system has {}",
            cfg.kind,
            cfg.n_dpus()
        );
        let dpus = (0..n_dpus).map(|_| Dpu::new(cfg.dpu)).collect();
        PimSet {
            dpus,
            engine: TransferEngine::new(XferModel {
                rank_size: cfg.dpus_per_rank(),
                ..XferModel::default()
            }),
            host: HostModel::default(),
            metrics: TimeBreakdown::default(),
            layout: MramLayout::new(cfg.dpu.mram_bytes),
            exec,
            rank0: 0,
            cmd_queue: None,
            queue_pool: None,
            trace: None,
            trace_clock: 0.0,
            trace_req: None,
            telemetry: None,
            tel_clock: 0.0,
            cfg,
        }
    }

    /// Install a trace sink (builder style) and stamp the capture
    /// geometry. Every subsequent operation — synchronous or queued —
    /// lands in the sink as a [`TraceEvent`].
    pub fn with_trace(mut self, sink: TraceSink) -> Self {
        let per = self.cfg.dpus_per_rank().max(1) as usize;
        let n_ranks = self.dpus.len().div_ceil(per) as u32;
        sink.set_geometry("queue", n_ranks);
        self.trace = Some(sink);
        self
    }

    /// Install a live telemetry registry (builder style). Every
    /// subsequent `queue_sync` folds its schedule digest — per-lane
    /// busy seconds, dep stalls, in-flight profile — into the registry.
    pub fn with_telemetry(mut self, tel: Telemetry) -> Self {
        self.telemetry = Some(tel);
        self
    }

    /// Swap the fleet executor (builder style).
    pub fn with_executor(mut self, exec: Arc<dyn FleetExecutor>) -> Self {
        self.exec = exec;
        self
    }

    pub fn n_dpus(&self) -> u32 {
        self.dpus.len() as u32
    }

    /// Does the set reach past the near socket's ranks of the 2,556-DPU
    /// machine? The paper observes the Inter-DPU NUMA jump beyond 16
    /// ranks (1,024 → 2,048 DPUs); for a freshly allocated fleet
    /// (`rank0 == 0`) this is the original ">16 ranks" test, and a rank
    /// slice carved from the middle of the machine counts its physical
    /// position, not just its size.
    pub fn spans_sockets(&self) -> bool {
        let per = self.cfg.dpus_per_rank();
        self.rank0 * per + self.n_dpus() > 16 * per
    }

    // ------------------------------------------------------------ transfers

    /// Allocate a typed MRAM region from the fleet layout (shorthand for
    /// `set.layout.alloc`).
    pub fn symbol<T: Pod>(&mut self, elems: usize) -> Symbol<T> {
        self.layout.alloc(elems)
    }

    /// The unified transfer entry point: start a transfer against `sym`.
    /// Chain a [`Bucket`] override (`.bucket(..)` / `.inter()`), pick the
    /// direction (`.to()` / `.from()`), then a distribution terminal:
    ///
    /// ```no_run
    /// # use prim_pim::arch::SystemConfig;
    /// # use prim_pim::coordinator::PimSet;
    /// # let mut set = PimSet::allocate(SystemConfig::p21_rank(), 4);
    /// let sym = set.symbol::<i64>(1024);
    /// let bufs: Vec<Vec<i64>> = (0..4usize).map(|d| vec![d as i64; 256 + 64 * d]).collect();
    /// set.xfer(sym).to().ragged(&bufs);            // per-DPU sizes, CPU-DPU bucket
    /// set.xfer(sym).inter().to().broadcast(&[1]);  // same bytes everywhere, Inter-DPU
    /// let lens: Vec<usize> = bufs.iter().map(Vec::len).collect();
    /// let back = set.xfer(sym).from().ragged(&lens);
    /// # let _ = back;
    /// ```
    pub fn xfer<T: Pod>(&mut self, sym: Symbol<T>) -> Xfer<'_, T> {
        assert_eq!(
            sym.generation(),
            self.layout.generation(),
            "stale {sym:?}: the MRAM layout was reset since this symbol was allocated"
        );
        Xfer { set: self, sym, bucket: None, after: Vec::new() }
    }

    /// Rewind the fleet's MRAM layout so a warm session can re-plan its
    /// resident dataset **without reallocating the fleet**. All symbols
    /// from the previous layout generation become stale; using one in a
    /// transfer panics (see [`MramLayout::reset`]). MRAM contents are
    /// untouched — the next `load` overwrites what it needs.
    pub fn reset_layout(&mut self) {
        self.layout.reset();
    }

    // ------------------------------------------------------ command queue

    /// Open an async command queue over this set: the returned session
    /// accepts the same `xfer`/`launch`/`launch_seq`/`launch_on`
    /// vocabulary; [`QueueSession::sync`] drains it, scheduling the
    /// recorded commands on the modeled resource timelines and crediting
    /// `sum(secs) − makespan` to [`TimeBreakdown::overlapped`]. Commands
    /// still execute functionally at issue time, in program order, so
    /// results are identical to synchronous calls.
    pub fn queue(&mut self) -> QueueSession<'_> {
        self.queue_begin();
        QueueSession { set: self, synced: false }
    }

    /// Flag-style variant of [`PimSet::queue`] for callers that cannot
    /// hold a guard across control flow (`Session::execute_batch`).
    pub fn queue_begin(&mut self) {
        assert!(
            self.cmd_queue.is_none(),
            "a command queue is already open on this set"
        );
        // Reuse the pooled shell from the previous session (already
        // grown to steady-state capacity) instead of allocating fresh.
        self.cmd_queue = Some(self.queue_pool.take().unwrap_or_default());
    }

    /// Drain the open queue: schedule the recorded commands onto the
    /// bus / rank / host lanes and fold the derived overlap into the
    /// metrics. Returns the hidden seconds. (If a kernel panicked
    /// mid-session the queue stays open and the *next* `queue_begin`
    /// reports it — the session that unwound is already lost.)
    pub fn queue_sync(&mut self) -> f64 {
        let mut q = self
            .cmd_queue
            .take()
            .expect("queue_sync without an open command queue");
        assert!(
            !q.group_open(),
            "queue_sync with an open transfer group (missing group_end)"
        );
        let per = self.cfg.dpus_per_rank().max(1) as usize;
        let n_ranks = self.dpus.len().div_ceil(per);
        // ONE scheduling pass serves both consumers: the overlap credit
        // (`Schedule::hidden`, bit-identical to the old `hidden_secs`
        // path — see `hidden_secs_matches_single_schedule_pass_bitwise`)
        // and the trace events at their scheduled offsets.
        let hidden = if q.is_empty() {
            0.0
        } else {
            let sched = q.schedule(n_ranks, per);
            if let Some(sink) = self.trace.as_ref() {
                let base = self.trace_clock;
                let id0 = sink.next_id();
                let lanes = q.lanes(n_ranks, per);
                let deps = q.dep_edges();
                for (i, cmd) in q.cmds().iter().enumerate() {
                    sink.push(TraceEvent {
                        id: 0, // assigned by the sink
                        kind: cmd.kind,
                        lane: lanes[i].clone().into(),
                        start: base + sched.start[i],
                        secs: cmd.secs,
                        bytes: cmd.bytes,
                        tenant: None,
                        req: cmd.req,
                        deps: deps[i].iter().map(|&j| id0 + j as u64).collect(),
                    });
                }
                self.trace_clock = base + sched.makespan;
            }
            if let Some(tel) = self.telemetry.as_ref() {
                let stats = q.schedule_stats(&sched, n_ranks, per);
                tel.record_schedule(&stats, self.tel_clock);
                self.tel_clock += sched.makespan;
            }
            sched.hidden()
        };
        self.metrics.overlapped += hidden;
        q.reset();
        self.queue_pool = Some(q);
        hidden
    }

    /// Id of the most recently recorded command (None outside a queue
    /// session) — the handle explicit `after` dependencies use.
    pub fn last_cmd(&self) -> Option<CmdId> {
        self.cmd_queue.as_ref().and_then(|q| q.last_id())
    }

    /// Enqueue a zero-second synchronization barrier (no-op outside a
    /// queue session) — the modeled `dpu_sync` between command groups.
    pub fn fence(&mut self) {
        self.record(CmdMeta::fence());
    }

    /// Start coalescing subsequent transfers into one recorded bus
    /// command (no-op outside a queue session; see
    /// [`CmdQueue::group_begin`]). Bucket accounting is unchanged — only
    /// the timeline granularity coarsens.
    pub fn group_begin(&mut self) {
        if let Some(q) = self.cmd_queue.as_mut() {
            q.group_begin();
        }
    }

    /// Close the transfer group opened by [`PimSet::group_begin`].
    pub fn group_end(&mut self) {
        if let Some(q) = self.cmd_queue.as_mut() {
            q.group_end();
        }
    }

    /// Is anything watching command metadata — an open queue or a trace
    /// sink? The transfer terminals check this before building a
    /// [`CmdMeta`], keeping the synchronous hot path (e.g. TRNS's
    /// per-request storm of tiny pushes) free of per-transfer
    /// allocations when neither is active.
    fn observing(&self) -> bool {
        self.cmd_queue.is_some() || self.trace.is_some()
    }

    /// Record a command into the open queue, if any, and/or into the
    /// trace. Inside a queue session the command only lands in the
    /// queue — its trace event is emitted at its *scheduled* offset
    /// during [`PimSet::queue_sync`]. Outside one, a synchronous call is
    /// the degenerate one-command queue whose makespan equals its
    /// seconds: it hides nothing, and its event goes back-to-back on the
    /// session-local trace clock.
    fn record(&mut self, mut cmd: CmdMeta) {
        cmd.req = self.trace_req;
        if let Some(q) = self.cmd_queue.as_mut() {
            q.push(cmd);
        } else if let Some(sink) = self.trace.as_ref() {
            let per = self.cfg.dpus_per_rank().max(1) as usize;
            let n_ranks = self.dpus.len().div_ceil(per);
            sink.push(TraceEvent {
                id: 0, // assigned by the sink
                kind: cmd.kind,
                lane: queue::lane_for(&cmd, per, n_ranks).into(),
                start: self.trace_clock,
                secs: cmd.secs,
                bytes: cmd.bytes,
                tenant: None,
                req: cmd.req,
                deps: Vec::new(),
            });
            self.trace_clock += cmd.secs;
        }
    }

    // --------------------------------------------------------------- launch

    /// Launch the SPMD function `f(dpu_idx, ctx)` on every DPU with
    /// `n_tasklets` tasklets. DPUs execute concurrently on real hardware,
    /// so the launch is charged `max` of the per-DPU times.
    pub fn launch<F>(&mut self, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        self.run_job(
            &LaunchJob { kernel: &f, n_tasklets, seq_tasklets: false },
            None,
            None,
        )
    }

    /// [`PimSet::launch`] with a declared MRAM footprint ([`Access`]):
    /// inside an async queue session the launch only serializes against
    /// commands touching the declared regions instead of the whole bank,
    /// which is what lets an independent (double-buffered) push hide
    /// under it. Outside a queue the declaration is inert.
    pub fn launch_acc<F>(&mut self, acc: Access, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        self.run_job(
            &LaunchJob { kernel: &f, n_tasklets, seq_tasklets: false },
            None,
            Some(acc),
        )
    }

    /// Sequential-tasklet-fast-path launch (§Perf): identical semantics to
    /// [`PimSet::launch`] for kernels without barriers or forward
    /// handshake waits (see [`crate::dpu::Dpu::launch_seq`]), but with
    /// zero per-tasklet thread overhead. Combined with the parallel fleet
    /// executor this is the lever that makes 2,048-DPU functional
    /// simulation tractable: one OS thread per *shard* instead of one per
    /// tasklet.
    pub fn launch_seq<F>(&mut self, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        self.run_job(
            &LaunchJob { kernel: &f, n_tasklets, seq_tasklets: true },
            None,
            None,
        )
    }

    /// [`PimSet::launch_seq`] with a declared MRAM footprint (see
    /// [`PimSet::launch_acc`]).
    pub fn launch_seq_acc<F>(&mut self, acc: Access, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        self.run_job(
            &LaunchJob { kernel: &f, n_tasklets, seq_tasklets: true },
            None,
            Some(acc),
        )
    }

    /// Launch on a prefix subset of the DPUs (NW uses fewer DPUs on short
    /// diagonals). Time is still `max` over the active DPUs.
    pub fn launch_on<F>(&mut self, dpu_ids: &[usize], n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        self.run_job(
            &LaunchJob { kernel: &f, n_tasklets, seq_tasklets: false },
            Some(dpu_ids),
            None,
        )
    }

    /// Common launch path: build the slot vector (whole fleet or a
    /// subset), hand it to the fleet executor, and account the modeled
    /// seconds. Timings come back in slot order, so the metrics folds are
    /// executor-independent (see [`executor`]'s determinism contract).
    /// An open command queue additionally records the launch, with the
    /// declared footprint or — undeclared — the whole bank (the safe,
    /// fully-serializing default of the synchronous shim).
    fn run_job(
        &mut self,
        job: &LaunchJob<'_>,
        subset: Option<&[usize]>,
        acc: Option<Access>,
    ) -> LaunchStats {
        let arch = self.cfg.dpu;
        let exec = Arc::clone(&self.exec);
        let timings = match subset {
            None => {
                let mut slots: Vec<FleetSlot<'_>> =
                    self.dpus.iter_mut().enumerate().collect();
                exec.launch(&mut slots, job)
            }
            Some(ids) => {
                let mut by_idx: Vec<Option<&mut Dpu>> =
                    self.dpus.iter_mut().map(Some).collect();
                let mut slots: Vec<FleetSlot<'_>> = Vec::with_capacity(ids.len());
                for &i in ids {
                    let dpu = by_idx[i].take().expect("duplicate DPU id in launch_on");
                    slots.push((i, dpu));
                }
                exec.launch(&mut slots, job)
            }
        };
        let max_cycles = timings.iter().map(|t| t.cycles).fold(0.0, f64::max);
        let secs = arch.cycles_to_secs(max_cycles);
        self.metrics.dpu += secs;
        self.metrics.launches += 1;
        if self.observing() {
            // conservative contiguous DPU span for sparse launch_on sets
            let dpus = match subset {
                None => 0..self.dpus.len(),
                Some(ids) => {
                    let lo = ids.iter().copied().min().unwrap_or(0);
                    let hi = ids.iter().copied().max().map_or(0, |m| m + 1);
                    lo..hi
                }
            };
            let cmd = match acc {
                Some(a) => CmdMeta::launch(dpus, a, secs),
                None => CmdMeta::launch_full(dpus, arch.mram_bytes, secs),
            };
            self.record(cmd);
        }
        LaunchStats { timings, secs }
    }

    // ----------------------------------------------------------- host model

    /// Charge host-side merge work (bytes streamed, scalar ops executed)
    /// to the `Inter-DPU` bucket. In a queue session the merge records
    /// with **fence** semantics (it conservatively depends on everything
    /// before it and gates everything after) — use
    /// [`PimSet::host_merge_dep`] to declare the precise data flow and
    /// let the merge overlap unrelated bus traffic.
    pub fn host_merge(&mut self, bytes: u64, ops: u64) {
        let spans = self.spans_sockets();
        let secs = self.host.merge_numa(bytes, ops, spans);
        self.metrics.inter_dpu += secs;
        self.record(CmdMeta::host_merge(secs).with_bytes(bytes));
    }

    /// [`PimSet::host_merge`] with declared dependencies: the merge
    /// consumes only the host images of the listed commands (typically
    /// the pulls it unions), so on the modeled timeline it runs on the
    /// host CPU lane concurrently with later bus transfers. Identical
    /// accounting to `host_merge` — the bucket charge does not change.
    pub fn host_merge_dep(&mut self, bytes: u64, ops: u64, after: &[CmdId]) {
        let spans = self.spans_sockets();
        let secs = self.host.merge_numa(bytes, ops, spans);
        self.metrics.inter_dpu += secs;
        self.record(CmdMeta::host_merge_after(secs, after.to_vec()).with_bytes(bytes));
    }

    /// Charge host merge work to an explicit bucket (SEL/UNI charge their
    /// retrieval-time merge to `DPU-CPU`, per the paper's methodology).
    pub fn host_merge_in(&mut self, bucket: Bucket, bytes: u64, ops: u64) {
        let spans = self.spans_sockets();
        let secs = self.host.merge_numa(bytes, ops, spans);
        self.metrics.account(bucket, secs, 0);
        self.record(CmdMeta::host_merge(secs).with_bytes(bytes));
    }

    /// Reset accumulated metrics (dataset stays in MRAM).
    pub fn reset_metrics(&mut self) {
        self.metrics = TimeBreakdown::default();
    }

    // ---------------------------------------------------------------- slicing

    /// Carve this fleet into **rank-granular, non-overlapping** sub-fleets:
    /// slice `i` takes the next `ranks[i]` whole ranks' worth of DPUs, in
    /// allocation order, and gets its own fresh [`MramLayout`] and metrics
    /// while inheriting the parent's transfer-engine and host-model
    /// calibration. The slices must cover the fleet exactly — the rank is
    /// the natural allocation unit of the UPMEM machine (transfers
    /// parallelize *within* a rank and serialize *across* ranks, §5.1.1),
    /// so multi-tenant sharing hands out whole ranks (see [`scheduler`]).
    ///
    /// All slices share the parent's fleet executor, so one worker pool
    /// serves the whole machine, and each records its physical rank
    /// origin ([`PimSet::rank0`]) so NUMA placement stays visible.
    pub fn split_ranks(self, ranks: &[u32]) -> Vec<PimSet> {
        let per = self.cfg.dpus_per_rank();
        assert!(!ranks.is_empty(), "need at least one slice");
        assert!(ranks.iter().all(|&r| r >= 1), "every slice needs at least one rank");
        let covered: u32 = ranks.iter().map(|&r| r * per).sum();
        assert_eq!(
            self.n_dpus(),
            covered,
            "slices must cover the fleet exactly: {} DPUs allocated, {covered} sliced \
             ({} DPUs/rank)",
            self.n_dpus(),
            per
        );
        let PimSet { cfg, dpus, engine, host, exec, rank0, telemetry, .. } = self;
        let mut rest = dpus;
        let mut next_rank0 = rank0;
        ranks
            .iter()
            .map(|&r| {
                let tail = rest.split_off((r * per) as usize);
                let slice_dpus = std::mem::replace(&mut rest, tail);
                let slice_rank0 = next_rank0;
                next_rank0 += r;
                PimSet {
                    dpus: slice_dpus,
                    engine: TransferEngine::new(engine.model.clone()),
                    host: host.clone(),
                    metrics: TimeBreakdown::default(),
                    layout: MramLayout::new(cfg.dpu.mram_bytes),
                    exec: Arc::clone(&exec),
                    rank0: slice_rank0,
                    cmd_queue: None,
                    queue_pool: None,
                    // Slices do NOT inherit the sink: each slice has its
                    // own session-local clock, and mixing them in one
                    // buffer would interleave incoherent timelines. The
                    // scheduler traces tenant work on the fleet-global
                    // timeline instead (`SchedConfig::trace`).
                    trace: None,
                    trace_clock: 0.0,
                    trace_req: None,
                    // Telemetry DOES propagate: the registry is keyed by
                    // (name, labels), not by a per-slice clock, so slice
                    // queue digests merge coherently in dispatch order.
                    telemetry: telemetry.clone(),
                    tel_clock: 0.0,
                    cfg: cfg.clone(),
                }
            })
            .collect()
    }

    /// Resize this slice in place to `n_ranks` whole ranks rooted at
    /// physical rank `rank0` — the mechanism behind elastic autoscaling
    /// (see [`elastic`]). The DPUs are re-provisioned fresh (resident
    /// MRAM contents do **not** teleport to the new geometry) and the
    /// layout generation is bumped, so every symbol allocated before
    /// the resize panics on use: the caller *must* re-plan and re-load
    /// its dataset, paying the migration bill as real modeled bus
    /// traffic. Metrics keep accumulating across the resize so the
    /// migration cost lands in the same accumulators the serving
    /// window uses (separable via [`TimeBreakdown::delta`]).
    pub fn resize_ranks(&mut self, rank0: u32, n_ranks: u32) {
        assert!(n_ranks >= 1, "a slice needs at least one rank");
        assert!(
            self.cmd_queue.is_none(),
            "cannot resize a slice with an open command queue"
        );
        let per = self.cfg.dpus_per_rank();
        self.dpus = (0..n_ranks * per).map(|_| Dpu::new(self.cfg.dpu)).collect();
        self.rank0 = rank0;
        self.layout.reset();
    }
}

// ------------------------------------------------------- transfer builder

/// A transfer in the making: symbol chosen, bucket optionally overridden,
/// direction not yet picked. See [`PimSet::xfer`].
#[must_use = "a transfer does nothing until a direction + distribution terminal runs"]
pub struct Xfer<'s, T: Pod> {
    set: &'s mut PimSet,
    sym: Symbol<T>,
    bucket: Option<Bucket>,
    after: Vec<CmdId>,
}

impl<'s, T: Pod> Xfer<'s, T> {
    /// Charge this transfer to an explicit accounting bucket. Defaults:
    /// `to` → [`Bucket::CpuDpu`], `from` → [`Bucket::DpuCpu`].
    pub fn bucket(mut self, bucket: Bucket) -> Self {
        self.bucket = Some(bucket);
        self
    }

    /// Shorthand for `.bucket(Bucket::InterDpu)` — mid-run exchanges
    /// between launches (the paper's "Inter-DPU" bar).
    pub fn inter(self) -> Self {
        self.bucket(Bucket::InterDpu)
    }

    /// Declare explicit queue dependencies (ids from
    /// [`PimSet::last_cmd`]): the transfer's payload derives from those
    /// commands' host-side results, which the symbol-region inference
    /// cannot see. Inert outside a queue session.
    pub fn after(mut self, deps: &[CmdId]) -> Self {
        self.after.extend_from_slice(deps);
        self
    }

    /// Host → MRAM direction.
    pub fn to(self) -> ToXfer<'s, T> {
        let bucket = self.bucket.unwrap_or(Bucket::CpuDpu);
        ToXfer { set: self.set, sym: self.sym, bucket, after: self.after }
    }

    /// MRAM → host direction.
    // An inherent `from` cannot be confused with `From::from` here: it
    // takes `self` and continues the builder chain.
    #[allow(clippy::should_implement_trait)]
    pub fn from(self) -> FromXfer<'s, T> {
        let bucket = self.bucket.unwrap_or(Bucket::DpuCpu);
        FromXfer { set: self.set, sym: self.sym, bucket, after: self.after }
    }
}

/// Host→MRAM transfer with direction fixed; pick a distribution terminal.
#[must_use = "a transfer does nothing until a distribution terminal runs"]
pub struct ToXfer<'s, T: Pod> {
    set: &'s mut PimSet,
    sym: Symbol<T>,
    bucket: Bucket,
    after: Vec<CmdId>,
}

/// Shared bounds check of every builder terminal: a transfer may not
/// exceed its symbol's capacity.
fn check_fits<T: Pod>(sym: &Symbol<T>, elems: usize) {
    assert!(
        elems <= sym.len(),
        "transfer of {elems} elements overflows {sym:?}"
    );
}

impl<T: Pod> ToXfer<'_, T> {
    /// Serial transfer to a single DPU (`dpu_copy_to`).
    pub fn one(self, dpu: usize, data: &[T]) {
        check_fits(&self.sym, data.len());
        let secs = self.set.engine.copy_to(&mut self.set.dpus[dpu], self.sym.off(), data);
        let bytes = std::mem::size_of_val(data);
        self.set.metrics.account(self.bucket, secs, bytes as u64);
        if self.set.observing() {
            let cmd = CmdMeta::push(
                dpu..dpu + 1,
                self.sym.off()..self.sym.off() + bytes,
                secs,
                self.after,
            )
            .with_bytes(bytes as u64);
            self.set.record(cmd);
        }
    }

    /// Parallel transfer of equal-size per-DPU buffers (`dpu_push_xfer`,
    /// the 2021.1.1 SDK shape).
    pub fn equal(self, bufs: &[Vec<T>]) {
        for b in bufs {
            check_fits(&self.sym, b.len());
        }
        let secs = self.set.engine.push_to(
            &*self.set.exec,
            &mut self.set.dpus,
            self.sym.off(),
            bufs,
        );
        let bytes: u64 =
            bufs.iter().map(|b| std::mem::size_of_val(b.as_slice()) as u64).sum();
        self.set.metrics.account(self.bucket, secs, bytes);
        let per_dpu = bufs.first().map_or(0, |b| std::mem::size_of_val(b.as_slice()));
        let n = self.set.dpus.len();
        if self.set.observing() {
            let cmd = CmdMeta::push(
                0..n,
                self.sym.off()..self.sym.off() + per_dpu,
                secs,
                self.after,
            )
            .with_bytes(bytes);
            self.set.record(cmd);
        }
    }

    /// Parallel transfer with **per-DPU sizes** — the generalization that
    /// retires the sentinel-padding workarounds (empty buffers skip their
    /// DPU entirely).
    pub fn ragged(self, bufs: &[Vec<T>]) {
        for b in bufs {
            check_fits(&self.sym, b.len());
        }
        let secs = self.set.engine.push_to_ragged(
            &*self.set.exec,
            &mut self.set.dpus,
            self.sym.off(),
            bufs,
        );
        let bytes: u64 =
            bufs.iter().map(|b| std::mem::size_of_val(b.as_slice()) as u64).sum();
        self.set.metrics.account(self.bucket, secs, bytes);
        let widest =
            bufs.iter().map(|b| std::mem::size_of_val(b.as_slice())).max().unwrap_or(0);
        let n = self.set.dpus.len();
        if self.set.observing() {
            let cmd = CmdMeta::push(
                0..n,
                self.sym.off()..self.sym.off() + widest,
                secs,
                self.after,
            )
            .with_bytes(bytes);
            self.set.record(cmd);
        }
    }

    /// Same buffer to every DPU (`dpu_broadcast_to`).
    pub fn broadcast(self, data: &[T]) {
        check_fits(&self.sym, data.len());
        let secs = self.set.engine.broadcast_to(
            &*self.set.exec,
            &mut self.set.dpus,
            self.sym.off(),
            data,
        );
        let per_dpu = std::mem::size_of_val(data);
        let n = self.set.dpus.len();
        self.set.metrics.account(self.bucket, secs, (n * per_dpu) as u64);
        if self.set.observing() {
            let cmd = CmdMeta::push(
                0..n,
                self.sym.off()..self.sym.off() + per_dpu,
                secs,
                self.after,
            )
            .with_bytes((n * per_dpu) as u64);
            self.set.record(cmd);
        }
    }
}

/// MRAM→host transfer with direction fixed; pick a distribution terminal.
#[must_use = "a transfer does nothing until a distribution terminal runs"]
pub struct FromXfer<'s, T: Pod> {
    set: &'s mut PimSet,
    sym: Symbol<T>,
    bucket: Bucket,
    after: Vec<CmdId>,
}

impl<T: Pod> FromXfer<'_, T> {
    /// Serial retrieval of `n` elements from a single DPU
    /// (`dpu_copy_from`).
    pub fn one(self, dpu: usize, n: usize) -> Vec<T> {
        check_fits(&self.sym, n);
        let (v, secs) = self.set.engine.copy_from(&self.set.dpus[dpu], self.sym.off(), n);
        let bytes = n * std::mem::size_of::<T>();
        self.set.metrics.account(self.bucket, secs, bytes as u64);
        if self.set.observing() {
            let cmd = CmdMeta::pull(
                dpu..dpu + 1,
                self.sym.off()..self.sym.off() + bytes,
                secs,
                self.after,
            )
            .with_bytes(bytes as u64);
            self.set.record(cmd);
        }
        v
    }

    /// Parallel retrieval of `n` elements from every DPU.
    pub fn equal(self, n: usize) -> Vec<Vec<T>> {
        check_fits(&self.sym, n);
        let (v, secs) = self.set.engine.push_from(
            &*self.set.exec,
            &mut self.set.dpus,
            self.sym.off(),
            n,
        );
        let per_dpu = n * std::mem::size_of::<T>();
        let n_dpus = self.set.dpus.len();
        self.set.metrics.account(self.bucket, secs, (n_dpus * per_dpu) as u64);
        if self.set.observing() {
            let cmd = CmdMeta::pull(
                0..n_dpus,
                self.sym.off()..self.sym.off() + per_dpu,
                secs,
                self.after,
            )
            .with_bytes((n_dpus * per_dpu) as u64);
            self.set.record(cmd);
        }
        v
    }

    /// Parallel retrieval of the whole symbol from every DPU.
    pub fn all(self) -> Vec<Vec<T>> {
        let n = self.sym.len();
        self.equal(n)
    }

    /// Parallel retrieval with **per-DPU lengths** (a zero length skips
    /// that DPU and returns an empty vector for it).
    pub fn ragged(self, lens: &[usize]) -> Vec<Vec<T>> {
        for &n in lens {
            check_fits(&self.sym, n);
        }
        let (v, secs) = self.set.engine.push_from_ragged(
            &*self.set.exec,
            &mut self.set.dpus,
            self.sym.off(),
            lens,
        );
        let bytes: u64 = lens.iter().map(|&n| (n * std::mem::size_of::<T>()) as u64).sum();
        self.set.metrics.account(self.bucket, secs, bytes);
        let widest = lens.iter().map(|&n| n * std::mem::size_of::<T>()).max().unwrap_or(0);
        let n_dpus = self.set.dpus.len();
        if self.set.observing() {
            let cmd = CmdMeta::pull(
                0..n_dpus,
                self.sym.off()..self.sym.off() + widest,
                secs,
                self.after,
            )
            .with_bytes(bytes);
            self.set.record(cmd);
        }
        v
    }
}

// ------------------------------------------------------- async queue guard

/// An open async command queue over a [`PimSet`] — the builder returned
/// by [`PimSet::queue`]. It accepts the same `xfer` / `launch` /
/// `launch_seq` / `launch_on` vocabulary as the set itself (commands
/// execute functionally at issue time and record their modeled
/// metadata), and [`QueueSession::sync`] drains it: the recorded program
/// is scheduled onto the bus / rank / host lanes and the derived overlap
/// credit lands in [`TimeBreakdown::overlapped`]. Dropping the session
/// without calling `sync` syncs implicitly.
pub struct QueueSession<'s> {
    set: &'s mut PimSet,
    synced: bool,
}

impl QueueSession<'_> {
    /// The underlying set, for anything not mirrored here.
    pub fn set(&mut self) -> &mut PimSet {
        self.set
    }

    /// See [`PimSet::xfer`].
    pub fn xfer<T: Pod>(&mut self, sym: Symbol<T>) -> Xfer<'_, T> {
        self.set.xfer(sym)
    }

    /// See [`PimSet::launch`].
    pub fn launch<F>(&mut self, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        self.set.launch(n_tasklets, f)
    }

    /// See [`PimSet::launch_seq`].
    pub fn launch_seq<F>(&mut self, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        self.set.launch_seq(n_tasklets, f)
    }

    /// See [`PimSet::launch_on`].
    pub fn launch_on<F>(&mut self, dpu_ids: &[usize], n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        self.set.launch_on(dpu_ids, n_tasklets, f)
    }

    /// See [`PimSet::launch_acc`].
    pub fn launch_acc<F>(&mut self, acc: Access, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        self.set.launch_acc(acc, n_tasklets, f)
    }

    /// See [`PimSet::launch_seq_acc`].
    pub fn launch_seq_acc<F>(&mut self, acc: Access, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        self.set.launch_seq_acc(acc, n_tasklets, f)
    }

    /// See [`PimSet::host_merge`].
    pub fn host_merge(&mut self, bytes: u64, ops: u64) {
        self.set.host_merge(bytes, ops);
    }

    /// See [`PimSet::host_merge_dep`].
    pub fn host_merge_dep(&mut self, bytes: u64, ops: u64, after: &[CmdId]) {
        self.set.host_merge_dep(bytes, ops, after);
    }

    /// See [`PimSet::fence`].
    pub fn fence(&mut self) {
        self.set.fence();
    }

    /// See [`PimSet::last_cmd`].
    pub fn last_cmd(&self) -> Option<CmdId> {
        self.set.last_cmd()
    }

    /// Drain the queue: schedule the recorded commands and credit the
    /// derived overlap. Returns the hidden seconds.
    pub fn sync(mut self) -> f64 {
        self.synced = true;
        self.set.queue_sync()
    }
}

impl Drop for QueueSession<'_> {
    fn drop(&mut self) {
        if !self.synced {
            self.set.queue_sync();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::SystemConfig;

    #[test]
    fn allocate_and_launch() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 4);
        let data = set.symbol::<i64>(16);
        let out = set.symbol::<i64>(1);
        let bufs: Vec<Vec<i64>> = (0..4).map(|i| vec![i as i64; 16]).collect();
        set.xfer(data).to().equal(&bufs);
        let out_off = out.off();
        let stats = set.launch(8, move |_i, ctx| {
            let b = ctx.mem_alloc(128);
            ctx.mram_read(data.off(), b, 128);
            let v: Vec<i64> = ctx.wram_get(b, 16);
            let s: i64 = v.iter().sum();
            ctx.wram_set(b, &[s]);
            ctx.charge_stream(crate::arch::DType::I64, crate::arch::Op::Add, 16);
            ctx.mram_write(b, out_off, 8);
        });
        assert_eq!(stats.timings.len(), 4);
        assert!(stats.secs > 0.0);
        assert!(set.metrics.dpu > 0.0);
        assert!(set.metrics.cpu_dpu > 0.0);
        // per-DPU sums
        for i in 0..4usize {
            let s = set.xfer(out).from().one(i, 1);
            assert_eq!(s[0], 16 * i as i64);
        }
        assert!(set.metrics.dpu_cpu > 0.0);
    }

    #[test]
    fn launch_charges_max_not_sum() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 8);
        let stats = set.launch(1, |i, ctx| {
            ctx.compute(1000 * (i as u64 + 1));
        });
        // max DPU has 8000 instrs at 1/11 → 88_000 cycles
        let expect = set.cfg.dpu.cycles_to_secs(88_000.0);
        assert!((stats.secs - expect).abs() / expect < 0.01);
    }

    #[test]
    #[should_panic(expected = "requested")]
    fn over_allocation_rejected() {
        PimSet::allocate(SystemConfig::p21_rank(), 65);
    }

    #[test]
    fn imbalance_metric() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 2);
        let stats = set.launch(1, |i, ctx| ctx.compute(if i == 0 { 100 } else { 300 }));
        assert!(stats.imbalance() > 1.4);
    }

    /// Serial and parallel executors produce bit-identical stats and data
    /// through the full PimSet surface (equal push / launch / launch_on /
    /// equal gather).
    #[test]
    fn executors_bit_identical_through_pimset() {
        let run = |exec: Arc<dyn FleetExecutor>| {
            let mut set = PimSet::allocate_with(SystemConfig::p21_rank(), 8, exec);
            let data = set.symbol::<i64>(16);
            let out = set.symbol::<i64>(1);
            let bufs: Vec<Vec<i64>> = (0..8).map(|i| vec![i as i64 + 1; 16]).collect();
            set.xfer(data).to().equal(&bufs);
            let out_off = out.off();
            let s1 = set.launch(4, move |d, ctx| {
                let b = ctx.mem_alloc(128);
                ctx.mram_read(data.off(), b, 128);
                let v: Vec<i64> = ctx.wram_get(b, 16);
                let sum: i64 = v.iter().sum();
                ctx.wram_set(b, &[sum]);
                ctx.charge_stream(crate::arch::DType::I64, crate::arch::Op::Add, 16);
                ctx.compute(10 * d as u64);
                ctx.mram_write(b, out_off, 8);
            });
            let s2 = set.launch_on(&[1, 3, 5], 2, |d, ctx| ctx.compute(50 * d as u64 + 7));
            let out = set.xfer(out).from().equal(1);
            (s1, s2, out, set.metrics)
        };
        let (a1, a2, ao, am) = run(Arc::new(SerialExecutor));
        let (b1, b2, bo, bm) = run(Arc::new(ParallelExecutor::new(4)));
        assert_eq!(ao, bo, "functional outputs must not depend on the executor");
        assert_eq!(am, bm, "time breakdown must be bit-identical");
        assert_eq!(a1.secs.to_bits(), b1.secs.to_bits());
        assert_eq!(a2.secs.to_bits(), b2.secs.to_bits());
        assert_eq!(a1.timings.len(), b1.timings.len());
        assert_eq!(a2.timings.len(), 3);
        for (s, p) in a1.timings.iter().zip(&b1.timings).chain(a2.timings.iter().zip(&b2.timings))
        {
            assert_eq!(s.cycles.to_bits(), p.cycles.to_bits());
            assert_eq!(s.instrs, p.instrs);
            assert_eq!(s.dma_bytes, p.dma_bytes);
        }
    }

    #[test]
    fn broadcast_goes_through_executor() {
        let mut set = PimSet::allocate_with(
            SystemConfig::p21_rank(),
            6,
            Arc::new(ParallelExecutor::new(3)),
        );
        let sym = set.symbol::<i64>(8);
        set.xfer(sym).to().broadcast(&[9i64; 8]);
        for d in 0..6 {
            assert_eq!(set.xfer(sym).from().one(d, 8), vec![9i64; 8]);
        }
        assert!(set.metrics.cpu_dpu > 0.0);
    }

    #[test]
    fn ragged_roundtrip_and_accounting() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 4);
        let sym = set.symbol::<i32>(64);
        let bufs: Vec<Vec<i32>> =
            vec![vec![1; 64], vec![2; 8], Vec::new(), vec![4; 24]];
        set.xfer(sym).to().ragged(&bufs);
        let sent: usize = bufs.iter().map(|b| b.len() * 4).sum();
        assert_eq!(set.metrics.bytes_to_dpu, sent as u64);
        assert!(set.metrics.cpu_dpu > 0.0);
        let lens: Vec<usize> = bufs.iter().map(Vec::len).collect();
        let back = set.xfer(sym).from().ragged(&lens);
        assert_eq!(back, bufs);
        assert_eq!(set.metrics.bytes_from_dpu, sent as u64);
    }

    #[test]
    fn bucket_override_routes_every_terminal() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 2);
        let sym = set.symbol::<i64>(8);
        set.xfer(sym).inter().to().broadcast(&[1i64; 8]);
        set.xfer(sym).inter().to().one(0, &[2i64; 4]);
        let _ = set.xfer(sym).inter().from().equal(4);
        let _ = set.xfer(sym).bucket(Bucket::InterDpu).from().ragged(&[2, 4]);
        assert_eq!(set.metrics.cpu_dpu, 0.0);
        assert_eq!(set.metrics.dpu_cpu, 0.0);
        assert!(set.metrics.inter_dpu > 0.0);
        assert_eq!(set.metrics.bytes_to_dpu, 0);
        assert_eq!(set.metrics.bytes_from_dpu, 0);
        assert_eq!(
            set.metrics.bytes_inter,
            (2 * 64 + 32 + 2 * 32 + 48) as u64
        );
    }

    #[test]
    #[should_panic(expected = "overflows")]
    fn builder_rejects_symbol_overflow() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 2);
        let sym = set.symbol::<i64>(4);
        set.xfer(sym).to().broadcast(&[0i64; 8]);
    }

    /// The async surface: a double-buffered push with no data dependency
    /// on the running launch slides under it on the modeled timeline,
    /// and the credit lands in `overlapped` — while synchronous calls
    /// (the degenerate one-command queues) never credit anything.
    #[test]
    fn async_queue_surface_credits_overlap() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 4);
        let a = set.symbol::<i64>(256);
        let b = set.symbol::<i64>(256);
        let out = set.symbol::<i64>(2);
        let bufs: Vec<Vec<i64>> = (0..4).map(|d| vec![d as i64; 256]).collect();
        let mut q = set.queue();
        q.xfer(a).to().equal(&bufs);
        q.launch_seq_acc(
            Access::new().read(a.region()).write(out.region()),
            4,
            move |_d, ctx| {
                let w = ctx.mem_alloc(2048);
                ctx.mram_read(a.off(), w, 2048);
                ctx.compute(2_000_000);
                ctx.mram_write(w, out.off(), 16);
            },
        );
        // the next request's input goes to the other buffer: independent
        q.xfer(b).to().equal(&bufs);
        q.launch_seq_acc(
            Access::new().read(b.region()).write(out.region()),
            4,
            move |_d, ctx| {
                let w = ctx.mem_alloc(2048);
                ctx.mram_read(b.off(), w, 2048);
                ctx.compute(2_000_000);
                ctx.mram_write(w, out.off(), 16);
            },
        );
        let hidden = q.sync();
        assert!(hidden > 0.0, "the second push must hide under the first launch");
        assert_eq!(set.metrics.overlapped.to_bits(), hidden.to_bits());
        assert!(
            set.metrics.overlapped <= set.metrics.cpu_dpu,
            "here only pushes can hide"
        );
        assert!(set.metrics.total() < set.metrics.dpu + set.metrics.cpu_dpu);
    }

    #[test]
    fn queue_session_syncs_on_drop_and_charges_nothing_for_one_command() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 2);
        let sym = set.symbol::<i64>(8);
        {
            let mut q = set.queue();
            q.xfer(sym).to().broadcast(&[1i64; 8]);
        } // dropped without sync(): drains implicitly
        assert_eq!(set.metrics.overlapped, 0.0, "a single command hides nothing");
        // the queue closed cleanly: a new session can open
        let hidden = set.queue().sync();
        assert_eq!(hidden, 0.0);
    }

    /// Syncing with a transfer group still open would silently drop the
    /// folded members from the timeline — surface it at the bug site.
    #[test]
    #[should_panic(expected = "open transfer group")]
    fn queue_sync_with_open_group_panics() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 2);
        set.queue_begin();
        set.group_begin();
        set.queue_sync();
    }

    #[test]
    #[should_panic(expected = "stale")]
    fn stale_symbol_after_layout_reset_panics() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 2);
        let sym = set.symbol::<i64>(8);
        set.reset_layout();
        set.xfer(sym).to().broadcast(&[0i64; 8]);
    }

    #[test]
    fn reset_layout_replans_without_reallocating_the_fleet() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 2);
        let a = set.symbol::<i64>(8);
        set.xfer(a).to().broadcast(&[7i64; 8]);
        set.reset_layout();
        let b = set.symbol::<i32>(4);
        assert_eq!(b.off(), 0, "a fresh generation restarts the bump allocator");
        set.xfer(b).to().broadcast(&[1i32; 4]);
        assert_eq!(set.xfer(b).from().one(0, 4), vec![1i32; 4]);
    }

    /// Regression: all-zero-cycle timings (e.g. a launch that did no
    /// charged work) must report perfect balance, not walk max/mean.
    #[test]
    fn imbalance_of_all_zero_cycle_timings_is_one() {
        let stats = LaunchStats {
            timings: vec![DpuTiming::default(); 4],
            secs: 0.0,
        };
        assert_eq!(stats.imbalance(), 1.0);
        assert_eq!(LaunchStats::default().imbalance(), 1.0);
    }
}
