//! L3 coordinator — the rust analogue of the UPMEM host runtime.
//!
//! Owns the DPU fleet, the transfer engine, and the host cost model, and
//! accounts every second into the same four buckets the paper's figures
//! use: `DPU` (kernel time, max over concurrently-running DPUs),
//! `Inter-DPU` (host-orchestrated synchronization between launches),
//! `CPU-DPU` and `DPU-CPU` (input/result transfers).

pub mod executor;
pub mod metrics;
pub mod partition;

use crate::arch::SystemConfig;
use crate::dpu::{Ctx, Dpu, DpuTiming};
use crate::system::{HostModel, TransferEngine, XferModel};
use crate::util::pod::Pod;
use std::sync::Arc;

pub use executor::{
    ExecChoice, FleetExecutor, FleetSlot, LaunchJob, ParallelExecutor, SerialExecutor,
};
pub use metrics::TimeBreakdown;
pub use partition::{chunk_ranges, chunk_ranges_aligned, cyclic_blocks};

/// Statistics of one kernel launch across the allocated DPU set.
#[derive(Clone, Debug, Default)]
pub struct LaunchStats {
    /// Per-DPU timing (cycles etc.).
    pub timings: Vec<DpuTiming>,
    /// Seconds of the launch = slowest DPU (they run concurrently).
    pub secs: f64,
}

impl LaunchStats {
    /// Load imbalance: max/mean DPU cycles.
    pub fn imbalance(&self) -> f64 {
        if self.timings.is_empty() {
            return 1.0;
        }
        let max = self.timings.iter().map(|t| t.cycles).fold(0.0, f64::max);
        let mean =
            self.timings.iter().map(|t| t.cycles).sum::<f64>() / self.timings.len() as f64;
        if mean == 0.0 {
            1.0
        } else {
            max / mean
        }
    }

    pub fn total_instrs(&self) -> u64 {
        self.timings.iter().map(|t| t.instrs).sum()
    }

    pub fn total_dma_bytes(&self) -> u64 {
        self.timings.iter().map(|t| t.dma_bytes).sum()
    }
}

/// An allocated set of DPUs plus the host-side machinery — the object PrIM
/// benchmarks are written against (the `dpu_set_t` of the UPMEM SDK).
pub struct PimSet {
    pub cfg: SystemConfig,
    pub dpus: Vec<Dpu>,
    pub xfer: TransferEngine,
    pub host: HostModel,
    pub metrics: TimeBreakdown,
    /// Fleet execution engine: walks the DPU set on launches and parallel
    /// transfers (serial baseline or multi-core sharding; see
    /// [`executor`]). Both engines are bit-identical in modeled time.
    pub exec: Arc<dyn FleetExecutor>,
}

impl PimSet {
    /// Allocate `n_dpus` DPUs of the configured system
    /// (`dpu_alloc(n_dpus, ...)`), with the executor resolved from the
    /// environment (`PRIM_EXECUTOR` / `PRIM_THREADS`; default parallel).
    pub fn allocate(cfg: SystemConfig, n_dpus: u32) -> Self {
        Self::allocate_with(cfg, n_dpus, ExecChoice::Auto.build())
    }

    /// Allocate with an explicit fleet executor.
    pub fn allocate_with(cfg: SystemConfig, n_dpus: u32, exec: Arc<dyn FleetExecutor>) -> Self {
        assert!(n_dpus >= 1, "need at least one DPU");
        assert!(
            n_dpus <= cfg.n_dpus(),
            "requested {n_dpus} DPUs but the {:?} system has {}",
            cfg.kind,
            cfg.n_dpus()
        );
        let dpus = (0..n_dpus).map(|_| Dpu::new(cfg.dpu)).collect();
        PimSet {
            dpus,
            xfer: TransferEngine::new(XferModel {
                rank_size: cfg.dpus_per_rank(),
                ..XferModel::default()
            }),
            host: HostModel::default(),
            metrics: TimeBreakdown::default(),
            exec,
            cfg,
        }
    }

    /// Swap the fleet executor (builder style).
    pub fn with_executor(mut self, exec: Arc<dyn FleetExecutor>) -> Self {
        self.exec = exec;
        self
    }

    pub fn n_dpus(&self) -> u32 {
        self.dpus.len() as u32
    }

    /// Does the set span both sockets of the 2,556-DPU machine (>16 ranks)?
    pub fn spans_sockets(&self) -> bool {
        self.n_dpus() > 16 * self.cfg.dpus_per_rank()
    }

    // ------------------------------------------------------------ transfers

    /// Serial CPU→DPU transfer (`dpu_copy_to`); charged to `CPU-DPU`.
    pub fn copy_to<T: Pod>(&mut self, dpu: usize, mram_off: usize, data: &[T]) {
        let s = self.xfer.copy_to(&mut self.dpus[dpu], mram_off, data);
        self.metrics.cpu_dpu += s;
        self.metrics.bytes_to_dpu += std::mem::size_of_val(data) as u64;
    }

    /// Serial DPU→CPU transfer (`dpu_copy_from`); charged to `DPU-CPU`.
    pub fn copy_from<T: Pod>(&mut self, dpu: usize, mram_off: usize, n: usize) -> Vec<T> {
        let (v, s) = self.xfer.copy_from(&self.dpus[dpu], mram_off, n);
        self.metrics.dpu_cpu += s;
        self.metrics.bytes_from_dpu += (n * std::mem::size_of::<T>()) as u64;
        v
    }

    /// Parallel CPU→DPU transfer of equal-size buffers (`dpu_push_xfer`).
    pub fn push_to<T: Pod>(&mut self, mram_off: usize, bufs: &[Vec<T>]) {
        let s = self.xfer.push_to(&*self.exec, &mut self.dpus, mram_off, bufs);
        self.metrics.cpu_dpu += s;
        self.metrics.bytes_to_dpu +=
            bufs.iter().map(|b| std::mem::size_of_val(b.as_slice()) as u64).sum::<u64>();
    }

    /// Parallel DPU→CPU retrieval of equal-size buffers.
    pub fn push_from<T: Pod>(&mut self, mram_off: usize, n: usize) -> Vec<Vec<T>> {
        let (v, s) = self.xfer.push_from(&*self.exec, &mut self.dpus, mram_off, n);
        self.metrics.dpu_cpu += s;
        self.metrics.bytes_from_dpu += (self.dpus.len() * n * std::mem::size_of::<T>()) as u64;
        v
    }

    /// Broadcast the same buffer to all DPUs (`dpu_broadcast_to`).
    pub fn broadcast<T: Pod>(&mut self, mram_off: usize, data: &[T]) {
        let s = self.xfer.broadcast_to(&*self.exec, &mut self.dpus, mram_off, data);
        self.metrics.cpu_dpu += s;
        self.metrics.bytes_to_dpu +=
            (self.dpus.len() * std::mem::size_of_val(data)) as u64;
    }

    /// Variant of the parallel transfers used during *inter-DPU*
    /// synchronization phases (the paper charges mid-kernel exchanges to
    /// "Inter-DPU", not to CPU-DPU/DPU-CPU input/output time).
    pub fn push_to_inter<T: Pod>(&mut self, mram_off: usize, bufs: &[Vec<T>]) {
        let s = self.xfer.push_to(&*self.exec, &mut self.dpus, mram_off, bufs);
        self.metrics.inter_dpu += s;
        self.metrics.bytes_inter +=
            bufs.iter().map(|b| std::mem::size_of_val(b.as_slice()) as u64).sum::<u64>();
    }

    pub fn push_from_inter<T: Pod>(&mut self, mram_off: usize, n: usize) -> Vec<Vec<T>> {
        let (v, s) = self.xfer.push_from(&*self.exec, &mut self.dpus, mram_off, n);
        self.metrics.inter_dpu += s;
        self.metrics.bytes_inter += (self.dpus.len() * n * std::mem::size_of::<T>()) as u64;
        v
    }

    pub fn broadcast_inter<T: Pod>(&mut self, mram_off: usize, data: &[T]) {
        let s = self.xfer.broadcast_to(&*self.exec, &mut self.dpus, mram_off, data);
        self.metrics.inter_dpu += s;
        self.metrics.bytes_inter += (self.dpus.len() * std::mem::size_of_val(data)) as u64;
    }

    pub fn copy_to_inter<T: Pod>(&mut self, dpu: usize, mram_off: usize, data: &[T]) {
        let s = self.xfer.copy_to(&mut self.dpus[dpu], mram_off, data);
        self.metrics.inter_dpu += s;
        self.metrics.bytes_inter += std::mem::size_of_val(data) as u64;
    }

    pub fn copy_from_inter<T: Pod>(&mut self, dpu: usize, mram_off: usize, n: usize) -> Vec<T> {
        let (v, s) = self.xfer.copy_from(&self.dpus[dpu], mram_off, n);
        self.metrics.inter_dpu += s;
        self.metrics.bytes_inter += (n * std::mem::size_of::<T>()) as u64;
        v
    }

    // --------------------------------------------------------------- launch

    /// Launch the SPMD function `f(dpu_idx, ctx)` on every DPU with
    /// `n_tasklets` tasklets. DPUs execute concurrently on real hardware,
    /// so the launch is charged `max` of the per-DPU times.
    pub fn launch<F>(&mut self, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        self.run_job(
            &LaunchJob { kernel: &f, n_tasklets, seq_tasklets: false },
            None,
        )
    }

    /// Sequential-tasklet-fast-path launch (§Perf): identical semantics to
    /// [`PimSet::launch`] for kernels without barriers or forward
    /// handshake waits (see [`crate::dpu::Dpu::launch_seq`]), but with
    /// zero per-tasklet thread overhead. Combined with the parallel fleet
    /// executor this is the lever that makes 2,048-DPU functional
    /// simulation tractable: one OS thread per *shard* instead of one per
    /// tasklet.
    pub fn launch_seq<F>(&mut self, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        self.run_job(
            &LaunchJob { kernel: &f, n_tasklets, seq_tasklets: true },
            None,
        )
    }

    /// Launch on a prefix subset of the DPUs (NW uses fewer DPUs on short
    /// diagonals). Time is still `max` over the active DPUs.
    pub fn launch_on<F>(&mut self, dpu_ids: &[usize], n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        self.run_job(
            &LaunchJob { kernel: &f, n_tasklets, seq_tasklets: false },
            Some(dpu_ids),
        )
    }

    /// Common launch path: build the slot vector (whole fleet or a
    /// subset), hand it to the fleet executor, and account the modeled
    /// seconds. Timings come back in slot order, so the metrics folds are
    /// executor-independent (see [`executor`]'s determinism contract).
    fn run_job(&mut self, job: &LaunchJob<'_>, subset: Option<&[usize]>) -> LaunchStats {
        let arch = self.cfg.dpu;
        let exec = Arc::clone(&self.exec);
        let timings = match subset {
            None => {
                let mut slots: Vec<FleetSlot<'_>> =
                    self.dpus.iter_mut().enumerate().collect();
                exec.launch(&mut slots, job)
            }
            Some(ids) => {
                let mut by_idx: Vec<Option<&mut Dpu>> =
                    self.dpus.iter_mut().map(Some).collect();
                let mut slots: Vec<FleetSlot<'_>> = Vec::with_capacity(ids.len());
                for &i in ids {
                    let dpu = by_idx[i].take().expect("duplicate DPU id in launch_on");
                    slots.push((i, dpu));
                }
                exec.launch(&mut slots, job)
            }
        };
        let max_cycles = timings.iter().map(|t| t.cycles).fold(0.0, f64::max);
        let secs = arch.cycles_to_secs(max_cycles);
        self.metrics.dpu += secs;
        self.metrics.launches += 1;
        LaunchStats { timings, secs }
    }

    // ----------------------------------------------------------- host model

    /// Charge host-side merge work (bytes streamed, scalar ops executed)
    /// to the `Inter-DPU` bucket.
    pub fn host_merge(&mut self, bytes: u64, ops: u64) {
        let spans = self.spans_sockets();
        self.metrics.inter_dpu += self.host.merge_numa(bytes, ops, spans);
    }

    /// Reset accumulated metrics (dataset stays in MRAM).
    pub fn reset_metrics(&mut self) {
        self.metrics = TimeBreakdown::default();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::SystemConfig;

    #[test]
    fn allocate_and_launch() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 4);
        let bufs: Vec<Vec<i64>> = (0..4).map(|i| vec![i as i64; 16]).collect();
        set.push_to(0, &bufs);
        let stats = set.launch(8, |_i, ctx| {
            let b = ctx.mem_alloc(128);
            ctx.mram_read(0, b, 128);
            let v: Vec<i64> = ctx.wram_get(b, 16);
            let s: i64 = v.iter().sum();
            ctx.wram_set(b, &[s]);
            ctx.charge_stream(crate::arch::DType::I64, crate::arch::Op::Add, 16);
            ctx.mram_write(b, 1024, 8);
        });
        assert_eq!(stats.timings.len(), 4);
        assert!(stats.secs > 0.0);
        assert!(set.metrics.dpu > 0.0);
        assert!(set.metrics.cpu_dpu > 0.0);
        // per-DPU sums
        for i in 0..4usize {
            let s = set.copy_from::<i64>(i, 1024, 1);
            assert_eq!(s[0], 16 * i as i64);
        }
        assert!(set.metrics.dpu_cpu > 0.0);
    }

    #[test]
    fn launch_charges_max_not_sum() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 8);
        let stats = set.launch(1, |i, ctx| {
            ctx.compute(1000 * (i as u64 + 1));
        });
        // max DPU has 8000 instrs at 1/11 → 88_000 cycles
        let expect = set.cfg.dpu.cycles_to_secs(88_000.0);
        assert!((stats.secs - expect).abs() / expect < 0.01);
    }

    #[test]
    #[should_panic(expected = "requested")]
    fn over_allocation_rejected() {
        PimSet::allocate(SystemConfig::p21_rank(), 65);
    }

    #[test]
    fn imbalance_metric() {
        let mut set = PimSet::allocate(SystemConfig::p21_rank(), 2);
        let stats = set.launch(1, |i, ctx| ctx.compute(if i == 0 { 100 } else { 300 }));
        assert!(stats.imbalance() > 1.4);
    }

    /// Serial and parallel executors produce bit-identical stats and data
    /// through the full PimSet surface (push_to / launch / launch_on /
    /// push_from).
    #[test]
    fn executors_bit_identical_through_pimset() {
        let run = |exec: Arc<dyn FleetExecutor>| {
            let mut set = PimSet::allocate_with(SystemConfig::p21_rank(), 8, exec);
            let bufs: Vec<Vec<i64>> = (0..8).map(|i| vec![i as i64 + 1; 16]).collect();
            set.push_to(0, &bufs);
            let s1 = set.launch(4, |d, ctx| {
                let b = ctx.mem_alloc(128);
                ctx.mram_read(0, b, 128);
                let v: Vec<i64> = ctx.wram_get(b, 16);
                let sum: i64 = v.iter().sum();
                ctx.wram_set(b, &[sum]);
                ctx.charge_stream(crate::arch::DType::I64, crate::arch::Op::Add, 16);
                ctx.compute(10 * d as u64);
                ctx.mram_write(b, 1024, 8);
            });
            let s2 = set.launch_on(&[1, 3, 5], 2, |d, ctx| ctx.compute(50 * d as u64 + 7));
            let out = set.push_from::<i64>(1024, 1);
            (s1, s2, out, set.metrics)
        };
        let (a1, a2, ao, am) = run(Arc::new(SerialExecutor));
        let (b1, b2, bo, bm) = run(Arc::new(ParallelExecutor::new(4)));
        assert_eq!(ao, bo, "functional outputs must not depend on the executor");
        assert_eq!(am, bm, "time breakdown must be bit-identical");
        assert_eq!(a1.secs.to_bits(), b1.secs.to_bits());
        assert_eq!(a2.secs.to_bits(), b2.secs.to_bits());
        assert_eq!(a1.timings.len(), b1.timings.len());
        assert_eq!(a2.timings.len(), 3);
        for (s, p) in a1.timings.iter().zip(&b1.timings).chain(a2.timings.iter().zip(&b2.timings))
        {
            assert_eq!(s.cycles.to_bits(), p.cycles.to_bits());
            assert_eq!(s.instrs, p.instrs);
            assert_eq!(s.dma_bytes, p.dma_bytes);
        }
    }

    #[test]
    fn broadcast_goes_through_executor() {
        let mut set = PimSet::allocate_with(
            SystemConfig::p21_rank(),
            6,
            Arc::new(ParallelExecutor::new(3)),
        );
        set.broadcast(0, &[9i64; 8]);
        for d in 0..6 {
            assert_eq!(set.copy_from::<i64>(d, 0, 8), vec![9i64; 8]);
        }
        assert!(set.metrics.cpu_dpu > 0.0);
    }
}
