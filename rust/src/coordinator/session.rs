//! Persistent PIM sessions: warm MRAM state + batched execution over
//! async command queues.
//!
//! The paper's §5.2 breakdowns show CPU-DPU/DPU-CPU transfer dominating
//! many PrIM workloads, and §6 recommends amortizing input loads across
//! kernel invocations and overlapping transfers with computation. A
//! [`Session`] is the host-side object that makes both expressible: it
//! owns one allocated [`PimSet`] (fleet + `MramLayout` + metrics) for its
//! whole lifetime, so a workload can **load** its dataset into MRAM once
//! and then **execute** many requests against the warm state — paying the
//! big input distribution a single time instead of per run.
//!
//! [`Session::execute_batch`] serves a request stream. With pipelining
//! enabled it wraps the whole batch in one async command queue
//! (`PimSet::queue_begin` … `queue_sync`): every push, launch, pull, and
//! host merge the requests issue still executes functionally in program
//! order (so results and bucket accounting are bit-identical to the
//! serialized schedule), but the recorded commands are re-scheduled onto
//! the modeled resource timelines — one serialized host bus, per-rank
//! kernel lanes, the host CPU — with ordering inferred from the
//! `Symbol` regions each command reads and writes. Whatever the
//! timeline hides (a double-buffered next-request push under the current
//! launch, a frontier merge under later bus traffic) lands in
//! [`super::TimeBreakdown::overlapped`], now *derived* as
//! `sum(bucket secs) − makespan` instead of hand-credited; `total()`
//! subtracts it. See [`super::queue`] for the model and its §6 what-if
//! caveat.

use super::queue::Access;
use super::telemetry::Labels;
use super::{LaunchStats, PimSet};
use crate::dpu::Ctx;
use std::any::Any;

/// A persistent serving session: one allocated fleet, resident MRAM
/// state, and accumulated metrics across many requests.
pub struct Session {
    /// The fleet this session keeps warm. Metrics accumulate across
    /// requests; `set.reset_metrics()` starts a new measurement window
    /// without touching MRAM.
    pub set: PimSet,
    /// Tasklets per DPU for this session's launches.
    pub n_tasklets: u32,
    /// Total DPU pipeline instructions across all launches (the
    /// `BenchResult::dpu_instrs` feed).
    pub instrs: u64,
    /// Requests completed through [`Session::execute_batch`].
    pub requests_done: u64,
    pipeline: bool,
    state: Option<Box<dyn Any + Send>>,
    loaded: Option<&'static str>,
}

impl Session {
    /// Wrap an allocated fleet. The set must come from the same
    /// `RunConfig` the workload's `prepare` saw (partitioning is derived
    /// from the DPU count).
    pub fn new(set: PimSet, n_tasklets: u32) -> Self {
        Session {
            set,
            n_tasklets,
            instrs: 0,
            requests_done: 0,
            pipeline: false,
            state: None,
            loaded: None,
        }
    }

    /// Enable/disable pipelined (async-queue) batching (builder style).
    pub fn with_pipeline(mut self, on: bool) -> Self {
        self.pipeline = on;
        self
    }

    pub fn set_pipeline(&mut self, on: bool) {
        self.pipeline = on;
    }

    pub fn pipelined(&self) -> bool {
        self.pipeline
    }

    // ------------------------------------------------------ workload state

    /// Record which workload's dataset is resident in MRAM.
    pub fn mark_loaded(&mut self, name: &'static str) {
        self.loaded = Some(name);
    }

    /// Workload currently loaded into this session, if any.
    pub fn loaded(&self) -> Option<&'static str> {
        self.loaded
    }

    /// Install the workload's session state (symbols + per-request
    /// scratch). Replaces any previous state.
    pub fn put_state<S: Any + Send>(&mut self, state: S) {
        self.state = Some(Box::new(state));
    }

    /// Borrow the workload state installed by `load`.
    pub fn state<S: Any>(&self) -> &S {
        self.state
            .as_ref()
            .and_then(|b| b.downcast_ref::<S>())
            .unwrap_or_else(|| {
                panic!(
                    "session state is not a {} (loaded: {:?})",
                    std::any::type_name::<S>(),
                    self.loaded
                )
            })
    }

    /// Mutably borrow the workload state.
    pub fn state_mut<S: Any>(&mut self) -> &mut S {
        let loaded = self.loaded;
        self.state
            .as_mut()
            .and_then(|b| b.downcast_mut::<S>())
            .unwrap_or_else(|| {
                panic!(
                    "session state is not a {} (loaded: {loaded:?})",
                    std::any::type_name::<S>()
                )
            })
    }

    /// Re-home this session's slice to a new rank geometry (the elastic
    /// migration entry point — see [`super::elastic`]). Resizes the
    /// fleet via [`PimSet::resize_ranks`] (fresh DPUs, bumped layout
    /// generation) and **drops the resident workload state**: every
    /// symbol it held predates the resize and would panic on use, so
    /// keeping it around only turns a loud stale-generation panic into a
    /// confusing downcast one. The caller must re-run the workload's
    /// `load` before serving again.
    pub fn rebind_ranks(&mut self, rank0: u32, n_ranks: u32) {
        self.set.resize_ranks(rank0, n_ranks);
        self.state = None;
        self.loaded = None;
    }

    // ------------------------------------------------------------ launches

    /// [`PimSet::launch`] with session-level instruction accounting.
    pub fn launch<F>(&mut self, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        let stats = self.set.launch(n_tasklets, f);
        self.instrs += stats.total_instrs();
        stats
    }

    /// [`PimSet::launch_seq`] with session-level instruction accounting.
    pub fn launch_seq<F>(&mut self, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        let stats = self.set.launch_seq(n_tasklets, f);
        self.instrs += stats.total_instrs();
        stats
    }

    /// [`PimSet::launch_on`] with session-level instruction accounting.
    pub fn launch_on<F>(&mut self, dpu_ids: &[usize], n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        let stats = self.set.launch_on(dpu_ids, n_tasklets, f);
        self.instrs += stats.total_instrs();
        stats
    }

    /// [`PimSet::launch_acc`] with session-level instruction accounting:
    /// a launch with a declared MRAM footprint, so the async queue can
    /// overlap independent transfers under it.
    pub fn launch_acc<F>(&mut self, acc: Access, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        let stats = self.set.launch_acc(acc, n_tasklets, f);
        self.instrs += stats.total_instrs();
        stats
    }

    /// [`PimSet::launch_seq_acc`] with session-level instruction
    /// accounting.
    pub fn launch_seq_acc<F>(&mut self, acc: Access, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        let stats = self.set.launch_seq_acc(acc, n_tasklets, f);
        self.instrs += stats.total_instrs();
        stats
    }

    // ------------------------------------------------------------- batches

    /// Run a request batch through two caller-provided stages:
    ///
    /// * `stage(req) -> S` — pure host-side staging (input generation +
    ///   partitioning into per-DPU buffers); must not touch the session;
    /// * `exec(session, req, staged)` — push the staged input and launch
    ///   kernels against the resident state.
    ///
    /// Serialized mode runs the stages back to back and accounts every
    /// second fully. With [`Session::pipelined`] on, the whole batch
    /// becomes one async command queue: identical functional execution
    /// and bucket accounting, plus a derived
    /// [`super::TimeBreakdown::overlapped`] credit for whatever the
    /// modeled resource timelines can hide (see the module docs).
    pub fn execute_batch<R, S, FS, FE>(
        &mut self,
        reqs: &[R],
        stage: FS,
        mut exec: FE,
    ) -> Vec<LaunchStats>
    where
        R: Sync,
        S: Send,
        FS: Fn(&R) -> S + Sync,
        FE: FnMut(&mut Session, &R, S) -> LaunchStats,
    {
        if self.pipeline {
            self.set.queue_begin();
        }
        let launches_before = self.set.metrics.launches;
        let mut out = Vec::with_capacity(reqs.len());
        for req in reqs {
            let staged = stage(req);
            // Tag everything this request records (commands and their
            // trace events) with its batch-global request id.
            self.set.trace_req = Some(self.requests_done);
            out.push(exec(self, req, staged));
            self.set.trace_req = None;
            self.requests_done += 1;
        }
        if self.pipeline {
            self.set.queue_sync();
        }
        if let Some(tel) = self.set.telemetry.clone() {
            // batches against resident MRAM state are warm hits — the
            // amortization §6 recommends, counted per workload
            let labels = match self.loaded {
                Some(name) => Labels::bench(name),
                None => Labels::none(),
            };
            if self.loaded.is_some() {
                tel.counter_add("session_warm_hits", labels.clone(), reqs.len() as u64);
            }
            tel.counter_add(
                "session_launches",
                labels.clone(),
                self.set.metrics.launches - launches_before,
            );
            tel.gauge_set(
                "session_resident_bytes",
                labels,
                self.set.layout.used() as f64,
            );
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::SystemConfig;
    use crate::coordinator::{ExecChoice, Symbol, TimeBreakdown};

    fn session(exec: ExecChoice) -> Session {
        Session::new(
            PimSet::allocate_with(SystemConfig::p21_rank(), 4, exec.build()),
            8,
        )
    }

    #[test]
    fn state_roundtrip() {
        let mut s = session(ExecChoice::Serial);
        s.put_state((7u64, vec![1i32, 2]));
        s.mark_loaded("X");
        assert_eq!(s.loaded(), Some("X"));
        assert_eq!(s.state::<(u64, Vec<i32>)>().0, 7);
        s.state_mut::<(u64, Vec<i32>)>().1.push(3);
        assert_eq!(s.state::<(u64, Vec<i32>)>().1, vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "session state is not a")]
    fn state_type_mismatch_panics() {
        let mut s = session(ExecChoice::Serial);
        s.put_state(1u8);
        let _ = s.state::<u64>();
    }

    #[test]
    fn launch_wrappers_accumulate_instrs() {
        let mut s = session(ExecChoice::Serial);
        s.launch_seq(2, |_d, ctx| ctx.compute(50));
        let after_one = s.instrs;
        assert!(after_one > 0);
        s.launch(2, |_d, ctx| ctx.compute(50));
        assert_eq!(s.instrs, 2 * after_one);
    }

    /// One synthetic "workload": each request pushes a double-buffered
    /// input and runs a kernel with a declared footprint over it — the
    /// shape that lets the async queue hide warm pushes under launches.
    fn run_batch(exec: ExecChoice, pipeline: bool) -> (Vec<Vec<i64>>, TimeBreakdown, u64) {
        let mut sess = session(exec).with_pipeline(pipeline);
        let syms: [Symbol<i64>; 2] =
            [sess.set.symbol::<i64>(64), sess.set.symbol::<i64>(64)];
        let out_sym: Symbol<i64> = sess.set.symbol::<i64>(64);
        sess.put_state(Vec::<Vec<i64>>::new());
        let reqs: Vec<u64> = (0..4).collect();
        sess.execute_batch(
            &reqs,
            |r| -> Vec<Vec<i64>> {
                (0..4u64).map(|d| vec![(r * 10 + d) as i64; 64]).collect()
            },
            |s: &mut Session, r: &u64, bufs: Vec<Vec<i64>>| {
                let sym = syms[(*r % 2) as usize];
                s.set.xfer(sym).to().equal(&bufs);
                let acc = crate::coordinator::Access::new()
                    .read(sym.region())
                    .write(out_sym.region());
                let stats = s.launch_seq_acc(acc, 2, move |_d, ctx| {
                    let w = ctx.mem_alloc(512);
                    ctx.mram_read(sym.off(), w, 512);
                    let v: Vec<i64> = ctx.wram_get(w, 64);
                    let doubled: Vec<i64> = v.iter().map(|x| x * 2).collect();
                    ctx.wram_set(w, &doubled);
                    ctx.compute(64 * 20);
                    ctx.mram_write(w, out_sym.off(), 512);
                });
                let got = s.set.xfer(out_sym).from().equal(64);
                s.state_mut::<Vec<Vec<i64>>>().push(got.into_iter().flatten().collect());
                stats
            },
        );
        let results = std::mem::take(sess.state_mut::<Vec<Vec<i64>>>());
        (results, sess.set.metrics, sess.requests_done)
    }

    #[test]
    fn pipelined_batch_bit_identical_to_serialized_except_overlap() {
        let (r_ser, m_ser, n_ser) = run_batch(ExecChoice::Serial, false);
        let (r_pip, m_pip, n_pip) = run_batch(ExecChoice::Serial, true);
        assert_eq!(r_ser, r_pip, "pipelining must not change results");
        assert_eq!(n_ser, n_pip);
        // every bucket identical; only the derived overlap differs
        assert_eq!(m_ser.dpu.to_bits(), m_pip.dpu.to_bits());
        assert_eq!(m_ser.cpu_dpu.to_bits(), m_pip.cpu_dpu.to_bits());
        assert_eq!(m_ser.dpu_cpu.to_bits(), m_pip.dpu_cpu.to_bits());
        assert_eq!(m_ser.inter_dpu.to_bits(), m_pip.inter_dpu.to_bits());
        assert_eq!(m_ser.bytes_to_dpu, m_pip.bytes_to_dpu);
        assert_eq!(m_ser.overlapped, 0.0);
        assert!(m_pip.overlapped > 0.0, "double-buffered pushes must hide under launches");
        assert!(m_pip.total() < m_ser.total());
        let buckets = m_pip.dpu + m_pip.inter_dpu + m_pip.cpu_dpu + m_pip.dpu_cpu;
        assert!(
            m_pip.overlapped < buckets,
            "derived credit is bounded by the bucket sum"
        );
    }

    #[test]
    fn batch_bit_identical_across_executors() {
        for pipeline in [false, true] {
            let (r_s, m_s, _) = run_batch(ExecChoice::Serial, pipeline);
            let (r_p, m_p, _) = run_batch(ExecChoice::Parallel(3), pipeline);
            assert_eq!(r_s, r_p, "pipeline={pipeline}");
            assert_eq!(m_s, m_p, "pipeline={pipeline}");
        }
    }

    /// Without double buffering, every push conflicts (WAR) with the
    /// previous launch, the timeline degenerates to the serialized
    /// chain, and the derived overlap is exactly zero.
    #[test]
    fn single_buffered_batch_derives_zero_overlap() {
        let mut sess = session(ExecChoice::Serial).with_pipeline(true);
        let sym: Symbol<i64> = sess.set.symbol::<i64>(64);
        let out_sym: Symbol<i64> = sess.set.symbol::<i64>(8);
        let reqs: Vec<u64> = (0..3).collect();
        sess.execute_batch(
            &reqs,
            |r| vec![*r as i64; 64],
            |s: &mut Session, _r: &u64, buf: Vec<i64>| {
                s.set.xfer(sym).to().broadcast(&buf);
                let acc = crate::coordinator::Access::new()
                    .read(sym.region())
                    .write(out_sym.region());
                s.launch_seq_acc(acc, 2, move |_d, ctx| {
                    let w = ctx.mem_alloc(512);
                    ctx.mram_read(sym.off(), w, 512);
                    ctx.compute(1000);
                    ctx.mram_write(w, out_sym.off(), 8);
                })
            },
        );
        assert_eq!(sess.set.metrics.overlapped, 0.0, "fully dependent chain");
    }
}
