//! Persistent PIM sessions: warm MRAM state + batched, pipelined
//! execution.
//!
//! The paper's §5.2 breakdowns show CPU-DPU/DPU-CPU transfer dominating
//! many PrIM workloads, and §6 recommends amortizing input loads across
//! kernel invocations and overlapping transfers with computation. A
//! [`Session`] is the host-side object that makes both expressible: it
//! owns one allocated [`PimSet`] (fleet + `MramLayout` + metrics) for its
//! whole lifetime, so a workload can **load** its dataset into MRAM once
//! and then **execute** many requests against the warm state — paying the
//! big input distribution a single time instead of per run.
//!
//! [`Session::execute_batch`] additionally pipelines a request stream:
//! with pipelining enabled, the host-side staging of request *i + 1*
//! (input generation + partitioning into per-DPU buffers) runs
//! concurrently with the execution of request *i* (the fleet executor's
//! two-stage [`FleetExecutor::overlap`] schedule), and the modeled
//! CPU-DPU push time of request *i + 1* is overlapped under the modeled
//! launch window of request *i* in whole-**rank** chunks — transfers to
//! different ranks are serialized (§5.1.1), so a rank's push either fits
//! under the remaining launch window or waits. The hidden seconds
//! accumulate in [`super::TimeBreakdown::overlapped`]; the component
//! buckets keep their full values and `TimeBreakdown::total()` subtracts
//! the credit. The serial executor runs the same schedule without wallclock
//! overlap (fleet stage, then host stage) and is the bit-identical
//! reference: staging is pure host work, so the two orders cannot
//! diverge, and the overlap credit is computed from modeled seconds that
//! are themselves executor-independent.

use super::executor::FleetExecutor;
use super::{LaunchStats, PimSet};
use crate::dpu::Ctx;
use std::any::Any;
use std::sync::Arc;

/// A persistent serving session: one allocated fleet, resident MRAM
/// state, and accumulated metrics across many requests.
pub struct Session {
    /// The fleet this session keeps warm. Metrics accumulate across
    /// requests; `set.reset_metrics()` starts a new measurement window
    /// without touching MRAM.
    pub set: PimSet,
    /// Tasklets per DPU for this session's launches.
    pub n_tasklets: u32,
    /// Total DPU pipeline instructions across all launches (the
    /// `BenchResult::dpu_instrs` feed).
    pub instrs: u64,
    /// Requests completed through [`Session::execute_batch`].
    pub requests_done: u64,
    pipeline: bool,
    state: Option<Box<dyn Any + Send>>,
    loaded: Option<&'static str>,
}

impl Session {
    /// Wrap an allocated fleet. The set must come from the same
    /// `RunConfig` the workload's `prepare` saw (partitioning is derived
    /// from the DPU count).
    pub fn new(set: PimSet, n_tasklets: u32) -> Self {
        Session {
            set,
            n_tasklets,
            instrs: 0,
            requests_done: 0,
            pipeline: false,
            state: None,
            loaded: None,
        }
    }

    /// Enable/disable pipelined batching (builder style).
    pub fn with_pipeline(mut self, on: bool) -> Self {
        self.pipeline = on;
        self
    }

    pub fn set_pipeline(&mut self, on: bool) {
        self.pipeline = on;
    }

    pub fn pipelined(&self) -> bool {
        self.pipeline
    }

    // ------------------------------------------------------ workload state

    /// Record which workload's dataset is resident in MRAM.
    pub fn mark_loaded(&mut self, name: &'static str) {
        self.loaded = Some(name);
    }

    /// Workload currently loaded into this session, if any.
    pub fn loaded(&self) -> Option<&'static str> {
        self.loaded
    }

    /// Install the workload's session state (symbols + per-request
    /// scratch). Replaces any previous state.
    pub fn put_state<S: Any + Send>(&mut self, state: S) {
        self.state = Some(Box::new(state));
    }

    /// Borrow the workload state installed by `load`.
    pub fn state<S: Any>(&self) -> &S {
        self.state
            .as_ref()
            .and_then(|b| b.downcast_ref::<S>())
            .unwrap_or_else(|| {
                panic!(
                    "session state is not a {} (loaded: {:?})",
                    std::any::type_name::<S>(),
                    self.loaded
                )
            })
    }

    /// Mutably borrow the workload state.
    pub fn state_mut<S: Any>(&mut self) -> &mut S {
        let loaded = self.loaded;
        self.state
            .as_mut()
            .and_then(|b| b.downcast_mut::<S>())
            .unwrap_or_else(|| {
                panic!(
                    "session state is not a {} (loaded: {loaded:?})",
                    std::any::type_name::<S>()
                )
            })
    }

    // ------------------------------------------------------------ launches

    /// [`PimSet::launch`] with session-level instruction accounting.
    pub fn launch<F>(&mut self, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        let stats = self.set.launch(n_tasklets, f);
        self.instrs += stats.total_instrs();
        stats
    }

    /// [`PimSet::launch_seq`] with session-level instruction accounting.
    pub fn launch_seq<F>(&mut self, n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        let stats = self.set.launch_seq(n_tasklets, f);
        self.instrs += stats.total_instrs();
        stats
    }

    /// [`PimSet::launch_on`] with session-level instruction accounting.
    pub fn launch_on<F>(&mut self, dpu_ids: &[usize], n_tasklets: u32, f: F) -> LaunchStats
    where
        F: Fn(usize, &mut Ctx) + Sync,
    {
        let stats = self.set.launch_on(dpu_ids, n_tasklets, f);
        self.instrs += stats.total_instrs();
        stats
    }

    // ------------------------------------------------------------- batches

    /// Run a request batch through two caller-provided stages:
    ///
    /// * `stage(req) -> S` — pure host-side staging (input generation +
    ///   partitioning into per-DPU buffers); must not touch the session;
    /// * `exec(session, req, staged)` — push the staged input and launch
    ///   kernels against the resident state.
    ///
    /// Serialized mode runs `stage`/`exec` strictly alternating. With
    /// [`Session::pipelined`] on, the staging of request *i + 1* runs
    /// under the execution of request *i* (the executor's two-stage
    /// overlap schedule), and the modeled CPU-DPU push seconds of each
    /// warm request are hidden under the previous request's launch
    /// window in whole-rank chunks ([`super::TimeBreakdown::overlapped`]).
    pub fn execute_batch<R, S, FS, FE>(
        &mut self,
        reqs: &[R],
        stage: FS,
        mut exec: FE,
    ) -> Vec<LaunchStats>
    where
        R: Sync,
        S: Send,
        FS: Fn(&R) -> S + Sync,
        FE: FnMut(&mut Session, &R, S) -> LaunchStats,
    {
        let fleet: Arc<dyn FleetExecutor> = Arc::clone(&self.set.exec);
        let pipeline = self.pipeline;
        let rank = self.set.cfg.dpus_per_rank().max(1) as usize;
        let n_ranks = (self.set.n_dpus() as usize).div_ceil(rank);
        let mut out = Vec::with_capacity(reqs.len());
        let mut staged: Option<S> = reqs.first().map(|r| stage(r));
        // modeled launch seconds of the previous request — the window the
        // next request's push may hide under
        let mut headroom = 0.0f64;
        for (i, req) in reqs.iter().enumerate() {
            let cur = staged.take().expect("request input staged");
            let before = self.set.metrics;
            let stats = if pipeline {
                if let Some(next_req) = reqs.get(i + 1) {
                    let mut stats_slot: Option<LaunchStats> = None;
                    let mut next_slot: Option<S> = None;
                    {
                        let this = &mut *self;
                        let exec_ref = &mut exec;
                        let stats_ref = &mut stats_slot;
                        let stage_ref = &stage;
                        let next_ref = &mut next_slot;
                        fleet.overlap(
                            Box::new(move || {
                                *stats_ref = Some(exec_ref(this, req, cur));
                            }),
                            Box::new(move || {
                                *next_ref = Some(stage_ref(next_req));
                            }),
                        );
                    }
                    staged = next_slot;
                    stats_slot.expect("fleet stage must run")
                } else {
                    exec(self, req, cur)
                }
            } else {
                let stats = exec(self, req, cur);
                staged = reqs.get(i + 1).map(|r| stage(r));
                stats
            };
            if pipeline && i > 0 {
                let push = self.set.metrics.cpu_dpu - before.cpu_dpu;
                self.set.metrics.overlapped += rank_granular_overlap(push, headroom, n_ranks);
            }
            headroom = self.set.metrics.dpu - before.dpu;
            self.requests_done += 1;
            out.push(stats);
        }
        out
    }
}

/// Seconds of a CPU-DPU push that fit under a `window_secs` launch
/// window, in whole-rank chunks. Pushes to different ranks are serialized
/// (§5.1.1), so the schedulable unit is one rank's push — a chunk either
/// fits entirely in the remaining window or is not overlapped.
///
/// This is a deliberate **what-if of the paper's §6 recommendation**: the
/// shipping UPMEM runtime cannot touch a rank's MRAM while its DPUs run,
/// so on today's hardware the credit is unrealizable — the model answers
/// "what would double-buffered request symbols plus launch-concurrent
/// transfers buy", the improvement §6 argues for. Functionally nothing
/// races: pushes are applied in strict serial order between launches, and
/// only the modeled seconds are credited.
fn rank_granular_overlap(push_secs: f64, window_secs: f64, n_ranks: usize) -> f64 {
    if push_secs <= 0.0 || window_secs <= 0.0 || n_ranks == 0 {
        return 0.0;
    }
    let chunk = push_secs / n_ranks as f64;
    if chunk <= 0.0 {
        return 0.0;
    }
    let fitting = (window_secs / chunk).floor().min(n_ranks as f64);
    (chunk * fitting).min(push_secs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::SystemConfig;
    use crate::coordinator::{ExecChoice, Symbol, TimeBreakdown};

    fn session(exec: ExecChoice) -> Session {
        Session::new(
            PimSet::allocate_with(SystemConfig::p21_rank(), 4, exec.build()),
            8,
        )
    }

    #[test]
    fn state_roundtrip() {
        let mut s = session(ExecChoice::Serial);
        s.put_state((7u64, vec![1i32, 2]));
        s.mark_loaded("X");
        assert_eq!(s.loaded(), Some("X"));
        assert_eq!(s.state::<(u64, Vec<i32>)>().0, 7);
        s.state_mut::<(u64, Vec<i32>)>().1.push(3);
        assert_eq!(s.state::<(u64, Vec<i32>)>().1, vec![1, 2, 3]);
    }

    #[test]
    #[should_panic(expected = "session state is not a")]
    fn state_type_mismatch_panics() {
        let mut s = session(ExecChoice::Serial);
        s.put_state(1u8);
        let _ = s.state::<u64>();
    }

    #[test]
    fn launch_wrappers_accumulate_instrs() {
        let mut s = session(ExecChoice::Serial);
        s.launch_seq(2, |_d, ctx| ctx.compute(50));
        let after_one = s.instrs;
        assert!(after_one > 0);
        s.launch(2, |_d, ctx| ctx.compute(50));
        assert_eq!(s.instrs, 2 * after_one);
    }

    /// One synthetic "workload": each request pushes a buffer and runs a
    /// kernel over it. Used to pin the batch schedules against each other.
    fn run_batch(exec: ExecChoice, pipeline: bool) -> (Vec<Vec<i64>>, TimeBreakdown, u64) {
        let mut sess = session(exec).with_pipeline(pipeline);
        let sym: Symbol<i64> = sess.set.symbol::<i64>(64);
        let out_sym: Symbol<i64> = sess.set.symbol::<i64>(64);
        sess.put_state(Vec::<Vec<i64>>::new());
        let reqs: Vec<u64> = (0..4).collect();
        sess.execute_batch(
            &reqs,
            |r| -> Vec<Vec<i64>> {
                (0..4u64).map(|d| vec![(r * 10 + d) as i64; 64]).collect()
            },
            |s: &mut Session, _r: &u64, bufs: Vec<Vec<i64>>| {
                s.set.xfer(sym).to().equal(&bufs);
                let stats = s.launch_seq(2, |_d, ctx| {
                    let w = ctx.mem_alloc(512);
                    ctx.mram_read(sym.off(), w, 512);
                    let v: Vec<i64> = ctx.wram_get(w, 64);
                    let doubled: Vec<i64> = v.iter().map(|x| x * 2).collect();
                    ctx.wram_set(w, &doubled);
                    ctx.compute(64 * 20);
                    ctx.mram_write(w, out_sym.off(), 512);
                });
                let got = s.set.xfer(out_sym).from().equal(64);
                s.state_mut::<Vec<Vec<i64>>>().push(got.into_iter().flatten().collect());
                stats
            },
        );
        let results = std::mem::take(sess.state_mut::<Vec<Vec<i64>>>());
        (results, sess.set.metrics, sess.requests_done)
    }

    #[test]
    fn pipelined_batch_bit_identical_to_serialized_except_overlap() {
        let (r_ser, m_ser, n_ser) = run_batch(ExecChoice::Serial, false);
        let (r_pip, m_pip, n_pip) = run_batch(ExecChoice::Serial, true);
        assert_eq!(r_ser, r_pip, "pipelining must not change results");
        assert_eq!(n_ser, n_pip);
        // every bucket identical; only the overlap credit differs
        assert_eq!(m_ser.dpu.to_bits(), m_pip.dpu.to_bits());
        assert_eq!(m_ser.cpu_dpu.to_bits(), m_pip.cpu_dpu.to_bits());
        assert_eq!(m_ser.dpu_cpu.to_bits(), m_pip.dpu_cpu.to_bits());
        assert_eq!(m_ser.inter_dpu.to_bits(), m_pip.inter_dpu.to_bits());
        assert_eq!(m_ser.bytes_to_dpu, m_pip.bytes_to_dpu);
        assert_eq!(m_ser.overlapped, 0.0);
        assert!(m_pip.overlapped > 0.0, "warm pushes must hide under launches");
        assert!(m_pip.total() < m_ser.total());
        assert!(m_pip.overlapped <= m_pip.cpu_dpu, "cannot hide more than the pushes");
    }

    #[test]
    fn batch_bit_identical_across_executors() {
        for pipeline in [false, true] {
            let (r_s, m_s, _) = run_batch(ExecChoice::Serial, pipeline);
            let (r_p, m_p, _) = run_batch(ExecChoice::Parallel(3), pipeline);
            assert_eq!(r_s, r_p, "pipeline={pipeline}");
            assert_eq!(m_s, m_p, "pipeline={pipeline}");
        }
    }

    #[test]
    fn rank_granularity_of_overlap() {
        // one rank: all-or-nothing
        assert_eq!(rank_granular_overlap(1.0, 0.5, 1), 0.0);
        assert_eq!(rank_granular_overlap(1.0, 1.5, 1), 1.0);
        // four ranks: whole chunks of 0.25
        assert_eq!(rank_granular_overlap(1.0, 0.6, 4), 0.5);
        assert_eq!(rank_granular_overlap(1.0, 10.0, 4), 1.0);
        assert_eq!(rank_granular_overlap(0.0, 1.0, 4), 0.0);
        assert_eq!(rank_granular_overlap(1.0, 0.0, 4), 0.0);
    }
}
