//! Elastic autoscaling: live tenant slice resizing with modeled state
//! migration.
//!
//! [`super::PimSet::split_ranks`] fixes tenant slice geometry at launch;
//! a tenant whose queue explodes after a load shift just misses its
//! latency targets. This module makes the geometry dynamic, with the
//! honest-accounting discipline the rest of the simulator enforces:
//! ranks never teleport between tenants — every reallocation pays a
//! **modeled migration bill**, because re-provisioned ranks hold none of
//! the tenant's resident data and the re-push travels the same
//! serialized host bus (§5.1.1) every other transfer does.
//!
//! The split of responsibilities:
//!
//! * An [`ElasticPolicy`] decides *whether* and *what* to move. Policies
//!   read the [`Telemetry`](super::telemetry::Telemetry) series the
//!   scheduler already samples (`sched_queue_depth`,
//!   `sched_done_latency`) through an [`ElasticView`] — they do not
//!   invent private counters. Thrash is damped twice: a policy fires
//!   only after its trigger condition holds for `hysteresis` consecutive
//!   decision points, and the scheduler enforces a modeled-seconds
//!   [`ElasticConfig::cooldown`] between migrations.
//! * A [`Migrator`] executes a decided move: it resizes the tenant's
//!   slice ([`super::PimSet::resize_ranks`] bumps the
//!   [`MramLayout`](super::layout::MramLayout) generation so every
//!   pre-migration [`Symbol`](super::layout::Symbol) panics on use),
//!   re-plans the dataset for the new DPU count, and re-loads it through
//!   the ordinary workload `load` path — so the migration cost is priced
//!   by the very same `XferModel` arithmetic as a hand-issued re-push,
//!   bitwise (pinned in `tests/properties.rs`). With a
//!   [`NetModel`] configured, the move additionally pays a cross-machine
//!   link leg, as a real [`CmdKind::Net`](super::queue::CmdKind)
//!   reservation on the shared timeline.
//! * The scheduler (`coordinator::scheduler`) owns the lifecycle:
//!   **freeze** (affected tenants stop dispatching) → **drain** (their
//!   in-flight batches finish) → **migrate** (bus + optional link
//!   reservations on the shared `Timeline`, typed
//!   `MigrateDrain`/`MigrateCopy`/`MigrateResume` trace events) →
//!   **resume** (the new rank lanes re-enter service).
//!
//! # Determinism
//!
//! Policy evaluation is read-only: it draws no RNG, reserves nothing,
//! and perturbs no floats. A run in which the policy never fires is
//! bit-identical to the static scheduler, and runs that do migrate are
//! bit-identical across executors and repeats of the same seed
//! (`tests/executor_equivalence.rs`).

use super::cluster::NetModel;
use super::session::Session;
use super::telemetry::{Labels, Telemetry};
use super::TimeBreakdown;
use crate::prim::common::RunConfig;
use crate::prim::workload::{Dataset, Workload};

/// One decided reallocation: move `ranks` whole ranks from tenant
/// `from`'s slice to tenant `to`'s. A *grow* and a *shrink* are the two
/// halves of the same move; a *steal* is a move whose donor is picked by
/// the policy rather than volunteered.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct MoveRanks {
    /// Donor tenant index (must keep ≥ 1 rank after the move).
    pub from: usize,
    /// Receiver tenant index.
    pub to: usize,
    /// Whole ranks to move (≥ 1).
    pub ranks: u32,
}

/// A scripted move for [`ElasticPolicyKind::Planned`]: fires at the
/// first decision point at or after `at` modeled seconds. Used by tests
/// and experiments that need a deterministic grow/shrink schedule
/// independent of signal thresholds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PlannedMove {
    /// Earliest modeled time this move may fire.
    pub at: f64,
    /// The move itself.
    pub mv: MoveRanks,
}

/// Read-only window over the scheduler's state that a policy may
/// consult: current rank geometry plus the PR 9 telemetry series. All
/// values derive from modeled seconds, so policy decisions are
/// executor-independent.
pub struct ElasticView<'a> {
    /// Current decision point, modeled seconds.
    pub now: f64,
    /// Ranks currently owned per tenant (index = tenant).
    pub ranks: &'a [u32],
    tel: &'a Telemetry,
    window: usize,
}

impl<'a> ElasticView<'a> {
    /// Assemble a view; `window` is the number of trailing series points
    /// a signal averages over (the policy's smoothing window).
    pub fn new(now: f64, ranks: &'a [u32], tel: &'a Telemetry, window: usize) -> Self {
        ElasticView { now, ranks, tel, window }
    }

    fn tail_mean(&self, series: &str, tenant: usize) -> Option<f64> {
        let lbl = Labels::tenant(&format!("t{tenant}"));
        let tail = self.tel.series_tail(series, &lbl, self.window);
        if tail.len() < self.window {
            return None; // not enough signal yet — never fire on a cold series
        }
        Some(tail.iter().map(|&(_, v)| v).sum::<f64>() / tail.len() as f64)
    }

    /// Mean of the trailing `window` samples of the tenant's
    /// `sched_queue_depth` series (requests arrived but not dispatched).
    /// `None` until the series holds a full window.
    pub fn queue_depth(&self, tenant: usize) -> Option<f64> {
        self.tail_mean("sched_queue_depth", tenant)
    }

    /// Mean of the trailing `window` points of the tenant's
    /// `sched_done_latency` series (per-completion end-to-end latency —
    /// the EWMA-smoothed tail the p99 target watches). `None` until the
    /// series holds a full window.
    pub fn done_latency(&self, tenant: usize) -> Option<f64> {
        self.tail_mean("sched_done_latency", tenant)
    }
}

/// A slice-resizing policy: called at scheduler decision points (between
/// batches), returns at most one move. Implementations must be
/// deterministic functions of the view (plus their own counters) — no
/// RNG, no wall clock.
pub trait ElasticPolicy: Send {
    /// Short stable name (reports, JSON).
    fn name(&self) -> &'static str;
    /// Decide a move, or `None` to leave the geometry alone.
    fn decide(&mut self, view: &ElasticView) -> Option<MoveRanks>;
}

/// Pick the receiver/donor pair by a per-tenant signal: receiver is the
/// tenant with the highest signal, donor the multi-rank tenant with the
/// lowest. Fires when `receiver ≥ high` and `receiver ≥ ratio · donor`
/// hold for `hysteresis` consecutive decision points.
struct ImbalanceTrigger {
    high: f64,
    ratio: f64,
    hysteresis: u32,
    streak: u32,
}

impl ImbalanceTrigger {
    fn decide(
        &mut self,
        view: &ElasticView,
        signal: &dyn Fn(usize) -> Option<f64>,
    ) -> Option<MoveRanks> {
        let n = view.ranks.len();
        let mut rx: Option<(usize, f64)> = None;
        let mut dn: Option<(usize, f64)> = None;
        for t in 0..n {
            let Some(s) = signal(t) else {
                self.streak = 0;
                return None; // a cold tenant means the picture is partial
            };
            match rx {
                Some((_, best)) if s <= best => {}
                _ => rx = Some((t, s)),
            }
            if view.ranks[t] > 1 {
                match dn {
                    Some((_, best)) if s >= best => {}
                    _ => dn = Some((t, s)),
                }
            }
        }
        let (Some((to, hot)), Some((from, cold))) = (rx, dn) else {
            self.streak = 0;
            return None;
        };
        if from == to || hot < self.high || hot < self.ratio * cold {
            self.streak = 0;
            return None;
        }
        self.streak += 1;
        if self.streak < self.hysteresis {
            return None;
        }
        self.streak = 0;
        Some(MoveRanks { from, to, ranks: 1 })
    }
}

/// Queue-depth policy: rebalance toward the tenant whose arrival queue
/// is deepest (target queue depth signal).
pub struct DepthPolicy {
    trigger: ImbalanceTrigger,
}

impl DepthPolicy {
    pub fn new(high: f64, ratio: f64, hysteresis: u32) -> Self {
        DepthPolicy { trigger: ImbalanceTrigger { high, ratio, hysteresis, streak: 0 } }
    }
}

impl ElasticPolicy for DepthPolicy {
    fn name(&self) -> &'static str {
        "depth"
    }
    fn decide(&mut self, view: &ElasticView) -> Option<MoveRanks> {
        self.trigger.decide(view, &|t| view.queue_depth(t))
    }
}

/// Completion-latency policy: rebalance toward the tenant whose smoothed
/// end-to-end latency is highest (EWMA p99 signal).
pub struct LatencyPolicy {
    trigger: ImbalanceTrigger,
}

impl LatencyPolicy {
    pub fn new(high: f64, ratio: f64, hysteresis: u32) -> Self {
        LatencyPolicy { trigger: ImbalanceTrigger { high, ratio, hysteresis, streak: 0 } }
    }
}

impl ElasticPolicy for LatencyPolicy {
    fn name(&self) -> &'static str {
        "latency"
    }
    fn decide(&mut self, view: &ElasticView) -> Option<MoveRanks> {
        self.trigger.decide(view, &|t| view.done_latency(t))
    }
}

/// Scripted policy: replays a fixed move schedule (ignores all signals).
/// The deterministic workhorse of the bit-identity tests.
pub struct PlannedPolicy {
    moves: Vec<PlannedMove>,
    next: usize,
}

impl ElasticPolicy for PlannedPolicy {
    fn name(&self) -> &'static str {
        "planned"
    }
    fn decide(&mut self, view: &ElasticView) -> Option<MoveRanks> {
        let pm = self.moves.get(self.next)?;
        if view.now >= pm.at {
            self.next += 1;
            return Some(pm.mv);
        }
        None
    }
}

/// Which [`ElasticPolicy`] to build — the CLI-facing enum (mirrors
/// `scheduler::PolicyKind`).
#[derive(Clone, Debug, PartialEq)]
pub enum ElasticPolicyKind {
    /// [`DepthPolicy`].
    Depth,
    /// [`LatencyPolicy`].
    Latency,
    /// [`PlannedPolicy`] with the given schedule (not CLI-parseable).
    Planned(Vec<PlannedMove>),
}

impl ElasticPolicyKind {
    /// CLI-parseable kinds.
    pub const ALL: [&'static str; 2] = ["depth", "latency"];

    pub fn parse(s: &str) -> Option<ElasticPolicyKind> {
        match s.to_ascii_lowercase().as_str() {
            "depth" => Some(ElasticPolicyKind::Depth),
            "latency" => Some(ElasticPolicyKind::Latency),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ElasticPolicyKind::Depth => "depth",
            ElasticPolicyKind::Latency => "latency",
            ElasticPolicyKind::Planned(_) => "planned",
        }
    }
}

/// Full elastic configuration carried by `SchedConfig::elastic`.
#[derive(Clone, Debug)]
pub struct ElasticConfig {
    /// Policy to build.
    pub kind: ElasticPolicyKind,
    /// Trigger threshold in signal units (requests for `depth`, seconds
    /// for `latency`).
    pub high: f64,
    /// Receiver/donor imbalance ratio that must also hold.
    pub ratio: f64,
    /// Consecutive decision points the trigger must hold before firing.
    pub hysteresis: u32,
    /// Trailing series samples a signal averages over.
    pub window: usize,
    /// Minimum modeled seconds between migrations (measured from the
    /// end of the previous migration's copy phase).
    pub cooldown: f64,
    /// When set, each migration additionally pays a cross-machine link
    /// leg priced by this model on the shared timeline's `Link(0)` lane
    /// (the cluster case: the donor ranks live on another machine).
    pub net: Option<NetModel>,
}

impl ElasticConfig {
    /// Kind-appropriate defaults: depth triggers at a mean backlog of 2
    /// requests, latency at 1 ms smoothed completion latency; both
    /// require a 2× receiver/donor imbalance sustained for 2 decision
    /// points, average over 2 samples, and cool down 1 ms between moves.
    pub fn new(kind: ElasticPolicyKind) -> Self {
        let high = match kind {
            ElasticPolicyKind::Latency => 1e-3,
            _ => 2.0,
        };
        ElasticConfig {
            kind,
            high,
            ratio: 2.0,
            hysteresis: 2,
            window: 2,
            cooldown: 1e-3,
            net: None,
        }
    }

    /// Build the policy instance.
    pub fn build(&self) -> Box<dyn ElasticPolicy> {
        match &self.kind {
            ElasticPolicyKind::Depth => {
                Box::new(DepthPolicy::new(self.high, self.ratio, self.hysteresis))
            }
            ElasticPolicyKind::Latency => {
                Box::new(LatencyPolicy::new(self.high, self.ratio, self.hysteresis))
            }
            ElasticPolicyKind::Planned(moves) => {
                Box::new(PlannedPolicy { moves: clone_sorted(moves), next: 0 })
            }
        }
    }
}

impl Default for ElasticConfig {
    fn default() -> Self {
        ElasticConfig::new(ElasticPolicyKind::Depth)
    }
}

fn clone_sorted(moves: &[PlannedMove]) -> Vec<PlannedMove> {
    let mut v = moves.to_vec();
    v.sort_by(|a, b| a.at.total_cmp(&b.at));
    v
}

/// Modeled price of one tenant's migration, measured — not estimated —
/// around the re-load.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct MigrationCost {
    /// Exact accounting delta of the re-load (the bus copy lives in
    /// `cpu_dpu`; `total()` is the bus occupancy the scheduler
    /// reserves).
    pub bd: TimeBreakdown,
    /// Bytes re-pushed host→MRAM.
    pub bytes: u64,
    /// Cross-machine link seconds (0 without a [`NetModel`]).
    pub net_secs: f64,
}

impl MigrationCost {
    /// Bus seconds of the copy phase.
    pub fn bus_secs(&self) -> f64 {
        self.bd.total()
    }
    /// Total modeled seconds of the copy (link leg + bus leg; they
    /// serialize — the state crosses the wire before it can be pushed).
    pub fn secs(&self) -> f64 {
        self.net_secs + self.bus_secs()
    }
}

/// Executes decided moves: resizes a tenant's slice and re-loads its
/// dataset, measuring the true modeled cost. The scheduler owns the
/// surrounding freeze/drain/resume choreography and the timeline
/// reservations; the `Migrator` owns the state mechanics, so tests can
/// drive a migration directly against a bare `Session`.
#[derive(Clone, Debug, Default)]
pub struct Migrator {
    /// Optional cross-machine leg (see [`ElasticConfig::net`]).
    pub net: Option<NetModel>,
}

impl Migrator {
    /// Re-home `session`'s slice to `n_ranks` ranks at `rank0` and
    /// re-push its resident state: re-provisions the DPUs (bumping the
    /// layout generation so pre-migration symbols panic), re-plans the
    /// dataset under `rc` (whose `n_dpus` must already reflect the new
    /// geometry), and runs the workload's ordinary `load`. Returns the
    /// new dataset and the measured cost.
    ///
    /// The cost is measured from a **zero** metrics baseline (the
    /// accumulated serving breakdown is set aside and re-added after),
    /// not as an accumulate-then-subtract delta: floating-point addition
    /// does not cancel exactly, and the bitwise pin in
    /// `tests/properties.rs` — migration cost ≡ a hand-issued re-push on
    /// a fresh identically-homed fleet — is the module's honesty
    /// guarantee.
    pub fn migrate(
        &self,
        session: &mut Session,
        workload: &dyn Workload,
        rc: &RunConfig,
        rank0: u32,
        n_ranks: u32,
    ) -> (Dataset, MigrationCost) {
        assert_eq!(
            rc.n_dpus,
            n_ranks * rc.sys.dpus_per_rank(),
            "RunConfig::n_dpus must match the post-migration geometry"
        );
        let saved = session.set.metrics;
        session.set.reset_metrics();
        session.rebind_ranks(rank0, n_ranks);
        let dataset = workload.prepare(rc);
        workload.load(session, &dataset);
        let bd = session.set.metrics;
        let mut restored = saved;
        restored.add(&bd);
        session.set.metrics = restored;
        let bytes = bd.bytes_to_dpu;
        let net_secs = self.net.as_ref().map_or(0.0, |n| n.xfer_secs(bytes));
        (dataset, MigrationCost { bd, bytes, net_secs })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view_tel(depths: &[(usize, &[f64])]) -> Telemetry {
        let tel = Telemetry::default();
        for &(t, vals) in depths {
            for (i, &v) in vals.iter().enumerate() {
                tel.sample(
                    "sched_queue_depth",
                    Labels::tenant(&format!("t{t}")),
                    i as f64,
                    v,
                );
            }
        }
        tel
    }

    #[test]
    fn depth_policy_needs_hysteresis_and_imbalance() {
        let tel = view_tel(&[(0, &[6.0, 6.0]), (1, &[0.0, 0.0])]);
        let ranks = [1u32, 2];
        let mut p = DepthPolicy::new(2.0, 2.0, 2);
        let v = ElasticView::new(0.0, &ranks, &tel, 2);
        // First breach arms the trigger, second fires it.
        assert_eq!(p.decide(&v), None);
        assert_eq!(
            p.decide(&v),
            Some(MoveRanks { from: 1, to: 0, ranks: 1 })
        );
        // After firing the streak resets.
        assert_eq!(p.decide(&v), None);
    }

    #[test]
    fn depth_policy_never_fires_below_threshold_or_on_cold_series() {
        let ranks = [1u32, 2];
        // Balanced load: imbalance ratio not met.
        let tel = view_tel(&[(0, &[3.0, 3.0]), (1, &[2.0, 2.0])]);
        let mut p = DepthPolicy::new(2.0, 2.0, 1);
        assert_eq!(p.decide(&ElasticView::new(0.0, &ranks, &tel, 2)), None);
        // Hot but short series: window not yet full.
        let tel = view_tel(&[(0, &[9.0]), (1, &[0.0])]);
        assert_eq!(p.decide(&ElasticView::new(0.0, &ranks, &tel, 2)), None);
    }

    #[test]
    fn depth_policy_never_drains_a_single_rank_donor() {
        // The only cold tenant has 1 rank — no eligible donor.
        let tel = view_tel(&[(0, &[6.0, 6.0]), (1, &[0.0, 0.0])]);
        let ranks = [2u32, 1];
        let mut p = DepthPolicy::new(2.0, 2.0, 1);
        // Donor search skips t1 (1 rank); t0 is both receiver and the
        // only multi-rank tenant, so no move.
        assert_eq!(p.decide(&ElasticView::new(0.0, &ranks, &tel, 2)), None);
    }

    #[test]
    fn interrupted_streak_restarts() {
        let hot = view_tel(&[(0, &[6.0, 6.0]), (1, &[0.0, 0.0])]);
        let cold = view_tel(&[(0, &[0.0, 0.0]), (1, &[0.0, 0.0])]);
        let ranks = [1u32, 2];
        let mut p = DepthPolicy::new(2.0, 2.0, 2);
        assert_eq!(p.decide(&ElasticView::new(0.0, &ranks, &hot, 2)), None);
        // Condition lapses — the armed streak must reset…
        assert_eq!(p.decide(&ElasticView::new(0.0, &ranks, &cold, 2)), None);
        // …so one more breach is not enough.
        assert_eq!(p.decide(&ElasticView::new(0.0, &ranks, &hot, 2)), None);
        assert!(p.decide(&ElasticView::new(0.0, &ranks, &hot, 2)).is_some());
    }

    #[test]
    fn latency_policy_reads_done_latency_series() {
        let tel = Telemetry::default();
        for (t, lat) in [(0usize, 5e-3), (1usize, 1e-4)] {
            for i in 0..2 {
                tel.sample(
                    "sched_done_latency",
                    Labels::tenant(&format!("t{t}")),
                    i as f64,
                    lat,
                );
            }
        }
        let ranks = [1u32, 2];
        let mut p = LatencyPolicy::new(1e-3, 2.0, 1);
        assert_eq!(
            p.decide(&ElasticView::new(0.0, &ranks, &tel, 2)),
            Some(MoveRanks { from: 1, to: 0, ranks: 1 })
        );
    }

    #[test]
    fn planned_policy_fires_in_time_order() {
        let mv1 = MoveRanks { from: 1, to: 0, ranks: 1 };
        let mv2 = MoveRanks { from: 0, to: 1, ranks: 1 };
        let cfg = ElasticConfig::new(ElasticPolicyKind::Planned(vec![
            PlannedMove { at: 2.0, mv: mv2 },
            PlannedMove { at: 1.0, mv: mv1 },
        ]));
        let mut p = cfg.build();
        let tel = Telemetry::default();
        let ranks = [2u32, 2];
        let v = |now| ElasticView::new(now, &ranks, &tel, 2);
        assert_eq!(p.decide(&v(0.5)), None);
        assert_eq!(p.decide(&v(1.0)), Some(mv1), "schedule is sorted by time");
        assert_eq!(p.decide(&v(1.5)), None);
        assert_eq!(p.decide(&v(3.0)), Some(mv2));
        assert_eq!(p.decide(&v(9.0)), None, "schedule exhausted");
    }

    #[test]
    fn kind_parses_cli_names() {
        assert_eq!(ElasticPolicyKind::parse("depth"), Some(ElasticPolicyKind::Depth));
        assert_eq!(ElasticPolicyKind::parse("LATENCY"), Some(ElasticPolicyKind::Latency));
        assert_eq!(ElasticPolicyKind::parse("planned"), None, "not CLI-constructible");
        assert_eq!(ElasticPolicyKind::parse("nope"), None);
        for name in ElasticPolicyKind::ALL {
            assert_eq!(ElasticPolicyKind::parse(name).unwrap().name(), name);
        }
    }

    #[test]
    fn migration_cost_secs_serializes_link_and_bus() {
        let c = MigrationCost {
            bd: TimeBreakdown { cpu_dpu: 2e-3, ..Default::default() },
            bytes: 1 << 20,
            net_secs: 5e-4,
        };
        assert_eq!(c.bus_secs(), 2e-3);
        assert_eq!(c.secs(), 2e-3 + 5e-4);
    }
}
