//! Fig. 8: sustained MRAM bandwidth for strided and random access.
//!
//! Two strategies (Programming Recommendation 4):
//! * **coarse-grained DMA** — fetch full 1,024-B blocks and stride inside
//!   WRAM (what a CPU cache line does): effective bandwidth falls as
//!   1/stride because unused data is transferred;
//! * **fine-grained DMA** — fetch exactly the 8-B elements used: bandwidth
//!   is engine-throughput-bound (~72 MB/s at 16 tasklets) independent of
//!   stride, so it wins for strides ≥ 16.
//!
//! Random access (GUPS read-modify-write) uses fine-grained DMA by nature.
//!
//! Reported bandwidth is **effective** (useful bytes / time), matching the
//! paper's accounting (e.g. stride-16 coarse = 622/16 ≈ 38.9 MB/s).

use crate::arch::{DpuArch, DType, Op};
use crate::dpu::{Ctx, Dpu};
use crate::util::Rng;

/// Copy `a[i] -> c[i]` for i = 0, s, 2s, ... with coarse-grained DMA.
/// Returns effective MB/s.
pub fn coarse_strided_bw(arch: DpuArch, stride: usize, n_tasklets: u32, total_elems: usize) -> f64 {
    const BLOCK: usize = 1024;
    let mut dpu = Dpu::new(arch);
    let src: Vec<i64> = (0..total_elems as i64).collect();
    dpu.mram_store(0, &src);
    let abytes = total_elems * 8;
    let elems_per_block = BLOCK / 8;
    let n_blocks = total_elems * 8 / BLOCK;

    let run = dpu.launch(
        &|ctx: &mut Ctx| {
            let wa = ctx.mem_alloc(BLOCK);
            let wc = ctx.mem_alloc(BLOCK);
            let mut blk = ctx.tasklet_id as usize;
            while blk < n_blocks {
                ctx.mram_read(blk * BLOCK, wa, BLOCK);
                // stride inside WRAM: copy every stride-th element
                let av: Vec<i64> = ctx.wram_get(wa, elems_per_block);
                let mut cv: Vec<i64> = ctx.wram_get(wc, elems_per_block);
                let mut i = 0;
                while i < elems_per_block {
                    cv[i] = av[i];
                    i += stride;
                }
                ctx.wram_set(wc, &cv);
                ctx.charge_stream(DType::I64, Op::Add, elems_per_block.div_ceil(stride) as u64);
                ctx.mram_write(wc, abytes + blk * BLOCK, BLOCK);
                blk += ctx.n_tasklets as usize;
            }
        },
        n_tasklets,
    );
    let useful = 16 * (total_elems / stride) as u64; // 8 read + 8 written per used element
    useful as f64 / arch.cycles_to_secs(run.timing.cycles) / 1e6
}

/// Copy every stride-th element with 8-B fine-grained DMA transfers.
pub fn fine_strided_bw(arch: DpuArch, stride: usize, n_tasklets: u32, total_elems: usize) -> f64 {
    let mut dpu = Dpu::new(arch);
    let src: Vec<i64> = (0..total_elems as i64).collect();
    dpu.mram_store(0, &src);
    let abytes = total_elems * 8;
    let used = total_elems / stride;

    let run = dpu.launch(
        &|ctx: &mut Ctx| {
            let w = ctx.mem_alloc(8);
            let t = ctx.tasklet_id as usize;
            let nt = ctx.n_tasklets as usize;
            let mut k = t;
            while k < used {
                let i = k * stride;
                ctx.mram_read(i * 8, w, 8);
                ctx.compute(4); // address arithmetic + loop
                ctx.mram_write(w, abytes + i * 8, 8);
                k += nt;
            }
        },
        n_tasklets,
    );
    (16 * used) as f64 / arch.cycles_to_secs(run.timing.cycles) / 1e6
}

/// GUPS: random read-modify-write over the array, fine-grained DMA.
pub fn gups_bw(arch: DpuArch, n_tasklets: u32, total_elems: usize, n_updates: usize) -> f64 {
    let mut dpu = Dpu::new(arch);
    let src: Vec<i64> = vec![1; total_elems];
    dpu.mram_store(0, &src);
    // pre-generate random indices (the paper's a[] index array)
    let mut rng = Rng::new(0x6F5);
    let idx: Vec<usize> = (0..n_updates).map(|_| rng.below(total_elems as u64) as usize).collect();

    let run = dpu.launch(
        &|ctx: &mut Ctx| {
            let w = ctx.mem_alloc(8);
            let t = ctx.tasklet_id as usize;
            let nt = ctx.n_tasklets as usize;
            let mut k = t;
            while k < idx.len() {
                let i = idx[k];
                ctx.mram_read(i * 8, w, 8);
                let v: Vec<i64> = ctx.wram_get(w, 1);
                ctx.wram_set(w, &[v[0].wrapping_add(0x5DEECE)]);
                ctx.charge_stream(DType::I64, Op::Add, 1);
                ctx.mram_write(w, i * 8, 8);
                k += nt;
            }
        },
        n_tasklets,
    );
    (16 * n_updates) as f64 / arch.cycles_to_secs(run.timing.cycles) / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    const N: usize = 8 * 1024;

    #[test]
    fn coarse_bw_falls_with_stride() {
        let arch = DpuArch::p21();
        let s1 = coarse_strided_bw(arch, 1, 16, N);
        let s4 = coarse_strided_bw(arch, 4, 16, N);
        let s16 = coarse_strided_bw(arch, 16, 16, N);
        // paper: 622 → ~1/4 → ~1/16 (38.95)
        assert!((s1 - 622.0).abs() < 45.0, "{s1}");
        assert!((s4 / s1 - 0.25).abs() < 0.05, "{s4} vs {s1}");
        assert!((s16 - 38.95).abs() < 6.0, "{s16}");
    }

    #[test]
    fn fine_bw_flat_with_stride() {
        let arch = DpuArch::p21();
        let s16 = fine_strided_bw(arch, 16, 16, N);
        let s64 = fine_strided_bw(arch, 64, 16, N);
        // paper: 72.58 MB/s, independent of stride
        assert!((s16 - 72.58).abs() < 10.0, "{s16}");
        assert!((s64 - s16).abs() / s16 < 0.1);
    }

    #[test]
    fn crossover_at_stride_16_rec_4() {
        // coarse wins for small strides, fine for stride ≥ 16
        let arch = DpuArch::p21();
        assert!(coarse_strided_bw(arch, 4, 16, N) > fine_strided_bw(arch, 4, 16, N));
        assert!(fine_strided_bw(arch, 16, 16, N) > coarse_strided_bw(arch, 16, 16, N) * 0.9);
        assert!(fine_strided_bw(arch, 64, 16, N) > coarse_strided_bw(arch, 64, 16, N));
    }

    #[test]
    fn gups_matches_fine_grained() {
        let arch = DpuArch::p21();
        let g = gups_bw(arch, 16, N, 2048);
        assert!((g - 70.0).abs() < 12.0, "{g} (paper 72.58)");
    }

    #[test]
    fn gups_functional_updates_land() {
        let arch = DpuArch::p21();
        let mut dpu = Dpu::new(arch);
        dpu.mram_store(0, &vec![0i64; 64]);
        let idx = [3usize, 17, 42];
        dpu.launch(
            &|ctx: &mut Ctx| {
                if ctx.tasklet_id == 0 {
                    let w = ctx.mem_alloc(8);
                    for &i in &idx {
                        ctx.mram_read(i * 8, w, 8);
                        let v: Vec<i64> = ctx.wram_get(w, 1);
                        ctx.wram_set(w, &[v[0] + 1]);
                        ctx.mram_write(w, i * 8, 8);
                    }
                }
            },
            2,
        );
        let out: Vec<i64> = dpu.mram_load(0, 64);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, if idx.contains(&i) { 1 } else { 0 });
        }
    }
}
