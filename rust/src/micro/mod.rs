//! Section 3 microbenchmarks: the architecture-characterization suite.
//!
//! Each submodule reproduces one experiment family of the paper:
//! - [`arith`]        — Fig. 4: arithmetic throughput vs tasklets
//! - [`wram_stream`]  — Fig. 5: sustained WRAM bandwidth (STREAM)
//! - [`mram`]         — Fig. 6: MRAM DMA latency/bandwidth vs size
//! - [`mram_stream`]  — Fig. 7: sustained MRAM bandwidth (STREAM + COPY-DMA)
//! - [`strided`]      — Fig. 8: strided (coarse/fine DMA) and random (GUPS)
//! - [`opint`]        — Figs. 9/18: throughput vs operational intensity
//! - [`xfer`]         — Fig. 10: CPU↔DPU transfer bandwidth

pub mod arith;
pub mod mram;
pub mod mram_stream;
pub mod opint;
pub mod strided;
pub mod wram_stream;
pub mod xfer;
