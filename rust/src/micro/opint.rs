//! Figs. 9 & 18: arithmetic throughput vs operational intensity (the
//! roofline-style experiment that establishes Key Observation 6: the DPU is
//! fundamentally compute-bound).
//!
//! The microbenchmark streams 1,024-B blocks MRAM→WRAM→MRAM and performs a
//! variable number of arithmetic operations per block; operational
//! intensity = ops / bytes-accessed-from-MRAM. At low intensity the DMA
//! engine dominates (memory-bound region, throughput ∝ intensity); past
//! the *throughput saturation point* the pipeline dominates (compute-bound
//! plateau at Eq. 1).

use crate::arch::{isa, DpuArch, DType, Op};
use crate::dpu::{Ctx, Dpu};

/// DMA block size.
const BLOCK: usize = 1024;

/// Measure arithmetic throughput (MOPS) at a given operational intensity
/// (operations per MRAM byte; the paper sweeps 1/2048 .. 8).
pub fn throughput_at_intensity(
    arch: DpuArch,
    dtype: DType,
    op: Op,
    intensity: f64,
    n_tasklets: u32,
    n_blocks_total: usize,
) -> f64 {
    // bytes per block counted as read+write (the block is streamed back)
    let bytes_per_block = (2 * BLOCK) as f64;
    let ops_per_block = (intensity * bytes_per_block).max(0.0);
    // each operation is a full read-modify-write loop iteration on a WRAM
    // operand (Listing 1 structure): addr calc + ld + op + st + loop ctrl
    let instrs_per_op = isa::stream_loop_instrs(dtype, op) as u64;

    let mut dpu = Dpu::new(arch);
    dpu.mram_store(0, &vec![1u8; n_blocks_total * BLOCK]);
    let run = dpu.launch(
        &|ctx: &mut Ctx| {
            let w = ctx.mem_alloc(BLOCK);
            let mut blk = ctx.tasklet_id as usize;
            // accumulate fractional ops per block so low intensities are exact
            let mut carry = 0f64;
            while blk < n_blocks_total {
                ctx.mram_read(blk * BLOCK, w, BLOCK);
                carry += ops_per_block;
                let ops_now = carry as u64;
                carry -= ops_now as f64;
                ctx.compute(ops_now * instrs_per_op);
                ctx.mram_write(w, blk * BLOCK, BLOCK);
                blk += ctx.n_tasklets as usize;
            }
        },
        n_tasklets,
    );
    let total_ops = intensity * bytes_per_block * n_blocks_total as f64;
    let secs = arch.cycles_to_secs(run.timing.cycles);
    total_ops / secs / 1e6
}

/// The intensity grid of Fig. 9 (powers of two from 1/2048 to 8 OP/B).
pub fn fig9_intensities() -> Vec<f64> {
    (-11..=3).map(|e| 2f64.powi(e)).collect()
}

/// Find the throughput saturation point: the smallest grid intensity whose
/// throughput is ≥95% of the plateau.
pub fn saturation_point(arch: DpuArch, dtype: DType, op: Op, n_tasklets: u32) -> f64 {
    let grid = fig9_intensities();
    let plateau = throughput_at_intensity(arch, dtype, op, 8.0, n_tasklets, 64);
    for &i in &grid {
        let t = throughput_at_intensity(arch, dtype, op, i, n_tasklets, 64);
        if t >= 0.95 * plateau {
            return i;
        }
    }
    8.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn memory_bound_then_compute_bound() {
        let arch = DpuArch::p21();
        // memory-bound region: throughput grows ~linearly with intensity
        let lo = throughput_at_intensity(arch, DType::I32, Op::Add, 1.0 / 512.0, 16, 64);
        let mid = throughput_at_intensity(arch, DType::I32, Op::Add, 1.0 / 128.0, 16, 64);
        assert!((mid / lo - 4.0).abs() < 0.5, "{mid} vs {lo}");
        // compute-bound plateau
        let hi = throughput_at_intensity(arch, DType::I32, Op::Add, 4.0, 16, 64);
        let hi2 = throughput_at_intensity(arch, DType::I32, Op::Add, 8.0, 16, 64);
        assert!((hi2 - hi).abs() / hi < 0.1, "{hi} vs {hi2}");
    }

    #[test]
    fn saturation_at_low_intensity_key_obs_6() {
        // int32 add saturates below 1 OP/B — the DPU is compute-bound
        let arch = DpuArch::p21();
        let sat = saturation_point(arch, DType::I32, Op::Add, 16);
        assert!(sat <= 1.0, "saturation at {sat} OP/B");
    }

    #[test]
    fn expensive_ops_saturate_earlier() {
        // mul (29 instrs) saturates at lower intensity than add (1 instr);
        // f32 mul (178) earlier still (paper: 1/4 vs 1/32 vs 1/128)
        let arch = DpuArch::p21();
        let s_add = saturation_point(arch, DType::I32, Op::Add, 16);
        let s_mul = saturation_point(arch, DType::I32, Op::Mul, 16);
        let s_fmul = saturation_point(arch, DType::F32, Op::Mul, 16);
        assert!(s_mul < s_add, "mul {s_mul} vs add {s_add}");
        assert!(s_fmul < s_mul, "fmul {s_fmul} vs mul {s_mul}");
    }

    #[test]
    fn fig18_memory_bound_saturates_below_11_tasklets() {
        // at very low intensity, throughput is DMA-bound: it saturates
        // with a handful of tasklets (paper: 2; model: ~4 — both ≪ 11)
        let arch = DpuArch::p21();
        let t4 = throughput_at_intensity(arch, DType::I32, Op::Add, 1.0 / 64.0, 4, 64);
        let t8 = throughput_at_intensity(arch, DType::I32, Op::Add, 1.0 / 64.0, 8, 64);
        let t16 = throughput_at_intensity(arch, DType::I32, Op::Add, 1.0 / 64.0, 16, 64);
        assert!((t16 - t8).abs() / t8 < 0.10, "{t8} vs {t16}");
        assert!(t8 < t4 * 1.6, "sublinear past saturation: {t4} vs {t8}");
        // in the compute-bound region 11 tasklets are needed
        let c8 = throughput_at_intensity(arch, DType::I32, Op::Add, 8.0, 8, 64);
        let c11 = throughput_at_intensity(arch, DType::I32, Op::Add, 8.0, 11, 64);
        assert!(c11 > c8 * 1.2, "{c8} vs {c11}");
    }

    #[test]
    fn plateau_equals_eq1_throughput() {
        let arch = DpuArch::p21();
        let hi = throughput_at_intensity(arch, DType::I32, Op::Mul, 8.0, 16, 64);
        // the compute-bound plateau is the Fig. 4 streaming throughput
        let expect = crate::arch::isa::expected_mops(DType::I32, Op::Mul, 350);
        assert!((hi - expect).abs() / expect < 0.1, "{hi} vs {expect}");
    }
}
