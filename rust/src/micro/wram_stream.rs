//! Fig. 5: sustained WRAM bandwidth for the four STREAM versions (COPY,
//! ADD, SCALE, TRIAD) on 64-bit integers, loops unrolled (no loop-control
//! instructions), as a function of tasklet count.
//!
//! Instruction costs per element (paper §3.1.1/§3.1.3):
//! COPY  = ld + sd                          = 2 instrs / 16 B
//! ADD   = 2·ld + add + addc + sd           = 5 instrs / 24 B
//! SCALE = ld + __muldi3 + sd               = 2 + 132 instrs / 16 B
//! TRIAD = 2·ld + __muldi3 + add + addc + sd = 3 + 134 instrs / 24 B

use crate::arch::{isa, DpuArch, DType, Op};
use crate::dpu::{Ctx, Dpu};

/// STREAM versions.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Stream {
    Copy,
    Add,
    Scale,
    Triad,
}

impl Stream {
    pub const ALL: [Stream; 4] = [Stream::Copy, Stream::Add, Stream::Scale, Stream::Triad];

    pub fn name(self) -> &'static str {
        match self {
            Stream::Copy => "COPY",
            Stream::Add => "ADD",
            Stream::Scale => "SCALE",
            Stream::Triad => "TRIAD",
        }
    }

    /// (instructions, bytes accessed) per 64-bit element, unrolled.
    pub fn cost(self) -> (u64, u64) {
        let mul = isa::op_instrs(DType::I64, Op::Mul) as u64;
        let add = isa::op_instrs(DType::I64, Op::Add) as u64;
        match self {
            Stream::Copy => (2, 16),
            Stream::Add => (3 + add, 24),
            Stream::Scale => (2 + mul, 16),
            Stream::Triad => (3 + mul + add, 24),
        }
    }
}

/// Elements per tasklet (WRAM-resident arrays, as in the paper).
const ELEMS_PER_TASKLET: u64 = 512;
/// Outer repetitions to lengthen the run.
const REPS: u64 = 64;

/// Sustained WRAM bandwidth in MB/s for one STREAM version.
pub fn wram_bw_mbps(arch: DpuArch, version: Stream, n_tasklets: u32) -> f64 {
    let (instrs, bytes) = version.cost();
    let mut dpu = Dpu::new(arch);
    let run = dpu.launch(
        &|ctx: &mut Ctx| {
            // functional payload: three small WRAM arrays per tasklet
            let a = ctx.mem_alloc(256);
            let b = ctx.mem_alloc(256);
            let c = ctx.mem_alloc(256);
            ctx.wram_set(a, &[1i64; 32]);
            ctx.wram_set(b, &[2i64; 32]);
            let scalar = 3i64;
            // one real pass for correctness of the wram path
            let av: Vec<i64> = ctx.wram_get(a, 32);
            let bv: Vec<i64> = ctx.wram_get(b, 32);
            let cv: Vec<i64> = match version {
                Stream::Copy => av.clone(),
                Stream::Add => av.iter().zip(&bv).map(|(x, y)| x + y).collect(),
                Stream::Scale => av.iter().map(|x| x * scalar).collect(),
                Stream::Triad => av.iter().zip(&bv).map(|(x, y)| x + y * scalar).collect(),
            };
            ctx.wram_set(c, &cv);
            // timing: the unrolled stream loop
            ctx.compute(ELEMS_PER_TASKLET * REPS * instrs);
        },
        n_tasklets,
    );
    let total_bytes = ELEMS_PER_TASKLET * REPS * bytes * n_tasklets as u64;
    let secs = arch.cycles_to_secs(run.timing.cycles);
    total_bytes as f64 / secs / 1e6
}

/// Fig. 5 sweep: (version, tasklets, MB/s).
pub fn fig5_sweep(arch: DpuArch, tasklet_counts: &[u32]) -> Vec<(Stream, u32, f64)> {
    let mut out = Vec::new();
    for v in Stream::ALL {
        for &t in tasklet_counts {
            out.push((v, t, wram_bw_mbps(arch, v, t)));
        }
    }
    out
}

/// WRAM access pattern for the footnote-10 microbenchmark.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WramPattern {
    Unit,
    Strided(usize),
    Random,
}

/// The paper's footnote-10 microbenchmark (Key Observation 3):
/// `c[a[i]] = b[a[i]]` where the index array `a` is unit-stride, strided,
/// or random — WRAM bandwidth must be identical for all three, because
/// every 8-B WRAM load/store is one pipeline cycle regardless of address.
/// Returns sustained MB/s.
pub fn wram_pattern_bw(arch: DpuArch, pattern: WramPattern, n_tasklets: u32) -> f64 {
    use crate::util::Rng;
    // 16 tasklets × 3 arrays × 1 KB = 48 KB of the 64-KB WRAM
    const N: usize = 128; // elements per tasklet array
    const REPS: u64 = 64;
    let mut dpu = crate::dpu::Dpu::new(arch);
    let run = dpu.launch(
        &|ctx: &mut Ctx| {
            let a = ctx.mem_alloc(N * 8);
            let b = ctx.mem_alloc(N * 8);
            let c = ctx.mem_alloc(N * 8);
            // build the index array
            let mut rng = Rng::new(ctx.tasklet_id as u64 + 1);
            let idx: Vec<i64> = (0..N)
                .map(|i| match pattern {
                    WramPattern::Unit => i as i64,
                    WramPattern::Strided(s) => ((i * s) % N) as i64,
                    WramPattern::Random => rng.below(N as u64) as i64,
                })
                .collect();
            ctx.wram_set(a, &idx);
            ctx.wram_set(b, &(0..N as i64).map(|x| x * 3).collect::<Vec<_>>());
            // functional pass: c[a[i]] = b[a[i]]
            let av: Vec<i64> = ctx.wram_get(a, N);
            let bv: Vec<i64> = ctx.wram_get(b, N);
            let mut cv = vec![0i64; N];
            for &j in &av {
                cv[j as usize] = bv[j as usize];
            }
            ctx.wram_set(c, &cv);
            // timing: per element ld a[i], ld b[a[i]], st c[a[i]], loop —
            // identical instruction count for every pattern
            ctx.compute(
                REPS * N as u64 * (3 * isa::WRAM_LS + isa::ADDR_CALC + isa::LOOP_CTRL) as u64,
            );
        },
        n_tasklets,
    );
    let bytes = REPS * N as u64 * 24 * n_tasklets as u64; // ld idx + ld + st
    bytes as f64 / arch.cycles_to_secs(run.timing.cycles) / 1e6
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn copy_reaches_2800_mbps() {
        // paper: 2,818.98 MB/s measured, 2,800 theoretical
        let bw = wram_bw_mbps(DpuArch::p21(), Stream::Copy, 16);
        assert!((bw - 2800.0).abs() < 60.0, "{bw}");
    }

    #[test]
    fn add_reaches_1680_mbps() {
        let bw = wram_bw_mbps(DpuArch::p21(), Stream::Add, 16);
        assert!((bw - 1680.0).abs() < 40.0, "{bw}");
    }

    #[test]
    fn scale_triad_order_of_magnitude_lower() {
        // paper: SCALE 42.03, TRIAD 61.66 MB/s (multiplication-bound)
        let scale = wram_bw_mbps(DpuArch::p21(), Stream::Scale, 16);
        let triad = wram_bw_mbps(DpuArch::p21(), Stream::Triad, 16);
        assert!((scale - 42.03).abs() < 4.0, "{scale}");
        assert!((triad - 61.66).abs() < 5.0, "{triad}");
    }

    #[test]
    fn wram_bw_pattern_independent_key_obs_3() {
        // footnote 10: unit-stride, strided, and random WRAM access all
        // sustain the same bandwidth
        let arch = DpuArch::p21();
        let unit = wram_pattern_bw(arch, WramPattern::Unit, 16);
        let strided = wram_pattern_bw(arch, WramPattern::Strided(7), 16);
        let random = wram_pattern_bw(arch, WramPattern::Random, 16);
        assert!((strided - unit).abs() / unit < 1e-9, "{unit} vs {strided}");
        assert!((random - unit).abs() / unit < 1e-9, "{unit} vs {random}");
    }

    #[test]
    fn saturates_at_11() {
        let b10 = wram_bw_mbps(DpuArch::p21(), Stream::Copy, 10);
        let b11 = wram_bw_mbps(DpuArch::p21(), Stream::Copy, 11);
        let b16 = wram_bw_mbps(DpuArch::p21(), Stream::Copy, 16);
        assert!(b11 > b10);
        assert!((b16 - b11).abs() / b11 < 0.02);
    }
}
