//! Fig. 6: MRAM read/write latency and bandwidth vs DMA transfer size
//! (8–2,048 B), single tasklet, plus the Eq. 3 linear-model overlay.

use crate::arch::DpuArch;
use crate::dpu::{Ctx, Dpu};

/// One measurement row of Fig. 6.
#[derive(Clone, Copy, Debug)]
pub struct MramPoint {
    pub bytes: u32,
    /// Measured latency (cycles per transfer, from the replayed run).
    pub latency_cycles: f64,
    /// Analytical Eq. 3 latency (the dashed overlay line).
    pub model_cycles: f64,
    /// Sustained bandwidth in MB/s.
    pub bandwidth_mbps: f64,
}

/// Measure one transfer size / direction over `reps` transfers.
pub fn mram_point(arch: DpuArch, read: bool, bytes: u32, reps: u32) -> MramPoint {
    let mut dpu = Dpu::new(arch);
    // seed MRAM so reads return real data
    dpu.mram_store(0, &vec![0xABu8; bytes as usize]);
    let run = dpu.launch(
        &|ctx: &mut Ctx| {
            let buf = ctx.mem_alloc(bytes as usize);
            for _ in 0..reps {
                if read {
                    ctx.mram_read(0, buf, bytes as usize);
                } else {
                    ctx.mram_write(buf, 0, bytes as usize);
                }
            }
        },
        1,
    );
    let latency = run.timing.cycles / reps as f64;
    let secs = arch.cycles_to_secs(run.timing.cycles);
    MramPoint {
        bytes,
        latency_cycles: latency,
        model_cycles: arch.dma_latency_cycles(read, bytes),
        bandwidth_mbps: (bytes as u64 * reps as u64) as f64 / secs / 1e6,
    }
}

/// The transfer sizes of Fig. 6 (powers of two, 8..2048).
pub fn fig6_sizes() -> Vec<u32> {
    (3..=11).map(|s| 1u32 << s).collect()
}

/// Full Fig. 6 sweep for one direction.
pub fn fig6_sweep(arch: DpuArch, read: bool) -> Vec<MramPoint> {
    fig6_sizes().into_iter().map(|b| mram_point(arch, read, b, 64)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::linear_fit;

    #[test]
    fn latency_is_linear_in_size_key_obs_4() {
        // fit measured latency = a + b·size; expect a≈α, b≈0.5, r²≈1
        let pts = fig6_sweep(DpuArch::p21(), true);
        let xs: Vec<f64> = pts.iter().map(|p| p.bytes as f64).collect();
        let ys: Vec<f64> = pts.iter().map(|p| p.latency_cycles).collect();
        let (a, b, r2) = linear_fit(&xs, &ys);
        assert!((a - 77.0).abs() < 2.0, "alpha {a}");
        assert!((b - 0.5).abs() < 0.01, "beta {b}");
        assert!(r2 > 0.9999);
    }

    #[test]
    fn paper_latency_checkpoints() {
        // paper: 8-B read = 81 cycles, 128-B read = 141 cycles (+74%)
        let arch = DpuArch::p21();
        let p8 = mram_point(arch, true, 8, 32);
        let p128 = mram_point(arch, true, 128, 32);
        assert!((p8.latency_cycles - 81.0).abs() < 1.0, "{}", p8.latency_cycles);
        assert!((p128.latency_cycles - 141.0).abs() < 1.0);
    }

    #[test]
    fn max_bandwidth_near_628() {
        // paper: 628.23 MB/s read / 633.22 MB/s write at 2,048 B
        let arch = DpuArch::p21();
        let rd = mram_point(arch, true, 2048, 64);
        let wr = mram_point(arch, false, 2048, 64);
        assert!((rd.bandwidth_mbps - 628.0).abs() < 30.0, "{}", rd.bandwidth_mbps);
        assert!(wr.bandwidth_mbps > rd.bandwidth_mbps, "write slightly faster (lower alpha)");
    }

    #[test]
    fn read_write_symmetric() {
        // Fig. 6: read and write curves are very similar
        let arch = DpuArch::p21();
        for b in [64u32, 512, 2048] {
            let rd = mram_point(arch, true, b, 16);
            let wr = mram_point(arch, false, b, 16);
            let rel = (rd.latency_cycles - wr.latency_cycles).abs() / rd.latency_cycles;
            assert!(rel < 0.2, "{b}: {rel}");
        }
    }

    #[test]
    fn measured_matches_model_exactly() {
        for p in fig6_sweep(DpuArch::p21(), false) {
            assert!((p.latency_cycles - p.model_cycles).abs() < 0.5);
        }
    }
}
