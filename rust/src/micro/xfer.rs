//! Fig. 10: CPU↔DPU transfer bandwidth sweeps.
//!
//! (a) single-DPU transfer size sweep 8 B – 32 MB;
//! (b) serial / parallel / broadcast aggregate bandwidth for 1–64 DPUs in
//!     one rank at 32 MB per DPU.
//!
//! Small sizes also move real bytes through the typed-symbol transfer
//! builder to keep the functional path exercised; large sizes query the
//! calibrated model directly.

use crate::arch::SystemConfig;
use crate::coordinator::PimSet;
use crate::system::{Dir, XferModel};

/// Fig. 10a: (bytes, cpu→dpu MB/s, dpu→cpu MB/s) for one DPU.
pub fn fig10a_sweep() -> Vec<(usize, f64, f64)> {
    let m = XferModel::default();
    let mut out = Vec::new();
    let mut size = 8usize;
    while size <= 32 * 1024 * 1024 {
        out.push((
            size,
            m.serial_bw(Dir::CpuToDpu, size) / 1e6,
            m.serial_bw(Dir::DpuToCpu, size) / 1e6,
        ));
        size *= 4;
    }
    out
}

/// Fig. 10b row: aggregate bandwidth (GB/s) of each transfer mode for `n`
/// DPUs at `bytes` per DPU.
#[derive(Clone, Copy, Debug)]
pub struct Fig10bRow {
    pub n_dpus: u32,
    pub serial_c2d: f64,
    pub serial_d2c: f64,
    pub parallel_c2d: f64,
    pub parallel_d2c: f64,
    pub broadcast: f64,
}

/// Fig. 10b sweep over DPU counts within one rank.
pub fn fig10b_sweep(bytes: usize, dpu_counts: &[u32]) -> Vec<Fig10bRow> {
    let m = XferModel::default();
    dpu_counts
        .iter()
        .map(|&n| {
            let total = n as f64 * bytes as f64;
            Fig10bRow {
                n_dpus: n,
                serial_c2d: total / (n as f64 * m.serial_secs(Dir::CpuToDpu, bytes)) / 1e9,
                serial_d2c: total / (n as f64 * m.serial_secs(Dir::DpuToCpu, bytes)) / 1e9,
                parallel_c2d: total / m.parallel_secs(Dir::CpuToDpu, bytes, n) / 1e9,
                parallel_d2c: total / m.parallel_secs(Dir::DpuToCpu, bytes, n) / 1e9,
                broadcast: total / m.broadcast_secs(bytes, n) / 1e9,
            }
        })
        .collect()
}

/// Functional smoke transfer: round-trip up to `n` i64 per DPU through
/// the typed symbol + builder path — an equal-size leg and a ragged leg —
/// and verify the data (used by tests and the harness preamble).
pub fn roundtrip_check(sys: SystemConfig, n_dpus: u32, n: usize) -> bool {
    let mut set = PimSet::allocate_with(
        sys,
        n_dpus,
        std::sync::Arc::new(crate::coordinator::executor::SerialExecutor),
    );
    let sym = set.symbol::<i64>(n);
    let equal: Vec<Vec<i64>> = (0..n_dpus as i64)
        .map(|i| (0..n as i64).map(|j| i * 1000 + j).collect())
        .collect();
    set.xfer(sym).to().equal(&equal);
    if set.xfer(sym).from().equal(n) != equal {
        return false;
    }
    // ragged: DPU d keeps only its first d+1 elements' worth of data
    let ragged: Vec<Vec<i64>> = (0..n_dpus as usize)
        .map(|d| equal[d][..(d + 1).min(n)].to_vec())
        .collect();
    let lens: Vec<usize> = ragged.iter().map(Vec::len).collect();
    set.xfer(sym).to().ragged(&ragged);
    set.xfer(sym).from().ragged(&lens) == ragged
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig10a_monotone_key_obs_7() {
        let sweep = fig10a_sweep();
        for w in sweep.windows(2) {
            assert!(w[1].1 >= w[0].1, "c2d bandwidth must grow with size");
            assert!(w[1].2 >= w[0].2);
        }
        // ends near 330 / 120 MB/s
        let last = sweep.last().unwrap();
        assert!((last.1 - 330.0).abs() < 15.0, "{}", last.1);
        assert!((last.2 - 120.0).abs() < 10.0);
    }

    #[test]
    fn fig10b_parallel_grows_serial_flat() {
        let rows = fig10b_sweep(32 << 20, &[1, 4, 16, 64]);
        assert!((rows[3].parallel_c2d - 6.68).abs() < 0.2);
        assert!((rows[3].parallel_d2c - 4.74).abs() < 0.2);
        assert!((rows[3].broadcast - 16.88).abs() < 0.6);
        // serial flat
        assert!((rows[0].serial_c2d - rows[3].serial_c2d).abs() < 1e-9);
        // parallel monotone
        for w in rows.windows(2) {
            assert!(w[1].parallel_c2d > w[0].parallel_c2d);
        }
    }

    #[test]
    fn functional_roundtrip() {
        assert!(roundtrip_check(SystemConfig::p21_rank(), 8, 64));
    }
}
