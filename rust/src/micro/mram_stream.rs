//! Fig. 7: sustained MRAM bandwidth for streaming benchmarks (COPY-DMA,
//! COPY, ADD, SCALE, TRIAD) with 1,024-byte DMA transfers, vs tasklets.
//!
//! COPY/ADD saturate at 4/6 tasklets at the DMA-engine roof (memory-bound,
//! Key Obs. 5); SCALE/TRIAD saturate at 11 tasklets an order of magnitude
//! lower (multiplication-bound — their MRAM bandwidth equals their WRAM
//! bandwidth).

use super::wram_stream::Stream;
use crate::arch::DpuArch;
use crate::dpu::{Ctx, Dpu};
use crate::util::pod::cast_slice_mut;

/// Fig. 7 benchmark variants: the four STREAMs plus COPY-DMA.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MramStream {
    CopyDma,
    Stream(Stream),
}

impl MramStream {
    pub const ALL: [MramStream; 5] = [
        MramStream::CopyDma,
        MramStream::Stream(Stream::Copy),
        MramStream::Stream(Stream::Add),
        MramStream::Stream(Stream::Scale),
        MramStream::Stream(Stream::Triad),
    ];

    pub fn name(self) -> &'static str {
        match self {
            MramStream::CopyDma => "COPY-DMA",
            MramStream::Stream(s) => s.name(),
        }
    }
}

/// DMA block size used by the paper's Fig. 7 experiment.
pub const BLOCK: usize = 1024;

/// Run one Fig. 7 configuration. Streams `total_elems` 8-byte elements
/// split across tasklets; returns sustained MRAM bandwidth in MB/s
/// (bytes through the DMA engine / time).
pub fn mram_stream_bw(
    arch: DpuArch,
    version: MramStream,
    n_tasklets: u32,
    total_elems: usize,
) -> f64 {
    let mut dpu = Dpu::new(arch);
    let src: Vec<i64> = (0..total_elems as i64).collect();
    let src2: Vec<i64> = (0..total_elems as i64).map(|x| x * 3).collect();
    // layout: a at 0, b after a, c after b
    let abytes = total_elems * 8;
    dpu.mram_store(0, &src);
    dpu.mram_store(abytes, &src2);
    let scalar = 7i64;

    let elems_per_block = BLOCK / 8;
    let n_blocks = total_elems / elems_per_block;

    let run = dpu.launch(
        &|ctx: &mut Ctx| {
            let t = ctx.tasklet_id as usize;
            let nt = ctx.n_tasklets as usize;
            let wa = ctx.mem_alloc(BLOCK);
            let wb = ctx.mem_alloc(BLOCK);
            let wc = ctx.mem_alloc(BLOCK);
            // block-cyclic over blocks
            let mut blk = t;
            while blk < n_blocks {
                let off = blk * BLOCK;
                match version {
                    MramStream::CopyDma => {
                        // MRAM→WRAM→MRAM without touching the core
                        ctx.mram_read(off, wa, BLOCK);
                        ctx.mram_write(wa, 2 * abytes + off, BLOCK);
                    }
                    MramStream::Stream(s) => {
                        ctx.mram_read(off, wa, BLOCK);
                        let needs_b = matches!(s, Stream::Add | Stream::Triad);
                        if needs_b {
                            ctx.mram_read(abytes + off, wb, BLOCK);
                        }
                        // functional element work
                        let av: Vec<i64> = ctx.wram_get(wa, elems_per_block);
                        let bv: Vec<i64> = if needs_b {
                            ctx.wram_get(wb, elems_per_block)
                        } else {
                            Vec::new()
                        };
                        let cv: Vec<i64> = match s {
                            Stream::Copy => av,
                            Stream::Add => av.iter().zip(&bv).map(|(x, y)| x + y).collect(),
                            Stream::Scale => av.iter().map(|x| x * scalar).collect(),
                            Stream::Triad => {
                                av.iter().zip(&bv).map(|(x, y)| x + y * scalar).collect()
                            }
                        };
                        ctx.wram(|w| {
                            cast_slice_mut::<i64>(&mut w[wc..wc + BLOCK]).copy_from_slice(&cv)
                        });
                        // pipeline cost of the unrolled loop
                        let (instrs, _) = s.cost();
                        ctx.compute(elems_per_block as u64 * instrs);
                        ctx.mram_write(wc, 2 * abytes + off, BLOCK);
                    }
                }
                blk += nt;
            }
        },
        n_tasklets,
    );
    let secs = arch.cycles_to_secs(run.timing.cycles);
    run.timing.dma_bytes as f64 / secs / 1e6
}

/// Fig. 7 sweep: (version, tasklets, MB/s).
pub fn fig7_sweep(
    arch: DpuArch,
    tasklet_counts: &[u32],
    total_elems: usize,
) -> Vec<(MramStream, u32, f64)> {
    let mut out = Vec::new();
    for v in MramStream::ALL {
        for &t in tasklet_counts {
            out.push((v, t, mram_stream_bw(arch, v, t, total_elems)));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const N: usize = 16 * 1024; // 128 KB per array — enough blocks for 16 tasklets

    #[test]
    fn copy_dma_saturates_at_2_tasklets() {
        let arch = DpuArch::p21();
        let b1 = mram_stream_bw(arch, MramStream::CopyDma, 1, N);
        let b2 = mram_stream_bw(arch, MramStream::CopyDma, 2, N);
        let b8 = mram_stream_bw(arch, MramStream::CopyDma, 8, N);
        assert!(b2 > b1);
        assert!((b8 - b2).abs() / b2 < 0.03, "flat after 2: {b2} vs {b8}");
        // paper: 624 MB/s; model: ~654
        assert!((b2 - 624.0).abs() < 40.0, "{b2}");
    }

    #[test]
    fn copy_add_memory_bound_key_obs_5() {
        // COPY saturates by ~4 tasklets, ADD by ~6, both near COPY-DMA bw
        let arch = DpuArch::p21();
        let copy4 = mram_stream_bw(arch, MramStream::Stream(Stream::Copy), 4, N);
        let copy16 = mram_stream_bw(arch, MramStream::Stream(Stream::Copy), 16, N);
        assert!((copy16 - copy4).abs() / copy4 < 0.05, "{copy4} vs {copy16}");
        let add8 = mram_stream_bw(arch, MramStream::Stream(Stream::Add), 8, N);
        let add16 = mram_stream_bw(arch, MramStream::Stream(Stream::Add), 16, N);
        assert!((add16 - add8).abs() / add8 < 0.05);
        assert!(copy16 > 550.0, "{copy16}");
    }

    #[test]
    fn scale_triad_compute_bound() {
        // SCALE/TRIAD: pipeline-bound; MRAM bw ≈ WRAM bw (42 / 61.7 MB/s)
        let arch = DpuArch::p21();
        let scale = mram_stream_bw(arch, MramStream::Stream(Stream::Scale), 16, N);
        let triad = mram_stream_bw(arch, MramStream::Stream(Stream::Triad), 16, N);
        assert!((scale - 42.0).abs() < 6.0, "{scale}");
        assert!((triad - 61.7).abs() < 8.0, "{triad}");
        // saturation at 11, not earlier
        let scale8 = mram_stream_bw(arch, MramStream::Stream(Stream::Scale), 8, N);
        let scale11 = mram_stream_bw(arch, MramStream::Stream(Stream::Scale), 11, N);
        assert!(scale11 > scale8 * 1.2);
    }

    #[test]
    fn copy_functional_correctness() {
        // the COPY variant must actually copy a→c through WRAM
        let arch = DpuArch::p21();
        let mut dpu = Dpu::new(arch);
        let n = 1024usize;
        let src: Vec<i64> = (0..n as i64).map(|x| x * 11).collect();
        dpu.mram_store(0, &src);
        let abytes = n * 8;
        dpu.launch(
            &|ctx: &mut Ctx| {
                let w = ctx.mem_alloc(BLOCK);
                let mut blk = ctx.tasklet_id as usize;
                let nblocks = n * 8 / BLOCK;
                while blk < nblocks {
                    ctx.mram_read(blk * BLOCK, w, BLOCK);
                    ctx.mram_write(w, 2 * abytes + blk * BLOCK, BLOCK);
                    blk += ctx.n_tasklets as usize;
                }
            },
            4,
        );
        let out: Vec<i64> = dpu.mram_load(2 * abytes, n);
        assert_eq!(out, src);
    }
}
