//! Fig. 4: arithmetic throughput of one DPU vs number of tasklets, for
//! {int32,int64,float,double} × {add,sub,mul,div}.
//!
//! The microbenchmark is Listing 1: every tasklet streams over a WRAM
//! buffer performing read-modify-write operations; the loop costs
//! `stream_loop_instrs(dtype, op)` instructions per element.

use crate::arch::{DpuArch, DType, Op};
use crate::dpu::{Ctx, Dpu};

/// Elements processed per tasklet (enough to amortize startup exactly).
const ELEMS_PER_TASKLET: u64 = 32 * 1024;

/// Run the streaming arithmetic microbenchmark; returns measured MOPS.
pub fn throughput_mops(arch: DpuArch, dtype: DType, op: Op, n_tasklets: u32) -> f64 {
    let mut dpu = Dpu::new(arch);
    // functional payload: a real i64 buffer in WRAM per tasklet, so the
    // benchmark also exercises the wram path (values are irrelevant to
    // timing, but keep the simulator honest)
    let run = dpu.launch(
        &|ctx: &mut Ctx| {
            let buf = ctx.mem_alloc(1024);
            ctx.wram_set(buf, &[1i64; 128]);
            ctx.charge_stream(dtype, op, ELEMS_PER_TASKLET);
        },
        n_tasklets,
    );
    let total_ops = ELEMS_PER_TASKLET * n_tasklets as u64;
    let secs = arch.cycles_to_secs(run.timing.cycles);
    total_ops as f64 / secs / 1e6
}

/// Full Fig. 4 sweep: (dtype, op, tasklets, MOPS) tuples.
pub fn fig4_sweep(arch: DpuArch, tasklet_counts: &[u32]) -> Vec<(DType, Op, u32, f64)> {
    let mut out = Vec::new();
    for &dt in &[DType::I32, DType::I64, DType::F32, DType::F64] {
        for &op in &Op::ARITH {
            for &t in tasklet_counts {
                out.push((dt, op, t, throughput_mops(arch, dt, op, t)));
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::isa::expected_mops;

    #[test]
    fn saturates_at_11_tasklets_key_obs_1() {
        let arch = DpuArch::p21();
        for dt in [DType::I32, DType::F32] {
            let t10 = throughput_mops(arch, dt, Op::Add, 10);
            let t11 = throughput_mops(arch, dt, Op::Add, 11);
            let t16 = throughput_mops(arch, dt, Op::Add, 16);
            let t24 = throughput_mops(arch, dt, Op::Add, 24);
            assert!(t11 > t10 * 1.05, "{dt:?}: t11 {t11} vs t10 {t10}");
            assert!((t16 - t11).abs() / t11 < 0.02, "{dt:?}: flat after 11");
            assert!((t24 - t11).abs() / t11 < 0.02);
        }
    }

    #[test]
    fn saturated_throughput_matches_paper() {
        // Fig. 4 measured values at 16 tasklets, 350 MHz.
        let arch = DpuArch::p21();
        let cases = [
            (DType::I32, Op::Add, 58.56),
            (DType::I64, Op::Add, 50.16),
            (DType::I32, Op::Mul, 10.27),
            (DType::F32, Op::Add, 4.91),
            (DType::F64, Op::Div, 0.16),
        ];
        for (dt, op, paper) in cases {
            let got = throughput_mops(arch, dt, op, 16);
            assert!(
                (got - paper).abs() / paper < 0.06,
                "{dt:?} {op:?}: {got} vs paper {paper}"
            );
        }
    }

    #[test]
    fn linear_scaling_below_saturation() {
        let arch = DpuArch::p21();
        let t1 = throughput_mops(arch, DType::I32, Op::Add, 1);
        let t2 = throughput_mops(arch, DType::I32, Op::Add, 2);
        let t8 = throughput_mops(arch, DType::I32, Op::Add, 8);
        assert!((t2 / t1 - 2.0).abs() < 0.05);
        assert!((t8 / t1 - 8.0).abs() < 0.2);
    }

    #[test]
    fn matches_eq1_model() {
        let arch = DpuArch::p21();
        for dt in [DType::I32, DType::I64] {
            for op in Op::ARITH {
                let got = throughput_mops(arch, dt, op, 16);
                let model = expected_mops(dt, op, 350);
                assert!((got - model).abs() / model < 0.01, "{dt:?} {op:?}");
            }
        }
    }

    #[test]
    fn e19_scales_with_frequency() {
        let p21 = throughput_mops(DpuArch::p21(), DType::I32, Op::Add, 16);
        let e19 = throughput_mops(DpuArch::e19(), DType::I32, Op::Add, 16);
        assert!((p21 / e19 - 350.0 / 267.0).abs() < 0.01);
    }
}
