//! Minimal JSON parsing — enough to read back this repo's own
//! hand-written bench/trace writers (the vendored crate set has no
//! serde). Promoted out of the `perf_gate` binary so the trace
//! subsystem's replay/triage loaders and the gate share one parser.
//!
//! Numbers parse as `f64` via `str::parse`, and every writer in this
//! repo prints floats with Rust's shortest-roundtrip `Display`/`{:e}`
//! formatting — so a parse of our own output recovers **bit-identical**
//! floats, which the trace replay determinism tests rely on.

/// Minimal JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Value>),
    Obj(Vec<(String, Value)>),
}

impl Value {
    /// Object field lookup (None on non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&Value> {
        match self {
            Value::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(v) => Some(v),
            _ => None,
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl Parser<'_> {
    fn ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&mut self) -> Result<u8, String> {
        self.ws();
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| "unexpected end of JSON".into())
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek()? == c {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected '{}' at byte {}, found '{}'",
                c as char, self.i, self.b[self.i] as char
            ))
        }
    }

    fn value(&mut self) -> Result<Value, String> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Value::Str(self.string()?)),
            b't' => self.lit("true", Value::Bool(true)),
            b'f' => self.lit("false", Value::Bool(false)),
            b'n' => self.lit("null", Value::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Value) -> Result<Value, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.b.get(self.i).copied().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    // our writers never escape, but pass basic ones through
                    self.i += 1;
                    let c = self.b.get(self.i).copied().ok_or("bad escape")?;
                    s.push(match c {
                        b'n' => '\n',
                        b't' => '\t',
                        other => other as char,
                    });
                    self.i += 1;
                }
                c => {
                    s.push(c as char);
                    self.i += 1;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Value, String> {
        self.ws();
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>()
            .map(Value::Num)
            .map_err(|_| format!("bad number '{s}' at byte {start}"))
    }

    fn array(&mut self) -> Result<Value, String> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Value::Arr(out));
        }
        loop {
            out.push(self.value()?);
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Value::Arr(out));
                }
                c => return Err(format!("expected ',' or ']', found '{}'", c as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Value, String> {
        self.eat(b'{')?;
        let mut out = Vec::new();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Value::Obj(out));
        }
        loop {
            let k = self.string()?;
            self.eat(b':')?;
            let v = self.value()?;
            out.push((k, v));
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Value::Obj(out));
                }
                c => return Err(format!("expected ',' or '}}', found '{}'", c as char)),
            }
        }
    }
}

/// Parse a complete JSON document (trailing garbage is an error).
pub fn parse_json(s: &str) -> Result<Value, String> {
    let mut p = Parser { b: s.as_bytes(), i: 0 };
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrips_writer_shapes() {
        let v = parse_json(r#"{"a": [1, 2.5e-3, true, null], "s": "x"}"#).unwrap();
        assert_eq!(v.get("s").and_then(Value::as_str), Some("x"));
        let arr = v.get("a").and_then(Value::as_arr).unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.5e-3));
        assert_eq!(arr[2], Value::Bool(true));
        assert_eq!(arr[3], Value::Null);
        assert!(parse_json("[1, 2,]").is_err(), "trailing comma rejected");
        assert!(parse_json("{\"a\": 1} x").is_err(), "trailing garbage rejected");
    }

    #[test]
    fn float_roundtrip_is_bit_identical() {
        for x in [1.0 / 3.0, 2.5e-3, f64::MIN_POSITIVE, 1e300, 0.1 + 0.2] {
            let shortest = format!("{x}");
            let exp = format!("{x:e}");
            for s in [shortest, exp] {
                let v = parse_json(&s).unwrap();
                assert_eq!(v.as_f64().unwrap().to_bits(), x.to_bits(), "{s}");
            }
        }
    }
}
