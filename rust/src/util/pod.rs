//! Minimal plain-old-data casting (the vendored crate set has no bytemuck).
//!
//! Simulated DPU memories (MRAM/WRAM) are stored as `Vec<u64>`-backed byte
//! buffers so that any `Pod` slice view (align ≤ 8) is valid as long as the
//! byte offset is a multiple of the element size — which mirrors the UPMEM
//! SDK's own 8-byte alignment rules for DMA transfers.

/// Types that are safe to reinterpret to/from raw bytes.
///
/// # Safety
/// Implementors must be `repr(C)` scalars with no padding and no invalid bit
/// patterns.
pub unsafe trait Pod: Copy + Send + Sync + 'static {}

unsafe impl Pod for u8 {}
unsafe impl Pod for i8 {}
unsafe impl Pod for u16 {}
unsafe impl Pod for i16 {}
unsafe impl Pod for u32 {}
unsafe impl Pod for i32 {}
unsafe impl Pod for u64 {}
unsafe impl Pod for i64 {}
unsafe impl Pod for f32 {}
unsafe impl Pod for f64 {}

/// View a byte slice as a `&[T]`. Panics on misalignment or ragged length.
pub fn cast_slice<T: Pod>(bytes: &[u8]) -> &[T] {
    let size = std::mem::size_of::<T>();
    assert_eq!(bytes.len() % size, 0, "ragged cast: {} % {}", bytes.len(), size);
    assert_eq!(
        bytes.as_ptr() as usize % std::mem::align_of::<T>(),
        0,
        "misaligned cast"
    );
    // SAFETY: alignment and length checked above; T is Pod.
    unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const T, bytes.len() / size) }
}

/// View a mutable byte slice as a `&mut [T]`.
pub fn cast_slice_mut<T: Pod>(bytes: &mut [u8]) -> &mut [T] {
    let size = std::mem::size_of::<T>();
    assert_eq!(bytes.len() % size, 0, "ragged cast: {} % {}", bytes.len(), size);
    assert_eq!(
        bytes.as_ptr() as usize % std::mem::align_of::<T>(),
        0,
        "misaligned cast"
    );
    // SAFETY: alignment and length checked above; T is Pod.
    unsafe { std::slice::from_raw_parts_mut(bytes.as_mut_ptr() as *mut T, bytes.len() / size) }
}

/// Copy a typed slice into a byte buffer at `off`.
pub fn write_pod_slice<T: Pod>(bytes: &mut [u8], off: usize, src: &[T]) {
    let size = std::mem::size_of::<T>();
    let dst = &mut bytes[off..off + src.len() * size];
    // SAFETY: T is Pod; ranges checked by the slice index above.
    unsafe {
        std::ptr::copy_nonoverlapping(src.as_ptr() as *const u8, dst.as_mut_ptr(), dst.len());
    }
}

/// Read a typed vector out of a byte buffer at `off`.
pub fn read_pod_vec<T: Pod>(bytes: &[u8], off: usize, n: usize) -> Vec<T> {
    let size = std::mem::size_of::<T>();
    let src = &bytes[off..off + n * size];
    let mut out = Vec::<T>::with_capacity(n);
    // SAFETY: T is Pod; `out` capacity is n; src length is n*size.
    unsafe {
        std::ptr::copy_nonoverlapping(src.as_ptr(), out.as_mut_ptr() as *mut u8, src.len());
        out.set_len(n);
    }
    out
}

/// A byte buffer backed by `u64` storage, guaranteeing 8-byte base alignment.
#[derive(Clone, Debug, Default)]
pub struct AlignedBuf {
    words: Vec<u64>,
    len: usize,
}

impl AlignedBuf {
    /// New buffer of `len` zeroed bytes.
    pub fn new(len: usize) -> Self {
        AlignedBuf {
            words: vec![0u64; len.div_ceil(8)],
            len,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Grow (zero-filled) so that at least `len` bytes are addressable.
    pub fn ensure(&mut self, len: usize) {
        if len > self.len {
            self.words.resize(len.div_ceil(8), 0);
            self.len = len;
        }
    }

    pub fn bytes(&self) -> &[u8] {
        // SAFETY: u64 storage reinterpreted as bytes; len <= words*8.
        unsafe { std::slice::from_raw_parts(self.words.as_ptr() as *const u8, self.len) }
    }

    pub fn bytes_mut(&mut self) -> &mut [u8] {
        // SAFETY: as above.
        unsafe { std::slice::from_raw_parts_mut(self.words.as_mut_ptr() as *mut u8, self.len) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_i32() {
        let mut buf = AlignedBuf::new(64);
        write_pod_slice(buf.bytes_mut(), 8, &[1i32, -2, 3, 4]);
        let v: Vec<i32> = read_pod_vec(buf.bytes(), 8, 4);
        assert_eq!(v, vec![1, -2, 3, 4]);
    }

    #[test]
    fn cast_alignment_from_aligned_buf() {
        let mut buf = AlignedBuf::new(32);
        write_pod_slice(buf.bytes_mut(), 0, &[1u64, 2, 3, 4]);
        let s: &[u64] = cast_slice(buf.bytes());
        assert_eq!(s, &[1, 2, 3, 4]);
    }

    #[test]
    fn ensure_grows_zeroed() {
        let mut buf = AlignedBuf::new(8);
        buf.ensure(24);
        assert_eq!(buf.len(), 24);
        assert!(buf.bytes().iter().all(|&b| b == 0));
    }

    #[test]
    fn f64_roundtrip() {
        let mut buf = AlignedBuf::new(16);
        write_pod_slice(buf.bytes_mut(), 0, &[1.5f64, -2.25]);
        let v: Vec<f64> = read_pod_vec(buf.bytes(), 0, 2);
        assert_eq!(v, vec![1.5, -2.25]);
    }

    #[test]
    #[should_panic]
    fn ragged_cast_panics() {
        let b = [0u8; 7];
        let _: &[u32] = cast_slice(&b);
    }
}
