//! Minimal benchmarking harness (the vendored crate set has no criterion).
//!
//! `cargo bench` targets use `harness = false` and drive this: warmup,
//! timed repetitions, median/mean/stddev report, and an optional
//! comparison column. Wall-clock is measured with `std::time::Instant`.

use super::stats::{mean, median, stddev};
use std::time::Instant;

/// One benchmark measurement.
#[derive(Clone, Debug)]
pub struct Sample {
    pub name: String,
    pub secs: Vec<f64>,
    /// Optional work amount for throughput reporting (items per rep).
    pub items: Option<f64>,
}

impl Sample {
    pub fn median(&self) -> f64 {
        median(&self.secs)
    }
}

/// Runner collecting samples.
pub struct Bencher {
    pub samples: Vec<Sample>,
    warmup: usize,
    reps: usize,
}

impl Bencher {
    pub fn new() -> Self {
        // honor a quick mode for CI-ish runs
        let quick = std::env::var("BENCH_QUICK").is_ok();
        Bencher {
            samples: Vec::new(),
            warmup: if quick { 0 } else { 1 },
            reps: if quick { 2 } else { 5 },
        }
    }

    /// Time `f` (called `reps` times after warmup).
    pub fn bench<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> &Sample {
        self.bench_items(name, None, &mut f)
    }

    /// Time `f`, reporting throughput for `items` work items per call.
    pub fn bench_items<R>(
        &mut self,
        name: &str,
        items: Option<f64>,
        f: &mut impl FnMut() -> R,
    ) -> &Sample {
        for _ in 0..self.warmup {
            std::hint::black_box(f());
        }
        let mut secs = Vec::with_capacity(self.reps);
        for _ in 0..self.reps {
            let t0 = Instant::now();
            std::hint::black_box(f());
            secs.push(t0.elapsed().as_secs_f64());
        }
        self.samples.push(Sample {
            name: name.to_string(),
            secs,
            items,
        });
        self.samples.last().unwrap()
    }

    /// Render the collected samples as a JSON array (machine-readable
    /// companion to [`Bencher::report`]; the hot-path bench embeds it in
    /// `results/BENCH_HOTPATH.json` — schema documented in
    /// EXPERIMENTS.md). Names are plain ASCII identifiers, so string
    /// encoding is direct quoting, matching the repro CLI's writers.
    pub fn json_entries(&self) -> String {
        let mut out = String::from("[\n");
        for (i, s) in self.samples.iter().enumerate() {
            let med = median(&s.secs);
            let thr = s
                .items
                .map(|n| format!("{:e}", n / med))
                .unwrap_or_else(|| "null".to_string());
            out.push_str(&format!(
                "    {{\"name\": \"{}\", \"median_secs\": {:e}, \"mean_secs\": {:e}, \
                 \"stddev_secs\": {:e}, \"items_per_sec\": {}}}{}\n",
                s.name,
                med,
                mean(&s.secs),
                stddev(&s.secs),
                thr,
                if i + 1 < self.samples.len() { "," } else { "" },
            ));
        }
        out.push_str("  ]");
        out
    }

    /// Median seconds of the named sample (panics if absent) — for
    /// derived cross-sample figures like speedup ratios.
    pub fn median_of(&self, name: &str) -> f64 {
        self.samples
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("no bench sample named '{name}'"))
            .median()
    }

    /// Print the report table.
    pub fn report(&self, title: &str) {
        println!("\n== bench: {title} ==");
        println!(
            "{:<44} {:>12} {:>12} {:>10} {:>14}",
            "name", "median", "mean", "stddev", "throughput"
        );
        for s in &self.samples {
            let med = median(&s.secs);
            let thr = s
                .items
                .map(|n| format!("{:.2} M/s", n / med / 1e6))
                .unwrap_or_else(|| "-".to_string());
            println!(
                "{:<44} {:>12} {:>12} {:>10} {:>14}",
                s.name,
                fmt_secs(med),
                fmt_secs(mean(&s.secs)),
                fmt_secs(stddev(&s.secs)),
                thr
            );
        }
    }
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

/// Human-readable seconds.
pub fn fmt_secs(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bencher_collects_samples() {
        let mut b = Bencher::new();
        b.bench("noop", || 1 + 1);
        assert_eq!(b.samples.len(), 1);
        assert!(!b.samples[0].secs.is_empty());
    }

    #[test]
    fn json_entries_shape() {
        let mut b = Bencher::new();
        b.bench("alpha", || 1 + 1);
        b.bench_items("beta", Some(1000.0), &mut || 2 + 2);
        let j = b.json_entries();
        assert!(j.starts_with("[\n"), "array form: {j}");
        assert!(j.ends_with(']'));
        assert!(j.contains("\"name\": \"alpha\""));
        assert!(j.contains("\"median_secs\": "));
        assert!(j.contains("\"items_per_sec\": null"), "no items -> null");
        assert!(j.contains("\"name\": \"beta\""));
        // exactly one separating comma between the two entries
        assert_eq!(j.matches("},\n").count(), 1);
        assert!((b.median_of("alpha") - b.samples[0].median()).abs() < 1e-18);
    }

    #[test]
    #[should_panic(expected = "no bench sample named")]
    fn median_of_unknown_panics() {
        Bencher::new().median_of("nope");
    }

    #[test]
    fn fmt_ranges() {
        assert!(fmt_secs(2.0).ends_with(" s"));
        assert!(fmt_secs(2e-3).ends_with("ms"));
        assert!(fmt_secs(2e-6).ends_with("us"));
        assert!(fmt_secs(2e-9).ends_with("ns"));
    }
}
