//! Deterministic xorshift64* RNG.
//!
//! All experiments must be reproducible run-to-run, so every dataset
//! generator takes an explicit seed and uses this generator; nothing in the
//! repository uses OS entropy.

/// xorshift64* — fast, full-period (2^64-1), passes BigCrush on high bits.
#[derive(Clone, Debug)]
pub struct Rng {
    state: u64,
}

impl Rng {
    /// Create a generator from a seed. Seed 0 is remapped (xorshift state
    /// must be non-zero).
    pub fn new(seed: u64) -> Self {
        Rng {
            state: if seed == 0 { 0x9E3779B97F4A7C15 } else { seed },
        }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Next 32-bit value (high bits of the 64-bit output).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` (Lemire's multiply-shift; slight modulo bias
    /// is irrelevant for workload generation).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        if bound == 0 {
            return 0;
        }
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        lo + self.below(hi.saturating_sub(lo))
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Vector of uniform i32 values in [0, bound).
    pub fn vec_i32(&mut self, n: usize, bound: u64) -> Vec<i32> {
        (0..n).map(|_| self.below(bound) as i32).collect()
    }

    /// Vector of uniform i64 values in [0, bound).
    pub fn vec_i64(&mut self, n: usize, bound: u64) -> Vec<i64> {
        (0..n).map(|_| self.below(bound) as i64).collect()
    }

    /// Vector of uniform f32 values in [0, 1).
    pub fn vec_f32(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn zero_seed_remapped() {
        let mut r = Rng::new(0);
        assert_ne!(r.next_u64(), 0);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(42);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn mean_roughly_half() {
        let mut r = Rng::new(11);
        let m: f64 = (0..10_000).map(|_| r.f64()).sum::<f64>() / 10_000.0;
        assert!((m - 0.5).abs() < 0.02, "mean {m}");
    }
}
