//! Shared utilities: deterministic RNG, plain-old-data casts, statistics,
//! synthetic dataset generators, table/CSV output, and a minimal
//! property-based-testing framework (the vendored crate set has no proptest).

pub mod bencher;
pub mod data;
pub mod json;
pub mod pod;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;

pub use pod::{cast_slice, cast_slice_mut, Pod};
pub use rng::Rng;
