//! Aligned-text table and CSV emission for the experiment harness.
//!
//! Every figure/table generator produces a [`Table`]; the harness prints it
//! to stdout (aligned) and writes it to `results/<id>.csv`.

use std::fmt::Write as _;
use std::io::Write as _;
use std::path::Path;

/// A simple column-oriented results table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row of already-formatted cells.
    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Format a float compactly (3 significant-ish decimals).
    pub fn fmt(x: f64) -> String {
        if x == 0.0 {
            "0".into()
        } else if x.abs() >= 1000.0 {
            format!("{x:.0}")
        } else if x.abs() >= 10.0 {
            format!("{x:.2}")
        } else if x.abs() >= 0.01 {
            format!("{x:.4}")
        } else {
            format!("{x:.3e}")
        }
    }

    /// Render as an aligned text table.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let hdr: Vec<String> = self
            .headers
            .iter()
            .enumerate()
            .map(|(i, h)| format!("{:>w$}", h, w = widths[i]))
            .collect();
        let _ = writeln!(out, "{}", hdr.join("  "));
        let _ = writeln!(out, "{}", "-".repeat(hdr.join("  ").len()));
        for r in &self.rows {
            let cells: Vec<String> = r
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:>w$}", c, w = widths[i]))
                .collect();
            let _ = writeln!(out, "{}", cells.join("  "));
        }
        out
    }

    /// CSV encoding (quotes cells containing commas).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        let _ = writeln!(
            out,
            "{}",
            self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(",")
        );
        for r in &self.rows {
            let _ = writeln!(out, "{}", r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
        }
        out
    }

    /// Write `<dir>/<id>.csv`, creating the directory as needed.
    pub fn save_csv(&self, dir: &Path, id: &str) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{id}.csv")))?;
        f.write_all(self.to_csv().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_csv() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let r = t.render();
        assert!(r.contains("demo"));
        assert!(r.contains('1'));
        let csv = t.to_csv();
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(Table::fmt(0.0), "0");
        assert_eq!(Table::fmt(12345.0), "12345");
        assert_eq!(Table::fmt(12.345), "12.35");
    }
}
