//! Small statistics helpers used by the harness and the bench framework.

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean (the paper's cross-benchmark average).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (sorts a copy; `total_cmp` keeps a stray NaN from panicking the
/// comparator — NaNs sort to the top instead).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// 0-based nearest-rank index of percentile `p` (in [0,100]) within a
/// sorted sample of `n` elements — the **single** rank formula behind
/// [`percentile`], [`latency_summary`], and
/// `coordinator::telemetry::Histogram::quantile`, so exact-value and
/// bucketed quantiles agree on shared inputs by construction.
pub fn nearest_rank(n: usize, p: f64) -> usize {
    if n == 0 {
        return 0;
    }
    let r = ((p / 100.0) * (n as f64 - 1.0)).round() as usize;
    r.min(n - 1)
}

/// Percentile in [0,100] by nearest-rank on a sorted copy (NaN-safe via
/// `total_cmp`, like [`median`]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    v[nearest_rank(v.len(), p)]
}

/// The latency percentiles QoS reports quote (scheduler per-tenant lines,
/// `BENCH_SCHED.json`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

/// Summarize a latency sample into p50/p95/p99/max with a single sort.
pub fn latency_summary(xs: &[f64]) -> LatencySummary {
    if xs.is_empty() {
        return LatencySummary::default();
    }
    let mut v = xs.to_vec();
    v.sort_by(f64::total_cmp);
    let rank = |p: f64| v[nearest_rank(v.len(), p)];
    LatencySummary {
        p50: rank(50.0),
        p95: rank(95.0),
        p99: rank(99.0),
        max: v[v.len() - 1],
    }
}

/// Ordinary least squares fit `y = a + b*x`; returns (a, b, r2).
///
/// Used to validate the simulator's MRAM latency against the paper's linear
/// model (Eq. 3) in tests and in the Fig. 6 harness.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let mx = mean(x);
    let my = mean(y);
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let ss_res: f64 = x
        .iter()
        .zip(y)
        .map(|(xi, yi)| {
            let e = yi - (a + b * xi);
            e * e
        })
        .sum();
    let ss_tot: f64 = y.iter().map(|yi| (yi - my) * (yi - my)).sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    (a, b, r2 * n / n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0, 16.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn mean_median() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn fit_exact_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 2.0 * v).collect();
        let (a, b, r2) = linear_fit(&x, &y);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn stddev_constant_zero() {
        assert_eq!(stddev(&[2.0, 2.0, 2.0]), 0.0);
    }

    #[test]
    fn median_and_percentile_survive_nan() {
        // a stray NaN must not panic the sort; total_cmp puts it last
        let xs = [1.0, f64::NAN, 2.0, 3.0];
        let _ = median(&xs);
        let _ = percentile(&xs, 50.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
    }

    #[test]
    fn nearest_rank_bounds() {
        assert_eq!(nearest_rank(0, 50.0), 0);
        assert_eq!(nearest_rank(1, 99.0), 0);
        assert_eq!(nearest_rank(101, 50.0), 50);
        assert_eq!(nearest_rank(101, 99.0), 99);
        assert_eq!(nearest_rank(5, 100.0), 4);
        assert_eq!(nearest_rank(5, 200.0), 4, "out-of-range p clamps");
    }

    #[test]
    fn latency_summary_percentiles() {
        // 101 samples: rank(p) = p/100 * 100 is exact, no rounding edge
        let xs: Vec<f64> = (0..=100).map(|i| i as f64).collect();
        let s = latency_summary(&xs);
        assert_eq!(s.p50, 50.0);
        assert_eq!(s.p95, 95.0);
        assert_eq!(s.p99, 99.0);
        assert_eq!(s.max, 100.0);
        assert_eq!(latency_summary(&[]), LatencySummary::default());
        let one = latency_summary(&[7.0]);
        assert_eq!((one.p50, one.max), (7.0, 7.0));
    }
}
