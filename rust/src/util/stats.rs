//! Small statistics helpers used by the harness and the bench framework.

/// Arithmetic mean.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean (the paper's cross-benchmark average).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Sample standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (sorts a copy).
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Percentile in [0,100] by nearest-rank on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = ((p / 100.0) * (v.len() as f64 - 1.0)).round() as usize;
    v[rank.min(v.len() - 1)]
}

/// Ordinary least squares fit `y = a + b*x`; returns (a, b, r2).
///
/// Used to validate the simulator's MRAM latency against the paper's linear
/// model (Eq. 3) in tests and in the Fig. 6 harness.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    if x.is_empty() {
        return (0.0, 0.0, 0.0);
    }
    let mx = mean(x);
    let my = mean(y);
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let b = if sxx == 0.0 { 0.0 } else { sxy / sxx };
    let a = my - b * mx;
    let ss_res: f64 = x
        .iter()
        .zip(y)
        .map(|(xi, yi)| {
            let e = yi - (a + b * xi);
            e * e
        })
        .sum();
    let ss_tot: f64 = y.iter().map(|yi| (yi - my) * (yi - my)).sum();
    let r2 = if ss_tot == 0.0 { 1.0 } else { 1.0 - ss_res / ss_tot };
    (a, b, r2 * n / n)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn geomean_of_powers() {
        assert!((geomean(&[1.0, 4.0, 16.0]) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn mean_median() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(median(&[4.0, 1.0, 2.0, 3.0]), 2.5);
    }

    #[test]
    fn fit_exact_line() {
        let x: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 + 2.0 * v).collect();
        let (a, b, r2) = linear_fit(&x, &y);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b - 2.0).abs() < 1e-9);
        assert!(r2 > 0.999999);
    }

    #[test]
    fn percentile_bounds() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn stddev_constant_zero() {
        assert_eq!(stddev(&[2.0, 2.0, 2.0]), 0.0);
    }
}
