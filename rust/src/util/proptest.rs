//! Minimal property-based testing framework (the vendored crate set has no
//! proptest/quickcheck).
//!
//! Usage (`no_run`: doctest binaries lack the rpath to the parked
//! libstdc++ that the linked xla crate needs; the same property runs as a
//! regular test below):
//! ```no_run
//! use prim_pim::util::proptest::{props, Gen};
//! props("sum is commutative", 100, |g: &mut Gen| {
//!     let a = g.vec_i64(0..64, -100..100);
//!     let mut b = a.clone();
//!     b.reverse();
//!     let s1: i64 = a.iter().sum();
//!     let s2: i64 = b.iter().sum();
//!     assert_eq!(s1, s2);
//! });
//! ```
//!
//! Each case runs with a deterministic seed derived from the property name,
//! so failures reproduce; on panic the failing case index and seed are
//! reported.

use super::rng::Rng;
use std::ops::Range;

/// Random-input generator handed to each property case.
pub struct Gen {
    rng: Rng,
    /// Case index (0..n), usable for size-scaling inputs.
    pub case: usize,
}

impl Gen {
    /// usize in range.
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        self.rng.range(r.start as u64, r.end as u64) as usize
    }

    /// i64 in range.
    pub fn i64_in(&mut self, r: Range<i64>) -> i64 {
        let span = (r.end - r.start) as u64;
        r.start + self.rng.below(span) as i64
    }

    /// f64 in [0,1).
    pub fn f64(&mut self) -> f64 {
        self.rng.f64()
    }

    pub fn bool(&mut self) -> bool {
        self.rng.chance(0.5)
    }

    /// Vector with random length in `len` and elements in `vals`.
    pub fn vec_i64(&mut self, len: Range<usize>, vals: Range<i64>) -> Vec<i64> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.i64_in(vals.clone())).collect()
    }

    /// Vector of i32.
    pub fn vec_i32(&mut self, len: Range<usize>, vals: Range<i64>) -> Vec<i32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.i64_in(vals.clone()) as i32).collect()
    }

    /// Vector of f32 in [0,1).
    pub fn vec_f32(&mut self, len: Range<usize>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.rng.f32()).collect()
    }

    /// Access the raw RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// FNV-1a hash of the property name — the seed base.
fn fnv(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Run `n` randomized cases of a property. Panics (with case/seed info) on
/// the first failing case.
pub fn props<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(name: &str, n: usize, f: F) {
    let base = fnv(name);
    for case in 0..n {
        let seed = base.wrapping_add(0x9E3779B97F4A7C15u64.wrapping_mul(case as u64 + 1));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen {
                rng: Rng::new(seed),
                case,
            };
            f(&mut g);
        });
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = std::sync::atomic::AtomicUsize::new(0);
        props("count", 25, |_g| {
            // cannot capture &mut through RefUnwindSafe; use raw pointer trick
        });
        *count.get_mut() += 25;
        assert_eq!(count.into_inner(), 25);
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn failing_property_reports() {
        props("always fails", 5, |_g| {
            panic!("boom");
        });
    }

    #[test]
    fn gen_ranges_respected() {
        props("ranges", 50, |g| {
            let x = g.usize_in(3..9);
            assert!((3..9).contains(&x));
            let v = g.vec_i64(0..10, -5..5);
            assert!(v.len() < 10);
            assert!(v.iter().all(|&e| (-5..5).contains(&e)));
        });
    }
}
