//! Synthetic dataset generators with the statistics of the paper's inputs.
//!
//! The paper uses external datasets we cannot download (bcsstk30 from
//! Matrix Market, loc-gowalla from SNAP, a van Hateren natural image). Each
//! generator below matches the *property that the kernel is sensitive to*:
//! sparsity structure (banded SPD pattern), degree skew (rMat power law —
//! which the paper itself uses for BFS weak scaling), and pixel-value
//! distribution (natural images are low-entropy / bimodal).

use super::rng::Rng;

/// CSR sparse matrix (f32 values), the format used by SpMV and BFS.
#[derive(Clone, Debug)]
pub struct Csr {
    pub n_rows: usize,
    pub n_cols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    pub fn nnz(&self) -> usize {
        self.col_idx.len()
    }

    /// Dense reference SpMV: y = A * x.
    pub fn spmv_ref(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.n_cols);
        let mut y = vec![0f32; self.n_rows];
        for r in 0..self.n_rows {
            let (s, e) = (self.row_ptr[r] as usize, self.row_ptr[r + 1] as usize);
            let mut acc = 0f32;
            for k in s..e {
                acc += self.values[k] * x[self.col_idx[k] as usize];
            }
            y[r] = acc;
        }
        y
    }
}

/// Banded symmetric-positive-definite-like pattern, the structure class of
/// bcsstk30 (a stiffness matrix: dense band around the diagonal with
/// irregular row population). `band` is the half-bandwidth; `fill` the
/// expected fraction of in-band entries present.
pub fn banded_matrix(n: usize, band: usize, fill: f64, seed: u64) -> Csr {
    let mut rng = Rng::new(seed);
    let mut row_ptr = Vec::with_capacity(n + 1);
    let mut col_idx = Vec::new();
    let mut values = Vec::new();
    row_ptr.push(0u32);
    for r in 0..n {
        let lo = r.saturating_sub(band);
        let hi = (r + band + 1).min(n);
        for c in lo..hi {
            if c == r || rng.chance(fill) {
                col_idx.push(c as u32);
                values.push(rng.f32() * 2.0 - 1.0);
            }
        }
        row_ptr.push(col_idx.len() as u32);
    }
    Csr {
        n_rows: n,
        n_cols: n,
        row_ptr,
        col_idx,
        values,
    }
}

/// Unweighted directed graph in CSR (adjacency) form for BFS.
#[derive(Clone, Debug)]
pub struct Graph {
    pub n_vertices: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
}

impl Graph {
    pub fn n_edges(&self) -> usize {
        self.col_idx.len()
    }

    /// Reference BFS distances from `src` (u32::MAX = unreachable).
    pub fn bfs_ref(&self, src: usize) -> Vec<u32> {
        let mut dist = vec![u32::MAX; self.n_vertices];
        let mut frontier = vec![src];
        dist[src] = 0;
        let mut level = 0u32;
        while !frontier.is_empty() {
            level += 1;
            let mut next = Vec::new();
            for &v in &frontier {
                let (s, e) = (self.row_ptr[v] as usize, self.row_ptr[v + 1] as usize);
                for &w in &self.col_idx[s..e] {
                    if dist[w as usize] == u32::MAX {
                        dist[w as usize] = level;
                        next.push(w as usize);
                    }
                }
            }
            frontier = next;
        }
        dist
    }
}

/// R-MAT power-law graph (the generator the paper itself uses for BFS weak
/// scaling): recursive quadrant selection with probabilities (a,b,c,d) =
/// (0.57, 0.19, 0.19, 0.05), deduplicated, symmetrized like loc-gowalla
/// (an undirected friendship graph).
pub fn rmat_graph(n_vertices: usize, n_edges: usize, seed: u64) -> Graph {
    let scale = (n_vertices.max(2) as f64).log2().ceil() as u32;
    let mut rng = Rng::new(seed);
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(2 * n_edges);
    for _ in 0..n_edges {
        let (mut u, mut v) = (0usize, 0usize);
        for _ in 0..scale {
            let r = rng.f64();
            let (du, dv) = if r < 0.57 {
                (0, 0)
            } else if r < 0.76 {
                (0, 1)
            } else if r < 0.95 {
                (1, 0)
            } else {
                (1, 1)
            };
            u = (u << 1) | du;
            v = (v << 1) | dv;
        }
        let (u, v) = (u % n_vertices.max(1), v % n_vertices.max(1));
        if u != v {
            edges.push((u as u32, v as u32));
            edges.push((v as u32, u as u32));
        }
    }
    edges.sort_unstable();
    edges.dedup();
    let mut row_ptr = vec![0u32; n_vertices + 1];
    for &(u, _) in &edges {
        row_ptr[u as usize + 1] += 1;
    }
    for i in 0..n_vertices {
        row_ptr[i + 1] += row_ptr[i];
    }
    let col_idx = edges.iter().map(|&(_, v)| v).collect();
    Graph {
        n_vertices,
        row_ptr,
        col_idx,
    }
}

/// Synthetic "natural image" pixel stream: mixture of two broad Gaussians
/// (sky/ground bimodality of the van Hateren set), clamped to the sensor
/// depth. `depth_bits` ≤ 16; HST bins index these values.
pub fn natural_image(n_pixels: usize, depth_bits: u32, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    let max = (1u32 << depth_bits) - 1;
    let mut px = Vec::with_capacity(n_pixels);
    for _ in 0..n_pixels {
        let (mu, sigma) = if rng.chance(0.6) {
            (0.3, 0.12)
        } else {
            (0.7, 0.15)
        };
        // Box–Muller
        let u1 = rng.f64().max(1e-12);
        let u2 = rng.f64();
        let g = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        let v = ((mu + sigma * g).clamp(0.0, 1.0) * max as f64) as u32;
        px.push(v);
    }
    px
}

/// Sorted i64 array + query values for binary search.
pub fn sorted_with_queries(n: usize, n_queries: usize, seed: u64) -> (Vec<i64>, Vec<i64>) {
    let mut rng = Rng::new(seed);
    // strictly increasing so every element is found at a unique position
    let mut arr = Vec::with_capacity(n);
    let mut v = 0i64;
    for _ in 0..n {
        v += 1 + rng.below(4) as i64;
        arr.push(v);
    }
    let queries = (0..n_queries).map(|_| arr[rng.below(n as u64) as usize]).collect();
    (arr, queries)
}

/// Random-walk time series (matrix-profile workloads are run on physiological
/// / sensor random-walk-like signals) as i32, plus a query drawn from it.
pub fn time_series(n: usize, query_len: usize, seed: u64) -> (Vec<i32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let mut ts = Vec::with_capacity(n);
    let mut v: i64 = 0;
    for _ in 0..n {
        v += rng.below(201) as i64 - 100;
        ts.push(v.clamp(i32::MIN as i64, i32::MAX as i64) as i32);
    }
    let start = rng.below((n - query_len) as u64) as usize;
    let query = ts[start..start + query_len].to_vec();
    (ts, query)
}

/// DNA-like sequences (values 0..4) for Needleman–Wunsch.
pub fn dna_pair(len_a: usize, len_b: usize, seed: u64) -> (Vec<u8>, Vec<u8>) {
    let mut rng = Rng::new(seed);
    let a: Vec<u8> = (0..len_a).map(|_| rng.below(4) as u8).collect();
    // b = a with ~20% point mutations, so alignment is meaningful
    let b: Vec<u8> = (0..len_b)
        .map(|i| {
            if i < a.len() && !rng.chance(0.2) {
                a[i]
            } else {
                rng.below(4) as u8
            }
        })
        .collect();
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banded_structure() {
        let m = banded_matrix(100, 8, 0.5, 1);
        assert_eq!(m.row_ptr.len(), 101);
        assert_eq!(m.row_ptr[100] as usize, m.nnz());
        // diagonal always present, entries within band
        for r in 0..100usize {
            let (s, e) = (m.row_ptr[r] as usize, m.row_ptr[r + 1] as usize);
            assert!(m.col_idx[s..e].contains(&(r as u32)));
            for &c in &m.col_idx[s..e] {
                assert!((c as i64 - r as i64).unsigned_abs() <= 8);
            }
        }
    }

    #[test]
    fn spmv_ref_identity_band() {
        // band 0, fill 0 -> diagonal matrix
        let m = banded_matrix(10, 0, 0.0, 2);
        let x: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let y = m.spmv_ref(&x);
        for i in 0..10 {
            assert!((y[i] - m.values[i] * x[i]).abs() < 1e-6);
        }
    }

    #[test]
    fn rmat_valid_csr() {
        let g = rmat_graph(256, 2048, 3);
        assert_eq!(g.row_ptr.len(), 257);
        assert_eq!(*g.row_ptr.last().unwrap() as usize, g.n_edges());
        for &c in &g.col_idx {
            assert!((c as usize) < 256);
        }
        // power-law-ish: max degree well above mean
        let degs: Vec<u32> = (0..256).map(|v| g.row_ptr[v + 1] - g.row_ptr[v]).collect();
        let max = *degs.iter().max().unwrap() as f64;
        let mean = g.n_edges() as f64 / 256.0;
        assert!(max > 2.0 * mean, "max {max} mean {mean}");
    }

    #[test]
    fn bfs_ref_line_graph() {
        // path 0-1-2-3
        let g = Graph {
            n_vertices: 4,
            row_ptr: vec![0, 1, 3, 5, 6],
            col_idx: vec![1, 0, 2, 1, 3, 2],
        };
        assert_eq!(g.bfs_ref(0), vec![0, 1, 2, 3]);
    }

    #[test]
    fn image_in_depth() {
        let px = natural_image(1000, 8, 4);
        assert!(px.iter().all(|&p| p < 256));
    }

    #[test]
    fn sorted_queries_found() {
        let (arr, qs) = sorted_with_queries(1000, 50, 5);
        assert!(arr.windows(2).all(|w| w[0] < w[1]));
        for q in qs {
            assert!(arr.binary_search(&q).is_ok());
        }
    }

    #[test]
    fn dna_alphabet() {
        let (a, b) = dna_pair(64, 64, 6);
        assert!(a.iter().chain(b.iter()).all(|&c| c < 4));
    }
}
