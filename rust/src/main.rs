//! `repro` — the PrIM-RS experiment driver.
//!
//! Subcommands:
//! ```text
//! repro list                         list regenerable tables/figures
//! repro table <id>                   print Table 1-4
//! repro figure <id> [--quick]        regenerate a paper figure
//! repro micro                        all §3 microbenchmark figures (4-10)
//! repro prim [--bench N] [--dpus D] [--tasklets T] [--scale S]
//!            [--executor serial|parallel] [--threads N]
//!            [--json] [--quick]      --json writes BENCH_PRIM.json
//! repro prim --overlap [--requests R] [--json] [--quick]
//!            sync vs async command queues per workload; --json writes
//!            BENCH_OVERLAP.json
//! repro serve --bench N [--requests R] [--pipeline] [--dpus D]
//!            [--tasklets T] [--scale S]   persistent-session serving
//! repro sched [--tenants "gemv:2,bs:1,va:1"] [--requests N]
//!            [--policy fifo|wrr|sjf] [--rate R] [--batch B] [--pipeline]
//!            [--json] [--quick]      multi-tenant rank-sliced scheduling
//! repro sched --elastic [depth|latency] [--shift t:at:factor]
//!            live rank reallocation with modeled state migration;
//!            --shift multiplies tenant t's arrival rate by `factor`
//!            from modeled second `at`; --json writes BENCH_ELASTIC.json
//! repro compare [--quick]            Fig. 16 + Fig. 17
//! repro estimate --dpus N            fleet estimator via the PJRT artifact
//! repro trace [--bench N] [--requests R] [--json]   traced pipelined
//!            serving + hotspot triage; or --load <trace.v1.json> to
//!            triage a recorded trace
//! repro trace --diff <a.v1.json> <b.v1.json> [--top K] [--json]
//!            compare two recorded traces: per-lane busy deltas and the
//!            top-K events whose placement moved
//! repro cluster [--machines N] [--bench B] [--dpus D] [--tasklets T]
//!            [--scale S] [--json] [--quick]   sharded GEMV/SpMV/BFS/MLP
//!            over a modeled multi-machine fleet with network
//!            collectives; --json writes BENCH_CLUSTER.json
//! repro metrics [--load <metrics.json>] [--json] [--slo-p99 S]
//!            [--slo-rps R]           per-tenant SLO health (latency,
//!            throughput, energy) over a metrics/v1 snapshot; without
//!            --load, runs the default sched mix with live telemetry
//! repro all [--quick]                everything, CSVs into --outdir
//! ```
//! All outputs land in `--outdir` (default `results/`). The global
//! `--seed S` flag (default 42) drives dataset synthesis *and* traffic
//! generation for `prim`, `serve`, and `sched`; harness tables/figures
//! pin their own seeds so regenerated artifacts stay comparable.
//!
//! The global `--trace [path]` flag (on `prim`, `serve`, and `sched`)
//! records the modeled timeline of every operation into a Chrome-trace
//! JSON at `path` (default `<outdir>/trace.json`; load it in Perfetto
//! or `chrome://tracing`) plus a compact native `trace/v1` sibling at
//! `<path minus .json>.v1.json` (the form `repro trace --load` and the
//! replay engine consume). See `coordinator::trace`.
//!
//! The global `--metrics [path]` flag records every run's labeled
//! telemetry (counters, gauges, histograms, simulated-time series; see
//! `coordinator::telemetry`) into a native `metrics/v1` JSON at `path`
//! (default `<outdir>/BENCH_METRICS.json`) plus a Prometheus
//! text-exposition sibling at `<path minus .json>.prom` (the form
//! `repro metrics --load` consumes).

use prim_pim::arch::SystemConfig;
use prim_pim::coordinator::trace::{analyze, diff_traces};
use prim_pim::coordinator::{
    parse_metrics, parse_trace, run_sched, ElasticConfig, ElasticPolicyKind, ExecChoice,
    LoadShift, PolicyKind, ReplayEngine, SchedConfig, SloMonitor, SloTarget, Telemetry,
    TenantSpec, TraceSink,
};
use prim_pim::harness::{self, ALL_IDS};
use prim_pim::prim::common::{all_benches, bench_by_name, BenchResult, RunConfig};
use prim_pim::prim::scaleout::{run_bench as run_scaleout, ScaleoutConfig, SCALEOUT_BENCHES};
use prim_pim::prim::workload::{serve, workload_by_name};
use prim_pim::runtime;
use std::path::{Path, PathBuf};

struct Args {
    flags: std::collections::HashMap<String, String>,
    positional: Vec<String>,
}

fn parse_args(argv: &[String]) -> Args {
    let mut flags = std::collections::HashMap::new();
    let mut positional = Vec::new();
    let mut i = 0;
    while i < argv.len() {
        let a = &argv[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                flags.insert(name.to_string(), argv[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positional.push(a.clone());
            i += 1;
        }
    }
    Args { flags, positional }
}

impl Args {
    /// Typed flag lookup. A *present but unparsable* value is a hard error
    /// (exit 2), matching the `--executor` validation — `--dpus abc` must
    /// not silently fall back to the default.
    fn flag<T: std::str::FromStr>(&self, name: &str, default: T) -> T {
        match self.flags.get(name) {
            None => default,
            Some(v) => v.parse().unwrap_or_else(|_| {
                eprintln!(
                    "invalid value '{v}' for --{name} (expected a {})",
                    std::any::type_name::<T>()
                );
                std::process::exit(2);
            }),
        }
    }

    fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    /// Fleet executor resolution: CLI flags win, else
    /// `PRIM_EXECUTOR`/`PRIM_THREADS`. Parsing is strict everywhere — a
    /// typo'd `--executor`, `--threads`, or env value exits 2 instead of
    /// silently selecting the parallel default.
    fn exec_choice(&self) -> ExecChoice {
        if self.has("executor") || self.has("threads") {
            ExecChoice::parse(
                self.flags.get("executor").map(String::as_str),
                self.flags.get("threads").map(String::as_str),
            )
            .unwrap_or_else(|e| {
                eprintln!("--executor/--threads: {e}");
                std::process::exit(2);
            })
        } else {
            ExecChoice::Auto
        }
    }
}

fn usage() -> ! {
    eprintln!(
        "usage: repro <list|table|figure|micro|prim|serve|sched|trace|cluster|metrics|compare|estimate|all> \
         [--seed S] [--trace [path]] [--metrics [path]] [args]\n\
         run `repro list` for the experiment index"
    );
    std::process::exit(2);
}

/// System picked from the DPU count: one rank up to 64, else the
/// 2,556-DPU machine.
fn system_for(n_dpus: u32) -> SystemConfig {
    if n_dpus <= 64 {
        SystemConfig::p21_rank()
    } else {
        SystemConfig::p21_2556()
    }
}

/// Escape nothing fancy: our names are plain ASCII identifiers, so JSON
/// string encoding is direct quoting.
fn bench_results_json(results: &[BenchResult]) -> String {
    let mut out = String::from("[\n");
    for (i, r) in results.iter().enumerate() {
        let b = &r.breakdown;
        out.push_str(&format!(
            "  {{\"name\": \"{}\", \"verified\": {}, \"work_items\": {}, \"dpu_instrs\": {},\n   \
             \"dpu_secs\": {:e}, \"inter_dpu_secs\": {:e}, \"cpu_dpu_secs\": {:e}, \
             \"dpu_cpu_secs\": {:e}, \"total_secs\": {:e},\n   \
             \"bytes_to_dpu\": {}, \"bytes_from_dpu\": {}, \"bytes_inter\": {}, \
             \"launches\": {}}}{}\n",
            r.name,
            r.verified,
            r.work_items,
            r.dpu_instrs,
            b.dpu,
            b.inter_dpu,
            b.cpu_dpu,
            b.dpu_cpu,
            b.total(),
            b.bytes_to_dpu,
            b.bytes_from_dpu,
            b.bytes_inter,
            b.launches,
            if i + 1 < results.len() { "," } else { "" },
        ));
    }
    out.push_str("]\n");
    out
}

fn write_bench_json(outdir: &Path, results: &[BenchResult]) -> anyhow::Result<()> {
    std::fs::create_dir_all(outdir)?;
    let path = outdir.join("BENCH_PRIM.json");
    std::fs::write(&path, bench_results_json(results))?;
    println!("wrote {}", path.display());
    Ok(())
}

/// Resolve the `--trace [path]` flag: bare `--trace` defaults to
/// `<outdir>/trace.json`.
fn trace_path(args: &Args, outdir: &Path) -> Option<PathBuf> {
    let v = args.flags.get("trace")?;
    if v == "true" {
        Some(outdir.join("trace.json"))
    } else {
        Some(PathBuf::from(v))
    }
}

/// Resolve the `--metrics [path]` flag: bare `--metrics` defaults to
/// `<outdir>/BENCH_METRICS.json`.
fn metrics_path(args: &Args, outdir: &Path) -> Option<PathBuf> {
    let v = args.flags.get("metrics")?;
    if v == "true" {
        Some(outdir.join("BENCH_METRICS.json"))
    } else {
        Some(PathBuf::from(v))
    }
}

/// Export a captured metrics registry: native `metrics/v1` at `path`
/// plus a Prometheus text-exposition sibling at `<path minus .json>.prom`.
fn write_metrics(path: &Path, tel: &Telemetry) -> anyhow::Result<()> {
    let snap = tel.snapshot();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, snap.to_json())?;
    let s = path.to_string_lossy();
    let prom = PathBuf::from(match s.strip_suffix(".json") {
        Some(stem) => format!("{stem}.prom"),
        None => format!("{s}.prom"),
    });
    std::fs::write(&prom, snap.to_prometheus())?;
    println!(
        "wrote {} ({} metrics) and {}",
        path.display(),
        snap.entries.len(),
        prom.display()
    );
    Ok(())
}

/// Export a captured trace: Chrome-trace JSON at `path` (Perfetto /
/// `chrome://tracing`), native `trace/v1` at `<path minus .json>.v1.json`.
fn write_trace(path: &Path, sink: &TraceSink) -> anyhow::Result<()> {
    let trace = sink.snapshot();
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, trace.to_chrome_json())?;
    let s = path.to_string_lossy();
    let native = PathBuf::from(match s.strip_suffix(".json") {
        Some(stem) => format!("{stem}.v1.json"),
        None => format!("{s}.v1.json"),
    });
    std::fs::write(&native, trace.to_json())?;
    println!(
        "wrote {} ({} events, {} source) and {}",
        path.display(),
        trace.events.len(),
        trace.source,
        native.display()
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        usage();
    }
    let cmd = argv[0].as_str();
    let args = parse_args(&argv[1..]);
    let outdir = PathBuf::from(args.flag("outdir", "results".to_string()));
    let quick = args.has("quick");
    // global seed: one flag drives dataset synthesis AND traffic
    // generation, so any run is reproducible from the command line
    let seed: u64 = args.flag("seed", 42);
    // global trace capture: one sink threads through every RunConfig /
    // SchedConfig the subcommand builds; exported after the run
    let trace_out = trace_path(&args, &outdir);
    let trace_sink = trace_out.as_ref().map(|_| TraceSink::new());
    // global metrics capture: one registry threads through every
    // RunConfig / SchedConfig the subcommand builds; exported after the
    // run as metrics/v1 JSON + Prometheus text
    let metrics_out = metrics_path(&args, &outdir);
    let metrics_sink = metrics_out.as_ref().map(|_| Telemetry::new());

    match cmd {
        "list" => {
            println!("regenerable experiments (DESIGN.md §4):");
            for id in ALL_IDS {
                println!("  {id}");
            }
        }
        "table" | "figure" => {
            let id = args.positional.first().map(|s| s.as_str()).unwrap_or_else(|| usage());
            harness::run_id(id, &outdir, quick)?;
        }
        "micro" => {
            for id in ["fig4", "fig5", "fig6", "fig7", "fig8", "fig9", "fig10"] {
                harness::run_id(id, &outdir, quick)?;
            }
        }
        "prim" => {
            let benches: Vec<Box<dyn prim_pim::prim::PrimBench>> =
                if let Some(name) = args.flags.get("bench") {
                    vec![bench_by_name(name)
                        .unwrap_or_else(|| panic!("unknown benchmark {name}"))]
                } else {
                    all_benches()
                };
            let n_dpus: u32 = args.flag("dpus", 64);
            let sys = system_for(n_dpus);
            let exec = args.exec_choice();
            // --quick shrinks every dataset 20× below the harness scale
            // (the CI smoke setting behind the BENCH_PRIM.json artifact)
            let scale_factor = if quick { 0.05 } else { 1.0 };
            if args.has("overlap") {
                // async-mode smoke: serve each workload twice — serialized
                // vs async command queues — and report the derived overlap.
                // Defaults to the serving-shaped subset (the streaming
                // workloads with fence-style merges gain nothing and NW's
                // per-diagonal command count is pathological); --bench
                // narrows to one workload.
                let names: Vec<String> = if args.flags.contains_key("bench") {
                    benches.iter().map(|b| b.name().to_string()).collect()
                } else {
                    ["VA", "GEMV", "MLP", "BS", "TS", "BFS", "TRNS"]
                        .iter()
                        .map(|s| s.to_string())
                        .collect()
                };
                let requests: usize = args.flag("requests", if quick { 2 } else { 4 });
                let mut rows = String::from("[\n");
                for (i, name) in names.iter().enumerate() {
                    let w = workload_by_name(name)
                        .unwrap_or_else(|| panic!("unknown benchmark {name}"));
                    let rc = RunConfig {
                        n_dpus,
                        n_tasklets: args.flag("tasklets", w.best_tasklets()),
                        scale: args
                            .flag("scale", harness::harness_scale(w.name()) * scale_factor),
                        seed,
                        sys: sys.clone(),
                        exec,
                        trace: trace_sink.clone(),
                        metrics: metrics_sink.clone(),
                    };
                    let t0 = std::time::Instant::now();
                    let ser = serve(w.as_ref(), &rc, requests, false);
                    let asy = serve(w.as_ref(), &rc, requests, true);
                    println!(
                        "{:<9} [{}] sync {:>9.3} ms | async {:>9.3} ms | hidden {:>8.3} ms | \
                         sim wall {:.2}s",
                        ser.name,
                        if ser.verified && asy.verified { "ok" } else { "VERIFY-FAIL" },
                        ser.warm.total() * 1e3,
                        asy.warm.total() * 1e3,
                        asy.warm.overlapped * 1e3,
                        t0.elapsed().as_secs_f64(),
                    );
                    rows.push_str(&format!(
                        "  {{\"name\": \"{}\", \"verified\": {}, \"requests\": {}, \
                         \"cold_secs\": {:e}, \"sync_warm_secs\": {:e}, \
                         \"async_warm_secs\": {:e}, \"overlapped_secs\": {:e}}}{}\n",
                        ser.name,
                        ser.verified && asy.verified,
                        requests,
                        ser.cold.total(),
                        ser.warm.total(),
                        asy.warm.total(),
                        asy.warm.overlapped,
                        if i + 1 < names.len() { "," } else { "" },
                    ));
                }
                rows.push_str("]\n");
                if args.has("json") {
                    std::fs::create_dir_all(&outdir)?;
                    let path = outdir.join("BENCH_OVERLAP.json");
                    std::fs::write(&path, rows)?;
                    println!("wrote {}", path.display());
                }
                return Ok(());
            }
            let mut results: Vec<BenchResult> = Vec::new();
            for b in benches {
                let rc = RunConfig {
                    n_dpus,
                    n_tasklets: args.flag("tasklets", b.best_tasklets()),
                    scale: args.flag("scale", harness::harness_scale(b.name()) * scale_factor),
                    seed,
                    sys: sys.clone(),
                    exec,
                    trace: trace_sink.clone(),
                    metrics: metrics_sink.clone(),
                };
                let t0 = std::time::Instant::now();
                let r = b.run(&rc);
                println!(
                    "{:<9} [{}] {} | {} items | sim wall {:.2}s",
                    r.name,
                    if r.verified { "ok" } else { "VERIFY-FAIL" },
                    r.breakdown.fmt_ms(),
                    r.work_items,
                    t0.elapsed().as_secs_f64(),
                );
                results.push(r);
            }
            if args.has("json") {
                write_bench_json(&outdir, &results)?;
            }
        }
        "serve" => {
            let name = args.flags.get("bench").cloned().unwrap_or_else(|| {
                eprintln!("serve requires --bench <name> (e.g. --bench BS)");
                std::process::exit(2);
            });
            let w = workload_by_name(&name).unwrap_or_else(|| {
                eprintln!("unknown benchmark {name}");
                std::process::exit(2);
            });
            let n_requests: usize = args.flag("requests", 8);
            let pipeline = args.has("pipeline");
            let n_dpus: u32 = args.flag("dpus", 64);
            let rc = RunConfig {
                n_dpus,
                n_tasklets: args.flag("tasklets", w.best_tasklets()),
                scale: args.flag("scale", harness::harness_scale(w.name())),
                seed,
                sys: system_for(n_dpus),
                exec: args.exec_choice(),
                trace: trace_sink.clone(),
                metrics: metrics_sink.clone(),
            };
            let t0 = std::time::Instant::now();
            let rep = serve(w.as_ref(), &rc, n_requests, pipeline);
            println!(
                "{} · {} DPUs · {} requests · {} schedule · [{}]",
                rep.name,
                n_dpus,
                n_requests,
                if pipeline { "pipelined" } else { "serialized" },
                if rep.verified { "ok" } else { "VERIFY-FAIL" },
            );
            println!("cold load : {}", rep.cold.fmt_ms());
            for (i, r) in rep.requests.iter().enumerate() {
                println!("request {i:>2}: {}", r.fmt_ms());
            }
            let steady = rep.steady_state();
            println!("steady    : {}", steady.fmt_ms());
            let amortized = rep.cold.total() + rep.warm.total();
            let oneshot = (rep.cold.total() + steady.total()) * n_requests as f64;
            println!(
                "warm total {:.3} ms (overlap hidden {:.3} ms) | cold+warm {:.3} ms vs {:.3} ms \
                 for {} one-shot runs ({:.2}x)",
                rep.warm.total() * 1e3,
                rep.warm.overlapped * 1e3,
                amortized * 1e3,
                oneshot * 1e3,
                n_requests,
                oneshot / amortized.max(f64::MIN_POSITIVE),
            );
            println!("sim wall {:.2}s", t0.elapsed().as_secs_f64());
        }
        "sched" => {
            let mix = args
                .flags
                .get("tenants")
                .cloned()
                .unwrap_or_else(|| "gemv:2,bs:1,va:1".to_string());
            let mut tenants = TenantSpec::parse_list(&mix).unwrap_or_else(|e| {
                eprintln!("bad --tenants: {e}");
                std::process::exit(2);
            });
            // dataset scale follows the per-workload harness factors;
            // --quick is the CI smoke setting behind BENCH_SCHED.json
            let scale_mul = if quick { 0.02 } else { 0.25 };
            for t in &mut tenants {
                let w = workload_by_name(&t.bench).unwrap_or_else(|| {
                    eprintln!("unknown benchmark {}", t.bench);
                    std::process::exit(2);
                });
                t.scale = args.flag("scale", harness::harness_scale(w.name()) * scale_mul);
            }
            let policy_name = args.flag("policy", "wrr".to_string());
            let policy = PolicyKind::parse(&policy_name).unwrap_or_else(|| {
                eprintln!("unknown --policy '{policy_name}' (expected fifo|wrr|sjf)");
                std::process::exit(2);
            });
            // `--elastic` alone selects the depth policy; `--elastic latency`
            // (any name from ElasticPolicyKind::ALL) picks another
            let elastic = match args.flags.get("elastic") {
                None => None,
                Some(v) if v == "true" => Some(ElasticConfig::default()),
                Some(v) => match ElasticPolicyKind::parse(v) {
                    Some(kind) => Some(ElasticConfig::new(kind)),
                    None => {
                        eprintln!(
                            "unknown --elastic policy '{v}' (expected {})",
                            ElasticPolicyKind::ALL.join("|")
                        );
                        std::process::exit(2);
                    }
                },
            };
            // `--shift t:at:factor` — multiply tenant t's arrival rate by
            // `factor` from modeled second `at` onward
            let shift = args.flags.get("shift").map(|v| {
                let parts: Vec<&str> = v.split(':').collect();
                let parsed = match parts.as_slice() {
                    [t, at, f] => match (t.parse(), at.parse(), f.parse()) {
                        (Ok(tenant), Ok(at), Ok(factor)) => {
                            Some(LoadShift { tenant, at, factor })
                        }
                        _ => None,
                    },
                    _ => None,
                };
                parsed.unwrap_or_else(|| {
                    eprintln!("bad --shift '{v}' (expected tenant:at_secs:factor, e.g. 0:0.005:8)");
                    std::process::exit(2);
                })
            });
            let cfg = SchedConfig {
                requests: args.flag("requests", 8),
                policy,
                rate: args.flag("rate", 500.0),
                max_batch: args.flag("batch", 4),
                pipeline: args.has("pipeline"),
                seed,
                exec: args.exec_choice(),
                tenants,
                trace: trace_sink.clone(),
                metrics: metrics_sink.clone(),
                elastic,
                shift,
            };
            let t0 = std::time::Instant::now();
            let rep = run_sched(&cfg)?;
            println!(
                "policy {} · seed {} · {} tenants on {} ranks · {} requests/tenant · {} \
                 schedule",
                rep.policy,
                rep.seed,
                rep.tenants.len(),
                rep.total_ranks,
                cfg.requests,
                if rep.pipelined { "pipelined" } else { "serialized" },
            );
            for t in &rep.tenants {
                let l = t.latency_summary();
                println!(
                    "tenant {} {:<9} {:>2} ranks | thr {:>9.1} req/s | p50 {:>8.3} ms  \
                     p95 {:>8.3} ms  p99 {:>8.3} ms | util {:>5.1}% | {:>8.3} J | [{}]",
                    t.slice.tenant,
                    t.bench,
                    t.slice.n_ranks,
                    t.throughput(),
                    l.p50 * 1e3,
                    l.p95 * 1e3,
                    l.p99 * 1e3,
                    t.utilization(rep.makespan) * 100.0,
                    t.joules,
                    if t.verified { "ok" } else { "VERIFY-FAIL" },
                );
            }
            println!(
                "machine occupancy {:.1}% | makespan {:.3} ms | sim wall {:.2}s",
                rep.occupancy() * 100.0,
                rep.makespan * 1e3,
                t0.elapsed().as_secs_f64(),
            );
            if let Some(pol) = rep.elastic {
                println!(
                    "elastic {} | {} migrations | mig {:.3} ms | {} bytes | {:.3} J",
                    pol,
                    rep.migrations(),
                    rep.mig_secs() * 1e3,
                    rep.mig_bytes(),
                    rep.mig_joules(),
                );
            }
            if args.has("json") {
                std::fs::create_dir_all(&outdir)?;
                // elastic runs get their own artifact so the static
                // BENCH_SCHED baseline never mixes with autoscaled output
                let file = if rep.elastic.is_some() {
                    "BENCH_ELASTIC.json"
                } else {
                    "BENCH_SCHED.json"
                };
                let path = outdir.join(file);
                std::fs::write(&path, rep.to_json())?;
                println!("wrote {}", path.display());
            }
        }
        "metrics" => {
            // SLO health over a `metrics/v1` snapshot: --load triages a
            // recorded registry (the CI validation path); without it the
            // command runs the default multi-tenant sched mix with live
            // telemetry and evaluates what it captured.
            let snap = if let Some(file) = args.flags.get("load") {
                let src = std::fs::read_to_string(file)
                    .map_err(|e| anyhow::anyhow!("--load {file}: {e}"))?;
                parse_metrics(&src).map_err(|e| anyhow::anyhow!("--load {file}: {e}"))?
            } else {
                // live mode: reuse the global --metrics sink when given so
                // the end-of-run flush exports what this run recorded
                let tel = metrics_sink.clone().unwrap_or_default();
                let mix = args
                    .flags
                    .get("tenants")
                    .cloned()
                    .unwrap_or_else(|| "gemv:2,bs:1,va:1".to_string());
                let mut tenants = TenantSpec::parse_list(&mix).unwrap_or_else(|e| {
                    eprintln!("bad --tenants: {e}");
                    std::process::exit(2);
                });
                let scale_mul = if quick { 0.02 } else { 0.25 };
                for t in &mut tenants {
                    let w = workload_by_name(&t.bench).unwrap_or_else(|| {
                        eprintln!("unknown benchmark {}", t.bench);
                        std::process::exit(2);
                    });
                    t.scale = args.flag("scale", harness::harness_scale(w.name()) * scale_mul);
                }
                let cfg = SchedConfig {
                    requests: args.flag("requests", 8),
                    rate: args.flag("rate", 500.0),
                    seed,
                    exec: args.exec_choice(),
                    metrics: Some(tel.clone()),
                    ..SchedConfig::new(tenants)
                };
                let rep = run_sched(&cfg)?;
                println!(
                    "live sched run: policy {} · {} tenants · makespan {:.3} ms",
                    rep.policy,
                    rep.tenants.len(),
                    rep.makespan * 1e3,
                );
                tel.snapshot()
            };
            let target = SloTarget {
                p99_secs: args.flag("slo-p99", 0.0),
                min_throughput_rps: args.flag("slo-rps", 0.0),
            };
            let health = SloMonitor::new(target).evaluate(&snap);
            if args.has("json") {
                print!("{}", health.to_json());
            } else {
                println!(
                    "{} metrics · {} tenants under SLO evaluation",
                    snap.entries.len(),
                    health.tenants.len(),
                );
                for t in &health.tenants {
                    println!(
                        "tenant {:<4} [{:<6}] burn {:>5.2} | p99 {:>8.3} ms (target {:>8.3} ms) \
                         | thr {:>8.1} req/s (min {:>7.1}) | {:>8.3} J | {} windows",
                        t.tenant,
                        t.status.name(),
                        t.burn_rate,
                        t.p99_secs * 1e3,
                        t.p99_target_secs * 1e3,
                        t.throughput_rps,
                        t.min_throughput_rps,
                        t.joules,
                        t.windows,
                    );
                }
                println!("health: {}", if health.healthy() { "OK" } else { "BREACH" });
            }
        }
        "trace" => {
            // Diff mode: compare two recorded native traces and report
            // what moved (same-config captures diff event-by-event).
            if let Some(a_path) = args.flags.get("diff") {
                let b_path = args.positional.first().map(String::as_str).unwrap_or_else(|| {
                    eprintln!("trace --diff needs two traces: --diff <a.v1.json> <b.v1.json>");
                    std::process::exit(2);
                });
                let load = |p: &str| -> anyhow::Result<prim_pim::coordinator::Trace> {
                    let src = std::fs::read_to_string(p)
                        .map_err(|e| anyhow::anyhow!("--diff {p}: {e}"))?;
                    parse_trace(&src).map_err(|e| anyhow::anyhow!("--diff {p}: {e}"))
                };
                let (a, b) = (load(a_path)?, load(b_path)?);
                let d = diff_traces(&a, &b, args.flag("top", 10));
                if args.has("json") {
                    print!("{}", d.to_json());
                } else {
                    print!("{}", d.render());
                }
                return Ok(());
            }
            // Two modes: triage a recorded native trace (--load, the CI
            // validation path), or run a traced pipelined serving window
            // and triage what it captured.
            let trace = if let Some(file) = args.flags.get("load") {
                let src = std::fs::read_to_string(file)
                    .map_err(|e| anyhow::anyhow!("--load {file}: {e}"))?;
                parse_trace(&src).map_err(|e| anyhow::anyhow!("--load {file}: {e}"))?
            } else {
                let name = args.flags.get("bench").cloned().unwrap_or_else(|| "BS".into());
                let w = workload_by_name(&name).unwrap_or_else(|| {
                    eprintln!("unknown benchmark {name}");
                    std::process::exit(2);
                });
                let n_requests: usize = args.flag("requests", if quick { 4 } else { 8 });
                let n_dpus: u32 = args.flag("dpus", 64);
                let sink = TraceSink::new();
                let rc = RunConfig {
                    n_dpus,
                    n_tasklets: args.flag("tasklets", w.best_tasklets()),
                    scale: args.flag(
                        "scale",
                        harness::harness_scale(w.name()) * if quick { 0.05 } else { 0.25 },
                    ),
                    seed,
                    sys: system_for(n_dpus),
                    exec: args.exec_choice(),
                    trace: Some(sink.clone()),
                    metrics: metrics_sink.clone(),
                };
                let rep = serve(w.as_ref(), &rc, n_requests, true);
                println!(
                    "traced {} · {} requests · [{}] · {} events",
                    rep.name,
                    n_requests,
                    if rep.verified { "ok" } else { "VERIFY-FAIL" },
                    sink.len(),
                );
                write_trace(&trace_out.clone().unwrap_or_else(|| outdir.join("trace.json")), &sink)?;
                sink.snapshot()
            };
            // cursor-wise replay: walk the whole trace once so the
            // summary below is backed by the replay engine, not just
            // the raw event list
            let mut replay = ReplayEngine::new(&trace);
            let mut steps = 0usize;
            while replay.step_next().is_some() {
                steps += 1;
            }
            let (t0, t1) = replay.bounds();
            let report = analyze(&trace);
            if args.has("json") {
                print!("{}", report.to_json());
            } else {
                println!(
                    "replayed {steps} events over [{t0:.6}, {t1:.6}] s{}",
                    if replay.dropped_duplicates > 0 {
                        format!(" ({} duplicate ids dropped)", replay.dropped_duplicates)
                    } else {
                        String::new()
                    }
                );
                print!("{}", report.table());
            }
        }
        "cluster" => {
            // Sharded fleets: each bench solves its fixed-size problem
            // across --machines machines of --dpus DPUs, with the
            // cross-machine traffic modeled as network collectives.
            let machines: u32 = args.flag("machines", 4);
            let names: Vec<&str> = if let Some(b) = args.flags.get("bench") {
                vec![SCALEOUT_BENCHES
                    .iter()
                    .copied()
                    .find(|n| n.eq_ignore_ascii_case(b))
                    .unwrap_or_else(|| {
                        eprintln!("unknown sharded benchmark {b} (expected GEMV|SpMV|BFS|MLP)");
                        std::process::exit(2);
                    })]
            } else {
                SCALEOUT_BENCHES.to_vec()
            };
            let mut rows = String::from("[\n");
            for (i, name) in names.iter().enumerate() {
                let mut sc = ScaleoutConfig::new(machines);
                sc.dpus_per_machine = args.flag("dpus", 4);
                sc.n_tasklets = args.flag("tasklets", 16);
                // per-bench defaults match the scaleout harness; --quick
                // is the CI smoke setting behind BENCH_CLUSTER.json
                let base = match *name {
                    "BFS" => 0.02,
                    "SpMV" => 0.05,
                    _ => 0.10,
                };
                sc.scale = args.flag("scale", base * if quick { 0.5 } else { 1.0 });
                sc.seed = seed;
                sc.exec = args.exec_choice();
                sc.trace = trace_sink.clone();
                sc.metrics = metrics_sink.clone();
                let t0 = std::time::Instant::now();
                let r = run_scaleout(name, &sc).expect("known sharded bench");
                println!(
                    "{:<5} x{:<2} [{}] makespan {:>9.3} ms | net {:>8.3} ms / {:>10} B | \
                     sim wall {:.2}s",
                    r.name,
                    r.machines,
                    if r.verified { "ok" } else { "VERIFY-FAIL" },
                    r.makespan * 1e3,
                    r.net_secs * 1e3,
                    r.net_bytes,
                    t0.elapsed().as_secs_f64(),
                );
                let b = &r.breakdown;
                rows.push_str(&format!(
                    "  {{\"name\": \"{}/m{}\", \"bench\": \"{}\", \"machines\": {}, \
                     \"verified\": {}, \"work_items\": {},\n   \
                     \"makespan_secs\": {:e}, \"net_secs\": {:e}, \"net_bytes\": {},\n   \
                     \"dpu_secs\": {:e}, \"inter_dpu_secs\": {:e}, \"cpu_dpu_secs\": {:e}, \
                     \"dpu_cpu_secs\": {:e}, \"total_secs\": {:e}}}{}\n",
                    r.name,
                    r.machines,
                    r.name,
                    r.machines,
                    r.verified,
                    r.work_items,
                    r.makespan,
                    r.net_secs,
                    r.net_bytes,
                    b.dpu,
                    b.inter_dpu,
                    b.cpu_dpu,
                    b.dpu_cpu,
                    b.total(),
                    if i + 1 < names.len() { "," } else { "" },
                ));
            }
            rows.push_str("]\n");
            if args.has("json") {
                std::fs::create_dir_all(&outdir)?;
                let path = outdir.join("BENCH_CLUSTER.json");
                std::fs::write(&path, rows)?;
                println!("wrote {}", path.display());
            }
        }
        "compare" => {
            harness::run_id("fig16", &outdir, quick)?;
            harness::run_id("fig17", &outdir, quick)?;
        }
        "estimate" => {
            let n: usize = args.flag("dpus", 2048);
            let instrs: f64 = args.flag("instrs", 1_000_000.0);
            let tasklets: f64 = args.flag("tasklets", 16.0);
            let descs: Vec<runtime::DpuDesc> = (0..n)
                .map(|_| runtime::DpuDesc {
                    instrs_per_tasklet: instrs,
                    tasklets,
                    n_reads: 1000.0,
                    read_bytes: 1024.0,
                    n_writes: 1000.0,
                    write_bytes: 1024.0,
                })
                .collect();
            let cycles = if runtime::artifacts_available() {
                let rt = runtime::PjrtRuntime::cpu()?;
                let est = runtime::FleetEstimator::load(&rt)?;
                println!("fleet estimator: PJRT artifact (dpu_timing.hlo.txt)");
                est.estimate(&descs)?
            } else {
                println!("fleet estimator: native fallback (run `make artifacts`)");
                runtime::fleet_cycles_native(&descs)
            };
            let max = cycles.iter().cloned().fold(0.0, f64::max);
            let freq = SystemConfig::p21_2556().dpu.freq_hz();
            println!(
                "{n} DPUs, {instrs:.0} instrs/tasklet x {tasklets:.0} tasklets: max {max:.0} cycles = {:.3} ms/launch",
                max / freq * 1e3
            );
        }
        "all" => {
            for id in ALL_IDS {
                println!("--- {id} ---");
                harness::run_id(id, &outdir, quick)?;
            }
        }
        _ => usage(),
    }
    // flush the global --trace capture (the `trace` subcommand writes
    // its own files inline)
    if cmd != "trace" {
        if let (Some(path), Some(sink)) = (&trace_out, &trace_sink) {
            if sink.is_empty() {
                eprintln!("--trace: no events captured ({cmd} does not trace)");
            } else {
                write_trace(path, sink)?;
            }
        }
    }
    // flush the global --metrics capture (`metrics --load` reads a file
    // and records nothing itself — stay quiet in that case)
    if let (Some(path), Some(tel)) = (&metrics_out, &metrics_sink) {
        if tel.is_empty() {
            if cmd != "metrics" {
                eprintln!("--metrics: no metrics recorded ({cmd} does not record)");
            }
        } else {
            write_metrics(path, tel)?;
        }
    }
    Ok(())
}
