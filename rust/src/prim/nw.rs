//! NW — Needleman-Wunsch global sequence alignment (§4.10).
//! Bioinformatics; int32; sequential + strided; barrier intra-DPU;
//! **the heaviest inter-DPU benchmark**: the host exchanges block
//! boundaries after every anti-diagonal, and the number of active DPUs
//! varies per diagonal — the sources of NW's sublinear scaling (§5.1).
//!
//! Structure: the (L+1)² score matrix is tiled into B×B blocks; blocks on
//! the same anti-diagonal run in parallel (one per DPU, multiple rounds if
//! the diagonal is longer than the DPU count); inside a block, tasklets
//! compute 2×2 sub-blocks in a wavefront with a barrier per sub-diagonal.
//!
//! Lifecycle: the two sequences are resident (broadcast once); a warm
//! request re-runs the whole wavefront — the boundary exchange is
//! per-request inter-DPU traffic by construction.

use super::common::{BenchTraits, RunConfig};
use super::workload::{Dataset, Output, Request, Staged, Workload};
use crate::arch::{isa, DType, Op};
use crate::coordinator::{LaunchStats, Session, Symbol, TimeBreakdown};
use crate::dpu::Ctx;
use crate::prim::common::BenchResult;
use crate::util::data::dna_pair;
use crate::util::pod::cast_slice_mut;

/// Paper dataset (Table 3, 1 DPU – 1 rank): 2,560 base pairs.
const PAPER_BPS: usize = 2560;
const MATCH: i32 = 1;
const MISMATCH: i32 = -1;
const GAP: i32 = -2;
/// Small sub-block edge (paper: 2).
const SUB: usize = 2;

fn reference_nw(a: &[u8], b: &[u8]) -> Vec<Vec<i32>> {
    let (la, lb) = (a.len(), b.len());
    let mut m = vec![vec![0i32; la + 1]; lb + 1];
    for j in 0..=la {
        m[0][j] = j as i32 * GAP;
    }
    for i in 0..=lb {
        m[i][0] = i as i32 * GAP;
    }
    for i in 1..=lb {
        for j in 1..=la {
            let sub = if a[j - 1] == b[i - 1] { MATCH } else { MISMATCH };
            m[i][j] = (m[i - 1][j - 1] + sub)
                .max(m[i - 1][j] + GAP)
                .max(m[i][j - 1] + GAP);
        }
    }
    m
}

pub struct Nw;

pub struct NwData {
    a: Vec<u8>,
    b: Vec<u8>,
    m_ref: Vec<Vec<i32>>,
    l: usize,
    bsz: usize,
    nb: usize,
}

struct NwState {
    a_sym: Symbol<u8>,
    b_sym: Symbol<u8>,
    top_sym: Symbol<i32>,
    left_sym: Symbol<i32>,
    corner_sym: Symbol<i32>,
    out_sym: Symbol<i32>,
    cur_m: Option<Vec<Vec<i32>>>,
}

/// Retrieved result: the full score matrix of the last alignment.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NwOut {
    pub m: Vec<Vec<i32>>,
}

impl Workload for Nw {
    fn name(&self) -> &'static str {
        "NW"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Bioinformatics",
            sequential: true,
            strided: true,
            random: false,
            ops: "add, sub, compare",
            dtype: "int32_t",
            intra_sync: "barrier",
            inter_sync: true,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        let nd = rc.n_dpus as usize;
        // large-block edge: paper uses L/#DPUs; cap so the (B+1)² WRAM
        // block fits; round L up to a whole number of blocks
        let l0 = rc.scaled(PAPER_BPS);
        let bsz = (l0 / nd).clamp(8, 96) & !1;
        let l = l0.div_ceil(bsz) * bsz;
        let nb = l / bsz;
        let (a, b) = dna_pair(l, l, rc.seed);
        let m_ref = reference_nw(&a, &b);
        Dataset::new((l * l) as u64, NwData { a, b, m_ref, l, bsz, nb })
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        let d = ds.get::<NwData>();
        // MRAM layout: a | b | top | left | corner | block_out
        let a_sym = sess.set.symbol::<u8>(d.l);
        let b_sym = sess.set.symbol::<u8>(d.l);
        let top_sym = sess.set.symbol::<i32>(d.bsz);
        let left_sym = sess.set.symbol::<i32>(d.bsz);
        let corner_sym = sess.set.symbol::<i32>(2);
        let out_sym = sess.set.symbol::<i32>(d.bsz * d.bsz);
        sess.set.xfer(a_sym).to().broadcast(&d.a);
        sess.set.xfer(b_sym).to().broadcast(&d.b);
        sess.put_state(NwState {
            a_sym,
            b_sym,
            top_sym,
            left_sym,
            corner_sym,
            out_sym,
            cur_m: None,
        });
        sess.mark_loaded("NW");
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        _req: &Request,
        _staged: Staged,
    ) -> LaunchStats {
        nw_execute(sess, ds, false).0
    }

    fn retrieve(&self, sess: &mut Session, _ds: &Dataset) -> Output {
        let m = sess
            .state::<NwState>()
            .cur_m
            .clone()
            .expect("NW retrieve before any execute");
        Output::new(NwOut { m })
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        out.get::<NwOut>().m == ds.get::<NwData>().m_ref
    }
}

/// The anti-diagonal wavefront over the loaded session. Returns the stats
/// of the final launch plus (when `longest_diag_only`) the metrics delta
/// of the busiest diagonal (the §9.2.1 / Fig. 19 experiment).
fn nw_execute(
    sess: &mut Session,
    ds: &Dataset,
    longest_diag_only: bool,
) -> (LaunchStats, TimeBreakdown) {
    let d = ds.get::<NwData>();
    let (a_sym, b_sym, top_sym, left_sym, corner_sym, out_sym) = {
        let st = sess.state::<NwState>();
        (st.a_sym, st.b_sym, st.top_sym, st.left_sym, st.corner_sym, st.out_sym)
    };
    let (a_off, b_off) = (a_sym.off(), b_sym.off());
    let (top_off, left_off) = (top_sym.off(), left_sym.off());
    let (corner_off, out_off) = (corner_sym.off(), out_sym.off());
    let (l, bsz, nb) = (d.l, d.bsz, d.nb);
    let nd = sess.set.n_dpus() as usize;

    // host-side full score matrix
    let mut m = vec![vec![0i32; l + 1]; l + 1];
    for j in 0..=l {
        m[0][j] = j as i32 * GAP;
    }
    for i in 0..=l {
        m[i][0] = i as i32 * GAP;
    }

    let per_cell = (4 * isa::WRAM_LS + isa::LOOP_CTRL) as u64
        + 3 * isa::op_instrs(DType::I32, Op::Cmp) as u64
        + 2 * isa::op_instrs(DType::I32, Op::Add) as u64;

    let longest_diag = nb - 1; // 0-based diagonal with nb blocks
    let mut metrics_longest = TimeBreakdown::default();
    let mut last_stats = LaunchStats::default();

    for diag in 0..(2 * nb - 1) {
        // blocks (bi, bj) with bi + bj == diag
        let blocks: Vec<(usize, usize)> = (0..nb)
            .filter_map(|bi| {
                let bj = diag.checked_sub(bi)?;
                (bj < nb).then_some((bi, bj))
            })
            .collect();
        let metrics_before = sess.set.metrics;
        for round in blocks.chunks(nd) {
            // send boundaries to each assigned DPU
            for (slot, &(bi, bj)) in round.iter().enumerate() {
                let top: Vec<i32> = (0..bsz).map(|j| m[bi * bsz][bj * bsz + 1 + j]).collect();
                let left: Vec<i32> = (0..bsz).map(|i| m[bi * bsz + 1 + i][bj * bsz]).collect();
                let corner = [m[bi * bsz][bj * bsz], 0];
                sess.set.xfer(top_sym).inter().to().one(slot, &top);
                sess.set.xfer(left_sym).inter().to().one(slot, &left);
                sess.set.xfer(corner_sym).inter().to().one(slot, &corner);
            }
            let assignment: Vec<(usize, usize)> = round.to_vec();
            let dpu_ids: Vec<usize> = (0..round.len()).collect();
            // a wavefront diagonal has at most B/SUB sub-blocks: extra
            // tasklets only pay barrier overhead (both on real hardware
            // and in simulator wallclock)
            let tl = sess.n_tasklets.min((bsz / SUB) as u32).max(1);
            let stats = sess.launch_on(&dpu_ids, tl, |slot, ctx: &mut Ctx| {
                let (bi, bj) = assignment[slot];
                nw_block_kernel(
                    ctx, bsz, bi, bj, a_off, b_off, top_off, left_off, corner_off, out_off,
                    per_cell,
                );
            });
            last_stats = stats;
            // retrieve blocks into the host matrix
            for (slot, &(bi, bj)) in round.iter().enumerate() {
                let cells = sess.set.xfer(out_sym).inter().from().one(slot, bsz * bsz);
                for i in 0..bsz {
                    for j in 0..bsz {
                        m[bi * bsz + 1 + i][bj * bsz + 1 + j] = cells[i * bsz + j];
                    }
                }
                sess.set.host_merge((bsz * bsz * 4) as u64, (bsz * bsz) as u64);
            }
        }
        if longest_diag_only && diag == longest_diag {
            metrics_longest = sess.set.metrics.delta(&metrics_before);
        }
    }

    sess.state_mut::<NwState>().cur_m = Some(m);
    (last_stats, metrics_longest)
}

/// Run NW one-shot; if `longest_diag_only`, report only the diagonal with
/// the most blocks (the §9.2.1 / Fig. 19 experiment). Returns (result, L).
pub fn run_nw(rc: &RunConfig, longest_diag_only: bool) -> (BenchResult, usize) {
    let ds = Nw.prepare(rc);
    let l = ds.get::<NwData>().l;
    let mut sess = rc.session();
    Nw.load(&mut sess, &ds);
    let (_stats, metrics_longest) = nw_execute(&mut sess, &ds, longest_diag_only);
    let out = Nw.retrieve(&mut sess, &ds);
    let verified = Nw.verify(&ds, &out);
    let breakdown = if longest_diag_only { metrics_longest } else { sess.set.metrics };
    (
        BenchResult {
            name: "NW",
            breakdown,
            verified,
            work_items: ds.work_items,
            dpu_instrs: sess.instrs,
        },
        l,
    )
}

/// Compute one B×B block with a tasklet wavefront over SUB×SUB sub-blocks.
#[allow(clippy::too_many_arguments)]
fn nw_block_kernel(
    ctx: &mut Ctx,
    bsz: usize,
    bi: usize,
    bj: usize,
    a_off: usize,
    b_off: usize,
    top_off: usize,
    left_off: usize,
    corner_off: usize,
    out_off: usize,
    per_cell: u64,
) {
    let t = ctx.tasklet_id as usize;
    let nt = ctx.n_tasklets as usize;
    let w = bsz + 1;
    // shared score block (B+1)×(B+1)
    let wblk = ctx.mem_alloc_shared(1, w * w * 4);
    let wtmp = ctx.mem_alloc(((bsz * 4 + 7) & !7).max(16));
    // sequence slices are staged by tasklet 0 and read by all
    let wseq = ctx.mem_alloc_shared(2, ((bsz + 7) & !7) * 2);

    // tasklet 0 stages boundaries and sequence slices
    if t == 0 {
        // top row + corner + left col into the block frame
        ctx.mram_read(corner_off, wtmp, 8);
        let c: Vec<i32> = ctx.wram_get(wtmp, 1);
        ctx.wram(|wr| {
            cast_slice_mut::<i32>(&mut wr[wblk..wblk + w * w * 4])[0] = c[0];
        });
        ctx.mram_read(top_off, wtmp, (bsz * 4 + 7) & !7);
        let top: Vec<i32> = ctx.wram_get(wtmp, bsz);
        ctx.mram_read(left_off, wtmp, (bsz * 4 + 7) & !7);
        let left: Vec<i32> = ctx.wram_get(wtmp, bsz);
        ctx.wram(|wr| {
            let blk = cast_slice_mut::<i32>(&mut wr[wblk..wblk + w * w * 4]);
            for j in 0..bsz {
                blk[j + 1] = top[j];
            }
            for i in 0..bsz {
                blk[(i + 1) * w] = left[i];
            }
        });
        // sequence slices a[bj*B..], b[bi*B..]
        let abase = (a_off + bj * bsz) & !7;
        let ashift = a_off + bj * bsz - abase;
        ctx.mram_read(abase, wtmp, ((ashift + bsz + 7) & !7).min(1024));
        let ab: Vec<u8> = ctx.wram_get(wtmp, ashift + bsz);
        ctx.wram(|wr| {
            let dst = wseq;
            wr[dst..dst + bsz].copy_from_slice(&ab[ashift..ashift + bsz]);
        });
        let bbase = (b_off + bi * bsz) & !7;
        let bshift = b_off + bi * bsz - bbase;
        ctx.mram_read(bbase, wtmp, ((bshift + bsz + 7) & !7).min(1024));
        let bb: Vec<u8> = ctx.wram_get(wtmp, bshift + bsz);
        ctx.wram(|wr| {
            let dst = wseq + ((bsz + 7) & !7);
            wr[dst..dst + bsz].copy_from_slice(&bb[bshift..bshift + bsz]);
        });
        ctx.compute((2 * bsz + 2) as u64);
    }
    ctx.barrier(0);

    let aseq: Vec<u8> = ctx.wram_get(wseq, bsz);
    let bseq: Vec<u8> = ctx.wram_get(wseq + ((bsz + 7) & !7), bsz);

    // wavefront over SUB×SUB sub-blocks
    let ns = bsz / SUB;
    for sd in 0..(2 * ns - 1) {
        let subs: Vec<(usize, usize)> = (0..ns)
            .filter_map(|si| {
                let sj = sd.checked_sub(si)?;
                (sj < ns).then_some((si, sj))
            })
            .collect();
        for (k, &(si, sj)) in subs.iter().enumerate() {
            if k % nt != t {
                continue;
            }
            ctx.wram(|wr| {
                let blk = cast_slice_mut::<i32>(&mut wr[wblk..wblk + w * w * 4]);
                for di in 0..SUB {
                    for dj in 0..SUB {
                        let i = si * SUB + di + 1;
                        let j = sj * SUB + dj + 1;
                        let sub = if aseq[j - 1] == bseq[i - 1] { MATCH } else { MISMATCH };
                        blk[i * w + j] = (blk[(i - 1) * w + (j - 1)] + sub)
                            .max(blk[(i - 1) * w + j] + GAP)
                            .max(blk[i * w + (j - 1)] + GAP);
                    }
                }
            });
            ctx.compute((SUB * SUB) as u64 * per_cell);
        }
        ctx.barrier(1);
    }

    // tasklet 0 writes the block (without frame) back to MRAM, row-wise
    if t == 0 {
        let row_bytes = (bsz * 4 + 7) & !7;
        for i in 0..bsz {
            ctx.wram(|wr| {
                let blk: Vec<i32> = {
                    let s = crate::util::pod::cast_slice::<i32>(&wr[wblk..wblk + w * w * 4]);
                    s[(i + 1) * w + 1..(i + 1) * w + 1 + bsz].to_vec()
                };
                crate::util::pod::write_pod_slice(wr, wtmp, &blk);
            });
            ctx.mram_write(wtmp, out_off + i * row_bytes, row_bytes);
        }
    }
    ctx.barrier(2);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.05,
            ..RunConfig::rank_default()
        };
        let (r, _) = run_nw(&rc, false);
        assert!(r.verified);
        assert!(r.breakdown.inter_dpu > 0.0, "NW is inter-DPU heavy");
    }

    #[test]
    fn single_dpu_verifies() {
        let rc = RunConfig {
            n_dpus: 1,
            n_tasklets: 8,
            scale: 0.02,
            ..RunConfig::rank_default()
        };
        assert!(run_nw(&rc, false).0.verified);
    }

    #[test]
    fn inter_dpu_dominates_at_scale_key_obs_16() {
        let rc = RunConfig {
            n_dpus: 8,
            scale: 0.1,
            ..RunConfig::rank_default()
        };
        let (r, _) = run_nw(&rc, false);
        assert!(
            r.breakdown.inter_dpu > r.breakdown.dpu,
            "inter {} vs dpu {}",
            r.breakdown.inter_dpu,
            r.breakdown.dpu
        );
    }

    #[test]
    fn longest_diag_subset_of_total() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.05,
            ..RunConfig::rank_default()
        };
        let (full, _) = run_nw(&rc, false);
        let (diag, _) = run_nw(&rc, true);
        assert!(diag.breakdown.dpu <= full.breakdown.dpu);
        assert!(diag.breakdown.dpu > 0.0);
    }
}
