//! SEL — Select (§4.4). Databases; int64; sequential; handshake + barrier
//! intra-DPU, inter-DPU merge on the host (serial DPU-CPU transfers, since
//! each DPU returns a different number of filtered elements).
//!
//! The kernel is the paper's block-wise compaction: each tasklet filters a
//! 1,024-B block in WRAM, passes its running count to the next tasklet
//! through a handshake chain (an inherent prefix sum), and DMA-writes its
//! compacted elements at the received offset.
//!
//! Inputs are distributed with **ragged** parallel transfers: each DPU
//! receives exactly its slice of the array (the old equal-size path forced
//! sentinel padding with values the predicate had to filter back out).
//!
//! The same machinery implements UNI (§4.5) — the handshake additionally
//! carries the predecessor's last element value.
//!
//! Lifecycle: the input array is resident; warm requests re-run the
//! compaction against it (streaming workload — the kernel never mutates
//! its input region, so re-execution is exact).

use super::common::{BenchTraits, RunConfig};
use super::workload::{Dataset, Output, Request, Staged, Workload};
use crate::arch::{isa, DType, Op};
use crate::coordinator::{chunk_ranges, ragged_counts, Bucket, LaunchStats, Session, Symbol};
use crate::dpu::Ctx;
use crate::util::Rng;

/// Paper dataset (Table 3): 3.8 M int64 elements.
pub const PAPER_N: usize = 3_800_000;
const BLOCK: usize = 1024;
const EPB: usize = BLOCK / 8;

/// SEL keeps elements that do NOT satisfy the predicate (pred = "is even").
#[inline]
pub fn sel_keep(x: i64) -> bool {
    x % 2 != 0
}

/// Which compaction semantics a kernel run uses.
#[derive(Clone, Copy, PartialEq)]
pub enum CompactKind {
    Select,
    Unique,
}

/// MRAM symbols of the compaction kernel, shared by host and kernel sides.
/// `input`/`output` are sized for the widest per-DPU slice; per-DPU
/// element counts ride in the launch closure.
#[derive(Clone, Copy)]
pub struct CompactSyms {
    /// Input slice (per-DPU length varies; ragged transfers).
    pub input: Symbol<i64>,
    /// Handshake chain slots: (cumulative_count, last_value) per tasklet.
    pub slots: Symbol<i64>,
    /// Compacted output.
    pub output: Symbol<i64>,
    /// (DPU total count, DPU last value).
    pub count: Symbol<i64>,
}

impl CompactSyms {
    /// Carve the four regions for slices of up to `max_per` elements.
    pub fn alloc(set: &mut crate::coordinator::PimSet, max_per: usize, n_tasklets: u32) -> Self {
        CompactSyms {
            input: set.symbol::<i64>(max_per),
            slots: set.symbol::<i64>(n_tasklets as usize * 2),
            output: set.symbol::<i64>(max_per),
            count: set.symbol::<i64>(2),
        }
    }
}

pub fn compact_kernel(ctx: &mut Ctx, kind: CompactKind, syms: CompactSyms, my_elems: usize) {
    let t = ctx.tasklet_id as usize;
    let nt = ctx.n_tasklets as usize;
    let in_off = syms.input.off();
    let slot_off = syms.slots.off();
    let out_off = syms.output.off();
    let win = ctx.mem_alloc(BLOCK);
    let wout = ctx.mem_alloc(BLOCK);
    let wslot = ctx.mem_alloc(16);

    // contiguous range per tasklet
    let my = chunk_ranges(my_elems, nt)[t].clone();
    let per_elem = (isa::WRAM_LS + isa::ADDR_CALC + isa::LOOP_CTRL) as u64
        + isa::op_instrs(DType::I64, Op::Cmp) as u64
        + isa::op_instrs(DType::I64, Op::Add) as u64;

    // pass 1: filter into a local MRAM staging area? The paper compacts
    // in one pass: we filter block-wise, buffering kept elements and
    // flushing to a *local-offset* staging region, then (after the chain
    // tells us our global base) copy staging → final. To stay close to
    // the paper while keeping WRAM bounded, we instead count first
    // (streaming read), chain, then re-stream and write at the base —
    // same DMA volume as staging+copy.
    let mut kept = 0u64;
    let mut last_val = i64::MIN;
    let mut blk = my.start;
    while blk < my.end {
        let cnt = (my.end - blk).min(EPB);
        ctx.mram_read(in_off + blk * 8, win, ((cnt * 8 + 7) & !7).max(8));
        let v: Vec<i64> = ctx.wram_get(win, cnt);
        for (i, x) in v.iter().enumerate() {
            let keep = match kind {
                CompactKind::Select => sel_keep(*x),
                CompactKind::Unique => {
                    let prev = if blk + i == my.start {
                        None // resolved after the chain for tasklet > 0
                    } else {
                        Some(last_val)
                    };
                    prev != Some(*x)
                }
            };
            if keep {
                kept += 1;
            }
            last_val = *x;
        }
        ctx.compute(cnt as u64 * per_elem);
        blk += cnt;
    }

    // handshake chain: receive (base, prev_last) from predecessor
    let (mut base, prev_last) = if t == 0 {
        (0u64, i64::MIN)
    } else {
        ctx.handshake_wait_for(t as u32 - 1);
        ctx.mram_read(slot_off + (t - 1) * 16, wslot, 16);
        let s: Vec<i64> = ctx.wram_get(wslot, 2);
        (s[0] as u64, s[1])
    };

    // UNI: if our first element equals predecessor's last, it is not unique
    if kind == CompactKind::Unique && !my.is_empty() && t > 0 {
        ctx.mram_read((in_off + my.start * 8) & !7, win, 8);
        let first: Vec<i64> = ctx.wram_get(win, 1);
        if first[0] == prev_last {
            kept -= 1;
        }
        ctx.charge_ops(DType::I64, Op::Cmp, 1);
    }

    // publish (base + kept, my_last) and notify successor; the last
    // tasklet's cumulative count IS the DPU total, so it records it here —
    // no barrier needed (and the kernel stays sequential-launch-safe)
    let my_last = if my.is_empty() { prev_last } else { last_val };
    ctx.wram_set(wslot, &[(base + kept) as i64, my_last]);
    ctx.mram_write(wslot, slot_off + t * 16, 16);
    if t + 1 < nt {
        ctx.handshake_notify();
    } else {
        ctx.mram_write(wslot, syms.count.off(), 16);
    }

    // pass 2: re-stream, compact, write at global base
    let mut prev = if t == 0 { i64::MIN } else { prev_last };
    let mut have_prev = t != 0;
    let mut obuf: Vec<i64> = Vec::with_capacity(EPB);
    let mut blk = my.start;
    while blk < my.end {
        let cnt = (my.end - blk).min(EPB);
        ctx.mram_read(in_off + blk * 8, win, ((cnt * 8 + 7) & !7).max(8));
        let v: Vec<i64> = ctx.wram_get(win, cnt);
        for x in v {
            let keep = match kind {
                CompactKind::Select => sel_keep(x),
                CompactKind::Unique => !(have_prev && prev == x),
            };
            prev = x;
            have_prev = true;
            if keep {
                obuf.push(x);
                if obuf.len() == EPB {
                    ctx.wram_set(wout, &obuf);
                    ctx.compute(obuf.len() as u64 * 2);
                    ctx.mram_write(wout, out_off + base as usize * 8, BLOCK);
                    base += EPB as u64;
                    obuf.clear();
                }
            }
        }
        ctx.compute(cnt as u64 * per_elem);
        blk += cnt;
    }
    if !obuf.is_empty() {
        ctx.wram_set(wout, &obuf);
        ctx.compute(obuf.len() as u64 * 2);
        ctx.mram_write(wout, out_off + base as usize * 8, (obuf.len() * 8 + 7) & !7);
    }
}

// ------------------------------------------------ shared lifecycle stages

pub(super) struct CompactData {
    input: Vec<i64>,
    reference: Vec<i64>,
    n: usize,
    per: usize,
    counts: Vec<usize>,
}

struct CompactState {
    syms: CompactSyms,
}

/// Retrieved, host-merged compaction result.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CompactOut {
    pub result: Vec<i64>,
}

pub(super) fn prepare_compact(kind: CompactKind, rc: &RunConfig) -> Dataset {
    let n = rc.scaled(PAPER_N);
    let mut rng = Rng::new(rc.seed);
    // UNI wants runs of equal consecutive values; SEL wants a value mix
    let input: Vec<i64> = match kind {
        CompactKind::Select => (0..n).map(|_| rng.below(1 << 30) as i64).collect(),
        CompactKind::Unique => {
            let mut v = Vec::with_capacity(n);
            let mut cur = 0i64;
            while v.len() < n {
                cur += 1 + rng.below(8) as i64;
                let run = 1 + rng.below(5) as usize;
                for _ in 0..run.min(n - v.len()) {
                    v.push(cur);
                }
            }
            v
        }
    };

    // reference
    let reference: Vec<i64> = match kind {
        CompactKind::Select => input.iter().copied().filter(|&x| sel_keep(x)).collect(),
        CompactKind::Unique => {
            let mut out: Vec<i64> = Vec::new();
            for &x in &input {
                if out.last() != Some(&x) {
                    out.push(x);
                }
            }
            out
        }
    };

    let nd = rc.n_dpus as usize;
    let per = n.div_ceil(nd).div_ceil(EPB) * EPB;
    let counts = ragged_counts(n, per, nd);
    Dataset::new(n as u64, CompactData { input, reference, n, per, counts })
}

pub(super) fn load_compact(sess: &mut Session, ds: &Dataset) {
    let d = ds.get::<CompactData>();
    let nd = sess.set.n_dpus() as usize;
    assert_eq!(nd, d.counts.len(), "session fleet must match the dataset");
    let syms = CompactSyms::alloc(&mut sess.set, d.per, sess.n_tasklets);
    // exact per-DPU slices — ragged transfers need no predicate-aware
    // sentinel padding
    let bufs: Vec<Vec<i64>> = (0..nd)
        .map(|i| d.input[(i * d.per).min(d.n)..((i + 1) * d.per).min(d.n)].to_vec())
        .collect();
    sess.set.xfer(syms.input).to().ragged(&bufs);
    sess.put_state(CompactState { syms });
}

pub(super) fn execute_compact(kind: CompactKind, sess: &mut Session, ds: &Dataset) -> LaunchStats {
    let d = ds.get::<CompactData>();
    let syms = sess.state::<CompactState>().syms;
    let counts_ref = &d.counts;
    sess.launch_seq(sess.n_tasklets, move |dpu, ctx: &mut Ctx| {
        compact_kernel(ctx, kind, syms, counts_ref[dpu]);
    })
}

pub(super) fn retrieve_compact(kind: CompactKind, sess: &mut Session, ds: &Dataset) -> Output {
    let d = ds.get::<CompactData>();
    let syms = sess.state::<CompactState>().syms;
    let nd = sess.set.n_dpus() as usize;
    // serial retrieval + host merge (the paper's final merge step)
    let mut result: Vec<i64> = Vec::with_capacity(d.n);
    for dpu in 0..nd {
        let cnt = sess.set.xfer(syms.count).from().one(dpu, 1)[0] as usize;
        let vals = sess.set.xfer(syms.output).from().one(dpu, cnt);
        // host merge: UNI must also dedup across DPU boundaries. The merge
        // is part of result *retrieval* (the paper's SEL/UNI merge happens
        // while serially copying each DPU's output into place), so its
        // host cost is charged to DPU-CPU, not Inter-DPU.
        match kind {
            CompactKind::Select => result.extend(vals),
            CompactKind::Unique => {
                for v in vals {
                    if result.last() != Some(&v) {
                        result.push(v);
                    }
                }
            }
        }
        sess.set.host_merge_in(Bucket::DpuCpu, (cnt * 8) as u64, cnt as u64);
    }
    Output::new(CompactOut { result })
}

pub(super) fn verify_compact(ds: &Dataset, out: &Output) -> bool {
    out.get::<CompactOut>().result == ds.get::<CompactData>().reference
}

pub struct Sel;

impl Workload for Sel {
    fn name(&self) -> &'static str {
        "SEL"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Databases",
            sequential: true,
            strided: false,
            random: false,
            ops: "add, compare",
            dtype: "int64_t",
            intra_sync: "handshake, barrier",
            inter_sync: true,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        prepare_compact(CompactKind::Select, rc)
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        load_compact(sess, ds);
        sess.mark_loaded("SEL");
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        _req: &Request,
        _staged: Staged,
    ) -> LaunchStats {
        execute_compact(CompactKind::Select, sess, ds)
    }

    fn retrieve(&self, sess: &mut Session, ds: &Dataset) -> Output {
        retrieve_compact(CompactKind::Select, sess, ds)
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        verify_compact(ds, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prim::common::PrimBench;

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        let r = Sel.run(&rc);
        assert!(r.verified);
        assert!(r.breakdown.dpu_cpu > 0.0, "serial retrieval charged");
    }

    #[test]
    fn single_tasklet_no_handshake_needed() {
        let rc = RunConfig {
            n_dpus: 1,
            n_tasklets: 1,
            scale: 0.001,
            ..RunConfig::rank_default()
        };
        assert!(Sel.run(&rc).verified);
    }

    #[test]
    fn ragged_input_moves_exactly_n_elements() {
        let rc = RunConfig {
            n_dpus: 5,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        let n = rc.scaled(PAPER_N) as u64;
        let r = Sel.run(&rc);
        assert!(r.verified);
        assert_eq!(r.breakdown.bytes_to_dpu, n * 8, "no sentinel padding pushed");
    }

    #[test]
    fn dpu_cpu_grows_with_dpus() {
        // serial retrieval: more DPUs → more fixed transfer costs
        let mk = |nd: u32| {
            let rc = RunConfig {
                n_dpus: nd,
                scale: 0.002,
                ..RunConfig::rank_default()
            };
            Sel.run(&rc).breakdown.dpu_cpu
        };
        assert!(mk(8) > mk(2));
    }

    /// Warm re-execute: the compaction kernel never mutates its input, so
    /// a second request reproduces the result bit-for-bit with no reload.
    #[test]
    fn warm_reexecute_is_exact() {
        let rc = RunConfig {
            n_dpus: 3,
            scale: 0.001,
            ..RunConfig::rank_default()
        };
        let ds = Sel.prepare(&rc);
        let mut sess = rc.session();
        Sel.load(&mut sess, &ds);
        let req0 = Request::new(0, rc.seed);
        Sel.execute(&mut sess, &ds, &req0, Staged::empty());
        let first = Sel.retrieve(&mut sess, &ds);
        let pushed = sess.set.metrics.bytes_to_dpu;
        let req1 = Request::new(1, rc.seed ^ 99);
        Sel.execute(&mut sess, &ds, &req1, Staged::empty());
        let second = Sel.retrieve(&mut sess, &ds);
        assert_eq!(first.get::<CompactOut>(), second.get::<CompactOut>());
        assert!(Sel.verify(&ds, &second));
        assert_eq!(sess.set.metrics.bytes_to_dpu, pushed, "no input reload");
    }
}
