//! TS — Time Series analysis (§4.7). Matrix-profile-style streaming
//! similarity search: slide a query over the series, track the minimum
//! distance. int32; sequential; heavy integer multiplication; no
//! synchronization (per-tasklet minima merged by tasklet 0, per-DPU minima
//! merged by the host).
//!
//! Each DPU receives its position range plus the QUERY_LEN−1 window
//! overlap as a **ragged** transfer of exactly that many elements. (The
//! equal-size transfer path used to round every slice up to whole 1,024-B
//! blocks and fill the tail with `i32::MAX / 4` sentinels chosen to sort
//! far from any real match — a correction the ragged path deletes.)
//!
//! Distance is the sum of squared differences over the window (the integer
//! analogue of the z-normalized Euclidean profile — same add/sub/mul mix
//! the paper's Table 2 lists for TS).
//!
//! Lifecycle: the series slices are resident; each request stages a fresh
//! query window (an exact slice of the series at a seeded position, so a
//! zero-distance match always exists) — query-style serving over warm
//! series data.

use super::common::{BenchTraits, RunConfig};
use super::workload::{Dataset, Output, Request, Staged, Workload};
use crate::arch::{isa, DType, Op};
use crate::coordinator::{chunk_ranges, LaunchStats, Session, Symbol};
use crate::dpu::Ctx;
use crate::util::data::time_series;
use crate::util::Rng;

/// Paper dataset (Table 3): 512 K elements, 256-element query.
const PAPER_N: usize = 524_288;
pub const QUERY_LEN: usize = 256;
const BLOCK: usize = 1024;

pub struct Ts;

fn ssd(window: &[i32], query: &[i32]) -> i64 {
    window
        .iter()
        .zip(query)
        .map(|(a, b)| {
            let d = (*a as i64) - (*b as i64);
            d * d
        })
        .sum()
}

/// Host dataset: the series plus the per-DPU overlap-slice partition.
pub struct TsData {
    series: Vec<i32>,
    n: usize,
    positions: usize,
    per_pos: usize,
    slice_elems: usize,
    counts: Vec<usize>,
    nd: usize,
}

struct TsState {
    series_sym: Symbol<i32>,
    q_sym: Symbol<i32>,
    out_sym: Symbol<i64>,
    cur_query: Vec<i32>,
}

pub struct TsStaged {
    pub query: Vec<i32>,
}

/// Retrieved result: the query and the global minimum it found.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TsOut {
    pub query: Vec<i32>,
    pub best: i64,
    pub best_pos: usize,
}

impl Workload for Ts {
    fn name(&self) -> &'static str {
        "TS"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Data analytics",
            sequential: true,
            strided: false,
            random: false,
            ops: "add, sub, mul, div",
            dtype: "int32_t",
            intra_sync: "",
            inter_sync: false,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        let n = rc.scaled(PAPER_N).max(4 * QUERY_LEN);
        let (series, _seed_query) = time_series(n, QUERY_LEN, rc.seed);
        let nd = rc.n_dpus as usize;
        let positions = n - QUERY_LEN + 1;
        // even per-DPU position stride keeps every ragged slice start on
        // the 8-B DMA boundary (i32 elements)
        let per_pos = positions.div_ceil(nd).div_ceil(2) * 2;
        // each DPU gets its positions plus QUERY_LEN-1 overlap, rounded up
        // to an even element count with *real* neighboring data (never a
        // sentinel); the final slice ends exactly at the series end
        let slice_elems = per_pos + QUERY_LEN; // even; QUERY_LEN-1 overlap + 1
        let counts: Vec<usize> =
            (0..nd).map(|d| slice_elems.min(n.saturating_sub(d * per_pos))).collect();
        Dataset::new(
            positions as u64,
            TsData { series, n, positions, per_pos, slice_elems, counts, nd },
        )
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        let d = ds.get::<TsData>();
        assert_eq!(sess.set.n_dpus() as usize, d.nd, "session fleet must match the dataset");
        let bufs: Vec<Vec<i32>> = (0..d.nd)
            .map(|i| {
                let lo = (i * d.per_pos).min(d.n);
                d.series[lo..lo + d.counts[i]].to_vec()
            })
            .collect();
        let series_sym = sess.set.symbol::<i32>(d.slice_elems);
        let q_sym = sess.set.symbol::<i32>(QUERY_LEN);
        let out_sym = sess.set.symbol::<i64>(sess.n_tasklets as usize * 2);
        sess.set.xfer(series_sym).to().ragged(&bufs);
        sess.put_state(TsState { series_sym, q_sym, out_sym, cur_query: Vec::new() });
        sess.mark_loaded("TS");
    }

    fn stage(&self, ds: &Dataset, req: &Request) -> Staged {
        let d = ds.get::<TsData>();
        // the query is an exact window of the series at a seeded position,
        // so every request has a zero-distance match to find
        let mut rng = Rng::new(req.seed);
        let pos = rng.below(d.positions as u64) as usize;
        Staged::new(TsStaged { query: d.series[pos..pos + QUERY_LEN].to_vec() })
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        _req: &Request,
        staged: Staged,
    ) -> LaunchStats {
        let d = ds.get::<TsData>();
        let TsStaged { query } = staged.take::<TsStaged>();
        let (series_sym, q_sym, out_sym) = {
            let st = sess.state::<TsState>();
            (st.series_sym, st.q_sym, st.out_sym)
        };
        sess.set.xfer(q_sym).to().broadcast(&query);

        let arch = sess.set.cfg.dpu;
        let per_elem = (2 * isa::WRAM_LS + isa::LOOP_CTRL) as u64
            + isa::op_instrs_for(&arch, DType::I32, Op::Sub) as u64
            + isa::op_instrs_for(&arch, DType::I32, Op::Mul) as u64
            + isa::op_instrs_for(&arch, DType::I64, Op::Add) as u64;

        let (per_pos, positions) = (d.per_pos, d.positions);
        let counts_ref = &d.counts;
        let stats = sess.launch_seq(sess.n_tasklets, |dpu, ctx: &mut Ctx| {
            let t = ctx.tasklet_id as usize;
            let nt = ctx.n_tasklets as usize;
            let slice_bytes = counts_ref[dpu] * 4;
            // query resident in WRAM for the whole kernel
            let wq = ctx.mem_alloc(QUERY_LEN * 4);
            ctx.mram_read(q_sym.off(), wq, QUERY_LEN * 4);
            let qv: Vec<i32> = ctx.wram_get(wq, QUERY_LEN);
            // sliding window buffer: CHUNK positions need CHUNK+QUERY_LEN
            // elements
            const CHUNK: usize = 256;
            let wbuf = ctx.mem_alloc((CHUNK + QUERY_LEN) * 4);
            let wout = ctx.mem_alloc(16);

            let dpu_positions = per_pos.min(positions.saturating_sub(dpu * per_pos));
            let my = chunk_ranges(dpu_positions, nt)[t].clone();
            let mut best = i64::MAX;
            let mut best_pos = 0usize;
            let mut p = my.start;
            while p < my.end {
                let cnt = (my.end - p).min(CHUNK);
                let need = cnt + QUERY_LEN; // elements
                let nbytes = (need * 4 + 1023) & !1023;
                // stream the span in 1024-B DMA chunks, clamped to the
                // DPU's exact slice (no sentinel blocks to overrun into)
                let base = (p * 4) & !7;
                let shift = (p * 4 - base) / 4;
                let limit = nbytes.min(slice_bytes - base);
                let mut got = 0;
                while got < limit {
                    let take = (limit - got).min(BLOCK);
                    ctx.mram_read(series_sym.off() + base + got, wbuf + got, take);
                    got += take;
                }
                let span: Vec<i32> = ctx.wram_get(wbuf, (got / 4).min(CHUNK + QUERY_LEN));
                for i in 0..cnt {
                    if shift + i + QUERY_LEN > span.len() {
                        break;
                    }
                    let dist = ssd(&span[shift + i..shift + i + QUERY_LEN], &qv);
                    if dist < best {
                        best = dist;
                        best_pos = p + i;
                    }
                }
                ctx.compute((cnt * QUERY_LEN) as u64 * per_elem);
                p += cnt;
            }
            // per-tasklet result slots
            ctx.wram_set(wout, &[best, best_pos as i64]);
            ctx.mram_write(wout, out_sym.off() + t * 16, 16);
        });
        sess.state_mut::<TsState>().cur_query = query;
        stats
    }

    fn retrieve(&self, sess: &mut Session, ds: &Dataset) -> Output {
        let d = ds.get::<TsData>();
        let out_sym = sess.state::<TsState>().out_sym;
        let nt = sess.n_tasklets as usize;
        // host merge: per-DPU per-tasklet minima
        let mut best = i64::MAX;
        let mut best_pos = 0usize;
        for dpu in 0..d.nd {
            let slots = sess.set.xfer(out_sym).from().one(dpu, nt * 2);
            for t in 0..nt {
                let (b, p) = (slots[t * 2], slots[t * 2 + 1] as usize);
                if b < best {
                    best = b;
                    best_pos = dpu * d.per_pos + p;
                }
            }
        }
        Output::new(TsOut { query: sess.state::<TsState>().cur_query.clone(), best, best_pos })
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        let d = ds.get::<TsData>();
        let o = out.get::<TsOut>();
        if o.query.len() != QUERY_LEN || o.best_pos + QUERY_LEN > d.n {
            return false;
        }
        // reference: global minimum SSD over all positions
        let mut best_ref = i64::MAX;
        for p in 0..=(d.n - QUERY_LEN) {
            let dist = ssd(&d.series[p..p + QUERY_LEN], &o.query);
            if dist < best_ref {
                best_ref = dist;
            }
        }
        o.best == best_ref && ssd(&d.series[o.best_pos..o.best_pos + QUERY_LEN], &o.query) == best_ref
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prim::common::PrimBench;
    use crate::prim::workload::serve;

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.01,
            ..RunConfig::rank_default()
        };
        let r = Ts.run(&rc);
        assert!(r.verified);
        assert_eq!(r.breakdown.inter_dpu, 0.0);
    }

    #[test]
    fn ragged_slices_carry_no_sentinel_blocks() {
        let rc = RunConfig {
            n_dpus: 3,
            scale: 0.01,
            ..RunConfig::rank_default()
        };
        let r = Ts.run(&rc);
        assert!(r.verified);
        // expected input volume: exact overlap slices + broadcast query —
        // not whole-block-rounded sentinel-padded slices
        let n = rc.scaled(524_288).max(4 * QUERY_LEN);
        let positions = n - QUERY_LEN + 1;
        let per_pos = positions.div_ceil(3).div_ceil(2) * 2;
        let slices: usize = (0..3usize)
            .map(|d| (per_pos + QUERY_LEN).min(n.saturating_sub(d * per_pos)))
            .sum();
        let expect = (slices + 3 * QUERY_LEN) * 4;
        assert_eq!(r.breakdown.bytes_to_dpu, expect as u64);
        // independent regression pin: strictly below what the old
        // whole-1024-B-block sentinel layout would have pushed
        let padded = 3 * ((per_pos + QUERY_LEN - 1 + 255) & !255) + 3 * QUERY_LEN;
        assert!(r.breakdown.bytes_to_dpu < (padded * 4) as u64, "block padding crept back");
    }

    #[test]
    fn exact_match_found() {
        // the query is an exact slice of the series → min distance 0
        let rc = RunConfig {
            n_dpus: 2,
            scale: 0.005,
            ..RunConfig::rank_default()
        };
        assert!(Ts.run(&rc).verified);
    }

    /// Serving: every warm request slides a fresh query over the resident
    /// series, re-pushing only QUERY_LEN elements per DPU.
    #[test]
    fn warm_requests_push_only_the_query() {
        let rc = RunConfig {
            n_dpus: 3,
            scale: 0.005,
            ..RunConfig::rank_default()
        };
        let rep = serve(&Ts, &rc, 3, false);
        assert!(rep.verified);
        for r in &rep.requests {
            assert_eq!(r.bytes_to_dpu, (3 * QUERY_LEN * 4) as u64);
        }
        assert!(rep.steady_state().cpu_dpu < rep.cold.cpu_dpu / 4.0);
    }
}
