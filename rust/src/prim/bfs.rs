//! BFS — Breadth-First Search (§4.8). Graph processing; uint64 bit-vectors;
//! random access; barrier + mutex intra-DPU; **heavy inter-DPU
//! synchronization** — the frontier is unioned by the host after every
//! level, which is why BFS scales worst of the suite (§5.1/§5.2).
//!
//! Top-down: vertices are range-partitioned; every DPU keeps a local copy
//! of the visited bit-vector and produces a next-frontier bit-vector from
//! the neighbor lists of its owned frontier vertices (mutex-protected
//! updates).
//!
//! Lifecycle: the CSR slices are resident; each request traverses from a
//! fresh root (request 0 keeps the paper's max-degree root), paying only a
//! small bit-vector reset instead of re-pushing the graph.
//!
//! In an async command-queue batch the level loop declares its real data
//! flow: the per-level frontier union depends only on the pulls whose
//! host images it consumes (`host_merge_dep`), and the next level's
//! frontier scatter carries the union's output (`.after(..)`). On the
//! modeled timeline the host-side union therefore overlaps the bus
//! traffic that zeroes the next-frontier vectors — the §6 overlap BFS
//! can realize even though its level chain is otherwise serial.

use super::common::{BenchTraits, RunConfig};
use super::workload::{Dataset, Output, Request, Staged, Workload};
use crate::arch::{isa, DType, Op};
use crate::coordinator::{chunk_ranges, Access, CmdId, LaunchStats, Session, Symbol};
use crate::dpu::Ctx;
use crate::util::data::{rmat_graph, Graph};
use crate::util::Rng;
use std::ops::Range;

/// loc-gowalla statistics: ~197 K vertices, ~1.9 M (directed) edges.
const PAPER_V: usize = 196_591;
const PAPER_E: usize = 1_900_654;

pub struct Bfs;

pub struct BfsData {
    g: Graph,
    v: usize,
    /// The paper's root: the maximum-degree vertex (request 0 uses it).
    max_degree_root: usize,
}

struct BfsState {
    rp_sym: Symbol<u32>,
    ci_sym: Symbol<u32>,
    fr_sym: Symbol<u64>,
    nxvis_sym: Symbol<u64>,
    words: usize,
    row_parts: Vec<Range<usize>>,
    /// Most recent traversal (root + distances), for retrieval.
    cur: Option<BfsOut>,
}

/// One request's staged input: the traversal root.
pub struct BfsStaged {
    pub root: usize,
}

/// Result of the last traversal. BFS's distances are assembled host-side
/// during the level loop (the inter-DPU phase *is* the retrieval), so
/// `retrieve` reports them without further transfers.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BfsOut {
    pub root: usize,
    pub dist: Vec<u32>,
}

impl Workload for Bfs {
    fn name(&self) -> &'static str {
        "BFS"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Graph processing",
            sequential: true,
            strided: false,
            random: true,
            ops: "bitwise logic",
            dtype: "uint64_t",
            intra_sync: "barrier, mutex",
            inter_sync: true,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        // keep the three WRAM bit-vectors (3 × V/8 bytes) plus per-tasklet
        // buffers inside the 64 KB WRAM: cap vertices at 96 K
        let v = rc.scaled(PAPER_V).min(96 * 1024);
        let e = rc.scaled(PAPER_E).min(v * 12);
        let g = rmat_graph(v, e, rc.seed);
        let max_degree_root =
            (0..v).max_by_key(|&u| g.row_ptr[u + 1] - g.row_ptr[u]).unwrap_or(0);
        let work = g.n_edges() as u64;
        Dataset::new(work, BfsData { g, v, max_degree_root })
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        let d = ds.get::<BfsData>();
        let nd = sess.set.n_dpus() as usize;
        let parts = chunk_ranges(d.v, nd);
        let words = d.v.div_ceil(64);

        // input distribution: per-DPU CSR slices (serial copies — sizes
        // differ, §5.1.1). Fleet-wide symbols sized for the widest slice:
        //   rp_sym   rebased row_ptr (rows+1 u32)
        //   ci_sym   neighbor lists (u32)
        //   fr_sym   current frontier bit-vector (words u64)
        //   nxvis    next frontier + visited bit-vectors (adjacent, so
        //            both reset together in one transfer per request)
        let max_rows = parts.iter().map(|r| r.len()).max().unwrap_or(0);
        let max_deg = parts
            .iter()
            .map(|r| (d.g.row_ptr[r.end] - d.g.row_ptr[r.start]) as usize)
            .max()
            .unwrap_or(0);
        let rp_sym = sess.set.symbol::<u32>(max_rows + 1);
        let ci_sym = sess.set.symbol::<u32>(max_deg);
        let fr_sym = sess.set.symbol::<u64>(words);
        let nxvis_sym = sess.set.symbol::<u64>(2 * words);
        for (i, r) in parts.iter().enumerate() {
            let base = d.g.row_ptr[r.start];
            let rp: Vec<u32> = d.g.row_ptr[r.start..=r.end].iter().map(|x| x - base).collect();
            let deg = (d.g.row_ptr[r.end] - base) as usize;
            let ci = d.g.col_idx[base as usize..base as usize + deg].to_vec();
            sess.set.xfer(rp_sym).to().one(i, &rp);
            sess.set.xfer(ci_sym).to().one(i, &ci);
        }
        sess.put_state(BfsState {
            rp_sym,
            ci_sym,
            fr_sym,
            nxvis_sym,
            words,
            row_parts: parts,
            cur: None,
        });
        sess.mark_loaded("BFS");
    }

    fn stage(&self, ds: &Dataset, req: &Request) -> Staged {
        let d = ds.get::<BfsData>();
        let root = if req.id == 0 {
            d.max_degree_root
        } else {
            // a fresh seeded root with at least one edge (else the paper's)
            let mut rng = Rng::new(req.seed);
            let cand = rng.below(d.v as u64) as usize;
            if d.g.row_ptr[cand + 1] > d.g.row_ptr[cand] {
                cand
            } else {
                d.max_degree_root
            }
        };
        Staged::new(BfsStaged { root })
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        _req: &Request,
        staged: Staged,
    ) -> LaunchStats {
        let d = ds.get::<BfsData>();
        let BfsStaged { root } = staged.take::<BfsStaged>();
        let (rp_sym, ci_sym, fr_sym, nxvis_sym, words, row_parts) = {
            let st = sess.state::<BfsState>();
            (st.rp_sym, st.ci_sym, st.fr_sym, st.nxvis_sym, st.words, st.row_parts.clone())
        };
        let nx_sym = nxvis_sym.slice(0, words);
        let vis_sym = nxvis_sym.slice(words, words);
        let nd = sess.set.n_dpus() as usize;
        let v = d.v;

        // per-request state reset: zero next + visited on every DPU (the
        // only warm CPU-DPU cost — the graph itself stays resident)
        let zeros = vec![0u64; 2 * words];
        sess.set.group_begin();
        for i in 0..nd {
            sess.set.xfer(nxvis_sym).to().one(i, &zeros);
        }
        sess.set.group_end();

        // frontier bootstrap
        let mut frontier = vec![0u64; words];
        frontier[root / 64] |= 1 << (root % 64);
        let mut dist = vec![u32::MAX; v];
        dist[root] = 0;
        let mut level = 0u32;

        let per_edge = (2 * isa::WRAM_LS + isa::ADDR_CALC) as u64
            + isa::op_instrs(DType::U64, Op::Bitwise) as u64;

        let mut last_stats = LaunchStats::default();
        // id of the previous level's frontier union: the next scatter
        // carries its output (host-side data flow the region inference
        // cannot see)
        let mut prev_merge: Vec<CmdId> = Vec::new();
        loop {
            // distribute the current frontier (inter-DPU phase). Each DPU
            // keeps a private copy it mutates, so these are serial per-DPU
            // copies, not a broadcast (matching the PrIM host loop);
            // queued, they coalesce into one recorded scatter command.
            let frontier_now = frontier.clone();
            sess.set.group_begin();
            for i in 0..nd {
                sess.set.xfer(fr_sym).inter().after(&prev_merge).to().one(i, &frontier_now);
            }
            sess.set.group_end();

            let (ci_off, fr_off, nx_off, vis_off) =
                (ci_sym.off(), fr_sym.off(), nx_sym.off(), vis_sym.off());
            let rp_off = rp_sym.off();
            let row_parts_ref = &row_parts;
            let acc = Access::new()
                .read(rp_sym.region())
                .read(ci_sym.region())
                .read(fr_sym.region())
                .read(nxvis_sym.region())
                .write(nxvis_sym.region());
            let stats = sess.launch_acc(acc, sess.n_tasklets, |dpu, ctx: &mut Ctx| {
                let rows = row_parts_ref[dpu].clone();
                let n_rows = rows.len();
                // shared WRAM bit-vectors
                let wfr = ctx.mem_alloc_shared(1, words * 8);
                let wnx = ctx.mem_alloc_shared(2, words * 8);
                let wvis = ctx.mem_alloc_shared(3, words * 8);
                let wtmp = ctx.mem_alloc(1024);
                // tasklet 0 stages the bit-vectors MRAM→WRAM
                if ctx.tasklet_id == 0 {
                    let mut off = 0;
                    while off < words * 8 {
                        let take = (words * 8 - off).min(1024);
                        ctx.mram_read(fr_off + off, wfr + off, take);
                        ctx.mram_read(nx_off + off, wnx + off, take);
                        ctx.mram_read(vis_off + off, wvis + off, take);
                        off += take;
                    }
                    // visited |= frontier (mark current level as seen)
                    let fr: Vec<u64> = ctx.wram_get(wfr, words);
                    let mut vis: Vec<u64> = ctx.wram_get(wvis, words);
                    for (a, b) in vis.iter_mut().zip(&fr) {
                        *a |= *b;
                    }
                    ctx.wram_set(wvis, &vis);
                    ctx.charge_ops(DType::U64, Op::Bitwise, words as u64);
                }
                ctx.barrier(0);

                let fr: Vec<u64> = ctx.wram_get(wfr, words);
                let vis: Vec<u64> = ctx.wram_get(wvis, words);
                let my = chunk_ranges(n_rows, ctx.n_tasklets as usize)
                    [ctx.tasklet_id as usize]
                    .clone();
                for lr in my {
                    let gv = rows.start + lr;
                    ctx.charge_ops(DType::U64, Op::Bitwise, 1);
                    if fr[gv / 64] & (1 << (gv % 64)) == 0 {
                        continue;
                    }
                    // stream this vertex's neighbor list
                    // row_ptr pair (aligned fetch)
                    let rp0 = (lr * 4) & !7;
                    ctx.mram_read(rp_off + rp0, wtmp, 16.min(1024));
                    let wv: Vec<u32> = ctx.wram_get(wtmp, 4);
                    let idx = (lr * 4 - rp0) / 4;
                    let (s, e) = (wv[idx] as usize, wv[idx + 1] as usize);
                    ctx.compute(4);
                    let mut k = s;
                    while k < e {
                        let k0 = k & !1;
                        let cnt = (e - k).min(256 - (k - k0));
                        let span = (k - k0 + cnt + 1) & !1;
                        ctx.mram_read(ci_off + k0 * 4, wtmp, span * 4);
                        let nbrs: Vec<u32> = ctx.wram_get(wtmp, span);
                        for i in 0..cnt {
                            let w = nbrs[k - k0 + i] as usize;
                            // visited test + next-frontier update
                            if vis[w / 64] & (1 << (w % 64)) == 0 {
                                ctx.mutex_lock(0);
                                ctx.wram(|wr| {
                                    let words_mut = crate::util::pod::cast_slice_mut::<u64>(
                                        &mut wr[wnx..wnx + words * 8],
                                    );
                                    words_mut[w / 64] |= 1 << (w % 64);
                                });
                                ctx.charge_ops(DType::U64, Op::Bitwise, 2);
                                ctx.mutex_unlock(0);
                            }
                        }
                        ctx.compute(cnt as u64 * per_edge);
                        k += cnt;
                    }
                }

                ctx.barrier(1);
                // tasklet 0 writes back next + visited
                if ctx.tasklet_id == 0 {
                    let mut off = 0;
                    while off < words * 8 {
                        let take = (words * 8 - off).min(1024);
                        ctx.mram_write(wnx + off, nx_off + off, take);
                        ctx.mram_write(wvis + off, vis_off + off, take);
                        off += take;
                    }
                }
            });
            last_stats = stats;

            // host gathers per-DPU next frontiers and unions sequentially
            level += 1;
            let mut next = vec![0u64; words];
            let mut pull_ids: Vec<CmdId> = Vec::with_capacity(nd);
            for i in 0..nd {
                let part = sess.set.xfer(nx_sym).inter().from().one(i, words);
                if let Some(id) = sess.set.last_cmd() {
                    pull_ids.push(id);
                }
                for (a, b) in next.iter_mut().zip(&part) {
                    *a |= *b;
                }
                // zero the DPU's next-frontier for the following level
                sess.set.xfer(nx_sym).inter().to().one(i, &vec![0u64; words]);
            }
            // the union consumes only the pulls' host images: declared,
            // so the modeled merge overlaps the zeroing bus traffic
            sess.set
                .host_merge_dep((nd * words * 8) as u64, (nd * words) as u64, &pull_ids);
            prev_merge = sess.set.last_cmd().into_iter().collect();

            // strip already-visited, assign distances
            let mut any = false;
            for w in 0..words {
                let mut bits = next[w];
                // remove vertices already at a distance
                for b in 0..64 {
                    let vtx = w * 64 + b;
                    if bits & (1 << b) != 0 {
                        if vtx < v && dist[vtx] == u32::MAX {
                            dist[vtx] = level;
                            any = true;
                        } else {
                            bits &= !(1 << b);
                        }
                    }
                }
                next[w] = bits;
            }
            frontier = next;
            if !any {
                break;
            }
        }

        sess.state_mut::<BfsState>().cur = Some(BfsOut { root, dist });
        last_stats
    }

    fn retrieve(&self, sess: &mut Session, _ds: &Dataset) -> Output {
        let out = sess
            .state::<BfsState>()
            .cur
            .clone()
            .expect("BFS retrieve before any execute");
        Output::new(out)
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        let d = ds.get::<BfsData>();
        let o = out.get::<BfsOut>();
        o.dist == d.g.bfs_ref(o.root)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prim::common::PrimBench;

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        let r = Bfs.run(&rc);
        assert!(r.verified);
        assert!(r.breakdown.inter_dpu > 0.0, "BFS must pay inter-DPU sync");
    }

    #[test]
    fn inter_dpu_grows_with_dpus_key_obs_16() {
        let mk = |nd: u32| {
            let rc = RunConfig {
                n_dpus: nd,
                scale: 0.002,
                ..RunConfig::rank_default()
            };
            Bfs.run(&rc).breakdown.inter_dpu
        };
        assert!(mk(16) > mk(2), "frontier union cost scales with DPU count");
    }

    #[test]
    fn single_dpu_correct() {
        let rc = RunConfig {
            n_dpus: 1,
            n_tasklets: 8,
            scale: 0.001,
            ..RunConfig::rank_default()
        };
        assert!(Bfs.run(&rc).verified);
    }

    /// Multi-root serving: each warm request traverses from a fresh root
    /// against the resident graph, and verifies against the reference for
    /// *that* root.
    #[test]
    fn serves_fresh_roots_against_resident_graph() {
        let rc = RunConfig {
            n_dpus: 2,
            n_tasklets: 8,
            scale: 0.001,
            ..RunConfig::rank_default()
        };
        let ds = Bfs.prepare(&rc);
        let mut sess = rc.session();
        Bfs.load(&mut sess, &ds);
        let graph_bytes = sess.set.metrics.bytes_to_dpu;
        let mut roots = Vec::new();
        for req in Request::stream(rc.seed, 3) {
            let staged = Bfs.stage(&ds, &req);
            Bfs.execute(&mut sess, &ds, &req, staged);
            let out = Bfs.retrieve(&mut sess, &ds);
            assert!(Bfs.verify(&ds, &out), "request {}", req.id);
            roots.push(out.get::<BfsOut>().root);
        }
        assert_eq!(roots[0], ds.get::<BfsData>().max_degree_root);
        // warm CPU-DPU traffic is only the per-request bit-vector reset,
        // never the CSR slices
        let words = ds.get::<BfsData>().v.div_ceil(64) as u64;
        let resets = 3 * 2 * words * 8 * sess.set.n_dpus() as u64;
        assert_eq!(sess.set.metrics.bytes_to_dpu, graph_bytes + resets);
    }
}
