//! BFS — Breadth-First Search (§4.8). Graph processing; uint64 bit-vectors;
//! random access; barrier + mutex intra-DPU; **heavy inter-DPU
//! synchronization** — the frontier is unioned by the host after every
//! level, which is why BFS scales worst of the suite (§5.1/§5.2).
//!
//! Top-down: vertices are range-partitioned; every DPU keeps a local copy
//! of the visited bit-vector and produces a next-frontier bit-vector from
//! the neighbor lists of its owned frontier vertices (mutex-protected
//! updates).

use super::common::{BenchResult, BenchTraits, PrimBench, RunConfig};
use crate::arch::{isa, DType, Op};
use crate::coordinator::chunk_ranges;
use crate::dpu::Ctx;
use crate::util::data::rmat_graph;

/// loc-gowalla statistics: ~197 K vertices, ~1.9 M (directed) edges.
const PAPER_V: usize = 196_591;
const PAPER_E: usize = 1_900_654;

pub struct Bfs;

impl PrimBench for Bfs {
    fn name(&self) -> &'static str {
        "BFS"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Graph processing",
            sequential: true,
            strided: false,
            random: true,
            ops: "bitwise logic",
            dtype: "uint64_t",
            intra_sync: "barrier, mutex",
            inter_sync: true,
        }
    }

    fn run(&self, rc: &RunConfig) -> BenchResult {
        // keep the three WRAM bit-vectors (3 × V/8 bytes) plus per-tasklet
        // buffers inside the 64 KB WRAM: cap vertices at 96 K
        let v = rc.scaled(PAPER_V).min(96 * 1024);
        let e = rc.scaled(PAPER_E).min(v * 12);
        let g = rmat_graph(v, e, rc.seed);
        let src = (0..v).max_by_key(|&u| g.row_ptr[u + 1] - g.row_ptr[u]).unwrap_or(0);
        let dist_ref = g.bfs_ref(src);

        let mut set = rc.alloc();
        let nd = rc.n_dpus as usize;
        let parts = chunk_ranges(v, nd);
        let words = v.div_ceil(64);

        // input distribution: per-DPU CSR slices (serial copies — sizes
        // differ, §5.1.1). Fleet-wide symbols sized for the widest slice:
        //   rp_sym   rebased row_ptr (rows+1 u32)
        //   ci_sym   neighbor lists (u32)
        //   fr_sym   current frontier bit-vector (words u64)
        //   nx_sym   next frontier bit-vector
        //   vis_sym  visited bit-vector
        let max_rows = parts.iter().map(|r| r.len()).max().unwrap_or(0);
        let max_deg = parts
            .iter()
            .map(|r| (g.row_ptr[r.end] - g.row_ptr[r.start]) as usize)
            .max()
            .unwrap_or(0);
        let rp_sym = set.symbol::<u32>(max_rows + 1);
        let ci_sym = set.symbol::<u32>(max_deg);
        let fr_sym = set.symbol::<u64>(words);
        // next + visited adjacent, so both zero together in one transfer
        let nxvis_sym = set.symbol::<u64>(2 * words);
        let nx_sym = nxvis_sym.slice(0, words);
        let vis_sym = nxvis_sym.slice(words, words);
        let mut row_parts = Vec::with_capacity(nd);
        for (d, r) in parts.iter().enumerate() {
            let base = g.row_ptr[r.start];
            let rp: Vec<u32> = g.row_ptr[r.start..=r.end].iter().map(|x| x - base).collect();
            let deg = (g.row_ptr[r.end] - base) as usize;
            let ci = g.col_idx[base as usize..base as usize + deg].to_vec();
            set.xfer(rp_sym).to().one(d, &rp);
            set.xfer(ci_sym).to().one(d, &ci);
            // zero visited + next
            set.xfer(nxvis_sym).to().one(d, &vec![0u64; 2 * words]);
            row_parts.push(r.clone());
        }

        // frontier bootstrap
        let mut frontier = vec![0u64; words];
        frontier[src / 64] |= 1 << (src % 64);
        let mut dist = vec![u32::MAX; v];
        dist[src] = 0;
        let mut level = 0u32;
        let mut total_instrs = 0u64;

        let per_edge = (2 * isa::WRAM_LS + isa::ADDR_CALC) as u64
            + isa::op_instrs(DType::U64, Op::Bitwise) as u64;

        loop {
            // distribute the current frontier (inter-DPU phase). Each DPU
            // keeps a private copy it mutates, so these are serial per-DPU
            // copies, not a broadcast (matching the PrIM host loop).
            let frontier_now = frontier.clone();
            for d in 0..nd {
                set.xfer(fr_sym).inter().to().one(d, &frontier_now);
            }

            let (ci_off, fr_off, nx_off, vis_off) =
                (ci_sym.off(), fr_sym.off(), nx_sym.off(), vis_sym.off());
            let rp_off = rp_sym.off();
            let row_parts_ref = &row_parts;
            let stats = set.launch(rc.n_tasklets, |d, ctx: &mut Ctx| {
                let rows = row_parts_ref[d].clone();
                let n_rows = rows.len();
                // shared WRAM bit-vectors
                let wfr = ctx.mem_alloc_shared(1, words * 8);
                let wnx = ctx.mem_alloc_shared(2, words * 8);
                let wvis = ctx.mem_alloc_shared(3, words * 8);
                let wtmp = ctx.mem_alloc(1024);
                // tasklet 0 stages the bit-vectors MRAM→WRAM
                if ctx.tasklet_id == 0 {
                    let mut off = 0;
                    while off < words * 8 {
                        let take = (words * 8 - off).min(1024);
                        ctx.mram_read(fr_off + off, wfr + off, take);
                        ctx.mram_read(nx_off + off, wnx + off, take);
                        ctx.mram_read(vis_off + off, wvis + off, take);
                        off += take;
                    }
                    // visited |= frontier (mark current level as seen)
                    let fr: Vec<u64> = ctx.wram_get(wfr, words);
                    let mut vis: Vec<u64> = ctx.wram_get(wvis, words);
                    for (a, b) in vis.iter_mut().zip(&fr) {
                        *a |= *b;
                    }
                    ctx.wram_set(wvis, &vis);
                    ctx.charge_ops(DType::U64, Op::Bitwise, words as u64);
                }
                ctx.barrier(0);

                let fr: Vec<u64> = ctx.wram_get(wfr, words);
                let vis: Vec<u64> = ctx.wram_get(wvis, words);
                let my = chunk_ranges(n_rows, ctx.n_tasklets as usize)
                    [ctx.tasklet_id as usize]
                    .clone();
                for lr in my {
                    let gv = rows.start + lr;
                    ctx.charge_ops(DType::U64, Op::Bitwise, 1);
                    if fr[gv / 64] & (1 << (gv % 64)) == 0 {
                        continue;
                    }
                    // stream this vertex's neighbor list
                    // row_ptr pair (aligned fetch)
                    let rp0 = (lr * 4) & !7;
                    ctx.mram_read(rp_off + rp0, wtmp, 16.min(1024));
                    let wv: Vec<u32> = ctx.wram_get(wtmp, 4);
                    let idx = (lr * 4 - rp0) / 4;
                    let (s, e) = (wv[idx] as usize, wv[idx + 1] as usize);
                    ctx.compute(4);
                    let mut k = s;
                    while k < e {
                        let k0 = k & !1;
                        let cnt = (e - k).min(256 - (k - k0));
                        let span = (k - k0 + cnt + 1) & !1;
                        ctx.mram_read(ci_off + k0 * 4, wtmp, span * 4);
                        let nbrs: Vec<u32> = ctx.wram_get(wtmp, span);
                        for i in 0..cnt {
                            let w = nbrs[k - k0 + i] as usize;
                            // visited test + next-frontier update
                            if vis[w / 64] & (1 << (w % 64)) == 0 {
                                ctx.mutex_lock(0);
                                ctx.wram(|wr| {
                                    let words_mut = crate::util::pod::cast_slice_mut::<u64>(
                                        &mut wr[wnx..wnx + words * 8],
                                    );
                                    words_mut[w / 64] |= 1 << (w % 64);
                                });
                                ctx.charge_ops(DType::U64, Op::Bitwise, 2);
                                ctx.mutex_unlock(0);
                            }
                        }
                        ctx.compute(cnt as u64 * per_edge);
                        k += cnt;
                    }
                }

                ctx.barrier(1);
                // tasklet 0 writes back next + visited
                if ctx.tasklet_id == 0 {
                    let mut off = 0;
                    while off < words * 8 {
                        let take = (words * 8 - off).min(1024);
                        ctx.mram_write(wnx + off, nx_off + off, take);
                        ctx.mram_write(wvis + off, vis_off + off, take);
                        off += take;
                    }
                }
            });
            total_instrs += stats.total_instrs();

            // host gathers per-DPU next frontiers and unions sequentially
            level += 1;
            let mut next = vec![0u64; words];
            for d in 0..nd {
                let part = set.xfer(nx_sym).inter().from().one(d, words);
                for (a, b) in next.iter_mut().zip(&part) {
                    *a |= *b;
                }
                // zero the DPU's next-frontier for the following level
                set.xfer(nx_sym).inter().to().one(d, &vec![0u64; words]);
            }
            set.host_merge((nd * words * 8) as u64, (nd * words) as u64);

            // strip already-visited, assign distances
            let mut any = false;
            for w in 0..words {
                let mut bits = next[w];
                // remove vertices already at a distance
                for b in 0..64 {
                    let vtx = w * 64 + b;
                    if bits & (1 << b) != 0 {
                        if vtx < v && dist[vtx] == u32::MAX {
                            dist[vtx] = level;
                            any = true;
                        } else {
                            bits &= !(1 << b);
                        }
                    }
                }
                next[w] = bits;
            }
            frontier = next;
            if !any {
                break;
            }
        }

        let verified = dist == dist_ref;

        BenchResult {
            name: self.name(),
            breakdown: set.metrics,
            verified,
            work_items: g.n_edges() as u64,
            dpu_instrs: total_instrs,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        let r = Bfs.run(&rc);
        assert!(r.verified);
        assert!(r.breakdown.inter_dpu > 0.0, "BFS must pay inter-DPU sync");
    }

    #[test]
    fn inter_dpu_grows_with_dpus_key_obs_16() {
        let mk = |nd: u32| {
            let rc = RunConfig {
                n_dpus: nd,
                scale: 0.002,
                ..RunConfig::rank_default()
            };
            Bfs.run(&rc).breakdown.inter_dpu
        };
        assert!(mk(16) > mk(2), "frontier union cost scales with DPU count");
    }

    #[test]
    fn single_dpu_correct() {
        let rc = RunConfig {
            n_dpus: 1,
            n_tasklets: 8,
            scale: 0.001,
            ..RunConfig::rank_default()
        };
        assert!(Bfs.run(&rc).verified);
    }
}
