//! SpMV — Sparse Matrix-Vector Multiply (§4.3). Sparse linear algebra;
//! float; CSR; sequential + random access; no synchronization primitives,
//! but serial transfers (per-DPU sizes differ) and heavy float
//! multiplication — the reasons SpMV is one of the three benchmarks where
//! PIM loses to the CPU (§5.2).
//!
//! Lifecycle: the CSR slices and the replicated `x` vector are resident;
//! warm requests re-execute the multiply (streaming workload).

use super::common::{BenchTraits, RunConfig};
use super::workload::{Dataset, Output, Request, Staged, Workload};
use crate::arch::{isa, DType, Op};
use crate::coordinator::{chunk_ranges, LaunchStats, Session, Symbol};
use crate::dpu::Ctx;
use crate::util::data::{banded_matrix, Csr};
use std::ops::Range;

/// bcsstk30 statistics: n = 28,924, ~2.04 M nonzeros (~70/row, banded).
const PAPER_N: usize = 28_924;
const BAND: usize = 48;
const FILL: f64 = 0.72;
const BLOCK: usize = 1024;

pub struct Spmv;

pub struct SpmvData {
    mat: Csr,
    x: Vec<f32>,
    y_ref: Vec<f32>,
    n: usize,
    row_parts: Vec<Range<usize>>,
}

#[derive(Clone, Copy)]
struct SpmvSyms {
    x_sym: Symbol<f32>,
    rp_sym: Symbol<u32>,
    ci_sym: Symbol<u32>,
    va_sym: Symbol<f32>,
    y_sym: Symbol<f32>,
}

struct SpmvState {
    syms: SpmvSyms,
}

#[derive(Clone, Debug, PartialEq)]
pub struct SpmvOut {
    pub y: Vec<f32>,
}

impl Workload for Spmv {
    fn name(&self) -> &'static str {
        "SpMV"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Sparse linear algebra",
            sequential: true,
            strided: false,
            random: true,
            ops: "add, mul",
            dtype: "float",
            intra_sync: "",
            inter_sync: false,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        let n = rc.scaled(PAPER_N);
        let mat: Csr = banded_matrix(n, BAND, FILL, rc.seed);
        let x: Vec<f32> = (0..n).map(|i| (i % 97) as f32 * 0.25).collect();
        let y_ref = mat.spmv_ref(&x);
        let row_parts = chunk_ranges(n, rc.n_dpus as usize);
        let work = mat.nnz() as u64;
        Dataset::new(work, SpmvData { mat, x, y_ref, n, row_parts })
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        let d = ds.get::<SpmvData>();
        let nd = sess.set.n_dpus() as usize;
        assert_eq!(nd, d.row_parts.len(), "session fleet must match the prepared dataset");
        // symbol capacities: the widest per-DPU CSR slice (symbols live at
        // one fleet-wide offset, like linker-placed SDK symbols)
        let max_rows = d.row_parts.iter().map(|r| r.len()).max().unwrap_or(0);
        let max_nnz = d
            .row_parts
            .iter()
            .map(|r| (d.mat.row_ptr[r.end] - d.mat.row_ptr[r.start]) as usize)
            .max()
            .unwrap_or(0);
        let x_sym = sess.set.symbol::<f32>(d.n);
        let rp_sym = sess.set.symbol::<u32>(max_rows + 1);
        let ci_sym = sess.set.symbol::<u32>(max_nnz);
        let va_sym = sess.set.symbol::<f32>(max_nnz);
        let y_sym = sess.set.symbol::<f32>(max_rows * 2);

        // x replicated on every DPU (broadcast); CSR pieces are serial
        // per-DPU copies because sizes differ (§5.1.1)
        sess.set.xfer(x_sym).to().broadcast(&d.x);
        for (i, r) in d.row_parts.iter().enumerate() {
            let rp_raw: Vec<u32> = d.mat.row_ptr[r.start..=r.end].to_vec();
            let base = rp_raw[0];
            let rp: Vec<u32> = rp_raw.iter().map(|v| v - base).collect();
            let nnz = (d.mat.row_ptr[r.end] - d.mat.row_ptr[r.start]) as usize;
            let ci = d.mat.col_idx[base as usize..base as usize + nnz].to_vec();
            let vals = d.mat.values[base as usize..base as usize + nnz].to_vec();
            sess.set.xfer(rp_sym).to().one(i, &rp);
            sess.set.xfer(ci_sym).to().one(i, &ci);
            sess.set.xfer(va_sym).to().one(i, &vals);
        }
        sess.put_state(SpmvState {
            syms: SpmvSyms { x_sym, rp_sym, ci_sym, va_sym, y_sym },
        });
        sess.mark_loaded("SpMV");
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        _req: &Request,
        _staged: Staged,
    ) -> LaunchStats {
        let d = ds.get::<SpmvData>();
        let syms = sess.state::<SpmvState>().syms;
        let (x_off, rp_off, ci_off, va_off, y_off) = (
            syms.x_sym.off(),
            syms.rp_sym.off(),
            syms.ci_sym.off(),
            syms.va_sym.off(),
            syms.y_sym.off(),
        );
        let arch = sess.set.cfg.dpu;
        let per_nnz_instrs = (2 * isa::WRAM_LS + isa::ADDR_CALC + isa::LOOP_CTRL) as u64
            + isa::op_instrs_for(&arch, DType::F32, Op::Mul) as u64
            + isa::op_instrs_for(&arch, DType::F32, Op::Add) as u64;
        let row_parts = &d.row_parts;
        sess.launch_seq(sess.n_tasklets, |dpu, ctx: &mut Ctx| {
            let rows = row_parts[dpu].clone();
            let n_rows = rows.len();
            let wrp = ctx.mem_alloc(BLOCK);
            let wci = ctx.mem_alloc(BLOCK);
            let wva = ctx.mem_alloc(BLOCK);
            let wx = ctx.mem_alloc(8);
            let wy = ctx.mem_alloc(8);
            let my = chunk_ranges(n_rows, ctx.n_tasklets as usize)[ctx.tasklet_id as usize].clone();
            for r in my {
                // row extent (row_ptr is sequential: small cached reads)
                let rp_byte = rp_off + r * 4 & !7;
                ctx.mram_read(rp_byte, wrp, 8);
                let words: Vec<u32> = ctx.wram_get(wrp, 2);
                let (s, e) = if (rp_off + r * 4) % 8 == 0 {
                    (words[0] as usize, words[1] as usize)
                } else {
                    // unaligned pair: fetch next word too
                    ctx.mram_read(rp_byte + 8, wrp, 8);
                    let w2: Vec<u32> = ctx.wram_get(wrp, 2);
                    (words[1] as usize, w2[0] as usize)
                };
                ctx.compute(4);
                let mut acc = 0f32;
                let mut k = s;
                while k < e {
                    let k0 = k & !1; // 8-byte-aligned element index
                    let avail = BLOCK / 4 - (k - k0);
                    let cnt = (e - k).min(avail);
                    let span = (k - k0 + cnt + 1) & !1; // even element count
                    ctx.mram_read(ci_off + k0 * 4, wci, span * 4);
                    ctx.mram_read(va_off + k0 * 4, wva, span * 4);
                    let cis: Vec<u32> = ctx.wram_get(wci, span);
                    let vas: Vec<f32> = ctx.wram_get(wva, span);
                    for i in 0..cnt {
                        let ci = cis[k - k0 + i] as usize;
                        let va = vas[k - k0 + i];
                        // random-access x element: fine-grained 8-B DMA
                        ctx.mram_read((x_off + ci * 4) & !7, wx, 8);
                        let xw: Vec<f32> = ctx.wram_get(wx, 2);
                        let xv = xw[(ci * 4 % 8) / 4];
                        acc += va * xv;
                    }
                    ctx.compute(cnt as u64 * per_nnz_instrs);
                    k += cnt;
                }
                ctx.wram_set(wy, &[acc, 0.0]);
                ctx.mram_write(wy, y_off + r * 8, 8);
            }
        })
    }

    fn retrieve(&self, sess: &mut Session, ds: &Dataset) -> Output {
        let d = ds.get::<SpmvData>();
        let y_sym = sess.state::<SpmvState>().syms.y_sym;
        // serial result retrieval (per paper)
        let mut y = vec![0f32; d.n];
        for (i, rows) in d.row_parts.iter().enumerate() {
            let pairs = sess.set.xfer(y_sym).from().one(i, rows.len() * 2);
            for (k, r) in rows.clone().enumerate() {
                y[r] = pairs[k * 2];
            }
        }
        Output::new(SpmvOut { y })
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        let d = ds.get::<SpmvData>();
        let o = out.get::<SpmvOut>();
        o.y.len() == d.y_ref.len()
            && o.y
                .iter()
                .zip(&d.y_ref)
                .all(|(got, want)| (got - want).abs() <= 1e-3 * (1.0 + want.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prim::common::PrimBench;

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.01,
            ..RunConfig::rank_default()
        };
        let r = Spmv.run(&rc);
        assert!(r.verified);
    }

    #[test]
    fn float_mul_dominates_time() {
        // SpMV per-nnz cost should dwarf VA per-element cost (f32 mul = 178)
        let rc = RunConfig {
            n_dpus: 2,
            scale: 0.01,
            ..RunConfig::rank_default()
        };
        let r = Spmv.run(&rc);
        let per_nnz = r.breakdown.dpu / r.work_items as f64;
        let v = super::super::va::Va.run(&rc);
        let per_elem = v.breakdown.dpu / v.work_items as f64;
        assert!(per_nnz > 10.0 * per_elem);
    }
}
