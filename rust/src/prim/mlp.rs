//! MLP — Multilayer Perceptron inference (§4.9). Neural networks; int32;
//! sequential; each of the 3 fully-connected layers is a GEMV + ReLU
//! (reusing the GEMV kernel); between layers the host gathers the output
//! vector chunks and redistributes them as the next layer's input —
//! the inter-DPU phase that burdens MLP at scale (§5.1).

use super::common::{BenchResult, BenchTraits, PrimBench, RunConfig};
use super::gemv::gemv_kernel;
use crate::dpu::Ctx;
use crate::util::Rng;

/// Paper dataset (Table 3, 1 DPU – 1 rank): 3 layers × 2 K neurons.
const PAPER_NEURONS: usize = 2048;
const LAYERS: usize = 3;

pub struct Mlp;

impl PrimBench for Mlp {
    fn name(&self) -> &'static str {
        "MLP"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Neural networks",
            sequential: true,
            strided: false,
            random: false,
            ops: "add, mul, compare",
            dtype: "int32_t",
            intra_sync: "",
            inter_sync: true,
        }
    }

    fn run(&self, rc: &RunConfig) -> BenchResult {
        let nd = rc.n_dpus as usize;
        // square layers; dimension must be a multiple of 256 (DMA blocks)
        // and of the DPU count (row partitioning)
        let unit = 256 * nd / gcd(256, nd);
        let m = rc.scaled(PAPER_NEURONS).div_ceil(unit) * unit;
        let mut rng = Rng::new(rc.seed);
        // small weights so int32 accumulation stays far from overflow
        let weights: Vec<Vec<u32>> =
            (0..LAYERS).map(|_| (0..m * m).map(|_| rng.below(5) as u32).collect()).collect();
        let x0: Vec<u32> = (0..m).map(|_| rng.below(9) as u32).collect();

        // reference forward pass
        let mut h = x0.clone();
        for w in &weights {
            let mut next = vec![0u32; m];
            for (r, out) in next.iter_mut().enumerate() {
                let mut acc: u32 = 0;
                for c in 0..m {
                    acc = acc.wrapping_add(w[r * m + c].wrapping_mul(h[c]));
                }
                *out = if (acc as i32) < 0 { 0 } else { acc };
            }
            h = next;
        }
        let y_ref = h;

        let mut set = rc.alloc();
        let rows_per = m / nd;
        // MRAM layout per DPU: W1 | W2 | W3 | x | y
        let w_syms: Vec<_> = (0..LAYERS).map(|_| set.symbol::<u32>(rows_per * m)).collect();
        let x_sym = set.symbol::<u32>(m);
        let y_sym = set.symbol::<u32>(rows_per * 2);
        for (l, w) in weights.iter().enumerate() {
            let bufs: Vec<Vec<u32>> =
                (0..nd).map(|d| w[d * rows_per * m..(d + 1) * rows_per * m].to_vec()).collect();
            set.xfer(w_syms[l]).to().equal(&bufs);
        }
        set.xfer(x_sym).to().broadcast(&x0);

        let mut total_instrs = 0u64;
        for l in 0..LAYERS {
            let w_sym = w_syms[l];
            let stats = set.launch_seq(rc.n_tasklets, |_d, ctx: &mut Ctx| {
                gemv_kernel(ctx, rows_per, m, w_sym.off(), x_sym.off(), y_sym.off(), true);
            });
            total_instrs += stats.total_instrs();
            if l + 1 < LAYERS {
                // host: gather y chunks, rebuild the vector, redistribute
                let parts = set.xfer(y_sym).inter().from().all();
                let next: Vec<u32> =
                    parts.iter().flat_map(|p| p.iter().step_by(2).copied()).collect();
                set.host_merge((m * 4) as u64, m as u64);
                set.xfer(x_sym).inter().to().broadcast(&next);
            }
        }

        let out = set.xfer(y_sym).from().all();
        let y: Vec<u32> = out.iter().flat_map(|p| p.iter().step_by(2).copied()).collect();
        let verified = y == y_ref;

        BenchResult {
            name: self.name(),
            breakdown: set.metrics,
            verified,
            work_items: (LAYERS * m * m) as u64,
            dpu_instrs: total_instrs,
        }
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.06,
            ..RunConfig::rank_default()
        };
        let r = Mlp.run(&rc);
        assert!(r.verified);
        assert!(r.breakdown.inter_dpu > 0.0, "layer exchange is inter-DPU");
    }

    #[test]
    fn single_dpu_no_distribution_overhead() {
        let rc = RunConfig {
            n_dpus: 1,
            scale: 0.06,
            ..RunConfig::rank_default()
        };
        assert!(Mlp.run(&rc).verified);
    }
}
