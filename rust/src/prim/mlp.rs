//! MLP — Multilayer Perceptron inference (§4.9). Neural networks; int32;
//! sequential; each of the 3 fully-connected layers is a GEMV + ReLU
//! (reusing the GEMV kernel); between layers the host gathers the output
//! vector chunks and redistributes them as the next layer's input —
//! the inter-DPU phase that burdens MLP at scale (§5.1).
//!
//! Lifecycle: the weight matrices are resident (the classic
//! inference-serving shape); each request broadcasts a fresh input vector
//! and runs the 3-layer forward pass. The input vector is
//! double-buffered by request parity and every layer launch declares its
//! weight/activation footprint, so in an async command-queue batch the
//! next inference's input broadcast hides under the current forward
//! pass, and the inter-layer host merge (declared to depend only on its
//! activation pull) overlaps later bus traffic.

use super::common::{BenchTraits, RunConfig};
use super::gemv::gemv_kernel;
use super::workload::{Dataset, Output, Request, Staged, Workload};
use crate::coordinator::{Access, CmdId, LaunchStats, Session, Symbol};
use crate::dpu::Ctx;
use crate::util::Rng;

/// Paper dataset (Table 3, 1 DPU – 1 rank): 3 layers × 2 K neurons.
const PAPER_NEURONS: usize = 2048;
const LAYERS: usize = 3;

pub struct Mlp;

pub struct MlpData {
    weights: Vec<Vec<u32>>,
    m: usize,
    rows_per: usize,
}

struct MlpState {
    w_syms: Vec<Symbol<u32>>,
    /// Double-buffered activation vectors, indexed by `request id % 2`.
    x_syms: [Symbol<u32>; 2],
    y_sym: Symbol<u32>,
    cur_x: Vec<u32>,
}

pub struct MlpStaged {
    pub x0: Vec<u32>,
}

/// Retrieved result: the request's input and the final layer activations.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MlpOut {
    pub x0: Vec<u32>,
    pub y: Vec<u32>,
}

impl Workload for Mlp {
    fn name(&self) -> &'static str {
        "MLP"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Neural networks",
            sequential: true,
            strided: false,
            random: false,
            ops: "add, mul, compare",
            dtype: "int32_t",
            intra_sync: "",
            inter_sync: true,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        let nd = rc.n_dpus as usize;
        // square layers; dimension must be a multiple of 256 (DMA blocks)
        // and of the DPU count (row partitioning)
        let unit = 256 * nd / gcd(256, nd);
        let m = rc.scaled(PAPER_NEURONS).div_ceil(unit) * unit;
        let mut rng = Rng::new(rc.seed);
        // small weights so int32 accumulation stays far from overflow
        let weights: Vec<Vec<u32>> =
            (0..LAYERS).map(|_| (0..m * m).map(|_| rng.below(5) as u32).collect()).collect();
        Dataset::new((LAYERS * m * m) as u64, MlpData { weights, m, rows_per: m / nd })
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        let d = ds.get::<MlpData>();
        let nd = sess.set.n_dpus() as usize;
        assert_eq!(d.rows_per * nd, d.m, "session fleet must match the dataset");
        // MRAM layout per DPU: W1 | W2 | W3 | x0 | x1 | y
        let w_syms: Vec<Symbol<u32>> =
            (0..LAYERS).map(|_| sess.set.symbol::<u32>(d.rows_per * d.m)).collect();
        let x_syms = [sess.set.symbol::<u32>(d.m), sess.set.symbol::<u32>(d.m)];
        let y_sym = sess.set.symbol::<u32>(d.rows_per * 2);
        for (l, w) in d.weights.iter().enumerate() {
            let bufs: Vec<Vec<u32>> = (0..nd)
                .map(|i| w[i * d.rows_per * d.m..(i + 1) * d.rows_per * d.m].to_vec())
                .collect();
            sess.set.xfer(w_syms[l]).to().equal(&bufs);
        }
        sess.put_state(MlpState { w_syms, x_syms, y_sym, cur_x: Vec::new() });
        sess.mark_loaded("MLP");
    }

    fn stage(&self, ds: &Dataset, req: &Request) -> Staged {
        let d = ds.get::<MlpData>();
        let mut rng = Rng::new(req.seed);
        let x0: Vec<u32> = (0..d.m).map(|_| rng.below(9) as u32).collect();
        Staged::new(MlpStaged { x0 })
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        req: &Request,
        staged: Staged,
    ) -> LaunchStats {
        let d = ds.get::<MlpData>();
        let MlpStaged { x0 } = staged.take::<MlpStaged>();
        let (w_syms, x_sym, y_sym) = {
            let st = sess.state::<MlpState>();
            (st.w_syms.clone(), st.x_syms[(req.id % 2) as usize], st.y_sym)
        };
        let (m, rows_per) = (d.m, d.rows_per);
        sess.set.xfer(x_sym).to().broadcast(&x0);

        let mut last_stats = LaunchStats::default();
        for (l, w_sym) in w_syms.iter().copied().enumerate() {
            let acc = Access::new()
                .read(w_sym.region())
                .read(x_sym.region())
                .write(y_sym.region());
            last_stats = sess.launch_seq_acc(acc, sess.n_tasklets, move |_d, ctx: &mut Ctx| {
                gemv_kernel(ctx, rows_per, m, w_sym.off(), x_sym.off(), y_sym.off(), true);
            });
            if l + 1 < LAYERS {
                // host: gather y chunks, rebuild the vector, redistribute.
                // The merge consumes only the pull's host image, and the
                // redistributed broadcast carries the merge's output —
                // declared so the async timeline gets the true data flow.
                let parts = sess.set.xfer(y_sym).inter().from().all();
                let pull_dep: Vec<CmdId> = sess.set.last_cmd().into_iter().collect();
                let next: Vec<u32> =
                    parts.iter().flat_map(|p| p.iter().step_by(2).copied()).collect();
                sess.set.host_merge_dep((m * 4) as u64, m as u64, &pull_dep);
                let merge_dep: Vec<CmdId> = sess.set.last_cmd().into_iter().collect();
                sess.set.xfer(x_sym).inter().after(&merge_dep).to().broadcast(&next);
            }
        }
        sess.state_mut::<MlpState>().cur_x = x0;
        last_stats
    }

    fn retrieve(&self, sess: &mut Session, _ds: &Dataset) -> Output {
        let y_sym = sess.state::<MlpState>().y_sym;
        let out = sess.set.xfer(y_sym).from().all();
        let y: Vec<u32> = out.iter().flat_map(|p| p.iter().step_by(2).copied()).collect();
        Output::new(MlpOut { x0: sess.state::<MlpState>().cur_x.clone(), y })
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        let d = ds.get::<MlpData>();
        let o = out.get::<MlpOut>();
        if o.x0.len() != d.m || o.y.len() != d.m {
            return false;
        }
        // reference forward pass
        let mut h = o.x0.clone();
        for w in &d.weights {
            let mut next = vec![0u32; d.m];
            for (r, out) in next.iter_mut().enumerate() {
                let mut acc: u32 = 0;
                for c in 0..d.m {
                    acc = acc.wrapping_add(w[r * d.m + c].wrapping_mul(h[c]));
                }
                *out = if (acc as i32) < 0 { 0 } else { acc };
            }
            h = next;
        }
        o.y == h
    }
}

fn gcd(a: usize, b: usize) -> usize {
    if b == 0 {
        a
    } else {
        gcd(b, a % b)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prim::common::PrimBench;

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.06,
            ..RunConfig::rank_default()
        };
        let r = Mlp.run(&rc);
        assert!(r.verified);
        assert!(r.breakdown.inter_dpu > 0.0, "layer exchange is inter-DPU");
    }

    #[test]
    fn single_dpu_no_distribution_overhead() {
        let rc = RunConfig {
            n_dpus: 1,
            scale: 0.06,
            ..RunConfig::rank_default()
        };
        assert!(Mlp.run(&rc).verified);
    }

    /// Inference serving: the weights load once; every request runs the
    /// forward pass on a fresh input and verifies.
    #[test]
    fn weight_load_amortizes_across_inferences() {
        let rc = RunConfig {
            n_dpus: 2,
            scale: 0.06,
            ..RunConfig::rank_default()
        };
        let ds = Mlp.prepare(&rc);
        let mut sess = rc.session();
        Mlp.load(&mut sess, &ds);
        let weight_bytes = sess.set.metrics.bytes_to_dpu;
        for req in Request::stream(rc.seed, 2) {
            let staged = Mlp.stage(&ds, &req);
            Mlp.execute(&mut sess, &ds, &req, staged);
            let out = Mlp.retrieve(&mut sess, &ds);
            assert!(Mlp.verify(&ds, &out), "request {}", req.id);
        }
        let m = ds.get::<MlpData>().m as u64;
        let x_bytes = 2 * sess.set.n_dpus() as u64 * m * 4;
        assert_eq!(sess.set.metrics.bytes_to_dpu, weight_bytes + x_bytes);
        // warm input is tiny next to the resident weights: m² × 3 vs m
        assert!(weight_bytes > 100 * x_bytes);
    }
}
