//! Scale-out variants of GEMV, SpMV, BFS, and MLP over a modeled
//! multi-machine cluster (`coordinator::cluster`).
//!
//! Each driver shards the paper workload across N machines of DPUs and
//! wires the cross-machine data flow through modeled collectives:
//!
//! * **GEMV** — row-sharded matrix; the input vector fans out from
//!   machine 0 over the network, result shards stream back, machine 0's
//!   host assembles the product.
//! * **SpMV** — row-sharded CSR with the full `x` replicated per
//!   machine; the output vector is combined with an **all-reduce**.
//! * **BFS** — vertex-partitioned; every level ends in a point-to-point
//!   **frontier exchange** between all machine pairs.
//! * **MLP** — row-sharded layer weights; between layers the activation
//!   shards are **all-gathered** so every machine rebuilds the full
//!   input vector of the next layer.
//!
//! Problem sizes are fixed per scale factor — independent of the
//! machine count — so sweeping `machines` measures strong scaling
//! (`harness::scaleout` turns this into 1→16-machine efficiency
//! curves). With one machine every collective degenerates to nothing
//! and the recorded program is bit-identical to a single-machine
//! `PimSet` queue session (see `tests/executor_equivalence.rs`).

use super::gemv::gemv_kernel;
use crate::arch::{isa, DType, Op, SystemConfig};
use crate::coordinator::{
    chunk_ranges, Access, Bucket, Cluster, ClusterConfig, CmdId, ExecChoice, NetModel,
    Telemetry, TimeBreakdown, TraceSink,
};
use crate::dpu::Ctx;
use crate::util::data::{banded_matrix, rmat_graph};
use crate::util::Rng;
use std::ops::Range;

/// The four sharded benchmarks, in reporting order.
pub const SCALEOUT_BENCHES: [&str; 4] = ["GEMV", "SpMV", "BFS", "MLP"];

/// Run configuration for one sharded benchmark.
#[derive(Clone, Debug)]
pub struct ScaleoutConfig {
    pub machines: u32,
    pub dpus_per_machine: u32,
    pub n_tasklets: u32,
    /// Dataset scale relative to the paper sizes (like `RunConfig`).
    pub scale: f64,
    pub seed: u64,
    pub exec: ExecChoice,
    pub net: NetModel,
    pub trace: Option<TraceSink>,
    /// Live telemetry registry (`--metrics`): per-link egress traffic,
    /// collective counters, and per-sync queue digests. `None` = off.
    pub metrics: Option<Telemetry>,
}

impl ScaleoutConfig {
    /// Defaults mirroring `RunConfig::rank_default`, shrunk per machine:
    /// 4 DPUs × 16 tasklets each, tenth-scale data.
    pub fn new(machines: u32) -> Self {
        ScaleoutConfig {
            machines,
            dpus_per_machine: 4,
            n_tasklets: 16,
            scale: 0.10,
            seed: 42,
            exec: ExecChoice::Auto,
            net: NetModel::default(),
            trace: None,
            metrics: None,
        }
    }

    /// Scale a paper size and round up to a multiple of `unit`. The
    /// unit never depends on the machine count, so every point of a
    /// machine sweep solves the same problem (strong scaling).
    fn sized(&self, paper_n: usize, unit: usize) -> usize {
        ((paper_n as f64 * self.scale) as usize).max(unit).div_ceil(unit) * unit
    }

    fn cluster(&self) -> Cluster {
        let mut cfg =
            ClusterConfig::new(SystemConfig::p21_rank(), self.machines, self.dpus_per_machine);
        cfg.net = self.net.clone();
        let mut c = Cluster::new(cfg, self.exec.build());
        if let Some(sink) = &self.trace {
            c = c.with_trace(sink.clone());
        }
        if let Some(tel) = &self.metrics {
            c = c.with_telemetry(tel.clone());
        }
        c
    }
}

/// Outcome of one sharded run.
#[derive(Clone, Debug)]
pub struct ScaleoutResult {
    pub name: &'static str,
    pub machines: u32,
    /// Output checked against the host reference.
    pub verified: bool,
    /// Modeled wall time of the scheduled cluster program — the number
    /// the efficiency curves are built from.
    pub makespan: f64,
    /// Summed per-machine buckets plus the cluster overlap credit.
    pub breakdown: TimeBreakdown,
    pub net_secs: f64,
    pub net_bytes: u64,
    pub work_items: u64,
}

/// Dispatch a sharded benchmark by (case-insensitive) name.
pub fn run_bench(name: &str, sc: &ScaleoutConfig) -> Option<ScaleoutResult> {
    match name.to_ascii_lowercase().as_str() {
        "gemv" => Some(gemv(sc)),
        "spmv" => Some(spmv(sc)),
        "bfs" => Some(bfs(sc)),
        "mlp" => Some(mlp(sc)),
        _ => None,
    }
}

// ------------------------------------------------------------------ GEMV

/// Fixed column count of the sharded GEMV (multiple of the kernel's
/// 256-element DMA block; half the paper's 1024 keeps sweeps fast).
const GEMV_COLS: usize = 512;

/// Row-sharded GEMV: machine `i` holds rows `[i·m/N, (i+1)·m/N)` split
/// equally over its DPUs; `x` fans out from machine 0 over the wire and
/// the result shards stream back for the final host assembly.
pub fn gemv(sc: &ScaleoutConfig) -> ScaleoutResult {
    let n_machines = sc.machines as usize;
    let nd = sc.dpus_per_machine as usize;
    let n = GEMV_COLS;
    let m = sc.sized(8192, 1024);
    assert_eq!(
        m % (n_machines * nd),
        0,
        "GEMV rows ({m}) must split evenly over {n_machines} machines x {nd} DPUs"
    );
    let rows_per_machine = m / n_machines;
    let rows_per_dpu = rows_per_machine / nd;
    let mut rng = Rng::new(sc.seed);
    let mat: Vec<u32> = (0..m * n).map(|_| rng.next_u32() >> 16).collect();
    let x: Vec<u32> = (0..n).map(|_| rng.next_u32() >> 16).collect();

    let mut c = sc.cluster();
    let mat_sym = c.symbol::<u32>(rows_per_dpu * n);
    let x_sym = c.symbol::<u32>(n);
    let y_sym = c.symbol::<u32>(rows_per_dpu * 2);

    // resident row shards
    for mi in 0..n_machines {
        let base = mi * rows_per_machine * n;
        let bufs: Vec<Vec<u32>> = (0..nd)
            .map(|d| mat[base + d * rows_per_dpu * n..base + (d + 1) * rows_per_dpu * n].to_vec())
            .collect();
        c.push_equal(mi as u32, Bucket::CpuDpu, mat_sym, &bufs, &[]);
    }

    // the input vector lives on machine 0's host: wire it to the others
    let x_bytes = (n * 4) as u64;
    let msgs: Vec<(u32, u32, u64)> =
        (1..n_machines).map(|j| (0u32, j as u32, x_bytes)).collect();
    let xin = c.exchange(&msgs, &vec![Vec::new(); n_machines]);

    let mut y = vec![0u32; m];
    let mut merge_deps: Vec<CmdId> = Vec::with_capacity(n_machines);
    for mi in 0..n_machines {
        let dep: Vec<CmdId> = if mi == 0 { Vec::new() } else { vec![xin[mi - 1]] };
        c.broadcast(mi as u32, Bucket::CpuDpu, x_sym, &x, &dep);
        let acc = Access::new()
            .read(mat_sym.region())
            .read(x_sym.region())
            .write(y_sym.region());
        let (moff, xoff, yoff) = (mat_sym.off(), x_sym.off(), y_sym.off());
        c.launch_seq_acc(mi as u32, acc, sc.n_tasklets, move |_d, ctx: &mut Ctx| {
            gemv_kernel(ctx, rows_per_dpu, n, moff, xoff, yoff, false);
        });
        let (parts, pid) =
            c.pull_equal(mi as u32, Bucket::DpuCpu, y_sym, rows_per_dpu * 2, &[]);
        for (d, p) in parts.iter().enumerate() {
            let row0 = mi * rows_per_machine + d * rows_per_dpu;
            for (k, v) in p.iter().step_by(2).enumerate() {
                y[row0 + k] = *v;
            }
        }
        if mi == 0 {
            merge_deps.push(pid);
        } else {
            // result shard streams back to machine 0 over the wire
            merge_deps.push(c.net_send(mi as u32, (rows_per_machine * 4) as u64, &[pid]));
        }
    }
    // machine 0's host assembles the product vector
    c.host_merge(0, (m * 4) as u64, m as u64, &merge_deps);
    c.sync();

    let mut verified = true;
    for r in 0..m {
        let mut acc: u32 = 0;
        for col in 0..n {
            acc = acc.wrapping_add(mat[r * n + col].wrapping_mul(x[col]));
        }
        if y[r] != acc {
            verified = false;
            break;
        }
    }
    result("GEMV", &c, verified, (m * n) as u64)
}

// ------------------------------------------------------------------ SpMV

/// Row-sharded SpMV with an all-reduce of the output vector: every
/// machine runs the CSR kernel on its row slice against a locally
/// replicated `x`, then the per-machine results are combined so each
/// machine ends holding the full `y` (the textbook all-reduce pattern).
pub fn spmv(sc: &ScaleoutConfig) -> ScaleoutResult {
    const BAND: usize = 48;
    const FILL: f64 = 0.72;
    const BLOCK: usize = 1024;
    let n_machines = sc.machines as usize;
    let nd = sc.dpus_per_machine as usize;
    let n = sc.sized(28_924, 64);
    let mat = banded_matrix(n, BAND, FILL, sc.seed);
    let x: Vec<f32> = (0..n).map(|i| (i % 97) as f32 * 0.25).collect();
    let y_ref = mat.spmv_ref(&x);

    // machine i owns DPU parts [i*nd, (i+1)*nd) of one global partition
    let parts = chunk_ranges(n, n_machines * nd);
    let max_rows = parts.iter().map(|r| r.len()).max().unwrap_or(0);
    let max_nnz = parts
        .iter()
        .map(|r| (mat.row_ptr[r.end] - mat.row_ptr[r.start]) as usize)
        .max()
        .unwrap_or(0);

    let mut c = sc.cluster();
    let x_sym = c.symbol::<f32>(n);
    let rp_sym = c.symbol::<u32>(max_rows + 1);
    let ci_sym = c.symbol::<u32>(max_nnz);
    let va_sym = c.symbol::<f32>(max_nnz);
    let y_sym = c.symbol::<f32>(max_rows * 2);

    // x fans out from machine 0, then replicates locally; CSR slices
    // are serial per-DPU copies (sizes differ, §5.1.1)
    let msgs: Vec<(u32, u32, u64)> =
        (1..n_machines).map(|j| (0u32, j as u32, (n * 4) as u64)).collect();
    let xin = c.exchange(&msgs, &vec![Vec::new(); n_machines]);
    for mi in 0..n_machines {
        let dep: Vec<CmdId> = if mi == 0 { Vec::new() } else { vec![xin[mi - 1]] };
        c.broadcast(mi as u32, Bucket::CpuDpu, x_sym, &x, &dep);
        for d in 0..nd {
            let r = &parts[mi * nd + d];
            let base = mat.row_ptr[r.start];
            let rp: Vec<u32> = mat.row_ptr[r.start..=r.end].iter().map(|v| v - base).collect();
            let nnz = (mat.row_ptr[r.end] - base) as usize;
            let ci = mat.col_idx[base as usize..base as usize + nnz].to_vec();
            let va = mat.values[base as usize..base as usize + nnz].to_vec();
            c.push_one(mi as u32, Bucket::CpuDpu, rp_sym, d, &rp, &[]);
            c.push_one(mi as u32, Bucket::CpuDpu, ci_sym, d, &ci, &[]);
            c.push_one(mi as u32, Bucket::CpuDpu, va_sym, d, &va, &[]);
        }
    }

    let arch = c.sets[0].cfg.dpu;
    let per_nnz_instrs = (2 * isa::WRAM_LS + isa::ADDR_CALC + isa::LOOP_CTRL) as u64
        + isa::op_instrs_for(&arch, DType::F32, Op::Mul) as u64
        + isa::op_instrs_for(&arch, DType::F32, Op::Add) as u64;

    let mut y = vec![0f32; n];
    let mut pull_ids: Vec<Vec<CmdId>> = vec![Vec::new(); n_machines];
    for mi in 0..n_machines {
        let my_parts: Vec<Range<usize>> = parts[mi * nd..(mi + 1) * nd].to_vec();
        let acc = Access::new()
            .read(x_sym.region())
            .read(rp_sym.region())
            .read(ci_sym.region())
            .read(va_sym.region())
            .write(y_sym.region());
        let (x_off, rp_off, ci_off, va_off, y_off) =
            (x_sym.off(), rp_sym.off(), ci_sym.off(), va_sym.off(), y_sym.off());
        let kparts = my_parts.clone();
        c.launch_seq_acc(mi as u32, acc, sc.n_tasklets, move |dpu, ctx: &mut Ctx| {
            let n_rows = kparts[dpu].len();
            let wrp = ctx.mem_alloc(BLOCK);
            let wci = ctx.mem_alloc(BLOCK);
            let wva = ctx.mem_alloc(BLOCK);
            let wx = ctx.mem_alloc(8);
            let wy = ctx.mem_alloc(8);
            let my =
                chunk_ranges(n_rows, ctx.n_tasklets as usize)[ctx.tasklet_id as usize].clone();
            for r in my {
                let rp_byte = rp_off + r * 4 & !7;
                ctx.mram_read(rp_byte, wrp, 8);
                let words: Vec<u32> = ctx.wram_get(wrp, 2);
                let (s, e) = if (rp_off + r * 4) % 8 == 0 {
                    (words[0] as usize, words[1] as usize)
                } else {
                    ctx.mram_read(rp_byte + 8, wrp, 8);
                    let w2: Vec<u32> = ctx.wram_get(wrp, 2);
                    (words[1] as usize, w2[0] as usize)
                };
                ctx.compute(4);
                let mut acc = 0f32;
                let mut k = s;
                while k < e {
                    let k0 = k & !1;
                    let avail = BLOCK / 4 - (k - k0);
                    let cnt = (e - k).min(avail);
                    let span = (k - k0 + cnt + 1) & !1;
                    ctx.mram_read(ci_off + k0 * 4, wci, span * 4);
                    ctx.mram_read(va_off + k0 * 4, wva, span * 4);
                    let cis: Vec<u32> = ctx.wram_get(wci, span);
                    let vas: Vec<f32> = ctx.wram_get(wva, span);
                    for i in 0..cnt {
                        let ci = cis[k - k0 + i] as usize;
                        let va = vas[k - k0 + i];
                        ctx.mram_read((x_off + ci * 4) & !7, wx, 8);
                        let xw: Vec<f32> = ctx.wram_get(wx, 2);
                        acc += va * xw[(ci * 4 % 8) / 4];
                    }
                    ctx.compute(cnt as u64 * per_nnz_instrs);
                    k += cnt;
                }
                ctx.wram_set(wy, &[acc, 0.0]);
                ctx.mram_write(wy, y_off + r * 8, 8);
            }
        });
        for (d, r) in my_parts.iter().enumerate() {
            let (pairs, pid) =
                c.pull_one(mi as u32, Bucket::DpuCpu, y_sym, d, r.len() * 2, &[]);
            for (k, row) in r.clone().enumerate() {
                y[row] = pairs[k * 2];
            }
            pull_ids[mi].push(pid);
        }
    }

    // all-reduce of the output vector: machine i owns reduced shard i
    let vparts = chunk_ranges(n, n_machines);
    let shard_bytes: Vec<u64> = vparts.iter().map(|r| (r.len() * 4) as u64).collect();
    let merge_ops: Vec<u64> = vparts
        .iter()
        .map(|r| (n_machines as u64 - 1) * r.len() as u64)
        .collect();
    c.all_reduce(&shard_bytes, &merge_ops, &pull_ids);
    c.sync();

    let verified = y.len() == y_ref.len()
        && y.iter()
            .zip(&y_ref)
            .all(|(got, want)| (got - want).abs() <= 1e-3 * (1.0 + want.abs()));
    result("SpMV", &c, verified, mat.nnz() as u64)
}

// ------------------------------------------------------------------- BFS

/// Vertex-partitioned BFS: machine `i` owns a contiguous vertex range
/// (further split over its DPUs) and produces a partial next-frontier
/// each level; the partials cross the wire in a point-to-point exchange
/// between every machine pair before the next level starts.
pub fn bfs(sc: &ScaleoutConfig) -> ScaleoutResult {
    let n_machines = sc.machines as usize;
    let nd = sc.dpus_per_machine as usize;
    // same WRAM cap as the single-machine BFS (3 bit-vectors resident)
    let v = sc.sized(196_591, 64).min(96 * 1024);
    let e = ((1_900_654.0 * sc.scale) as usize).min(v * 12);
    let g = rmat_graph(v, e, sc.seed);
    let root = (0..v).max_by_key(|&u| g.row_ptr[u + 1] - g.row_ptr[u]).unwrap_or(0);
    let words = v.div_ceil(64);

    let parts = chunk_ranges(v, n_machines * nd);
    let max_rows = parts.iter().map(|r| r.len()).max().unwrap_or(0);
    let max_deg = parts
        .iter()
        .map(|r| (g.row_ptr[r.end] - g.row_ptr[r.start]) as usize)
        .max()
        .unwrap_or(0);

    let mut c = sc.cluster();
    let rp_sym = c.symbol::<u32>(max_rows + 1);
    let ci_sym = c.symbol::<u32>(max_deg);
    let fr_sym = c.symbol::<u64>(words);
    let nxvis_sym = c.symbol::<u64>(2 * words);
    let nx_sym = nxvis_sym.slice(0, words);
    let vis_sym = nxvis_sym.slice(words, words);

    // resident CSR slices + zeroed next/visited vectors
    let zeros = vec![0u64; 2 * words];
    for mi in 0..n_machines {
        for d in 0..nd {
            let r = &parts[mi * nd + d];
            let base = g.row_ptr[r.start];
            let rp: Vec<u32> = g.row_ptr[r.start..=r.end].iter().map(|x| x - base).collect();
            let deg = (g.row_ptr[r.end] - base) as usize;
            let ci = g.col_idx[base as usize..base as usize + deg].to_vec();
            c.push_one(mi as u32, Bucket::CpuDpu, rp_sym, d, &rp, &[]);
            c.push_one(mi as u32, Bucket::CpuDpu, ci_sym, d, &ci, &[]);
            c.push_one(mi as u32, Bucket::CpuDpu, nxvis_sym, d, &zeros, &[]);
        }
    }

    let per_edge = (2 * isa::WRAM_LS + isa::ADDR_CALC) as u64
        + isa::op_instrs(DType::U64, Op::Bitwise) as u64;

    let mut frontier = vec![0u64; words];
    frontier[root / 64] |= 1 << (root % 64);
    let mut dist = vec![u32::MAX; v];
    dist[root] = 0;
    let mut level = 0u32;
    // what the next level's frontier scatter on machine j waits for:
    // its own union + every wire transfer destined to it
    let mut scatter_deps: Vec<Vec<CmdId>> = vec![Vec::new(); n_machines];
    loop {
        // distribute the current frontier (each DPU mutates a private
        // copy — per-DPU scatters, grouped per machine on the timeline)
        for mi in 0..n_machines {
            c.group_begin();
            for d in 0..nd {
                c.push_one(mi as u32, Bucket::InterDpu, fr_sym, d, &frontier, &scatter_deps[mi]);
            }
            c.group_end();
        }

        for mi in 0..n_machines {
            let my_parts: Vec<Range<usize>> = parts[mi * nd..(mi + 1) * nd].to_vec();
            let acc = Access::new()
                .read(rp_sym.region())
                .read(ci_sym.region())
                .read(fr_sym.region())
                .read(nxvis_sym.region())
                .write(nxvis_sym.region());
            let (rp_off, ci_off) = (rp_sym.off(), ci_sym.off());
            let (fr_off, nx_off, vis_off) = (fr_sym.off(), nx_sym.off(), vis_sym.off());
            c.launch_acc(mi as u32, acc, sc.n_tasklets, move |dpu, ctx: &mut Ctx| {
                let rows = my_parts[dpu].clone();
                let n_rows = rows.len();
                let wfr = ctx.mem_alloc_shared(1, words * 8);
                let wnx = ctx.mem_alloc_shared(2, words * 8);
                let wvis = ctx.mem_alloc_shared(3, words * 8);
                let wtmp = ctx.mem_alloc(1024);
                if ctx.tasklet_id == 0 {
                    let mut off = 0;
                    while off < words * 8 {
                        let take = (words * 8 - off).min(1024);
                        ctx.mram_read(fr_off + off, wfr + off, take);
                        ctx.mram_read(nx_off + off, wnx + off, take);
                        ctx.mram_read(vis_off + off, wvis + off, take);
                        off += take;
                    }
                    let fr: Vec<u64> = ctx.wram_get(wfr, words);
                    let mut vis: Vec<u64> = ctx.wram_get(wvis, words);
                    for (a, b) in vis.iter_mut().zip(&fr) {
                        *a |= *b;
                    }
                    ctx.wram_set(wvis, &vis);
                    ctx.charge_ops(DType::U64, Op::Bitwise, words as u64);
                }
                ctx.barrier(0);

                let fr: Vec<u64> = ctx.wram_get(wfr, words);
                let vis: Vec<u64> = ctx.wram_get(wvis, words);
                let my = chunk_ranges(n_rows, ctx.n_tasklets as usize)
                    [ctx.tasklet_id as usize]
                    .clone();
                for lr in my {
                    let gv = rows.start + lr;
                    ctx.charge_ops(DType::U64, Op::Bitwise, 1);
                    if fr[gv / 64] & (1 << (gv % 64)) == 0 {
                        continue;
                    }
                    let rp0 = (lr * 4) & !7;
                    ctx.mram_read(rp_off + rp0, wtmp, 16.min(1024));
                    let wv: Vec<u32> = ctx.wram_get(wtmp, 4);
                    let idx = (lr * 4 - rp0) / 4;
                    let (s, e) = (wv[idx] as usize, wv[idx + 1] as usize);
                    ctx.compute(4);
                    let mut k = s;
                    while k < e {
                        let k0 = k & !1;
                        let cnt = (e - k).min(256 - (k - k0));
                        let span = (k - k0 + cnt + 1) & !1;
                        ctx.mram_read(ci_off + k0 * 4, wtmp, span * 4);
                        let nbrs: Vec<u32> = ctx.wram_get(wtmp, span);
                        for i in 0..cnt {
                            let w = nbrs[k - k0 + i] as usize;
                            if vis[w / 64] & (1 << (w % 64)) == 0 {
                                ctx.mutex_lock(0);
                                ctx.wram(|wr| {
                                    let words_mut = crate::util::pod::cast_slice_mut::<u64>(
                                        &mut wr[wnx..wnx + words * 8],
                                    );
                                    words_mut[w / 64] |= 1 << (w % 64);
                                });
                                ctx.charge_ops(DType::U64, Op::Bitwise, 2);
                                ctx.mutex_unlock(0);
                            }
                        }
                        ctx.compute(cnt as u64 * per_edge);
                        k += cnt;
                    }
                }

                ctx.barrier(1);
                if ctx.tasklet_id == 0 {
                    let mut off = 0;
                    while off < words * 8 {
                        let take = (words * 8 - off).min(1024);
                        ctx.mram_write(wnx + off, nx_off + off, take);
                        ctx.mram_write(wvis + off, vis_off + off, take);
                        off += take;
                    }
                }
            });
        }

        // per-machine union of the partial next-frontiers
        level += 1;
        let mut next = vec![0u64; words];
        let mut merge_ids: Vec<CmdId> = Vec::with_capacity(n_machines);
        for mi in 0..n_machines {
            let mut pull_ids: Vec<CmdId> = Vec::with_capacity(nd);
            for d in 0..nd {
                let (part, pid) =
                    c.pull_one(mi as u32, Bucket::InterDpu, nx_sym, d, words, &[]);
                pull_ids.push(pid);
                for (a, b) in next.iter_mut().zip(&part) {
                    *a |= *b;
                }
                c.push_one(mi as u32, Bucket::InterDpu, nx_sym, d, &vec![0u64; words], &[]);
            }
            merge_ids.push(c.host_merge(
                mi as u32,
                (nd * words * 8) as u64,
                (nd * words) as u64,
                &pull_ids,
            ));
        }

        // frontier exchange: every machine wires its partial frontier
        // to every other machine before the next level may scatter
        let mut msgs: Vec<(u32, u32, u64)> = Vec::new();
        for i in 0..n_machines {
            for j in 0..n_machines {
                if i != j {
                    msgs.push((i as u32, j as u32, (words * 8) as u64));
                }
            }
        }
        let after: Vec<Vec<CmdId>> = merge_ids.iter().map(|&id| vec![id]).collect();
        let net_ids = c.exchange(&msgs, &after);
        for (deps, &mid) in scatter_deps.iter_mut().zip(&merge_ids) {
            deps.clear();
            deps.push(mid);
        }
        for (k, &(_, dst, _)) in msgs.iter().enumerate() {
            scatter_deps[dst as usize].push(net_ids[k]);
        }

        // host: strip visited vertices, assign distances
        let mut any = false;
        for w in 0..words {
            let mut bits = next[w];
            for b in 0..64 {
                let vtx = w * 64 + b;
                if bits & (1 << b) != 0 {
                    if vtx < v && dist[vtx] == u32::MAX {
                        dist[vtx] = level;
                        any = true;
                    } else {
                        bits &= !(1 << b);
                    }
                }
            }
            next[w] = bits;
        }
        frontier = next;
        if !any {
            break;
        }
    }
    c.sync();

    let verified = dist == g.bfs_ref(root);
    result("BFS", &c, verified, g.n_edges() as u64)
}

// ------------------------------------------------------------------- MLP

/// Row-sharded 3-layer MLP: every machine computes its activation shard
/// per layer, then an all-gather rebuilds the full vector everywhere
/// for the next layer — the collective the tentpole names for MLP.
pub fn mlp(sc: &ScaleoutConfig) -> ScaleoutResult {
    const LAYERS: usize = 3;
    let n_machines = sc.machines as usize;
    let nd = sc.dpus_per_machine as usize;
    // square layers, multiple of the kernel's 256-element block and of
    // every sweep point's DPU total
    let m = sc.sized(2048, 512);
    assert_eq!(
        m % (n_machines * nd),
        0,
        "MLP neurons ({m}) must split evenly over {n_machines} machines x {nd} DPUs"
    );
    let rows_per_machine = m / n_machines;
    let rows_per_dpu = rows_per_machine / nd;
    let mut rng = Rng::new(sc.seed);
    let weights: Vec<Vec<u32>> =
        (0..LAYERS).map(|_| (0..m * m).map(|_| rng.below(5) as u32).collect()).collect();
    let x0: Vec<u32> = (0..m).map(|_| rng.below(9) as u32).collect();

    let mut c = sc.cluster();
    let w_syms: Vec<_> = (0..LAYERS).map(|_| c.symbol::<u32>(rows_per_dpu * m)).collect();
    let x_sym = c.symbol::<u32>(m);
    let y_sym = c.symbol::<u32>(rows_per_dpu * 2);

    for mi in 0..n_machines {
        for (l, w) in weights.iter().enumerate() {
            let base = mi * rows_per_machine * m;
            let bufs: Vec<Vec<u32>> = (0..nd)
                .map(|d| w[base + d * rows_per_dpu * m..base + (d + 1) * rows_per_dpu * m].to_vec())
                .collect();
            c.push_equal(mi as u32, Bucket::CpuDpu, w_syms[l], &bufs, &[]);
        }
    }

    // the request's input fans out from machine 0, like GEMV
    let msgs: Vec<(u32, u32, u64)> =
        (1..n_machines).map(|j| (0u32, j as u32, (m * 4) as u64)).collect();
    let xin = c.exchange(&msgs, &vec![Vec::new(); n_machines]);
    let mut bcast_deps: Vec<Vec<CmdId>> = (0..n_machines)
        .map(|mi| if mi == 0 { Vec::new() } else { vec![xin[mi - 1]] })
        .collect();

    let mut h = x0.clone();
    for l in 0..LAYERS {
        let mut merge_ids: Vec<CmdId> = Vec::with_capacity(n_machines);
        let mut next = vec![0u32; m];
        for mi in 0..n_machines {
            c.broadcast(mi as u32, Bucket::CpuDpu, x_sym, &h, &bcast_deps[mi]);
            let w_sym = w_syms[l];
            let acc = Access::new()
                .read(w_sym.region())
                .read(x_sym.region())
                .write(y_sym.region());
            let (woff, xoff, yoff) = (w_sym.off(), x_sym.off(), y_sym.off());
            c.launch_seq_acc(mi as u32, acc, sc.n_tasklets, move |_d, ctx: &mut Ctx| {
                gemv_kernel(ctx, rows_per_dpu, m, woff, xoff, yoff, true);
            });
            let (parts, pid) =
                c.pull_equal(mi as u32, Bucket::InterDpu, y_sym, rows_per_dpu * 2, &[]);
            for (d, p) in parts.iter().enumerate() {
                let row0 = mi * rows_per_machine + d * rows_per_dpu;
                for (k, v) in p.iter().step_by(2).enumerate() {
                    next[row0 + k] = *v;
                }
            }
            // machine host rebuilds its own activation shard
            merge_ids.push(c.host_merge(
                mi as u32,
                (rows_per_machine * 4) as u64,
                rows_per_machine as u64,
                &[pid],
            ));
        }
        h = next;
        if l + 1 < LAYERS {
            // all-gather of the activation shards: the next layer's
            // broadcast on every machine waits for the whole collective
            let shard_bytes = vec![(rows_per_machine * 4) as u64; n_machines];
            let after: Vec<Vec<CmdId>> = merge_ids.iter().map(|&id| vec![id]).collect();
            let ag = c.all_gather(&shard_bytes, &after);
            bcast_deps = (0..n_machines)
                .map(|mi| {
                    let mut deps = ag.clone();
                    deps.push(merge_ids[mi]);
                    deps
                })
                .collect();
        }
    }
    c.sync();

    // reference forward pass
    let mut want = x0;
    for w in &weights {
        let mut nx = vec![0u32; m];
        for (r, out) in nx.iter_mut().enumerate() {
            let mut acc: u32 = 0;
            for col in 0..m {
                acc = acc.wrapping_add(w[r * m + col].wrapping_mul(want[col]));
            }
            *out = if (acc as i32) < 0 { 0 } else { acc };
        }
        want = nx;
    }
    let verified = h == want;
    result("MLP", &c, verified, (LAYERS * m * m) as u64)
}

// ---------------------------------------------------------------- shared

fn result(name: &'static str, c: &Cluster, verified: bool, work_items: u64) -> ScaleoutResult {
    let rep = c.report();
    ScaleoutResult {
        name,
        machines: rep.machines,
        verified,
        makespan: rep.makespan,
        breakdown: rep.breakdown,
        net_secs: rep.net_secs,
        net_bytes: rep.net_bytes,
        work_items,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(machines: u32, scale: f64) -> ScaleoutConfig {
        ScaleoutConfig {
            scale,
            n_tasklets: 8,
            exec: ExecChoice::Serial,
            ..ScaleoutConfig::new(machines)
        }
    }

    #[test]
    fn gemv_verifies_and_wires_shards_home() {
        let r = gemv(&tiny(2, 0.02));
        assert!(r.verified);
        assert_eq!(r.machines, 2);
        // x out (1 msg) + one result shard home
        assert!(r.net_bytes > 0, "two machines must exchange traffic");
        assert!(r.makespan > 0.0 && r.net_secs > 0.0);
    }

    #[test]
    fn spmv_all_reduce_verifies() {
        let r = spmv(&tiny(2, 0.01));
        assert!(r.verified);
        assert!(r.net_bytes > 0);
        assert!(r.breakdown.inter_dpu > 0.0, "the combine runs on machine hosts");
    }

    #[test]
    fn bfs_frontier_exchange_matches_reference() {
        let r = bfs(&tiny(2, 0.002));
        assert!(r.verified);
        assert!(r.net_bytes > 0, "levels must exchange frontiers");
    }

    #[test]
    fn mlp_all_gather_between_layers_verifies() {
        let r = mlp(&tiny(2, 0.06));
        assert!(r.verified);
        // 2 inter-layer all-gathers + the input fan-out
        assert!(r.net_bytes > 0);
    }

    #[test]
    fn one_machine_runs_without_network() {
        for name in SCALEOUT_BENCHES {
            let scale = if name == "BFS" { 0.002 } else { 0.02 };
            let r = run_bench(name, &tiny(1, scale)).unwrap();
            assert!(r.verified, "{name} must verify on one machine");
            assert_eq!(r.net_bytes, 0, "{name}: one machine has no wire to cross");
            assert_eq!(r.net_secs, 0.0);
        }
    }

    #[test]
    fn unknown_bench_is_none() {
        assert!(run_bench("nope", &tiny(1, 0.01)).is_none());
    }
}
