//! RED — Reduction (§4.12). Parallel primitives; int64; sequential +
//! strided; barrier intra-DPU; host merges per-DPU partials.
//!
//! Three variants of the final intra-DPU step (§9.2.3 / Fig. 21 in our
//! harness):
//! * `Single` — tasklet 0 sums the per-tasklet partials (the version the
//!   paper ships, since it is never slower);
//! * `TreeBarrier` — log₂(T) rounds of pairwise adds with a barrier
//!   between rounds;
//! * `TreeHandshake` — the same tree with handshake pairs instead of
//!   barriers.
//!
//! Lifecycle: the input array is resident; warm requests re-reduce it
//! (streaming workload).

use super::common::{BenchTraits, RunConfig};
use super::workload::{run_oneshot, Dataset, Output, Request, Staged, Workload};
use crate::arch::{isa, DType, Op};
use crate::coordinator::{LaunchStats, Session, Symbol};
use crate::dpu::Ctx;
use crate::util::pod::cast_slice_mut;
use crate::util::Rng;

/// Paper dataset (Table 3): 6.3 M int64 elements.
const PAPER_N: usize = 6_300_000;
const BLOCK: usize = 1024;
const EPB: usize = BLOCK / 8;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RedVersion {
    #[default]
    Single,
    TreeBarrier,
    TreeHandshake,
}

#[derive(Default)]
pub struct Red {
    pub version: RedVersion,
}

pub struct RedData {
    input: Vec<i64>,
    sum_ref: i64,
    n: usize,
    per: usize,
}

struct RedState {
    in_sym: Symbol<i64>,
    sum_sym: Symbol<i64>,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RedOut {
    pub total: i64,
}

impl Workload for Red {
    fn name(&self) -> &'static str {
        "RED"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Parallel primitives",
            sequential: true,
            strided: true,
            random: false,
            ops: "add",
            dtype: "int64_t",
            intra_sync: "barrier",
            inter_sync: true,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        let n = rc.scaled(PAPER_N);
        let mut rng = Rng::new(rc.seed);
        let input = rng.vec_i64(n, 1 << 24);
        let sum_ref: i64 = input.iter().sum();
        let nd = rc.n_dpus as usize;
        let per = n.div_ceil(nd).div_ceil(EPB) * EPB;
        Dataset::new(n as u64, RedData { input, sum_ref, n, per })
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        let d = ds.get::<RedData>();
        let nd = sess.set.n_dpus() as usize;
        let bufs: Vec<Vec<i64>> = (0..nd)
            .map(|i| {
                let lo = (i * d.per).min(d.n);
                let hi = ((i + 1) * d.per).min(d.n);
                let mut v = d.input[lo..hi].to_vec();
                v.resize(d.per, 0); // additive identity (not a sentinel hack)
                v
            })
            .collect();
        let in_sym = sess.set.symbol::<i64>(d.per);
        let sum_sym = sess.set.symbol::<i64>(1);
        sess.set.xfer(in_sym).to().equal(&bufs);
        sess.put_state(RedState { in_sym, sum_sym });
        sess.mark_loaded("RED");
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        _req: &Request,
        _staged: Staged,
    ) -> LaunchStats {
        let d = ds.get::<RedData>();
        let (in_sym, sum_sym) = {
            let st = sess.state::<RedState>();
            (st.in_sym, st.sum_sym)
        };
        let out_off = sum_sym.off();
        let version = self.version;
        let per_elem = (isa::WRAM_LS + isa::ADDR_CALC + isa::LOOP_CTRL) as u64
            + isa::op_instrs(DType::I64, Op::Add) as u64;
        let n_blocks = d.per / EPB;

        sess.launch(sess.n_tasklets, move |_d, ctx: &mut Ctx| {
            let t = ctx.tasklet_id as usize;
            let nt = ctx.n_tasklets as usize;
            let win = ctx.mem_alloc(BLOCK);
            let slots = ctx.mem_alloc_shared(1, nt * 8);
            let wres = ctx.mem_alloc(8);
            // phase 1: local accumulation (block-cyclic)
            let mut acc = 0i64;
            let mut blk = t;
            while blk < n_blocks {
                ctx.mram_read(in_sym.off() + blk * BLOCK, win, BLOCK);
                let v: Vec<i64> = ctx.wram_get(win, EPB);
                acc += v.iter().sum::<i64>();
                ctx.compute(EPB as u64 * per_elem);
                blk += nt;
            }
            ctx.wram_set(slots + t * 8, &[acc]);
            // phase 2: combine partials
            match version {
                RedVersion::Single => {
                    ctx.barrier(0);
                    if t == 0 {
                        let parts: Vec<i64> = ctx.wram_get(slots, nt);
                        let total: i64 = parts.iter().sum();
                        ctx.charge_stream(DType::I64, Op::Add, nt as u64);
                        ctx.wram_set(wres, &[total]);
                        ctx.mram_write(wres, out_off, 8);
                    }
                }
                RedVersion::TreeBarrier => {
                    let mut stride = 1usize;
                    let mut bid = 1u16;
                    while stride < nt {
                        ctx.barrier(bid);
                        bid += 1;
                        if t % (2 * stride) == 0 && t + stride < nt {
                            ctx.wram(|w| {
                                let s = cast_slice_mut::<i64>(&mut w[slots..slots + nt * 8]);
                                s[t] += s[t + stride];
                            });
                            ctx.charge_stream(DType::I64, Op::Add, 1);
                        }
                        stride *= 2;
                    }
                    ctx.barrier(bid);
                    if t == 0 {
                        let total: Vec<i64> = ctx.wram_get(slots, 1);
                        ctx.wram_set(wres, &[total[0]]);
                        ctx.mram_write(wres, out_off, 8);
                    }
                }
                RedVersion::TreeHandshake => {
                    // tasklet t waits for its tree children before adding
                    let mut stride = 1usize;
                    while stride < nt {
                        if t % (2 * stride) == 0 {
                            if t + stride < nt {
                                ctx.handshake_wait_for((t + stride) as u32);
                                ctx.wram(|w| {
                                    let s =
                                        cast_slice_mut::<i64>(&mut w[slots..slots + nt * 8]);
                                    s[t] += s[t + stride];
                                });
                                ctx.charge_stream(DType::I64, Op::Add, 1);
                            }
                        } else if t % (2 * stride) == stride {
                            ctx.handshake_notify();
                            break;
                        }
                        stride *= 2;
                    }
                    if t == 0 {
                        let total: Vec<i64> = ctx.wram_get(slots, 1);
                        ctx.wram_set(wres, &[total[0]]);
                        ctx.mram_write(wres, out_off, 8);
                    }
                }
            }
        })
    }

    fn retrieve(&self, sess: &mut Session, _ds: &Dataset) -> Output {
        let sum_sym = sess.state::<RedState>().sum_sym;
        let nd = sess.set.n_dpus() as usize;
        // host: gather per-DPU sums (8 B each, serial) and reduce
        let mut total = 0i64;
        for i in 0..nd {
            total += sess.set.xfer(sum_sym).from().one(i, 1)[0];
        }
        sess.set.host_merge((nd * 8) as u64, nd as u64);
        Output::new(RedOut { total })
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        out.get::<RedOut>().total == ds.get::<RedData>().sum_ref
    }
}

/// One-shot run of a specific reduction variant (Fig. 21 / benches).
pub fn run_red(version: RedVersion, rc: &RunConfig) -> crate::prim::common::BenchResult {
    run_oneshot(&Red { version }, rc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_versions_verify() {
        for v in [RedVersion::Single, RedVersion::TreeBarrier, RedVersion::TreeHandshake] {
            let rc = RunConfig {
                n_dpus: 4,
                scale: 0.002,
                ..RunConfig::rank_default()
            };
            let r = run_red(v, &rc);
            assert!(r.verified, "{v:?}");
        }
    }

    #[test]
    fn tree_versions_with_odd_tasklets() {
        for v in [RedVersion::TreeBarrier, RedVersion::TreeHandshake] {
            for nt in [3u32, 5, 7, 12] {
                let rc = RunConfig {
                    n_dpus: 2,
                    n_tasklets: nt,
                    scale: 0.001,
                    ..RunConfig::rank_default()
                };
                assert!(run_red(v, &rc).verified, "{v:?} nt={nt}");
            }
        }
    }

    #[test]
    fn single_never_slower_appendix_9_2_3() {
        let rc = RunConfig {
            n_dpus: 1,
            scale: 0.01,
            ..RunConfig::rank_default()
        };
        let single = run_red(RedVersion::Single, &rc).breakdown.dpu;
        let tree_b = run_red(RedVersion::TreeBarrier, &rc).breakdown.dpu;
        assert!(single <= tree_b * 1.05, "single {single} tree {tree_b}");
    }
}
