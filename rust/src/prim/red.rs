//! RED — Reduction (§4.12). Parallel primitives; int64; sequential +
//! strided; barrier intra-DPU; host merges per-DPU partials.
//!
//! Three variants of the final intra-DPU step (§9.2.3 / Fig. 21 in our
//! harness):
//! * `Single` — tasklet 0 sums the per-tasklet partials (the version the
//!   paper ships, since it is never slower);
//! * `TreeBarrier` — log₂(T) rounds of pairwise adds with a barrier
//!   between rounds;
//! * `TreeHandshake` — the same tree with handshake pairs instead of
//!   barriers.

use super::common::{BenchResult, BenchTraits, PrimBench, RunConfig};
use crate::arch::{isa, DType, Op};
use crate::coordinator::chunk_ranges;
use crate::dpu::Ctx;
use crate::util::pod::cast_slice_mut;
use crate::util::Rng;

/// Paper dataset (Table 3): 6.3 M int64 elements.
const PAPER_N: usize = 6_300_000;
const BLOCK: usize = 1024;
const EPB: usize = BLOCK / 8;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum RedVersion {
    #[default]
    Single,
    TreeBarrier,
    TreeHandshake,
}

#[derive(Default)]
pub struct Red {
    pub version: RedVersion,
}

impl PrimBench for Red {
    fn name(&self) -> &'static str {
        "RED"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Parallel primitives",
            sequential: true,
            strided: true,
            random: false,
            ops: "add",
            dtype: "int64_t",
            intra_sync: "barrier",
            inter_sync: true,
        }
    }

    fn run(&self, rc: &RunConfig) -> BenchResult {
        run_red(self.version, rc)
    }
}

pub fn run_red(version: RedVersion, rc: &RunConfig) -> BenchResult {
    let n = rc.scaled(PAPER_N);
    let mut rng = Rng::new(rc.seed);
    let input = rng.vec_i64(n, 1 << 24);
    let sum_ref: i64 = input.iter().sum();

    let mut set = rc.alloc();
    let nd = rc.n_dpus as usize;
    let per = n.div_ceil(nd).div_ceil(EPB) * EPB;
    let bufs: Vec<Vec<i64>> = (0..nd)
        .map(|d| {
            let lo = (d * per).min(n);
            let hi = ((d + 1) * per).min(n);
            let mut v = input[lo..hi].to_vec();
            v.resize(per, 0); // additive identity (not a sentinel hack)
            v
        })
        .collect();
    let in_sym = set.symbol::<i64>(per);
    let sum_sym = set.symbol::<i64>(1);
    set.xfer(in_sym).to().equal(&bufs);
    let out_off = sum_sym.off();

    let per_elem = (isa::WRAM_LS + isa::ADDR_CALC + isa::LOOP_CTRL) as u64
        + isa::op_instrs(DType::I64, Op::Add) as u64;
    let n_blocks = per / EPB;

    let stats = set.launch(rc.n_tasklets, |_d, ctx: &mut Ctx| {
        let t = ctx.tasklet_id as usize;
        let nt = ctx.n_tasklets as usize;
        let win = ctx.mem_alloc(BLOCK);
        let slots = ctx.mem_alloc_shared(1, nt * 8);
        let wres = ctx.mem_alloc(8);
        // phase 1: local accumulation (block-cyclic)
        let mut acc = 0i64;
        let mut blk = t;
        while blk < n_blocks {
            ctx.mram_read(in_sym.off() + blk * BLOCK, win, BLOCK);
            let v: Vec<i64> = ctx.wram_get(win, EPB);
            acc += v.iter().sum::<i64>();
            ctx.compute(EPB as u64 * per_elem);
            blk += nt;
        }
        ctx.wram_set(slots + t * 8, &[acc]);
        // phase 2: combine partials
        match version {
            RedVersion::Single => {
                ctx.barrier(0);
                if t == 0 {
                    let parts: Vec<i64> = ctx.wram_get(slots, nt);
                    let total: i64 = parts.iter().sum();
                    ctx.charge_stream(DType::I64, Op::Add, nt as u64);
                    ctx.wram_set(wres, &[total]);
                    ctx.mram_write(wres, out_off, 8);
                }
            }
            RedVersion::TreeBarrier => {
                let mut stride = 1usize;
                let mut bid = 1u16;
                while stride < nt {
                    ctx.barrier(bid);
                    bid += 1;
                    if t % (2 * stride) == 0 && t + stride < nt {
                        ctx.wram(|w| {
                            let s = cast_slice_mut::<i64>(&mut w[slots..slots + nt * 8]);
                            s[t] += s[t + stride];
                        });
                        ctx.charge_stream(DType::I64, Op::Add, 1);
                    }
                    stride *= 2;
                }
                ctx.barrier(bid);
                if t == 0 {
                    let total: Vec<i64> = ctx.wram_get(slots, 1);
                    ctx.wram_set(wres, &[total[0]]);
                    ctx.mram_write(wres, out_off, 8);
                }
            }
            RedVersion::TreeHandshake => {
                // tasklet t waits for its tree children before adding
                let mut stride = 1usize;
                while stride < nt {
                    if t % (2 * stride) == 0 {
                        if t + stride < nt {
                            ctx.handshake_wait_for((t + stride) as u32);
                            ctx.wram(|w| {
                                let s = cast_slice_mut::<i64>(&mut w[slots..slots + nt * 8]);
                                s[t] += s[t + stride];
                            });
                            ctx.charge_stream(DType::I64, Op::Add, 1);
                        }
                    } else if t % (2 * stride) == stride {
                        ctx.handshake_notify();
                        break;
                    }
                    stride *= 2;
                }
                if t == 0 {
                    let total: Vec<i64> = ctx.wram_get(slots, 1);
                    ctx.wram_set(wres, &[total[0]]);
                    ctx.mram_write(wres, out_off, 8);
                }
            }
        }
    });

    // host: gather per-DPU sums (8 B each, serial) and reduce
    let mut total = 0i64;
    for d in 0..nd {
        total += set.xfer(sum_sym).from().one(d, 1)[0];
    }
    set.host_merge((nd * 8) as u64, nd as u64);

    BenchResult {
        name: "RED",
        breakdown: set.metrics,
        verified: total == sum_ref,
        work_items: n as u64,
        dpu_instrs: stats.total_instrs(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_versions_verify() {
        for v in [RedVersion::Single, RedVersion::TreeBarrier, RedVersion::TreeHandshake] {
            let rc = RunConfig {
                n_dpus: 4,
                scale: 0.002,
                ..RunConfig::rank_default()
            };
            let r = run_red(v, &rc);
            assert!(r.verified, "{v:?}");
        }
    }

    #[test]
    fn tree_versions_with_odd_tasklets() {
        for v in [RedVersion::TreeBarrier, RedVersion::TreeHandshake] {
            for nt in [3u32, 5, 7, 12] {
                let rc = RunConfig {
                    n_dpus: 2,
                    n_tasklets: nt,
                    scale: 0.001,
                    ..RunConfig::rank_default()
                };
                assert!(run_red(v, &rc).verified, "{v:?} nt={nt}");
            }
        }
    }

    #[test]
    fn single_never_slower_appendix_9_2_3() {
        let rc = RunConfig {
            n_dpus: 1,
            scale: 0.01,
            ..RunConfig::rank_default()
        };
        let single = run_red(RedVersion::Single, &rc).breakdown.dpu;
        let tree_b = run_red(RedVersion::TreeBarrier, &rc).breakdown.dpu;
        assert!(single <= tree_b * 1.05, "single {single} tree {tree_b}");
    }
}
