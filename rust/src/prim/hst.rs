//! HST — Image Histogram (§4.11), both variants.
//!
//! * **HST-S**: per-tasklet private WRAM histograms, barrier, parallel
//!   merge (tasklet t reduces bin range t across all private copies).
//!   Histogram size limited to ~256 bins × 16 tasklets by WRAM.
//! * **HST-L**: one shared WRAM histogram per DPU, every update inside a
//!   mutex — scales worse with tasklets (best at 8, Key Obs. 11) but
//!   supports larger histograms.
//!
//! §9.2.2 (Fig. 20 in our harness) compares the two across histogram
//! sizes via [`run_hst`]'s `bins` parameter.
//!
//! Pixels are distributed with **ragged** parallel transfers, so each DPU
//! counts exactly its share — the old equal-size path padded the tail DPU
//! with sentinel zero pixels and subtracted them from bucket 0 afterwards.
//!
//! Lifecycle: the image is resident; warm requests re-count it (streaming
//! workload — the shared WRAM histogram is fresh per launch, so
//! re-execution is exact).

use super::common::{BenchTraits, RunConfig};
use super::workload::{Dataset, Output, Request, Staged, Workload};
use crate::arch::{isa, DType, Op};
use crate::coordinator::{ragged_counts, LaunchStats, Session, Symbol};
use crate::dpu::Ctx;
use crate::util::data::natural_image;
use crate::util::pod::cast_slice_mut;

/// Paper dataset (Table 3): 1536 × 1024 natural image, 12-bit depth.
const PAPER_PIXELS: usize = 1536 * 1024;
const DEPTH_BITS: u32 = 12;
const BLOCK: usize = 1024;

#[derive(Clone, Copy, PartialEq)]
pub enum HstKind {
    Short,
    Long,
}

/// A parameterized histogram workload: variant + bucket count. The
/// Table 2 entries are `Hst::short()` (256 bins) and `Hst::long()` (256 bins,
/// long); the Fig. 20 study sweeps `bins`.
pub struct Hst {
    pub kind: HstKind,
    pub name: &'static str,
    pub bins: usize,
}

pub struct HstData {
    pixels: Vec<u32>,
    hist_ref: Vec<u32>,
    shift: u32,
    n: usize,
    counts: Vec<usize>,
}

struct HstState {
    px_sym: Symbol<u32>,
    hist_sym: Symbol<u32>,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct HstOut {
    pub hist: Vec<u32>,
}

impl Workload for Hst {
    fn name(&self) -> &'static str {
        self.name
    }

    fn traits(&self) -> BenchTraits {
        match self.kind {
            HstKind::Short => BenchTraits {
                domain: "Image processing",
                sequential: true,
                strided: false,
                random: true,
                ops: "add",
                dtype: "uint32_t",
                intra_sync: "barrier",
                inter_sync: true,
            },
            HstKind::Long => BenchTraits {
                domain: "Image processing",
                sequential: true,
                strided: false,
                random: true,
                ops: "add",
                dtype: "uint32_t",
                intra_sync: "barrier, mutex",
                inter_sync: true,
            },
        }
    }

    fn best_tasklets(&self) -> u32 {
        match self.kind {
            HstKind::Short => 16,
            // mutex contention makes 16 slower (Key Obs. 11)
            HstKind::Long => 8,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        assert!(self.bins.is_power_of_two() && self.bins <= 4096);
        let shift = DEPTH_BITS - (self.bins as f64).log2() as u32;
        let n = rc.scaled(PAPER_PIXELS);
        let pixels = natural_image(n, DEPTH_BITS, rc.seed);
        let mut hist_ref = vec![0u32; self.bins];
        for &p in &pixels {
            hist_ref[(p >> shift) as usize] += 1;
        }
        // exact contiguous pixel shares (8-element granularity keeps
        // ragged slices DMA-aligned); no bucket-0 sentinel padding
        let nd = rc.n_dpus as usize;
        let per = n.div_ceil(nd).div_ceil(8) * 8;
        let counts = ragged_counts(n, per, nd);
        Dataset::new(n as u64, HstData { pixels, hist_ref, shift, n, counts })
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        let d = ds.get::<HstData>();
        let nd = sess.set.n_dpus() as usize;
        assert_eq!(nd, d.counts.len(), "session fleet must match the dataset");
        let per = d.n.div_ceil(nd).div_ceil(8) * 8;
        let bufs: Vec<Vec<u32>> = (0..nd)
            .map(|i| d.pixels[(i * per).min(d.n)..((i + 1) * per).min(d.n)].to_vec())
            .collect();
        let px_sym = sess.set.symbol::<u32>(per);
        let hist_sym = sess.set.symbol::<u32>(self.bins.max(2));
        sess.set.xfer(px_sym).to().ragged(&bufs);
        sess.put_state(HstState { px_sym, hist_sym });
        sess.mark_loaded(self.name);
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        _req: &Request,
        _staged: Staged,
    ) -> LaunchStats {
        let d = ds.get::<HstData>();
        let (px_sym, hist_sym) = {
            let st = sess.state::<HstState>();
            (st.px_sym, st.hist_sym)
        };
        let out_off = hist_sym.off();
        let (bins, shift, kind) = (self.bins, d.shift, self.kind);
        let per_pixel = (2 * isa::WRAM_LS + isa::ADDR_CALC + isa::LOOP_CTRL) as u64
            + isa::op_instrs(DType::U32, Op::Add) as u64
            + 1; // shift
        let counts_ref = &d.counts;
        sess.launch(sess.n_tasklets, move |dpu, ctx: &mut Ctx| {
            let t = ctx.tasklet_id as usize;
            let nt = ctx.n_tasklets as usize;
            let my_bytes = counts_ref[dpu] * 4;
            let n_blocks = my_bytes.div_ceil(BLOCK);
            let win = ctx.mem_alloc(BLOCK);
            match kind {
                HstKind::Short => {
                    // private histograms in one shared region (so the merge
                    // phase can read all of them)
                    let hists = ctx.mem_alloc_shared(1, nt * bins * 4);
                    let my_hist = hists + t * bins * 4;
                    let mut local = vec![0u32; bins];
                    let mut blk = t;
                    while blk < n_blocks {
                        let take = (my_bytes - blk * BLOCK).min(BLOCK);
                        ctx.mram_read(px_sym.off() + blk * BLOCK, win, take);
                        let px: Vec<u32> = ctx.wram_get(win, take / 4);
                        for p in px {
                            local[(p >> shift) as usize] += 1;
                        }
                        ctx.compute((take / 4) as u64 * per_pixel);
                        blk += nt;
                    }
                    ctx.wram_set(my_hist, &local);
                    ctx.barrier(0);
                    // parallel merge: tasklet t reduces its bin range (ranges
                    // rounded to even bins so MRAM writes stay 8-B aligned)
                    let lo = (t * bins / nt) & !1;
                    let hi = if t + 1 == nt { bins } else { ((t + 1) * bins / nt) & !1 };
                    if hi > lo {
                        let mut merged = vec![0u32; hi - lo];
                        for other in 0..nt {
                            let h: Vec<u32> =
                                ctx.wram_get(hists + other * bins * 4 + lo * 4, hi - lo);
                            for (m, v) in merged.iter_mut().zip(&h) {
                                *m += v;
                            }
                        }
                        ctx.charge_ops(DType::U32, Op::Add, ((hi - lo) * nt) as u64);
                        // write this bin range to MRAM (8-B aligned slices)
                        ctx.wram_set(hists + lo * 4, &merged);
                        let lo_b = (lo * 4) & !7;
                        let hi_b = (hi * 4 + 7) & !7;
                        ctx.mram_write(hists + lo_b, out_off + lo_b, hi_b - lo_b);
                    }
                }
                HstKind::Long => {
                    // one shared histogram; mutex-protected updates
                    let hist = ctx.mem_alloc_shared(1, bins * 4);
                    let mut blk = t;
                    while blk < n_blocks {
                        let take = (my_bytes - blk * BLOCK).min(BLOCK);
                        ctx.mram_read(px_sym.off() + blk * BLOCK, win, take);
                        let px: Vec<u32> = ctx.wram_get(win, take / 4);
                        for p in px {
                            let b = (p >> shift) as usize;
                            ctx.mutex_lock(0);
                            ctx.wram(|w| {
                                cast_slice_mut::<u32>(&mut w[hist..hist + bins * 4])[b] += 1;
                            });
                            ctx.charge_ops(DType::U32, Op::Add, 1);
                            ctx.mutex_unlock(0);
                        }
                        ctx.compute((take / 4) as u64 * (per_pixel - 1));
                        blk += nt;
                    }
                    ctx.barrier(0);
                    if t == 0 {
                        let mut off = 0;
                        while off < bins * 4 {
                            let take = (bins * 4 - off).min(1024);
                            ctx.mram_write(hist + off, out_off + off, take.max(8));
                            off += take;
                        }
                    }
                }
            }
        })
    }

    fn retrieve(&self, sess: &mut Session, _ds: &Dataset) -> Output {
        let hist_sym = sess.state::<HstState>().hist_sym;
        let nd = sess.set.n_dpus() as usize;
        // host: gather per-DPU histograms (equal sizes → parallel) and merge
        let parts = sess.set.xfer(hist_sym).from().equal(self.bins);
        let mut hist = vec![0u32; self.bins];
        for p in &parts {
            for (h, v) in hist.iter_mut().zip(p) {
                *h += v;
            }
        }
        sess.set.host_merge((nd * self.bins * 4) as u64, (nd * self.bins) as u64);
        Output::new(HstOut { hist })
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        out.get::<HstOut>().hist == ds.get::<HstData>().hist_ref
    }
}

/// Run either histogram variant with `bins` buckets (the Fig. 20 sweep).
/// Pixel values are 12-bit; bucket = value >> (12 - log2(bins)).
pub fn run_hst(
    kind: HstKind,
    name: &'static str,
    rc: &RunConfig,
    bins: usize,
) -> crate::prim::common::BenchResult {
    super::workload::run_oneshot(&Hst { kind, name, bins }, rc)
}

impl Hst {
    /// The Table 2 "HST-S" entry: private per-tasklet histograms.
    pub const fn short() -> Hst {
        Hst { kind: HstKind::Short, name: "HST-S", bins: 256 }
    }

    /// The Table 2 "HST-L" entry: one mutex-protected shared histogram.
    pub const fn long() -> Hst {
        Hst { kind: HstKind::Long, name: "HST-L", bins: 256 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prim::common::PrimBench;

    #[test]
    fn hst_s_verifies() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.01,
            ..RunConfig::rank_default()
        };
        assert!(Hst::short().run(&rc).verified);
    }

    #[test]
    fn ragged_input_counts_no_pad_pixels() {
        // pixel count not divisible by the DPU count: every bucket must
        // still match the reference without any bucket-0 correction, and
        // the pushed volume is exactly the image
        let rc = RunConfig {
            n_dpus: 6,
            scale: 0.011,
            ..RunConfig::rank_default()
        };
        let r = Hst::short().run(&rc);
        assert!(r.verified);
        assert_eq!(r.breakdown.bytes_to_dpu, rc.scaled(1536 * 1024) as u64 * 4);
    }

    #[test]
    fn hst_l_verifies() {
        let rc = RunConfig {
            n_dpus: 2,
            n_tasklets: 8,
            scale: 0.005,
            ..RunConfig::rank_default()
        };
        assert!(Hst::long().run(&rc).verified);
    }

    #[test]
    fn hst_l_mutex_contention_hurts() {
        // HST-L at 16 tasklets should NOT be meaningfully faster than at 8
        // (paper: best at 8)
        let mk = |t: u32| {
            let rc = RunConfig {
                n_dpus: 1,
                n_tasklets: t,
                scale: 0.002,
                ..RunConfig::rank_default()
            };
            Hst::long().run(&rc).breakdown.dpu
        };
        let t8 = mk(8);
        let t16 = mk(16);
        assert!(t16 > t8 * 0.9, "t8 {t8} t16 {t16}");
        // while HST-S keeps scaling
        let mk_s = |t: u32| {
            let rc = RunConfig {
                n_dpus: 1,
                n_tasklets: t,
                scale: 0.002,
                ..RunConfig::rank_default()
            };
            Hst::short().run(&rc).breakdown.dpu
        };
        assert!(mk_s(16) < mk_s(8));
    }

    #[test]
    fn larger_bins_supported_by_hst_l() {
        let rc = RunConfig {
            n_dpus: 2,
            n_tasklets: 8,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        let r = run_hst(HstKind::Long, "HST-L", &rc, 4096);
        assert!(r.verified);
    }

    /// Warm re-execute is exact: the shared WRAM histogram is fresh per
    /// launch, so a second count of the resident image matches the first.
    #[test]
    fn warm_recount_is_exact() {
        use crate::prim::workload::Request;
        let rc = RunConfig {
            n_dpus: 3,
            n_tasklets: 8,
            scale: 0.003,
            ..RunConfig::rank_default()
        };
        for w in [Hst::short(), Hst::long()] {
            let ds = w.prepare(&rc);
            let mut sess = rc.session();
            w.load(&mut sess, &ds);
            w.execute(&mut sess, &ds, &Request::new(0, rc.seed), Staged::empty());
            let first = w.retrieve(&mut sess, &ds);
            w.execute(&mut sess, &ds, &Request::new(1, rc.seed ^ 3), Staged::empty());
            let second = w.retrieve(&mut sess, &ds);
            assert_eq!(first.get::<HstOut>(), second.get::<HstOut>());
            assert!(w.verify(&ds, &second), "{}", Workload::name(&w));
        }
    }
}
