//! The PrIM benchmark suite: 16 workloads (19 kernels) ported 1:1 from the
//! paper's §4 descriptions onto the simulated UPMEM system.
//!
//! Every benchmark is a staged [`workload::Workload`]: it (a) **prepares**
//! a deterministic synthetic dataset with the paper's statistics, (b)
//! **loads** it through typed MRAM symbols and the transfer builder with
//! the same pattern the paper describes (parallel equal/ragged, serial
//! per-DPU, broadcast), (c) **executes** requests with the same
//! tasklet-level algorithm against the [`crate::dpu::Ctx`] API and the
//! same synchronization primitives, (d) **retrieves** and merges results
//! on the host, and (e) **verifies** the output against a native
//! reference — returning the paper's four-bucket time breakdown. The
//! one-shot [`common::PrimBench::run`] is a compatibility shim over the
//! stages; persistent sessions serve many requests against warm state
//! (see [`workload`] and `coordinator::session`).

pub mod bfs;
pub mod bs;
pub mod common;
pub mod workload;
pub mod gemv;
pub mod hst;
pub mod mlp;
pub mod nw;
pub mod red;
pub mod scaleout;
pub mod scan;
pub mod sel;
pub mod spmv;
pub mod trns;
pub mod ts;
pub mod uni;
pub mod va;

pub use common::{all_benches, bench_by_name, BenchResult, BenchTraits, PrimBench, RunConfig};
pub use workload::{
    all_workloads, run_oneshot, serve, workload_by_name, Dataset, Output, Request, ServeReport,
    Staged, Workload,
};
