//! BS — Binary Search (§4.6). Data analytics; int64; sequential query
//! stream + random array probes; no synchronization. The sorted array is
//! replicated on every DPU; query values are partitioned.
//!
//! Random probes into the MRAM-resident array use fine-grained 8-B DMA —
//! the access pattern that makes BS weak on GPUs (uncoalescible) and is
//! why the 640-DPU system already beats the Titan V on it (§5.2).

use super::common::{BenchResult, BenchTraits, PrimBench, RunConfig};
use crate::arch::{isa, DType, Op};
use crate::coordinator::{chunk_ranges, ragged_counts};
use crate::dpu::Ctx;
use crate::util::data::sorted_with_queries;

/// Paper dataset (Table 3): 2 M-element sorted array, 256 K queries.
const PAPER_N: usize = 2_000_000;
const PAPER_Q: usize = 262_144;

pub struct Bs;

impl PrimBench for Bs {
    fn name(&self) -> &'static str {
        "BS"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Data analytics",
            sequential: true,
            strided: false,
            random: true,
            ops: "compare",
            dtype: "int64_t",
            intra_sync: "",
            inter_sync: false,
        }
    }

    fn run(&self, rc: &RunConfig) -> BenchResult {
        let n = rc.scaled(PAPER_N);
        let q = rc.scaled(PAPER_Q);
        let (arr, queries) = sorted_with_queries(n, q, rc.seed);

        let mut set = rc.alloc();
        let nd = rc.n_dpus as usize;
        // the array is replicated in each DPU (CPU-DPU cost grows with
        // DPU count — the paper's Fig. 13 note)
        let arr_sym = set.symbol::<i64>(n);
        set.xfer(arr_sym).to().broadcast(&arr);
        // queries partitioned contiguously; ragged transfers carry each
        // DPU's exact share (no "findable value" padding)
        let per_q = q.div_ceil(nd);
        let q_counts = ragged_counts(q, per_q, nd);
        let qbufs: Vec<Vec<i64>> = (0..nd)
            .map(|d| queries[(d * per_q).min(q)..((d + 1) * per_q).min(q)].to_vec())
            .collect();
        let q_sym = set.symbol::<i64>(per_q);
        let out_sym = set.symbol::<i64>(per_q);
        set.xfer(q_sym).to().ragged(&qbufs);

        let per_step = (2 * isa::ADDR_CALC + isa::LOOP_CTRL) as u64
            + isa::op_instrs(DType::I64, Op::Cmp) as u64;

        let q_counts_ref = &q_counts;
        let stats = set.launch_seq(rc.n_tasklets, |d, ctx: &mut Ctx| {
            let wq = ctx.mem_alloc(1024);
            let we = ctx.mem_alloc(8);
            let wo = ctx.mem_alloc(8);
            let my = chunk_ranges(q_counts_ref[d], ctx.n_tasklets as usize)
                [ctx.tasklet_id as usize]
                .clone();
            let mut k = my.start;
            while k < my.end {
                let cnt = (my.end - k).min(128);
                ctx.mram_read(q_sym.off() + k * 8, wq, ((cnt * 8 + 7) & !7).max(8));
                let qs: Vec<i64> = ctx.wram_get(wq, cnt);
                for (i, qv) in qs.iter().enumerate() {
                    // binary search with fine-grained MRAM probes
                    let (mut lo, mut hi) = (0usize, n);
                    let mut pos = -1i64;
                    while lo < hi {
                        let mid = (lo + hi) / 2;
                        ctx.mram_read(arr_sym.off() + mid * 8, we, 8);
                        let v: Vec<i64> = ctx.wram_get(we, 1);
                        ctx.compute(per_step);
                        match v[0].cmp(qv) {
                            std::cmp::Ordering::Equal => {
                                pos = mid as i64;
                                break;
                            }
                            std::cmp::Ordering::Less => lo = mid + 1,
                            std::cmp::Ordering::Greater => hi = mid,
                        }
                    }
                    ctx.wram_set(wo, &[pos]);
                    ctx.mram_write(wo, out_sym.off() + (k + i) * 8, 8);
                }
                k += cnt;
            }
        });

        let out = set.xfer(out_sym).from().ragged(&q_counts);
        let mut verified = true;
        'outer: for d in 0..nd {
            let lo = (d * per_q).min(q);
            for (i, gq) in (lo..lo + q_counts[d]).enumerate() {
                let pos = out[d][i];
                if pos < 0 || arr[pos as usize] != queries[gq] {
                    verified = false;
                    break 'outer;
                }
            }
        }

        BenchResult {
            name: self.name(),
            breakdown: set.metrics,
            verified,
            work_items: q as u64,
            dpu_instrs: stats.total_instrs(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.001,
            ..RunConfig::rank_default()
        };
        let r = Bs.run(&rc);
        assert!(r.verified);
    }

    #[test]
    fn cpu_dpu_does_not_shrink_with_more_dpus() {
        // the replicated array makes input volume grow with DPU count
        let mk = |nd: u32| {
            let rc = RunConfig {
                n_dpus: nd,
                scale: 0.001,
                ..RunConfig::rank_default()
            };
            Bs.run(&rc).breakdown.cpu_dpu
        };
        assert!(mk(16) >= mk(4) * 0.9);
    }

    #[test]
    fn memory_bound_scaling_limited_past_8_tasklets() {
        // BS does one comparison per probed element → fine-grained-DMA
        // bound; paper sees only 3% gain from 8 → 16 tasklets
        let mk = |t: u32| {
            let rc = RunConfig {
                n_dpus: 1,
                n_tasklets: t,
                scale: 0.0005,
                ..RunConfig::rank_default()
            };
            Bs.run(&rc).breakdown.dpu
        };
        let t8 = mk(8);
        let t16 = mk(16);
        assert!(t8 / t16 < 1.30, "{}", t8 / t16);
    }
}
