//! BS — Binary Search (§4.6). Data analytics; int64; sequential query
//! stream + random array probes; no synchronization. The sorted array is
//! replicated on every DPU; query values are partitioned.
//!
//! Random probes into the MRAM-resident array use fine-grained 8-B DMA —
//! the access pattern that makes BS weak on GPUs (uncoalescible) and is
//! why the 640-DPU system already beats the Titan V on it (§5.2).
//!
//! Lifecycle: the replicated sorted array is the big resident input; each
//! request stages a fresh query batch (drawn from the array, so every
//! query is findable) — the canonical query-serving workload: warm
//! requests pay only the small query push, and pipelined batches hide it
//! under the previous request's launch.

use super::common::{BenchTraits, RunConfig};
use super::workload::{Dataset, Output, Request, Staged, Workload};
use crate::arch::{isa, DType, Op};
use crate::coordinator::{chunk_ranges, ragged_counts, LaunchStats, Session, Symbol};
use crate::dpu::Ctx;
use crate::util::data::sorted_with_queries;
use crate::util::Rng;

/// Paper dataset (Table 3): 2 M-element sorted array, 256 K queries.
const PAPER_N: usize = 2_000_000;
const PAPER_Q: usize = 262_144;

pub struct Bs;

/// Host dataset: the sorted array plus the per-DPU query partition shape.
pub struct BsData {
    arr: Vec<i64>,
    n: usize,
    q: usize,
    per_q: usize,
    q_counts: Vec<usize>,
    nd: usize,
}

struct BsState {
    arr_sym: Symbol<i64>,
    q_sym: Symbol<i64>,
    out_sym: Symbol<i64>,
    /// Queries of the most recent request (for verification).
    cur_queries: Vec<i64>,
}

/// One request's staged input: the query batch, pre-partitioned.
pub struct BsStaged {
    pub queries: Vec<i64>,
    pub qbufs: Vec<Vec<i64>>,
}

/// Retrieved result: per-DPU found positions plus the queries they answer.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BsOut {
    pub queries: Vec<i64>,
    pub positions: Vec<Vec<i64>>,
}

impl Workload for Bs {
    fn name(&self) -> &'static str {
        "BS"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Data analytics",
            sequential: true,
            strided: false,
            random: true,
            ops: "compare",
            dtype: "int64_t",
            intra_sync: "",
            inter_sync: false,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        let n = rc.scaled(PAPER_N);
        let q = rc.scaled(PAPER_Q);
        // queries are per-request (staged from the request seed), so only
        // the array is generated here
        let (arr, _) = sorted_with_queries(n, 0, rc.seed);
        let nd = rc.n_dpus as usize;
        // queries partitioned contiguously; ragged transfers carry each
        // DPU's exact share (no "findable value" padding)
        let per_q = q.div_ceil(nd);
        let q_counts = ragged_counts(q, per_q, nd);
        Dataset::new(q as u64, BsData { arr, n, q, per_q, q_counts, nd })
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        let d = ds.get::<BsData>();
        assert_eq!(sess.set.n_dpus() as usize, d.nd, "session fleet must match the dataset");
        // the array is replicated in each DPU (CPU-DPU cost grows with
        // DPU count — the paper's Fig. 13 note)
        let arr_sym = sess.set.symbol::<i64>(d.n);
        let q_sym = sess.set.symbol::<i64>(d.per_q);
        let out_sym = sess.set.symbol::<i64>(d.per_q);
        sess.set.xfer(arr_sym).to().broadcast(&d.arr);
        sess.put_state(BsState { arr_sym, q_sym, out_sym, cur_queries: Vec::new() });
        sess.mark_loaded("BS");
    }

    fn stage(&self, ds: &Dataset, req: &Request) -> Staged {
        let d = ds.get::<BsData>();
        let mut rng = Rng::new(req.seed);
        // queries drawn from the resident array: every query findable
        let queries: Vec<i64> =
            (0..d.q).map(|_| d.arr[rng.below(d.n as u64) as usize]).collect();
        let qbufs: Vec<Vec<i64>> = (0..d.nd)
            .map(|i| queries[(i * d.per_q).min(d.q)..((i + 1) * d.per_q).min(d.q)].to_vec())
            .collect();
        Staged::new(BsStaged { queries, qbufs })
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        _req: &Request,
        staged: Staged,
    ) -> LaunchStats {
        let d = ds.get::<BsData>();
        let BsStaged { queries, qbufs } = staged.take::<BsStaged>();
        let (arr_sym, q_sym, out_sym) = {
            let st = sess.state::<BsState>();
            (st.arr_sym, st.q_sym, st.out_sym)
        };
        sess.set.xfer(q_sym).to().ragged(&qbufs);

        let n = d.n;
        let per_step = (2 * isa::ADDR_CALC + isa::LOOP_CTRL) as u64
            + isa::op_instrs(DType::I64, Op::Cmp) as u64;
        let q_counts_ref = &d.q_counts;
        let stats = sess.launch_seq(sess.n_tasklets, |dpu, ctx: &mut Ctx| {
            let wq = ctx.mem_alloc(1024);
            let we = ctx.mem_alloc(8);
            let wo = ctx.mem_alloc(8);
            let my = chunk_ranges(q_counts_ref[dpu], ctx.n_tasklets as usize)
                [ctx.tasklet_id as usize]
                .clone();
            let mut k = my.start;
            while k < my.end {
                let cnt = (my.end - k).min(128);
                ctx.mram_read(q_sym.off() + k * 8, wq, ((cnt * 8 + 7) & !7).max(8));
                let qs: Vec<i64> = ctx.wram_get(wq, cnt);
                for (i, qv) in qs.iter().enumerate() {
                    // binary search with fine-grained MRAM probes
                    let (mut lo, mut hi) = (0usize, n);
                    let mut pos = -1i64;
                    while lo < hi {
                        let mid = (lo + hi) / 2;
                        ctx.mram_read(arr_sym.off() + mid * 8, we, 8);
                        let v: Vec<i64> = ctx.wram_get(we, 1);
                        ctx.compute(per_step);
                        match v[0].cmp(qv) {
                            std::cmp::Ordering::Equal => {
                                pos = mid as i64;
                                break;
                            }
                            std::cmp::Ordering::Less => lo = mid + 1,
                            std::cmp::Ordering::Greater => hi = mid,
                        }
                    }
                    ctx.wram_set(wo, &[pos]);
                    ctx.mram_write(wo, out_sym.off() + (k + i) * 8, 8);
                }
                k += cnt;
            }
        });
        sess.state_mut::<BsState>().cur_queries = queries;
        stats
    }

    fn retrieve(&self, sess: &mut Session, ds: &Dataset) -> Output {
        let d = ds.get::<BsData>();
        let out_sym = sess.state::<BsState>().out_sym;
        let positions = sess.set.xfer(out_sym).from().ragged(&d.q_counts);
        Output::new(BsOut { queries: sess.state::<BsState>().cur_queries.clone(), positions })
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        let d = ds.get::<BsData>();
        let o = out.get::<BsOut>();
        if o.queries.len() != d.q {
            return false;
        }
        for dpu in 0..d.nd {
            let lo = (dpu * d.per_q).min(d.q);
            if o.positions[dpu].len() != d.q_counts[dpu] {
                return false;
            }
            for (i, gq) in (lo..lo + d.q_counts[dpu]).enumerate() {
                let pos = o.positions[dpu][i];
                if pos < 0 || d.arr[pos as usize] != o.queries[gq] {
                    return false;
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prim::common::PrimBench;
    use crate::prim::workload::serve;

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.001,
            ..RunConfig::rank_default()
        };
        let r = Bs.run(&rc);
        assert!(r.verified);
    }

    #[test]
    fn cpu_dpu_does_not_shrink_with_more_dpus() {
        // the replicated array makes input volume grow with DPU count
        let mk = |nd: u32| {
            let rc = RunConfig {
                n_dpus: nd,
                scale: 0.001,
                ..RunConfig::rank_default()
            };
            Bs.run(&rc).breakdown.cpu_dpu
        };
        assert!(mk(16) >= mk(4) * 0.9);
    }

    #[test]
    fn memory_bound_scaling_limited_past_8_tasklets() {
        // BS does one comparison per probed element → fine-grained-DMA
        // bound; paper sees only 3% gain from 8 → 16 tasklets
        let mk = |t: u32| {
            let rc = RunConfig {
                n_dpus: 1,
                n_tasklets: t,
                scale: 0.0005,
                ..RunConfig::rank_default()
            };
            Bs.run(&rc).breakdown.dpu
        };
        let t8 = mk(8);
        let t16 = mk(16);
        assert!(t8 / t16 < 1.30, "{}", t8 / t16);
    }

    /// Warm serving: the array broadcast happens once, and each warm
    /// request's CPU-DPU time is only the small query push — the
    /// amortization §6 recommends.
    #[test]
    fn warm_requests_amortize_the_array_broadcast() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        let rep = serve(&Bs, &rc, 4, false);
        assert!(rep.verified);
        assert_eq!(rep.requests.len(), 4);
        let steady = rep.steady_state();
        // the array itself is never re-pushed; the remaining warm CPU-DPU
        // time is only the query batch (array:queries ≈ 7.6:1 in Table 3)
        assert!(
            steady.cpu_dpu < rep.cold.cpu_dpu / 4.0,
            "warm input push {} must be far below the cold load {}",
            steady.cpu_dpu,
            rep.cold.cpu_dpu
        );
        // every warm request pushes exactly the query batch
        let d = Bs.prepare(&rc);
        let q = d.get::<BsData>().q;
        for r in &rep.requests {
            assert_eq!(r.bytes_to_dpu, (q * 8) as u64);
        }
    }

    /// The pipelined batch hides query pushes under launches: bit-identical
    /// results, strictly smaller modeled total.
    #[test]
    fn pipelined_batching_hides_query_pushes() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        let ser = serve(&Bs, &rc, 4, false);
        let pip = serve(&Bs, &rc, 4, true);
        assert!(ser.verified && pip.verified);
        assert_eq!(
            ser.output.get::<BsOut>(),
            pip.output.get::<BsOut>(),
            "pipelining must not change results"
        );
        assert_eq!(ser.warm.cpu_dpu.to_bits(), pip.warm.cpu_dpu.to_bits());
        assert_eq!(ser.warm.dpu.to_bits(), pip.warm.dpu.to_bits());
        assert_eq!(ser.warm.overlapped, 0.0);
        assert!(pip.warm.overlapped > 0.0, "query pushes must hide under launches");
        assert!(pip.warm.total() < ser.warm.total());
    }
}
