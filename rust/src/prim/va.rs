//! VA — Vector Addition (§4.1). Dense linear algebra; int32; sequential
//! reads; no intra- or inter-DPU synchronization.
//!
//! Host splits `a` and `b` into contiguous chunks pushed with **ragged**
//! parallel transfers (the tail DPU simply receives fewer elements — no
//! sentinel padding), each DPU's tasklets stream 1,024-B blocks
//! cyclically: DMA in, add in WRAM, DMA out.

use super::common::{BenchResult, BenchTraits, PrimBench, RunConfig};
use crate::arch::{isa, DType, Op};
use crate::coordinator::ragged_counts;
use crate::dpu::Ctx;
use crate::util::Rng;

/// Paper dataset (Table 3, 1 DPU – 1 rank): 2.5 M elements.
const PAPER_N: usize = 2_500_000;
/// DMA block.
const BLOCK: usize = 1024;
const EPB: usize = BLOCK / 4; // i32 elements per block

pub struct Va;

impl PrimBench for Va {
    fn name(&self) -> &'static str {
        "VA"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Dense linear algebra",
            sequential: true,
            strided: false,
            random: false,
            ops: "add",
            dtype: "int32_t",
            intra_sync: "",
            inter_sync: false,
        }
    }

    fn run(&self, rc: &RunConfig) -> BenchResult {
        let n = rc.scaled(PAPER_N);
        let mut rng = Rng::new(rc.seed);
        let a = rng.vec_i32(n, 1 << 20);
        let b = rng.vec_i32(n, 1 << 20);

        let mut set = rc.alloc();
        let nd = rc.n_dpus as usize;
        // contiguous chunks of whole blocks; the tail chunk keeps its real
        // size (ragged transfers — no padding, no result trimming)
        let per = n.div_ceil(nd).div_ceil(EPB) * EPB;
        let counts = ragged_counts(n, per, nd);
        let chunk = |src: &[i32], d: usize| src[(d * per).min(n)..((d + 1) * per).min(n)].to_vec();
        let abufs: Vec<Vec<i32>> = (0..nd).map(|d| chunk(&a, d)).collect();
        let bbufs: Vec<Vec<i32>> = (0..nd).map(|d| chunk(&b, d)).collect();
        let a_sym = set.symbol::<i32>(per);
        let b_sym = set.symbol::<i32>(per);
        let c_sym = set.symbol::<i32>(per);
        set.xfer(a_sym).to().ragged(&abufs);
        set.xfer(b_sym).to().ragged(&bbufs);

        let instrs_per_elem =
            (2 * isa::WRAM_LS + isa::ADDR_CALC + isa::LOOP_CTRL) as u64
                + isa::op_instrs(DType::I32, Op::Add) as u64;
        let counts_ref = &counts;
        let stats = set.launch_seq(rc.n_tasklets, |d, ctx: &mut Ctx| {
            let my_bytes = counts_ref[d] * 4;
            let n_blocks = my_bytes.div_ceil(BLOCK);
            let wa = ctx.mem_alloc(BLOCK);
            let wb = ctx.mem_alloc(BLOCK);
            let mut blk = ctx.tasklet_id as usize;
            while blk < n_blocks {
                let off = blk * BLOCK;
                let take = (my_bytes - off).min(BLOCK);
                ctx.mram_read(a_sym.off() + off, wa, take);
                ctx.mram_read(b_sym.off() + off, wb, take);
                // zero-copy in-WRAM add: c (over a's buffer) = a + b
                ctx.wram_zip::<i32>(wb, wa, take / 4, |b, a| {
                    for (x, y) in a.iter_mut().zip(b) {
                        *x = x.wrapping_add(*y);
                    }
                });
                ctx.compute((take / 4) as u64 * instrs_per_elem);
                ctx.mram_write(wa, c_sym.off() + off, take);
                blk += ctx.n_tasklets as usize;
            }
        });

        let out = set.xfer(c_sym).from().ragged(&counts);
        let mut c = Vec::with_capacity(n);
        for part in &out {
            c.extend_from_slice(part);
        }
        let verified = c
            .iter()
            .zip(a.iter().zip(&b))
            .all(|(cv, (av, bv))| *cv == av.wrapping_add(*bv));

        BenchResult {
            name: self.name(),
            breakdown: set.metrics,
            verified,
            work_items: n as u64,
            dpu_instrs: stats.total_instrs(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verifies_on_small_run() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        let r = Va.run(&rc);
        assert!(r.verified);
        assert!(r.breakdown.dpu > 0.0);
        assert!(r.breakdown.cpu_dpu > 0.0);
        assert!(r.breakdown.dpu_cpu > 0.0);
        assert_eq!(r.breakdown.inter_dpu, 0.0, "VA has no inter-DPU sync");
    }

    #[test]
    fn ragged_moves_exactly_the_dataset() {
        // no sentinel padding: bytes moved == 2n in + n out, even when n
        // does not divide evenly across the DPUs
        let rc = RunConfig {
            n_dpus: 7,
            scale: 0.003,
            ..RunConfig::rank_default()
        };
        let n = rc.scaled(2_500_000) as u64;
        let r = Va.run(&rc);
        assert!(r.verified);
        assert_eq!(r.breakdown.bytes_to_dpu, 2 * n * 4);
        assert_eq!(r.breakdown.bytes_from_dpu, n * 4);
    }

    #[test]
    fn strong_scaling_dpu_time_drops() {
        let mk = |nd: u32| {
            let rc = RunConfig {
                n_dpus: nd,
                scale: 0.004,
                ..RunConfig::rank_default()
            };
            Va.run(&rc).breakdown.dpu
        };
        let t1 = mk(1);
        let t4 = mk(4);
        assert!(t1 / t4 > 3.0, "speedup {}", t1 / t4);
    }

    #[test]
    fn tasklet_scaling_saturates_near_11() {
        let mk = |t: u32| {
            let rc = RunConfig {
                n_dpus: 1,
                n_tasklets: t,
                scale: 0.002,
                ..RunConfig::rank_default()
            };
            Va.run(&rc).breakdown.dpu
        };
        let t1 = mk(1);
        let t8 = mk(8);
        let t16 = mk(16);
        assert!(t1 / t8 > 4.0);
        assert!(t8 / t16 < 2.0, "diminishing returns after 8");
    }
}
