//! VA — Vector Addition (§4.1). Dense linear algebra; int32; sequential
//! reads; no intra- or inter-DPU synchronization.
//!
//! Host splits `a` and `b` into contiguous chunks pushed with **ragged**
//! parallel transfers (the tail DPU simply receives fewer elements — no
//! sentinel padding), each DPU's tasklets stream 1,024-B blocks
//! cyclically: DMA in, add in WRAM, DMA out.
//!
//! Lifecycle: the two input vectors are resident (loaded once); a warm
//! request re-executes the add against them — a streaming workload in the
//! staged API.

use super::common::{BenchTraits, RunConfig};
use super::workload::{Dataset, Output, Request, Staged, Workload};
use crate::arch::{isa, DType, Op};
use crate::coordinator::{ragged_counts, LaunchStats, Session, Symbol};
use crate::dpu::Ctx;
use crate::util::Rng;

/// Paper dataset (Table 3, 1 DPU – 1 rank): 2.5 M elements.
const PAPER_N: usize = 2_500_000;
/// DMA block.
const BLOCK: usize = 1024;
const EPB: usize = BLOCK / 4; // i32 elements per block

pub struct Va;

/// Host dataset: inputs, reference sum, and the per-DPU partition.
pub struct VaData {
    a: Vec<i32>,
    b: Vec<i32>,
    c_ref: Vec<i32>,
    n: usize,
    per: usize,
    counts: Vec<usize>,
}

/// Resident MRAM state.
#[derive(Clone, Copy)]
struct VaState {
    a_sym: Symbol<i32>,
    b_sym: Symbol<i32>,
    c_sym: Symbol<i32>,
}

/// Retrieved result of the last request.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct VaOut {
    pub c: Vec<i32>,
}

impl Workload for Va {
    fn name(&self) -> &'static str {
        "VA"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Dense linear algebra",
            sequential: true,
            strided: false,
            random: false,
            ops: "add",
            dtype: "int32_t",
            intra_sync: "",
            inter_sync: false,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        let n = rc.scaled(PAPER_N);
        let mut rng = Rng::new(rc.seed);
        let a = rng.vec_i32(n, 1 << 20);
        let b = rng.vec_i32(n, 1 << 20);
        let c_ref: Vec<i32> =
            a.iter().zip(&b).map(|(x, y)| x.wrapping_add(*y)).collect();
        // contiguous chunks of whole blocks; the tail chunk keeps its real
        // size (ragged transfers — no padding, no result trimming)
        let nd = rc.n_dpus as usize;
        let per = n.div_ceil(nd).div_ceil(EPB) * EPB;
        let counts = ragged_counts(n, per, nd);
        Dataset::new(n as u64, VaData { a, b, c_ref, n, per, counts })
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        let d = ds.get::<VaData>();
        let nd = sess.set.n_dpus() as usize;
        assert_eq!(nd, d.counts.len(), "session fleet must match the prepared dataset");
        let chunk =
            |src: &[i32], i: usize| src[(i * d.per).min(d.n)..((i + 1) * d.per).min(d.n)].to_vec();
        let abufs: Vec<Vec<i32>> = (0..nd).map(|i| chunk(&d.a, i)).collect();
        let bbufs: Vec<Vec<i32>> = (0..nd).map(|i| chunk(&d.b, i)).collect();
        let a_sym = sess.set.symbol::<i32>(d.per);
        let b_sym = sess.set.symbol::<i32>(d.per);
        let c_sym = sess.set.symbol::<i32>(d.per);
        sess.set.xfer(a_sym).to().ragged(&abufs);
        sess.set.xfer(b_sym).to().ragged(&bbufs);
        sess.put_state(VaState { a_sym, b_sym, c_sym });
        sess.mark_loaded("VA");
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        _req: &Request,
        _staged: Staged,
    ) -> LaunchStats {
        let d = ds.get::<VaData>();
        let st = *sess.state::<VaState>();
        let instrs_per_elem = (2 * isa::WRAM_LS + isa::ADDR_CALC + isa::LOOP_CTRL) as u64
            + isa::op_instrs(DType::I32, Op::Add) as u64;
        let counts_ref = &d.counts;
        sess.launch_seq(sess.n_tasklets, |dpu, ctx: &mut Ctx| {
            let my_bytes = counts_ref[dpu] * 4;
            let n_blocks = my_bytes.div_ceil(BLOCK);
            let wa = ctx.mem_alloc(BLOCK);
            let wb = ctx.mem_alloc(BLOCK);
            let mut blk = ctx.tasklet_id as usize;
            while blk < n_blocks {
                let off = blk * BLOCK;
                let take = (my_bytes - off).min(BLOCK);
                ctx.mram_read(st.a_sym.off() + off, wa, take);
                ctx.mram_read(st.b_sym.off() + off, wb, take);
                // zero-copy in-WRAM add: c (over a's buffer) = a + b
                ctx.wram_zip::<i32>(wb, wa, take / 4, |b, a| {
                    for (x, y) in a.iter_mut().zip(b) {
                        *x = x.wrapping_add(*y);
                    }
                });
                ctx.compute((take / 4) as u64 * instrs_per_elem);
                ctx.mram_write(wa, st.c_sym.off() + off, take);
                blk += ctx.n_tasklets as usize;
            }
        })
    }

    fn retrieve(&self, sess: &mut Session, ds: &Dataset) -> Output {
        let d = ds.get::<VaData>();
        let c_sym = sess.state::<VaState>().c_sym;
        let out = sess.set.xfer(c_sym).from().ragged(&d.counts);
        let mut c = Vec::with_capacity(d.n);
        for part in &out {
            c.extend_from_slice(part);
        }
        Output::new(VaOut { c })
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        out.get::<VaOut>().c == ds.get::<VaData>().c_ref
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prim::common::PrimBench;

    #[test]
    fn verifies_on_small_run() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        let r = Va.run(&rc);
        assert!(r.verified);
        assert!(r.breakdown.dpu > 0.0);
        assert!(r.breakdown.cpu_dpu > 0.0);
        assert!(r.breakdown.dpu_cpu > 0.0);
        assert_eq!(r.breakdown.inter_dpu, 0.0, "VA has no inter-DPU sync");
    }

    #[test]
    fn ragged_moves_exactly_the_dataset() {
        // no sentinel padding: bytes moved == 2n in + n out, even when n
        // does not divide evenly across the DPUs
        let rc = RunConfig {
            n_dpus: 7,
            scale: 0.003,
            ..RunConfig::rank_default()
        };
        let n = rc.scaled(2_500_000) as u64;
        let r = Va.run(&rc);
        assert!(r.verified);
        assert_eq!(r.breakdown.bytes_to_dpu, 2 * n * 4);
        assert_eq!(r.breakdown.bytes_from_dpu, n * 4);
    }

    #[test]
    fn strong_scaling_dpu_time_drops() {
        let mk = |nd: u32| {
            let rc = RunConfig {
                n_dpus: nd,
                scale: 0.004,
                ..RunConfig::rank_default()
            };
            Va.run(&rc).breakdown.dpu
        };
        let t1 = mk(1);
        let t4 = mk(4);
        assert!(t1 / t4 > 3.0, "speedup {}", t1 / t4);
    }

    #[test]
    fn tasklet_scaling_saturates_near_11() {
        let mk = |t: u32| {
            let rc = RunConfig {
                n_dpus: 1,
                n_tasklets: t,
                scale: 0.002,
                ..RunConfig::rank_default()
            };
            Va.run(&rc).breakdown.dpu
        };
        let t1 = mk(1);
        let t8 = mk(8);
        let t16 = mk(16);
        assert!(t1 / t8 > 4.0);
        assert!(t8 / t16 < 2.0, "diminishing returns after 8");
    }

    /// Warm re-execute: a second request against the resident vectors
    /// pays zero CPU-DPU input reload and the bit-identical kernel time.
    #[test]
    fn warm_reexecute_amortizes_input_load() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        let ds = Va.prepare(&rc);
        let mut sess = rc.session();
        Va.load(&mut sess, &ds);
        let load_bytes = sess.set.metrics.bytes_to_dpu;
        let r0 = Request::new(0, rc.seed);
        let s0 = Va.execute(&mut sess, &ds, &r0, Staged::empty());
        let before = sess.set.metrics;
        let r1 = Request::new(1, rc.seed ^ 1);
        let s1 = Va.execute(&mut sess, &ds, &r1, Staged::empty());
        let delta = sess.set.metrics.delta(&before);
        assert_eq!(delta.bytes_to_dpu, 0, "no input reload on warm requests");
        assert_eq!(delta.cpu_dpu, 0.0);
        assert_eq!(s0.secs.to_bits(), s1.secs.to_bits(), "identical modeled kernel time");
        assert_eq!(sess.set.metrics.bytes_to_dpu, load_bytes);
        let out = Va.retrieve(&mut sess, &ds);
        assert!(Va.verify(&ds, &out));
    }
}
