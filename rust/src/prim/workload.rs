//! The staged workload lifecycle: `prepare → load → (stage → execute)* →
//! retrieve → verify`.
//!
//! The monolithic `PrimBench::run` re-allocated the fleet and re-pushed
//! every input on each call — exactly the one-shot pattern the paper's §6
//! recommendations argue against. [`Workload`] splits the run into
//! explicit stages so a [`Session`] can keep a dataset resident in MRAM
//! and serve many requests against warm state:
//!
//! * [`Workload::prepare`] — pure host-side dataset generation;
//! * [`Workload::load`] — allocate `Symbol<T>` regions and push the
//!   resident inputs (the cold, amortizable CPU-DPU cost);
//! * [`Workload::stage`] — pure host-side staging of one request's input
//!   buffers (overlappable under the previous launch);
//! * [`Workload::execute`] — push the staged input and launch kernels;
//! * [`Workload::retrieve`] — pull and merge the last request's results;
//! * [`Workload::verify`] — check an output against the native reference.
//!
//! `PrimBench::run` survives as a thin compatibility shim
//! ([`run_oneshot`], blanket-implemented for every `Workload`): one
//! session, one request, same four-bucket breakdown as before.
//!
//! Query-style workloads (BS, TS, BFS, MLP, GEMV) accept genuinely new
//! work per request — fresh queries, input vectors, or roots — while
//! streaming workloads re-execute their kernels against the warm resident
//! dataset (TRNS is the exception: its input layout *is* the per-request
//! step-1 transfer, so warm requests still pay it; that is the paper's
//! Key Observation 13 in lifecycle form).

use super::common::{BenchResult, BenchTraits, PrimBench, RunConfig};
use crate::coordinator::{LaunchStats, Session, TimeBreakdown};
use std::any::Any;

// ---------------------------------------------------------------- request

/// One unit of serving work against a loaded dataset.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Request {
    /// Position in the request stream (0 = the one-shot request).
    pub id: u64,
    /// Seed for the request's input generation.
    pub seed: u64,
}

impl Request {
    pub fn new(id: u64, seed: u64) -> Self {
        Request { id, seed }
    }

    /// A deterministic request stream: request 0 replays `base_seed`
    /// (one-shot compatibility), later ids decorrelate via a
    /// golden-ratio hash.
    pub fn stream(base_seed: u64, n: usize) -> Vec<Request> {
        (0..n as u64)
            .map(|i| {
                let seed = if i == 0 {
                    base_seed
                } else {
                    base_seed ^ i.wrapping_mul(0x9E37_79B9_7F4A_7C15)
                };
                Request::new(i, seed)
            })
            .collect()
    }
}

// ------------------------------------------------------- type-erased boxes

/// A prepared dataset: the host-side inputs plus reference data, opaque to
/// the harness (each workload downcasts its own payload).
pub struct Dataset {
    /// Problem-size indicator (elements / queries / cells) for
    /// throughput reporting.
    pub work_items: u64,
    payload: Box<dyn Any + Send + Sync>,
}

impl Dataset {
    pub fn new<T: Any + Send + Sync>(work_items: u64, payload: T) -> Self {
        Dataset { work_items, payload: Box::new(payload) }
    }

    /// Borrow the typed payload; panics if the caller asks for the wrong
    /// workload's type.
    pub fn get<T: Any>(&self) -> &T {
        self.payload.downcast_ref::<T>().unwrap_or_else(|| {
            panic!("dataset payload is not a {}", std::any::type_name::<T>())
        })
    }
}

/// Host-side staged input of one request (what `stage` hands `execute`).
pub struct Staged(Option<Box<dyn Any + Send>>);

impl Staged {
    pub fn new<T: Any + Send>(payload: T) -> Self {
        Staged(Some(Box::new(payload)))
    }

    /// For workloads whose requests carry no per-request input (warm
    /// re-execute of the resident dataset).
    pub fn empty() -> Self {
        Staged(None)
    }

    /// Consume the staged payload.
    pub fn take<T: Any>(self) -> T {
        let boxed = self.0.expect("staged input is empty");
        *boxed.downcast::<T>().unwrap_or_else(|_| {
            panic!("staged input is not a {}", std::any::type_name::<T>())
        })
    }
}

/// A retrieved (and host-merged) result of the most recent request.
pub struct Output {
    payload: Box<dyn Any + Send>,
}

impl Output {
    pub fn new<T: Any + Send>(payload: T) -> Self {
        Output { payload: Box::new(payload) }
    }

    pub fn get<T: Any>(&self) -> &T {
        self.payload.downcast_ref::<T>().unwrap_or_else(|| {
            panic!("output payload is not a {}", std::any::type_name::<T>())
        })
    }
}

// ---------------------------------------------------------------- trait

/// A PrIM workload expressed as a staged lifecycle (see the module docs).
///
/// `load` installs the workload's session state (its `Symbol<T>` handles
/// plus per-request scratch) via [`Session::put_state`]; `execute` and
/// `retrieve` read it back with [`Session::state`].
pub trait Workload: Sync {
    fn name(&self) -> &'static str;
    fn traits(&self) -> BenchTraits;
    /// Best-performing tasklet count from the Fig. 12 study (16 for most;
    /// 8 for the mutex-heavy HST-L / TRNS step 3).
    fn best_tasklets(&self) -> u32 {
        16
    }

    /// Generate the host-side dataset (pure; no PIM interaction). The
    /// partitioning baked into the dataset derives from `rc.n_dpus`, so
    /// the session serving it must be allocated from the same config.
    fn prepare(&self, rc: &RunConfig) -> Dataset;

    /// Push the resident inputs into MRAM and install session state.
    fn load(&self, sess: &mut Session, ds: &Dataset);

    /// Pure host-side staging of one request's input buffers. Runs
    /// concurrently with the previous request's execution in pipelined
    /// batches, so it must not touch the session. Default: no per-request
    /// input (warm re-execute).
    fn stage(&self, ds: &Dataset, req: &Request) -> Staged {
        let _ = (ds, req);
        Staged::empty()
    }

    /// Push the staged input (CPU-DPU) and launch kernels against the
    /// resident state. Returns the stats of the request's final launch;
    /// per-launch instruction counts accumulate in `Session::instrs`.
    fn execute(&self, sess: &mut Session, ds: &Dataset, req: &Request, staged: Staged)
        -> LaunchStats;

    /// Pull the last executed request's results and run the host-side
    /// merge (charged to the same buckets the monolithic run used).
    fn retrieve(&self, sess: &mut Session, ds: &Dataset) -> Output;

    /// Check a retrieved output against the native reference.
    fn verify(&self, ds: &Dataset, out: &Output) -> bool;
}

/// Every staged workload is a `PrimBench`: `run` is the one-shot
/// compatibility shim over the stages.
impl<W: Workload> PrimBench for W {
    fn name(&self) -> &'static str {
        Workload::name(self)
    }

    fn traits(&self) -> BenchTraits {
        Workload::traits(self)
    }

    fn best_tasklets(&self) -> u32 {
        Workload::best_tasklets(self)
    }

    fn run(&self, rc: &RunConfig) -> BenchResult {
        run_oneshot(self, rc)
    }
}

/// One-shot run through the staged lifecycle: fresh session, single
/// request (id 0, the dataset seed), retrieve, verify.
pub fn run_oneshot<W: Workload + ?Sized>(w: &W, rc: &RunConfig) -> BenchResult {
    let ds = w.prepare(rc);
    let mut sess = Session::new(rc.alloc(), rc.n_tasklets);
    w.load(&mut sess, &ds);
    let req = Request::new(0, rc.seed);
    let staged = w.stage(&ds, &req);
    w.execute(&mut sess, &ds, &req, staged);
    let out = w.retrieve(&mut sess, &ds);
    let verified = w.verify(&ds, &out);
    BenchResult {
        name: Workload::name(w),
        breakdown: sess.set.metrics,
        verified,
        work_items: ds.work_items,
        dpu_instrs: sess.instrs,
    }
}

// ---------------------------------------------------------------- serving

/// Result of a [`serve`] run: cold load cost vs per-request warm costs.
pub struct ServeReport {
    pub name: &'static str,
    /// Breakdown of `prepare`-to-`load` (allocation + resident input
    /// distribution) — the cost a one-shot run pays on *every* call.
    pub cold: TimeBreakdown,
    /// Per-request breakdown deltas — execute *and* retrieve, so the
    /// DPU-CPU response traffic of answering each request is charged —
    /// in request order (overlap credits are batch-level and appear in
    /// `warm`, not here).
    pub requests: Vec<TimeBreakdown>,
    /// Accumulated warm-window breakdown over all requests, including
    /// any pipeline overlap credit.
    pub warm: TimeBreakdown,
    /// The last request's output, verified against the native reference.
    pub output: Output,
    pub verified: bool,
    pub pipelined: bool,
    pub work_items: u64,
}

impl ServeReport {
    /// Mean warm-request breakdown, skipping request 0 (which may still
    /// warm caches); falls back to all requests for 1-request runs.
    /// Every field is averaged — byte counters and launch counts
    /// (integer division) included, so derived rates stay consistent
    /// with the averaged seconds.
    pub fn steady_state(&self) -> TimeBreakdown {
        let window: &[TimeBreakdown] = if self.requests.len() > 1 {
            &self.requests[1..]
        } else {
            &self.requests
        };
        let mut avg = TimeBreakdown::default();
        for r in window {
            avg.add(r);
        }
        if !window.is_empty() {
            let n = window.len() as f64;
            avg.dpu /= n;
            avg.inter_dpu /= n;
            avg.cpu_dpu /= n;
            avg.dpu_cpu /= n;
            avg.overlapped /= n;
            let k = window.len() as u64;
            avg.bytes_to_dpu /= k;
            avg.bytes_from_dpu /= k;
            avg.bytes_inter /= k;
            avg.launches /= k;
        }
        avg
    }
}

/// Load `w`'s dataset into a fresh persistent session and serve
/// `n_requests` against the warm state, optionally with the pipelined
/// batch schedule. Returns the cold/warm split plus the verified last
/// output.
pub fn serve(w: &dyn Workload, rc: &RunConfig, n_requests: usize, pipeline: bool) -> ServeReport {
    assert!(n_requests >= 1, "serving needs at least one request");
    let ds = w.prepare(rc);
    let mut sess = Session::new(rc.alloc(), rc.n_tasklets).with_pipeline(pipeline);
    w.load(&mut sess, &ds);
    let cold = sess.set.metrics;
    sess.set.reset_metrics();

    let reqs = Request::stream(rc.seed, n_requests);
    let mut per_request: Vec<TimeBreakdown> = Vec::with_capacity(n_requests);
    let mut last_out: Option<Output> = None;
    {
        let ds_ref = &ds;
        let per = &mut per_request;
        let out_slot = &mut last_out;
        sess.execute_batch(
            &reqs,
            |r| w.stage(ds_ref, r),
            |s: &mut Session, r: &Request, staged: Staged| {
                let before = s.set.metrics;
                let stats = w.execute(s, ds_ref, r, staged);
                // a served request is only answered once its output is
                // pulled — charge the per-request DPU-CPU response
                // traffic instead of overwriting results silently
                *out_slot = Some(w.retrieve(s, ds_ref));
                per.push(s.set.metrics.delta(&before));
                stats
            },
        );
    }
    let out = last_out.expect("at least one request served");
    let verified = w.verify(&ds, &out);
    ServeReport {
        name: Workload::name(w),
        cold,
        requests: per_request,
        warm: sess.set.metrics,
        output: out,
        verified,
        pipelined: pipeline,
        work_items: ds.work_items,
    }
}

// --------------------------------------------------------------- registry

/// All 16 workloads in Table 2 order, as staged-lifecycle objects.
pub fn all_workloads() -> Vec<Box<dyn Workload>> {
    vec![
        Box::new(super::va::Va),
        Box::new(super::gemv::Gemv),
        Box::new(super::spmv::Spmv),
        Box::new(super::sel::Sel),
        Box::new(super::uni::Uni),
        Box::new(super::bs::Bs),
        Box::new(super::ts::Ts),
        Box::new(super::bfs::Bfs),
        Box::new(super::mlp::Mlp),
        Box::new(super::nw::Nw),
        Box::new(super::hst::Hst::short()),
        Box::new(super::hst::Hst::long()),
        Box::new(super::red::Red::default()),
        Box::new(super::scan::ScanSsa),
        Box::new(super::scan::ScanRss),
        Box::new(super::trns::Trns),
    ]
}

/// Look up a staged workload by its short name (case-insensitive).
pub fn workload_by_name(name: &str) -> Option<Box<dyn Workload>> {
    let lname = name.to_ascii_lowercase();
    all_workloads().into_iter().find(|w| w.name().to_ascii_lowercase() == lname)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_stream_is_deterministic_and_decorrelated() {
        let a = Request::stream(42, 4);
        let b = Request::stream(42, 4);
        assert_eq!(a, b);
        assert_eq!(a[0].seed, 42, "request 0 replays the dataset seed");
        assert!(a.iter().skip(1).all(|r| r.seed != 42));
        assert_eq!(a.len(), 4);
    }

    #[test]
    fn sixteen_workloads_registered() {
        assert_eq!(all_workloads().len(), 16);
        assert!(workload_by_name("bs").is_some());
        assert!(workload_by_name("Scan-RSS").is_some());
        assert!(workload_by_name("nope").is_none());
    }

    /// The staged registry and the one-shot registry are maintained as
    /// two literal lists — pin them to the same names in the same
    /// (Table 2) order so they cannot drift apart.
    #[test]
    fn registries_agree_with_all_benches() {
        let staged: Vec<&str> = all_workloads().iter().map(|w| w.name()).collect();
        let oneshot: Vec<&str> =
            super::super::common::all_benches().iter().map(|b| b.name()).collect();
        assert_eq!(staged, oneshot);
    }

    #[test]
    fn boxes_roundtrip_typed_payloads() {
        let ds = Dataset::new(10, vec![1u32, 2]);
        assert_eq!(ds.get::<Vec<u32>>(), &vec![1, 2]);
        assert_eq!(ds.work_items, 10);
        let st = Staged::new(7i64);
        assert_eq!(st.take::<i64>(), 7);
        let out = Output::new("done".to_string());
        assert_eq!(out.get::<String>(), "done");
    }

    #[test]
    #[should_panic(expected = "dataset payload is not a")]
    fn wrong_payload_type_panics() {
        let ds = Dataset::new(1, 5u8);
        let _ = ds.get::<u16>();
    }
}
