//! SCAN — Prefix Sum (§4.13), both versions.
//!
//! * **SCAN-SSA** (Scan-Scan-Add): local exclusive scan per DPU → host
//!   scans the per-DPU totals → an Add kernel shifts every element.
//!   Less synchronization; 4N MRAM accesses.
//! * **SCAN-RSS** (Reduce-Scan-Scan): local reduction per DPU → host scans
//!   totals → a local scan kernel seeded with the DPU base. One barrier
//!   more, but only 3N+1 MRAM accesses — wins for large arrays (§9.2.4 /
//!   Fig. 22 in our harness).
//!
//! Intra-DPU, both use the SEL-style handshake chain to propagate tasklet
//! prefixes.

use super::common::{BenchResult, BenchTraits, PrimBench, RunConfig};
use crate::arch::{isa, DType, Op};
use crate::coordinator::chunk_ranges;
use crate::dpu::Ctx;
use crate::util::Rng;

/// Paper dataset (Table 3): 3.8 M int64 elements.
const PAPER_N: usize = 3_800_000;
const BLOCK: usize = 1024;
const EPB: usize = BLOCK / 8;

#[derive(Clone, Copy, PartialEq)]
pub enum ScanKind {
    Ssa,
    Rss,
}

/// Intra-DPU exclusive scan of `per` elements at `in_off` → output at
/// `out_off`, starting from `base_off` (8-B MRAM cell holding the DPU
/// base). Tasklet prefix chain via handshake + MRAM slots at `slot_off`.
fn local_scan_kernel(
    ctx: &mut Ctx,
    per: usize,
    in_off: usize,
    slot_off: usize,
    out_off: usize,
    base_off: usize,
) {
    let t = ctx.tasklet_id as usize;
    let nt = ctx.n_tasklets as usize;
    let win = ctx.mem_alloc(BLOCK);
    let wout = ctx.mem_alloc(BLOCK);
    let wslot = ctx.mem_alloc(8);
    let my = chunk_ranges(per, nt)[t].clone();
    let per_elem = (2 * isa::WRAM_LS + isa::LOOP_CTRL) as u64
        + isa::op_instrs(DType::I64, Op::Add) as u64;

    // pass 1: local sum
    let mut sum = 0i64;
    let mut k = my.start;
    while k < my.end {
        let cnt = (my.end - k).min(EPB);
        ctx.mram_read(in_off + k * 8, win, cnt * 8);
        let v: Vec<i64> = ctx.wram_get(win, cnt);
        sum += v.iter().sum::<i64>();
        ctx.compute(cnt as u64 * per_elem);
        k += cnt;
    }

    // chain: receive my prefix base
    let mut base = if t == 0 {
        ctx.mram_read(base_off, wslot, 8);
        let b: Vec<i64> = ctx.wram_get(wslot, 1);
        b[0]
    } else {
        ctx.handshake_wait_for(t as u32 - 1);
        ctx.mram_read(slot_off + (t - 1) * 8, wslot, 8);
        ctx.wram_get::<i64>(wslot, 1)[0]
    };
    ctx.wram_set(wslot, &[base + sum]);
    ctx.mram_write(wslot, slot_off + t * 8, 8);
    if t + 1 < nt {
        ctx.handshake_notify();
    }

    // pass 2: exclusive scan writing output
    let mut k = my.start;
    while k < my.end {
        let cnt = (my.end - k).min(EPB);
        ctx.mram_read(in_off + k * 8, win, cnt * 8);
        let v: Vec<i64> = ctx.wram_get(win, cnt);
        let mut out = Vec::with_capacity(cnt);
        for x in v {
            out.push(base);
            base += x;
        }
        ctx.wram_set(wout, &out);
        ctx.compute(cnt as u64 * per_elem);
        ctx.mram_write(wout, out_off + k * 8, cnt * 8);
        k += cnt;
    }
}

pub fn run_scan(kind: ScanKind, name: &'static str, rc: &RunConfig) -> BenchResult {
    let n = rc.scaled(PAPER_N);
    let mut rng = Rng::new(rc.seed);
    let input = rng.vec_i64(n, 1 << 20);
    // exclusive scan reference
    let mut scan_ref = Vec::with_capacity(n);
    let mut acc = 0i64;
    for &x in &input {
        scan_ref.push(acc);
        acc += x;
    }

    let mut set = rc.alloc();
    let nd = rc.n_dpus as usize;
    let per = n.div_ceil(nd).div_ceil(EPB) * EPB;
    let bufs: Vec<Vec<i64>> = (0..nd)
        .map(|d| {
            let lo = (d * per).min(n);
            let hi = ((d + 1) * per).min(n);
            let mut v = input[lo..hi].to_vec();
            v.resize(per, 0); // additive identity
            v
        })
        .collect();
    let in_sym = set.symbol::<i64>(per);
    let slot_sym = set.symbol::<i64>(rc.n_tasklets as usize);
    let base_sym = set.symbol::<i64>(1);
    let out_sym = set.symbol::<i64>(per);
    set.xfer(in_sym).to().equal(&bufs);
    let (slot_off, base_off, out_off) = (slot_sym.off(), base_sym.off(), out_sym.off());
    // zero bases
    set.xfer(base_sym).to().broadcast(&[0i64]);

    let mut total_instrs = 0u64;
    match kind {
        ScanKind::Ssa => {
            // kernel 1: local scan (base 0)
            let s1 = set.launch_seq(rc.n_tasklets, |_d, ctx: &mut Ctx| {
                local_scan_kernel(ctx, per, in_sym.off(), slot_off, out_off, base_off);
            });
            total_instrs += s1.total_instrs();
            // host: gather per-DPU totals (last chain slot), scan, send bases
            let last_slot = slot_sym.slice(rc.n_tasklets as usize - 1, 1);
            let mut bases = Vec::with_capacity(nd);
            let mut running = 0i64;
            for d in 0..nd {
                bases.push(running);
                running += set.xfer(last_slot).inter().from().one(d, 1)[0];
            }
            set.host_merge((nd * 8) as u64, nd as u64);
            for (d, b) in bases.iter().enumerate() {
                set.xfer(base_sym).inter().to().one(d, &[*b]);
            }
            // kernel 2: Add base to every output element
            let per_elem = (2 * isa::WRAM_LS + isa::LOOP_CTRL) as u64
                + isa::op_instrs(DType::I64, Op::Add) as u64;
            let s2 = set.launch_seq(rc.n_tasklets, |_d, ctx: &mut Ctx| {
                let win = ctx.mem_alloc(BLOCK);
                let wb = ctx.mem_alloc(8);
                ctx.mram_read(base_off, wb, 8);
                let base = ctx.wram_get::<i64>(wb, 1)[0];
                let my = chunk_ranges(per, ctx.n_tasklets as usize)
                    [ctx.tasklet_id as usize]
                    .clone();
                let mut k = my.start;
                while k < my.end {
                    let cnt = (my.end - k).min(EPB);
                    ctx.mram_read(out_off + k * 8, win, cnt * 8);
                    let v: Vec<i64> = ctx.wram_get(win, cnt);
                    let o: Vec<i64> = v.iter().map(|x| x + base).collect();
                    ctx.wram_set(win, &o);
                    ctx.compute(cnt as u64 * per_elem);
                    ctx.mram_write(win, out_off + k * 8, cnt * 8);
                    k += cnt;
                }
            });
            total_instrs += s2.total_instrs();
        }
        ScanKind::Rss => {
            // kernel 1: per-DPU reduction (reuse the chain: the last slot
            // after a scan pass 1 is the DPU total; a pure reduction is
            // cheaper — one pass, one barrier)
            let per_elem = (isa::WRAM_LS + isa::ADDR_CALC + isa::LOOP_CTRL) as u64
                + isa::op_instrs(DType::I64, Op::Add) as u64;
            let n_blocks = per / EPB;
            let s1 = set.launch(rc.n_tasklets, |_d, ctx: &mut Ctx| {
                let t = ctx.tasklet_id as usize;
                let nt = ctx.n_tasklets as usize;
                let win = ctx.mem_alloc(BLOCK);
                let slots = ctx.mem_alloc_shared(1, nt * 8);
                let wres = ctx.mem_alloc(8);
                let mut acc = 0i64;
                let mut blk = t;
                while blk < n_blocks {
                    ctx.mram_read(in_sym.off() + blk * BLOCK, win, BLOCK);
                    let v: Vec<i64> = ctx.wram_get(win, EPB);
                    acc += v.iter().sum::<i64>();
                    ctx.compute(EPB as u64 * per_elem);
                    blk += nt;
                }
                ctx.wram_set(slots + t * 8, &[acc]);
                ctx.barrier(0);
                if t == 0 {
                    let parts: Vec<i64> = ctx.wram_get(slots, nt);
                    ctx.charge_stream(DType::I64, Op::Add, nt as u64);
                    ctx.wram_set(wres, &[parts.iter().sum::<i64>()]);
                    ctx.mram_write(wres, slot_off, 8);
                }
            });
            total_instrs += s1.total_instrs();
            // host scan of totals
            let mut bases = Vec::with_capacity(nd);
            let mut running = 0i64;
            for d in 0..nd {
                bases.push(running);
                running += set.xfer(slot_sym).inter().from().one(d, 1)[0];
            }
            set.host_merge((nd * 8) as u64, nd as u64);
            for (d, b) in bases.iter().enumerate() {
                set.xfer(base_sym).inter().to().one(d, &[*b]);
            }
            // kernel 2: local scan seeded with the base
            let s2 = set.launch_seq(rc.n_tasklets, |_d, ctx: &mut Ctx| {
                local_scan_kernel(ctx, per, in_sym.off(), slot_off, out_off, base_off);
            });
            total_instrs += s2.total_instrs();
        }
    }

    // retrieve the full scanned array (parallel — equal sizes)
    let parts = set.xfer(out_sym).from().all();
    let mut result = Vec::with_capacity(n);
    for (d, p) in parts.iter().enumerate() {
        let lo = (d * per).min(n);
        let hi = ((d + 1) * per).min(n);
        result.extend_from_slice(&p[..hi - lo]);
    }
    let verified = result == scan_ref;

    BenchResult {
        name,
        breakdown: set.metrics,
        verified,
        work_items: n as u64,
        dpu_instrs: total_instrs,
    }
}

pub struct ScanSsa;

impl PrimBench for ScanSsa {
    fn name(&self) -> &'static str {
        "SCAN-SSA"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Parallel primitives",
            sequential: true,
            strided: false,
            random: false,
            ops: "add",
            dtype: "int64_t",
            intra_sync: "handshake, barrier",
            inter_sync: true,
        }
    }

    fn run(&self, rc: &RunConfig) -> BenchResult {
        run_scan(ScanKind::Ssa, "SCAN-SSA", rc)
    }
}

pub struct ScanRss;

impl PrimBench for ScanRss {
    fn name(&self) -> &'static str {
        "SCAN-RSS"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Parallel primitives",
            sequential: true,
            strided: false,
            random: false,
            ops: "add",
            dtype: "int64_t",
            intra_sync: "handshake, barrier",
            inter_sync: true,
        }
    }

    fn run(&self, rc: &RunConfig) -> BenchResult {
        run_scan(ScanKind::Rss, "SCAN-RSS", rc)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ssa_verifies() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        let r = ScanSsa.run(&rc);
        assert!(r.verified);
        assert!(r.breakdown.inter_dpu > 0.0);
    }

    #[test]
    fn rss_verifies() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        assert!(ScanRss.run(&rc).verified);
    }

    #[test]
    fn rss_fewer_dma_bytes_than_ssa() {
        // RSS does 3N+1 MRAM accesses vs SSA's 4N
        let rc = RunConfig {
            n_dpus: 2,
            scale: 0.004,
            ..RunConfig::rank_default()
        };
        let ssa = ScanSsa.run(&rc);
        let rss = ScanRss.run(&rc);
        assert!(rss.breakdown.dpu < ssa.breakdown.dpu, "RSS wins for large arrays");
    }

    #[test]
    fn odd_tasklet_counts() {
        for nt in [1u32, 3, 13] {
            let rc = RunConfig {
                n_dpus: 2,
                n_tasklets: nt,
                scale: 0.001,
                ..RunConfig::rank_default()
            };
            assert!(ScanSsa.run(&rc).verified, "nt={nt}");
            assert!(ScanRss.run(&rc).verified, "nt={nt}");
        }
    }
}
