//! SCAN — Prefix Sum (§4.13), both versions.
//!
//! * **SCAN-SSA** (Scan-Scan-Add): local exclusive scan per DPU → host
//!   scans the per-DPU totals → an Add kernel shifts every element.
//!   Less synchronization; 4N MRAM accesses.
//! * **SCAN-RSS** (Reduce-Scan-Scan): local reduction per DPU → host scans
//!   totals → a local scan kernel seeded with the DPU base. One barrier
//!   more, but only 3N+1 MRAM accesses — wins for large arrays (§9.2.4 /
//!   Fig. 22 in our harness).
//!
//! Intra-DPU, both use the SEL-style handshake chain to propagate tasklet
//! prefixes.
//!
//! Lifecycle: the input array is resident; each warm request re-scans it
//! from a zeroed base (the base cell is re-broadcast per request, so
//! re-execution is exact even though the inter-DPU phase overwrites it).

use super::common::{BenchTraits, RunConfig};
use super::workload::{run_oneshot, Dataset, Output, Request, Staged, Workload};
use crate::arch::{isa, DType, Op};
use crate::coordinator::{chunk_ranges, LaunchStats, Session, Symbol};
use crate::dpu::Ctx;
use crate::util::Rng;

/// Paper dataset (Table 3): 3.8 M int64 elements.
const PAPER_N: usize = 3_800_000;
const BLOCK: usize = 1024;
const EPB: usize = BLOCK / 8;

#[derive(Clone, Copy, PartialEq)]
pub enum ScanKind {
    Ssa,
    Rss,
}

/// Intra-DPU exclusive scan of `per` elements at `in_off` → output at
/// `out_off`, starting from `base_off` (8-B MRAM cell holding the DPU
/// base). Tasklet prefix chain via handshake + MRAM slots at `slot_off`.
fn local_scan_kernel(
    ctx: &mut Ctx,
    per: usize,
    in_off: usize,
    slot_off: usize,
    out_off: usize,
    base_off: usize,
) {
    let t = ctx.tasklet_id as usize;
    let nt = ctx.n_tasklets as usize;
    let win = ctx.mem_alloc(BLOCK);
    let wout = ctx.mem_alloc(BLOCK);
    let wslot = ctx.mem_alloc(8);
    let my = chunk_ranges(per, nt)[t].clone();
    let per_elem = (2 * isa::WRAM_LS + isa::LOOP_CTRL) as u64
        + isa::op_instrs(DType::I64, Op::Add) as u64;

    // pass 1: local sum
    let mut sum = 0i64;
    let mut k = my.start;
    while k < my.end {
        let cnt = (my.end - k).min(EPB);
        ctx.mram_read(in_off + k * 8, win, cnt * 8);
        let v: Vec<i64> = ctx.wram_get(win, cnt);
        sum += v.iter().sum::<i64>();
        ctx.compute(cnt as u64 * per_elem);
        k += cnt;
    }

    // chain: receive my prefix base
    let mut base = if t == 0 {
        ctx.mram_read(base_off, wslot, 8);
        let b: Vec<i64> = ctx.wram_get(wslot, 1);
        b[0]
    } else {
        ctx.handshake_wait_for(t as u32 - 1);
        ctx.mram_read(slot_off + (t - 1) * 8, wslot, 8);
        ctx.wram_get::<i64>(wslot, 1)[0]
    };
    ctx.wram_set(wslot, &[base + sum]);
    ctx.mram_write(wslot, slot_off + t * 8, 8);
    if t + 1 < nt {
        ctx.handshake_notify();
    }

    // pass 2: exclusive scan writing output
    let mut k = my.start;
    while k < my.end {
        let cnt = (my.end - k).min(EPB);
        ctx.mram_read(in_off + k * 8, win, cnt * 8);
        let v: Vec<i64> = ctx.wram_get(win, cnt);
        let mut out = Vec::with_capacity(cnt);
        for x in v {
            out.push(base);
            base += x;
        }
        ctx.wram_set(wout, &out);
        ctx.compute(cnt as u64 * per_elem);
        ctx.mram_write(wout, out_off + k * 8, cnt * 8);
        k += cnt;
    }
}

// ------------------------------------------------ shared lifecycle stages

struct ScanData {
    input: Vec<i64>,
    scan_ref: Vec<i64>,
    n: usize,
    per: usize,
}

struct ScanState {
    in_sym: Symbol<i64>,
    slot_sym: Symbol<i64>,
    base_sym: Symbol<i64>,
    out_sym: Symbol<i64>,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ScanOut {
    pub result: Vec<i64>,
}

fn prepare_scan(rc: &RunConfig) -> Dataset {
    let n = rc.scaled(PAPER_N);
    let mut rng = Rng::new(rc.seed);
    let input = rng.vec_i64(n, 1 << 20);
    // exclusive scan reference
    let mut scan_ref = Vec::with_capacity(n);
    let mut acc = 0i64;
    for &x in &input {
        scan_ref.push(acc);
        acc += x;
    }
    let nd = rc.n_dpus as usize;
    let per = n.div_ceil(nd).div_ceil(EPB) * EPB;
    Dataset::new(n as u64, ScanData { input, scan_ref, n, per })
}

fn load_scan(sess: &mut Session, ds: &Dataset) {
    let d = ds.get::<ScanData>();
    let nd = sess.set.n_dpus() as usize;
    let bufs: Vec<Vec<i64>> = (0..nd)
        .map(|i| {
            let lo = (i * d.per).min(d.n);
            let hi = ((i + 1) * d.per).min(d.n);
            let mut v = d.input[lo..hi].to_vec();
            v.resize(d.per, 0); // additive identity
            v
        })
        .collect();
    let in_sym = sess.set.symbol::<i64>(d.per);
    let slot_sym = sess.set.symbol::<i64>(sess.n_tasklets as usize);
    let base_sym = sess.set.symbol::<i64>(1);
    let out_sym = sess.set.symbol::<i64>(d.per);
    sess.set.xfer(in_sym).to().equal(&bufs);
    sess.put_state(ScanState { in_sym, slot_sym, base_sym, out_sym });
}

fn execute_scan(kind: ScanKind, sess: &mut Session, ds: &Dataset) -> LaunchStats {
    let d = ds.get::<ScanData>();
    let (in_sym, slot_sym, base_sym, out_sym) = {
        let st = sess.state::<ScanState>();
        (st.in_sym, st.slot_sym, st.base_sym, st.out_sym)
    };
    let (slot_off, base_off, out_off) = (slot_sym.off(), base_sym.off(), out_sym.off());
    let nd = sess.set.n_dpus() as usize;
    let nt = sess.n_tasklets;
    let per = d.per;
    // zero bases — the inter-DPU phase below overwrites the cell, so a
    // warm re-execute must reset it to reproduce the cold run exactly
    sess.set.xfer(base_sym).to().broadcast(&[0i64]);

    match kind {
        ScanKind::Ssa => {
            // kernel 1: local scan (base 0)
            sess.launch_seq(nt, |_d, ctx: &mut Ctx| {
                local_scan_kernel(ctx, per, in_sym.off(), slot_off, out_off, base_off);
            });
            // host: gather per-DPU totals (last chain slot), scan, send bases
            let last_slot = slot_sym.slice(nt as usize - 1, 1);
            let mut bases = Vec::with_capacity(nd);
            let mut running = 0i64;
            for i in 0..nd {
                bases.push(running);
                running += sess.set.xfer(last_slot).inter().from().one(i, 1)[0];
            }
            sess.set.host_merge((nd * 8) as u64, nd as u64);
            for (i, b) in bases.iter().enumerate() {
                sess.set.xfer(base_sym).inter().to().one(i, &[*b]);
            }
            // kernel 2: Add base to every output element
            let per_elem = (2 * isa::WRAM_LS + isa::LOOP_CTRL) as u64
                + isa::op_instrs(DType::I64, Op::Add) as u64;
            sess.launch_seq(nt, |_d, ctx: &mut Ctx| {
                let win = ctx.mem_alloc(BLOCK);
                let wb = ctx.mem_alloc(8);
                ctx.mram_read(base_off, wb, 8);
                let base = ctx.wram_get::<i64>(wb, 1)[0];
                let my = chunk_ranges(per, ctx.n_tasklets as usize)
                    [ctx.tasklet_id as usize]
                    .clone();
                let mut k = my.start;
                while k < my.end {
                    let cnt = (my.end - k).min(EPB);
                    ctx.mram_read(out_off + k * 8, win, cnt * 8);
                    let v: Vec<i64> = ctx.wram_get(win, cnt);
                    let o: Vec<i64> = v.iter().map(|x| x + base).collect();
                    ctx.wram_set(win, &o);
                    ctx.compute(cnt as u64 * per_elem);
                    ctx.mram_write(win, out_off + k * 8, cnt * 8);
                    k += cnt;
                }
            })
        }
        ScanKind::Rss => {
            // kernel 1: per-DPU reduction (reuse the chain: the last slot
            // after a scan pass 1 is the DPU total; a pure reduction is
            // cheaper — one pass, one barrier)
            let per_elem = (isa::WRAM_LS + isa::ADDR_CALC + isa::LOOP_CTRL) as u64
                + isa::op_instrs(DType::I64, Op::Add) as u64;
            let n_blocks = per / EPB;
            sess.launch(nt, |_d, ctx: &mut Ctx| {
                let t = ctx.tasklet_id as usize;
                let ntl = ctx.n_tasklets as usize;
                let win = ctx.mem_alloc(BLOCK);
                let slots = ctx.mem_alloc_shared(1, ntl * 8);
                let wres = ctx.mem_alloc(8);
                let mut acc = 0i64;
                let mut blk = t;
                while blk < n_blocks {
                    ctx.mram_read(in_sym.off() + blk * BLOCK, win, BLOCK);
                    let v: Vec<i64> = ctx.wram_get(win, EPB);
                    acc += v.iter().sum::<i64>();
                    ctx.compute(EPB as u64 * per_elem);
                    blk += ntl;
                }
                ctx.wram_set(slots + t * 8, &[acc]);
                ctx.barrier(0);
                if t == 0 {
                    let parts: Vec<i64> = ctx.wram_get(slots, ntl);
                    ctx.charge_stream(DType::I64, Op::Add, ntl as u64);
                    ctx.wram_set(wres, &[parts.iter().sum::<i64>()]);
                    ctx.mram_write(wres, slot_off, 8);
                }
            });
            // host scan of totals
            let mut bases = Vec::with_capacity(nd);
            let mut running = 0i64;
            for i in 0..nd {
                bases.push(running);
                running += sess.set.xfer(slot_sym).inter().from().one(i, 1)[0];
            }
            sess.set.host_merge((nd * 8) as u64, nd as u64);
            for (i, b) in bases.iter().enumerate() {
                sess.set.xfer(base_sym).inter().to().one(i, &[*b]);
            }
            // kernel 2: local scan seeded with the base
            sess.launch_seq(nt, |_d, ctx: &mut Ctx| {
                local_scan_kernel(ctx, per, in_sym.off(), slot_off, out_off, base_off);
            })
        }
    }
}

fn retrieve_scan(sess: &mut Session, ds: &Dataset) -> Output {
    let d = ds.get::<ScanData>();
    let out_sym = sess.state::<ScanState>().out_sym;
    // retrieve the full scanned array (parallel — equal sizes)
    let parts = sess.set.xfer(out_sym).from().all();
    let mut result = Vec::with_capacity(d.n);
    for (i, p) in parts.iter().enumerate() {
        let lo = (i * d.per).min(d.n);
        let hi = ((i + 1) * d.per).min(d.n);
        result.extend_from_slice(&p[..hi - lo]);
    }
    Output::new(ScanOut { result })
}

fn verify_scan(ds: &Dataset, out: &Output) -> bool {
    out.get::<ScanOut>().result == ds.get::<ScanData>().scan_ref
}

pub struct ScanSsa;

impl Workload for ScanSsa {
    fn name(&self) -> &'static str {
        "SCAN-SSA"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Parallel primitives",
            sequential: true,
            strided: false,
            random: false,
            ops: "add",
            dtype: "int64_t",
            intra_sync: "handshake, barrier",
            inter_sync: true,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        prepare_scan(rc)
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        load_scan(sess, ds);
        sess.mark_loaded("SCAN-SSA");
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        _req: &Request,
        _staged: Staged,
    ) -> LaunchStats {
        execute_scan(ScanKind::Ssa, sess, ds)
    }

    fn retrieve(&self, sess: &mut Session, ds: &Dataset) -> Output {
        retrieve_scan(sess, ds)
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        verify_scan(ds, out)
    }
}

pub struct ScanRss;

impl Workload for ScanRss {
    fn name(&self) -> &'static str {
        "SCAN-RSS"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Parallel primitives",
            sequential: true,
            strided: false,
            random: false,
            ops: "add",
            dtype: "int64_t",
            intra_sync: "handshake, barrier",
            inter_sync: true,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        prepare_scan(rc)
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        load_scan(sess, ds);
        sess.mark_loaded("SCAN-RSS");
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        _req: &Request,
        _staged: Staged,
    ) -> LaunchStats {
        execute_scan(ScanKind::Rss, sess, ds)
    }

    fn retrieve(&self, sess: &mut Session, ds: &Dataset) -> Output {
        retrieve_scan(sess, ds)
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        verify_scan(ds, out)
    }
}

/// One-shot run of a specific scan variant (kept for the Fig. 22 harness).
pub fn run_scan(
    kind: ScanKind,
    _name: &'static str,
    rc: &RunConfig,
) -> crate::prim::common::BenchResult {
    match kind {
        ScanKind::Ssa => run_oneshot(&ScanSsa, rc),
        ScanKind::Rss => run_oneshot(&ScanRss, rc),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prim::common::PrimBench;

    #[test]
    fn ssa_verifies() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        let r = ScanSsa.run(&rc);
        assert!(r.verified);
        assert!(r.breakdown.inter_dpu > 0.0);
    }

    #[test]
    fn rss_verifies() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        assert!(ScanRss.run(&rc).verified);
    }

    #[test]
    fn rss_fewer_dma_bytes_than_ssa() {
        // RSS does 3N+1 MRAM accesses vs SSA's 4N
        let rc = RunConfig {
            n_dpus: 2,
            scale: 0.004,
            ..RunConfig::rank_default()
        };
        let ssa = ScanSsa.run(&rc);
        let rss = ScanRss.run(&rc);
        assert!(rss.breakdown.dpu < ssa.breakdown.dpu, "RSS wins for large arrays");
    }

    #[test]
    fn odd_tasklet_counts() {
        for nt in [1u32, 3, 13] {
            let rc = RunConfig {
                n_dpus: 2,
                n_tasklets: nt,
                scale: 0.001,
                ..RunConfig::rank_default()
            };
            assert!(ScanSsa.run(&rc).verified, "nt={nt}");
            assert!(ScanRss.run(&rc).verified, "nt={nt}");
        }
    }

    /// The base cell is overwritten by the inter-DPU phase; the
    /// per-request reset makes warm re-execution exact for both variants.
    #[test]
    fn warm_rescan_is_exact() {
        for (w, name) in [(&ScanSsa as &dyn Workload, "SSA"), (&ScanRss as &dyn Workload, "RSS")] {
            let rc = RunConfig {
                n_dpus: 3,
                scale: 0.001,
                ..RunConfig::rank_default()
            };
            let ds = w.prepare(&rc);
            let mut sess = rc.session();
            w.load(&mut sess, &ds);
            w.execute(&mut sess, &ds, &Request::new(0, rc.seed), Staged::empty());
            let first = w.retrieve(&mut sess, &ds);
            assert!(w.verify(&ds, &first), "{name} cold");
            w.execute(&mut sess, &ds, &Request::new(1, rc.seed ^ 5), Staged::empty());
            let second = w.retrieve(&mut sess, &ds);
            assert!(w.verify(&ds, &second), "{name} warm");
            assert_eq!(first.get::<ScanOut>(), second.get::<ScanOut>());
        }
    }
}
