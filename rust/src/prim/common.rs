//! Shared benchmark infrastructure: run configuration, results, the
//! `PrimBench` trait, and the Table 2 taxonomy.

use crate::arch::SystemConfig;
use crate::coordinator::{PimSet, Session, Telemetry, TimeBreakdown, TraceSink};

pub use crate::coordinator::ExecChoice;

/// Run configuration for a PrIM benchmark.
#[derive(Clone, Debug)]
pub struct RunConfig {
    pub sys: SystemConfig,
    /// DPUs to allocate.
    pub n_dpus: u32,
    /// Tasklets per DPU.
    pub n_tasklets: u32,
    /// Dataset scale factor relative to the paper's Table 3 sizes
    /// (1.0 = paper size; the harness defaults keep full-suite functional
    /// simulation laptop-tractable and EXPERIMENTS.md records the factor).
    pub scale: f64,
    pub seed: u64,
    /// Fleet execution engine for launches and parallel transfers.
    /// `Auto` resolves `PRIM_EXECUTOR=serial|parallel` / `PRIM_THREADS=N`
    /// (default: parallel over all host cores). Serial and parallel are
    /// bit-identical in results and modeled time — see
    /// `rust/tests/executor_equivalence.rs`.
    pub exec: ExecChoice,
    /// Trace capture sink (`--trace` CLI flag). When set, every fleet
    /// allocated through [`RunConfig::alloc`] records its modeled
    /// timeline into this sink (see `coordinator::trace`); when `None`
    /// — the default everywhere — capture costs nothing.
    pub trace: Option<TraceSink>,
    /// Live telemetry registry (`--metrics` CLI flag). When set, every
    /// fleet allocated through [`RunConfig::alloc`] folds its queue
    /// schedule digests into this registry (see `coordinator::telemetry`);
    /// when `None` — the default everywhere — recording costs nothing.
    pub metrics: Option<Telemetry>,
}

impl RunConfig {
    /// One-rank default: 64 DPUs, 16 tasklets, quarter-scale data.
    pub fn rank_default() -> Self {
        RunConfig {
            sys: SystemConfig::p21_rank(),
            n_dpus: 64,
            n_tasklets: 16,
            scale: 0.25,
            seed: 42,
            exec: ExecChoice::Auto,
            trace: None,
            metrics: None,
        }
    }

    /// Single-DPU default.
    pub fn one_dpu() -> Self {
        RunConfig {
            n_dpus: 1,
            ..Self::rank_default()
        }
    }

    /// Scale an element count, keeping it positive and 8-aligned.
    pub fn scaled(&self, paper_n: usize) -> usize {
        (((paper_n as f64 * self.scale) as usize).max(16) + 7) & !7
    }

    /// Override the fleet executor (builder style, handy in tests).
    pub fn with_exec(mut self, exec: ExecChoice) -> Self {
        self.exec = exec;
        self
    }

    /// Install a trace sink (builder style) — see `coordinator::trace`.
    pub fn with_trace(mut self, sink: TraceSink) -> Self {
        self.trace = Some(sink);
        self
    }

    /// Install a telemetry registry (builder style) — see
    /// `coordinator::telemetry`.
    pub fn with_metrics(mut self, tel: Telemetry) -> Self {
        self.metrics = Some(tel);
        self
    }

    /// Allocate the configured PIM set (`sys` × `n_dpus`) behind the
    /// configured fleet executor — the one allocation path every PrIM
    /// workload uses. A configured trace sink / telemetry registry is
    /// installed on the fleet.
    pub fn alloc(&self) -> PimSet {
        let mut set = PimSet::allocate_with(self.sys.clone(), self.n_dpus, self.exec.build());
        if let Some(sink) = &self.trace {
            set = set.with_trace(sink.clone());
        }
        if let Some(tel) = &self.metrics {
            set = set.with_telemetry(tel.clone());
        }
        set
    }

    /// Allocate a persistent serving session over [`RunConfig::alloc`].
    pub fn session(&self) -> Session {
        Session::new(self.alloc(), self.n_tasklets)
    }
}

/// Outcome of one benchmark run.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: &'static str,
    pub breakdown: TimeBreakdown,
    /// Output checked against the native reference.
    pub verified: bool,
    /// Problem-size indicator (elements / queries / cells) for
    /// throughput reporting.
    pub work_items: u64,
    /// Total DPU pipeline instructions (from the replayed timings).
    pub dpu_instrs: u64,
}

/// Table 2 row: the workload taxonomy.
#[derive(Clone, Copy, Debug)]
pub struct BenchTraits {
    pub domain: &'static str,
    pub sequential: bool,
    pub strided: bool,
    pub random: bool,
    pub ops: &'static str,
    pub dtype: &'static str,
    pub intra_sync: &'static str,
    pub inter_sync: bool,
}

/// The one-shot benchmark surface: allocate, load, execute one request,
/// retrieve, verify — in a single call.
///
/// Since the staged-lifecycle redesign this is a *compatibility shim*:
/// every [`crate::prim::workload::Workload`] gets a blanket `PrimBench`
/// impl whose `run` drives the stages through a fresh
/// `coordinator::Session` (see `prim::workload::run_oneshot`). Serving
/// paths that want warm state use the stages directly.
pub trait PrimBench: Sync {
    fn name(&self) -> &'static str;
    fn traits(&self) -> BenchTraits;
    /// Best-performing tasklet count from the Fig. 12 study (16 for most;
    /// 8 for the mutex-heavy HST-L / TRNS step 3).
    fn best_tasklets(&self) -> u32 {
        16
    }
    fn run(&self, rc: &RunConfig) -> BenchResult;
}

/// All 16 benchmarks in the paper's Table 2 order.
pub fn all_benches() -> Vec<Box<dyn PrimBench>> {
    vec![
        Box::new(super::va::Va),
        Box::new(super::gemv::Gemv),
        Box::new(super::spmv::Spmv),
        Box::new(super::sel::Sel),
        Box::new(super::uni::Uni),
        Box::new(super::bs::Bs),
        Box::new(super::ts::Ts),
        Box::new(super::bfs::Bfs),
        Box::new(super::mlp::Mlp),
        Box::new(super::nw::Nw),
        Box::new(super::hst::Hst::short()),
        Box::new(super::hst::Hst::long()),
        Box::new(super::red::Red::default()),
        Box::new(super::scan::ScanSsa),
        Box::new(super::scan::ScanRss),
        Box::new(super::trns::Trns),
    ]
}

/// Look up a benchmark by its short name (case-insensitive).
pub fn bench_by_name(name: &str) -> Option<Box<dyn PrimBench>> {
    let lname = name.to_ascii_lowercase();
    all_benches().into_iter().find(|b| b.name().to_ascii_lowercase() == lname)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sixteen_benchmarks_registered() {
        let bs = all_benches();
        assert_eq!(bs.len(), 16);
        let names: Vec<&str> = bs.iter().map(|b| b.name()).collect();
        for expected in [
            "VA", "GEMV", "SpMV", "SEL", "UNI", "BS", "TS", "BFS", "MLP", "NW", "HST-S",
            "HST-L", "RED", "SCAN-SSA", "SCAN-RSS", "TRNS",
        ] {
            assert!(names.contains(&expected), "missing {expected}");
        }
    }

    #[test]
    fn lookup_by_name() {
        assert!(bench_by_name("va").is_some());
        assert!(bench_by_name("Scan-SSA").is_some());
        assert!(bench_by_name("nope").is_none());
    }

    #[test]
    fn scaled_is_aligned() {
        let rc = RunConfig::rank_default();
        assert_eq!(rc.scaled(1000) % 8, 0);
        assert!(rc.scaled(1) >= 16);
    }

    #[test]
    fn alloc_respects_exec_choice() {
        let rc = RunConfig { n_dpus: 2, ..RunConfig::rank_default() };
        let rc = rc.with_exec(ExecChoice::Serial);
        assert_eq!(rc.alloc().exec.name(), "serial");
        let rc = rc.with_exec(ExecChoice::Parallel(3));
        assert_eq!(rc.alloc().exec.name(), "parallel");
    }
}
