//! GEMV — Matrix-Vector Multiply (§4.2). Dense linear algebra; uint32;
//! sequential reads; no synchronization. Rows are partitioned across DPUs
//! (linear assignment), the input vector is replicated on every DPU.
//!
//! Lifecycle: the matrix is resident (loaded once); each request carries a
//! fresh input vector `x` — a query-style workload that amortizes the
//! dominant matrix distribution across requests. The input vector is
//! **double-buffered** (two `x` symbols, alternating by request id) and
//! the kernel declares its MRAM footprint, so in an async command-queue
//! batch the next request's broadcast has no data dependency on the
//! running launch and hides under it (§6's overlap recommendation).

use super::common::{BenchTraits, RunConfig};
use super::workload::{Dataset, Output, Request, Staged, Workload};
use crate::arch::{isa, DType, Op};
use crate::coordinator::{chunk_ranges, Access, LaunchStats, Session, Symbol};
use crate::dpu::Ctx;
use crate::util::Rng;

/// Paper dataset (Table 3, 1 DPU – 1 rank): 8192 × 1024.
const PAPER_M: usize = 8192;
pub const N_COLS: usize = 1024;
const BLOCK: usize = 1024;
const EPB: usize = BLOCK / 4;

pub struct Gemv;

/// Shared GEMV kernel body, reused by MLP (§4.9). Computes
/// `y[r] = Σ_c m[r][c] * x[c]` for the DPU's row chunk living in MRAM at
/// `mat_off`, with x at `x_off` (n u32 words), writing y at `y_off`.
pub fn gemv_kernel(
    ctx: &mut Ctx,
    rows: usize,
    n: usize,
    mat_off: usize,
    x_off: usize,
    y_off: usize,
    relu: bool,
) {
    let n_blocks = n / EPB;
    let wm = ctx.mem_alloc(BLOCK);
    let wx = ctx.mem_alloc(BLOCK);
    let wy = ctx.mem_alloc(8);
    let arch = ctx.arch();
    let instrs_per_elem = (2 * isa::WRAM_LS + isa::LOOP_CTRL) as u64
        + isa::op_instrs_for(&arch, DType::U32, Op::Mul) as u64
        + isa::op_instrs_for(&arch, DType::U32, Op::Add) as u64;
    // consecutive row subset per tasklet
    let ranges = chunk_ranges(rows, ctx.n_tasklets as usize);
    let my = ranges[ctx.tasklet_id as usize].clone();
    for r in my {
        let mut acc: u32 = 0;
        for blk in 0..n_blocks {
            ctx.mram_read(mat_off + (r * n + blk * EPB) * 4, wm, BLOCK);
            ctx.mram_read(x_off + blk * EPB * 4, wx, BLOCK);
            // zero-copy dot-product over the two staged blocks
            ctx.wram_zip::<u32>(wx, wm, EPB, |xv, mv| {
                for (a, b) in mv.iter().zip(xv) {
                    acc = acc.wrapping_add(a.wrapping_mul(*b));
                }
            });
            ctx.compute(EPB as u64 * instrs_per_elem);
        }
        let out = if relu {
            // ReLU on signed view (MLP): max(acc, 0)
            if (acc as i32) < 0 {
                0
            } else {
                acc
            }
        } else {
            acc
        };
        if relu {
            ctx.charge_ops(DType::I32, Op::Cmp, 1);
        }
        // accumulate one output word; pad store to the 8-B DMA minimum
        ctx.wram_set(wy, &[out, 0]);
        ctx.mram_write(wy, y_off + r * 8, 8);
    }
}

/// Host dataset: the row-partitioned matrix.
pub struct GemvData {
    mat: Vec<u32>,
    m: usize,
    n: usize,
    rows_per: usize,
}

#[derive(Clone, Copy)]
struct GemvSyms {
    mat_sym: Symbol<u32>,
    /// Double-buffered input vectors, indexed by `request id % 2`.
    x_syms: [Symbol<u32>; 2],
    y_sym: Symbol<u32>,
}

struct GemvState {
    syms: GemvSyms,
    /// Input vector of the most recent request (for verification).
    cur_x: Vec<u32>,
}

/// One request's staged input.
pub struct GemvStaged {
    pub x: Vec<u32>,
}

/// Retrieved result: the request's input vector and the product.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct GemvOut {
    pub x: Vec<u32>,
    pub y: Vec<u32>,
}

impl Workload for Gemv {
    fn name(&self) -> &'static str {
        "GEMV"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Dense linear algebra",
            sequential: true,
            strided: false,
            random: false,
            ops: "add, mul",
            dtype: "uint32_t",
            intra_sync: "",
            inter_sync: false,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        let nd = rc.n_dpus as usize;
        // scale rows; keep N_COLS fixed like the paper's 1-rank dataset
        let m = rc.scaled(PAPER_M).div_ceil(nd) * nd;
        let n = N_COLS;
        let mut rng = Rng::new(rc.seed);
        let mat: Vec<u32> = (0..m * n).map(|_| rng.next_u32() >> 16).collect();
        Dataset::new((m * n) as u64, GemvData { mat, m, n, rows_per: m / nd })
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        let d = ds.get::<GemvData>();
        let nd = sess.set.n_dpus() as usize;
        assert_eq!(d.rows_per * nd, d.m, "session fleet must match the prepared dataset");
        let mat_bufs: Vec<Vec<u32>> = (0..nd)
            .map(|i| d.mat[i * d.rows_per * d.n..(i + 1) * d.rows_per * d.n].to_vec())
            .collect();
        let mat_sym = sess.set.symbol::<u32>(d.rows_per * d.n);
        let x_syms = [sess.set.symbol::<u32>(d.n), sess.set.symbol::<u32>(d.n)];
        let y_sym = sess.set.symbol::<u32>(d.rows_per * 2);
        sess.set.xfer(mat_sym).to().equal(&mat_bufs);
        sess.put_state(GemvState {
            syms: GemvSyms { mat_sym, x_syms, y_sym },
            cur_x: Vec::new(),
        });
        sess.mark_loaded("GEMV");
    }

    fn stage(&self, ds: &Dataset, req: &Request) -> Staged {
        let d = ds.get::<GemvData>();
        let mut rng = Rng::new(req.seed);
        let x: Vec<u32> = (0..d.n).map(|_| rng.next_u32() >> 16).collect();
        Staged::new(GemvStaged { x })
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        req: &Request,
        staged: Staged,
    ) -> LaunchStats {
        let d = ds.get::<GemvData>();
        let GemvStaged { x } = staged.take::<GemvStaged>();
        let syms = sess.state::<GemvState>().syms;
        let x_sym = syms.x_syms[(req.id % 2) as usize];
        sess.set.xfer(x_sym).to().broadcast(&x);
        let rows_per = d.rows_per;
        let n = d.n;
        let acc = Access::new()
            .read(syms.mat_sym.region())
            .read(x_sym.region())
            .write(syms.y_sym.region());
        let stats = sess.launch_seq_acc(acc, sess.n_tasklets, move |_d, ctx: &mut Ctx| {
            gemv_kernel(ctx, rows_per, n, syms.mat_sym.off(), x_sym.off(), syms.y_sym.off(), false);
        });
        sess.state_mut::<GemvState>().cur_x = x;
        stats
    }

    fn retrieve(&self, sess: &mut Session, _ds: &Dataset) -> Output {
        let syms = sess.state::<GemvState>().syms;
        let out = sess.set.xfer(syms.y_sym).from().all();
        let y: Vec<u32> = out.iter().flat_map(|c| c.iter().step_by(2).copied()).collect();
        Output::new(GemvOut { x: sess.state::<GemvState>().cur_x.clone(), y })
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        let d = ds.get::<GemvData>();
        let o = out.get::<GemvOut>();
        if o.y.len() != d.m || o.x.len() != d.n {
            return false;
        }
        for r in 0..d.m {
            let mut acc: u32 = 0;
            for c in 0..d.n {
                acc = acc.wrapping_add(d.mat[r * d.n + c].wrapping_mul(o.x[c]));
            }
            if o.y[r] != acc {
                return false;
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prim::common::PrimBench;

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.01,
            ..RunConfig::rank_default()
        };
        let r = Gemv.run(&rc);
        assert!(r.verified);
        assert!(r.breakdown.dpu > 0.0);
    }

    #[test]
    fn mul_heavy_slower_than_va_per_byte() {
        // GEMV uses 32-bit mul (29 instrs) → far lower throughput per
        // element than VA's native add
        let rc = RunConfig {
            n_dpus: 2,
            scale: 0.004,
            ..RunConfig::rank_default()
        };
        let g = Gemv.run(&rc);
        let per_elem = g.breakdown.dpu / g.work_items as f64;
        let v = super::super::va::Va.run(&rc);
        let va_per_elem = v.breakdown.dpu / v.work_items as f64;
        assert!(per_elem > 2.0 * va_per_elem, "{per_elem} vs {va_per_elem}");
    }

    /// Multi-request batching: every request multiplies a fresh vector
    /// against the resident matrix, and each verifies.
    #[test]
    fn serves_fresh_vectors_against_resident_matrix() {
        let rc = RunConfig {
            n_dpus: 2,
            scale: 0.004,
            ..RunConfig::rank_default()
        };
        let ds = Gemv.prepare(&rc);
        let mut sess = rc.session();
        Gemv.load(&mut sess, &ds);
        let mat_bytes = sess.set.metrics.bytes_to_dpu;
        let mut seen = Vec::new();
        for req in Request::stream(rc.seed, 3) {
            let staged = Gemv.stage(&ds, &req);
            Gemv.execute(&mut sess, &ds, &req, staged);
            let out = Gemv.retrieve(&mut sess, &ds);
            assert!(Gemv.verify(&ds, &out), "request {}", req.id);
            seen.push(out.get::<GemvOut>().x.clone());
        }
        assert_ne!(seen[0], seen[1], "requests carry distinct vectors");
        // the matrix was pushed exactly once; only x broadcasts follow
        let x_bytes = (3 * sess.set.n_dpus() as usize * seen[0].len() * 4) as u64;
        assert_eq!(sess.set.metrics.bytes_to_dpu, mat_bytes + x_bytes);
    }
}
