//! GEMV — Matrix-Vector Multiply (§4.2). Dense linear algebra; uint32;
//! sequential reads; no synchronization. Rows are partitioned across DPUs
//! (linear assignment), the input vector is replicated on every DPU.

use super::common::{BenchResult, BenchTraits, PrimBench, RunConfig};
use crate::arch::{isa, DType, Op};
use crate::coordinator::chunk_ranges;
use crate::dpu::Ctx;
use crate::util::Rng;

/// Paper dataset (Table 3, 1 DPU – 1 rank): 8192 × 1024.
const PAPER_M: usize = 8192;
pub const N_COLS: usize = 1024;
const BLOCK: usize = 1024;
const EPB: usize = BLOCK / 4;

pub struct Gemv;

/// Shared GEMV kernel body, reused by MLP (§4.9). Computes
/// `y[r] = Σ_c m[r][c] * x[c]` for the DPU's row chunk living in MRAM at
/// `mat_off`, with x at `x_off` (n u32 words), writing y at `y_off`.
pub fn gemv_kernel(
    ctx: &mut Ctx,
    rows: usize,
    n: usize,
    mat_off: usize,
    x_off: usize,
    y_off: usize,
    relu: bool,
) {
    let n_blocks = n / EPB;
    let wm = ctx.mem_alloc(BLOCK);
    let wx = ctx.mem_alloc(BLOCK);
    let wy = ctx.mem_alloc(8);
    let arch = ctx.arch();
    let instrs_per_elem = (2 * isa::WRAM_LS + isa::LOOP_CTRL) as u64
        + isa::op_instrs_for(&arch, DType::U32, Op::Mul) as u64
        + isa::op_instrs_for(&arch, DType::U32, Op::Add) as u64;
    // consecutive row subset per tasklet
    let ranges = chunk_ranges(rows, ctx.n_tasklets as usize);
    let my = ranges[ctx.tasklet_id as usize].clone();
    for r in my {
        let mut acc: u32 = 0;
        for blk in 0..n_blocks {
            ctx.mram_read(mat_off + (r * n + blk * EPB) * 4, wm, BLOCK);
            ctx.mram_read(x_off + blk * EPB * 4, wx, BLOCK);
            // zero-copy dot-product over the two staged blocks
            ctx.wram_zip::<u32>(wx, wm, EPB, |xv, mv| {
                for (a, b) in mv.iter().zip(xv) {
                    acc = acc.wrapping_add(a.wrapping_mul(*b));
                }
            });
            ctx.compute(EPB as u64 * instrs_per_elem);
        }
        let out = if relu {
            // ReLU on signed view (MLP): max(acc, 0)
            if (acc as i32) < 0 {
                0
            } else {
                acc
            }
        } else {
            acc
        };
        if relu {
            ctx.charge_ops(DType::I32, Op::Cmp, 1);
        }
        // accumulate one output word; pad store to the 8-B DMA minimum
        ctx.wram_set(wy, &[out, 0]);
        ctx.mram_write(wy, y_off + r * 8, 8);
    }
}

impl PrimBench for Gemv {
    fn name(&self) -> &'static str {
        "GEMV"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Dense linear algebra",
            sequential: true,
            strided: false,
            random: false,
            ops: "add, mul",
            dtype: "uint32_t",
            intra_sync: "",
            inter_sync: false,
        }
    }

    fn run(&self, rc: &RunConfig) -> BenchResult {
        let nd = rc.n_dpus as usize;
        // scale rows; keep N_COLS fixed like the paper's 1-rank dataset
        let m = rc.scaled(PAPER_M).div_ceil(nd) * nd;
        let n = N_COLS;
        let mut rng = Rng::new(rc.seed);
        let mat: Vec<u32> = (0..m * n).map(|_| rng.next_u32() >> 16).collect();
        let x: Vec<u32> = (0..n).map(|_| rng.next_u32() >> 16).collect();

        let mut set = rc.alloc();
        let rows_per = m / nd;
        let mat_bufs: Vec<Vec<u32>> =
            (0..nd).map(|d| mat[d * rows_per * n..(d + 1) * rows_per * n].to_vec()).collect();
        let mat_sym = set.symbol::<u32>(rows_per * n);
        let x_sym = set.symbol::<u32>(n);
        let y_sym = set.symbol::<u32>(rows_per * 2);
        set.xfer(mat_sym).to().equal(&mat_bufs);
        set.xfer(x_sym).to().broadcast(&x);

        let stats = set.launch_seq(rc.n_tasklets, |_d, ctx: &mut Ctx| {
            gemv_kernel(ctx, rows_per, n, mat_sym.off(), x_sym.off(), y_sym.off(), false);
        });

        let out = set.xfer(y_sym).from().all();
        let y: Vec<u32> = out.iter().flat_map(|c| c.iter().step_by(2).copied()).collect();

        // reference
        let mut verified = true;
        for r in 0..m {
            let mut acc: u32 = 0;
            for c in 0..n {
                acc = acc.wrapping_add(mat[r * n + c].wrapping_mul(x[c]));
            }
            if y[r] != acc {
                verified = false;
                break;
            }
        }

        BenchResult {
            name: self.name(),
            breakdown: set.metrics,
            verified,
            work_items: (m * n) as u64,
            dpu_instrs: stats.total_instrs(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.01,
            ..RunConfig::rank_default()
        };
        let r = Gemv.run(&rc);
        assert!(r.verified);
        assert!(r.breakdown.dpu > 0.0);
    }

    #[test]
    fn mul_heavy_slower_than_va_per_byte() {
        // GEMV uses 32-bit mul (29 instrs) → far lower throughput per
        // element than VA's native add
        let rc = RunConfig {
            n_dpus: 2,
            scale: 0.004,
            ..RunConfig::rank_default()
        };
        let g = Gemv.run(&rc);
        let per_elem = g.breakdown.dpu / g.work_items as f64;
        let v = super::super::va::Va.run(&rc);
        let va_per_elem = v.breakdown.dpu / v.work_items as f64;
        assert!(per_elem > 2.0 * va_per_elem, "{per_elem} vs {va_per_elem}");
    }
}
