//! UNI — Unique (§4.5). Databases; int64; sequential; like SEL but the
//! handshake chain also carries each tasklet's **last element value**, so
//! the successor can decide whether its first element is unique in the
//! context of the whole array.

use super::common::{BenchTraits, RunConfig};
use super::sel::{
    execute_compact, load_compact, prepare_compact, retrieve_compact, verify_compact, CompactKind,
};
use super::workload::{Dataset, Output, Request, Staged, Workload};
use crate::coordinator::{LaunchStats, Session};

pub struct Uni;

impl Workload for Uni {
    fn name(&self) -> &'static str {
        "UNI"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Databases",
            sequential: true,
            strided: false,
            random: false,
            ops: "add, compare",
            dtype: "int64_t",
            intra_sync: "handshake, barrier",
            inter_sync: true,
        }
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        prepare_compact(CompactKind::Unique, rc)
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        load_compact(sess, ds);
        sess.mark_loaded("UNI");
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        _req: &Request,
        _staged: Staged,
    ) -> LaunchStats {
        execute_compact(CompactKind::Unique, sess, ds)
    }

    fn retrieve(&self, sess: &mut Session, ds: &Dataset) -> Output {
        retrieve_compact(CompactKind::Unique, sess, ds)
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        verify_compact(ds, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prim::common::{PrimBench, RunConfig};

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        assert!(Uni.run(&rc).verified);
    }

    #[test]
    fn verifies_across_tasklet_and_dpu_boundaries() {
        // many DPUs / tasklets → duplicates straddle both boundary kinds
        for nd in [1u32, 2, 8] {
            for nt in [2u32, 5, 16] {
                let rc = RunConfig {
                    n_dpus: nd,
                    n_tasklets: nt,
                    scale: 0.001,
                    seed: 7 + nd as u64 * 100 + nt as u64,
                    ..RunConfig::rank_default()
                };
                assert!(Uni.run(&rc).verified, "nd={nd} nt={nt}");
            }
        }
    }
}
