//! TRNS — In-place Matrix Transposition (§4.14). The 3-step tiled
//! algorithm: the M×N array is factored as M'×m × N'×n;
//!
//! * **step 1** happens *during the CPU→DPU transfer*: M'×m serial
//!   transfers of n elements each per DPU — tiny transfers, which is why
//!   TRNS's CPU-DPU bar dominates Fig. 12 (Key Obs. 13);
//! * **step 2** (kernel): each tasklet transposes m×n tiles in WRAM;
//! * **step 3** (kernel): tasklets collaborate on the transposition of the
//!   M'×n array of m-element tiles, following permutation cycles with a
//!   mutex-protected flag bit-vector (the UPMEM ISA has no atomics).
//!
//! int64 elements; step-3 is mutex-limited, so its best tasklet count is 8
//! (Key Obs. 11).
//!
//! Lifecycle: TRNS is the suite's exception — its input layout **is** the
//! step-1 transfer, and step 2 transposes it in place, so every request
//! (warm or cold) re-pushes the matrix. The staged API makes Key Obs. 13
//! structural: `load` only carves symbols; `execute` pays the dominant
//! CPU-DPU cost each time. The in/out regions are **double-buffered** by
//! request parity and the kernels declare their footprints, so in an
//! async command-queue batch the next request's step-1 pushes (grouped
//! into one recorded bus command) slide under the current request's
//! step-2/3 kernels — exactly the overlap §6 recommends for the
//! workload whose CPU-DPU bar dominates Fig. 12.

use super::common::{BenchTraits, RunConfig};
use super::workload::{Dataset, Output, Request, Staged, Workload};
use crate::arch::{isa, DType, Op};
use crate::coordinator::{Access, LaunchStats, Session, Symbol};
use crate::dpu::Ctx;
use crate::util::pod::cast_slice_mut;
use crate::util::Rng;

/// Paper factorization (Table 3): 12288 × 16 × #DPUs × 8.
const PAPER_MPRIME: usize = 12_288;
pub const TILE_M: usize = 16;
pub const TILE_N: usize = 8;

pub struct Trns;

pub struct TrnsData {
    mat: Vec<i64>,
    mp: usize,
    grid: usize,
    n: usize,
    nd: usize,
}

#[derive(Clone, Copy)]
struct TrnsState {
    /// Double-buffered in/out regions, indexed by `request id % 2`.
    in_syms: [Symbol<i64>; 2],
    out_syms: [Symbol<i64>; 2],
    /// Buffer of the most recent request (retrieval reads it).
    cur: usize,
}

#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TrnsOut {
    pub parts: Vec<Vec<i64>>,
}

impl Workload for Trns {
    fn name(&self) -> &'static str {
        "TRNS"
    }

    fn traits(&self) -> BenchTraits {
        BenchTraits {
            domain: "Parallel primitives",
            sequential: true,
            strided: false,
            random: true,
            ops: "add, sub, mul",
            dtype: "int64_t",
            intra_sync: "mutex",
            inter_sync: false,
        }
    }

    fn best_tasklets(&self) -> u32 {
        8
    }

    fn prepare(&self, rc: &RunConfig) -> Dataset {
        let nd = rc.n_dpus as usize;
        let mp = rc.scaled(PAPER_MPRIME).max(TILE_N * 2); // M'
        let (m, n) = (mp * TILE_M, nd * TILE_N); // full matrix M×N
        let mut rng = Rng::new(rc.seed);
        let mat: Vec<i64> = (0..m * n).map(|_| rng.next_u64() as i64).collect();
        Dataset::new((m * n) as u64, TrnsData { mat, mp, grid: mp * TILE_N, n, nd })
    }

    fn load(&self, sess: &mut Session, ds: &Dataset) {
        let d = ds.get::<TrnsData>();
        assert_eq!(sess.set.n_dpus() as usize, d.nd, "session fleet must match the dataset");
        let in_syms = [
            sess.set.symbol::<i64>(d.mp * TILE_M * TILE_N),
            sess.set.symbol::<i64>(d.mp * TILE_M * TILE_N),
        ];
        // (step-3 claim flags live entirely in shared WRAM — no MRAM region)
        let out_syms = [
            sess.set.symbol::<i64>(d.grid * TILE_M),
            sess.set.symbol::<i64>(d.grid * TILE_M),
        ];
        sess.put_state(TrnsState { in_syms, out_syms, cur: 0 });
        sess.mark_loaded("TRNS");
    }

    fn execute(
        &self,
        sess: &mut Session,
        ds: &Dataset,
        req: &Request,
        _staged: Staged,
    ) -> LaunchStats {
        let d = ds.get::<TrnsData>();
        let buf = (req.id % 2) as usize;
        let (in_sym, out_sym) = {
            let st = sess.state::<TrnsState>();
            (st.in_syms[buf], st.out_syms[buf])
        };
        let (in_off, out_off) = (in_sym.off(), out_sym.off());
        let (mp, grid, n, nd) = (d.mp, d.grid, d.n, d.nd);

        // step 1: M'×m transfers of n elements per DPU; DPU dd receives
        // column-tile dd laid out as [j][r][n] (j = 0..M', r = 0..m).
        // In a queue session the thousands of tiny pushes coalesce into
        // one recorded bus command (identical bucket accounting) that
        // can slide under the previous request's kernels.
        sess.set.group_begin();
        for dd in 0..nd {
            for j in 0..mp {
                for r in 0..TILE_M {
                    let row = j * TILE_M + r;
                    let src = &d.mat[row * n + dd * TILE_N..row * n + dd * TILE_N + TILE_N];
                    sess.set
                        .xfer(in_sym.slice((j * TILE_M + r) * TILE_N, TILE_N))
                        .to()
                        .one(dd, src);
                }
            }
        }
        sess.set.group_end();

        let tile_bytes = TILE_M * TILE_N * 8; // 1 KB tiles
        let per_elem_s2 = (2 * isa::WRAM_LS + isa::ADDR_CALC + isa::LOOP_CTRL) as u64;
        // step 2: transpose each m×n tile in place (WRAM)
        let s2_acc = Access::new().read(in_sym.region()).write(in_sym.region());
        sess.launch_seq_acc(s2_acc, sess.n_tasklets, |_d, ctx: &mut Ctx| {
            let wt = ctx.mem_alloc(tile_bytes);
            let mut j = ctx.tasklet_id as usize;
            while j < mp {
                ctx.mram_read(in_off + j * tile_bytes, wt, tile_bytes);
                let tile: Vec<i64> = ctx.wram_get(wt, TILE_M * TILE_N);
                let mut tr = vec![0i64; TILE_M * TILE_N];
                for r in 0..TILE_M {
                    for c in 0..TILE_N {
                        tr[c * TILE_M + r] = tile[r * TILE_N + c];
                    }
                }
                ctx.wram_set(wt, &tr);
                ctx.compute((TILE_M * TILE_N) as u64 * per_elem_s2);
                ctx.mram_write(wt, in_off + j * tile_bytes, tile_bytes);
                j += ctx.n_tasklets as usize;
            }
        });

        // step 3: transpose the M'×n grid of m-element tiles: position
        // (j, c) → (c, j). Cycle-following with a mutex-protected claimed
        // bit-vector; output written to a separate MRAM region (the paper
        // does it in place; a scratch output keeps the same DMA traffic —
        // one read + one write per tile — without the cycle bookkeeping
        // affecting data layout).
        let vec_bytes = TILE_M * 8; // m-element tile vector = 128 B
        let arch = sess.set.cfg.dpu;
        let per_tile_s3 = (4 * isa::ADDR_CALC + isa::LOOP_CTRL) as u64
            + 2 * isa::op_instrs_for(&arch, DType::I64, Op::Mul) as u64;
        let s3_tasklets = Workload::best_tasklets(self).min(sess.n_tasklets);
        let s3_acc = Access::new().read(in_sym.region()).write(out_sym.region());
        let stats = sess.launch_seq_acc(s3_acc, s3_tasklets, |_d, ctx: &mut Ctx| {
            let t = ctx.tasklet_id as usize;
            let nt = ctx.n_tasklets as usize;
            let wv = ctx.mem_alloc(vec_bytes);
            let words = grid.div_ceil(64);
            let wflags = ctx.mem_alloc_shared(1, words * 8);
            // claim positions cyclically
            let mut pos = t;
            while pos < grid {
                // claim with mutex (flags in shared WRAM)
                ctx.mutex_lock(0);
                let claimed = ctx.wram(|wr| {
                    let f = cast_slice_mut::<u64>(&mut wr[wflags..wflags + words * 8]);
                    let was = f[pos / 64] & (1 << (pos % 64)) != 0;
                    f[pos / 64] |= 1 << (pos % 64);
                    was
                });
                ctx.charge_ops(DType::U64, Op::Bitwise, 2);
                ctx.mutex_unlock(0);
                if !claimed {
                    let (j, c) = (pos / TILE_N, pos % TILE_N);
                    // source: after step 2, tile j holds [c][r] vectors:
                    // vector (j, c) at j*tile + c*m
                    ctx.mram_read(in_off + j * tile_bytes + c * vec_bytes, wv, vec_bytes);
                    ctx.compute(per_tile_s3);
                    // destination: (c, j) in the n×M' grid
                    ctx.mram_write(wv, out_off + (c * mp + j) * vec_bytes, vec_bytes);
                }
                pos += nt;
            }
        });
        sess.state_mut::<TrnsState>().cur = buf;
        stats
    }

    fn retrieve(&self, sess: &mut Session, _ds: &Dataset) -> Output {
        let st = *sess.state::<TrnsState>();
        let out_sym = st.out_syms[st.cur];
        // retrieval: DPU dd holds rows dd*n' .. of the transposed matrix
        // (equal sizes → parallel)
        Output::new(TrnsOut { parts: sess.set.xfer(out_sym).from().all() })
    }

    fn verify(&self, ds: &Dataset, out: &Output) -> bool {
        let d = ds.get::<TrnsData>();
        let o = out.get::<TrnsOut>();
        // T[dn + c][j*m + r] == mat[(j*m + r)*n + d*n + c]
        for (dd, p) in o.parts.iter().enumerate() {
            for c in 0..TILE_N {
                for j in 0..d.mp {
                    for r in 0..TILE_M {
                        let got = p[(c * d.mp + j) * TILE_M + r];
                        let want = d.mat[(j * TILE_M + r) * d.n + dd * TILE_N + c];
                        if got != want {
                            return false;
                        }
                    }
                }
            }
        }
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prim::common::PrimBench;

    #[test]
    fn verifies_small() {
        let rc = RunConfig {
            n_dpus: 4,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        let r = Trns.run(&rc);
        assert!(r.verified);
    }

    #[test]
    fn cpu_dpu_dominates_key_obs_13() {
        // step-1's tiny serial transfers must dominate the breakdown
        let rc = RunConfig {
            n_dpus: 2,
            scale: 0.01,
            ..RunConfig::rank_default()
        };
        let r = Trns.run(&rc);
        assert!(
            r.breakdown.cpu_dpu > r.breakdown.dpu,
            "cpu_dpu {} vs dpu {}",
            r.breakdown.cpu_dpu,
            r.breakdown.dpu
        );
    }

    #[test]
    fn single_dpu_verifies() {
        let rc = RunConfig {
            n_dpus: 1,
            n_tasklets: 8,
            scale: 0.002,
            ..RunConfig::rank_default()
        };
        assert!(Trns.run(&rc).verified);
    }
}
