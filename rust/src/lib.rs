//! # PrIM-RS
//!
//! A full-system reproduction of *"Benchmarking a New Paradigm: An
//! Experimental Analysis of a Real Processing-in-Memory Architecture"*
//! (Gómez-Luna et al., 2021) — the UPMEM PIM characterization paper and the
//! PrIM benchmark suite.
//!
//! Since UPMEM hardware is not available, the substrate is a
//! **cycle-accounting simulator** whose timing model is exactly the
//! analytical model the paper derives and validates against real hardware
//! (Eq. 1 pipeline throughput, Eq. 3/4 MRAM DMA latency/bandwidth, the
//! 14-stage / 11-cycle-dispatch fine-grained-multithreaded pipeline, the
//! serialized per-DPU DMA engine, and the Fig. 10 CPU↔DPU transfer curves).
//!
//! Layering (see DESIGN.md):
//! - [`arch`]    — architecture parameters and the ISA instruction-cost model
//! - [`dpu`]     — single-DPU functional execution + fluid timing replay
//! - [`system`]  — ranks/chips organization, CPU↔DPU transfer engine, host model
//! - [`coordinator`] — L3: partitioning, kernel launch, metrics (the rust
//!   analogue of the UPMEM host runtime), the typed MRAM layout + transfer
//!   builder ([`coordinator::layout`]: `Symbol<T>` regions moved via
//!   `PimSet::xfer` with equal/ragged/broadcast distributions and explicit
//!   accounting buckets), async command queues ([`coordinator::queue`]:
//!   typed Push/Pull/Launch/HostMerge/Fence commands scheduled onto one
//!   serialized bus + per-rank kernel lanes + the host CPU, with
//!   `TimeBreakdown::overlapped` derived from the command DAG), the fleet
//!   execution engine ([`coordinator::executor`]: serial baseline vs
//!   multi-core sharding, bit-identical in modeled time), and the
//!   multi-tenant scheduler ([`coordinator::scheduler`]: rank-sliced
//!   tenants, seeded open-loop traffic, pluggable bus-arbitration
//!   policies, per-tenant QoS on the same resource timeline)
//! - [`runtime`] — PJRT client loading the AOT JAX/Pallas artifacts
//! - [`energy`]  — energy model for the Fig. 17 comparison
//! - [`baselines`] — CPU (native + roofline) and GPU (roofline) comparators
//! - [`micro`]   — Section 3 microbenchmarks (Figs. 4–10, 18)
//! - [`prim`]    — the 16 PrIM workloads (19 kernels)
//! - [`harness`] — per-table/per-figure experiment generators
//! - [`util`]    — RNG, stats, data generators, table output, mini-proptest

// Simulator kernels pass explicit MRAM/WRAM offsets (the UPMEM SDK's own
// calling convention), so several take many arguments by design.
#![allow(clippy::too_many_arguments)]

pub mod arch;
pub mod baselines;
pub mod coordinator;
pub mod dpu;
pub mod energy;
pub mod harness;
pub mod micro;
pub mod prim;
pub mod runtime;
pub mod system;
pub mod util;
