//! Instruction-cost model of the DPU ISA.
//!
//! The DPU is a 32-bit RISC core with native integer add/sub and bitwise
//! ops; 32-bit mul/div are sequences of `mul_step`/`div_step` instructions
//! (up to 32); 64-bit mul/div call runtime-library routines (`__muldi3`:
//! 123 instructions, `__divdi3`: 191); all floating point is software
//! emulation (tens to >2000 instructions).
//!
//! Per-operation instruction counts below are back-solved from the paper's
//! measured Fig. 4 throughputs via Eq. 1 (`throughput = f/n` with a
//! 5-instruction streaming-loop overhead: address calc, load, store, index
//! add, branch — Listing 1 shows 6 total for 32-bit add, i.e. overhead 5 +
//! op 1). This makes the simulator reproduce Fig. 4 by construction and
//! carries the same costs into every PrIM kernel.

/// Data types characterized by the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    I32,
    I64,
    U32,
    U64,
    F32,
    F64,
}

impl DType {
    pub fn bytes(self) -> u32 {
        match self {
            DType::I32 | DType::U32 | DType::F32 => 4,
            DType::I64 | DType::U64 | DType::F64 => 8,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::I32 => "int32",
            DType::I64 => "int64",
            DType::U32 => "uint32",
            DType::U64 => "uint64",
            DType::F32 => "float",
            DType::F64 => "double",
        }
    }

    pub const ALL: [DType; 6] = [
        DType::I32,
        DType::I64,
        DType::U32,
        DType::U64,
        DType::F32,
        DType::F64,
    ];
}

/// Arithmetic operations characterized by the paper.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Op {
    Add,
    Sub,
    Mul,
    Div,
    /// Compare (used by SEL/UNI/BS/MLP-ReLU): native, single instruction.
    Cmp,
    /// Bitwise logic (used by BFS bit-vectors): native, single instruction.
    Bitwise,
}

impl Op {
    pub fn name(self) -> &'static str {
        match self {
            Op::Add => "add",
            Op::Sub => "sub",
            Op::Mul => "mul",
            Op::Div => "div",
            Op::Cmp => "cmp",
            Op::Bitwise => "bit",
        }
    }

    pub const ARITH: [Op; 4] = [Op::Add, Op::Sub, Op::Mul, Op::Div];
}

/// Streaming-loop overhead per element: WRAM address calc (`lsl_add`),
/// WRAM load (`lw`/`ld`), WRAM store (`sw`/`sd`), loop index `add`,
/// conditional branch `jneq` (Listing 1b minus the operation itself).
pub const STREAM_OVERHEAD: u32 = 5;

/// Instructions executed in the pipeline for one arithmetic operation on
/// WRAM-resident operands (excluding the streaming-loop overhead).
///
/// Unsigned integer throughput equals signed (paper §3.1.1).
pub fn op_instrs(dtype: DType, op: Op) -> u32 {
    use DType::*;
    use Op::*;
    match (dtype, op) {
        // Native single-cycle ALU ops.
        (I32 | U32, Add | Sub) => 1,
        (I32 | U32, Cmp | Bitwise) => 1,
        // 64-bit add/sub: extra addc/subc for the upper word.
        (I64 | U64, Add | Sub) => 2,
        (I64 | U64, Cmp | Bitwise) => 2,
        // 32-bit mul/div: mul_step/div_step sequences. Back-solved from
        // 10.27 / 11.27 MOPS at 350 MHz: n = 350/10.27 ≈ 34 → op ≈ 29;
        // n = 350/11.27 ≈ 31 → op ≈ 26.
        (I32 | U32, Mul) => 29,
        (I32 | U32, Div) => 26,
        // 64-bit mul/div: __muldi3 / __divdi3 library calls. Measured
        // 2.56 / 1.40 MOPS → n ≈ 137 / 250 → op ≈ 132 / 245.
        (I64 | U64, Mul) => 132,
        (I64 | U64, Div) => 245,
        // 32-bit float emulation. Measured 4.91 / 4.59 / 1.91 / 0.34 MOPS
        // → op ≈ 66 / 71 / 178 / 1024.
        (F32, Add) => 66,
        (F32, Sub) => 71,
        (F32, Mul) => 178,
        (F32, Div) => 1024,
        (F32, Cmp) => 10,
        (F32, Bitwise) => 1,
        // 64-bit float emulation. Measured 3.32 / 3.11 / 0.53 / 0.16 MOPS
        // → op ≈ 100 / 108 / 655 / 2182.
        (F64, Add) => 100,
        (F64, Sub) => 108,
        (F64, Mul) => 655,
        (F64, Div) => 2182,
        (F64, Cmp) => 14,
        (F64, Bitwise) => 2,
    }
}

/// Total instructions per iteration of the §3.1.1 streaming read-modify-
/// write loop (Listing 1): overhead + operation.
pub fn stream_loop_instrs(dtype: DType, op: Op) -> u32 {
    // 64-bit elements need paired lw/sw on a 32-bit core only for the
    // value-carrying ops; the paper's measured 7-instruction loop for
    // 64-bit add is overhead(5) + add(1) + addc(1) = op_instrs already
    // captures the extra word.
    STREAM_OVERHEAD + op_instrs(dtype, op)
}

/// Expected streaming arithmetic throughput in MOPS at `freq_mhz` (Eq. 1).
pub fn expected_mops(dtype: DType, op: Op, freq_mhz: u32) -> f64 {
    freq_mhz as f64 / stream_loop_instrs(dtype, op) as f64
}

/// Instruction cost under the §6 future-PIM ablation
/// ([`crate::arch::DpuArch::future`]): hardware integer mul/div (pipelined
/// multiplier; multi-cycle divider) and native FP units with latencies in
/// line with simple in-order FPU designs.
pub fn op_instrs_native(dtype: DType, op: Op) -> u32 {
    use DType::*;
    use Op::*;
    match (dtype, op) {
        (I32 | U32, Mul) => 2,
        (I32 | U32, Div) => 8,
        (I64 | U64, Mul) => 4,
        (I64 | U64, Div) => 12,
        (F32, Add | Sub) => 3,
        (F32, Mul) => 4,
        (F32, Div) => 12,
        (F64, Add | Sub) => 4,
        (F64, Mul) => 6,
        (F64, Div) => 20,
        (F32 | F64, Cmp) => 2,
        _ => op_instrs(dtype, op),
    }
}

/// Architecture-aware operation cost: consults the DPU's §6 ablation flags
/// (native mul/div, native FP). All kernel charge helpers route through
/// this, so re-running any benchmark under [`crate::arch::DpuArch::future`]
/// re-times the whole workload.
pub fn op_instrs_for(arch: &crate::arch::DpuArch, dtype: DType, op: Op) -> u32 {
    let is_fp = matches!(dtype, DType::F32 | DType::F64);
    let is_muldiv = matches!(op, Op::Mul | Op::Div);
    if (is_fp && arch.native_fp) || (!is_fp && is_muldiv && arch.native_muldiv) {
        op_instrs_native(dtype, op)
    } else {
        op_instrs(dtype, op)
    }
}

/// Architecture-aware streaming-loop cost (Listing 1 with the op swapped).
pub fn stream_loop_instrs_for(arch: &crate::arch::DpuArch, dtype: DType, op: Op) -> u32 {
    STREAM_OVERHEAD + op_instrs_for(arch, dtype, op)
}

/// WRAM load/store instruction cost (any width up to 64-bit: one cycle when
/// the pipeline is full — Key Obs. 3).
pub const WRAM_LS: u32 = 1;

/// Address-calculation instruction cost.
pub const ADDR_CALC: u32 = 1;

/// Loop-control (index update + branch) cost per iteration.
pub const LOOP_CTRL: u32 = 2;

#[cfg(test)]
mod tests {
    use super::*;

    /// Eq. 1 must reproduce the paper's Fig. 4 measurements at 350 MHz.
    #[test]
    fn fig4_throughputs() {
        let cases = [
            (DType::I32, Op::Add, 58.33),
            (DType::I32, Op::Sub, 58.33),
            (DType::I64, Op::Add, 50.0),
            (DType::I32, Op::Mul, 10.27),
            (DType::I32, Op::Div, 11.27),
            (DType::I64, Op::Mul, 2.56),
            (DType::I64, Op::Div, 1.40),
            (DType::F32, Op::Add, 4.91),
            (DType::F32, Op::Sub, 4.59),
            (DType::F32, Op::Mul, 1.91),
            (DType::F32, Op::Div, 0.34),
            (DType::F64, Op::Add, 3.32),
            (DType::F64, Op::Sub, 3.11),
            (DType::F64, Op::Mul, 0.53),
            (DType::F64, Op::Div, 0.16),
        ];
        for (dt, op, paper_mops) in cases {
            let model = expected_mops(dt, op, 350);
            let err = (model - paper_mops).abs() / paper_mops;
            assert!(
                err < 0.05,
                "{:?} {:?}: model {model:.2} vs paper {paper_mops} ({:.1}% off)",
                dt,
                op,
                err * 100.0
            );
        }
    }

    #[test]
    fn unsigned_equals_signed() {
        for op in Op::ARITH {
            assert_eq!(op_instrs(DType::I32, op), op_instrs(DType::U32, op));
            assert_eq!(op_instrs(DType::I64, op), op_instrs(DType::U64, op));
        }
    }

    #[test]
    fn listing1_loop_is_6_instructions() {
        assert_eq!(stream_loop_instrs(DType::I32, Op::Add), 6);
        assert_eq!(stream_loop_instrs(DType::I64, Op::Add), 7);
    }

    #[test]
    fn fp_much_slower_than_int() {
        for op in Op::ARITH {
            assert!(op_instrs(DType::F32, op) > 10 * op_instrs(DType::I32, Op::Add));
        }
    }
}
