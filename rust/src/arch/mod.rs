//! Architecture parameters and the instruction-cost model of the UPMEM DPU.
//!
//! Everything here is taken from the paper (Section 2/3) and the UPMEM
//! documentation it cites: the pipeline shape, memory sizes, the measured
//! DMA constants (α, β), and the per-operation instruction counts that the
//! paper derives from compiled code (Listing 1) and back-solves from
//! measured throughput via Eq. 1 (`throughput = f / n`).

pub mod config;
pub mod isa;

pub use config::{DpuArch, SystemConfig, SystemKind};
pub use isa::{op_instrs, stream_loop_instrs, DType, Op};
