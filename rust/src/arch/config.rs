//! DPU and system configuration presets (Table 1 of the paper).

/// Parameters of a single DRAM Processing Unit.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct DpuArch {
    /// Core clock in MHz (350 on the 2,556-DPU system, 267 on the 640-DPU
    /// system; UPMEM targets 400+).
    pub freq_mhz: u32,
    /// Hardware threads (tasklets) per DPU.
    pub n_hw_threads: u32,
    /// Minimum cycles between two instructions of the same thread: only the
    /// last 3 of the 14 pipeline stages overlap with the next instruction's
    /// DISPATCH/FETCH, so instructions of one tasklet dispatch 11 cycles
    /// apart — the source of the "11 tasklets to fill the pipeline" rule.
    pub dispatch_interval: u32,
    /// WRAM scratchpad capacity in bytes (64 KB).
    pub wram_bytes: usize,
    /// MRAM bank capacity in bytes (64 MB).
    pub mram_bytes: usize,
    /// IRAM capacity in 48-bit instructions (4,096).
    pub iram_instrs: usize,
    /// Fixed cost of an MRAM→WRAM DMA transfer, cycles (measured: ~77).
    pub dma_alpha_read: u32,
    /// Fixed cost of a WRAM→MRAM DMA transfer, cycles (measured: ~61).
    pub dma_alpha_write: u32,
    /// Variable DMA cost in cycles per byte, as a rational (num/den) so the
    /// paper's 0.5 cy/B is exact: 2 bytes/cycle peak MRAM bandwidth.
    pub dma_beta_num: u32,
    pub dma_beta_den: u32,
    /// DMA engine occupancy overhead per transfer, cycles: the engine can
    /// overlap the tasklet-visible fixed latency α of the *next* transfer
    /// with the tail of the current one, so sustained throughput is
    /// `1 / (κ + β·size)` transfers/cycle rather than `1 / (α + β·size)`.
    /// κ = 36 reconciles the paper's 624 MB/s COPY-DMA (1,024-B blocks,
    /// ≥2 tasklets; model: 654 MB/s) with its 72.58 MB/s fine-grained
    /// 8-B random-access bandwidth at 16 tasklets (model: 70 MB/s) —
    /// neither is reachable if the full α serialized at the engine.
    pub dma_engine_overhead: u32,
    /// Max single DMA transfer size in bytes (SDK 2021.1.1 limit).
    pub dma_max_bytes: u32,
    /// Min single DMA transfer size / alignment in bytes.
    pub dma_align: u32,
    /// §6 future-PIM ablation: native integer multiply/divide hardware
    /// (the paper's Key Takeaway 2 recommendation) instead of
    /// mul_step/div_step sequences and `__muldi3`/`__divdi3`.
    pub native_muldiv: bool,
    /// §6 future-PIM ablation: native floating-point units instead of
    /// software emulation.
    pub native_fp: bool,
    /// Instructions charged for mutex lock / unlock (acquire & release are
    /// single WRAM atomic-ish ops in the SDK).
    pub mutex_instrs: u32,
    /// Instructions charged per tasklet for a barrier crossing.
    pub barrier_instrs: u32,
    /// Instructions charged for a handshake wait/notify call.
    pub handshake_instrs: u32,
}

impl DpuArch {
    /// The 350 MHz DPU of the 2,556-DPU (P21) system.
    pub fn p21() -> Self {
        DpuArch {
            freq_mhz: 350,
            ..Self::base()
        }
    }

    /// The 267 MHz DPU of the 640-DPU (E19) system.
    pub fn e19() -> Self {
        DpuArch {
            freq_mhz: 267,
            ..Self::base()
        }
    }

    /// Hypothetical next-generation DPU implementing the paper's §6
    /// recommendations: the 400–450 MHz clock UPMEM targets ([227]/[231]),
    /// hardware integer multiply/divide, and native FP units.
    pub fn future() -> Self {
        DpuArch {
            freq_mhz: 450,
            native_muldiv: true,
            native_fp: true,
            ..Self::base()
        }
    }

    fn base() -> Self {
        DpuArch {
            freq_mhz: 350,
            n_hw_threads: 24,
            dispatch_interval: 11,
            wram_bytes: 64 * 1024,
            mram_bytes: 64 * 1024 * 1024,
            iram_instrs: 4096,
            dma_alpha_read: 77,
            dma_alpha_write: 61,
            dma_beta_num: 1,
            dma_beta_den: 2,
            dma_engine_overhead: 36,
            dma_max_bytes: 2048,
            dma_align: 8,
            native_muldiv: false,
            native_fp: false,
            mutex_instrs: 2,
            barrier_instrs: 4,
            handshake_instrs: 2,
        }
    }

    /// Clock frequency in Hz.
    pub fn freq_hz(&self) -> f64 {
        self.freq_mhz as f64 * 1e6
    }

    /// Cycles → seconds.
    pub fn cycles_to_secs(&self, cycles: f64) -> f64 {
        cycles / self.freq_hz()
    }

    /// DMA latency in cycles for one transfer (Eq. 3: α + β·size).
    pub fn dma_latency_cycles(&self, read: bool, bytes: u32) -> f64 {
        let alpha = if read { self.dma_alpha_read } else { self.dma_alpha_write };
        alpha as f64 + bytes as f64 * self.dma_beta_num as f64 / self.dma_beta_den as f64
    }

    /// DMA engine occupancy of one transfer in cycles (sustained-rate
    /// cost; the issuing tasklet still observes the full Eq. 3 latency).
    pub fn dma_occupancy_cycles(&self, bytes: u32) -> f64 {
        self.dma_engine_overhead as f64
            + bytes as f64 * self.dma_beta_num as f64 / self.dma_beta_den as f64
    }

    /// Theoretical peak MRAM bandwidth, B/s (2 bytes/cycle — Key Obs. 4).
    pub fn peak_mram_bw(&self) -> f64 {
        self.freq_hz() * self.dma_beta_den as f64 / self.dma_beta_num as f64
    }

    /// Theoretical peak WRAM bandwidth for 8-byte accesses, B/s (one 8-byte
    /// load or store per cycle with a full pipeline).
    pub fn peak_wram_bw(&self) -> f64 {
        self.freq_hz() * 8.0
    }

    /// Peak arithmetic throughput in OPS (1 int add/cycle with a full
    /// pipeline).
    pub fn peak_ops(&self) -> f64 {
        self.freq_hz()
    }
}

/// Which of the paper's two machines (or a custom one) is being modeled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    /// 2,556-DPU / 20-DIMM / 350 MHz "P21" system.
    P21,
    /// 640-DPU / 10-DIMM / 267 MHz "E19" system.
    E19,
    Custom,
}

/// Whole-system organization (Table 1).
#[derive(Clone, Debug)]
pub struct SystemConfig {
    pub kind: SystemKind,
    pub dpu: DpuArch,
    /// DPUs per PIM chip.
    pub dpus_per_chip: u32,
    /// Chips per rank (8 chips × 8 DPUs = 64 DPUs/rank).
    pub chips_per_rank: u32,
    /// Ranks per DIMM (2 on P21, 1 on E19).
    pub ranks_per_dimm: u32,
    /// Number of PIM DIMMs.
    pub n_dimms: u32,
    /// DPUs unavailable in the real machine (4 faulty on the paper's P21).
    pub faulty_dpus: u32,
    /// Host memory-bus theoretical bandwidth per channel (DDR4-2400:
    /// 19.2 GB/s).
    pub ddr4_channel_bw: f64,
    /// Watts per PIM chip (UPMEM: 1.2 W/chip at 350 MHz).
    pub watts_per_chip: f64,
}

impl SystemConfig {
    /// The 2,556-DPU system (20 dual-rank P21 DIMMs, 4 faulty DPUs).
    pub fn p21_2556() -> Self {
        SystemConfig {
            kind: SystemKind::P21,
            dpu: DpuArch::p21(),
            dpus_per_chip: 8,
            chips_per_rank: 8,
            ranks_per_dimm: 2,
            n_dimms: 20,
            faulty_dpus: 4,
            ddr4_channel_bw: 19.2e9,
            watts_per_chip: 1.2,
        }
    }

    /// The 640-DPU system (10 single-rank E19 DIMMs).
    pub fn e19_640() -> Self {
        SystemConfig {
            kind: SystemKind::E19,
            dpu: DpuArch::e19(),
            dpus_per_chip: 8,
            chips_per_rank: 8,
            ranks_per_dimm: 1,
            n_dimms: 10,
            faulty_dpus: 0,
            ddr4_channel_bw: 19.2e9,
            watts_per_chip: 1.2,
        }
    }

    /// A single rank of the P21 system — the unit of most scaling studies.
    pub fn p21_rank() -> Self {
        SystemConfig {
            n_dimms: 1,
            ranks_per_dimm: 1,
            faulty_dpus: 0,
            ..Self::p21_2556()
        }
    }

    pub fn dpus_per_rank(&self) -> u32 {
        self.dpus_per_chip * self.chips_per_rank
    }

    pub fn n_ranks(&self) -> u32 {
        self.n_dimms * self.ranks_per_dimm
    }

    /// Usable DPUs (total minus faulty).
    pub fn n_dpus(&self) -> u32 {
        self.n_ranks() * self.dpus_per_rank() - self.faulty_dpus
    }

    /// Total PIM-visible MRAM capacity in bytes.
    pub fn total_mram(&self) -> u64 {
        self.n_dpus() as u64 * self.dpu.mram_bytes as u64
    }

    /// Aggregate peak MRAM bandwidth, B/s (paper: 1.7 TB/s on P21).
    pub fn aggregate_mram_bw(&self) -> f64 {
        self.n_dpus() as f64 * self.dpu.peak_mram_bw()
    }

    /// System TDP estimate (Table 4: chips × 1.2 W).
    pub fn tdp_watts(&self) -> f64 {
        let chips = (self.n_ranks() * self.chips_per_rank) as f64;
        chips * self.watts_per_chip * (self.dpu.freq_mhz as f64 / 350.0).min(1.0).max(0.6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_counts() {
        let p21 = SystemConfig::p21_2556();
        assert_eq!(p21.n_dpus(), 2556);
        assert_eq!(p21.dpus_per_rank(), 64);
        assert_eq!(p21.n_ranks(), 40);
        // 159.75 GB of MRAM
        assert_eq!(p21.total_mram(), 2556 * 64 * 1024 * 1024);

        let e19 = SystemConfig::e19_640();
        assert_eq!(e19.n_dpus(), 640);
        assert_eq!(e19.total_mram(), 640 * 64 * 1024 * 1024);
    }

    #[test]
    fn peak_bandwidths() {
        let a = DpuArch::p21();
        // 2 B/cycle at 350 MHz = 700 MB/s per DPU (paper §2.2)
        assert!((a.peak_mram_bw() - 700e6).abs() < 1.0);
        // 8 B/cycle at 350 MHz = 2,800 MB/s WRAM (paper §3.1)
        assert!((a.peak_wram_bw() - 2800e6).abs() < 1.0);
        // aggregate ≈ 1.7 TB/s on the fleet
        let sys = SystemConfig::p21_2556();
        assert!((sys.aggregate_mram_bw() / 1e12 - 1.7892).abs() < 0.01);
    }

    #[test]
    fn dma_latency_eq3() {
        let a = DpuArch::p21();
        // paper: 8-byte read = 81 cycles, 128-byte read = 141 cycles
        assert_eq!(a.dma_latency_cycles(true, 8) as u32, 81);
        assert_eq!(a.dma_latency_cycles(true, 128) as u32, 141);
        assert_eq!(a.dma_latency_cycles(false, 8) as u32, 65);
    }

    #[test]
    fn e19_is_slower() {
        assert!(DpuArch::e19().peak_mram_bw() < DpuArch::p21().peak_mram_bw());
    }
}
