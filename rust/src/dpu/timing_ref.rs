//! Cycle-stepped reference timing model (ablation baseline).
//!
//! Steps the DPU one cycle at a time with integer state: each tasklet may
//! issue one instruction per cycle if (a) its previous instruction was
//! issued ≥ `dispatch_interval` cycles ago and (b) no other tasklet issued
//! this cycle (single-issue in-order pipeline); the DMA engine serves one
//! transfer at a time with integer `α + β·size` latency.
//!
//! Only `Compute` / `DmaRead` / `DmaWrite` events are supported — enough
//! for every §3 microbenchmark trace. The fluid engine
//! ([`super::timing::replay`]) is validated against this model in tests and
//! in the `ablation_timing` bench; the fluid engine is ~3 orders of
//! magnitude faster, which is what makes full-suite simulation tractable.

use super::trace::{Ev, Trace};
use crate::arch::DpuArch;
use std::collections::VecDeque;

#[derive(Clone, Copy, PartialEq)]
enum St {
    Compute { rem: u64 },
    Dma,
    Done,
}

/// Cycle-stepped replay. Returns total cycles. Panics on sync events.
pub fn replay_stepped(traces: &[Trace], arch: &DpuArch) -> u64 {
    let n = traces.len();
    let mut idx = vec![0usize; n];
    let mut st: Vec<St> = vec![St::Done; n];
    let mut next_ok = vec![0u64; n]; // earliest cycle this tasklet may issue
    let mut dma_free_at = 0u64; // engine may start next transfer here
    let mut dma_done: Vec<(usize, u64)> = Vec::new(); // (tasklet, completion)
    let mut rr = 0usize; // round-robin issue pointer

    // load next event of a tasklet; DMA transfers are scheduled immediately
    // with the same start-time rule as the fluid engine
    fn fetch(
        t: usize,
        cycle: u64,
        traces: &[Trace],
        arch: &DpuArch,
        idx: &mut [usize],
        st: &mut [St],
        dma_free_at: &mut u64,
        dma_done: &mut Vec<(usize, u64)>,
    ) {
        if idx[t] >= traces[t].events.len() {
            st[t] = St::Done;
            return;
        }
        let ev = traces[t].events[idx[t]];
        idx[t] += 1;
        match ev {
            Ev::Compute(k) => st[t] = St::Compute { rem: k },
            Ev::DmaRead(b) | Ev::DmaWrite(b) => {
                let read = matches!(ev, Ev::DmaRead(_));
                st[t] = St::Dma;
                let start = cycle.max(*dma_free_at);
                let lat = arch.dma_latency_cycles(read, b).round() as u64;
                let occ = arch.dma_occupancy_cycles(b).round() as u64;
                *dma_free_at = start + occ;
                dma_done.push((t, start + lat));
            }
            other => panic!("timing_ref supports compute/dma only, got {other:?}"),
        }
    }

    for t in 0..n {
        fetch(t, 0, traces, arch, &mut idx, &mut st, &mut dma_free_at, &mut dma_done);
    }

    let mut cycle = 0u64;
    loop {
        if st.iter().all(|s| *s == St::Done) {
            break;
        }
        // DMA completions
        let mut i = 0;
        while i < dma_done.len() {
            let (t, fin) = dma_done[i];
            if fin <= cycle {
                dma_done.swap_remove(i);
                fetch(t, cycle, traces, arch, &mut idx, &mut st, &mut dma_free_at, &mut dma_done);
            } else {
                i += 1;
            }
        }
        // issue at most one instruction this cycle, round-robin fair
        for k in 0..n {
            let t = (rr + k) % n;
            if let St::Compute { rem } = st[t] {
                if next_ok[t] <= cycle {
                    next_ok[t] = cycle + arch.dispatch_interval as u64;
                    let rem2 = rem - 1;
                    if rem2 == 0 {
                        fetch(
                            t,
                            cycle,
                            traces,
                            arch,
                            &mut idx,
                            &mut st,
                            &mut dma_free_at,
                            &mut dma_done,
                        );
                    } else {
                        st[t] = St::Compute { rem: rem2 };
                    }
                    rr = (t + 1) % n;
                    break;
                }
            }
        }
        cycle += 1;
    }
    cycle
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::DpuArch;
    use crate::dpu::timing::replay;

    fn compute_trace(instrs: u64) -> Trace {
        let mut t = Trace::default();
        t.push_compute(instrs);
        t
    }

    #[test]
    fn stepped_matches_dispatch_interval() {
        let arch = DpuArch::p21();
        let c = replay_stepped(&[compute_trace(100)], &arch);
        // 100 instructions, 11 cycles apart → ≈ 1090..1101 cycles
        assert!((c as i64 - 1100).abs() <= 11, "{c}");
    }

    #[test]
    fn fluid_vs_stepped_compute_only() {
        let arch = DpuArch::p21();
        for t in [1u32, 2, 4, 8, 11, 16] {
            let traces: Vec<Trace> = (0..t).map(|i| compute_trace(500 + i as u64 * 37)).collect();
            let fluid = replay(&traces, &arch, t).cycles;
            let stepped = replay_stepped(&traces, &arch) as f64;
            let err = (fluid - stepped).abs() / stepped;
            assert!(err < 0.02, "T={t}: fluid {fluid} stepped {stepped} err {err}");
        }
    }

    #[test]
    fn fluid_vs_stepped_mixed_dma() {
        let arch = DpuArch::p21();
        for t in [1u32, 2, 4, 8] {
            let traces: Vec<Trace> = (0..t)
                .map(|_| {
                    let mut tr = Trace::default();
                    for _ in 0..20 {
                        tr.push(Ev::DmaRead(1024));
                        tr.push_compute(256);
                        tr.push(Ev::DmaWrite(1024));
                    }
                    tr
                })
                .collect();
            let fluid = replay(&traces, &arch, t).cycles;
            let stepped = replay_stepped(&traces, &arch) as f64;
            let err = (fluid - stepped).abs() / stepped;
            assert!(err < 0.03, "T={t}: fluid {fluid} stepped {stepped} err {err}");
        }
    }
}
