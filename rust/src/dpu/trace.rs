//! Per-tasklet execution traces.
//!
//! Functional execution (real data moving through simulated MRAM/WRAM)
//! records one [`Trace`] per tasklet; the timing engine
//! ([`super::timing`]) then replays all traces of a DPU against the
//! pipeline / DMA-engine / synchronization resources. Recording and timing
//! are separated so one functional run can be re-timed under different
//! architecture parameters (350 vs 267 MHz, etc.).

/// One observable event of a tasklet.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Ev {
    /// `n` instructions issued into the pipeline (ALU ops, WRAM
    /// loads/stores, address calculations, branches — all retire 1/cycle
    /// when the pipeline is full).
    Compute(u64),
    /// MRAM→WRAM DMA transfer (`mram_read`), bytes.
    DmaRead(u32),
    /// WRAM→MRAM DMA transfer (`mram_write`), bytes.
    DmaWrite(u32),
    MutexLock(u16),
    MutexUnlock(u16),
    /// Barrier across all tasklets of the DPU.
    Barrier(u16),
    /// Wait for `peer`'s `target`-th notify (1-based, counted at record
    /// time so replay is order-independent).
    HsWait { peer: u8, target: u64 },
    HsNotify,
    SemGive(u16),
    SemTake(u16),
}

/// The recorded event sequence of one tasklet.
#[derive(Clone, Debug, Default)]
pub struct Trace {
    pub events: Vec<Ev>,
}

impl Trace {
    /// Append pipeline work, merging with a trailing `Compute` to keep
    /// traces compact (hot kernels emit millions of tiny charges).
    #[inline]
    pub fn push_compute(&mut self, instrs: u64) {
        if instrs == 0 {
            return;
        }
        if let Some(Ev::Compute(n)) = self.events.last_mut() {
            *n += instrs;
        } else {
            self.events.push(Ev::Compute(instrs));
        }
    }

    #[inline]
    pub fn push(&mut self, ev: Ev) {
        self.events.push(ev);
    }

    /// Total pipeline instructions in the trace.
    pub fn total_instrs(&self) -> u64 {
        self.events
            .iter()
            .map(|e| if let Ev::Compute(n) = e { *n } else { 0 })
            .sum()
    }

    /// Total DMA bytes (read + write).
    pub fn dma_bytes(&self) -> u64 {
        self.events
            .iter()
            .map(|e| match e {
                Ev::DmaRead(b) | Ev::DmaWrite(b) => *b as u64,
                _ => 0,
            })
            .sum()
    }

    /// Number of DMA transfers.
    pub fn dma_count(&self) -> u64 {
        self.events
            .iter()
            .filter(|e| matches!(e, Ev::DmaRead(_) | Ev::DmaWrite(_)))
            .count() as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compute_merging() {
        let mut t = Trace::default();
        t.push_compute(5);
        t.push_compute(7);
        assert_eq!(t.events, vec![Ev::Compute(12)]);
        t.push(Ev::DmaRead(64));
        t.push_compute(3);
        assert_eq!(t.events.len(), 3);
        assert_eq!(t.total_instrs(), 15);
        assert_eq!(t.dma_bytes(), 64);
        assert_eq!(t.dma_count(), 1);
    }

    #[test]
    fn zero_compute_ignored() {
        let mut t = Trace::default();
        t.push_compute(0);
        assert!(t.events.is_empty());
    }
}
