//! Fluid event-driven timing replay.
//!
//! Replays the per-tasklet traces of one DPU against three resources:
//!
//! 1. **Pipeline** — fine-grained multithreading: a tasklet in a compute
//!    segment progresses at `1 / max(dispatch_interval, A)` instructions
//!    per cycle, where `A` is the number of concurrently-computing
//!    tasklets (per-thread dispatch every 11 cycles; aggregate issue of at
//!    most 1 instruction/cycle). This reproduces Key Observation 1
//!    (throughput saturates at 11 tasklets) by construction.
//! 2. **DMA engine** — one transfer at a time, FIFO, latency
//!    `α + β·bytes` (Eq. 3). Tasklets block on their own transfers;
//!    with ≥2 tasklets the engine stays busy (Key Observation 5).
//! 3. **Synchronization** — mutexes serialize critical sections, barriers
//!    join all tasklets, handshakes order producer/consumer pairs,
//!    semaphores count.
//!
//! The fluid approximation (piecewise-constant progress rates between
//! events) is validated against a cycle-stepped reference in
//! [`super::timing_ref`] (ablation bench + tests): divergence is <1% on
//! microbenchmark traces while running ~1000× faster.

use super::trace::{Ev, Trace};
use crate::arch::DpuArch;
use std::collections::VecDeque;

/// Replay result for one DPU launch.
#[derive(Clone, Debug, Default)]
pub struct DpuTiming {
    /// Total cycles until the last tasklet finishes.
    pub cycles: f64,
    /// Total pipeline instructions issued.
    pub instrs: u64,
    /// Total bytes moved by the DMA engine (both directions).
    pub dma_bytes: u64,
    /// Number of DMA transfers.
    pub dma_count: u64,
    /// Cycles the DMA engine was busy.
    pub dma_busy_cycles: f64,
    /// Instruction-issue cycles (= instrs; pipeline busy fraction is
    /// `instrs / cycles`).
    pub pipeline_busy_cycles: f64,
}

impl DpuTiming {
    /// Pipeline utilization in [0,1].
    pub fn pipeline_util(&self) -> f64 {
        if self.cycles == 0.0 {
            0.0
        } else {
            self.pipeline_busy_cycles / self.cycles
        }
    }

    /// DMA engine utilization in [0,1].
    pub fn dma_util(&self) -> f64 {
        if self.cycles == 0.0 {
            0.0
        } else {
            self.dma_busy_cycles / self.cycles
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum St {
    /// Ready to process the next trace event.
    Ready,
    Compute {
        rem: f64,
    },
    /// Queued for (or being served by) the DMA engine.
    Dma,
    MutexWait(u16),
    BarrierWait(u16),
    HsWait {
        peer: u8,
        target: u64,
    },
    SemWait(u16),
    Done,
}

struct Engine<'a> {
    traces: &'a [Trace],
    arch: &'a DpuArch,
    n: usize,
    idx: Vec<usize>,
    st: Vec<St>,
    // DMA engine: transfers start in FIFO order; the engine can start the
    // next transfer `occupancy` cycles after the previous one started
    // (request-setup pipelining), while the issuing tasklet observes the
    // full α+β·size latency.
    dma_free_at: f64,
    dma_inflight: Vec<(usize, f64)>, // (tasklet, completion time)
    // sync
    mutex_held: [bool; super::MAX_SYNC_IDS],
    mutex_waiters: Vec<VecDeque<usize>>,
    barrier_arrived: Vec<Vec<usize>>,
    notifies: Vec<u64>,
    sem_val: [i64; super::MAX_SYNC_IDS],
    sem_waiters: Vec<VecDeque<usize>>,
    // stats
    out: DpuTiming,
}

const EPS: f64 = 1e-6;

impl<'a> Engine<'a> {
    fn new(traces: &'a [Trace], arch: &'a DpuArch) -> Self {
        let n = traces.len();
        Engine {
            traces,
            arch,
            n,
            idx: vec![0; n],
            st: vec![St::Ready; n],
            dma_free_at: 0.0,
            dma_inflight: Vec::new(),
            mutex_held: [false; super::MAX_SYNC_IDS],
            mutex_waiters: (0..super::MAX_SYNC_IDS).map(|_| VecDeque::new()).collect(),
            barrier_arrived: (0..super::MAX_SYNC_IDS).map(|_| Vec::new()).collect(),
            notifies: vec![0; 24.max(n)],
            sem_val: [0; super::MAX_SYNC_IDS],
            sem_waiters: (0..super::MAX_SYNC_IDS).map(|_| VecDeque::new()).collect(),
            out: DpuTiming::default(),
        }
    }

    /// Schedule a DMA transfer issued by tasklet `t` at time `now`.
    fn enqueue_dma(&mut self, t: usize, now: f64, read: bool, bytes: u32) {
        let start = now.max(self.dma_free_at);
        let lat = self.arch.dma_latency_cycles(read, bytes);
        let occ = self.arch.dma_occupancy_cycles(bytes);
        self.dma_free_at = start + occ;
        self.dma_inflight.push((t, start + lat));
        self.out.dma_busy_cycles += occ;
        self.out.dma_bytes += bytes as u64;
        self.out.dma_count += 1;
    }

    /// Process events for tasklet `t` until it blocks or finishes.
    /// May unblock other tasklets (worklist).
    fn advance(&mut self, t: usize, now: f64, work: &mut Vec<usize>) {
        loop {
            let tr = &self.traces[t];
            if self.idx[t] >= tr.events.len() {
                self.st[t] = St::Done;
                return;
            }
            let ev = tr.events[self.idx[t]];
            self.idx[t] += 1;
            match ev {
                Ev::Compute(n) => {
                    self.out.instrs += n;
                    self.out.pipeline_busy_cycles += n as f64;
                    self.st[t] = St::Compute { rem: n as f64 };
                    return;
                }
                Ev::DmaRead(b) => {
                    self.st[t] = St::Dma;
                    self.enqueue_dma(t, now, true, b);
                    return;
                }
                Ev::DmaWrite(b) => {
                    self.st[t] = St::Dma;
                    self.enqueue_dma(t, now, false, b);
                    return;
                }
                Ev::MutexLock(id) => {
                    let id = id as usize;
                    if self.mutex_held[id] {
                        self.st[t] = St::MutexWait(id as u16);
                        self.mutex_waiters[id].push_back(t);
                        return;
                    }
                    self.mutex_held[id] = true;
                }
                Ev::MutexUnlock(id) => {
                    let id = id as usize;
                    debug_assert!(self.mutex_held[id]);
                    if let Some(w) = self.mutex_waiters[id].pop_front() {
                        // hand the mutex to the head waiter
                        self.st[w] = St::Ready;
                        work.push(w);
                    } else {
                        self.mutex_held[id] = false;
                    }
                }
                Ev::Barrier(id) => {
                    let id = id as usize;
                    self.barrier_arrived[id].push(t);
                    if self.barrier_arrived[id].len() == self.n {
                        let arrived = std::mem::take(&mut self.barrier_arrived[id]);
                        for w in arrived {
                            if w != t {
                                self.st[w] = St::Ready;
                                work.push(w);
                            }
                        }
                        // this tasklet continues immediately
                    } else {
                        self.st[t] = St::BarrierWait(id as u16);
                        return;
                    }
                }
                Ev::HsWait { peer, target } => {
                    if self.notifies[peer as usize] < target {
                        self.st[t] = St::HsWait { peer, target };
                        return;
                    }
                }
                Ev::HsNotify => {
                    self.notifies[t] += 1;
                    for w in 0..self.n {
                        if let St::HsWait { peer, target } = self.st[w] {
                            if peer as usize == t && self.notifies[t] >= target {
                                self.st[w] = St::Ready;
                                work.push(w);
                            }
                        }
                    }
                }
                Ev::SemGive(id) => {
                    let id = id as usize;
                    if let Some(w) = self.sem_waiters[id].pop_front() {
                        self.st[w] = St::Ready;
                        work.push(w);
                    } else {
                        self.sem_val[id] += 1;
                    }
                }
                Ev::SemTake(id) => {
                    let id = id as usize;
                    if self.sem_val[id] > 0 {
                        self.sem_val[id] -= 1;
                    } else {
                        self.st[t] = St::SemWait(id as u16);
                        self.sem_waiters[id].push_back(t);
                        return;
                    }
                }
            }
        }
    }

    fn drain_worklist(&mut self, now: f64, work: &mut Vec<usize>) {
        // `work` doubles as the stack: advance() pushes newly-unblocked
        // tasklets onto it — no per-event allocation on the hot path
        while let Some(t) = work.pop() {
            if self.st[t] == St::Ready {
                self.advance(t, now, work);
            }
        }
    }

    fn run(mut self) -> DpuTiming {
        let mut now = 0.0f64;
        // kick off: process every tasklet from the start of its trace
        let mut wl: Vec<usize> = Vec::new();
        for t in 0..self.n {
            if self.st[t] == St::Ready {
                self.advance(t, now, &mut wl);
            }
        }
        self.drain_worklist(now, &mut wl);

        loop {
            // active compute tasklets
            let a = self.st.iter().filter(|s| matches!(s, St::Compute { .. })).count();
            if a == 0 && self.dma_inflight.is_empty() {
                if self.st.iter().all(|s| *s == St::Done) {
                    break;
                }
                panic!(
                    "timing deadlock at cycle {now}: states {:?}",
                    self.st.iter().enumerate().collect::<Vec<_>>()
                );
            }
            let per_instr = self.arch.dispatch_interval.max(a as u32) as f64;
            // next event time
            let mut t_next = f64::INFINITY;
            for s in &self.st {
                if let St::Compute { rem } = s {
                    t_next = t_next.min(now + rem * per_instr);
                }
            }
            for &(_, fin) in &self.dma_inflight {
                t_next = t_next.min(fin);
            }
            debug_assert!(t_next.is_finite());
            let dt = t_next - now;
            // progress all computing tasklets
            if dt > 0.0 {
                for s in self.st.iter_mut() {
                    if let St::Compute { rem } = s {
                        *rem = (*rem - dt / per_instr).max(0.0);
                    }
                }
            }
            now = t_next;
            // completions
            let mut wl: Vec<usize> = Vec::new();
            for t in 0..self.n {
                if let St::Compute { rem } = self.st[t] {
                    if rem <= EPS {
                        self.st[t] = St::Ready;
                        self.advance(t, now, &mut wl);
                    }
                }
            }
            let mut i = 0;
            while i < self.dma_inflight.len() {
                let (t, fin) = self.dma_inflight[i];
                if fin <= now + EPS {
                    self.dma_inflight.swap_remove(i);
                    self.st[t] = St::Ready;
                    self.advance(t, now, &mut wl);
                } else {
                    i += 1;
                }
            }
            self.drain_worklist(now, &mut wl);
        }
        self.out.cycles = now;
        self.out
    }
}

/// Replay the traces of one DPU launch and return cycle accounting.
///
/// `n_tasklets` must equal `traces.len()` (barrier arity).
pub fn replay(traces: &[Trace], arch: &DpuArch, n_tasklets: u32) -> DpuTiming {
    assert_eq!(traces.len(), n_tasklets as usize);
    if traces.iter().all(|t| t.events.is_empty()) {
        return DpuTiming::default();
    }
    Engine::new(traces, arch).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::DpuArch;

    fn arch() -> DpuArch {
        DpuArch::p21()
    }

    fn compute_trace(instrs: u64) -> Trace {
        let mut t = Trace::default();
        t.push_compute(instrs);
        t
    }

    #[test]
    fn single_tasklet_dispatch_interval() {
        // 1 tasklet, n instructions → n * 11 cycles.
        let tm = replay(&[compute_trace(1000)], &arch(), 1);
        assert!((tm.cycles - 11_000.0).abs() < 1.0, "{}", tm.cycles);
    }

    #[test]
    fn pipeline_saturates_at_11_tasklets() {
        // T tasklets × n instrs: cycles = n*11 for T ≤ 11, n*T beyond.
        for t in [1u32, 2, 4, 8, 11, 16, 24] {
            let traces: Vec<Trace> = (0..t).map(|_| compute_trace(1000)).collect();
            let tm = replay(&traces, &arch(), t);
            let expect = 1000.0 * t.max(11) as f64;
            assert!(
                (tm.cycles - expect).abs() / expect < 0.01,
                "T={t}: {} vs {expect}",
                tm.cycles
            );
        }
    }

    #[test]
    fn throughput_matches_eq1_at_saturation() {
        // 16 tasklets of 32-bit adds: 58.33 MOPS at 350 MHz.
        let n_elem = 10_000u64;
        let traces: Vec<Trace> = (0..16).map(|_| compute_trace(n_elem * 6)).collect();
        let tm = replay(&traces, &arch(), 16);
        let secs = arch().cycles_to_secs(tm.cycles);
        let mops = (16.0 * n_elem as f64) / secs / 1e6;
        assert!((mops - 58.33).abs() < 0.5, "mops {mops}");
    }

    #[test]
    fn dma_serialization() {
        // 4 tasklets each issuing one 2048-B read: the engine starts a new
        // transfer every occupancy = 36 + 1024 cycles; the last tasklet
        // resumes at 3×1060 + (77 + 1024).
        let mk = || {
            let mut t = Trace::default();
            t.push(Ev::DmaRead(2048));
            t
        };
        let traces = vec![mk(), mk(), mk(), mk()];
        let tm = replay(&traces, &arch(), 4);
        let expect = 3.0 * (36.0 + 1024.0) + (77.0 + 1024.0);
        assert!((tm.cycles - expect).abs() < 1.0, "{} vs {expect}", tm.cycles);
        assert!(tm.dma_util() > 0.98);
    }

    #[test]
    fn fine_grained_random_access_bandwidth() {
        // Fig. 8b: 16 tasklets doing 8-B read + 8-B write per element →
        // engine-throughput-bound ≈ 70 MB/s (paper: 72.58 MB/s).
        let mk = || {
            let mut t = Trace::default();
            for _ in 0..100 {
                t.push(Ev::DmaRead(8));
                t.push_compute(8);
                t.push(Ev::DmaWrite(8));
            }
            t
        };
        let traces: Vec<Trace> = (0..16).map(|_| mk()).collect();
        let tm = replay(&traces, &arch(), 16);
        let secs = arch().cycles_to_secs(tm.cycles);
        let bw = tm.dma_bytes as f64 / secs / 1e6;
        assert!((bw - 72.58).abs() < 8.0, "fine-grained bw {bw} MB/s (paper 72.58)");
    }

    #[test]
    fn dma_overlaps_compute() {
        // tasklet 0: long compute; tasklet 1: one DMA. Total = max, not sum.
        let mut t0 = Trace::default();
        t0.push_compute(10_000);
        let mut t1 = Trace::default();
        t1.push(Ev::DmaRead(2048));
        let tm = replay(&[t0, t1], &arch(), 2);
        assert!((tm.cycles - 110_000.0).abs() < 2.0, "{}", tm.cycles);
    }

    #[test]
    fn mutex_serializes_critical_sections() {
        // 4 tasklets: lock, 1000 instrs, unlock. Critical sections cannot
        // overlap → ≥ 4 × 1000 × dispatch/of-active... with FIFO handoff the
        // total is ≈ 4 × 11,000 (only the holder computes at a time).
        let mk = || {
            let mut t = Trace::default();
            t.push(Ev::MutexLock(0));
            t.push_compute(1000);
            t.push(Ev::MutexUnlock(0));
            t
        };
        let traces = vec![mk(), mk(), mk(), mk()];
        let tm = replay(&traces, &arch(), 4);
        assert!(tm.cycles >= 4.0 * 11_000.0 - 1.0, "{}", tm.cycles);
    }

    #[test]
    fn barrier_joins() {
        // tasklet 0 computes 100, tasklet 1 computes 10_000, both barrier,
        // then each computes 100. End ≈ 10_000*? .. both finish ≈ barrier
        // release + tail.
        let mk = |n: u64| {
            let mut t = Trace::default();
            t.push_compute(n);
            t.push(Ev::Barrier(0));
            t.push_compute(100);
            t
        };
        let tm = replay(&[mk(100), mk(10_000)], &arch(), 2);
        // slow tasklet: 10_000×11 (alone after fast one waits: rate still 1/11)
        // then both compute 100 more: +100×11
        let expect = 10_000.0 * 11.0 + 100.0 * 11.0;
        assert!((tm.cycles - expect).abs() / expect < 0.05, "{} vs {expect}", tm.cycles);
    }

    #[test]
    fn handshake_orders_pair() {
        // t1 waits for t0's notify before computing.
        let mut t0 = Trace::default();
        t0.push_compute(5000);
        t0.push(Ev::HsNotify);
        let mut t1 = Trace::default();
        t1.push(Ev::HsWait { peer: 0, target: 1 });
        t1.push_compute(5000);
        let tm = replay(&[t0, t1], &arch(), 2);
        // serial: ≈ 2 × 5000 × 11
        assert!(tm.cycles > 2.0 * 5000.0 * 11.0 * 0.95, "{}", tm.cycles);
    }

    #[test]
    fn semaphore_blocks_until_give() {
        let mut t0 = Trace::default();
        t0.push_compute(3000);
        t0.push(Ev::SemGive(1));
        let mut t1 = Trace::default();
        t1.push(Ev::SemTake(1));
        t1.push_compute(10);
        let tm = replay(&[t0, t1], &arch(), 2);
        assert!(tm.cycles >= 3000.0 * 11.0, "{}", tm.cycles);
    }

    #[test]
    fn empty_traces_zero_cycles() {
        let tm = replay(&[Trace::default(), Trace::default()], &arch(), 2);
        assert_eq!(tm.cycles, 0.0);
    }

    #[test]
    fn copy_dma_bandwidth_two_tasklets() {
        // COPY-DMA: read 1024 + write 1024 per block. With 2 tasklets the
        // DMA engine is always busy → bw ≈ 1024/(36+512) B/cy ≈ 654 MB/s
        // at 350 MHz (paper measures 624 MB/s, 4.8% below; theoretical
        // 2 B/cy bound is 700 MB/s).
        let blocks = 200u32;
        let mk = || {
            let mut t = Trace::default();
            for _ in 0..blocks {
                t.push(Ev::DmaRead(1024));
                t.push(Ev::DmaWrite(1024));
            }
            t
        };
        let traces = vec![mk(), mk()];
        let tm = replay(&traces, &arch(), 2);
        let secs = arch().cycles_to_secs(tm.cycles);
        let bw = tm.dma_bytes as f64 / secs;
        assert!(
            (bw / 1e6 - 624.0).abs() < 40.0,
            "COPY-DMA bw {} MB/s (paper: 624)",
            bw / 1e6
        );
        assert!(bw < arch().peak_mram_bw(), "must stay under the 2 B/cy roof");
    }
}
